"""Migration planning between two partitions of the same stream.

A drift-triggered full repartition hands back fresh labels that have no
relation to the old ones: applied naively, nearly every example and server
set would "move".  The planner matches new→old parts by greedy maximum
weight on the ``(k, k)`` packed intersection matrix

    M[i, j] = |S_new_i ∩ S_old_j|     (popcounts over packed words)

and relabels the new partition through that matching — quality is
label-invariant, so the relabeled partition is the same partition, but
machine j now keeps the new part whose working set overlaps its resident
set most.  What still differs after relabeling is the true migration cost,
metered in the same units as ``TrafficCounters`` (bitmask-word bytes, 4
bytes per 32 parameters) and reported in its ``migration_bytes`` field so
recovery traffic never pollutes the steady-state push/pull counters: the
packed words each machine must newly acquire (``packed_delta(new, old)``),
the words it can retire, and moved U rows as delta-encoded example traffic
when degrees are provided.  ``MigrationPlan.acquired_bytes`` /
``retired_bytes`` keep the two directions separable.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..api_backends import TrafficCounters
from ..kernels.parsa_cost import packed_delta, packed_intersect_counts

__all__ = ["MigrationPlan", "plan_migration"]


@dataclasses.dataclass(frozen=True)
class MigrationPlan:
    """Relabeling + metered cost of swapping a live partition for a new one.

    ``assign[i]`` is the old label that new part ``i`` takes over, so the
    relabeled assignment is ``parts = assign[new_parts]`` and machine
    ``assign[i]`` hosts new part ``i``.
    """

    assign: np.ndarray          # (k,) int32 — new part i → old label
    parts_u: np.ndarray         # (|U|,) int32 relabeled new assignment
    s_masks: np.ndarray         # (k, W) int32 relabeled new server sets
    moved_u: int                # examples whose machine changed
    kept_overlap: int           # Σ_i M[i, assign[i]] — parameters retained
    traffic: TrafficCounters    # migration_bytes, TrafficCounters units
    acquired_bytes: int = 0     # words newly hosted (+ moved example rows)
    retired_bytes: int = 0      # words machines may drop


def _greedy_match(M: np.ndarray) -> np.ndarray:
    """Greedy maximum-weight perfect matching on a (k, k) score matrix:
    repeatedly take the globally largest unmatched cell.  Returns
    ``assign`` with ``assign[i] = j`` (row i matched to column j)."""
    k = M.shape[0]
    score = M.astype(np.int64).copy()
    assign = np.full(k, -1, np.int32)
    for _ in range(k):
        i, j = np.unravel_index(np.argmax(score), score.shape)
        assign[i] = j
        score[i, :] = -1
        score[:, j] = -1
    return assign


def plan_migration(
    new_parts: np.ndarray,
    new_masks: np.ndarray,
    old_parts: np.ndarray,
    old_masks: np.ndarray,
    degrees: np.ndarray | None = None,
) -> MigrationPlan:
    """Match a fresh partition onto the live one and meter the swap.

    ``old_parts`` may cover fewer U rows than ``new_parts`` (the stream
    grew since the old labels were assigned); only the common prefix counts
    toward ``moved_u``.  ``degrees``, when given (per-U edge counts of the
    common prefix), adds the moved rows' delta-encoded example bytes
    (4 bytes per edge) to ``pushed_bytes``.
    """
    new_parts = np.asarray(new_parts, np.int32)
    old_parts = np.asarray(old_parts, np.int32)
    new_masks = np.asarray(new_masks)
    old_masks = np.asarray(old_masks)
    k, W = new_masks.shape
    if old_masks.shape != (k, W):
        raise ValueError(
            f"old/new server sets disagree: {old_masks.shape} vs {(k, W)}")
    M = packed_intersect_counts(new_masks, old_masks)    # (k, k)
    assign = _greedy_match(M)
    parts = assign[new_parts]
    masks = np.zeros_like(new_masks)
    masks[assign] = new_masks                            # row assign[i] = new i
    n_common = min(old_parts.shape[0], parts.shape[0])
    moved = parts[:n_common] != old_parts[:n_common]
    moved_u = int(moved.sum())
    gained = int(np.count_nonzero(packed_delta(masks, old_masks)))
    dropped = int(np.count_nonzero(packed_delta(old_masks, masks)))
    acquired = 4 * gained
    if degrees is not None:
        degrees = np.asarray(degrees)
        acquired += 4 * int(degrees[:n_common][moved].sum())
    retired = 4 * dropped
    return MigrationPlan(
        assign=assign,
        parts_u=parts,
        s_masks=masks,
        moved_u=moved_u,
        kept_overlap=int(M[np.arange(k), assign].sum()),
        traffic=TrafficCounters(tasks=1,
                                migration_bytes=acquired + retired),
        acquired_bytes=acquired,
        retired_bytes=retired,
    )
