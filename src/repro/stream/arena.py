"""Growable device-resident graph/bitmask arena for streaming Parsa.

The arena is the mutable state a ``StreamSession`` partitions against as
U-vertex chunks arrive:

  * the live packed ``(k, W_cap)`` int32 server sets ``s_masks`` and the
    ``(k,)`` partition sizes — *device* arrays, donated into every feed's
    scan and replaced by its outputs, so the hot state never round-trips
    through the host between chunks;
  * the appended CSR edge structure of everything fed so far — *host*
    arrays with amortized O(1) appends (capacity doubling), used only for
    snapshots, drift-triggered full repartitions, and exact metrics.

Capacity doubling is what keeps the jit cache warm: the packed word width
``W_cap`` only changes when the parameter side outgrows the current
capacity, so a growing-V stream recompiles the feed scan O(log |V|) times
total instead of once per chunk.  All bits at columns ≥ ``num_v`` (the
ragged tail of the last logical word plus every capacity word beyond it)
are zero by construction — edges are validated against ``num_v`` on append
— and every packed operation downstream (``packed_union``/``packed_delta``/
the need paths) preserves that invariant (property-tested in
``tests/test_stream.py``).
"""
from __future__ import annotations

import pathlib

import numpy as np

from ..core.bipartite import BipartiteGraph

__all__ = ["StreamArena"]


class StreamArena:
    """Append-only bipartite graph + live packed partition state.

    ``num_v`` is the *logical* parameter-side extent (it may grow as chunks
    introduce new columns); ``W_cap`` the capacity in packed 32-bit words.
    ``s_masks``/``sizes`` live on device and are owned by the session's
    feed loop — read them through ``masks_np()`` when a host view is
    needed.
    """

    def __init__(self, k: int, num_v: int, u_capacity: int = 1024,
                 edge_capacity: int = 4096):
        import jax.numpy as jnp  # lazy: keep host-only imports jax-free

        if k <= 0:
            raise ValueError(f"k must be positive, got {k}")
        if num_v <= 0:
            raise ValueError(f"num_v must be positive, got {num_v}")
        self.k = k
        self.num_v = num_v
        self.W_cap = (num_v + 31) // 32
        self.s_masks = jnp.zeros((k, self.W_cap), jnp.int32)
        self.sizes = jnp.zeros((k,), jnp.int32)
        self.num_u = 0
        self._nnz = 0
        self._indptr = np.zeros(max(2, u_capacity + 1), np.int64)
        self._indices = np.empty(max(1, edge_capacity), np.int32)

    # ------------------------------------------------------------- growth
    @property
    def capacity_v(self) -> int:
        """Column capacity in bits (W_cap * 32) — the packing width."""
        return self.W_cap * 32

    def _grow_v(self, num_v_new: int) -> bool:
        """Raise the logical V extent; double ``W_cap`` (and zero-pad the
        live ``s_masks``) only when the new extent outgrows the capacity.
        Returns True when the packed width changed (the feed scan will
        recompile once)."""
        import jax.numpy as jnp

        self.num_v = max(self.num_v, num_v_new)
        W_need = (self.num_v + 31) // 32
        if W_need <= self.W_cap:
            return False
        W_new = self.W_cap
        while W_new < W_need:
            W_new *= 2
        self.s_masks = jnp.pad(self.s_masks, [(0, 0), (0, W_new - self.W_cap)])
        self.W_cap = W_new
        return True

    def prepare(self, chunk: BipartiteGraph) -> None:
        """Validate a chunk and grow the V capacity for it WITHOUT
        appending.  The session packs and scans against the prepared
        capacity first and appends only after the scan succeeds, so a
        mid-feed failure leaves the appended graph state untouched
        (capacity growth alone is benign: wider zero words change no
        objective)."""
        if chunk.num_edges and int(chunk.u_indices.max()) >= chunk.num_v:
            raise ValueError("chunk edge column exceeds its declared num_v")
        self._grow_v(chunk.num_v)

    def append(self, chunk: BipartiteGraph) -> tuple[int, int]:
        """Append a chunk's U rows (V ids are global, §4.2).  Returns the
        global U-id range ``(start, stop)`` the chunk now occupies.  Grows
        the V extent when the chunk references new columns."""
        self.prepare(chunk)
        start, n, e = self.num_u, chunk.num_u, chunk.num_edges
        if start + n + 1 > self._indptr.shape[0]:
            cap = max(1, self._indptr.shape[0])  # restored snapshots may
            while cap < start + n + 1:           # carry zero-length buffers
                cap *= 2
            self._indptr = np.concatenate(
                [self._indptr, np.zeros(cap - self._indptr.shape[0], np.int64)])
        if self._nnz + e > self._indices.shape[0]:
            cap = max(1, self._indices.shape[0])
            while cap < self._nnz + e:
                cap *= 2
            self._indices = np.concatenate(
                [self._indices,
                 np.empty(cap - self._indices.shape[0], np.int32)])
        self._indptr[start + 1 : start + n + 1] = \
            self._nnz + np.asarray(chunk.u_indptr[1:], np.int64)
        self._indices[self._nnz : self._nnz + e] = chunk.u_indices
        self.num_u += n
        self._nnz += e
        return start, start + n

    # --------------------------------------------------------- elasticity
    def set_partition_state(self, s_masks, sizes, k: int) -> None:
        """Swap in new live partition state, possibly at a different
        machine count ``k`` — capacity-stable: the packed width stays
        ``W_cap`` so the per-k jit cache survives grow/shrink/repair.
        Callers own the padding-bit invariant (columns ≥ ``num_v`` zero);
        masks derived from existing rows via OR/delta or produced by the
        feed scan preserve it by construction."""
        if k <= 0:
            raise ValueError(f"k must be positive, got {k}")
        if s_masks.shape != (k, self.W_cap):
            raise ValueError(
                f"s_masks must be ({k}, {self.W_cap}), got {s_masks.shape}")
        if sizes.shape != (k,):
            raise ValueError(f"sizes must be ({k},), got {sizes.shape}")
        self.k = k
        self.s_masks = s_masks
        self.sizes = sizes

    # ------------------------------------------------------------- views
    def graph(self) -> BipartiteGraph:
        """Snapshot of everything fed so far (trimmed views, logical V)."""
        return BipartiteGraph(
            self.num_u, self.num_v,
            self._indptr[: self.num_u + 1].copy(),
            self._indices[: self._nnz].copy())

    def capacity_graph(self, chunk: BipartiteGraph) -> BipartiteGraph:
        """The chunk re-declared at the arena's packing width: ``num_v`` is
        ``capacity_v`` so ``pack_graph_blocks`` emits (…, W_cap) word lists
        matching the live ``s_masks``.  Columns stay < logical ``num_v``,
        so every capacity-padding bit is zero."""
        return BipartiteGraph(chunk.num_u, self.capacity_v,
                              chunk.u_indptr, chunk.u_indices)

    def masks_np(self, logical: bool = True) -> np.ndarray:
        """Host copy of the live server sets; ``logical=True`` trims the
        capacity padding to the (k, ceil(num_v/32)) wire shape."""
        m = np.asarray(self.s_masks)
        if logical:
            m = m[:, : (self.num_v + 31) // 32]
        return m

    # ---------------------------------------------------------- snapshot
    def state_arrays(self) -> dict[str, np.ndarray | int]:
        """The arena's persistent fields as plain arrays (the npz payload
        shared by ``save`` and ``StreamSession.save``)."""
        return dict(
            k=self.k, num_u=self.num_u, num_v=self.num_v,
            u_indptr=self._indptr[: self.num_u + 1],
            u_indices=self._indices[: self._nnz],
            s_masks=self.masks_np(logical=False),
            sizes=np.asarray(self.sizes))

    def save(self, path: str | pathlib.Path) -> None:
        """Snapshot the graph + live server sets/sizes (companion of
        ``BipartiteGraph.save_npz`` for the arena).  NOTE: the per-vertex
        ``parts`` assignment is *session* state — use
        ``StreamSession.save`` to snapshot a restorable stream."""
        np.savez_compressed(path, **self.state_arrays())

    @classmethod
    def from_state(cls, z) -> "StreamArena":
        """Rebuild an arena from a ``state_arrays()``-shaped mapping."""
        import jax.numpy as jnp

        arena = cls(int(z["k"]), int(z["num_v"]))
        arena.num_u = int(z["num_u"])
        arena._indptr = np.asarray(z["u_indptr"], np.int64)
        arena._indices = np.asarray(z["u_indices"], np.int32)
        arena._nnz = int(arena._indptr[-1])
        arena.W_cap = int(z["s_masks"].shape[1])
        arena.s_masks = jnp.asarray(z["s_masks"], jnp.int32)
        arena.sizes = jnp.asarray(z["sizes"], jnp.int32)
        return arena

    @classmethod
    def load(cls, path: str | pathlib.Path) -> "StreamArena":
        return cls.from_state(np.load(path))
