"""``repro.stream``: online incremental Parsa over growing graphs.

One session per stream; feeds are O(1) device dispatches against the live
packed server sets; drift-triggered repartitions are matched back onto the
live labels with metered migration.  See ``online.py`` for the full story.
"""
from .arena import StreamArena  # noqa: F401
from .drift import DriftDecision, DriftTracker  # noqa: F401
from .migrate import MigrationPlan, plan_migration  # noqa: F401
from .online import (  # noqa: F401
    ParsaStreamConfig,
    StreamSession,
    StreamUpdate,
    stream_partition,
)
