"""Sliding-window objective tracking for streaming Parsa (drift detection).

Online greedy never reshuffles vertices it has already placed, so as the
arriving distribution drifts (topic drift, campaign churn, preferential
attachment) the live partition's objective decays relative to what a fresh
partition of the same graph would achieve.  The tracker watches the only
signal that is free to compute every feed — the PR 4 popcount metrics over
the live packed sets (objective (6)/(7) with ``parts_v=None``:
``traffic_max`` = max footprint) — and triggers a repartition when the
*imbalance ratio*

    drift = traffic_max · k / traffic_sum   (= max footprint / mean)

degrades past ``threshold`` × the best ratio seen inside a sliding window
of recent feeds.  The ratio is scale-free: footprints grow monotonically
with the stream, so comparing raw ``traffic_max`` across feeds would
always "degrade"; the max/mean ratio only rises when growth concentrates
on one machine — exactly the failure mode a repartition fixes.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..core.costs import PartitionMetrics

__all__ = ["DriftTracker", "DriftDecision"]


@dataclasses.dataclass(frozen=True)
class DriftDecision:
    """One tracker update: the imbalance observed and whether it tripped."""

    drift: float               # max/mean footprint ratio this feed
    baseline: float            # windowed-mean ratio (filled entries only)
    repartition: bool


class DriftTracker:
    """Sliding-window drift detector over per-feed ``PartitionMetrics``.

    ``window`` is how many recent feeds the baseline mean spans;
    ``threshold`` the multiplicative degradation that trips a repartition
    (1.0 = trip on any strict degradation past the windowed mean);
    ``min_feeds`` suppresses triggers until enough history exists.

    Cold-window behavior: the ring buffer is seeded *lazily* — the
    baseline is the mean over the entries actually observed so far, never
    over preallocated zeros.  A naive fixed-window mean would average in
    zeros before the window fills, deflating the baseline and tripping a
    repartition on the first feeds of every stream (and right after every
    ``reset``), exactly when a repartition is pointless.
    """

    def __init__(self, window: int = 8, threshold: float = 1.15,
                 min_feeds: int = 2):
        if window < 1:
            raise ValueError(f"window must be >= 1, got {window}")
        if threshold < 1.0:
            raise ValueError(f"threshold must be >= 1.0, got {threshold}")
        if min_feeds < 1:
            raise ValueError(f"min_feeds must be >= 1, got {min_feeds}")
        self.window = window
        self.threshold = threshold
        self.min_feeds = min_feeds
        self._ring = np.zeros(window, np.float64)
        self._count = 0      # observations since the last reset

    def _baseline(self, drift: float) -> float:
        filled = min(self._count, self.window)
        if filled == 0:
            return drift     # lazy seed: first observation is its own bar
        if filled < self.window:
            return float(self._ring[:filled].mean())
        return float(self._ring.mean())

    def update(self, metrics: PartitionMetrics) -> DriftDecision:
        """Record one feed's metrics; decide whether to repartition."""
        total = max(int(metrics.traffic_sum), 1)
        drift = metrics.traffic_max * metrics.k / total
        baseline = self._baseline(drift)
        trip = (self._count >= self.min_feeds
                and drift > self.threshold * baseline)
        self._ring[self._count % self.window] = drift
        self._count += 1
        if trip:
            self.reset()
        return DriftDecision(drift=drift, baseline=baseline, repartition=trip)

    def reset(self) -> None:
        """Forget the window (called after a repartition relevels the
        baseline — the post-repartition ratio starts a fresh window)."""
        self._count = 0
