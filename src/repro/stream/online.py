r"""Online incremental Parsa: partition a growing graph chunk by chunk.

The paper's blocked greedy (§4.2) is already an online algorithm — every
block is assigned against the live neighbor sets and never revisited — so
a *streaming* partitioner needs no new math, only new plumbing: keep the
packed ``(k, W)`` server sets resident on device across arrivals and run
each arriving chunk through the existing fused cost+select scan with the
live sets as the carry.

    session = StreamSession(ParsaStreamConfig(base=ParsaConfig(
        k=16, backend="device_scan")), num_v=65_536)
    for chunk in arriving_graphs:          # BipartiteGraph chunks
        upd = session.feed(chunk)          # ONE scan dispatch (asserted)
        upd.parts, upd.metrics             # incremental delta
    res = session.result()                 # full PartitionResult

``feed`` is O(chunk) work and O(1) XLA dispatches: one ``_partition_scan``
launch (the same jitted program ``device_scan`` runs, carries donated) plus
one popcount-metrics launch.  Same-shaped chunks hit the jit cache; the
truncated-row side channel is padded to powers of two (``tb_pad``) so data
jitter does not retrigger compilation.  With ``workers > 1`` the chunk's
blocks fan out across the ``parallel_device`` mesh through the cached
shard_map pipeline, with *randomized* block→worker assignment
(arXiv:1502.02606: random data distribution preserves the distributed
greedy's approximation guarantees in expectation) and OR-merges every
``merge_every`` blocks.

Drift repair: assignments are never revisited by ``feed``, so under
distribution drift the partition decays.  A ``DriftTracker`` watches the
per-feed popcount metrics and triggers ``repartition()`` — a warm-started
(§4.4 global-initialization) full repartition of the arena — whose result
is matched back onto the old labels by ``plan_migration`` so serving
machines keep the part closest to what they already host, with migration
bytes metered in ``TrafficCounters`` units.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Iterable

import numpy as np

from ..api import ParsaConfig, PartitionResult
from ..api_backends import TrafficCounters
from ..core.bipartite import BipartiteGraph
from ..core.costs import PartitionMetrics
from ..core.jax_partition import (
    _count_dispatch,
    _partition_scan,
    _run_parallel_packed_scan,
    blocked_partition_u_impl,
    pack_graph_blocks,
    parallel_blocked_partition_u_impl,
)
from ..core.parallel import global_initialization
from ..kernels.parsa_cost import coerce_packed_sets
from .arena import StreamArena
from .drift import DriftDecision, DriftTracker
from .migrate import MigrationPlan, plan_migration

__all__ = ["ParsaStreamConfig", "StreamSession", "StreamUpdate",
           "stream_partition"]

_STREAM_BACKENDS = ("device_scan", "parallel_device")


@dataclasses.dataclass(frozen=True)
class ParsaStreamConfig:
    """Streaming knobs on top of a device ``ParsaConfig``.

    ``base`` supplies the partitioning knobs the feed scan shares with the
    one-shot pipeline (k, block_size, cap, use_kernel/interpret, seed;
    workers/merge_every/devices when ``base.backend == "parallel_device"``).
    The stream fields control drift repair and shape stability.
    """

    base: ParsaConfig
    drift_window: int = 8          # feeds the drift baseline spans
    drift_threshold: float = 1.15  # degradation ratio that trips repair
    drift_min_feeds: int = 2       # history before a trigger is allowed
    repartition: str = "drift"     # "drift" (auto) | "never" (manual only)
    repartition_frac: float = 0.02  # §4.4 global-init sample; 0 = cold
    tb_pad: int = 8                # truncated-row channel pad (pow2 bucket)
    shuffle_blocks: bool = True    # randomized block→worker assignment

    def __post_init__(self):
        if self.base.backend not in _STREAM_BACKENDS:
            raise ValueError(
                f"streaming needs a device backend {_STREAM_BACKENDS}, got "
                f"base.backend={self.base.backend!r}")
        if self.repartition not in ("drift", "never"):
            raise ValueError(
                f"repartition must be 'drift' or 'never', got "
                f"{self.repartition!r}")
        if not 0.0 <= self.repartition_frac <= 1.0:
            raise ValueError(
                f"repartition_frac must be in [0, 1], got "
                f"{self.repartition_frac}")
        if self.tb_pad < 1:
            raise ValueError(f"tb_pad must be >= 1, got {self.tb_pad}")
        # window/threshold/min_feeds: fail at construction, not first feed
        DriftTracker(self.drift_window, self.drift_threshold,
                     self.drift_min_feeds)

    @property
    def workers(self) -> int:
        if self.base.backend != "parallel_device":
            return 1
        return (self.base.devices if self.base.devices is not None
                else self.base.workers)

    def replace(self, **changes) -> "ParsaStreamConfig":
        return dataclasses.replace(self, **changes)


@dataclasses.dataclass
class StreamUpdate:
    """Incremental ``PartitionResult`` delta for one fed chunk."""

    chunk: int                      # feed ordinal
    u_start: int                    # global U-id range this chunk occupies
    u_stop: int
    parts: np.ndarray               # (u_stop - u_start,) int32 assignments
    metrics: PartitionMetrics       # popcount objectives after this feed
    drift: DriftDecision | None     # None when repartition == "never"
    repartitioned: bool
    migration: MigrationPlan | None  # set when this feed triggered repair
    traffic: TrafficCounters | None  # parallel feeds: push/pull this feed
    timings: dict[str, float]
    dispatches: dict[str, int]      # device launches issued by this feed


class StreamSession:
    """Partition a graph that grows over time, entirely on device.

    The live state (packed server sets + sizes) never leaves the device
    between feeds; the arena keeps the appended CSR on the host for
    snapshots, repartitions, and exact metrics.  ``parts`` holds the
    current assignment of every fed U vertex (relabeled in place when a
    drift repair lands).
    """

    def __init__(self, config: ParsaStreamConfig, num_v: int, obs=None):
        self.obs = obs   # repro.obs.Observability hook; None = off
        if config.workers > 1:
            # fail at construction, not mid-stream
            from ..core.jax_partition import resolve_worker_devices

            resolve_worker_devices(config.workers)
        self.config = config
        self.k = config.base.k
        # Sketched arenas (base.set_repr="sketch"): the live sets, the
        # appended CSR, and every scan run at the sketched width.  Streams
        # use the IDENTITY hot prefix [0, hot_bits) — a footprint ranking
        # cannot see future data — and the hash covers arbitrary column
        # ids, so V growth is free: the arena width never grows in sketch
        # mode.  ``self.sketch`` stays None when the spec collapses to the
        # exact identity (hot_bits ≥ num_v), keeping bit-parity for free.
        self.sketch = None
        self._true_num_v = num_v
        arena_v = num_v
        base = config.base
        if getattr(base, "set_repr", "exact") == "sketch":
            from ..sketch import SketchSpec

            spec = SketchSpec.for_graph(
                num_v, base.sketch_hot_bits, base.sketch_bucket_bits,
                seed=base.seed)
            if not spec.is_exact:
                self.sketch = spec
                arena_v = spec.width_bits
        self.arena = StreamArena(config.base.k, arena_v)
        self._parts_buf = np.empty(1024, np.int32)  # doubles with the arena
        self.tracker = DriftTracker(config.drift_window,
                                    config.drift_threshold,
                                    config.drift_min_feeds)
        self._rng = np.random.default_rng(config.base.seed)
        self.n_feeds = 0
        self.repartitions = 0
        # S_i == N(U_i) holds for pure cold streaming; a §4.4-seeded
        # repartition may add sampled bits, after which popcount metrics
        # over s_masks are an upper bound and result() recomputes exactly.
        self._need_exact = True
        self._pushed = 0
        self._pulled = 0
        self._tasks = 0
        self._stale = 0
        self._migrated = 0

    # ------------------------------------------------------------- feeding
    def feed(self, chunk: BipartiteGraph,
             worker_weights: np.ndarray | None = None) -> StreamUpdate:
        """Assign one arriving chunk of U vertices against the live sets.

        ``worker_weights`` (parallel feeds only) biases the randomized
        block→worker assignment toward faster workers — see
        ``_run_parallel_packed_scan``; the elastic layer supplies an EWMA
        of per-worker scan times here so stragglers receive fewer blocks.

        One jitted scan dispatch (plus one popcount-metrics dispatch) per
        call, O(1) in both stream length and chunk count — asserted via
        ``dispatch_counter`` in tests and CI.  May additionally run a
        drift-triggered ``repartition()`` before returning.

        Failure atomicity: the chunk is appended to the arena only AFTER
        its scan succeeds, so an error while packing or launching leaves
        the session's graph and parts consistent (retry-safe).  The live
        server sets are donated into the dispatch itself — a failure
        *inside* the launch remains unrecoverable, like any donated-carry
        jax program.
        """
        import jax.numpy as jnp

        from ..core.jax_partition import dispatch_counter

        base = self.config.base
        timings: dict[str, float] = {}
        t_total = time.perf_counter()
        with dispatch_counter() as counts:
            n = chunk.num_u
            if self.sketch is not None:
                # host column remap only — the scan below stays one dispatch
                self._true_num_v = max(self._true_num_v, chunk.num_v)
                chunk = self.sketch.sketch_graph(chunk)
            self.arena.prepare(chunk)   # validate + capacity growth only
            order = self._rng.permutation(n)
            t0 = time.perf_counter()
            packed = pack_graph_blocks(
                self.arena.capacity_graph(chunk), base.block_size,
                order=order, cap=base.cap, tb_pad=self.config.tb_pad)
            timings["pack"] = time.perf_counter() - t0

            t0 = time.perf_counter()
            traffic = None
            if self.config.workers == 1:
                _count_dispatch(
                    "stream_feed_scan",
                    nbytes=(int(self.arena.s_masks.nbytes)
                            + int(self.arena.sizes.nbytes)),
                    k=self.k)
                parts_blocks, s_out, sz_out = _partition_scan(
                    jnp.asarray(packed.valid), jnp.asarray(packed.widx),
                    jnp.asarray(packed.vals), jnp.asarray(packed.trunc),
                    jnp.asarray(packed.tr_ids), jnp.asarray(packed.tr_masks),
                    self.arena.s_masks, self.arena.sizes,
                    k=self.k, use_kernel=base.use_kernel,
                    interpret=base.interpret,
                    sketch=self.sketch is not None)
                flat = np.asarray(parts_blocks).reshape(-1)[:n]
            else:
                flat, s_out, sz_out, traffic = self._feed_parallel(
                    packed, n, worker_weights)
            # scan succeeded — commit: live sets, CSR append, parts
            self.arena.s_masks, self.arena.sizes = s_out, sz_out
            u_start, u_stop = self.arena.append(chunk)
            parts_chunk = np.empty(n, np.int32)
            parts_chunk[order] = flat
            self._store_parts(u_start, parts_chunk)
            timings["partition_u"] = time.perf_counter() - t0

            t0 = time.perf_counter()
            metrics = self._popcount_metrics()
            timings["metrics"] = time.perf_counter() - t0

            decision = migration = None
            if self.config.repartition == "drift":
                decision = self.tracker.update(metrics)
                if decision.repartition:
                    t0 = time.perf_counter()
                    migration = self.repartition()
                    timings["repartition"] = time.perf_counter() - t0
                    metrics = self._popcount_metrics()
        self.n_feeds += 1
        timings["total"] = time.perf_counter() - t_total
        dispatches = {name: c for name, c in counts.items() if c}
        if self.obs is not None:
            self._trace_feed(n, u_start, u_stop, timings,
                             repartitioned=migration is not None)
        return StreamUpdate(
            chunk=self.n_feeds - 1, u_start=u_start, u_stop=u_stop,
            parts=self.parts[u_start:u_stop].copy(), metrics=metrics,
            drift=decision, repartitioned=migration is not None,
            migration=migration, traffic=traffic, timings=timings,
            dispatches=dispatches)

    def _trace_feed(self, n: int, u_start: int, u_stop: int,
                    timings: dict, repartitioned: bool) -> None:
        """Emit the ``feed → pack/scan(/merge)/metrics`` span tree.

        A feed has no modeled duration (it is host work, not a priced
        transfer), so the span occupies one fixed virtual unit with
        children at fixed fractions — deterministic across replays — and
        the measured phase seconds attached as ``wall_s`` evidence."""
        tr = self.obs.tracer
        sp = tr.begin("feed", v_start=tr.now, v_dur=1.0, track="stream",
                      feed=self.n_feeds - 1, rows=n, u_start=u_start,
                      u_stop=u_stop, k=self.k,
                      wall_s=timings.get("total"))
        sp.child("pack", 0.0, 0.25, wall_s=timings.get("pack"))
        sp.child("scan", 0.25, 0.45, wall_s=timings.get("partition_u"),
                 workers=self.config.workers)
        if self.config.workers > 1:
            # the all_gather + OR union-push folded into the parallel scan
            sp.child("merge", 0.7, 0.1,
                     merge_every=self.config.base.merge_every)
        sp.child("metrics", 0.8, 0.1, wall_s=timings.get("metrics"))
        if repartitioned:
            sp.child("repartition", 0.9, 0.1,
                     wall_s=timings.get("repartition"))
        tr.advance(1.0)

    def _feed_parallel(self, packed, n: int,
                       worker_weights: np.ndarray | None = None):
        """Fan one chunk's blocks across the worker mesh: the shared Alg 4
        core (``_run_parallel_packed_scan``) with randomized block→worker
        assignment, against the live donated (S, sizes)."""
        base = self.config.base
        workers = self.config.workers
        shuffle = (self._rng if self.config.shuffle_blocks and workers > 1
                   else None)
        parts_blocks, s_out, sz_out, traffic_d, perm = \
            _run_parallel_packed_scan(
                packed, self.arena.s_masks, self.arena.sizes, k=self.k,
                workers=workers, merge_every=base.merge_every,
                use_kernel=base.use_kernel, interpret=base.interpret,
                shuffle_rng=shuffle, worker_weights=worker_weights,
                count_name="stream_feed_scan",
                sketch=self.sketch is not None)
        B = packed.valid.shape[1]
        by_block = np.asarray(parts_blocks).reshape(-1, B)
        if perm is not None:
            by_block = by_block[np.argsort(perm)]
        flat = by_block.reshape(-1)[:n]
        traffic = TrafficCounters(**traffic_d)
        self._accumulate(traffic)
        return flat, s_out, sz_out, traffic

    @property
    def parts(self) -> np.ndarray:
        """Current assignment of every fed U vertex (view, not a copy)."""
        return self._parts_buf[: self.arena.num_u]

    def _store_parts(self, start: int, parts_chunk: np.ndarray) -> None:
        """Amortized-O(chunk) append: double the buffer like the arena
        does instead of re-concatenating the whole history every feed."""
        need = start + parts_chunk.shape[0]
        if need > self._parts_buf.shape[0]:
            cap = max(1, self._parts_buf.shape[0])
            while cap < need:
                cap *= 2
            buf = np.empty(cap, np.int32)
            buf[:start] = self._parts_buf[:start]
            self._parts_buf = buf
        self._parts_buf[start:need] = parts_chunk

    def _accumulate(self, t: TrafficCounters) -> None:
        self._pushed += t.pushed_bytes
        self._pulled += t.pulled_bytes
        self._tasks += t.tasks
        self._stale += t.stale_pushes_missed
        self._migrated += t.migration_bytes

    @property
    def traffic(self) -> TrafficCounters:
        """Cumulative session traffic: parallel-feed push/pull plus metered
        migration bytes, all in bitmask-word-byte units."""
        return TrafficCounters(self._pushed, self._pulled, self._tasks,
                               self._stale, self._migrated)

    # ------------------------------------------------------------- metrics
    def _popcount_metrics(self) -> PartitionMetrics:
        """Objectives (4)/(6) (+ the parts_v=None traffic convention) from
        the live packed sets — one tiny device launch, O(k·W)."""
        _count_dispatch("stream_metrics",
                        nbytes=int(self.arena.s_masks.nbytes))
        sizes, footprint = _popcount_rows(self.arena.s_masks,
                                          self.arena.sizes)
        sizes = np.asarray(sizes).astype(np.int64)
        footprint = np.asarray(footprint).astype(np.int64)
        return PartitionMetrics(self.k, sizes, footprint, footprint.copy(),
                                footprint.copy(), np.zeros(self.k, np.int64))

    # --------------------------------------------------------- drift repair
    def repartition(self) -> MigrationPlan:
        """Full repartition of everything fed so far, warm-started per §4.4
        (``repartition_frac`` sample seeds the sets; 0 = cold), matched back
        onto the live labels by the packed intersection matrix so serving
        machines keep their closest part.  Updates the live state in place
        and returns the metered ``MigrationPlan``."""
        import jax.numpy as jnp

        base = self.config.base
        g = self.arena.graph()
        old_parts = self.parts.copy()   # the buffer is overwritten below
        old_masks = self.arena.masks_np(logical=False)
        init_sets = None
        if self.config.repartition_frac > 0:
            dense = global_initialization(
                g, self.k, sample_frac=self.config.repartition_frac,
                theta=base.theta, select=base.select, seed=base.seed)
            packed = coerce_packed_sets(dense, g.num_v)
            init_sets = np.pad(
                packed, [(0, 0), (0, self.arena.W_cap - packed.shape[1])])
            self._need_exact = False
        g_cap = BipartiteGraph(g.num_u, self.arena.capacity_v,
                               g.u_indptr, g.u_indices)
        if self.config.workers > 1:
            new_parts, new_masks, scan_traffic = \
                parallel_blocked_partition_u_impl(
                    g_cap, self.k, workers=self.config.workers,
                    block=base.block_size, merge_every=base.merge_every,
                    init_sets=init_sets, use_kernel=base.use_kernel,
                    interpret=base.interpret, seed=base.seed, cap=base.cap,
                    sketch=self.sketch is not None)
            # the repair's own Alg 4 push/pull rides on the session total,
            # same units as the per-feed counters
            self._accumulate(TrafficCounters(**scan_traffic))
        else:
            new_parts, new_masks = blocked_partition_u_impl(
                g_cap, self.k, block=base.block_size, init_sets=init_sets,
                use_kernel=base.use_kernel, interpret=base.interpret,
                seed=base.seed, cap=base.cap,
                sketch=self.sketch is not None)
        plan = plan_migration(new_parts, new_masks, old_parts, old_masks,
                              degrees=g.degree_u())
        self._parts_buf[: plan.parts_u.shape[0]] = plan.parts_u
        self.arena.s_masks = jnp.asarray(plan.s_masks)
        self.arena.sizes = jnp.asarray(
            np.bincount(plan.parts_u, minlength=self.k).astype(np.int32))
        self._accumulate(plan.traffic)
        self.repartitions += 1
        self.tracker.reset()
        return plan

    # ----------------------------------------------------------- elasticity
    def apply_partition_state(self, parts_u: np.ndarray, s_masks,
                              sizes: np.ndarray | None = None,
                              k: int | None = None) -> None:
        """Commit an externally computed partition state, possibly with a
        different machine count ``k`` — the mid-run hook the elastic layer
        (``repro.elastic``) uses for grow/shrink/repair.

        ``s_masks`` must already be capacity-stable — shaped
        ``(k, arena.W_cap)`` with the padding-bit invariant intact (bits at
        columns ≥ ``num_v`` zero) — so subsequent feeds hit the same jit
        cache entry per k.  ``sizes`` defaults to the bincount of
        ``parts_u``.  The drift tracker resets: its baseline compares
        metrics at a fixed k, which just changed (or the partition was
        rebuilt in place).
        """
        import jax.numpy as jnp

        parts_u = np.asarray(parts_u, np.int32)
        if parts_u.shape[0] != self.arena.num_u:
            raise ValueError(
                f"parts_u covers {parts_u.shape[0]} U rows, arena holds "
                f"{self.arena.num_u}")
        new_k = self.k if k is None else int(k)
        masks_np = np.asarray(s_masks)
        if masks_np.shape != (new_k, self.arena.W_cap):
            raise ValueError(
                f"s_masks must be capacity-stable ({new_k}, "
                f"{self.arena.W_cap}), got {masks_np.shape}")
        if sizes is None:
            sizes = np.bincount(parts_u, minlength=new_k).astype(np.int32)
        self.k = new_k
        self.arena.set_partition_state(jnp.asarray(masks_np),
                                       jnp.asarray(np.asarray(sizes,
                                                              np.int32)),
                                       new_k)
        self._parts_buf[: parts_u.shape[0]] = parts_u
        self.tracker.reset()

    # ------------------------------------------------------------ snapshot
    def save(self, path) -> None:
        """Snapshot the FULL stream state — arena (graph + live sets),
        per-vertex parts, feed counters, and the RNG state — so ``load``
        resumes the stream exactly where it stopped (the next feed of the
        same chunk sequence is bit-identical).  The drift tracker's sliding
        window is not persisted: after a restore the baseline restarts,
        which can only delay (never corrupt) the next repair."""
        import json

        np.savez_compressed(
            path, **self.arena.state_arrays(),
            parts=self.parts,
            true_num_v=self._true_num_v,
            n_feeds=self.n_feeds, repartitions=self.repartitions,
            need_exact=self._need_exact,
            traffic=np.asarray([self._pushed, self._pulled, self._tasks,
                                self._stale, self._migrated], np.int64),
            rng_state=np.frombuffer(
                json.dumps(self._rng.bit_generator.state).encode(),
                dtype=np.uint8))

    @classmethod
    def load(cls, path, config: ParsaStreamConfig) -> "StreamSession":
        """Restore a stream saved by ``save``.  ``config.base.k`` must
        match the snapshot's k (the packed sets are k-shaped)."""
        import json

        z = np.load(path)
        if int(z["k"]) != config.base.k:
            raise ValueError(
                f"snapshot has k={int(z['k'])} but config.base.k="
                f"{config.base.k}")
        # sketched sessions store the arena at the sketched width; the
        # session is rebuilt from the TRUE extent so __init__ re-derives
        # the identical spec (identity prefix + seeded hash — no data
        # dependence), then the saved arena replaces the fresh one.
        true_v = int(z["true_num_v"]) if "true_num_v" in z else int(z["num_v"])
        session = cls(config, num_v=true_v)
        session._true_num_v = true_v
        session.arena = StreamArena.from_state(z)
        parts = np.asarray(z["parts"], np.int32)
        session._store_parts(0, parts)
        session.n_feeds = int(z["n_feeds"])
        session.repartitions = int(z["repartitions"])
        session._need_exact = bool(z["need_exact"])
        # pre-migration_bytes snapshots carry 4 counters, current ones 5
        t = [int(x) for x in z["traffic"]] + [0]
        (session._pushed, session._pulled, session._tasks, session._stale,
         session._migrated) = t[:5]
        session._rng.bit_generator.state = json.loads(
            bytes(z["rng_state"]).decode())
        return session

    # ------------------------------------------------------------- results
    def result(self, refine_v: bool | None = None) -> PartitionResult:
        """Assemble the current stream state into a full
        ``PartitionResult`` (device-resident Alg 2 + exact metrics), the
        same record the one-shot facade returns."""
        import jax.numpy as jnp

        from ..core.jax_refine import evaluate_device, refine_v_device

        base = self.config.base
        g = self.arena.graph()
        timings: dict[str, float] = {}
        t_total = time.perf_counter()
        s_logical = self.arena.masks_np()
        need_words = jnp.asarray(s_logical) if self._need_exact else None
        refine = base.refine_v if refine_v is None else refine_v
        parts_v = parts_v_dev = None
        if refine:
            t0 = time.perf_counter()
            parts_v_dev, need_words = refine_v_device(
                g, jnp.asarray(self.parts), self.k, sweeps=base.sweeps,
                chunk=base.refine_chunk, use_kernel=base.use_kernel,
                interpret=base.interpret, need_words=need_words)
            parts_v = np.asarray(parts_v_dev)
            timings["partition_v"] = time.perf_counter() - t0
        t0 = time.perf_counter()
        metrics = evaluate_device(g, self.parts, parts_v_dev, self.k,
                                  need_words=need_words)
        timings["metrics"] = time.perf_counter() - t0
        if self.sketch is not None and parts_v is not None:
            # sketch-space V assignment → the true parameter extent (every
            # real column served by the machine of its sketch slot)
            parts_v = self.sketch.expand_parts_v(parts_v, self._true_num_v)
        timings["total"] = time.perf_counter() - t_total
        return PartitionResult(
            parts_u=self.parts.copy(), parts_v=parts_v, num_v=g.num_v,
            k=self.k, config=base, metrics=metrics, timings=timings,
            traffic=(self.traffic
                     if self._tasks or self._pushed or self._migrated
                     else None),
            sketch=self.sketch,
            _packed_sets=s_logical)


_POPCOUNT_FN = None


def _popcount_rows(s_masks, sizes):
    """One fused launch: (sizes, per-row popcount of the packed sets)."""
    global _POPCOUNT_FN
    if _POPCOUNT_FN is None:
        import jax
        import jax.numpy as jnp

        def body(m, s):
            return s, jax.lax.population_count(m).astype(jnp.int32).sum(
                axis=1)

        _POPCOUNT_FN = jax.jit(body)
    return _POPCOUNT_FN(s_masks, sizes)


def stream_partition(
    chunks: Iterable[BipartiteGraph],
    config: ParsaStreamConfig,
    num_v: int | None = None,
) -> tuple[PartitionResult, list[StreamUpdate]]:
    """Facade convenience: feed every chunk through one ``StreamSession``
    and return ``(final PartitionResult, per-chunk StreamUpdate deltas)``.
    ``num_v`` defaults to the first chunk's parameter extent (the arena
    grows if later chunks exceed it)."""
    it = iter(chunks)
    try:
        first = next(it)
    except StopIteration:
        raise ValueError("stream_partition needs at least one chunk") \
            from None
    session = StreamSession(config,
                            num_v=num_v if num_v is not None else first.num_v)
    updates = [session.feed(first)]
    updates.extend(session.feed(c) for c in it)
    return session.result(), updates
