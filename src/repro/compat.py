"""Version compatibility shims.

``shard_map``: modern jax exposes ``jax.shard_map`` with a ``check_vma``
kwarg; jax 0.4.x only has ``jax.experimental.shard_map`` whose equivalent
kwarg is ``check_rep`` — and some transitional releases export the
top-level name while still taking ``check_rep``.  So the shim keys on the
actual signature, not on where the import succeeded: call sites can always
use the modern ``check_vma`` spelling.
"""
from __future__ import annotations

import inspect

try:  # modern jax
    from jax import shard_map as _shard_map  # type: ignore[attr-defined]
except ImportError:  # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

try:
    _HAS_CHECK_VMA = "check_vma" in inspect.signature(_shard_map).parameters
except (TypeError, ValueError):  # builtins / C callables: assume modern
    _HAS_CHECK_VMA = True

if _HAS_CHECK_VMA:
    shard_map = _shard_map
else:

    def shard_map(f=None, /, **kwargs):
        if "check_vma" in kwargs:
            kwargs["check_rep"] = kwargs.pop("check_vma")
        if f is None:
            return lambda g: _shard_map(g, **kwargs)
        return _shard_map(f, **kwargs)


__all__ = ["shard_map"]
