"""mixtral-8x22b [arXiv:2401.04088] — 8-expert top-2 MoE, GQA kv=8, SWA.

Sliding window (4096) keeps decode KV bounded ⇒ long_500k runs for this arch.
Parsa expert placement applies (DESIGN §3.2)."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    num_layers=56,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=32768,
    num_experts=8,
    num_experts_per_tok=2,
    swa_window=4096,
    rope_theta=1_000_000.0,
    fsdp=True,
    parsa_experts=True,
    microbatches=8,
))
