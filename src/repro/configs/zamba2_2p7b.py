"""zamba2-2.7b [arXiv:2411.15242] — Mamba2 backbone + shared attention block.

54 layers = 9 groups × (5 Mamba2 + 1 weight-tied shared attention block);
we drop the per-invocation LoRA deltas on the shared block (DESIGN §7).
SSM state ⇒ long_500k decode runs."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    num_layers=54,
    hybrid_group=6,           # 5 mamba + 1 shared attn per group
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    head_dim=80,
    d_ff=10240,
    vocab_size=32000,
    ssm_state=64,
    ssm_headdim=64,
    ssm_expand=2,
    ssm_conv=4,
    rope_theta=10_000.0,
    parsa_embedding=False,
    microbatches=2,
))
