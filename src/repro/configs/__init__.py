"""Architecture registry: one module per assigned architecture."""
from .base import ModelConfig, get_config, list_configs, register, REGISTRY  # noqa: F401

_LOADED = False


def _load_all():
    global _LOADED
    if _LOADED:
        return
    _LOADED = True
    from . import (  # noqa: F401
        codeqwen15_7b,
        qwen3_14b,
        command_r_35b,
        nemotron_4_340b,
        mixtral_8x22b,
        deepseek_v2_236b,
        whisper_medium,
        xlstm_350m,
        zamba2_2p7b,
        internvl2_76b,
        parsa_paper,
    )
