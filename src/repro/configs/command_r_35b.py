"""command-r-35b [hf:CohereForAI/c4ai-command-r-v01; unverified] — GQA, no bias,
LayerNorm, tied embeddings, 256k vocab."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="command-r-35b",
    family="dense",
    num_layers=40,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=22528,
    vocab_size=256000,
    norm="layernorm",
    mlp="swiglu",
    use_bias=False,
    tie_embeddings=True,
    rope_theta=8_000_000.0,
    fsdp=True,
    microbatches=4,
))
