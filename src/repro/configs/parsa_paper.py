"""The paper's own experiment configuration (§5): dataset analogues,
partitioner hyper-parameters, and the DBPG application settings."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ParsaExperimentConfig:
    k: int = 16                # partitions (paper default)
    a: int = 16                # init iterations (paper: a=b=16 for Table 2)
    b: int = 16                # subgraphs
    theta: int = 1000          # bucket head-pointer range (§4.1)
    tau: int | None = None     # max delay; None = eventual consistency (§5.4)
    workers: int = 4           # per-machine workers (§5.4)
    select: str = "size"       # grow smallest |U_i| (perfect balance, §4.1)
    trials: int = 10           # paper averages 10 trials
    # DBPG application (§5.5)
    lam: float = 1.0
    lr: float = 0.05
    dbpg_passes: int = 45      # paper: 45 data passes
    bandwidth: float = 125e6   # 1 GbE university cluster
    machines: int = 16


PAPER = ParsaExperimentConfig()
