"""whisper-medium [arXiv:2212.04356; unverified] — enc-dec; the conv/mel
frontend is a STUB per the assignment: input_specs() supplies precomputed
frame embeddings (B, 1500, d_model). 24 encoder + 24 decoder layers."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="whisper-medium",
    family="encdec",
    num_layers=24,            # decoder layers
    encoder_layers=24,
    encoder_seq=1500,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=51865,
    norm="layernorm",
    mlp="gelu",
    use_bias=True,
    rope_theta=0.0,           # sinusoidal absolute positions, no rope
))
