"""qwen3-14b [hf:Qwen/Qwen3-8B family] — GQA kv=8, per-head qk RMSNorm."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen3-14b",
    family="dense",
    num_layers=40,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=17408,
    vocab_size=151936,
    norm="rmsnorm",
    mlp="swiglu",
    qk_norm=True,
    rope_theta=1_000_000.0,
    microbatches=2,
))
