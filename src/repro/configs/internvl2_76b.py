"""internvl2-76b [arXiv:2404.16821; unverified] — InternViT + InternLM2.

The LLM backbone only (80L InternLM2-style); InternViT is the stubbed
modality frontend: input_specs() provides patch embeddings (B, 256, d_model)
prepended to the text sequence.  Loss is masked to text positions."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="internvl2-76b",
    family="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128256,
    num_patches=256,
    rope_theta=1_000_000.0,
    fsdp=True,
    opt_dtype="bfloat16",
    microbatches=8,
))
