"""deepseek-v2-236b [arXiv:2405.04434] — MLA (kv_lora=512) + 160-expert top-6
MoE with 2 shared experts; d_ff=1536 is the per-expert width.

Deviation (DESIGN §7): the HF model keeps layer 0 dense; we make all 60
layers MoE so the stack scans homogeneously."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,        # MLA: kv head count == heads (latent cache)
    head_dim=128,            # nope head dim
    d_ff=1536,               # per routed expert
    vocab_size=102400,
    num_experts=160,
    num_experts_per_tok=6,
    num_shared_experts=2,
    mla=True,
    kv_lora_rank=512,
    q_lora_rank=1536,
    rope_head_dim=64,
    v_head_dim=128,
    rope_theta=10_000.0,
    fsdp=True,
    opt_dtype="bfloat16",
    parsa_experts=True,
    microbatches=8,
))
