"""ModelConfig: one dataclass covering all 10 assigned architectures.

Every field that differs across the pool is explicit; families select which
block stack the model builder emits (see models/model.py).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

__all__ = ["ModelConfig", "register", "get_config", "list_configs", "REGISTRY"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | encdec | xlstm | hybrid | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # block details
    norm: str = "rmsnorm"        # rmsnorm | layernorm
    mlp: str = "swiglu"          # swiglu | squared_relu | gelu
    qk_norm: bool = False
    use_bias: bool = False
    rope_theta: float = 1e4
    swa_window: Optional[int] = None     # sliding-window attention
    tie_embeddings: bool = False

    # mixture of experts
    num_experts: int = 0
    num_experts_per_tok: int = 0
    num_shared_experts: int = 0
    moe_capacity_factor: float = 1.25

    # multi-head latent attention (deepseek-v2)
    mla: bool = False
    kv_lora_rank: int = 512
    q_lora_rank: int = 1536
    rope_head_dim: int = 64
    v_head_dim: int = 128

    # ssm / hybrid / xlstm
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    hybrid_group: int = 6        # zamba2: 5 mamba + 1 shared attn per group
    xlstm_group: int = 8         # xlstm: 7 mLSTM + 1 sLSTM per group

    # encoder-decoder (whisper)
    encoder_layers: int = 0
    encoder_seq: int = 1500      # precomputed frame embeddings (stub frontend)

    # vlm (internvl): stub patch embeddings prepended to the text sequence
    num_patches: int = 0

    # numerics / distribution policy
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    remat: str = "full"          # none | full | dots
    fsdp: bool = False           # shard weights over the data axis (ZeRO-3)
    opt_dtype: str = "float32"   # adam moment dtype (bf16 for the giants)
    attn_impl: str = "chunked"   # chunked | naive
    attn_chunk: int = 1024
    scan_layers: bool = True
    grad_compress: bool = False  # int8 + error-feedback on the DP all-reduce
    microbatches: int = 1        # gradient accumulation (activation memory ÷ n)

    # which Parsa features apply (DESIGN §3 / §7)
    parsa_embedding: bool = True
    parsa_experts: bool = False

    @property
    def group_dim(self) -> int:
        """GQA group size."""
        return self.num_heads // self.num_kv_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to 256 so the vocab axis shards over tp=16 with
        128-lane-aligned shards (whisper 51865→51968, qwen3 151936→152064,
        xlstm 50304→50432; the rest are already multiples)."""
        return int(-(-self.vocab_size // 256) * 256)

    def reduced(self, **overrides) -> "ModelConfig":
        """Smoke-test config of the same family (small widths, few layers)."""
        small = dict(
            num_layers=max(2, self.hybrid_group if self.family == "hybrid" else 2),
            d_model=64,
            num_heads=4,
            num_kv_heads=max(1, min(self.num_kv_heads, 2)),
            head_dim=16,
            d_ff=0 if self.d_ff == 0 else 128,
            vocab_size=256,
            encoder_layers=2 if self.encoder_layers else 0,
            encoder_seq=16 if self.encoder_layers else self.encoder_seq,
            num_patches=8 if self.num_patches else 0,
            num_experts=4 if self.num_experts else 0,
            num_experts_per_tok=min(2, self.num_experts_per_tok) if self.num_experts else 0,
            num_shared_experts=min(1, self.num_shared_experts),
            kv_lora_rank=32,
            q_lora_rank=48,
            rope_head_dim=8,
            v_head_dim=16,
            ssm_state=16 if self.ssm_state else 0,
            ssm_headdim=16 if self.ssm_state else 64,
            hybrid_group=3,
            xlstm_group=4,
            attn_impl="naive",
            remat="none",
            fsdp=False,
            scan_layers=True,
            dtype="float32",
        )
        if self.family == "hybrid":
            small["num_layers"] = 6   # 2 groups of (2 mamba + 1 shared attn)
        if self.family == "xlstm":
            small["num_layers"] = 8   # 2 groups of (3 mLSTM + 1 sLSTM)
        small.update(overrides)
        return dataclasses.replace(self, **small)


REGISTRY: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    from . import _load_all  # noqa: F401  (populate registry lazily)

    _load_all()
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(REGISTRY)}")
    return REGISTRY[name]


def list_configs() -> list[str]:
    from . import _load_all

    _load_all()
    return sorted(REGISTRY)
