"""nemotron-4-340b [arXiv:2402.16819; unverified] — dense GQA, squared-ReLU MLP.

ZeRO-3 weight sharding + bf16 optimizer moments: 340B params do not fit a
256-chip v5e pod with fp32 Adam state (see EXPERIMENTS.md §Dry-run)."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="nemotron-4-340b",
    family="dense",
    num_layers=96,
    d_model=18432,
    num_heads=96,
    num_kv_heads=8,
    head_dim=192,
    d_ff=73728,
    vocab_size=256000,
    norm="layernorm",
    mlp="squared_relu",
    rope_theta=10_000.0,
    fsdp=True,
    opt_dtype="bfloat16",
    microbatches=16,
))
