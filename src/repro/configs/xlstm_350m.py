"""xlstm-350m [arXiv:2405.04517; unverified] — sLSTM + mLSTM blocks.

24 blocks in 3 groups of 8 (7 mLSTM + 1 sLSTM, the paper's 7:1 ratio).
d_ff=0: the blocks carry their own up/down projections.  Recurrent state ⇒
long_500k decode runs (O(1) state, no KV growth).  Parsa's parameter-side
placement is inapplicable (no sparse data↔param interaction) — DESIGN §7."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="xlstm-350m",
    family="xlstm",
    num_layers=24,
    xlstm_group=8,
    d_model=1024,
    num_heads=4,
    num_kv_heads=4,
    head_dim=256,
    d_ff=0,
    vocab_size=50304,
    rope_theta=0.0,
    parsa_embedding=False,
    microbatches=2,
))
