"""codeqwen1.5-7b [hf:Qwen/CodeQwen1.5-7B] — qwen1.5 arch (attention bias, MHA)."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="codeqwen1.5-7b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=32,          # GQA kv=32 == MHA
    head_dim=128,
    d_ff=13440,
    vocab_size=92416,
    norm="rmsnorm",
    mlp="swiglu",
    use_bias=True,            # qwen1.5 keeps qkv bias
    rope_theta=1_000_000.0,
    microbatches=2,
))
