"""Synthetic analogues of the paper's datasets (Table 1).

Offline container ⇒ no rcv1/news20/KDDa/CTR/livejournal/orkut downloads;
we generate graphs with the same *structure* the paper leans on:

  * text_like  — documents × vocabulary, Zipfian word frequencies (rcv1 /
    news20 / KDDa analogues); document length ~ lognormal.
  * ctr_like   — impressions × (ads ∪ user features): Zipf features plus a
    dense block of frequent features (CTRa/CTRb analogue).
  * social_like — power-law (Barabási–Albert-ish) natural graph, converted
    to bipartite by the §2.2 construction U' = V (livejournal / orkut
    analogue).

All generators are seed-deterministic.
"""
from __future__ import annotations

import numpy as np

from ..core.bipartite import BipartiteGraph, from_edges

__all__ = ["text_like", "ctr_like", "social_like", "natural_to_bipartite"]


def _zipf_choice(rng, n: int, size: int, s: float = 1.1) -> np.ndarray:
    w = 1.0 / np.arange(1, n + 1) ** s
    w /= w.sum()
    return rng.choice(n, size=size, p=w)


def text_like(
    num_docs: int = 2000,
    vocab: int = 5000,
    mean_len: int = 60,
    zipf_s: float = 1.1,
    seed: int = 0,
) -> BipartiteGraph:
    rng = np.random.default_rng(seed)
    lens = np.maximum(1, rng.lognormal(np.log(mean_len), 0.6, num_docs).astype(int))
    total = int(lens.sum())
    words = _zipf_choice(rng, vocab, total, zipf_s)
    docs = np.repeat(np.arange(num_docs), lens)
    return from_edges(num_docs, vocab, docs, words)


def ctr_like(
    num_impressions: int = 2000,
    num_features: int = 8000,
    nnz_per_row: int = 40,
    dense_features: int = 30,
    clusters: int = 24,
    locality: float = 0.7,
    seed: int = 0,
) -> BipartiteGraph:
    """CTR analogue: a few dense head features (user-agent/geo style), plus a
    tail split between the impression's *campaign cluster* block (real CTR
    traffic is campaign/user-local — the structure Parsa exploits on CTRa/b)
    and a global Zipf tail."""
    rng = np.random.default_rng(seed)
    rows, cols = [], []
    head = rng.integers(0, dense_features, size=(num_impressions, 4))
    for i in range(4):
        rows.append(np.arange(num_impressions))
        cols.append(head[:, i])
    tail_n = nnz_per_row - 4
    tail_features = num_features - dense_features
    block = max(1, tail_features // clusters)
    row_cluster = rng.integers(0, clusters, size=num_impressions)
    local = rng.random((num_impressions, tail_n)) < locality
    # cluster-local draws (Zipf inside the block), global Zipf otherwise
    local_offsets = _zipf_choice(rng, block, num_impressions * tail_n, 1.1
                                 ).reshape(num_impressions, tail_n)
    local_ids = (row_cluster[:, None] * block + local_offsets) % tail_features
    global_ids = _zipf_choice(rng, tail_features, num_impressions * tail_n, 1.05
                              ).reshape(num_impressions, tail_n)
    tail = dense_features + np.where(local, local_ids, global_ids)
    rows.append(np.repeat(np.arange(num_impressions), tail_n))
    cols.append(tail.reshape(-1))
    return from_edges(
        num_impressions, num_features, np.concatenate(rows), np.concatenate(cols)
    )


def social_like(num_nodes: int = 3000, m: int = 8, seed: int = 0):
    """Preferential-attachment edge list (u < v), power-law degrees."""
    rng = np.random.default_rng(seed)
    src, dst = [], []
    targets = list(range(m))
    repeated: list[int] = list(range(m))
    for v in range(m, num_nodes):
        picks = rng.choice(len(repeated), size=m, replace=False)
        chosen = {repeated[p] for p in picks}
        for u in chosen:
            src.append(u)
            dst.append(v)
            repeated.append(u)
        repeated.extend([v] * len(chosen))
    return np.asarray(src), np.asarray(dst), num_nodes


def natural_to_bipartite(src: np.ndarray, dst: np.ndarray, n: int) -> BipartiteGraph:
    """§2.2 construction U' = V: u's row links every neighbor of u (both
    directions), so N(u) is u's adjacency list in the natural graph."""
    eu = np.concatenate([src, dst])
    ev = np.concatenate([dst, src])
    return from_edges(n, n, eu, ev)
