"""Synthetic analogues of the paper's datasets (Table 1).

Offline container ⇒ no rcv1/news20/KDDa/CTR/livejournal/orkut downloads;
we generate graphs with the same *structure* the paper leans on:

  * text_like  — documents × vocabulary, Zipfian word frequencies (rcv1 /
    news20 / KDDa analogues); document length ~ lognormal.
  * ctr_like   — impressions × (ads ∪ user features): Zipf features plus a
    dense block of frequent features (CTRa/CTRb analogue).
  * social_like — power-law (Barabási–Albert-ish) natural graph, converted
    to bipartite by the §2.2 construction U' = V (livejournal / orkut
    analogue).

All generators are seed-deterministic.
"""
from __future__ import annotations

import numpy as np

from ..core.bipartite import BipartiteGraph, from_edges

__all__ = ["text_like", "ctr_like", "social_like", "natural_to_bipartite",
           "text_like_stream", "ctr_like_stream", "social_like_stream"]


def _zipf_choice(rng, n: int, size: int, s: float = 1.1) -> np.ndarray:
    w = 1.0 / np.arange(1, n + 1) ** s
    w /= w.sum()
    return rng.choice(n, size=size, p=w)


def text_like(
    num_docs: int = 2000,
    vocab: int = 5000,
    mean_len: int = 60,
    zipf_s: float = 1.1,
    seed: int = 0,
) -> BipartiteGraph:
    rng = np.random.default_rng(seed)
    lens = np.maximum(1, rng.lognormal(np.log(mean_len), 0.6, num_docs).astype(int))
    total = int(lens.sum())
    words = _zipf_choice(rng, vocab, total, zipf_s)
    docs = np.repeat(np.arange(num_docs), lens)
    return from_edges(num_docs, vocab, docs, words)


def ctr_like(
    num_impressions: int = 2000,
    num_features: int = 8000,
    nnz_per_row: int = 40,
    dense_features: int = 30,
    clusters: int = 24,
    locality: float = 0.7,
    seed: int = 0,
) -> BipartiteGraph:
    """CTR analogue: a few dense head features (user-agent/geo style), plus a
    tail split between the impression's *campaign cluster* block (real CTR
    traffic is campaign/user-local — the structure Parsa exploits on CTRa/b)
    and a global Zipf tail."""
    rng = np.random.default_rng(seed)
    rows, cols = [], []
    head = rng.integers(0, dense_features, size=(num_impressions, 4))
    for i in range(4):
        rows.append(np.arange(num_impressions))
        cols.append(head[:, i])
    tail_n = nnz_per_row - 4
    tail_features = num_features - dense_features
    block = max(1, tail_features // clusters)
    row_cluster = rng.integers(0, clusters, size=num_impressions)
    local = rng.random((num_impressions, tail_n)) < locality
    # cluster-local draws (Zipf inside the block), global Zipf otherwise
    local_offsets = _zipf_choice(rng, block, num_impressions * tail_n, 1.1
                                 ).reshape(num_impressions, tail_n)
    local_ids = (row_cluster[:, None] * block + local_offsets) % tail_features
    global_ids = _zipf_choice(rng, tail_features, num_impressions * tail_n, 1.05
                              ).reshape(num_impressions, tail_n)
    tail = dense_features + np.where(local, local_ids, global_ids)
    rows.append(np.repeat(np.arange(num_impressions), tail_n))
    cols.append(tail.reshape(-1))
    return from_edges(
        num_impressions, num_features, np.concatenate(rows), np.concatenate(cols)
    )


def social_like(num_nodes: int = 3000, m: int = 8, seed: int = 0):
    """Preferential-attachment edge list (u < v), power-law degrees."""
    rng = np.random.default_rng(seed)
    src, dst = [], []
    targets = list(range(m))
    repeated: list[int] = list(range(m))
    for v in range(m, num_nodes):
        picks = rng.choice(len(repeated), size=m, replace=False)
        chosen = {repeated[p] for p in picks}
        for u in chosen:
            src.append(u)
            dst.append(v)
            repeated.append(u)
        repeated.extend([v] * len(chosen))
    return np.asarray(src), np.asarray(dst), num_nodes


# --------------------------------------------------------------------------
# Streaming variants: the same three structures, arriving as U-vertex
# chunks whose distribution *drifts* over the stream — the non-stationarity
# that makes online partitioning decay and drift repair worth having.
# --------------------------------------------------------------------------
def text_like_stream(
    num_docs: int = 2000,
    vocab: int = 5000,
    chunks: int = 8,
    mean_len: int = 60,
    zipf_s: float = 1.1,
    drift: float = 0.5,
    seed: int = 0,
) -> list[BipartiteGraph]:
    """Topic drift: each chunk's Zipf head sits at a rotating vocabulary
    offset (the hot topic moves), sweeping ``drift`` of the vocabulary over
    the whole stream.  Early chunks' hot words go cold — exactly the decay
    an online partitioner accumulates."""
    rng = np.random.default_rng(seed)
    out = []
    for c in range(chunks):
        n = num_docs // chunks + (1 if c < num_docs % chunks else 0)
        lens = np.maximum(
            1, rng.lognormal(np.log(mean_len), 0.6, n).astype(int))
        words = _zipf_choice(rng, vocab, int(lens.sum()), zipf_s)
        offset = int(drift * vocab * c / max(chunks - 1, 1))
        words = (words + offset) % vocab
        docs = np.repeat(np.arange(n), lens)
        out.append(from_edges(n, vocab, docs, words))
    return out


def ctr_like_stream(
    num_impressions: int = 2000,
    num_features: int = 8000,
    chunks: int = 8,
    nnz_per_row: int = 40,
    dense_features: int = 30,
    clusters: int = 24,
    locality: float = 0.7,
    churn: float = 0.3,
    seed: int = 0,
) -> list[BipartiteGraph]:
    """Campaign churn: impressions keep the head/cluster structure of
    ``ctr_like``, but between chunks a ``churn`` fraction of campaign
    clusters is retired and relaunched over a fresh feature block — the
    ad-serving non-stationarity the paper's CTR workloads live with."""
    rng = np.random.default_rng(seed)
    tail_features = num_features - dense_features
    block = max(1, tail_features // clusters)
    n_blocks = max(1, tail_features // block)
    # live campaign → feature-block mapping, churned between chunks
    campaign_block = rng.integers(0, n_blocks, size=clusters)
    out = []
    tail_n = nnz_per_row - 4
    for c in range(chunks):
        if c > 0:
            relaunch = rng.random(clusters) < churn
            campaign_block[relaunch] = rng.integers(
                0, n_blocks, size=int(relaunch.sum()))
        n = num_impressions // chunks + (1 if c < num_impressions % chunks
                                         else 0)
        rows, cols = [], []
        head = rng.integers(0, dense_features, size=(n, 4))
        for i in range(4):
            rows.append(np.arange(n))
            cols.append(head[:, i])
        row_cluster = rng.integers(0, clusters, size=n)
        local = rng.random((n, tail_n)) < locality
        local_offsets = _zipf_choice(rng, block, n * tail_n, 1.1
                                     ).reshape(n, tail_n)
        local_ids = (campaign_block[row_cluster][:, None] * block
                     + local_offsets) % tail_features
        global_ids = _zipf_choice(rng, tail_features, n * tail_n, 1.05
                                  ).reshape(n, tail_n)
        tail = dense_features + np.where(local, local_ids, global_ids)
        rows.append(np.repeat(np.arange(n), tail_n))
        cols.append(tail.reshape(-1))
        out.append(from_edges(n, num_features,
                              np.concatenate(rows), np.concatenate(cols)))
    return out


def social_like_stream(
    num_nodes: int = 3000,
    chunks: int = 8,
    m: int = 8,
    seed: int = 0,
) -> list[BipartiteGraph]:
    """Preferential-attachment growth: the natural graph grows node by
    node; each chunk carries the newly arrived nodes' rows under the §2.2
    construction (a node's row is its adjacency at arrival — earlier rows
    are not retro-edited, the append-only streaming approximation), with
    ``num_v`` growing chunk over chunk so the arena's capacity-doubling
    path is exercised."""
    src, dst, n = social_like(num_nodes, m=m, seed=seed)
    src, dst = np.asarray(src), np.asarray(dst)
    out = []
    bounds = np.linspace(m, num_nodes, chunks + 1).astype(int)
    for c in range(chunks):
        lo, hi = bounds[c], bounds[c + 1]
        if c == 0:
            lo = 0  # the seed clique rides in the first chunk
        sel = (dst >= max(lo, m)) & (dst < hi)
        eu = dst[sel] - lo        # arriving node's local row id
        ev = src[sel]             # neighbors at arrival (global V ids)
        out.append(from_edges(hi - lo, hi, eu, ev))
    return out


def natural_to_bipartite(src: np.ndarray, dst: np.ndarray, n: int) -> BipartiteGraph:
    """§2.2 construction U' = V: u's row links every neighbor of u (both
    directions), so N(u) is u's adjacency list in the natural graph."""
    eu = np.concatenate([src, dst])
    ev = np.concatenate([dst, src])
    return from_edges(n, n, eu, ev)
