from .synthetic import (  # noqa: F401
    ctr_like,
    ctr_like_stream,
    natural_to_bipartite,
    social_like,
    social_like_stream,
    text_like,
    text_like_stream,
)
