from .synthetic import (  # noqa: F401
    text_like,
    ctr_like,
    social_like,
    natural_to_bipartite,
)
