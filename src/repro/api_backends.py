"""Backend registry for the ``repro.api`` Parsa facade.

Every partitioning strategy in the repo is one registered backend with the
uniform signature ``fn(graph, config, init_sets=None) -> BackendOutput``:

  * ``host``                — Algorithm 3 (sequential reference); with
    ``config.blocks > 1`` or ``config.init_iters > 0`` the §4.2/§4.4
    subgraph-streaming driver (``sequential_parsa_impl``).
  * ``device_scan``         — the device-resident blocked pipeline: one
    jitted ``lax.scan`` over packed bitmask blocks, fused cost+select
    (``blocked_partition_u_impl``).
  * ``host_blocked_oracle`` — the seed per-block host loop, kept as the
    parity oracle and benchmark baseline.
  * ``parallel_sim``        — the deterministic Alg 4 parameter-server
    simulation with W workers and bounded delay τ, on the packed-word wire
    format; fills ``BackendOutput.traffic``.
  * ``parallel_device``     — the real distributed Alg 4: shard_map multi-
    worker blocked scans over packed bitmasks with periodic all_gather +
    OR merges (``merge_every`` blocks of staleness); fills
    ``BackendOutput.traffic`` with the same word-byte units.

New distributed strategies (e.g. randomized distributed submodular
maximization, arXiv:1502.02606, or sparse-DNN partitioning workloads,
arXiv:2104.11805) plug in as one more ``@register_backend`` function
instead of another ad-hoc module-level entry point.

This module is imported by ``repro.api`` and must not import it back —
backends receive the (duck-typed) ``ParsaConfig`` and return plain
``BackendOutput`` records; the facade owns result assembly.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from .core.bipartite import BipartiteGraph
from .core.jax_partition import (
    blocked_partition_u_hostloop_impl,
    blocked_partition_u_impl,
    parallel_blocked_partition_u_impl,
)
from .core.parallel import global_initialization, parallel_parsa_impl
from .core.partition_u import partition_u_impl
from .core.subgraphs import sequential_parsa_impl

__all__ = [
    "BackendOutput",
    "TrafficCounters",
    "register_backend",
    "get_backend",
    "available_backends",
    "BACKENDS",
]


@dataclasses.dataclass(frozen=True)
class TrafficCounters:
    """Parameter-server traffic of the partitioning run itself (Alg 4).

    Units are *bitmask-word bytes* in both directions (4 bytes per 32
    parameters, the packed wire format shared by ``parallel_sim`` and
    ``parallel_device``): pulls count the packed words a worker reads
    (``parallel_sim``: the words covering the task's V support;
    ``parallel_device``: the full (k, W) set per merge), pushes count the
    delta-encoded changed words (Alg 4 worker line 9)."""

    pushed_bytes: int = 0          # worker→server traffic (delta-encoded words)
    pulled_bytes: int = 0          # server→worker traffic (packed words)
    tasks: int = 0
    stale_pushes_missed: int = 0   # pushes invisible to a pull due to delay
    migration_bytes: int = 0       # one-time recovery/re-shard traffic
                                   #   (worker loss, grow/shrink, drift
                                   #   repair) — split from push/pull so
                                   #   steady-state and recovery traffic
                                   #   stay separable in benchmark rows

    def __add__(self, other: "TrafficCounters") -> "TrafficCounters":
        """Component-wise accumulation — streaming sessions sum per-feed
        and migration counters into one session total (same units, so the
        sum is meaningful)."""
        if not isinstance(other, TrafficCounters):
            return NotImplemented
        return TrafficCounters(
            self.pushed_bytes + other.pushed_bytes,
            self.pulled_bytes + other.pulled_bytes,
            self.tasks + other.tasks,
            self.stale_pushes_missed + other.stale_pushes_missed,
            self.migration_bytes + other.migration_bytes)


@dataclasses.dataclass
class BackendOutput:
    """What a backend hands back to the facade.

    Exactly one of ``s_masks`` (packed (k, W) int32 bitmasks) or
    ``neighbor_sets`` (dense (k, |V|) bool) must be set; the facade packs /
    lazily unpacks the other view.  Device backends may return ``parts_u``
    and ``s_masks`` as *device* arrays (when the config asks for a device-
    resident refine/metrics phase, ``refine_backend="device"``) — the facade
    converts to numpy only at result assembly, so nothing round-trips
    through the host between phases.  ``timings`` carries backend-internal
    phase attribution (today: ``"pack"``, the host-side bitmask packing
    seconds the facade splits out of ``timings["partition_u"]``).
    """

    parts_u: np.ndarray
    s_masks: np.ndarray | None = None
    neighbor_sets: np.ndarray | None = None
    traffic: TrafficCounters | None = None
    timings: dict | None = None


BackendFn = Callable[..., BackendOutput]
BACKENDS: dict[str, BackendFn] = {}


def register_backend(name: str) -> Callable[[BackendFn], BackendFn]:
    """Decorator: register ``fn(graph, config, init_sets=None)`` under
    ``name`` so ``ParsaConfig(backend=name)`` can reach it."""

    def deco(fn: BackendFn) -> BackendFn:
        BACKENDS[name] = fn
        fn.backend_name = name  # type: ignore[attr-defined]
        return fn

    return deco


def get_backend(name: str) -> BackendFn:
    try:
        return BACKENDS[name]
    except KeyError:
        raise ValueError(
            f"unknown Parsa backend {name!r}; available: "
            f"{', '.join(available_backends())}") from None


def available_backends() -> list[str]:
    return sorted(BACKENDS)


# --------------------------------------------------------------------------
# Registered adapters over the existing implementations.
# --------------------------------------------------------------------------
@register_backend("host")
def host_backend(graph: BipartiteGraph, config, init_sets=None) -> BackendOutput:
    """Sequential reference: Alg 3, optionally streamed over ``blocks``
    subgraphs with ``init_iters`` individual-initialization passes."""
    if config.blocks <= 1 and config.init_iters == 0:
        res = partition_u_impl(
            graph, config.k, init_sets=init_sets, theta=config.theta,
            select=config.select, seed=config.seed)
        return BackendOutput(res.parts_u, neighbor_sets=res.neighbor_sets)
    parts_u, sets = sequential_parsa_impl(
        graph, config.k, b=config.blocks, a=config.init_iters,
        theta=config.theta, select=config.select, seed=config.seed,
        init_sets=init_sets)
    return BackendOutput(parts_u, neighbor_sets=sets)


@register_backend("device_scan")
def device_scan_backend(graph: BipartiteGraph, config, init_sets=None) -> BackendOutput:
    """Device-resident blocked pipeline: one jitted scan, O(1) dispatches."""
    timings: dict = {}
    parts_u, s_masks = blocked_partition_u_impl(
        graph, config.k, block=config.block_size, init_sets=init_sets,
        use_kernel=config.use_kernel, interpret=config.interpret,
        seed=config.seed, cap=config.cap,
        as_numpy=getattr(config, "refine_backend", "host") != "device",
        timings=timings,
        sketch=getattr(config, "set_repr", "exact") == "sketch")
    return BackendOutput(parts_u, s_masks=s_masks, timings=timings)


@register_backend("host_blocked_oracle")
def host_blocked_oracle_backend(graph: BipartiteGraph, config, init_sets=None) -> BackendOutput:
    """Seed per-block host loop — the parity oracle for ``device_scan``."""
    parts_u, s_masks = blocked_partition_u_hostloop_impl(
        graph, config.k, block=config.block_size, init_sets=init_sets,
        use_kernel=config.use_kernel, interpret=config.interpret,
        seed=config.seed)
    return BackendOutput(parts_u, s_masks=s_masks)


@register_backend("parallel_sim")
def parallel_sim_backend(graph: BipartiteGraph, config, init_sets=None) -> BackendOutput:
    """Alg 4 parameter-server simulation (W workers, bounded delay τ).

    With ``config.global_init_frac > 0`` and no explicit warm start, runs
    §4.4 global initialization first and seeds every worker from it.
    """
    if init_sets is None and config.global_init_frac > 0:
        init_sets = global_initialization(
            graph, config.k, sample_frac=config.global_init_frac,
            theta=config.theta, select=config.select, seed=config.seed)
    report, s_masks = parallel_parsa_impl(
        graph, config.k, b=config.blocks, a=config.init_iters,
        workers=config.workers, tau=config.tau, theta=config.theta,
        select=config.select, seed=config.seed, init_sets=init_sets)
    traffic = TrafficCounters(
        pushed_bytes=report.pushed_bytes, pulled_bytes=report.pulled_bytes,
        tasks=report.tasks, stale_pushes_missed=report.stale_pushes_missed)
    return BackendOutput(report.parts_u, s_masks=s_masks, traffic=traffic)


@register_backend("parallel_device")
def parallel_device_backend(graph: BipartiteGraph, config, init_sets=None) -> BackendOutput:
    """Device-parallel Algorithm 4: shard_map multi-worker blocked Parsa.

    ``config.workers`` shards of U run the single-dispatch blocked scan
    concurrently, one per mesh device, each against a device-local stale
    copy of the packed server sets; every ``config.merge_every`` blocks the
    shards OR-merge (all_gather + lattice OR on uint32 words, the bulk-
    synchronous server union-push, τ ≡ merge_every − 1).  ``config.devices``
    overrides the mesh width (defaults to ``workers``); with one worker the
    output is bit-identical to ``device_scan``.  Global sizes stay balanced
    within ``workers`` (stale catch-ups can overlap when k ∤ |U| — see
    ``parallel_blocked_partition_u_impl``).  Supports §4.4 global
    initialization via ``global_init_frac`` like ``parallel_sim``.
    """
    if init_sets is None and config.global_init_frac > 0:
        init_sets = global_initialization(
            graph, config.k, sample_frac=config.global_init_frac,
            theta=config.theta, select=config.select, seed=config.seed)
    workers = config.devices if config.devices is not None else config.workers
    timings: dict = {}
    parts_u, s_masks, traffic = parallel_blocked_partition_u_impl(
        graph, config.k, workers=workers, block=config.block_size,
        merge_every=config.merge_every, init_sets=init_sets,
        use_kernel=config.use_kernel, interpret=config.interpret,
        seed=config.seed, cap=config.cap,
        as_numpy=getattr(config, "refine_backend", "host") != "device",
        timings=timings,
        sketch=getattr(config, "set_repr", "exact") == "sketch")
    return BackendOutput(parts_u, s_masks=s_masks,
                         traffic=TrafficCounters(**traffic), timings=timings)
