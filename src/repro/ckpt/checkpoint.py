"""Sharded checkpointing: npz shards + JSON manifest, async save, elastic
reshard-on-load.

Layout:  <dir>/step_<n>/manifest.json
         <dir>/step_<n>/shard_<i>.npz          (one per host in a real
         multi-host job; single-host here writes one shard per save thread)

Fault-tolerance contract (runtime/fault.py builds on this):
  * atomic: writes go to step_<n>.tmp, renamed only after fsync — a crash
    mid-save never corrupts the latest checkpoint;
  * restart: ``latest_step`` finds the newest complete manifest;
  * elastic: the manifest records logical array shapes (not device
    layouts), so a restore may land on a different mesh — the caller
    re-applies its own shardings via device_put.
"""
from __future__ import annotations

import json
import os
import pathlib
import shutil
import threading

import jax
import numpy as np

_SEP = "::"


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        out[key] = np.asarray(leaf)
    return out, treedef


def save_checkpoint(directory, step: int, tree, *, blocking: bool = True):
    directory = pathlib.Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    tmp = directory / f"step_{step}.tmp"
    final = directory / f"step_{step}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()
    flat, _ = _flatten(tree)

    def _write():
        np.savez(tmp / "shard_0.npz", **flat)
        manifest = {
            "step": step,
            "arrays": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                       for k, v in flat.items()},
            "format": 1,
        }
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        os.replace(tmp, final)  # atomic publish

    if blocking:
        _write()
        return None
    t = threading.Thread(target=_write, daemon=True)
    t.start()
    return t


def latest_step(directory) -> int | None:
    directory = pathlib.Path(directory)
    if not directory.exists():
        return None
    steps = []
    for p in directory.iterdir():
        if p.name.startswith("step_") and not p.name.endswith(".tmp") \
                and (p / "manifest.json").exists():
            steps.append(int(p.name.split("_")[1]))
    return max(steps) if steps else None


def restore_checkpoint(directory, step: int, like_tree, *, shardings=None):
    """Restore into the structure of ``like_tree``; optionally device_put onto
    ``shardings`` (elastic re-mesh: any mesh works, shapes are logical)."""
    directory = pathlib.Path(directory) / f"step_{step}"
    data = np.load(directory / "shard_0.npz")
    flat, treedef = _flatten(like_tree)
    restored = {}
    for key in flat:
        if key not in data:
            raise KeyError(f"checkpoint missing array {key!r}")
        restored[key] = data[key]
    leaves = [restored[k] for k in flat]
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.device_put(tree, shardings)
    return tree


class CheckpointManager:
    """Every-N-steps manager with async saves and bounded retention."""

    def __init__(self, directory, every: int = 100, keep: int = 3):
        self.directory = pathlib.Path(directory)
        self.every = every
        self.keep = keep
        self._pending: threading.Thread | None = None

    def maybe_save(self, step: int, tree, *, blocking: bool = False):
        if step % self.every:
            return False
        if self._pending is not None:
            self._pending.join()  # backpressure: one in-flight save
        self._pending = save_checkpoint(
            self.directory, step, tree, blocking=blocking)
        self._gc()
        return True

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _gc(self):
        steps = sorted(
            int(p.name.split("_")[1]) for p in self.directory.iterdir()
            if p.name.startswith("step_") and not p.name.endswith(".tmp"))
        for s in steps[: -self.keep]:
            shutil.rmtree(self.directory / f"step_{s}", ignore_errors=True)
