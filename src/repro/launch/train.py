"""End-to-end training driver.

Single-host execution (CPU smoke / examples) or mesh-sharded (pass --mesh).
Wires together: config registry → model → AdamW → synthetic or
Parsa-sharded data → TrainLoop (checkpoint/restart, failure injection) —
the full framework path a real pod job takes.

  PYTHONPATH=src python -m repro.launch.train --arch xlstm-350m \
      --reduce --steps 50 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..configs import get_config
from ..data import SyntheticLMData
from ..optim import AdamWConfig
from ..runtime import FaultConfig, TrainLoop
from ..serving import prefetch_batches
from .steps import make_train_step


def build(cfg, mesh=None, lr=3e-4):
    opt_cfg = AdamWConfig(lr=lr, moment_dtype=cfg.opt_dtype)
    model, train_step, init_state, _ = make_train_step(cfg, mesh, opt_cfg)
    return model, jax.jit(train_step, donate_argnums=(0, 1)), init_state


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--reduce", action="store_true",
                    help="reduced config of the same family (CPU-runnable)")
    ap.add_argument("--width", type=int, default=None,
                    help="override d_model for --reduce (e.g. ~100M model)")
    ap.add_argument("--layers", type=int, default=None)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--fail-at", type=int, default=None)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduce:
        over = {}
        if args.width:
            over.update(d_model=args.width, head_dim=args.width // 4,
                        d_ff=0 if cfg.d_ff == 0 else args.width * 4,
                        vocab_size=8192)
        if args.layers:
            over["num_layers"] = args.layers
        cfg = cfg.reduced(**over)
    model, train_step, init_state = build(cfg, lr=args.lr)

    data = SyntheticLMData(cfg.vocab_size, args.batch, args.seq, seed=0)

    def host_batches():
        for t in range(start, args.steps):
            b = data.batch_at(t)
            if cfg.family == "encdec":
                b["frames"] = np.zeros(
                    (args.batch, cfg.encoder_seq, cfg.d_model), np.float32)
            if cfg.family == "vlm":
                b["patches"] = np.zeros(
                    (args.batch, cfg.num_patches, cfg.d_model), np.float32)
            yield b

    def stage(b):
        return {k: jax.numpy.asarray(v) for k, v in b.items()}

    def batches():
        # double-buffered staging (repro.serving): batch t+1's host→device
        # transfer is in flight while the loop computes step t
        yield from prefetch_batches(host_batches(), stage, depth=2)

    fault = FaultConfig(ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                        fail_at_step=args.fail_at)
    loop = TrainLoop(train_step, fault)
    if args.resume:
        start, params, opt = loop.resume_or(
            lambda: init_state(jax.random.PRNGKey(0)))
        print(f"resumed at step {start}")
    else:
        start = 0
        params, opt = init_state(jax.random.PRNGKey(0))
    n = model.param_count(params)
    print(f"arch={cfg.name} params={n/1e6:.1f}M steps={start}->{args.steps}")
    t0 = time.time()
    params, opt, hist = loop.run(params, opt, batches(), start_step=start,
                                 log_every=args.log_every)
    dt = time.time() - t0
    steps_done = args.steps - start
    tok = steps_done * args.batch * args.seq
    print(f"done: {steps_done} steps, {dt:.1f}s, {tok/max(dt,1e-9):.0f} tok/s")
    if hist:
        print(f"loss: {hist[0]['loss']:.4f} -> {hist[-1]['loss']:.4f}")
    return hist


if __name__ == "__main__":
    main()
