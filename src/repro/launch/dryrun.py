import os
os.environ["XLA_FLAGS"] = os.environ.get("DRYRUN_XLA_FLAGS",
    "--xla_force_host_platform_device_count=512")
# ^ MUST precede any jax import (device count locks at first init).

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

Per cell, two kinds of compiles:

  1. REAL artifact — the production config (scan-over-layers, remat):
     proves sharding legality + collective support, and provides
     ``memory_analysis()`` (scan gives correct liveness → the fits-on-chip
     proof) and the compile itself.

  2. CALIBRATION pair — the same model UNROLLED at 1 and 2 layer-units
     (XLA's cost analysis counts a scan body ONCE, so FLOPs/bytes/wire from
     the scanned module undercount by ~L; the two-point unrolled fit
     m(u) = base + u·per_unit reconstructs true per-step totals:
     total = base + L·per_unit).  Verified against 6·N·D in the report.

Results cached per cell in benchmarks/out/dryrun/<cell>.json.

Usage:
  python -m repro.launch.dryrun --arch qwen3-14b --shape train_4k
  python -m repro.launch.dryrun --all --both-meshes
"""
import argparse
import dataclasses
import json
import pathlib
import time
import traceback

import jax
import numpy as np

from ..configs import get_config, list_configs
from ..models.model import SHAPES, build_model, input_specs, shape_applicable
from .mesh import make_production_mesh, mesh_name
from .roofline import Roofline, count_params, model_flops, parse_collectives
from .sharding import batch_specs, opt_pspecs, param_pspecs, to_named
from .steps import make_serve_step, make_train_step

OUT_DIR = pathlib.Path(__file__).resolve().parents[3] / "benchmarks" / "out" / "dryrun"


def _mem_analysis_dict(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
        return {
            "argument_bytes": int(getattr(ma, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(ma, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(ma, "temp_size_in_bytes", 0)),
        }
    except Exception as e:
        return {"error": repr(e)}


def _cost_analysis_dict(compiled) -> dict:
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        return {"flops": float(ca.get("flops", 0.0)),
                "bytes": float(ca.get("bytes accessed", 0.0)),
                "transcendentals": float(ca.get("transcendentals", 0.0))}
    except Exception as e:
        return {"error": repr(e)}


def lower_and_compile(cfg, shape: str, mesh):
    """One (config × shape × mesh) lowering; returns the compiled artifact."""
    info = SHAPES[shape]
    specs = input_specs(cfg, shape)
    bspecs = batch_specs(cfg, specs, mesh)
    P = jax.sharding.PartitionSpec
    if info["kind"] == "prefill":
        from .steps import make_prefill_step
        from .sharding import cache_specs
        model, step = make_prefill_step(cfg, mesh)
        pshape = jax.eval_shape(lambda k: model.init(k), jax.random.PRNGKey(0))
        pspec = param_pspecs(cfg, pshape, mesh)
        if cfg.family in ("xlstm", "hybrid"):
            out_sh = to_named(P(), mesh)
        else:
            out_shape = jax.eval_shape(step, pshape, specs)
            dp_ax = bspecs["tokens"][0]
            vocab_ax = "model" if cfg.padded_vocab % mesh.shape["model"] == 0 else None
            out_sh = to_named((P(dp_ax, vocab_ax),
                               cache_specs(cfg, out_shape[1], mesh)), mesh)
        jitted = jax.jit(step, in_shardings=to_named((pspec, bspecs), mesh),
                         out_shardings=out_sh)
        with mesh:
            return jitted.lower(pshape, specs).compile()
    if info["kind"] == "train":
        model, step, _, _ = make_train_step(cfg, mesh)
        pshape = jax.eval_shape(lambda k: model.init(k), jax.random.PRNGKey(0))
        pspec = param_pspecs(cfg, pshape, mesh)
        from ..optim import AdamWConfig, init_opt_state
        ocfg = AdamWConfig(moment_dtype=cfg.opt_dtype)
        oshape = jax.eval_shape(lambda: init_opt_state(pshape, ocfg))
        ospec = opt_pspecs(cfg, pshape, mesh)
        in_sh = to_named((pspec, ospec, bspecs), mesh)
        out_sh = to_named((pspec, ospec,
                           {"loss": P(), "tokens": P(), "grad_norm": P()}), mesh)
        jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=(0, 1))
        args = (pshape, oshape, specs)
    else:
        model, step = make_serve_step(cfg, mesh)
        pshape = jax.eval_shape(lambda k: model.init(k), jax.random.PRNGKey(0))
        pspec = param_pspecs(cfg, pshape, mesh)
        dp_ax = bspecs["token"][0]
        vocab_ax = "model" if cfg.padded_vocab % mesh.shape["model"] == 0 else None
        out_sh = to_named((P(dp_ax), P(dp_ax, vocab_ax), bspecs["cache"]), mesh)
        jitted = jax.jit(step, in_shardings=to_named((pshape and pspec, bspecs), mesh),
                         out_shardings=out_sh, donate_argnums=(1,))
        args = (pshape, specs)
    with mesh:
        lowered = jitted.lower(*args)
        compiled = lowered.compile()
    return compiled


def _calibration_cfgs(cfg):
    """(unit_count, cfg_at(n_units)) for the two-point unrolled fit."""
    if cfg.family == "xlstm":
        per = cfg.xlstm_group
        units = cfg.num_layers // per
        mk = lambda n: dataclasses.replace(cfg, num_layers=n * per, scan_layers=False, microbatches=1)
    elif cfg.family == "hybrid":
        per = cfg.hybrid_group
        units = cfg.num_layers // per
        mk = lambda n: dataclasses.replace(cfg, num_layers=n * per, scan_layers=False, microbatches=1)
    elif cfg.family == "encdec":
        units = cfg.num_layers
        mk = lambda n: dataclasses.replace(
            cfg, num_layers=n, encoder_layers=n, scan_layers=False,
            microbatches=1)
    else:
        units = cfg.num_layers
        mk = lambda n: dataclasses.replace(cfg, num_layers=n, scan_layers=False, microbatches=1)
    return units, mk


def calibrate(cfg, shape: str, mesh) -> dict:
    units, mk = _calibration_cfgs(cfg)
    pts = {}
    for n in (1, 2):
        compiled = lower_and_compile(mk(n), shape, mesh)
        cost = _cost_analysis_dict(compiled)
        colls = parse_collectives(compiled.as_text())
        pts[n] = {
            "flops": cost.get("flops", 0.0),
            "bytes": cost.get("bytes", 0.0),
            "wire": sum(c["wire_bytes"] for c in colls.values()),
            "colls": colls,
        }

    def fit(key):
        per = pts[2][key] - pts[1][key]
        return pts[1][key] + (units - 1) * per

    coll_total = {}
    for kind in set(pts[1]["colls"]) | set(pts[2]["colls"]):
        w1 = pts[1]["colls"].get(kind, {}).get("wire_bytes", 0.0)
        w2 = pts[2]["colls"].get(kind, {}).get("wire_bytes", 0.0)
        c1 = pts[1]["colls"].get(kind, {}).get("count", 0)
        c2 = pts[2]["colls"].get(kind, {}).get("count", 0)
        wt = w1 + (units - 1) * (w2 - w1)
        ct = c1 + (units - 1) * (c2 - c1)
        if ct > 0 and wt > 0:
            coll_total[kind] = {"wire_bytes": wt, "count": int(ct)}
    return {
        "flops_per_device": fit("flops"),
        "bytes_per_device": fit("bytes"),
        "wire_bytes_per_device": fit("wire"),
        "collectives": coll_total,
        "units": units,
        "points": {str(k): {kk: vv for kk, vv in v.items() if kk != "colls"}
                   for k, v in pts.items()},
    }


def run_cell(arch: str, shape: str, multi_pod: bool, verbose: bool = True,
             cfg_override=None, label: str | None = None) -> dict:
    cfg = cfg_override or get_config(arch)
    ok, reason = shape_applicable(cfg, shape)
    mesh = make_production_mesh(multi_pod=multi_pod)
    mname = mesh_name(mesh)
    cell = {"arch": label or arch, "shape": shape, "mesh": mname}
    if not ok:
        cell.update(status="skip", reason=reason)
        return cell
    info = SHAPES[shape]
    chips = int(np.prod(list(mesh.shape.values())))
    t0 = time.time()
    try:
        compiled = lower_and_compile(cfg, shape, mesh)      # REAL artifact
        t_real = time.time() - t0
        mem = _mem_analysis_dict(compiled)
        hlo_bytes = len(compiled.as_text())
        del compiled
        cal = calibrate(cfg, shape, mesh)                    # calibration pair
        t_all = time.time() - t0

        n_total, n_active = count_params(cfg)
        mf = model_flops(cfg, info, n_total, n_active)
        peak_mem = mem.get("argument_bytes", 0) + mem.get("temp_bytes", 0)
        rl = Roofline(
            arch=arch, shape=shape, mesh=mname, chips=chips,
            flops_per_device=cal["flops_per_device"],
            bytes_per_device=cal["bytes_per_device"],
            wire_bytes_per_device=cal["wire_bytes_per_device"],
            collectives=cal["collectives"],
            model_flops=mf,
            peak_memory_per_device=float(peak_mem),
        )
        cell.update(status="ok", compile_s=round(t_real, 1),
                    total_s=round(t_all, 1), memory=mem,
                    calibration=cal["points"], units=cal["units"],
                    roofline=rl.as_dict(), params_total=n_total,
                    params_active=n_active, hlo_bytes=hlo_bytes)
        if verbose:
            print(f"[ok] {cell['arch']} × {shape} × {mname}: "
                  f"compile {t_real:.0f}s (+cal {t_all - t_real:.0f}s) "
                  f"mem/dev={peak_mem/2**30:.2f}GiB "
                  f"bottleneck={rl.bottleneck} roofline={rl.roofline_fraction:.2%} "
                  f"useful={rl.useful_ratio:.2f}", flush=True)
    except Exception as e:
        cell.update(status="fail", error=f"{type(e).__name__}: {e}",
                    traceback=traceback.format_exc()[-4000:])
        if verbose:
            print(f"[FAIL] {cell['arch']} × {shape} × {mname}: "
                  f"{type(e).__name__}: {e}", flush=True)
    return cell


def cell_path(arch: str, shape: str, multi_pod: bool) -> pathlib.Path:
    mname = "2x16x16" if multi_pod else "16x16"
    if os.environ.get("REPRO_MESH"):
        mname = os.environ["REPRO_MESH"].replace(",", "x")
    return OUT_DIR / f"{arch}__{shape}__{mname}.json"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    OUT_DIR.mkdir(parents=True, exist_ok=True)
    archs = list_configs() if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if args.shape is None else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    n_ok = n_fail = n_skip = 0
    for mp in meshes:
        for arch in archs:
            for shape in shapes:
                p = cell_path(arch, shape, mp)
                if p.exists() and not args.force:
                    prev = json.loads(p.read_text())
                    if prev.get("status") in ("ok", "skip"):
                        print(f"[cached] {arch} × {shape} × {prev['mesh']}: "
                              f"{prev['status']}", flush=True)
                        n_ok += prev["status"] == "ok"
                        n_skip += prev["status"] == "skip"
                        continue
                cell = run_cell(arch, shape, mp)
                p.write_text(json.dumps(cell, indent=1))
                n_ok += cell["status"] == "ok"
                n_fail += cell["status"] == "fail"
                n_skip += cell["status"] == "skip"
    print(f"\ndry-run summary: ok={n_ok} fail={n_fail} skip={n_skip}")
    return 0 if n_fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
