"""Roofline terms from compiled dry-run artifacts (EXPERIMENTS.md §Roofline).

  compute    = HLO_FLOPs_per_device / peak_FLOP/s
  memory     = HLO_bytes_per_device / HBM_bw
  collective = Σ per-device wire bytes / ICI_bw

``compiled.cost_analysis()`` gives per-partition FLOPs/bytes (the SPMD
module is per-device).  Collective bytes are NOT in cost_analysis: we parse
``compiled.as_text()`` — post-SPMD HLO where all-gather/all-reduce/…
appear with per-device result shapes — and apply ring formulas with the
replica-group size n:

  all-gather        out × (n−1)/n          (out = gathered result)
  reduce-scatter    out × (n−1)            (out = local shard)
  all-reduce        2 × out × (n−1)/n
  all-to-all        out × (n−1)/n
  collective-permute out × 1

MODEL_FLOPS = 6·N·D (train, dense) / 6·N_active·D (MoE); 2·N·D per decoded
token.  The useful-compute ratio MODEL_FLOPS / (HLO_FLOPs × chips) exposes
remat and dispatch waste.
"""
from __future__ import annotations

import dataclasses
import json
import re

import numpy as np

from .mesh import HW

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
         "collective-permute")
# e.g.:  %ag = bf16[4,128]{1,0} all-gather(%p), replica_groups=...
_LINE_RE = re.compile(
    r"=\s*(?:\()?([a-z0-9]+)\[([0-9,]*)\][^=]*?\s"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    per = _DTYPE_BYTES.get(dtype, 4)
    if not dims:
        return per
    return per * int(np.prod([int(d) for d in dims.split(",") if d]))


def parse_collectives(hlo_text: str) -> dict:
    """Per-device wire bytes by collective type + op counts.

    XLA:CPU *promotes* bf16 all-reduces to f32 (no bf16 arithmetic on CPU);
    TPU reduces bf16 natively.  We detect promotion — an f32 all-reduce whose
    operand is produced by a convert-fusion — and count 2 bytes/element
    (verified semantically: JAX-level activation cotangents are bf16)."""
    producers: dict[str, str] = {}
    lines = hlo_text.splitlines()
    for line in lines:
        ls = line.strip()
        if ls.startswith("%") and "=" in ls:
            producers[ls.split(" ", 1)[0].lstrip("%")] = ls
    out = {c: {"wire_bytes": 0.0, "count": 0, "raw_bytes": 0,
               "bf16_promoted": 0} for c in _COLL}
    for line in lines:
        m = _LINE_RE.search(line)
        if m is None:
            continue
        dtype, dims, kind = m.groups()
        if f" {kind}" not in line and f"{kind}(" not in line:
            continue
        nbytes = _shape_bytes(dtype, dims)
        if kind == "all-reduce" and dtype == "f32":
            ops = re.findall(r"all-reduce(?:-start)?\(([^)]*)\)", line)
            if ops:
                first = ops[0].split(",")[0].strip().lstrip("%")
                src = producers.get(first, "")
                if "convert" in first or "convert" in src.split("=")[0]:
                    nbytes //= 2
                    out[kind]["bf16_promoted"] += 1
        # variadic collectives: count every result operand in the tuple
        if "= (" in line.split(kind)[0]:
            tuple_part = line.split("= (", 1)[1].split(")")[0]
            nbytes = sum(
                _shape_bytes(d, s)
                for d, s in re.findall(r"([a-z0-9]+)\[([0-9,]*)\]", tuple_part)
            )
        n = 1
        g = _GROUPS_LIST_RE.search(line)
        if g:
            n = len(g.group(1).split(","))
        else:
            g = _GROUPS_IOTA_RE.search(line)
            if g:
                n = int(g.group(2))
        if n <= 1 and kind != "collective-permute":
            continue
        if kind == "all-gather":
            wire = nbytes * (n - 1) / n
        elif kind == "all-reduce":
            wire = 2 * nbytes * (n - 1) / n
        elif kind == "reduce-scatter":
            wire = nbytes * (n - 1)
        elif kind == "all-to-all":
            wire = nbytes * (n - 1) / n
        else:
            wire = nbytes
        out[kind]["wire_bytes"] += wire
        out[kind]["count"] += 1
        out[kind]["raw_bytes"] += nbytes
    return out


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    wire_bytes_per_device: float
    collectives: dict
    model_flops: float
    peak_memory_per_device: float

    @property
    def t_compute(self) -> float:
        return self.flops_per_device / HW["peak_flops_bf16"]

    @property
    def t_memory(self) -> float:
        return self.bytes_per_device / HW["hbm_bw"]

    @property
    def t_collective(self) -> float:
        return self.wire_bytes_per_device / HW["ici_bw"]

    @property
    def bottleneck(self) -> str:
        t = {"compute": self.t_compute, "memory": self.t_memory,
             "collective": self.t_collective}
        return max(t, key=t.get)

    @property
    def useful_ratio(self) -> float:
        total = self.flops_per_device * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """useful-FLOPs MFU bound implied by the dominant term."""
        t_star = max(self.t_compute, self.t_memory, self.t_collective)
        if t_star == 0:
            return 0.0
        return (self.model_flops / self.chips / HW["peak_flops_bf16"]) / t_star

    def as_dict(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "wire_bytes_per_device": self.wire_bytes_per_device,
            "peak_memory_per_device": self.peak_memory_per_device,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops,
            "useful_ratio": self.useful_ratio,
            "roofline_fraction": self.roofline_fraction,
            "collectives": self.collectives,
        }


def model_flops(cfg, shape_info: dict, n_params: float, n_active: float) -> float:
    """6·N·D for training; 2·N·D per token for decode; 2·N·D·S for prefill."""
    B, S = shape_info["batch"], shape_info["seq"]
    if shape_info["kind"] == "train":
        return 6.0 * n_active * B * S
    if shape_info["kind"] == "prefill":
        return 2.0 * n_active * B * S
    return 2.0 * n_active * B * 1  # decode: one token per sequence


def count_params(cfg) -> tuple[float, float]:
    """(total, active) parameter counts from the config arithmetic."""
    D, L, V = cfg.d_model, cfg.num_layers, cfg.vocab_size
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    embed = V * D * (1 if cfg.tie_embeddings else 2)
    if cfg.family == "xlstm":
        G = L // cfg.xlstm_group
        n_m = cfg.xlstm_group - 1
        per_m = 4 * D * H * hd + 2 * D * H + H * hd * D
        per_s = 4 * (D * H * hd + H * hd * hd) + H * hd * D
        total = embed + G * (n_m * per_m + per_s)
        return float(total), float(total)
    if cfg.family == "hybrid":
        G = L // cfg.hybrid_group
        n_m = cfg.hybrid_group - 1
        d_in = cfg.ssm_expand * D
        Hs = d_in // cfg.ssm_headdim
        per_mamba = 2 * D * d_in + 2 * D * cfg.ssm_state + D * Hs + d_in * D
        attn = D * (H + 2 * KV) * hd + H * hd * D
        mlp = 3 * D * cfg.d_ff
        total = embed + G * (n_m * per_mamba + attn + mlp)
        return float(total), float(total)
    if cfg.mla:
        attn = (D * cfg.q_lora_rank + cfg.q_lora_rank * H * (hd + cfg.rope_head_dim)
                + D * (cfg.kv_lora_rank + cfg.rope_head_dim)
                + cfg.kv_lora_rank * H * (hd + cfg.v_head_dim) + H * cfg.v_head_dim * D)
    else:
        attn = D * (H + 2 * KV) * hd + H * hd * D
    if cfg.num_experts:
        per_expert = 3 * D * cfg.d_ff
        shared = 3 * D * cfg.d_ff * cfg.num_shared_experts
        router = D * cfg.num_experts
        mlp_total = cfg.num_experts * per_expert + shared + router
        mlp_active = cfg.num_experts_per_tok * per_expert + shared + router
    else:
        nmat = 3 if cfg.mlp == "swiglu" else 2
        mlp_total = mlp_active = nmat * D * cfg.d_ff
    enc = cfg.encoder_layers * (attn * 2 + mlp_total) if cfg.family == "encdec" else 0
    xattn = attn if cfg.family == "encdec" else 0
    total = embed + L * (attn + xattn + mlp_total) + enc
    active = embed + L * (attn + xattn + mlp_active) + enc
    return float(total), float(active)


def save_report(path: str, rows: list[dict]):
    with open(path, "w") as f:
        json.dump(rows, f, indent=1)
