"""Production meshes (TPU v5e).

Single pod: (16, 16)  ("data", "model")   — 256 chips.
Multi-pod : (2, 16, 16) ("pod", "data", "model") — 512 chips; the ``pod``
axis is pure data parallelism (its collectives ride DCN, so the sharding
rules place only the gradient all-reduce there).

Functions, not module constants — importing this module never touches JAX
device state (the dry-run sets XLA_FLAGS before any jax import).
"""
from __future__ import annotations

import jax

HW = {
    "peak_flops_bf16": 197e12,   # per chip
    "hbm_bw": 819e9,             # bytes/s per chip
    "ici_bw": 50e9,              # bytes/s per link
}


def make_production_mesh(*, multi_pod: bool = False):
    import os
    override = os.environ.get("REPRO_MESH")  # e.g. "2,2" — test-scale meshes
    if override:
        shape = tuple(int(x) for x in override.split(","))
        axes = ("pod", "data", "model")[-len(shape):]
        return jax.make_mesh(shape, axes)
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def mesh_name(mesh) -> str:
    return "x".join(str(mesh.shape[a]) for a in mesh.axis_names)


def make_host_mesh():
    """Degenerate 1-device mesh for smoke tests."""
    return jax.make_mesh((1, 1), ("data", "model"))


def dp_axes(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def tp_axis(mesh) -> str:
    return "model"


def dp_size(mesh) -> int:
    import numpy as np
    return int(np.prod([mesh.shape[a] for a in dp_axes(mesh)]))
