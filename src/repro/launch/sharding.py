"""Sharding rules: logical placement for every param / batch / cache leaf.

Axis roles
  model ("tp")        — tensor parallel: attention heads, FFN hidden, expert
                        dim (EP) or vocab rows; chosen per-leaf with
                        divisibility guards (GQA kv=8 < tp=16 ⇒ replicate
                        heads, shard head_dim instead where legal).
  data  ("fsdp"/dp)   — batch, plus ZeRO-3 weight sharding when cfg.fsdp.
  pod   (dp only)     — pure data parallelism across pods (DCN): batch and
                        gradient all-reduce, never weight storage.

Everything funnels through ``spec_for_param`` / ``batch_specs`` /
``cache_specs`` so the dry-run, the launchers, and the tests agree on one
source of truth.
"""
from __future__ import annotations

import re
from typing import Any

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig
from .mesh import dp_axes, dp_size


def _axsize(mesh, name) -> int:
    return int(mesh.shape[name]) if name in mesh.axis_names else 1


def _div(dim: int, mesh, axis: str):
    """axis if it divides dim, else None (replicate)."""
    return axis if dim % max(_axsize(mesh, axis), 1) == 0 and _axsize(mesh, axis) > 1 else None


def _dp(mesh, dim: int):
    axes = dp_axes(mesh)
    if not axes:
        return None
    if dim % dp_size(mesh) == 0:
        return axes if len(axes) > 1 else axes[0]
    # try data-only (e.g. batch 16 on a 2x16 dp grid)
    if "data" in axes and dim % _axsize(mesh, "data") == 0:
        return "data"
    return None


def activation_rules(cfg: ModelConfig, mesh, batch: int) -> dict:
    """Logical-name → mesh-axes map for models/shardctx.constrain."""
    return {
        "batch": _dp(mesh, batch),
        "vocab": _div(cfg.padded_vocab, mesh, "model"),
        "expert": _div(cfg.num_experts, mesh, "model") if cfg.num_experts else None,
        "tp": "model",
        "fsdp": "data" if (cfg.fsdp and _axsize(mesh, "data") > 1) else None,
    }


# --------------------------------------------------------------------- params
def _param_spec(path: str, shape: tuple, cfg: ModelConfig, mesh) -> P:
    """Spec for the *trailing* (per-layer) dims; leading scan dims handled by
    the caller.  ``path`` is a '/'-joined key path."""
    fsdp = "data" if (cfg.fsdp and _axsize(mesh, "data") > 1) else None
    tp = "model"
    name = path.split("/")[-1]
    nd = len(shape)

    def fs(dim_idx):
        return fsdp if fsdp and shape[dim_idx] % _axsize(mesh, "data") == 0 else None

    # embeddings / head
    if name == "embed":
        return P(_div(shape[0], mesh, tp), fs(1))
    if name == "lm_head":
        return P(fs(0), _div(shape[1], mesh, tp))

    # MoE experts: (E, D, F) / (E, F, D) — EP over tp when E divides, else
    # hidden-sharded; the d_model dim additionally ZeRO-shards over data when
    # cfg.fsdp (models/moe.py all-gathers it inside shard_map, bf16).
    if re.search(r"moe/(wg|wu|wd)$", path):
        ep = _div(shape[0], mesh, tp)
        if ep:
            # ZeRO-shard the FFN (F) dim over data: the shard_map body either
            # weight-gathers it (train/prefill) or keeps the slice and
            # token-gathers instead (decode) — models/moe.py §Perf #8
            if name in ("wg", "wu"):
                return P(ep, None, fs(2))
            return P(ep, fs(1), None)
        if name in ("wg", "wu"):
            return P(None, fs(1), _div(shape[2], mesh, tp))
        return P(None, _div(shape[1], mesh, tp), fs(2))
    if name == "router":
        return P(fs(0), None)

    # xlstm mLSTM: shard the value/output dim (state output axis)
    if "/mlstm/" in path or "/slstm/" in path:
        if name in ("wv", "wz"):
            return P(fs(0), None, _div(shape[2], mesh, tp))
        if name in ("wq", "wk"):
            return P(fs(0), None, None)
        if name == "wo":
            return P(None, _div(shape[1], mesh, tp), fs(2))
        if name == "out_norm":
            return P(None, _div(shape[1], mesh, tp))
        return P(*([None] * nd))

    # mamba2: shard SSM heads
    if "/mamba/" in path or "cell/" in path and name in (
        "wz", "wx", "wB", "wC", "w_dt", "dt_bias", "A_log", "D_skip",
        "conv_x", "conv_B", "conv_C", "out_norm",
    ):
        if name in ("wz", "wx"):
            return P(fs(0), _div(shape[1], mesh, tp), None)
        if name in ("wB", "wC"):
            return P(fs(0), None)
        if name == "w_dt":
            return P(fs(0), _div(shape[1], mesh, tp))
        if name in ("dt_bias", "A_log", "D_skip"):
            return P(_div(shape[0], mesh, tp))
        if name == "conv_x":
            return P(None, _div(shape[1], mesh, tp), None)
        if name in ("conv_B", "conv_C"):
            return P(None, None)
        if name == "out_norm":
            return P(_div(shape[0], mesh, tp), None)

    # attention
    if name in ("wq", "wk", "wv"):          # (D, H, hd)
        h_ax = _div(shape[1], mesh, tp)
        hd_ax = _div(shape[2], mesh, tp) if h_ax is None else None
        return P(fs(0), h_ax, hd_ax)
    if name == "wo" and nd == 3:             # (H, hd, D)
        h_ax = _div(shape[0], mesh, tp)
        hd_ax = _div(shape[1], mesh, tp) if h_ax is None else None
        return P(h_ax, hd_ax, fs(2))
    if name in ("bq", "bk", "bv"):            # (H, hd)
        return P(_div(shape[0], mesh, tp), None)
    # MLA
    if name in ("wq_a", "wkv_a"):             # (D, r)
        return P(fs(0), None)
    if name in ("wq_b", "wk_b", "wv_b"):      # (r, H, d)
        return P(fs(0), _div(shape[1], mesh, tp), None)

    # dense MLPs (incl. shared experts): (D, F) / (F, D)
    if name in ("wg", "wu", "wi"):
        return P(fs(0), _div(shape[1], mesh, tp))
    if name == "wd":
        return P(_div(shape[0], mesh, tp), fs(1))
    if name in ("bi",):
        return P(_div(shape[0], mesh, tp))
    if name in ("bd",):
        return P(None)

    # norms, biases, gates — replicate
    return P(*([None] * nd))


def _leading_scan_dims(path: str, cfg: ModelConfig) -> int:
    if "/mlstm/" in path or "/mamba/" in path:
        return 2                      # (G, n_inner, ...)
    if "/slstm/" in path:
        return 1                      # (G, ...)
    if "shared_attn/" in path:
        return 0                      # weight-tied single block
    if path.startswith(("stack/", "enc/")):
        return 1                      # (L, ...)
    return 0


def _path_str(key_path) -> str:
    parts = []
    for k in key_path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def param_pspecs(cfg: ModelConfig, params_shape, mesh):
    """Pytree of PartitionSpec matching a params (shape) pytree."""

    def one(key_path, leaf):
        path = _path_str(key_path)
        lead = _leading_scan_dims(path, cfg)
        trailing = tuple(leaf.shape[lead:])
        spec = _param_spec(path, trailing, cfg, mesh)
        return P(*([None] * lead + list(spec)))

    return jax.tree_util.tree_map_with_path(one, params_shape)


def opt_pspecs(cfg: ModelConfig, params_shape, mesh):
    ps = param_pspecs(cfg, params_shape, mesh)
    return {"m": ps, "v": ps, "step": P()}


# --------------------------------------------------------------------- batch
def batch_specs(cfg: ModelConfig, batch_shapes: dict, mesh) -> dict:
    out: dict[str, Any] = {}
    for k, v in batch_shapes.items():
        if k == "cache":
            out[k] = cache_specs(cfg, v, mesh)
            continue
        if k == "pos":
            out[k] = P()
            continue
        b = v.shape[0] if v.ndim else 1
        dp = _dp(mesh, b)
        if k in ("frames", "patches"):
            out[k] = P(dp, None, None)
        else:
            out[k] = P(*([dp] + [None] * (v.ndim - 1)))
    return out


def cache_specs(cfg: ModelConfig, cache_shapes, mesh):
    """Decode caches: batch over dp, heads (or head_dim / latent dim) over tp."""

    def one(key_path, leaf):
        path = _path_str(key_path)
        name = path.split("/")[-1]
        nd = leaf.ndim
        if name == "kpos":
            return P(*([None] * nd))
        if name in ("c_kv", "k_rope"):     # (L, B, S, r)
            return P(None, _dp(mesh, leaf.shape[1]), None,
                     _div(leaf.shape[3], mesh, "model"))
        if name in ("k", "v") or "cross" in path:
            # (L_or_G, B, S, KV, hd) or xattn precomputed (L, B, Se, KV, hd)
            if nd == 5:
                kv_ax = _div(leaf.shape[3], mesh, "model")
                hd_ax = _div(leaf.shape[4], mesh, "model") if kv_ax is None else None
                return P(None, _dp(mesh, leaf.shape[1]), None, kv_ax, hd_ax)
        if "ssm" in path and nd == 6:       # (G, n_m, B, H, P, N)
            return P(None, None, _dp(mesh, leaf.shape[2]),
                     _div(leaf.shape[3], mesh, "model"), None, None)
        if "conv" in path and nd == 5:      # (G, n_m, B, ks, C)
            return P(None, None, _dp(mesh, leaf.shape[2]), None,
                     _div(leaf.shape[4], mesh, "model"))
        if "m/" in path or path.startswith("m"):
            pass
        # xlstm states: shard batch over dp; value dim over tp when present
        if nd == 6:                          # mLSTM C (G, n_m, B, H, dv, dk)
            return P(None, None, _dp(mesh, leaf.shape[2]),
                     None, _div(leaf.shape[4], mesh, "model"), None)
        if nd == 5:                          # mLSTM n (G, n_m, B, H, d)
            return P(None, None, _dp(mesh, leaf.shape[2]), None, None)
        if nd == 4:                          # sLSTM states (G, B, H, dh) / mLSTM m
            return P(None, _dp(mesh, leaf.shape[1]), None, None)
        if nd == 3:
            return P(None, _dp(mesh, leaf.shape[1]), None)
        return P(*([None] * nd))

    return jax.tree_util.tree_map_with_path(one, cache_shapes)


def to_named(tree_specs, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))
