"""Serving driver: batched greedy decoding with a persistent KV cache/state.

Covers every family: dense/moe/vlm prefill the cache in one pass; recurrent
families (xlstm/hybrid) warm state by stepping the prompt token-by-token
(their prefill-parallel path does not thread final states out — DESIGN §7).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-14b --reduce \
      --batch 4 --prompt-len 16 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config
from ..models import transformer as TR
from .steps import make_serve_step


def decode_loop(model, serve_step, params, prompt, gen: int, cache_seq: int):
    cfg = model.cfg
    B, S = prompt.shape
    cache = model.init_cache(B, cache_seq)
    if cfg.family == "encdec":
        kv = TR.init_kv_caches(cfg, B, cfg.encoder_seq, dtype=jnp.dtype(cfg.dtype))
        cache["cross"] = (kv["k"], kv["v"])
    out_tokens = []
    # warm the cache on the prompt
    tok = prompt[:, :1]
    for t in range(S - 1):
        _, _, cache = serve_step(
            params, {"token": prompt[:, t:t + 1], "pos": jnp.asarray(t, jnp.int32),
                     "cache": cache})
    tok = prompt[:, -1:]
    for t in range(S - 1, S - 1 + gen):
        nxt, _, cache = serve_step(
            params, {"token": tok, "pos": jnp.asarray(t, jnp.int32), "cache": cache})
        tok = nxt[:, None]
        out_tokens.append(np.asarray(tok))
    return np.concatenate(out_tokens, axis=1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduce", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduce:
        cfg = cfg.reduced()
    model, serve_step = make_serve_step(cfg)
    serve_step = jax.jit(serve_step, donate_argnums=(1,))
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompt = jnp.asarray(
        rng.integers(0, cfg.vocab_size, size=(args.batch, args.prompt_len)),
        jnp.int32)
    t0 = time.time()
    out = decode_loop(model, serve_step, params, prompt, args.gen,
                      cache_seq=args.prompt_len + args.gen)
    dt = time.time() - t0
    print(f"arch={cfg.name} generated {out.shape} in {dt:.1f}s "
          f"({args.batch * args.gen / max(dt, 1e-9):.1f} tok/s)")
    print("sample:", out[0][:16])
    assert np.all(out >= 0) and np.all(out < cfg.vocab_size)
    return out


if __name__ == "__main__":
    main()
