"""Serving driver: batched greedy decoding with a persistent KV cache/state.

Covers every family: dense/moe/vlm prefill the cache in one pass; recurrent
families (xlstm/hybrid) warm state by stepping the prompt token-by-token
(their prefill-parallel path does not thread final states out — DESIGN §7).

Decoding runs through the ``repro.serving`` engine (one serving code path
for LM decode and PS request traffic): each token step is one engine
request, prompt tokens are staged ahead as ``ReadyHandle`` payloads, and
the engine's latency recorder supplies the tokens/s accounting.
``decode_loop`` is the pre-engine reference loop, kept as the parity
oracle (tests assert bit-identical tokens).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-14b --reduce \
      --batch 4 --prompt-len 16 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config
from ..models import transformer as TR
from ..serving import ReadyHandle, Request, ServingEngine
from .steps import make_serve_step


def _init_cache(model, B: int, cache_seq: int):
    cfg = model.cfg
    cache = model.init_cache(B, cache_seq)
    if cfg.family == "encdec":
        kv = TR.init_kv_caches(cfg, B, cfg.encoder_seq,
                               dtype=jnp.dtype(cfg.dtype))
        cache["cross"] = (kv["k"], kv["v"])
    return cache


def decode_loop(model, serve_step, params, prompt, gen: int, cache_seq: int):
    """Pre-engine reference decode (parity oracle for the engine route)."""
    B, S = prompt.shape
    cache = _init_cache(model, B, cache_seq)
    out_tokens = []
    # warm the cache on the prompt
    tok = prompt[:, :1]
    for t in range(S - 1):
        _, _, cache = serve_step(
            params, {"token": prompt[:, t:t + 1], "pos": jnp.asarray(t, jnp.int32),
                     "cache": cache})
    tok = prompt[:, -1:]
    for t in range(S - 1, S - 1 + gen):
        nxt, _, cache = serve_step(
            params, {"token": tok, "pos": jnp.asarray(t, jnp.int32), "cache": cache})
        tok = nxt[:, None]
        out_tokens.append(np.asarray(tok))
    return np.concatenate(out_tokens, axis=1)


class DecodeSource:
    """Greedy decode as an engine request source: one request per token
    step.  Prompt tokens are known ahead, so their host→device staging
    prefetches behind the current step; generated tokens depend on the
    previous commit, so their payload is read at compute time (the engine
    commits step t before computing t+1 in both modes)."""

    def __init__(self, model, serve_step, params, prompt, gen: int,
                 cache_seq: int):
        B, S = prompt.shape
        self.serve_step = serve_step
        self.params = params
        self.prompt = prompt
        self.gen = gen
        self.warm_steps = S - 1
        self.num_steps = S - 1 + gen
        self.batch = B
        self.cache = _init_cache(model, B, cache_seq)
        self.tok = prompt[:, -1:]
        self.out_tokens: list[np.ndarray] = []
        self._pos = 0

    def on_step(self, t: int) -> None:
        pass

    def next_request(self, t: int) -> Request:
        phase = "prefill" if t < self.warm_steps else "decode"
        return Request(tenant=phase, home=0, rows=None, batch=None,
                       need=None, examples=self.batch, tokens=self.batch)

    def issue(self, req: Request, t: int) -> ReadyHandle:
        if t < self.warm_steps:
            # prompt token known ahead: stage the device transfer now
            return ReadyHandle(jnp.asarray(self.prompt[:, t:t + 1]))
        return ReadyHandle(None)   # generated token: read at compute time

    def compute(self, req: Request, payload):
        tok = payload if payload is not None else self.tok
        return self.serve_step(
            self.params,
            {"token": tok, "pos": jnp.asarray(self._pos, jnp.int32),
             "cache": self.cache})

    def commit(self, req: Request, out, t: int) -> dict:
        nxt, _, cache = out
        self.cache = cache
        if t >= self.warm_steps:
            self.tok = nxt[:, None]
            self.out_tokens.append(np.asarray(self.tok))
        self._pos += 1
        return {}

    def run(self, prefetch: bool = True) -> tuple[np.ndarray, dict]:
        engine = ServingEngine(self, prefetch=prefetch, warmup=0)
        summary = engine.run(self.num_steps)
        return np.concatenate(self.out_tokens, axis=1), summary


def decode_loop_engine(model, serve_step, params, prompt, gen: int,
                       cache_seq: int, prefetch: bool = True):
    """Engine-routed decode; bit-identical tokens to ``decode_loop``."""
    src = DecodeSource(model, serve_step, params, prompt, gen, cache_seq)
    return src.run(prefetch=prefetch)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduce", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduce:
        cfg = cfg.reduced()
    model, serve_step = make_serve_step(cfg)
    serve_step = jax.jit(serve_step, donate_argnums=(1,))
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompt = jnp.asarray(
        rng.integers(0, cfg.vocab_size, size=(args.batch, args.prompt_len)),
        jnp.int32)
    t0 = time.time()
    out, summary = decode_loop_engine(model, serve_step, params, prompt,
                                      args.gen,
                                      cache_seq=args.prompt_len + args.gen)
    dt = time.time() - t0
    print(f"arch={cfg.name} generated {out.shape} in {dt:.1f}s "
          f"({args.batch * args.gen / max(dt, 1e-9):.1f} tok/s, engine "
          f"p50 {summary['p50_ms']:.1f}ms p99 {summary['p99_ms']:.1f}ms "
          f"per token step)")
    print("sample:", out[0][:16])
    assert np.all(out >= 0) and np.all(out < cfg.vocab_size)
    return out


if __name__ == "__main__":
    main()
