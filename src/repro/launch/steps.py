"""train_step / serve_step factories — the units the dry-run lowers and the
launchers execute.

The logical-axis rules context is entered *inside* the step so the
activation sharding constraints bind during tracing under any jit/lowering.
"""
from __future__ import annotations

import contextlib
import functools

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..models.model import Model, build_model
from ..models.shardctx import logical_axis_rules
from ..optim import AdamWConfig, apply_updates, compress_grads, init_compression, init_opt_state
from .sharding import activation_rules


def _rules_ctx(cfg, mesh, batch_size):
    if mesh is None:
        return contextlib.nullcontext()
    return logical_axis_rules(mesh, activation_rules(cfg, mesh, batch_size))


def _effective_microbatches(cfg, mesh, B: int) -> int:
    """Largest n ≤ cfg.microbatches with (B/n) still dividing the dp axes."""
    n = max(1, cfg.microbatches)
    if mesh is None:
        return min(n, B) if B % min(n, B) == 0 else 1
    from .mesh import dp_size
    dp = dp_size(mesh)
    while n > 1 and (B % n or (B // n) % dp):
        n -= 1
    return max(n, 1)


def make_train_step(cfg: ModelConfig, mesh=None, opt_cfg: AdamWConfig | None = None):
    model = build_model(cfg)
    opt_cfg = opt_cfg or AdamWConfig(moment_dtype=cfg.opt_dtype)

    def train_step(params, opt_state, batch):
        B = batch["tokens"].shape[0]
        n = _effective_microbatches(cfg, mesh, B)
        with _rules_ctx(cfg, mesh, B // n):
            grad_fn = jax.value_and_grad(model.loss_fn, has_aux=True)
            if n == 1:
                (loss, metrics), grads = grad_fn(params, batch)
            else:
                # gradient accumulation: activation memory ÷ n, same math
                micro = jax.tree.map(
                    lambda x: x.reshape((n, x.shape[0] // n) + x.shape[1:]),
                    batch)

                def body(acc, mb):
                    (l, m), g = grad_fn(params, mb)
                    g_acc = jax.tree.map(jnp.add, acc[0], g)
                    return (g_acc, acc[1] + l, acc[2] + m["tokens"]), None

                zeros = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)
                (g_sum, l_sum, tok), _ = jax.lax.scan(
                    body, (zeros, jnp.zeros((), jnp.float32),
                           jnp.zeros((), jnp.float32)), micro)
                grads = jax.tree.map(lambda g: g / n, g_sum)
                loss = l_sum / n
                metrics = {"loss": loss, "tokens": tok}
            if cfg.grad_compress:
                grads, comp = compress_grads(grads, opt_state["comp"])
            new_params, new_opt, om = apply_updates(
                params, grads, opt_state, opt_cfg)
            if cfg.grad_compress:
                new_opt["comp"] = comp
            metrics.update(om)
            return new_params, new_opt, metrics

    def init_state(key):
        params = model.init(key)
        opt = init_opt_state(params, opt_cfg)
        if cfg.grad_compress:
            opt["comp"] = init_compression(params)
        return params, opt

    return model, train_step, init_state, opt_cfg


def make_prefill_step(cfg: ModelConfig, mesh=None):
    """Inference prefill: forward + KV-cache population (no gradients).
    Recurrent families lower the forward pass (their states are warmed by
    the serving loop — DESIGN §7)."""
    model = build_model(cfg)

    def prefill_step(params, batch):
        with _rules_ctx(cfg, mesh, batch["tokens"].shape[0]):
            if cfg.family in ("xlstm", "hybrid"):
                loss, metrics = model.loss_fn(params, batch)
                return metrics["loss"]
            logits, cache = model.prefill(params, batch)
            return logits, cache

    return model, prefill_step


def make_serve_step(cfg: ModelConfig, mesh=None):
    model = build_model(cfg)

    def serve_step(params, batch):
        with _rules_ctx(cfg, mesh, batch["token"].shape[0]):
            logits, new_cache = model.decode_step(params, batch)
            # greedy sample — serving loop feeds it back
            next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return next_token, logits, new_cache

    return model, serve_step


def make_eval_step(cfg: ModelConfig, mesh=None):
    model = build_model(cfg)

    def eval_step(params, batch):
        with _rules_ctx(cfg, mesh, batch["tokens"].shape[0]):
            loss, metrics = model.loss_fn(params, batch)
            return metrics

    return model, eval_step
