"""Gradient compression for the data-parallel reduce: per-leaf int8
quantization with error feedback (the distributed-optimization analogue of
DBPG's value compression, [19] §5; beyond-paper applied to LM training).

Semantics: q = quantize(g + e);  e' = (g + e) − dequant(q);  the reduce sees
dequant(q).  On a real fabric the wire carries int8 (4× fewer DCN bytes for
the cross-pod all-reduce); in-graph we model the numerics exactly, and the
roofline model credits the cross-pod collective with the 4× byte reduction
when ``cfg.grad_compress`` is on (launch/roofline.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

CompressionState = dict  # error-feedback buffers mirroring grads


def init_compression(params) -> CompressionState:
    return {"ef": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)}


def _q(x):
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127)
    return q * scale  # dequantized wire value


def compress_grads(grads, state: CompressionState):
    def one(g, e):
        tot = g.astype(jnp.float32) + e
        wire = _q(tot)
        return wire, tot - wire

    out = jax.tree.map(one, grads, state["ef"])
    wire = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    ef = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    return wire, {"ef": ef}
