from .adamw import AdamWConfig, init_opt_state, apply_updates  # noqa: F401
from .compression import compress_grads, CompressionState, init_compression  # noqa: F401
