"""AdamW with dtype-policied moments (bf16 for the 200B+ configs) and
global-norm clipping.  Pure pytree functional — shards wherever params shard.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "float32"


def init_opt_state(params, cfg: AdamWConfig):
    md = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, md)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def apply_updates(params, grads, state, cfg: AdamWConfig):
    step = state["step"] + 1
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gn, 1e-12))
    md = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = m.astype(jnp.float32) * cfg.b1 + g * (1 - cfg.b1)
        v32 = v.astype(jnp.float32) * cfg.b2 + jnp.square(g) * (1 - cfg.b2)
        mhat = m32 / (1 - cfg.b1 ** step.astype(jnp.float32))
        vhat = v32 / (1 - cfg.b2 ** step.astype(jnp.float32))
        u = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - cfg.lr * u).astype(p.dtype),
                m32.astype(md), v32.astype(md))

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    return new_params, {"m": new_m, "v": new_v, "step": step}, {"grad_norm": gn}
