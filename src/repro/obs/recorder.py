"""Flight recorder: one bounded timeline for everything that explains an
SLO outcome.

The closed loop's evidence was scattered — chaos events in
``PSRequestSource.events``, elastic ops in ``ElasticSession.ops``,
decisions in ``SLOAutoscaler.decisions``, sheds in ``TelemetryBus.shed``
— each on its own clock.  The recorder correlates them: every layer
records structured events keyed by the engine slot (``step``) and the
virtual time (``v``), and ``explain(window_idx)`` walks that single
timeline to produce the causal chain behind a violated decision window.

Event kinds the instrumented layers emit:

  * ``chaos``        — a ``ChaosEvent`` applied (kind/machine/factor);
  * ``elastic_op``   — an ``ElasticOp`` (with its triggering
    ``TelemetrySnapshot``'s p99/step when the closed loop supplied one);
  * ``window``       — one autoscaler decision window's verdict
    (p99 vs SLO, action, reason);
  * ``decision``     — the autoscaler's own record (when its config
    carries the obs hook);
  * ``breaker_open`` / ``breaker_close`` — circuit transitions;
  * ``shed``         — one admission drop (tenant, backlog).

Events are plain dicts inside a bounded deque (oldest dropped), are
serialized deterministically (``to_json`` — byte-identical across seeded
replays), and snapshot alongside the stream npz via ``save``/``load``.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
from collections import deque

__all__ = ["ObsEvent", "Explanation", "FlightRecorder"]

# cause kinds explain() may attribute a violated window to — the
# vocabulary bench_slo's attribution gate checks against
CAUSE_KINDS = ("burst", "kill", "straggle", "migration")


def _json_default(o):
    try:
        import numpy as np
        if isinstance(o, np.integer):
            return int(o)
        if isinstance(o, np.floating):
            return float(o)
    except ImportError:       # pragma: no cover
        pass
    raise TypeError(f"not JSON-serializable: {type(o)}")


@dataclasses.dataclass
class ObsEvent:
    """One recorded fact: (sequence, engine slot, virtual time, kind,
    payload)."""

    seq: int
    step: int
    v: float
    kind: str
    data: dict

    def as_dict(self) -> dict:
        return {"seq": self.seq, "step": self.step, "v": self.v,
                "kind": self.kind, "data": self.data}


@dataclasses.dataclass
class Explanation:
    """The causal chain behind one decision window's verdict."""

    window: int
    step: int
    verdict: str              # "within-slo" | "violated"
    p99_ms: float | None
    slo_ms: float | None
    causes: list[dict]        # [{"kind", "step", "detail"}, ...]
    evidence: list[dict]      # supporting events in the lookback interval

    @property
    def attributed(self) -> bool:
        return self.verdict != "violated" or bool(self.causes)

    def __str__(self) -> str:
        head = (f"window {self.window} (slot {self.step}): "
                f"p99 {self.p99_ms:.1f}ms "
                if self.p99_ms is not None
                else f"window {self.window} (slot {self.step}): ")
        if self.verdict == "within-slo":
            return head + (f"within SLO {self.slo_ms:.1f}ms"
                           if self.slo_ms is not None else "within SLO")
        lines = [head + (f"VIOLATED SLO {self.slo_ms:.1f}ms"
                         if self.slo_ms is not None else "VIOLATED SLO")]
        if not self.causes:
            lines.append("  no recorded cause (unattributed)")
        for c in self.causes:
            lines.append(f"  <- {c['kind']} @ slot {c['step']}: "
                         f"{c['detail']}")
        return "\n".join(lines)


class FlightRecorder:
    """Bounded structured event log over the serving timeline."""

    def __init__(self, maxlen: int = 8192):
        self._events: deque[ObsEvent] = deque(maxlen=maxlen)
        self._seq = 0

    # ----------------------------------------------------------- record
    def record(self, kind: str, step: int = 0, v: float = 0.0,
               data: dict | None = None, **extra) -> ObsEvent:
        # data= takes payload keys that collide with the parameters here
        # (a chaos event's own "kind", e.g.); **extra is the common path
        payload = dict(data) if data else {}
        payload.update(extra)
        ev = ObsEvent(seq=self._seq, step=int(step), v=float(v),
                      kind=kind, data=payload)
        self._seq += 1
        self._events.append(ev)
        return ev

    @property
    def events(self) -> list[ObsEvent]:
        return list(self._events)

    def of_kind(self, kind: str) -> list[ObsEvent]:
        return [ev for ev in self._events if ev.kind == kind]

    def __len__(self) -> int:
        return len(self._events)

    # ---------------------------------------------------------- explain
    def explain(self, window_idx: int,
                lookback_windows: int = 2) -> Explanation:
        """Causal chain behind decision window ``window_idx``.

        A cause is a recorded condition whose *effect interval* overlaps
        the window's lookback interval ``(lo, step]`` where ``lo`` is the
        slot of the window ``lookback_windows`` earlier (covers backlog
        drain: a burst that calmed one window ago still explains the
        queue the current window is paying down):

          * ``burst``     — load factor > 1 from the burst event until
            the calming event (open-ended if never calmed);
          * ``kill``      — from the kill until that machine's committed
            repair op (open-ended while dead);
          * ``straggle``  — from the straggle until its recover;
          * ``migration`` — a committed elastic op (grow/shrink/repair):
            point effect at its slot (+ the tau-escalation stale window
            it triggers, covered by the lookback).
        """
        windows = self.of_kind("window")
        target = idx_in = None
        for i, ev in enumerate(windows):
            if ev.data.get("window") == window_idx:
                target, idx_in = ev, i
                break
        if target is None:
            raise KeyError(f"no recorded window {window_idx}")
        step, d = target.step, target.data
        p99, slo = d.get("p99_ms"), d.get("slo_ms")
        within = d.get("within")
        if within is None:
            within = (p99 is not None and slo is not None and p99 <= slo)
        if within:
            return Explanation(window_idx, step, "within-slo", p99, slo,
                               [], [])
        lo = (windows[max(idx_in - lookback_windows, 0)].step
              if idx_in > 0 else -1)

        INF = float("inf")
        intervals: list[tuple[str, float, float, str]] = []
        burst = None                # (start step, factor)
        straggles: dict = {}        # machine -> (start step, factor)
        kills: dict = {}            # machine -> kill step
        evidence: list[dict] = []
        for ev in self._events:
            if ev.step > step:
                continue
            if lo < ev.step <= step and ev.kind != "window":
                evidence.append(ev.as_dict())
            if ev.kind == "chaos":
                ck = ev.data.get("kind")
                m = ev.data.get("machine")
                f = ev.data.get("factor", 1.0)
                if ck == "burst":
                    if f is not None and f > 1.0:
                        if burst is None:
                            burst = (ev.step, f)
                    elif burst is not None:
                        intervals.append((
                            "burst", burst[0], ev.step,
                            f"load burst x{burst[1]:g} slots "
                            f"[{burst[0]}, {ev.step}) — queue drains "
                            f"after"))
                        burst = None
                elif ck == "kill":
                    kills[m] = ev.step
                elif ck == "straggle":
                    straggles[m] = (ev.step, f)
                elif ck == "recover":
                    if m in straggles:
                        s0, f0 = straggles.pop(m)
                        intervals.append((
                            "straggle", s0, ev.step,
                            f"machine {m} straggling x{f0:g} slots "
                            f"[{s0}, {ev.step})"))
            elif ev.kind == "elastic_op" and ev.data.get("committed"):
                kind = ev.data.get("kind", "?")
                m = ev.data.get("machine")
                intervals.append((
                    "migration", ev.step, ev.step,
                    f"{kind} op (k {ev.data.get('k_before')}->"
                    f"{ev.data.get('k_after')}, machine {m}, "
                    f"{ev.data.get('migration_bytes', 0)} B moved, "
                    f"tau-escalated serving follows)"))
                if kind == "repair" and m in kills:
                    s0 = kills.pop(m)
                    # inclusive of the repair slot: the retry storm the
                    # kill caused still owns the slot the repair lands in
                    # (under prefetch the end-of-slot repair is even
                    # numbered one slot *before* the kill it answers)
                    intervals.append((
                        "kill", s0, max(ev.step, s0) + 1,
                        f"machine {m} killed at slot {s0}, repaired at "
                        f"{ev.step}"))
        if burst is not None:
            intervals.append(("burst", burst[0], INF,
                              f"load burst x{burst[1]:g} since slot "
                              f"{burst[0]} (still in force)"))
        for m, (s0, f0) in straggles.items():
            intervals.append(("straggle", s0, INF,
                              f"machine {m} straggling x{f0:g} since "
                              f"slot {s0} (not recovered)"))
        for m, s0 in kills.items():
            intervals.append(("kill", s0, INF,
                              f"machine {m} killed at slot {s0} "
                              f"(not repaired)"))
        causes = [{"kind": kind, "step": int(s0), "detail": detail}
                  for kind, s0, s1, detail in intervals
                  if s0 <= step and s1 > lo]
        causes.sort(key=lambda c: (c["step"], c["kind"]))
        return Explanation(window_idx, step, "violated", p99, slo,
                           causes, evidence[:50])

    # -------------------------------------------------------- serialize
    def to_json(self) -> str:
        """Deterministic byte stream — seeded replays compare equal."""
        return json.dumps([ev.as_dict() for ev in self._events],
                          sort_keys=True, separators=(",", ":"),
                          default=_json_default)

    def save(self, path) -> pathlib.Path:
        """Snapshot alongside the stream npz (same basename, .json)."""
        path = pathlib.Path(path)
        path.write_text(self.to_json() + "\n")
        return path

    @classmethod
    def load(cls, path, maxlen: int = 8192) -> "FlightRecorder":
        rec = cls(maxlen=maxlen)
        for d in json.loads(pathlib.Path(path).read_text()):
            ev = ObsEvent(seq=d["seq"], step=d["step"], v=d["v"],
                          kind=d["kind"], data=d["data"])
            rec._events.append(ev)
            rec._seq = max(rec._seq, ev.seq + 1)
        return rec
