"""repro.obs — virtual-clock tracing, flight recorder, metrics export.

One ``Observability`` object bundles the two sinks and threads through
the pipeline as the single ``obs=`` hook (``ServingConfig.obs``,
``SLOConfig.obs``, ``StreamSession(obs=...)``,
``ElasticSession(obs=...)``).  Off by default: every instrumented call
site guards on ``obs is None`` (or the empty installed-tracer registry),
so the disabled path costs one attribute check — asserted in
``tests/test_obs.py``.
"""
from __future__ import annotations

import pathlib

from .trace import (Span, SpanHandle, Tracer, annotate_last_instant,
                    dispatch_instant, trace_instant)
from .recorder import (CAUSE_KINDS, Explanation, FlightRecorder, ObsEvent)
from .export import (chrome_trace_json, prometheus_text,
                     save_chrome_trace, to_chrome_trace)

__all__ = [
    "Observability",
    "Span", "SpanHandle", "Tracer", "trace_instant", "dispatch_instant",
    "annotate_last_instant",
    "ObsEvent", "Explanation", "FlightRecorder", "CAUSE_KINDS",
    "to_chrome_trace", "chrome_trace_json", "save_chrome_trace",
    "prometheus_text",
]


class Observability:
    """Tracer + flight recorder under one handle."""

    def __init__(self, tracer: Tracer | None = None,
                 recorder: FlightRecorder | None = None,
                 max_spans: int = 65536, max_events: int = 8192):
        self.tracer = tracer if tracer is not None else Tracer(max_spans)
        self.recorder = (recorder if recorder is not None
                         else FlightRecorder(max_events))

    def record(self, kind: str, step: int = 0, v: float = 0.0,
               data: dict | None = None, **extra):
        return self.recorder.record(kind, step=step, v=v, data=data,
                                    **extra)

    def explain(self, window_idx: int, lookback_windows: int = 2):
        return self.recorder.explain(window_idx,
                                     lookback_windows=lookback_windows)

    def save(self, dir_path, prefix: str = "obs",
             include_wall: bool = True) -> dict[str, pathlib.Path]:
        """Snapshot both sinks next to the stream npz: returns
        ``{"trace": ..., "events": ...}`` paths."""
        d = pathlib.Path(dir_path)
        d.mkdir(parents=True, exist_ok=True)
        return {
            "trace": save_chrome_trace(self.tracer,
                                       d / f"{prefix}_trace.json",
                                       include_wall=include_wall),
            "events": self.recorder.save(d / f"{prefix}_events.json"),
        }
