"""Virtual-clock tracing: nested spans over the deterministic timeline.

The serving/elastic/stream pipeline already keeps a *virtual clock* — the
request source advances ``vtime`` by ``service_model_s`` per engine slot
and books every transfer on a virtual ``LinkClock`` — precisely so that
seeded chaos replays are bit-deterministic.  The tracer lives on that
same clock: every span's ``v_start``/``v_dur`` is a modeled quantity
(wire seconds, retry penalty, virtual queue, service time, fixed
sub-phase fractions for host phases), never a wall-clock reading, so two
replays of the same seeded schedule emit byte-identical trace streams.
Measured wall-clock durations (from the engine's ``perf_counter`` /
``block_until_ready`` fences) ride along in ``Span.wall_s`` as optional
evidence and are *excluded* from the deterministic export by default
(``export.chrome_trace_json(include_wall=False)``).

Span trees emitted by the instrumented layers:

  * ``request → pull(wire/retry/queue)/compute/push`` — built by
    ``ServingEngine`` from the ``PullHandle``'s modeled breakdown;
  * ``feed → pack/scan/merge/metrics`` — ``StreamSession.feed``;
  * ``elastic_op → plan/scan/migrate`` — ``ElasticSession`` ops.

Trace/span ids are plain ordinals (deterministic).  Context propagates
two ways: explicitly (a ``SpanHandle`` adds children at offsets inside
its parent) and implicitly through the *installed-tracer registry* —
``Tracer.installed()`` registers the tracer for the duration of an
engine run, and deep layers that hold no reference to it
(``PSCluster.plan_pull/pull_nowait``, ``Router.refresh``, the dispatch
counter) call the module-level ``trace_instant``, which attaches an
instant event to the innermost open span of every installed tracer.
With no tracer installed those hooks are a truthiness test on an empty
list — the near-zero disabled overhead asserted in
``tests/test_obs.py``.
"""
from __future__ import annotations

import contextlib
import dataclasses
from collections import deque

__all__ = ["Span", "SpanHandle", "Tracer", "trace_instant",
           "dispatch_instant", "annotate_last_instant"]

# Tracers currently installed (engine runs, `with tracer.installed()`);
# module-level like jax_partition's _ACTIVE_COUNTERS so layers without an
# obs reference can still emit into the active trace context.
_ACTIVE: list["Tracer"] = []


@dataclasses.dataclass
class Span:
    """One interval (or instant) on the virtual timeline."""

    name: str
    trace_id: int
    span_id: int
    parent_id: int            # -1 for trace roots
    v_start: float            # virtual seconds (deterministic)
    v_dur: float              # virtual seconds; 0 for instants
    track: str                # Perfetto row ("home3", "stream", ...)
    wall_s: float | None = None   # measured wall clock, replay-variant
    instant: bool = False
    attrs: dict = dataclasses.field(default_factory=dict)


class SpanHandle:
    """Builder view over one span: add children at offsets inside it."""

    __slots__ = ("tracer", "span")

    def __init__(self, tracer: "Tracer", span: Span):
        self.tracer = tracer
        self.span = span

    def child(self, name: str, offset: float, dur: float,
              wall_s: float | None = None, track: str | None = None,
              **attrs) -> "SpanHandle":
        """Child span at ``[v_start + offset, v_start + offset + dur)``."""
        parent = self.span
        sp = Span(name=name, trace_id=parent.trace_id,
                  span_id=self.tracer._next_span(),
                  parent_id=parent.span_id,
                  v_start=parent.v_start + offset, v_dur=dur,
                  track=track if track is not None else parent.track,
                  wall_s=wall_s, attrs=attrs)
        self.tracer._add(sp)
        return SpanHandle(self.tracer, sp)

    def set(self, v_dur: float | None = None,
            wall_s: float | None = None, **attrs) -> "SpanHandle":
        """Finalize fields known only after the fact (retrospective
        duration / measured wall time)."""
        if v_dur is not None:
            self.span.v_dur = v_dur
        if wall_s is not None:
            self.span.wall_s = wall_s
        self.span.attrs.update(attrs)
        return self


class Tracer:
    """Bounded span sink on the virtual clock.

    ``now`` is the tracer's current virtual time: the serving source sets
    it to ``vtime`` every slot; a standalone stream advances it one unit
    per feed.  ``begin`` opens a new trace (root span); ``instant``
    records a point event parented to the innermost pushed span.
    """

    def __init__(self, max_spans: int = 65536):
        self.spans: deque[Span] = deque(maxlen=max_spans)
        self.now = 0.0
        self._trace_seq = 0
        self._span_seq = 0
        self._stack: list[Span] = []

    # ------------------------------------------------------------ clock
    def set_time(self, v: float) -> None:
        self.now = float(v)

    def advance(self, dv: float) -> None:
        self.now += float(dv)

    # ------------------------------------------------------------ spans
    def _next_span(self) -> int:
        self._span_seq += 1
        return self._span_seq

    def _add(self, sp: Span) -> None:
        self.spans.append(sp)

    def begin(self, name: str, v_start: float | None = None,
              v_dur: float = 0.0, track: str = "main",
              wall_s: float | None = None, **attrs) -> SpanHandle:
        """Open a new trace; returns the root span's handle."""
        self._trace_seq += 1
        sp = Span(name=name, trace_id=self._trace_seq,
                  span_id=self._next_span(), parent_id=-1,
                  v_start=self.now if v_start is None else float(v_start),
                  v_dur=v_dur, track=track, wall_s=wall_s, attrs=attrs)
        self._add(sp)
        return SpanHandle(self, sp)

    def instant(self, name: str, track: str | None = None,
                **attrs) -> None:
        """Point event at ``now``, inside the innermost pushed span."""
        parent = self._stack[-1] if self._stack else None
        if parent is not None:
            trace_id, parent_id = parent.trace_id, parent.span_id
            trk = parent.track if track is None else track
        else:
            self._trace_seq += 1
            trace_id, parent_id = self._trace_seq, -1
            trk = "main" if track is None else track
        self._add(Span(name=name, trace_id=trace_id,
                       span_id=self._next_span(), parent_id=parent_id,
                       v_start=self.now, v_dur=0.0, track=trk,
                       instant=True, attrs=attrs))

    # ------------------------------------------------- context stack
    def push(self, handle: SpanHandle) -> None:
        self._stack.append(handle.span)

    def pop(self) -> None:
        self._stack.pop()

    # --------------------------------------------- installed registry
    def install(self) -> None:
        _ACTIVE.append(self)

    def uninstall(self) -> None:
        for i, t in enumerate(_ACTIVE):
            if t is self:      # identity, like dispatch_counter teardown
                del _ACTIVE[i]
                break

    @contextlib.contextmanager
    def installed(self):
        """Register this tracer for module-level ``trace_instant`` hooks
        (the engine wraps its run loop in this)."""
        self.install()
        try:
            yield self
        finally:
            self.uninstall()


def trace_instant(name: str, **attrs) -> None:
    """Emit an instant into every installed tracer; no-op (one truthiness
    check) when tracing is off — safe to call on hot paths."""
    if not _ACTIVE:
        return
    for t in _ACTIVE:
        t.instant(name, **attrs)


def dispatch_instant(name: str, nbytes: int = 0,
                     meta: dict | None = None) -> None:
    """The dispatch counter's trace hook: one instant per device launch."""
    if not _ACTIVE:
        return
    for t in _ACTIVE:
        t.instant("dispatch:" + name, nbytes=int(nbytes), **(meta or {}))


def annotate_last_instant(**attrs) -> None:
    """Attach after-the-fact labels (jit cache hit/miss) to the dispatch
    instant just emitted — only touches a trailing ``dispatch:`` span."""
    for t in _ACTIVE:
        if t.spans and t.spans[-1].name.startswith("dispatch:"):
            t.spans[-1].attrs.update(attrs)
