"""Exporters: Chrome-trace/Perfetto JSON for spans, Prometheus-style text
for the scattered counters.

``to_chrome_trace`` emits the standard Trace Event Format (complete
``"X"`` events + ``"i"`` instants) that Perfetto / ``chrome://tracing``
open directly.  Timestamps are the *virtual* microseconds, so the trace
is the modeled timeline the closed loop actually decided on; tracks
(tids) are the span ``track`` labels (one row per home machine, one for
the stream, one for elastic ops).  ``include_wall=False`` (default)
drops the measured wall-clock annotations so two seeded replays export
byte-identical JSON (``chrome_trace_json`` is separator/sort-stable for
exactly that comparison).

``prometheus_text`` unifies the repo's counter objects under one naming
scheme (``parsa_<subsystem>_<metric>``): ``TrafficCounters`` (stream /
elastic migration bytes), ``LatencyRecorder`` (serving latency +
per-tenant sheds), ``TelemetryBus`` (windowed gauges, EWMA speeds), the
PS cluster's ``TrafficMeter``, and the labeled dispatch log.
"""
from __future__ import annotations

import json
import pathlib

from .recorder import _json_default
from .trace import Tracer

__all__ = ["to_chrome_trace", "chrome_trace_json", "save_chrome_trace",
           "prometheus_text"]


def to_chrome_trace(tracer: Tracer, include_wall: bool = False) -> dict:
    """Spans → Trace Event Format dict (Perfetto-loadable)."""
    tracks: dict[str, int] = {}
    events = []
    for sp in tracer.spans:
        tid = tracks.setdefault(sp.track, len(tracks))
        args = dict(sp.attrs)
        args["trace_id"] = sp.trace_id
        args["span_id"] = sp.span_id
        if sp.parent_id >= 0:
            args["parent_id"] = sp.parent_id
        if include_wall and sp.wall_s is not None:
            args["wall_ms"] = sp.wall_s * 1e3
        if not include_wall:
            # replay-variant evidence: jit caches are warm on the second
            # run of a process, so hit/miss labels would break the
            # byte-identical replay comparison exactly like wall clocks
            args.pop("cache_miss", None)
        ev = {"name": sp.name, "cat": "parsa", "pid": 0, "tid": tid,
              "ts": round(sp.v_start * 1e6, 3), "args": args}
        if sp.instant:
            ev["ph"] = "i"
            ev["s"] = "t"
        else:
            ev["ph"] = "X"
            ev["dur"] = round(sp.v_dur * 1e6, 3)
        events.append(ev)
    meta = [{"name": "process_name", "ph": "M", "pid": 0,
             "args": {"name": "parsa virtual clock"}}]
    meta += [{"name": "thread_name", "ph": "M", "pid": 0, "tid": tid,
              "args": {"name": trk}}
             for trk, tid in sorted(tracks.items(), key=lambda kv: kv[1])]
    return {"displayTimeUnit": "ms", "traceEvents": meta + events}


def chrome_trace_json(tracer: Tracer, include_wall: bool = False) -> str:
    """Deterministic serialization (sorted keys, fixed separators): the
    byte stream two seeded replays must reproduce identically."""
    return json.dumps(to_chrome_trace(tracer, include_wall=include_wall),
                      sort_keys=True, separators=(",", ":"),
                      default=_json_default)


def save_chrome_trace(tracer: Tracer, path,
                      include_wall: bool = True) -> pathlib.Path:
    """Write a Perfetto-openable trace; wall-clock annotations included
    by default (a human is reading this one, not a diff)."""
    path = pathlib.Path(path)
    path.write_text(chrome_trace_json(tracer, include_wall=include_wall)
                    + "\n")
    return path


# --------------------------------------------------------------- metrics
def _fmt_labels(labels: dict) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in sorted(labels.items()))
    return "{" + inner + "}"


def _fmt_value(v) -> str:
    f = float(v)
    return str(int(f)) if f == int(f) else repr(f)


def prometheus_text(latency=None, telemetry=None, traffic=None,
                    meter=None, dispatches=None) -> str:
    """One text snapshot over every counter surface the repo keeps.

    All arguments optional: ``latency`` a ``LatencyRecorder``,
    ``telemetry`` a ``TelemetryBus``, ``traffic`` a ``TrafficCounters``,
    ``meter`` a PS ``TrafficMeter``, ``dispatches`` a
    ``dispatch_counter`` log (plain counts or the labeled form).
    """
    import numpy as np

    # family -> (type, help, [(labels, value), ...])
    fams: dict[str, tuple[str, str, list]] = {}

    def add(name, typ, help_, value, **labels):
        fam = fams.setdefault(name, (typ, help_, []))
        fam[2].append((labels, value))

    if latency is not None:
        recs = [r for r in latency.records if not r.warmup]
        add("parsa_serving_requests_total", "counter",
            "Served requests (post-warmup).", len(recs))
        for tenant, n in sorted(latency.shed.items()):
            add("parsa_serving_shed_total", "counter",
                "Admission-shed requests by tenant.", n, tenant=tenant)
        if recs:
            modeled = np.array([r.modeled_s for r in recs]) * 1e3
            for stat, val in (("p50", np.percentile(modeled, 50)),
                              ("p99", np.percentile(modeled, 99)),
                              ("mean", modeled.mean())):
                add("parsa_serving_latency_ms", "gauge",
                    "Modeled request latency (virtual clock).",
                    float(val), stat=stat)
            add("parsa_serving_pull_bytes_total", "counter",
                "Inter-machine pull bytes.",
                int(sum(r.pull_inter_bytes for r in recs)))
            add("parsa_serving_push_bytes_total", "counter",
                "Inter-machine push bytes.",
                int(sum(r.push_inter_bytes for r in recs)))
            add("parsa_serving_stale_entries_total", "counter",
                "Entries served from the stale buffer.",
                int(sum(r.stale_entries for r in recs)))

    if telemetry is not None:
        add("parsa_telemetry_served_total", "counter",
            "Requests folded into the telemetry windows.",
            telemetry.served)
        for tenant, n in sorted(telemetry.shed.items()):
            add("parsa_telemetry_shed_total", "counter",
                "Sheds metered by the telemetry bus, by tenant.", n,
                tenant=tenant)
        add("parsa_telemetry_p99_ms", "gauge",
            "Sliding-window p99 latency.",
            float(telemetry.modeled.percentile(99)), clock="modeled")
        add("parsa_telemetry_p99_ms", "gauge",
            "Sliding-window p99 latency.",
            float(telemetry.measured.percentile(99)), clock="measured")
        for m, w in enumerate(telemetry.ewma.weights()):
            add("parsa_telemetry_speed_ratio", "gauge",
                "Per-machine delivery speed (StragglerEWMA, mean 1).",
                float(w), machine=m)

    if traffic is not None:
        for field in ("pushed_bytes", "pulled_bytes", "tasks",
                      "stale_pushes_missed", "migration_bytes"):
            add(f"parsa_stream_{field}_total", "counter",
                "Stream/elastic traffic counter (bitmask-word bytes).",
                int(getattr(traffic, field)))

    if meter is not None:
        add("parsa_ps_inner_bytes_total", "counter",
            "PS traffic staying inside a machine.",
            int(meter.inner_bytes))
        add("parsa_ps_inter_bytes_total", "counter",
            "PS traffic crossing machines (the paper's objective).",
            int(meter.inter_bytes))

    if dispatches is not None:
        for phase, n in sorted(dispatches.items()):
            add("parsa_dispatch_total", "counter",
                "Device pipeline launches by phase.", n, phase=phase)
        records = getattr(dispatches, "records", None)
        if records:
            by_phase: dict[str, int] = {}
            for r in records:
                by_phase[r.phase] = by_phase.get(r.phase, 0) + r.nbytes
            for phase, nbytes in sorted(by_phase.items()):
                add("parsa_dispatch_bytes_total", "counter",
                    "Donated-carry bytes shipped into dispatches.",
                    nbytes, phase=phase)

    lines = []
    for name in sorted(fams):
        typ, help_, samples = fams[name]
        lines.append(f"# HELP {name} {help_}")
        lines.append(f"# TYPE {name} {typ}")
        for labels, value in samples:
            lines.append(f"{name}{_fmt_labels(labels)} "
                         f"{_fmt_value(value)}")
    return "\n".join(lines) + "\n"
