"""Public Parsa facade: one config, one ``partition()`` entry point, one
result type.

The paper's pipeline is one conceptual operation — partition U (Alg 3/4),
refine V (Alg 2), place parameters, measure traffic — and this module is
the one place it is exposed:

    from repro.api import ParsaConfig, partition

    cfg = ParsaConfig(k=16, backend="host", blocks=8, init_iters=8)
    res = partition(graph, cfg)           # PartitionResult
    res.parts_u, res.parts_v              # Alg 3 + Alg 2 assignments
    res.metrics.traffic_max               # objectives (4)/(6)/(7)
    res.timings["partition_u"]            # wall clock per phase
    res2 = res.refine(tomorrows_graph)    # warm-start / incremental

Backends (``host``, ``device_scan``, ``host_blocked_oracle``,
``parallel_sim``, ``parallel_device``) live in the registry in
``repro.api_backends``; add a strategy with ``@register_backend`` instead
of a new module-level function.
The five pre-facade entry points (``partition_u``, ``sequential_parsa``,
``ParallelParsa.run``, ``blocked_partition_u``,
``blocked_partition_u_hostloop``) remain as deprecation-warning shims that
delegate here and return bit-identical results.

``PartitionResult`` uniformly carries the final neighbor sets as packed
bitmasks (``s_masks``, (k, ceil(|V|/32)) int32) with a lazy dense bool view
(``neighbor_sets``), so host- and device-produced sets are interchangeable
for warm starts.
"""
from __future__ import annotations

import dataclasses
import time
from typing import TYPE_CHECKING

import numpy as np

from .api_backends import (
    BACKENDS,
    BackendOutput,
    TrafficCounters,
    available_backends,
    get_backend,
    register_backend,
)
from .core.bipartite import BipartiteGraph
from .core.costs import PartitionMetrics, evaluate
from .core.partition_v import partition_v
from .kernels.parsa_cost import pack_bitmask, unpack_bitmask

if TYPE_CHECKING:  # avoid the placement ↔ api import cycle at runtime
    from .core.placement import Placement
    from .sketch import SketchSpec

__all__ = [
    "ParsaConfig",
    "PartitionResult",
    "PartitionMetrics",
    "TrafficCounters",
    "partition",
    "register_backend",
    "available_backends",
    # streaming surface (lazy — see __getattr__)
    "ParsaStreamConfig",
    "StreamSession",
    "StreamUpdate",
    "stream_partition",
    # elastic surface (lazy — see __getattr__)
    "ChaosEvent",
    "ChaosSchedule",
    "ElasticConfig",
    "ElasticPolicy",
    "ElasticSession",
    "SLOAutoscaler",
    "SLOConfig",
    "ThresholdPolicy",
    # serving surface (lazy — see __getattr__)
    "PSRequestSource",
    "RequestMix",
    "ServingConfig",
    "ServingEngine",
    "TelemetryBus",
    "TelemetrySnapshot",
    "ZipfWorkload",
    # observability surface (lazy — see __getattr__)
    "Observability",
    "Tracer",
    "FlightRecorder",
    "Explanation",
    "to_chrome_trace",
    "chrome_trace_json",
    "save_chrome_trace",
    "prometheus_text",
]

# Streaming lives in ``repro.stream`` (online incremental Parsa over
# growing graphs) but is surfaced here so the facade stays the one import:
#     from repro.api import ParsaStreamConfig, stream_partition
# Loaded lazily to keep `import repro.api` free of the stream module's
# device-state machinery until it is actually used (and to avoid the
# stream → api → stream import cycle at module load).
_STREAM_EXPORTS = ("ParsaStreamConfig", "StreamSession", "StreamUpdate",
                   "stream_partition")

# The elastic serving layer (``repro.elastic``: runtime-variable k, chaos
# injection, straggler-aware routing) is surfaced the same lazy way.
_ELASTIC_EXPORTS = ("ChaosEvent", "ChaosSchedule", "ElasticConfig",
                    "ElasticPolicy", "ElasticSession", "SLOAutoscaler",
                    "SLOConfig", "ThresholdPolicy")

# The request-driven serving engine (``repro.serving``: async pull/compute
# overlap over PSCluster shards) — same lazy surfacing.
_SERVING_EXPORTS = ("PSRequestSource", "RequestMix", "ServingConfig",
                    "ServingEngine", "TelemetryBus", "TelemetrySnapshot",
                    "ZipfWorkload")

# Observability (``repro.obs``: virtual-clock tracing, flight recorder,
# Perfetto/Prometheus export) — the ``obs=`` hook's types.
_OBS_EXPORTS = ("Observability", "Tracer", "FlightRecorder", "Explanation",
                "to_chrome_trace", "chrome_trace_json", "save_chrome_trace",
                "prometheus_text")


def __getattr__(name: str):
    if name in _STREAM_EXPORTS:
        from . import stream

        return getattr(stream, name)
    if name in _ELASTIC_EXPORTS:
        from . import elastic

        return getattr(elastic, name)
    if name in _SERVING_EXPORTS:
        from . import serving

        return getattr(serving, name)
    if name in _OBS_EXPORTS:
        from . import obs

        return getattr(obs, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

_SELECTS = ("size", "footprint")
_REFINE_BACKENDS = ("host", "device")
_SET_REPRS = ("exact", "sketch")


@dataclasses.dataclass(frozen=True)
class ParsaConfig:
    """Every knob of the Parsa pipeline, validated at construction.

    Only ``k`` is required.  Fields group by the phase they drive; backends
    ignore knobs that don't apply to them (e.g. ``workers`` outside
    ``parallel_sim``).
    """

    k: int
    backend: str = "host"

    # ---- subgraph streaming (§4.2/§4.4) — host / parallel_sim backends
    blocks: int = 1            # b: number of subgraphs (1 = global greedy)
    init_iters: int = 0        # a: individual-initialization iterations
    theta: int = 1000          # bucket-queue head-pointer range (§4.1)
    select: str = "size"       # "size" (perfect balance) | "footprint"
    seed: int = 0

    # ---- device backend knobs (device_scan / host_blocked_oracle)
    block_size: int = 256      # B: vertices greedily assigned per block
    cap: int = 48              # compact word-list width per vertex
    use_kernel: bool = False   # fused Pallas cost+select (TPU) vs jnp path
    interpret: bool | None = None  # force Pallas interpret mode (CI)

    # ---- parallel backend knobs (Alg 4: parallel_sim / parallel_device)
    workers: int = 4           # W concurrent workers
    tau: int | None = 0        # max push delay in tasks; None = eventual
    global_init_frac: float = 0.0  # §4.4 global-init sample fraction
    merge_every: int = 1       # parallel_device: blocks between OR-merges
                               #   (τ ≡ merge_every − 1 blocks of staleness)
    devices: int | None = None  # parallel_device mesh width; None → workers

    # ---- sketched server sets (repro.sketch — any backend; unlocks the
    #      VMEM-resident select kernel on the device backends)
    set_repr: str = "exact"    # "exact" | "sketch" (column-compressed sets)
    sketch_hot_bits: int = 4096    # exact identity slots (top-footprint V)
    sketch_bucket_bits: int = 8192  # hashed shared slots for the cold tail

    # ---- composition
    refine_v: bool = True      # run Alg 2 (partition_v) after partition_u
    sweeps: int = 2            # Alg 2 re-assignment sweeps
    refine_backend: str = "host"   # "host" = numpy oracle; "device" = the
                               #   packed-word refine + metrics pipeline
                               #   (bit-identical, O(1) dispatches/phase)
    refine_chunk: int = 1024   # C: parameters swept per device chunk
    placement: bool = False    # also derive an embedding Placement

    def __post_init__(self):
        if not isinstance(self.k, (int, np.integer)) or self.k <= 0:
            raise ValueError(f"k must be a positive int, got {self.k!r}")
        if self.backend not in BACKENDS:
            raise ValueError(
                f"unknown Parsa backend {self.backend!r}; available: "
                f"{', '.join(available_backends())}")
        if self.blocks < 1:
            raise ValueError(f"blocks must be >= 1, got {self.blocks}")
        if self.init_iters < 0:
            raise ValueError(f"init_iters must be >= 0, got {self.init_iters}")
        if self.select not in _SELECTS:
            raise ValueError(f"select must be one of {_SELECTS}, got {self.select!r}")
        if self.block_size <= 0 or self.block_size % 8 != 0:
            raise ValueError(
                f"block_size must be a positive multiple of 8 (sublane "
                f"alignment of the fused select kernel), got {self.block_size}")
        if self.cap <= 0:
            raise ValueError(f"cap must be > 0, got {self.cap}")
        if self.workers < 1:
            raise ValueError(f"workers must be >= 1, got {self.workers}")
        if self.tau is not None and self.tau < 0:
            raise ValueError(f"tau must be >= 0 or None, got {self.tau}")
        if not 0.0 <= self.global_init_frac <= 1.0:
            raise ValueError(
                f"global_init_frac must be in [0, 1], got {self.global_init_frac}")
        if self.merge_every < 1:
            raise ValueError(
                f"merge_every must be >= 1, got {self.merge_every}")
        if self.devices is not None and self.devices < 1:
            raise ValueError(
                f"devices must be >= 1 or None, got {self.devices}")
        if self.set_repr not in _SET_REPRS:
            raise ValueError(
                f"set_repr must be one of {_SET_REPRS}, got "
                f"{self.set_repr!r}")
        if self.sketch_hot_bits < 0 or self.sketch_hot_bits % 32 != 0:
            raise ValueError(
                f"sketch_hot_bits must be a nonnegative multiple of 32 "
                f"(packed word alignment), got {self.sketch_hot_bits}")
        if self.sketch_bucket_bits <= 0 or self.sketch_bucket_bits % 32 != 0:
            raise ValueError(
                f"sketch_bucket_bits must be a positive multiple of 32 "
                f"(packed word alignment), got {self.sketch_bucket_bits}")
        if self.sweeps < 1:
            raise ValueError(f"sweeps must be >= 1, got {self.sweeps}")
        if self.refine_backend not in _REFINE_BACKENDS:
            raise ValueError(
                f"refine_backend must be one of {_REFINE_BACKENDS}, got "
                f"{self.refine_backend!r}")
        if self.refine_chunk <= 0 or self.refine_chunk % 32 != 0:
            raise ValueError(
                f"refine_chunk must be a positive multiple of 32 (the packed "
                f"word width), got {self.refine_chunk}")
        if self.placement and not self.refine_v:
            raise ValueError("placement=True requires refine_v=True "
                             "(the embedding layout needs parts_v)")

    def replace(self, **changes) -> "ParsaConfig":
        return dataclasses.replace(self, **changes)


@dataclasses.dataclass
class PartitionResult:
    """Uniform output of every backend.

    The final neighbor sets are carried in whichever representation the
    backend produced and converted lazily on first access: ``s_masks`` is
    the packed int32 bitmask view (the device-native layout),
    ``neighbor_sets`` the dense bool view of the same bits.
    """

    parts_u: np.ndarray                 # (|U|,) int32
    parts_v: np.ndarray | None          # (|V|,) int32 or None (refine_v=False)
    num_v: int                          # domain of s_masks — the sketched
                                        #   width when ``sketch`` is set
    k: int
    config: ParsaConfig
    metrics: PartitionMetrics
    timings: dict[str, float]           # seconds per phase + "total"
    traffic: TrafficCounters | None = None   # parallel_sim / parallel_device
    placement: "Placement | None" = None     # config.placement only
    sketch: "SketchSpec | None" = None  # set_repr="sketch": the column map
                                        #   (parts_v is expanded to the TRUE
                                        #   extent ``sketch.num_v``; metrics
                                        #   are sketch-space estimates)
    _packed_sets: np.ndarray | None = dataclasses.field(
        default=None, repr=False, compare=False)
    _dense_sets: np.ndarray | None = dataclasses.field(
        default=None, repr=False, compare=False)

    def __post_init__(self):
        if self._packed_sets is None and self._dense_sets is None:
            raise ValueError("PartitionResult needs packed or dense "
                             "neighbor sets")

    @property
    def s_masks(self) -> np.ndarray:
        """(k, ceil(|V|/32)) int32 — packed bitmask view, built on first use."""
        if self._packed_sets is None:
            self._packed_sets = np.asarray(pack_bitmask(
                np.asarray(self._dense_sets, dtype=bool), self.num_v))
        return self._packed_sets

    @property
    def neighbor_sets(self) -> np.ndarray:
        """(k, |V|) bool — dense view of the neighbor sets, built on first use."""
        if self._dense_sets is None:
            self._dense_sets = unpack_bitmask(self._packed_sets, self.num_v)
        return self._dense_sets

    def refine(self, graph: BipartiteGraph,
               config: ParsaConfig | None = None) -> "PartitionResult":
        """Warm-start / incremental repartitioning: partition ``graph``
        seeding the neighbor sets from this result (§4.4 incremental mode)
        instead of hand-threading ``init_sets``.

        Hands over whichever neighbor-set view already exists: a device
        backend's packed ``s_masks`` flow straight into the next run's
        packed warm start (no dense (k, |V|) unpack), a host backend's
        dense sets stay dense — every backend accepts both.

        Sketched results refine against the TRUE graph: the stored
        ``SketchSpec`` is handed through so the new run reuses the exact
        same column map (re-deriving a footprint-ranked map on the new
        graph would silently scramble the warm-start masks).
        """
        if graph.num_v != self.num_v and not (
                self.sketch is not None
                and graph.num_v == self.sketch.num_v):
            raise ValueError(
                f"refine() needs a graph over the same parameter side: "
                f"result has num_v={self.num_v}, graph has "
                f"num_v={graph.num_v}")
        sets = (self._packed_sets if self._packed_sets is not None
                else self._dense_sets)
        return partition(graph, config or self.config, init_sets=sets,
                         sketch_spec=self.sketch)


def partition(
    graph: BipartiteGraph,
    config: ParsaConfig,
    *,
    init_sets: np.ndarray | None = None,
    sketch_spec: "SketchSpec | None" = None,
) -> PartitionResult:
    """Run the full Parsa pipeline described by ``config`` on ``graph``.

    Phases: backend partition_u → optional Alg 2 V-refinement → exact
    metrics (objectives (4)/(6)/(7)) → optional embedding placement.  Each
    phase's wall clock lands in ``result.timings``; device backends report
    their host-side bitmask packing separately as ``timings["pack"]`` so
    ``timings["partition_u"]`` is the scan alone.  ``init_sets`` is the
    internal warm-start hook — prefer ``PartitionResult.refine``; both
    dense (k, |V|) bool sets and packed (k, W) int32 words are accepted.

    With ``config.refine_backend == "device"`` the V-refinement and the
    metrics run on device over packed words (``core.jax_refine``),
    consuming the backend's ``parts_u`` without a host round trip and
    sharing one packed need matrix between the two phases — bit-identical
    to the host oracles, O(1) XLA dispatches per phase.
    """
    backend = get_backend(config.backend)
    timings: dict[str, float] = {}
    t_start = time.perf_counter()

    # ---- sketch phase: compress the V columns once, then run the WHOLE
    # pipeline (backend scan, refine, metrics) at the sketched width.  The
    # union lattice the backends rely on is preserved exactly (a hash of a
    # union is the union of the hashes), so nothing downstream changes —
    # only the packed width does.
    sketch = None
    run_graph = graph
    if getattr(config, "set_repr", "exact") == "sketch":
        from .sketch import SketchSpec, rank_hot_columns

        t0 = time.perf_counter()
        if sketch_spec is not None:
            sketch = sketch_spec
        else:
            hot_ids = None
            if 0 < config.sketch_hot_bits < graph.num_v:
                hot_ids = rank_hot_columns(graph, config.sketch_hot_bits)
            sketch = SketchSpec.for_graph(
                graph.num_v, config.sketch_hot_bits,
                config.sketch_bucket_bits, seed=config.seed,
                hot_ids=hot_ids)
        if config.placement and not sketch.is_exact:
            raise ValueError(
                "placement=True needs exact parameter identities; a "
                "compressing sketch co-locates hashed columns — raise "
                "sketch_hot_bits to >= num_v or use set_repr='exact'")
        run_graph = sketch.sketch_graph(graph)
        if init_sets is not None and not sketch.is_exact:
            w = np.asarray(init_sets).shape[1]
            if w != sketch.width_words:  # true-domain sets: compress them
                init_sets = sketch.sketch_masks(init_sets, graph.num_v)
        timings["sketch"] = time.perf_counter() - t0

    t0 = time.perf_counter()
    out: BackendOutput = backend(run_graph, config, init_sets=init_sets)
    if hasattr(out.parts_u, "block_until_ready"):
        # device-resident outputs: sync (no transfer) so phase attribution
        # doesn't leak the async scan into the refine clock
        out.parts_u.block_until_ready()
    elapsed = time.perf_counter() - t0
    pack_s = (out.timings or {}).get("pack")
    if pack_s is not None:
        timings["pack"] = pack_s
        timings["partition_u"] = elapsed - pack_s
    else:
        timings["partition_u"] = elapsed

    on_device = config.refine_backend == "device"
    parts_v = parts_v_dev = need_words = None
    if on_device and init_sets is None and config.init_iters == 0 \
            and config.global_init_frac == 0.0:
        # Cold-start invariant: with no warm start and no §4.4 seeding,
        # every backend's final S_i is EXACTLY N(U_i) (union of assigned
        # vertices' neighborhoods), so the packed sets it already returned
        # ARE the need matrix — the refine/metrics phases reuse them and
        # skip the segment-OR need pack entirely.
        import jax.numpy as jnp  # lazy: keep host-only paths jax-free

        from .kernels.parsa_cost import coerce_packed_sets

        # s_masks may already live on device — jnp.asarray keeps it there;
        # only host backends' dense sets go through the packing coercion
        need_words = (jnp.asarray(out.s_masks) if out.s_masks is not None
                      else jnp.asarray(coerce_packed_sets(
                          out.neighbor_sets, run_graph.num_v)))
    if config.refine_v:
        t0 = time.perf_counter()
        if on_device:
            from .core.jax_refine import refine_v_device  # lazy: jax cost

            parts_v_dev, need_words = refine_v_device(
                run_graph, out.parts_u, config.k, sweeps=config.sweeps,
                chunk=config.refine_chunk, use_kernel=config.use_kernel,
                interpret=config.interpret, need_words=need_words)
            parts_v_dev.block_until_ready()
            parts_v = np.asarray(parts_v_dev)
        else:
            parts_v = partition_v(run_graph, np.asarray(out.parts_u),
                                  config.k, sweeps=config.sweeps)
        timings["partition_v"] = time.perf_counter() - t0

    t0 = time.perf_counter()
    if on_device:
        from .core.jax_refine import evaluate_device

        metrics = evaluate_device(run_graph, out.parts_u, parts_v_dev,
                                  config.k, need_words=need_words)
    else:
        metrics = evaluate(run_graph, np.asarray(out.parts_u), parts_v,
                           config.k)
    timings["metrics"] = time.perf_counter() - t0

    if sketch is not None and parts_v is not None and not sketch.is_exact:
        # back to the true parameter extent: every real column is served by
        # the machine of its sketch slot (hot → its exact Alg 2 host,
        # bucketed tail → hash co-location)
        parts_v = sketch.expand_parts_v(parts_v)

    placement = None
    if config.placement:
        from .core.placement import placement_from_parts  # lazy: cycle

        t0 = time.perf_counter()
        placement = placement_from_parts(out.parts_u, parts_v,
                                         run_graph.num_v, config.k)
        timings["placement"] = time.perf_counter() - t0

    timings["total"] = time.perf_counter() - t_start

    return PartitionResult(
        parts_u=np.asarray(out.parts_u),
        parts_v=parts_v,
        num_v=run_graph.num_v,
        k=config.k,
        config=config,
        metrics=metrics,
        timings=timings,
        traffic=out.traffic,
        placement=placement,
        sketch=sketch,
        _packed_sets=None if out.s_masks is None else np.asarray(out.s_masks),
        _dense_sets=out.neighbor_sets,
    )
