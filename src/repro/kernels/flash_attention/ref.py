"""Pure-jnp oracle for the flash attention kernel (single head-group slice)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def attention_ref(q, k, v, *, causal: bool = True, window: int | None = None):
    """q (B,Sq,H,D), k/v (B,Skv,H,D) — same head count (GQA expansion done by
    the caller).  fp32 softmax, output in q.dtype."""
    B, Sq, H, D = q.shape
    Skv = k.shape[1]
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) / np.sqrt(D)
    q_pos = jnp.arange(Sq)[:, None] + (Skv - Sq)   # right-aligned positions
    k_pos = jnp.arange(Skv)[None, :]
    ok = jnp.ones((Sq, Skv), bool)
    if causal:
        ok &= k_pos <= q_pos
    if window is not None:
        ok &= k_pos > q_pos - window
    scores = jnp.where(ok[None, None], scores, -1e30)
    probs = jnp.exp(scores - scores.max(-1, keepdims=True))
    probs = probs / probs.sum(-1, keepdims=True)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(q.dtype), v)
    return out
