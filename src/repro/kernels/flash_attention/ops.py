"""jit'd public wrapper: layout adaptation + GQA head expansion + padding."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention_kernel
from .ref import attention_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def flash_attention(
    q: jax.Array,   # (B, Sq, H, D)   — model layout
    k: jax.Array,   # (B, Skv, KV, D)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    bq: int = 256,
    bk: int = 256,
    interpret: bool | None = None,
) -> jax.Array:
    """Flash attention with GQA: kv heads broadcast to q heads; sequences
    padded to block multiples (padding keys are masked by position)."""
    if interpret is None:
        interpret = not _on_tpu()
    B, Sq, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    if G > 1:
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
    # (B, S, H, D) → (B, H, S, D)
    qt, kt, vt = (t.swapaxes(1, 2) for t in (q, k, v))
    bq_ = min(bq, Sq) if Sq % min(bq, Sq) == 0 else Sq
    while Sq % bq_:
        bq_ //= 2
    bk_ = min(bk, k.shape[1])
    while k.shape[1] % bk_:
        bk_ //= 2
    out = flash_attention_kernel(
        qt, kt, vt, causal=causal, window=window,
        bq=max(bq_, 1), bk=max(bk_, 1), interpret=interpret)
    return out.swapaxes(1, 2)
