"""Pallas TPU flash attention (forward): online-softmax over KV blocks.

TPU adaptation notes (vs the CUDA flash algorithm):
  * blocks are (bq × d) / (bk × d) VMEM tiles with d the full head dim —
    MXU matmuls are (bq, d)×(d, bk) and (bq, bk)×(bk, d), both 128-aligned;
  * the running max/denominator (m, l) and the output accumulator live in
    VMEM scratch, carried across the KV grid axis (sequential innermost
    grid dim — the TPU analogue of the CUDA inner loop; no shared-memory /
    warp-shuffle machinery exists or is needed);
  * causal + sliding-window masking folds into block-index comparisons;
    fully-masked KV blocks are skipped with @pl.when.

Grid: (B, H, Sq/bq, Skv/bk), KV innermost.  VMEM per step (defaults
bq=bk=256, d≤256 fp32): q 256 KiB + k/v 512 KiB + acc 256 KiB ≈ 1 MiB.

Used for 32k prefill on TPU; the XLA chunked path (models/layers.py) is the
CPU/dry-run fallback and the numerical reference.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale, causal, window, bq, bk, n_kv):
    kv_idx = pl.program_id(3)
    q_idx = pl.program_id(2)

    @pl.when(kv_idx == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = q_idx * bq
    k_start = kv_idx * bk

    run = jnp.bool_(True)
    if causal:
        run = jnp.logical_and(run, k_start <= q_start + bq - 1)
    if window is not None:
        run = jnp.logical_and(run, k_start + bk - 1 > q_start - window)

    @pl.when(run)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)            # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)            # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        ok = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            ok = jnp.logical_and(ok, k_pos <= q_pos)
        if window is not None:
            ok = jnp.logical_and(ok, k_pos > q_pos - window)
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_scr[...]                            # (bq, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(kv_idx == n_kv - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "bq", "bk", "interpret"))
def flash_attention_kernel(
    q: jax.Array,   # (B, H, Sq, D)
    k: jax.Array,   # (B, H, Skv, D)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    bq: int = 256,
    bk: int = 256,
    interpret: bool = False,
) -> jax.Array:
    B, H, Sq, D = q.shape
    Skv = k.shape[2]
    assert Sq % bq == 0 and Skv % bk == 0, (Sq, Skv, bq, bk)
    n_kv = Skv // bk
    grid = (B, H, Sq // bq, n_kv)
    kern = functools.partial(
        _kernel, scale=1.0 / np.sqrt(D), causal=causal, window=window,
        bq=bq, bk=bk, n_kv=n_kv)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, iq, ik: (b, h, ik, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, iq, ik: (b, h, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
