"""Pallas TPU kernels for the perf-critical compute layers.

parsa_cost/       — packed-bitmask popcount vertex-cost kernel (the paper's
                    §4.1 hot loop re-thought for VMEM; DESIGN.md §2)
flash_attention/  — online-softmax blocked attention for 32k prefill

Each kernel ships <name>.py (pl.pallas_call + BlockSpec), ops.py (jit'd
wrapper w/ padding + GQA/packing adapters) and ref.py (pure-jnp oracle);
tests/test_kernels.py sweeps shapes/dtypes against the oracles in interpret
mode (this container is CPU-only).
"""
