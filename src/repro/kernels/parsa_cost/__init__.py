from .ops import parsa_cost, pack_bitmask  # noqa: F401
from .ref import parsa_cost_ref  # noqa: F401
