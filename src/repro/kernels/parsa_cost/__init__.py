from .ops import (  # noqa: F401
    coerce_dense_sets,
    coerce_packed_sets,
    compact_row_words,
    pack_bitmask,
    pack_bitmask_csr,
    pack_bitmask_csr_compact,
    pack_bitmask_csr_sparse,
    packed_delta,
    packed_intersect_counts,
    packed_union,
    packed_union_delta,
    parsa_cost,
    parsa_cost_select,
    refine_sweep_chunk,
    sketch_cost_select,
    unpack_bitmask,
)
from .ref import (  # noqa: F401
    BIG,
    parsa_cost_ref,
    parsa_select_greedy_ref,
    parsa_select_ref,
    refine_sweep_ref,
    select_from_cost,
    select_greedy_from_cost,
    sketch_select_ref,
)
from .select import (  # noqa: F401
    SKETCH_KERNEL_MAX_WORDS,
    refine_sweep_kernel,
    sketch_select_kernel,
)
