r"""Pallas TPU kernel: Parsa vertex costs over packed bitmasks.

The paper's hot loop (§4.1) evaluates cost_i(u) = |N(u) \ S_i| with a
pointer-chased bucket list — a CPU-native mechanism with no TPU analogue.
The TPU reformulation keeps neighbor sets as *packed bitmasks* and evaluates
a whole (U-block × K-partition) cost tile as dense VPU bit-ops in VMEM:

    cost[u, i] = Σ_w popcount(nbr[u, w] & ~s[i, w])

Tiling: grid = (U/bu, W/bw).  Each step loads an (bu, bw) int32 neighbor
tile and the (K, bw) slice of all partition masks, loops over K partitions
(K ≤ 64, kept unrolled in VMEM), and accumulates partial popcount sums into
the (bu, K) output tile, which is revisited across the W grid axis
(classic reduction-into-output pattern: initialize at w==0).

VMEM budget per step (defaults bu=256, bw=512, K≤64):
    nbr tile   256×512×4  = 512 KiB
    s tile      64×512×4  = 128 KiB
    out tile   256×64×4   =  64 KiB
    per-k temp 256×512×4  = 512 KiB      (inside the K loop)
  ≈ 1.2 MiB — comfortably inside the ~16 MiB VMEM of a v5e core, with room
  for double buffering.  bw is a multiple of 128 (lane width); bu a multiple
  of 8 (sublane) — int32 tiles are (8, 128).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(nbr_ref, s_ref, out_ref):
    w_idx = pl.program_id(1)

    @pl.when(w_idx == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    nbr = nbr_ref[...]  # (bu, bw) int32
    k = s_ref.shape[0]

    def body(i, _):
        s_row = s_ref[i, :]  # (bw,) int32
        masked = nbr & ~s_row[None, :]
        partial = jax.lax.population_count(masked).astype(jnp.int32).sum(axis=1)
        out_ref[:, i] += partial
        return _

    jax.lax.fori_loop(0, k, body, None, unroll=True)


@functools.partial(jax.jit, static_argnames=("bu", "bw", "interpret"))
def parsa_cost_kernel(
    nbr_masks: jax.Array,  # (U, W) int32, U % bu == 0, W % bw == 0
    s_masks: jax.Array,    # (K, W) int32
    *,
    bu: int = 256,
    bw: int = 512,
    interpret: bool = False,
) -> jax.Array:
    U, W = nbr_masks.shape
    K = s_masks.shape[0]
    grid = (U // bu, W // bw)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bu, bw), lambda u, w: (u, w)),
            pl.BlockSpec((K, bw), lambda u, w: (0, w)),
        ],
        out_specs=pl.BlockSpec((bu, K), lambda u, w: (u, 0)),
        out_shape=jax.ShapeDtypeStruct((U, K), jnp.int32),
        interpret=interpret,
    )(nbr_masks, s_masks)
