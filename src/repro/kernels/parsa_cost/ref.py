r"""Pure-jnp oracle for the parsa_cost kernel.

cost[u, i] = |N(u) \ S_i| = Σ_w popcount(nbr[u, w] & ~s[i, w])
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def parsa_cost_ref(nbr_masks: jax.Array, s_masks: jax.Array) -> jax.Array:
    """nbr_masks (U, W) int32 bit-packs, s_masks (K, W) int32 → (U, K) int32."""
    masked = nbr_masks[:, None, :] & ~s_masks[None, :, :]
    return jax.lax.population_count(masked).astype(jnp.int32).sum(axis=-1)
