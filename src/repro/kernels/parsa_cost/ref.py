r"""Pure-jnp oracles for the parsa_cost / parsa_select kernels.

cost[u, i] = |N(u) \ S_i| = Σ_w popcount(nbr[u, w] & ~s[i, w])

The *select* oracles fuse the cost tile with the greedy reduction the
blocked partitioner needs: per-partition (min, argmin) over the block's
unretired vertices.  Two flavours:

  * ``parsa_select_ref`` — independent per-partition reduction (each column
    reduced in isolation; retired rows masked to BIG).
  * ``parsa_select_greedy_ref`` — one greedy *round*: columns are visited in
    ``order``; each pick retires its vertex before the next column is
    reduced, so the k selections are distinct.  This is exactly one round of
    the perfectly-balanced greedy loop in ``jax_partition._assign_block``.

Both are bit-exact integer programs — the Pallas kernel in ``select.py``
must match them exactly (tested in interpret mode).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

BIG = 2**30  # sentinel cost for retired / padded vertices (fits int32)


def parsa_cost_ref(nbr_masks: jax.Array, s_masks: jax.Array) -> jax.Array:
    """nbr_masks (U, W) int32 bit-packs, s_masks (K, W) int32 → (U, K) int32."""
    masked = nbr_masks[:, None, :] & ~s_masks[None, :, :]
    return jax.lax.population_count(masked).astype(jnp.int32).sum(axis=-1)


def select_from_cost(cost: jax.Array, retired: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Independent per-column (min, argmin) of a (B, k) tile, retired→BIG.

    Ties resolve to the lowest row index (jnp.argmin semantics).
    """
    masked = jnp.where(retired[:, None], BIG, cost)
    mins = jnp.min(masked, axis=0).astype(jnp.int32)
    argmins = jnp.argmin(masked, axis=0).astype(jnp.int32)
    return mins, argmins


def select_greedy_from_cost(
    cost: jax.Array,             # (B, k) int32 — current cost tile
    retired: jax.Array,          # (B,) bool — already-assigned / padded rows
    order: jax.Array | None,     # (k,) int32 column visit order; None = 0..k-1
    enabled: jax.Array,          # (k,) bool — whether slot j may pick this round
) -> tuple[jax.Array, jax.Array]:
    """One greedy round over a cost tile: progressive-retirement selection.

    Returns (u_sel, c_sel), both (k,): slot j picked vertex u_sel[j] for
    partition order[j] at cost c_sel[j].  Inactive slots (disabled, or no
    unretired vertex left) return u_sel = -1, c_sel = BIG.

    Semantics are strictly sequential (slot j sees the retirements of slots
    < j), but the common case is computed in one vectorized pass: every
    slot's candidate is its column's masked argmin, and a slot's candidate
    only differs from its sequential pick if an *earlier slot grabs the
    same vertex*.  So when all active candidates are pairwise distinct —
    the overwhelmingly common case once the S_i differentiate — the
    one-pass result IS the sequential result.  Only on a collision does a
    ``lax.cond`` fall back to the scalar per-slot loop (which costs ~k
    small ops, but runs for a tiny fraction of rounds, e.g. the very first
    rounds where all partitions still have identical costs).
    """
    B, k = cost.shape
    iota_b = jnp.arange(B, dtype=jnp.int32)
    cols = cost if order is None else cost[:, order]  # (B, k) — slot j's column

    masked = jnp.where(retired[:, None], BIG, cols)            # (B, k)
    m = jnp.min(masked, axis=0)
    a = jnp.argmin(masked, axis=0).astype(jnp.int32)           # first row
    act = enabled & (m < BIG)                                  # (k,)
    pick = jnp.where(act, a, -1)
    same = (pick[None, :] == pick[:, None]) & act[None, :] & act[:, None]
    collide = jnp.triu(same, 1).any()

    def fast(_):
        return pick, jnp.where(act, m, BIG)

    def slow(_):
        def body(j, carry):
            u_sel, c_sel, ret = carry
            c = jax.lax.dynamic_slice_in_dim(cols, j, 1, 1)[:, 0]  # (B,)
            c = jnp.where(ret, BIG, c)
            mj = jnp.min(c)
            uj = jnp.argmin(c).astype(jnp.int32)
            actj = enabled[j] & (mj < BIG)
            ret = ret | ((iota_b == uj) & actj)
            u_sel = u_sel.at[j].set(jnp.where(actj, uj, -1))
            c_sel = c_sel.at[j].set(jnp.where(actj, mj, BIG))
            return u_sel, c_sel, ret

        u0 = jnp.full((k,), -1, jnp.int32)
        c0 = jnp.full((k,), BIG, jnp.int32)
        u_sel, c_sel, _ = jax.lax.fori_loop(0, k, body, (u0, c0, retired),
                                            unroll=True)
        return u_sel, c_sel

    return jax.lax.cond(collide, slow, fast, None)


def refine_sweep_ref(
    tile_words: jax.Array,  # (k, cw) int32 — packed need bits of one V chunk
    prev: jax.Array,        # (C,) int32 — assignments entering the sweep (C = 32·cw)
    cost: jax.Array,        # (k,) int32 — Alg 2 cost vector at chunk entry
) -> tuple[jax.Array, jax.Array]:
    """Sequential oracle for the fused refine-sweep kernel: one Algorithm 2
    greedy chunk, parameter by parameter.  Returns (cost', parts (C,)).

    Exact Alg 2 line-8 algebra: assign j→ξ adds −1 + (n_j − 1) at ξ;
    re-assignment (``prev[j] ≥ 0``) first retracts −1 + (n_j − u_{cur,j})
    at the old host.  Parameters nobody needs stay −1 and touch nothing.
    The Pallas kernel in ``select.py`` must match this bit-for-bit.
    """
    k, cw = tile_words.shape
    shifts = jnp.arange(32, dtype=jnp.int32)
    tile = ((tile_words[:, :, None] >> shifts) & 1).reshape(k, cw * 32)
    nneed = tile.sum(axis=0, dtype=jnp.int32)

    def step(c, xs):
        bits_col, nj, cur = xs
        cs = jnp.where(cur >= 0, cur, 0)
        c = c.at[cs].add(jnp.where(cur >= 0, 1 - nj + bits_col[cs], 0))
        masked = jnp.where(bits_col > 0, c, BIG)
        xi = jnp.argmin(masked).astype(jnp.int32)
        act = nj > 0
        c = c.at[jnp.where(act, xi, 0)].add(jnp.where(act, nj - 2, 0))
        return c, jnp.where(act, xi, -1)

    return jax.lax.scan(step, cost, (tile.T, nneed, prev))


def sketch_select_ref(nbr_masks, s_masks, retired, order=None, enabled=None,
                      *, greedy=False):
    """Oracle for the fully VMEM-resident sketch-select kernel.

    Numerically this is the same fused cost+select program as
    ``parsa_select_ref`` / ``parsa_select_greedy_ref`` — at sketch widths
    the packed words are simply fewer, which is what lets the kernel hold
    the whole (B, Ws) tile in one grid step.  Kept as a named oracle so
    the kernel's bit-exactness contract is explicit and independently
    testable.  Returns ((1, k) u/argmin, (1, k) cost) to match the kernel's
    output layout.
    """
    cost = parsa_cost_ref(nbr_masks, s_masks)
    if greedy:
        u, c = select_greedy_from_cost(cost, retired, order, enabled)
    else:
        c, u = select_from_cost(cost, retired)
    return u[None, :], c[None, :]


def parsa_select_ref(nbr_masks, s_masks, retired):
    """Fused cost+select oracle, independent mode → ((k,) mins, (k,) argmins)."""
    return select_from_cost(parsa_cost_ref(nbr_masks, s_masks), retired)


def parsa_select_greedy_ref(nbr_masks, s_masks, retired, order, enabled):
    """Fused cost+select oracle, greedy-round mode → ((k,) u_sel, (k,) c_sel)."""
    return select_greedy_from_cost(
        parsa_cost_ref(nbr_masks, s_masks), retired, order, enabled)
