"""jit'd public wrappers for the parsa_cost / parsa_select kernels
(padding + dispatch) and the host-side bitmask packing routines."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .parsa_cost import parsa_cost_kernel
from .ref import (
    parsa_cost_ref,
    parsa_select_greedy_ref,
    parsa_select_ref,
    refine_sweep_ref,
    sketch_select_ref,
)
from .select import (
    SKETCH_KERNEL_MAX_WORDS,
    packed_union_delta_kernel,
    parsa_select_kernel,
    refine_sweep_kernel,
    sketch_select_kernel,
)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def pack_bitmask(ids_per_row: list[np.ndarray] | np.ndarray, num_v: int) -> np.ndarray:
    """Pack per-row V-id sets into (rows, ceil(num_v/32)) int32 bitmasks."""
    W = (num_v + 31) // 32
    if isinstance(ids_per_row, np.ndarray) and ids_per_row.ndim == 2:
        # boolean membership matrix (rows, num_v); packbits binarizes the
        # rows directly so no dense-sized astype/pad transient is allocated
        rows = ids_per_row.shape[0]
        dense = ids_per_row if ids_per_row.dtype == np.bool_ \
            else ids_per_row.astype(bool)
        packed = np.packbits(dense, axis=-1, bitorder="little")  # (rows, ⌈V/8⌉)
        out = np.zeros((rows, W * 4), dtype=np.uint8)
        out[:, : packed.shape[1]] = packed
        return out.view(np.uint32).reshape(rows, W).view(np.int32)
    out = np.zeros((len(ids_per_row), W), dtype=np.uint32)
    for r, ids in enumerate(ids_per_row):
        ids = np.asarray(ids, dtype=np.int64)
        np.bitwise_or.at(out[r], ids // 32, np.uint32(1) << (ids % 32).astype(np.uint32))
    return out.view(np.int32)


def unpack_bitmask(masks: np.ndarray, num_v: int) -> np.ndarray:
    """Inverse of ``pack_bitmask``: (rows, ceil(num_v/32)) int32 bitmasks →
    (rows, num_v) bool membership matrix.  Exact round trip:
    ``unpack_bitmask(pack_bitmask(x, num_v), num_v) == x``.

    Allocates exactly one dense array: the 0/1 bytes from ``unpackbits``
    are reinterpreted as bool (same itemsize) instead of copied, so a
    worker pull in ``parallel.py`` costs one (rows, |V|) scratch, not two.
    """
    masks = np.ascontiguousarray(masks).view(np.uint32)
    rows, W = masks.shape
    bits = np.unpackbits(
        masks.view(np.uint8).reshape(rows, W * 4), axis=-1, bitorder="little")
    return bits[:, :num_v].view(np.bool_)


def coerce_packed_sets(sets, num_v: int) -> np.ndarray:
    """Normalize neighbor sets to the packed (k, ⌈num_v/32⌉) int32 wire
    format.  Accepts packed int32/uint32 words (returned as-is, no copy),
    a dense (k, num_v) bool membership matrix, or anything castable to one
    — so warm starts can hand ``PartitionResult.s_masks`` straight to a
    device backend without a dense round trip."""
    W = (num_v + 31) // 32
    a = np.asarray(sets)
    if a.ndim != 2:
        raise ValueError(f"neighbor sets must be 2-D, got shape {a.shape}")
    if a.dtype != np.bool_ and np.issubdtype(a.dtype, np.integer) \
            and a.shape[1] == W and a.shape[1] != num_v:
        return a.view(np.int32) if a.dtype == np.uint32 else \
            a.astype(np.int32, copy=False)
    if a.shape[1] != num_v:
        raise ValueError(
            f"neighbor sets width {a.shape[1]} matches neither num_v="
            f"{num_v} (dense) nor {W} packed words")
    return pack_bitmask(a.astype(bool, copy=False), num_v)


def coerce_dense_sets(sets, num_v: int) -> np.ndarray:
    """Inverse normalization: dense (k, num_v) bool view of neighbor sets
    handed in either format (packed input is unpacked into a fresh,
    writable scratch)."""
    W = (num_v + 31) // 32
    a = np.asarray(sets)
    if a.ndim != 2:
        raise ValueError(f"neighbor sets must be 2-D, got shape {a.shape}")
    if a.dtype != np.bool_ and np.issubdtype(a.dtype, np.integer) \
            and a.shape[1] == W and a.shape[1] != num_v:
        return unpack_bitmask(a, num_v)
    if a.shape[1] != num_v:
        raise ValueError(
            f"neighbor sets width {a.shape[1]} matches neither num_v="
            f"{num_v} (dense) nor {W} packed words")
    return a.astype(bool, copy=False)


def packed_union(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Word-wise union of packed bitmasks: the Alg 4 server OR-merge
    (line 9) on the wire format — works on any int word dtype."""
    return a | b


def packed_delta(new: np.ndarray, old: np.ndarray) -> np.ndarray:
    """Word-wise set difference ``new \\ old`` on packed bitmasks — the
    delta a worker pushes back to the server (Alg 4 worker line 9).
    ``packed_union(old, packed_delta(new, old)) == packed_union(old, new)``."""
    return new & ~old


# per-byte popcount table: the numpy<2.0 fallback (np.bitwise_count is 2.0+)
_POPCOUNT8 = np.unpackbits(
    np.arange(256, dtype=np.uint8).reshape(-1, 1), axis=1).sum(
        axis=1).astype(np.int64)


def packed_intersect_counts(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """All-pairs intersection sizes of two packed bitmask stacks:
    ``out[i, j] = |rows_a[i] ∩ rows_b[j]|`` for (ka, W) × (kb, W) int32
    words → (ka, kb) int64 counts.

    Host-side mirror of the (k, k) packed intersection matrix the device
    metrics use (``jax_refine._metrics_popcount``); the stream migration
    planner matches old→new parts with it.  The (ka, kb, W) AND transient
    is materialized in one go — fine for partition counts (k ≤ 1024)."""
    a = np.ascontiguousarray(a).view(np.uint32)
    b = np.ascontiguousarray(b).view(np.uint32)
    if a.ndim != 2 or b.ndim != 2 or a.shape[1] != b.shape[1]:
        raise ValueError(
            f"packed stacks must share the word width, got {a.shape} "
            f"vs {b.shape}")
    inter = a[:, None, :] & b[None, :, :]
    if hasattr(np, "bitwise_count"):
        return np.bitwise_count(inter).sum(axis=-1, dtype=np.int64)
    return _POPCOUNT8[inter.view(np.uint8)].sum(axis=-1)


def packed_union_delta(
    new_masks: jax.Array,
    old_masks: jax.Array,
    *,
    bw: int = 512,
    interpret: bool | None = None,
    use_kernel: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Fused (union, delta) over packed (k, W) int32 words.

    Pads W to a multiple of ``bw`` and k to the int32 sublane height (both
    lattice ops map zero words to zero words, so padding is exact), then
    dispatches the Pallas kernel (interpret mode off-TPU) or the jnp
    fallback.
    """
    if not use_kernel:
        return new_masks | old_masks, new_masks & ~old_masks
    if interpret is None:
        interpret = not _on_tpu()
    k, W = new_masks.shape
    bw_ = min(bw, max(128, 128 * ((W + 127) // 128)))
    pk = (-k) % 8
    pw = (-W) % bw_
    new_p = jnp.pad(new_masks, [(0, pk), (0, pw)])
    old_p = jnp.pad(old_masks, [(0, pk), (0, pw)])
    union, delta = packed_union_delta_kernel(new_p, old_p, bw=bw_,
                                             interpret=interpret)
    return union[:k, :W], delta[:k, :W]


def refine_sweep_chunk(
    tile_words: jax.Array,  # (k, cw) int32 packed need bits of one V chunk
    prev: jax.Array,        # (C,) int32 entering assignments, C == 32·cw
    cost: jax.Array,        # (k,) int32 Alg 2 cost vector
    *,
    interpret: bool | None = None,
    use_kernel: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Fused Algorithm 2 chunk sweep → (cost' (k,), parts (C,)).

    Pads k to the int32 sublane height with zero need words (a padding
    partition needs nothing, so it is never picked and its cost row is
    sliced away) and dispatches the Pallas kernel (interpret mode off-TPU)
    or the jnp oracle.  Lane alignment of ``cw`` is the caller's choice —
    use 32·cw ≥ 4096 chunks for real-TPU runs.
    """
    k, cw = tile_words.shape
    C = cw * 32
    if not use_kernel:
        cost_out, parts = refine_sweep_ref(tile_words, prev, cost)
        return cost_out, parts
    if interpret is None:
        interpret = not _on_tpu()
    pk = (-k) % 8
    words_p = jnp.pad(tile_words, [(0, pk), (0, 0)])
    cost_p = jnp.pad(cost, [(0, pk)])
    parts, cost_out = refine_sweep_kernel(
        words_p, prev.reshape(1, C), cost_p.reshape(1, k + pk),
        interpret=interpret)
    return cost_out[0, :k], parts[0]


def _gather_row_cols(
    indptr: np.ndarray,
    indices: np.ndarray,
    rows: np.ndarray | None,
) -> tuple[int, np.ndarray, np.ndarray, np.ndarray]:
    """Gather the CSR edge array in (optionally permuted) row order.

    Returns (n, lens, row_ids, cols): per-edge destination row ids and V
    columns, fully vectorized — the global position of edge e is
    start-of-its-row + offset-within-row.
    """
    indptr = np.asarray(indptr, dtype=np.int64)
    indices = np.asarray(indices, dtype=np.int64)
    if rows is None:
        n = indptr.shape[0] - 1
        lens = np.diff(indptr)
        cols = indices
    else:
        rows = np.asarray(rows, dtype=np.int64)
        n = rows.shape[0]
        lens = indptr[rows + 1] - indptr[rows]
        total = int(lens.sum())
        ends = np.cumsum(lens)
        offs = np.arange(total, dtype=np.int64) - np.repeat(ends - lens, lens)
        cols = indices[np.repeat(indptr[rows], lens) + offs]
    row_ids = np.repeat(np.arange(n, dtype=np.int64), lens)
    return n, lens, row_ids, cols


def pack_bitmask_csr(
    indptr: np.ndarray,
    indices: np.ndarray,
    num_v: int,
    rows: np.ndarray | None = None,
) -> np.ndarray:
    """Vectorized CSR → (rows, ceil(num_v/32)) int32 bitmask packing.

    Equivalent to ``pack_bitmask([indices[indptr[r]:indptr[r+1]] for r in
    rows], num_v)`` but with zero Python-level per-row work: one gather over
    the whole edge array plus one fused ``bitwise_or.at`` scatter.

    ``rows`` optionally selects/permutes rows (e.g. the random vertex order
    of the blocked partitioner); ``None`` packs all rows in CSR order.
    """
    n, _, row_ids, cols = _gather_row_cols(indptr, indices, rows)
    W = (num_v + 31) // 32
    out = np.zeros(n * W, dtype=np.uint32)
    np.bitwise_or.at(
        out,
        row_ids * W + (cols >> 5),
        (np.int64(1) << (cols & 31)).astype(np.uint32),
    )
    return out.reshape(n, W).view(np.int32)


def pack_bitmask_csr_sparse(
    indptr: np.ndarray,
    indices: np.ndarray,
    num_v: int,
    rows: np.ndarray | None = None,
    cap: int = 48,
) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray, np.ndarray, int, int]:
    """Sparse fused packing: the bitmask as (distinct flat word index, word
    value) pairs plus per-row compact word lists, in one sorted pass.

    One argsort over (row, word) keys yields both representations without
    ever touching a dense (n, W) array — the caller chooses where (and
    whether) to densify: ``pack_bitmask_csr_compact`` scatters on the host,
    while ``blocked_partition_u`` never densifies globally at all — it
    ships only the compact lists (plus the truncated rows' full masks,
    built from (uniq, wordvals)) and rebuilds each block's (B, W) bitmask
    on device inside the scan.

    Returns (uniq (nnz,) int64 flat indices into the (n, W) mask,
    wordvals (nnz,) int32, widx (n, cap) int32, vals (n, cap) int32,
    truncated (n,) bool, n, W).
    """
    n, _, row_ids, cols = _gather_row_cols(indptr, indices, rows)
    W = (num_v + 31) // 32
    widx = np.zeros((n, cap), dtype=np.int32)
    vals = np.zeros((n, cap), dtype=np.uint32)
    if cols.size == 0:
        return (np.zeros(0, np.int64), np.zeros(0, np.int32), widx,
                vals.view(np.int32), np.zeros(n, bool), n, W)
    fw = row_ids * W + (cols >> 5)            # flat (row, word) key per edge
    bit = (np.int64(1) << (cols & 31)).astype(np.uint32)
    srt = np.argsort(fw, kind="stable")
    fs, bs = fw[srt], bit[srt]
    boundary = np.empty(fs.size, bool)
    boundary[0] = True
    np.not_equal(fs[1:], fs[:-1], out=boundary[1:])
    first = np.flatnonzero(boundary)
    uniq = fs[first]                          # distinct (row, word), sorted
    acc = np.bitwise_or.reduceat(bs, first)   # the word values
    r = uniq // W
    counts = np.bincount(r, minlength=n)
    starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
    pos = np.arange(uniq.size, dtype=np.int64) - starts[r]
    keep = pos < cap
    flat = r[keep] * cap + pos[keep]
    widx.reshape(-1)[flat] = (uniq[keep] % W).astype(np.int32)
    vals.reshape(-1)[flat] = acc[keep]
    return (uniq, acc.view(np.int32), widx, vals.view(np.int32),
            counts > cap, n, W)


def pack_bitmask_csr_compact(
    indptr: np.ndarray,
    indices: np.ndarray,
    num_v: int,
    rows: np.ndarray | None = None,
    cap: int = 48,
) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Fused ``pack_bitmask_csr`` + ``compact_row_words`` in one sorted pass.

    Returns (masks (n, W) int32, widx (n, cap) int32, vals (n, cap) int32,
    truncated (n,) bool), matching the two-step reference exactly.
    """
    uniq, wordvals, widx, vals, trunc, n, W = pack_bitmask_csr_sparse(
        indptr, indices, num_v, rows=rows, cap=cap)
    masks = np.zeros(n * W, dtype=np.int32)
    masks[uniq] = wordvals
    return masks.reshape(n, W), widx, vals, trunc


def compact_row_words(
    masks: np.ndarray, cap: int
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Per-row compact word lists of a packed (N, W) bitmask.

    Returns (widx (N, cap) int32, vals (N, cap) int32, truncated (N,) bool).
    Rows with ≤ cap nonzero words are represented exactly: for any mask X,
    Σ_d popcount(vals[r, d] & X[widx[r, d]]) == popcount(masks[r] & X).
    Rows with more nonzero words keep their first ``cap`` words and are
    flagged in ``truncated`` so callers can fall back to the dense mask.
    Padding slots point at word 0 with value 0 (safe to gather, adds 0).
    """
    n = masks.shape[0]
    r, c = np.nonzero(masks)
    counts = np.bincount(r, minlength=n)
    starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
    pos = np.arange(r.size, dtype=np.int64) - starts[r]
    keep = pos < cap
    widx = np.zeros((n, cap), dtype=np.int32)
    vals = np.zeros((n, cap), dtype=np.int32)
    flat = r[keep] * cap + pos[keep]
    widx.reshape(-1)[flat] = c[keep]
    vals.reshape(-1)[flat] = masks[r[keep], c[keep]]
    return widx, vals, counts > cap


def parsa_cost(
    nbr_masks: jax.Array,
    s_masks: jax.Array,
    *,
    bu: int = 256,
    bw: int = 512,
    interpret: bool | None = None,
    use_kernel: bool = True,
) -> jax.Array:
    """cost[u, i] = |N(u) \\ S_i| for packed int32 bitmasks.

    Pads U to a multiple of ``bu`` and W to a multiple of ``bw`` (zero words
    contribute zero popcount, so padding is exact), then dispatches to the
    Pallas kernel (interpret mode off-TPU) or the jnp oracle.
    """
    if interpret is None:
        interpret = not _on_tpu()
    U, W = nbr_masks.shape
    if not use_kernel:
        return parsa_cost_ref(nbr_masks, s_masks)
    bu_ = min(bu, max(8, 8 * ((U + 7) // 8)))
    bw_ = min(bw, max(128, 128 * ((W + 127) // 128)))
    pu = (-U) % bu_
    pw = (-W) % bw_
    nbr_p = jnp.pad(nbr_masks, [(0, pu), (0, pw)])
    s_p = jnp.pad(s_masks, [(0, 0), (0, pw)])
    out = parsa_cost_kernel(nbr_p, s_p, bu=bu_, bw=bw_, interpret=interpret)
    return out[:U]


def parsa_cost_select(
    nbr_masks: jax.Array,   # (B, W) int32 packed N(u)
    s_masks: jax.Array,     # (k, W) int32 packed S_i
    retired: jax.Array,     # (B,) bool — rows excluded from selection
    *,
    order: jax.Array | None = None,    # (k,) int32 → greedy-round mode
    enabled: jax.Array | None = None,  # (k,) bool slot gate (greedy mode)
    bw: int = 512,
    interpret: bool | None = None,
    use_kernel: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Fused cost+select: reduce the (B, k) cost tile to per-partition
    (min, argmin) without materializing it outside VMEM.

    Independent mode (``order is None``) returns ((k,) mins, (k,) argmins)
    over unretired rows, ties to the lowest row.  Greedy mode visits columns
    in ``order`` with progressive retirement (one balanced greedy round) and
    returns ((k,) u_sel, (k,) c_sel) with u_sel = -1 / c_sel = BIG for
    inactive slots.  Bit-exact vs the ``ref.py`` oracles.
    """
    if interpret is None:
        interpret = not _on_tpu()
    B, W = nbr_masks.shape
    k = s_masks.shape[0]
    greedy = order is not None
    if enabled is None:
        enabled = jnp.ones((k,), bool)
    if not use_kernel:
        if greedy:
            return parsa_select_greedy_ref(nbr_masks, s_masks, retired,
                                           order, enabled)
        return parsa_select_ref(nbr_masks, s_masks, retired)
    bw_ = min(bw, max(128, 128 * ((W + 127) // 128)))
    pb = (-B) % 8
    pw = (-W) % bw_
    nbr_p = jnp.pad(nbr_masks, [(0, pb), (0, pw)])
    s_p = jnp.pad(s_masks, [(0, 0), (0, pw)])
    # padded rows are born retired so they never win a selection
    ret_p = jnp.pad(retired, [(0, pb)], constant_values=True)
    if greedy:
        order_in = order.astype(jnp.int32)[None, :]
    else:
        order_in = jnp.arange(k, dtype=jnp.int32)[None, :]
    enabled_in = enabled.astype(jnp.int32)[None, :]
    u_sel, c_sel = parsa_select_kernel(
        nbr_p, s_p, ret_p.astype(jnp.int32)[:, None], order_in, enabled_in,
        greedy=greedy, bw=bw_, interpret=interpret)
    if greedy:
        return u_sel[0], c_sel[0]
    return c_sel[0], u_sel[0]  # independent mode: (mins, argmins)


def sketch_cost_select(
    nbr_masks: jax.Array,   # (B, Ws) int32 packed sketched N(u)
    s_masks: jax.Array,     # (k, Ws) int32 packed sketched S_i
    retired: jax.Array,     # (B,) bool — rows excluded from selection
    *,
    order: jax.Array | None = None,    # (k,) int32 → greedy-round mode
    enabled: jax.Array | None = None,  # (k,) bool slot gate (greedy mode)
    interpret: bool | None = None,
    use_kernel: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Fused cost+select at sketched widths: the whole (B, Ws) tile VMEM
    resident in ONE grid step — no word grid, no cross-step accumulator.

    Same contract as ``parsa_cost_select`` (independent → (mins, argmins),
    greedy → (u_sel, c_sel)), bit-exact vs ``sketch_select_ref``.  Sketch
    widths padded beyond ``SKETCH_KERNEL_MAX_WORDS`` words fall back to
    the W-gridded ``parsa_cost_select`` — they no longer fit the
    single-step VMEM budget.
    """
    if interpret is None:
        interpret = not _on_tpu()
    B, W = nbr_masks.shape
    k = s_masks.shape[0]
    greedy = order is not None
    if enabled is None:
        enabled = jnp.ones((k,), bool)
    if not use_kernel:
        u_sel, c_sel = sketch_select_ref(nbr_masks, s_masks, retired,
                                         order, enabled, greedy=greedy)
        if greedy:
            return u_sel[0], c_sel[0]
        return c_sel[0], u_sel[0]  # independent mode: (mins, argmins)
    pw = (-W) % 128
    if W + pw > SKETCH_KERNEL_MAX_WORDS:
        return parsa_cost_select(nbr_masks, s_masks, retired, order=order,
                                 enabled=enabled, interpret=interpret,
                                 use_kernel=True)
    pb = (-B) % 8
    nbr_p = jnp.pad(nbr_masks, [(0, pb), (0, pw)])
    s_p = jnp.pad(s_masks, [(0, 0), (0, pw)])
    # padded rows are born retired so they never win a selection
    ret_p = jnp.pad(retired, [(0, pb)], constant_values=True)
    if greedy:
        order_in = order.astype(jnp.int32)[None, :]
    else:
        order_in = jnp.arange(k, dtype=jnp.int32)[None, :]
    enabled_in = enabled.astype(jnp.int32)[None, :]
    u_sel, c_sel = sketch_select_kernel(
        nbr_p, s_p, ret_p.astype(jnp.int32)[:, None], order_in, enabled_in,
        greedy=greedy, interpret=interpret)
    if greedy:
        return u_sel[0], c_sel[0]
    return c_sel[0], u_sel[0]  # independent mode: (mins, argmins)
