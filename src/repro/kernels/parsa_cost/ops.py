"""jit'd public wrapper for the parsa_cost kernel (padding + dispatch)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .parsa_cost import parsa_cost_kernel
from .ref import parsa_cost_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def pack_bitmask(ids_per_row: list[np.ndarray] | np.ndarray, num_v: int) -> np.ndarray:
    """Pack per-row V-id sets into (rows, ceil(num_v/32)) int32 bitmasks."""
    W = (num_v + 31) // 32
    if isinstance(ids_per_row, np.ndarray) and ids_per_row.ndim == 2:
        # boolean membership matrix (rows, num_v)
        rows = ids_per_row.shape[0]
        pad = W * 32 - num_v
        bits = np.pad(ids_per_row.astype(np.uint8), [(0, 0), (0, pad)])
        packed = np.packbits(bits.reshape(rows, W * 4, 8), axis=-1, bitorder="little")
        return np.ascontiguousarray(packed.reshape(rows, W, 4)).view(np.uint32).reshape(rows, W).view(np.int32)
    out = np.zeros((len(ids_per_row), W), dtype=np.uint32)
    for r, ids in enumerate(ids_per_row):
        ids = np.asarray(ids, dtype=np.int64)
        np.bitwise_or.at(out[r], ids // 32, np.uint32(1) << (ids % 32).astype(np.uint32))
    return out.view(np.int32)


def parsa_cost(
    nbr_masks: jax.Array,
    s_masks: jax.Array,
    *,
    bu: int = 256,
    bw: int = 512,
    interpret: bool | None = None,
    use_kernel: bool = True,
) -> jax.Array:
    """cost[u, i] = |N(u) \\ S_i| for packed int32 bitmasks.

    Pads U to a multiple of ``bu`` and W to a multiple of ``bw`` (zero words
    contribute zero popcount, so padding is exact), then dispatches to the
    Pallas kernel (interpret mode off-TPU) or the jnp oracle.
    """
    if interpret is None:
        interpret = not _on_tpu()
    U, W = nbr_masks.shape
    if not use_kernel:
        return parsa_cost_ref(nbr_masks, s_masks)
    bu_ = min(bu, max(8, 8 * ((U + 7) // 8)))
    bw_ = min(bw, max(128, 128 * ((W + 127) // 128)))
    pu = (-U) % bu_
    pw = (-W) % bw_
    nbr_p = jnp.pad(nbr_masks, [(0, pu), (0, pw)])
    s_p = jnp.pad(s_masks, [(0, 0), (0, pw)])
    out = parsa_cost_kernel(nbr_p, s_p, bu=bu_, bw=bw_, interpret=interpret)
    return out[:U]
