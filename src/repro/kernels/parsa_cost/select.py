r"""Pallas TPU kernel: fused Parsa cost + select over packed bitmasks.

The blocked greedy partitioner (``jax_partition._assign_block_rounds``) never
needs the full (B × k) cost tile in HBM — per round it only needs, for every
partition i, the cheapest unassigned vertex of the block:

    cost[u, i] = Σ_w popcount(nbr[u, w] & ~s[i, w])
    (min_i, argmin_i) = min/argmin over unretired u of cost[u, i]

This kernel computes the tile *and* the reduction in one pass: the (B, k)
partials accumulate in a VMEM scratch across the W grid axis, and the final
grid step reduces them to two (1, k) outputs.  The tile never leaves VMEM,
so B=1024 blocks cost 4·B·k bytes of scratch instead of an HBM round-trip —
that is what lets the greedy path scale past B=256.

Two selection modes (static switch):

  * independent — each column reduced in isolation over unretired rows
    (retired→BIG); ties take the lowest row index.
  * greedy — one *round* of the perfectly-balanced greedy loop: columns are
    visited in ``order``; each active pick retires its row before the next
    column is reduced, so the k picks are distinct.  Slots that are disabled
    or find no unretired row return (u=-1, c=BIG).

VMEM budget per step (B=1024, bw=512, k≤64):
    nbr tile  1024×512×4 = 2 MiB
    s tile      64×512×4 = 128 KiB
    acc       1024×64×4  = 256 KiB
    per-k temp 1024×512×4 = 2 MiB  (inside the unrolled k loop)
  ≈ 4.4 MiB — inside the ~16 MiB VMEM of a v5e core.  bw is a multiple of
  128 (lane width); B a multiple of 8 (int32 sublane).

This file also hosts the other fused lattice kernels of the pipeline:
``packed_union_delta_kernel`` (Alg 4 server merge wire ops) and
``refine_sweep_kernel`` (the Algorithm 2 cost-update sweep of one V chunk —
bit tile, cost vector, and parts row all VMEM-resident; ≈ (k + 33·32)·cw·4
bytes ≪ VMEM for cw=128 chunks at k≤64).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .ref import BIG


def _select_kernel(nbr_ref, s_ref, retired_ref, order_ref, enabled_ref,
                   umin_ref, cmin_ref, acc_ref, *, greedy: bool):
    w_idx = pl.program_id(0)
    nw = pl.num_programs(0)
    k = s_ref.shape[0]
    B = nbr_ref.shape[0]

    @pl.when(w_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    nbr = nbr_ref[...]  # (B, bw) int32

    def accum(i, _):
        s_row = s_ref[i, :]  # (bw,) int32
        masked = nbr & ~s_row[None, :]
        partial = jax.lax.population_count(masked).astype(jnp.int32).sum(axis=1)
        acc_ref[:, i] += partial
        return _

    jax.lax.fori_loop(0, k, accum, None, unroll=True)

    @pl.when(w_idx == nw - 1)
    def _reduce():
        cost = acc_ref[...]                                  # (B, k)
        ret = retired_ref[...] != 0                          # (B, 1)
        iota_b = jax.lax.broadcasted_iota(jnp.int32, (B, 1), 0)
        if not greedy:
            masked = jnp.where(ret, BIG, cost)               # (B, k)
            mins = jnp.min(masked, axis=0)                   # (k,)
            # first-occurrence argmin via the iota-min trick
            hit = masked == mins[None, :]
            argmins = jnp.min(jnp.where(hit, iota_b, B), axis=0)
            cmin_ref[...] = mins[None, :]
            umin_ref[...] = argmins[None, :]
        else:
            order = order_ref[...]      # (1, k) int32
            enabled = enabled_ref[...]  # (1, k) int32

            def pick(j, carry):
                u_sel, c_sel, ret = carry                    # (1,k),(1,k),(B,1)
                col = jax.lax.dynamic_index_in_dim(
                    order, j, 1, keepdims=False)[0]
                c = jax.lax.dynamic_slice(cost, (0, col), (B, 1))
                c = jnp.where(ret, BIG, c)                   # (B, 1)
                m = jnp.min(c)
                u = jnp.min(jnp.where(c == m, iota_b, B))    # first min row
                en = jax.lax.dynamic_index_in_dim(
                    enabled, j, 1, keepdims=False)[0] != 0
                act = en & (m < BIG)
                ret = ret | ((iota_b == u) & act)
                iota_k = jax.lax.broadcasted_iota(jnp.int32, (1, k), 1)
                u_sel = jnp.where(iota_k == j, jnp.where(act, u, -1), u_sel)
                c_sel = jnp.where(iota_k == j, jnp.where(act, m, BIG), c_sel)
                return u_sel, c_sel, ret

            u0 = jnp.full((1, k), -1, jnp.int32)
            c0 = jnp.full((1, k), BIG, jnp.int32)
            u_sel, c_sel, _ = jax.lax.fori_loop(0, k, pick, (u0, c0, ret),
                                                unroll=True)
            umin_ref[...] = u_sel
            cmin_ref[...] = c_sel


def _sketch_select_kernel(nbr_ref, s_ref, retired_ref, order_ref, enabled_ref,
                          umin_ref, cmin_ref, *, greedy: bool):
    """Fully VMEM-resident fused cost+select for sketched widths.

    Unlike ``_select_kernel`` there is no W grid axis and no cross-step
    scratch accumulator: the sketch compresses the packed width enough
    (guarded ≤ ~2048 words by the wrapper) that the whole (B, Ws) nbr
    tile, the (k, Ws) server sets, and the (B, k) cost tile live in VMEM
    simultaneously for one grid step.  That removes the accumulator
    read-modify-write per word tile *and* the grid bookkeeping — the
    kernel is one streamed pass.  Bit-exact vs ``ref.sketch_select_ref``.
    """
    k = s_ref.shape[0]
    B = nbr_ref.shape[0]
    nbr = nbr_ref[...]  # (B, Ws) int32 — the entire sketched block tile

    def accum(i, acc):
        s_row = s_ref[i, :]  # (Ws,) int32
        masked = nbr & ~s_row[None, :]
        partial = jax.lax.population_count(masked).astype(jnp.int32).sum(
            axis=1)
        return jax.lax.dynamic_update_slice(acc, partial[:, None], (0, i))

    cost = jax.lax.fori_loop(0, k, accum,
                             jnp.zeros((B, k), jnp.int32), unroll=True)

    ret = retired_ref[...] != 0                          # (B, 1)
    iota_b = jax.lax.broadcasted_iota(jnp.int32, (B, 1), 0)
    if not greedy:
        masked = jnp.where(ret, BIG, cost)               # (B, k)
        mins = jnp.min(masked, axis=0)                   # (k,)
        hit = masked == mins[None, :]
        argmins = jnp.min(jnp.where(hit, iota_b, B), axis=0)
        cmin_ref[...] = mins[None, :]
        umin_ref[...] = argmins[None, :]
    else:
        order = order_ref[...]      # (1, k) int32
        enabled = enabled_ref[...]  # (1, k) int32

        def pick(j, carry):
            u_sel, c_sel, ret = carry                    # (1,k),(1,k),(B,1)
            col = jax.lax.dynamic_index_in_dim(
                order, j, 1, keepdims=False)[0]
            c = jax.lax.dynamic_slice(cost, (0, col), (B, 1))
            c = jnp.where(ret, BIG, c)                   # (B, 1)
            m = jnp.min(c)
            u = jnp.min(jnp.where(c == m, iota_b, B))    # first min row
            en = jax.lax.dynamic_index_in_dim(
                enabled, j, 1, keepdims=False)[0] != 0
            act = en & (m < BIG)
            ret = ret | ((iota_b == u) & act)
            iota_k = jax.lax.broadcasted_iota(jnp.int32, (1, k), 1)
            u_sel = jnp.where(iota_k == j, jnp.where(act, u, -1), u_sel)
            c_sel = jnp.where(iota_k == j, jnp.where(act, m, BIG), c_sel)
            return u_sel, c_sel, ret

        u0 = jnp.full((1, k), -1, jnp.int32)
        c0 = jnp.full((1, k), BIG, jnp.int32)
        u_sel, c_sel, _ = jax.lax.fori_loop(0, k, pick, (u0, c0, ret),
                                            unroll=True)
        umin_ref[...] = u_sel
        cmin_ref[...] = c_sel


# padded sketch widths beyond this many words exceed the VMEM budget of the
# gridless kernel (B=1024 × 2048 × 4 B = 8 MiB for the nbr tile alone) —
# wrappers must fall back to the W-gridded kernel above it
SKETCH_KERNEL_MAX_WORDS = 2048


@functools.partial(jax.jit, static_argnames=("greedy", "interpret"))
def sketch_select_kernel(
    nbr_masks: jax.Array,  # (B, Ws) int32, B % 8 == 0, Ws % 128 == 0
    s_masks: jax.Array,    # (k, Ws) int32
    retired: jax.Array,    # (B, 1) int32 (0/1)
    order: jax.Array,      # (1, k) int32 column visit order (greedy mode)
    enabled: jax.Array,    # (1, k) int32 slot gate (greedy mode)
    *,
    greedy: bool,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Returns (u_sel (1, k), c_sel (1, k)) int32 — see ``_sketch_select_kernel``."""
    B, Ws = nbr_masks.shape
    k = s_masks.shape[0]
    if Ws > SKETCH_KERNEL_MAX_WORDS:
        raise ValueError(
            f"sketch width {Ws} words exceeds the VMEM-resident budget "
            f"({SKETCH_KERNEL_MAX_WORDS}); use parsa_select_kernel")
    umin, cmin = pl.pallas_call(
        functools.partial(_sketch_select_kernel, greedy=greedy),
        out_shape=[
            jax.ShapeDtypeStruct((1, k), jnp.int32),
            jax.ShapeDtypeStruct((1, k), jnp.int32),
        ],
        interpret=interpret,
    )(nbr_masks, s_masks, retired, order, enabled)
    return umin, cmin


def _refine_sweep_kernel(words_ref, prev_ref, cost_ref,
                         parts_ref, cost_out_ref):
    """Fused Algorithm 2 cost-update: sweep one V chunk entirely in VMEM.

    words (k, cw) int32 packed need bits; prev (1, C) int32 entering
    assignments (C = 32·cw); cost (1, k) int32.  Emits (parts (1, C),
    cost' (1, k)).  The (k, C) bit tile is expanded once from the packed
    words and the C greedy steps run as a fori_loop over VMEM state — the
    tile, the cost vector, and the growing parts row never leave the core.
    Bit-exact vs ``ref.refine_sweep_ref``.
    """
    k, cw = words_ref.shape
    C = cw * 32
    words = words_ref[...]
    shifts = jax.lax.broadcasted_iota(jnp.int32, (1, 1, 32), 2)
    bits = ((words[:, :, None] >> shifts) & 1).reshape(k, C)   # (k, C)
    nneed = bits.sum(axis=0, dtype=jnp.int32).reshape(1, C)
    prev = prev_ref[...]
    iota_k = jax.lax.broadcasted_iota(jnp.int32, (1, k), 1)
    iota_kc = jax.lax.broadcasted_iota(jnp.int32, (k, 1), 0)
    iota_c = jax.lax.broadcasted_iota(jnp.int32, (1, C), 1)

    def step(j, carry):
        cost, parts = carry                                    # (1,k), (1,C)
        bcol = jax.lax.dynamic_slice(bits, (0, j), (k, 1))     # (k, 1)
        nj = jax.lax.dynamic_slice(nneed, (0, j), (1, 1))[0, 0]
        cur = jax.lax.dynamic_slice(prev, (0, j), (1, 1))[0, 0]
        # retract j's old contribution: cost_cur −= −1 + (n_j − u_{cur,j})
        bitc = jnp.sum(jnp.where(iota_kc == cur, bcol, 0))
        retract = jnp.where(cur >= 0, 1 - nj + bitc, 0)
        cost = cost + jnp.where(iota_k == cur, retract, 0)
        # pick the needing partition with minimum cost (first on ties)
        masked = jnp.where(jnp.transpose(bcol) > 0, cost, BIG)  # (1, k)
        m = jnp.min(masked)
        xi = jnp.min(jnp.where(masked == m, iota_k, k))
        act = nj > 0
        # line 8: cost_ξ += −1 + (n_j − 1)
        cost = cost + jnp.where((iota_k == xi) & act, nj - 2, 0)
        parts = jnp.where(iota_c == j, jnp.where(act, xi, -1), parts)
        return cost, parts

    cost0 = cost_ref[...]
    parts0 = jnp.full((1, C), -1, jnp.int32)
    cost, parts = jax.lax.fori_loop(0, C, step, (cost0, parts0))
    parts_ref[...] = parts
    cost_out_ref[...] = cost


@functools.partial(jax.jit, static_argnames=("interpret",))
def refine_sweep_kernel(
    tile_words: jax.Array,  # (k, cw) int32, k % 8 == 0
    prev: jax.Array,        # (1, C) int32, C == 32·cw
    cost: jax.Array,        # (1, k) int32
    *,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Returns (parts (1, C), cost' (1, k)) int32 — see ``_refine_sweep_kernel``."""
    k, cw = tile_words.shape
    C = cw * 32
    parts, cost_out = pl.pallas_call(
        _refine_sweep_kernel,
        out_shape=[
            jax.ShapeDtypeStruct((1, C), jnp.int32),
            jax.ShapeDtypeStruct((1, k), jnp.int32),
        ],
        interpret=interpret,
    )(tile_words, prev, cost)
    return parts, cost_out


def _union_delta_kernel(new_ref, old_ref, union_ref, delta_ref):
    new = new_ref[...]
    old = old_ref[...]
    union_ref[...] = new | old
    delta_ref[...] = new & ~old


@functools.partial(jax.jit, static_argnames=("bw", "interpret"))
def packed_union_delta_kernel(
    new_masks: jax.Array,  # (k, W) int32 packed words, W % bw == 0
    old_masks: jax.Array,  # (k, W) int32
    *,
    bw: int = 512,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Fused lattice ops of the Alg-4 server line 9 on packed words:
    union = new | old (the OR-merge) and delta = new & ~old (the worker's
    delta-encoded push) in one VMEM pass over the word axis — the wire
    format shared by the host simulation and the shard_map backend."""
    k, W = new_masks.shape
    grid = (W // bw,)
    union, delta = pl.pallas_call(
        _union_delta_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((k, bw), lambda w: (0, w)),
            pl.BlockSpec((k, bw), lambda w: (0, w)),
        ],
        out_specs=[
            pl.BlockSpec((k, bw), lambda w: (0, w)),
            pl.BlockSpec((k, bw), lambda w: (0, w)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k, W), jnp.int32),
            jax.ShapeDtypeStruct((k, W), jnp.int32),
        ],
        interpret=interpret,
    )(new_masks, old_masks)
    return union, delta


@functools.partial(jax.jit,
                   static_argnames=("greedy", "bw", "interpret"))
def parsa_select_kernel(
    nbr_masks: jax.Array,  # (B, W) int32, B % 8 == 0, W % bw == 0
    s_masks: jax.Array,    # (k, W) int32
    retired: jax.Array,    # (B, 1) int32 (0/1)
    order: jax.Array,      # (1, k) int32 column visit order (greedy mode)
    enabled: jax.Array,    # (1, k) int32 slot gate (greedy mode)
    *,
    greedy: bool,
    bw: int = 512,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Returns (u_sel (1, k), c_sel (1, k)) int32 — see module docstring."""
    B, W = nbr_masks.shape
    k = s_masks.shape[0]
    grid = (W // bw,)
    umin, cmin = pl.pallas_call(
        functools.partial(_select_kernel, greedy=greedy),
        grid=grid,
        in_specs=[
            pl.BlockSpec((B, bw), lambda w: (0, w)),
            pl.BlockSpec((k, bw), lambda w: (0, w)),
            pl.BlockSpec((B, 1), lambda w: (0, 0)),
            pl.BlockSpec((1, k), lambda w: (0, 0)),
            pl.BlockSpec((1, k), lambda w: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, k), lambda w: (0, 0)),
            pl.BlockSpec((1, k), lambda w: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, k), jnp.int32),
            jax.ShapeDtypeStruct((1, k), jnp.int32),
        ],
        scratch_shapes=[pltpu.VMEM((B, k), jnp.int32)],
        interpret=interpret,
    )(nbr_masks, s_masks, retired, order, enabled)
    return umin, cmin
