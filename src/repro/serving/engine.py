"""Request-driven PS serving engine (ROADMAP item 1: close the paper's
end-to-end loop).

The engine drives k ``PSCluster`` shards through batched
pull → compute → push steps for a multi-tenant request mix.  One request
is one batched step on its *home* worker:

  pull    — the request's working set (the features its example rows
            touch), value-delta cached, priced per source link by the
            ``BandwidthModel`` and issued as a non-blocking
            ``PullHandle`` (the device future from ``ml/ps.py``);
  compute — ONE jitted dispatch: margins/loss, smooth gradient, and the
            masked proximal update on the worker's (≤ τ stale) weight
            view — the DBPG step, served;
  push    — gradient entries metered to their owning servers (key
            caching, compression — ``PSCluster.meter_push``), then the
            update commits.

In async mode (``prefetch=True``) the engine issues request t+1's pull
*before* blocking on request t's — double buffering, so the next
transfer ticks behind the current compute.  The buffered view is then
one commit stale: τ = 1, the §4.3 bounded-delay model.  Overlap is
measured, never assumed: ``PullHandle.block()`` sleeps out only the
transfer time still outstanding and ``jax.block_until_ready`` fences the
compute, so ``blocked_s`` vs ``wire_s`` is wall-clock evidence.

Fault handling composes the existing layers: a ``ChaosSchedule`` kills /
straggles shards mid-serve; a source link that cannot deliver within its
``RetryPolicy`` deadlines is dropped for the step and the worker serves
from its stale buffer (bounded-staleness fallback).  The per-link
``CircuitBreaker`` (``runtime.fault``) opens after the first burnt
budget — the link is skipped at zero cost — and *half-opens* after a
cooldown: one trial pull probes the link, so a recovered shard returns
to direct serving without operator intervention.  With an
``ElasticSession`` attached, kills trigger a warm repair whose new
placement reaches the router through ``PSCluster.placement_version``.

Closed-loop mode (PR 8) attaches an ``SLOAutoscaler``: the source keeps
a *virtual clock* — ``vtime`` advances ``service_model_s`` per engine
slot, and a second, virtual ``LinkClock`` books every pull/push on it —
so each request has a deterministic modeled latency
(wire + queue + retry penalty + service time) independent of wall-clock
jitter.  A ``TelemetryBus`` windows those latencies; every
``decide_every`` slots the autoscaler reads a snapshot and may grow /
shrink / repair / rebalance through the elastic session, with each
committed op followed by ``tau_escalation`` slots of fully-stale serving
(widened §4.3 staleness while the migration settles).  Under overload
the engine degrades instead of falling over: ``max_backlog_s`` bounds
each home's virtual NIC backlog, shedding lowest-weight tenants first
(the threshold scales with tenant weight) with every drop metered per
tenant.  Decisions replay bit-identically because nothing they read
comes from the wall clock.
"""
from __future__ import annotations

import dataclasses
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..core.jax_partition import _count_dispatch, annotate_dispatch
from ..ml.dbpg import soft_threshold
from ..ml.lr import SparseBatch, lr_grad, _margins
from ..ml.ps import PSCluster
from ..runtime.fault import CircuitBreaker, RetryPolicy
from .latency import BandwidthModel, LatencyRecorder, LinkClock, RequestRecord
from .prefetch import OverlapMeter
from .router import Router
from .telemetry import TelemetryBus

__all__ = ["Request", "ZipfWorkload", "RequestMix", "ServingConfig",
           "PSRequestSource", "ServingEngine"]


@dataclasses.dataclass(frozen=True)
class ZipfWorkload:
    """One tenant: Zipf-skewed batches against its home shard's rows."""

    name: str
    batch: int = 256
    zipf_s: float = 1.1
    hot_offset: int = 0      # rotates the pool: distinct hot set per tenant
    weight: float = 1.0

    def __post_init__(self):
        if self.batch < 1:
            raise ValueError(f"batch must be >= 1, got {self.batch}")
        if self.weight <= 0:
            raise ValueError(f"weight must be > 0, got {self.weight}")


@dataclasses.dataclass(frozen=True)
class RequestMix:
    """Weighted tenant mix; ``sample`` draws the next request's tenant."""

    workloads: tuple[ZipfWorkload, ...]

    def __post_init__(self):
        if not self.workloads:
            raise ValueError("need at least one workload")

    def sample(self, rng: np.random.Generator) -> ZipfWorkload:
        w = np.array([wl.weight for wl in self.workloads])
        return self.workloads[int(rng.choice(len(self.workloads),
                                             p=w / w.sum()))]


@dataclasses.dataclass(frozen=True)
class ServingConfig:
    prefetch: bool = True          # async double-buffered pulls
    bandwidth: float | None = None  # None → the cluster's modeled link
    retry: RetryPolicy = dataclasses.field(default_factory=RetryPolicy)
    update: bool = True            # online DBPG update per request
    warmup: int = 3                # requests excluded from the stats
    pad_multiple: int = 2048       # nnz pad bucket (bounds jit variants)
    seed: int = 0
    # --- closed-loop knobs (PR 8); defaults preserve PR 7 behavior ----
    service_model_s: float = 2e-3  # virtual-clock arrival interval / slot
    max_backlog_s: float | None = None  # admission bound (None = off)
    tau_escalation: int = 0        # fully-stale slots after an elastic op
    breaker_cooldown_s: float = 0.05    # circuit half-open probe delay
    breaker_max_cooldown_s: float = 2.0  # decorrelated-jitter backoff cap
    window_requests: int | None = None  # recorder sliding-window size
    # observability hook (repro.obs.Observability); None = off — every
    # instrumented site is behind an `obs is None` check.  Excluded from
    # equality/hash so the frozen config stays comparable.
    obs: object = dataclasses.field(default=None, compare=False,
                                    repr=False)


@dataclasses.dataclass
class Request:
    tenant: str
    home: int
    rows: np.ndarray
    batch: SparseBatch
    need: np.ndarray          # (V,) bool working set
    examples: int
    tokens: int               # nnz processed (text: words)


@functools.partial(jax.jit, static_argnames=("lr", "lam", "update"))
def _serve_step(batch: SparseBatch, w: jax.Array, need: jax.Array,
                lr: float, lam: float, update: bool):
    """One served DBPG step: loss + smooth gradient + masked prox update.

    The update touches only the request's working set — the server slice
    semantics of ``PSCluster.step`` restricted to the coordinates this
    worker may push."""
    m = _margins(batch, w)
    loss = jnp.sum(jnp.logaddexp(0.0, -m))
    g = lr_grad(batch, w)
    if update:
        new_w = jnp.where(need, soft_threshold(w - lr * g, lr * lam), w)
    else:
        new_w = w
    return new_w, g, loss


class PSRequestSource:
    """Generates, prices, and commits PS requests for the engine."""

    def __init__(self, cluster: PSCluster, mix: RequestMix,
                 config: ServingConfig | None = None, chaos=None,
                 elastic=None, autoscaler=None, telemetry=None):
        self.cluster = cluster
        self.mix = mix
        self.config = config if config is not None else ServingConfig()
        self.chaos = chaos
        self.elastic = elastic
        self.autoscaler = autoscaler
        self.router = Router(cluster)
        self.bw = BandwidthModel(self.config.bandwidth
                                 if self.config.bandwidth is not None
                                 else cluster.bandwidth)
        self.rng = np.random.default_rng(self.config.seed)
        self.link = LinkClock(cluster.k)      # wall-clock NIC bookings
        self.vlink = LinkClock(cluster.k)     # virtual-clock NIC bookings
        self.vtime = 0.0                      # deterministic request clock
        self.straggle = np.ones(cluster.k, np.float64)
        self.dead: set[int] = set()
        self.suspect: set[int] = set()   # links past their retry budget
        self.breaker = CircuitBreaker(
            cluster.k, cooldown_s=self.config.breaker_cooldown_s,
            max_cooldown_s=self.config.breaker_max_cooldown_s,
            seed=self.config.seed)
        self.load_factor = 1.0                # burst batch multiplier
        self.events: list[tuple[int, str, int]] = []
        self._pending_repairs: set[int] = set()
        self._tau_until = -1                  # τ-escalation deadline (slot)
        if autoscaler is not None and telemetry is None:
            telemetry = TelemetryBus(
                cluster.k,
                window_requests=autoscaler.config.window_requests)
        self.telemetry: TelemetryBus | None = telemetry
        obs = self.config.obs
        if obs is None and autoscaler is not None:
            obs = getattr(autoscaler.config, "obs", None)
        self.obs = obs
        if obs is not None and elastic is not None:
            elastic.obs = obs   # one hook covers the whole closed loop

    # ----------------------------------------------------------- chaos
    def on_step(self, t: int) -> None:
        # the virtual clock: requests arrive every service_model_s, full
        # stop — nothing downstream of a decision reads the wall clock
        self.vtime = t * self.config.service_model_s
        if self.obs is not None:
            self.obs.tracer.set_time(self.vtime)
        if self.chaos is None:
            return
        for ev in self.chaos.at(t):
            self._apply_event(ev, t)

    def _apply_event(self, ev, t: int) -> None:
        k = self.cluster.k
        if ev.kind == "kill":
            m = ev.machine % k
            if self.elastic is not None and self.autoscaler is None:
                # warm repair under load: re-place, re-shard the cluster,
                # and let the router pick it up via placement_version
                op = self.elastic.repair(m)
                self._sync_placement(op)
                self._sync_fleet()
                self.dead.discard(m)
                self.suspect.discard(m)
                self.breaker.reset(m)
                self._record_op(op, t)
            else:
                # closed loop (or no elastic): the controller discovers
                # the loss through its own circuit breaker and repairs
                self.dead.add(m)
        elif ev.kind == "add":
            if self.elastic is not None:
                op = self.elastic.grow_k(force=True)
                self._sync_placement(op)
                self._sync_fleet()
                self._record_op(op, t)
        elif ev.kind == "straggle":
            self.straggle[ev.machine % k] = ev.factor
        elif ev.kind == "recover":
            m = ev.machine % k
            self.straggle[m] = 1.0
            self.dead.discard(m)
            # deliberately NOT closing the circuit here: the half-open
            # probe must rediscover the link — that's the honest path a
            # real fleet has (nobody tells serving the shard came back)
        elif ev.kind == "burst":
            self.load_factor = float(ev.factor)
        m = -1 if ev.machine is None else ev.machine % max(k, 1)
        self.events.append((t, ev.kind, m))
        if self.obs is not None:
            self.obs.record(
                "chaos", step=t, v=self.vtime,
                data={"kind": ev.kind,
                      "machine": None if ev.machine is None else m,
                      "factor": getattr(ev, "factor", None)})

    def _record_op(self, op, t: int) -> None:
        """Put one elastic op on the flight-recorder timeline, with its
        triggering telemetry snapshot when the closed loop supplied one."""
        if self.obs is None or op is None:
            return
        traffic = getattr(op, "traffic", None)
        data = {"kind": op.kind, "committed": bool(op.committed),
                "machine": op.machine, "k_before": op.k_before,
                "k_after": op.k_after, "moved_u": int(op.moved_u),
                "mode": op.mode,
                "migration_bytes": (int(traffic.migration_bytes)
                                    if traffic is not None else 0)}
        snap = getattr(op, "telemetry", None)
        if snap is not None:
            data["trigger_p99_ms"] = float(snap.p99_ms)
            data["trigger_step"] = int(snap.step)
        self.obs.record("elastic_op", step=t, v=self.vtime, data=data)

    def _sync_fleet(self) -> None:
        k = self.cluster.k
        if self.straggle.shape[0] < k:
            self.straggle = np.concatenate(
                [self.straggle, np.ones(k - self.straggle.shape[0])])
        else:
            self.straggle = self.straggle[:k]
        self.link.resize(k)
        self.vlink.resize(k)
        self.breaker.resize(k)
        if self.telemetry is not None:
            self.telemetry.resize(k)
        self.dead = {m for m in self.dead if m < k}
        self.suspect = {m for m in self.suspect if m < k}
        self._pending_repairs = {m for m in self._pending_repairs if m < k}
        self.router.refresh(self.cluster)

    def _sync_placement(self, op=None) -> dict:
        """Push the elastic placement into the cluster *preserving* weight
        ownership: ``ElasticSession.sync_cluster``'s default re-stripes
        ``parts_v`` round-robin, which would destroy the feature locality
        the partitioner bought.  Instead the current owners are remapped
        per op — shrink retires machine ``op.partner`` into ``op.machine``;
        grow moves the features the split handed to the new machine
        (present in its packed mask, absent from the shrunk source's)."""
        cluster = self.cluster
        owner = cluster.owner.copy().astype(np.int32)
        if op is not None and getattr(op, "committed", False):
            if op.kind == "shrink" and op.partner >= 0:
                j = op.partner
                owner[owner == j] = op.machine
                owner[owner > j] -= 1
            elif op.kind == "grow" and op.partner >= 0:
                from ..kernels.parsa_cost import unpack_bitmask
                masks = self.elastic.stream.arena.masks_np(logical=False)
                num_v = cluster.graph.num_v
                pair = unpack_bitmask(
                    masks[[op.machine, op.partner]], num_v)
                move = (owner == op.machine) & pair[1] & ~pair[0]
                owner[move] = op.partner
        owner = np.minimum(owner, self.elastic.k - 1)
        return self.elastic.sync_cluster(cluster, parts_v=owner)

    # -------------------------------------------------------- requests
    def next_request(self, t: int) -> Request:
        self.router.refresh(self.cluster)
        wl = self.mix.sample(self.rng)
        home = self.router.next_home(self.dead)
        batch_size = max(1, int(round(wl.batch * self.load_factor)))
        rows = self.router.sample_rows(home, batch_size, self.rng,
                                       zipf_s=wl.zipf_s,
                                       hot_offset=wl.hot_offset)
        g = self.cluster.graph
        indptr = np.asarray(g.u_indptr, np.int64)
        nnz = int((indptr[rows + 1] - indptr[rows]).sum())
        pad = self.config.pad_multiple
        pad_to = max(pad, -(-nnz // pad) * pad)
        batch = SparseBatch.from_graph(g, rows, self.cluster._labels,
                                       pad_to=pad_to)
        need = np.zeros(g.num_v, bool)
        need[np.asarray(batch.col_ids)[:nnz]] = True
        return Request(tenant=wl.name, home=home, rows=rows, batch=batch,
                       need=need, examples=rows.size, tokens=nnz)

    # ------------------------------------------------------- admission
    def admit(self, req: Request) -> bool:
        """Bounded per-home queue: shed when the home's *virtual* NIC
        backlog exceeds ``max_backlog_s`` scaled by the tenant's relative
        weight — so as backlog climbs, the lowest-weight tenants are shed
        first and the heaviest tenant holds out to the full bound.
        Decided AFTER ``next_request`` so RNG consumption is identical
        with and without shedding (determinism contract)."""
        limit = self.config.max_backlog_s
        if limit is None:
            return True
        weights = {wl.name: wl.weight for wl in self.mix.workloads}
        wmax = max(weights.values())
        scaled = limit * weights.get(req.tenant, wmax) / wmax
        return self.vlink.backlog(req.home, self.vtime) <= scaled

    def note_shed(self, req: Request) -> None:
        if self.telemetry is not None:
            self.telemetry.observe_shed(req.tenant)
        if self.obs is not None:
            step = int(round(self.vtime / self.config.service_model_s))
            self.obs.record(
                "shed", step=step, v=self.vtime, tenant=req.tenant,
                home=req.home,
                backlog_s=float(self.vlink.backlog(req.home, self.vtime)))

    def issue(self, req: Request, t: int):
        """Price and issue the request's pull; returns a ``PullHandle``.

        With obs attached, opens the ``request`` root span (pushed on the
        tracer stack so the PS/dispatch instants emitted inside nest under
        it); the span's children are finalized retrospectively in
        ``ServingEngine._serve_one`` from the handle's modeled breakdown.
        """
        if self.obs is None:
            return self._issue(req, t)
        tracer = self.obs.tracer
        sp = tracer.begin("request", v_start=self.vtime,
                          track=f"home{req.home}", tenant=req.tenant,
                          step=t, examples=req.examples)
        tracer.push(sp)
        try:
            handle = self._issue(req, t)
        finally:
            tracer.pop()
        handle._span = sp
        return handle

    def _issue(self, req: Request, t: int):
        plan = self.cluster.plan_pull(req.home, need=req.need)
        secs = self.bw.per_source(plan.src_bytes, req.home, self.straggle)
        retry = self.config.retry
        exclude: set[int] = set()
        penalty = 0.0   # timeout clocks run concurrently with the wire
        vnow = self.vtime
        src_times = np.full(self.cluster.k, np.nan)
        escalated = t < self._tau_until
        for j in np.flatnonzero(plan.src_bytes):
            j = int(j)
            if j == req.home:
                continue
            if escalated:
                # widened bounded staleness while a repair/migration is
                # in flight: serve fully stale, burn no retry budgets
                exclude.add(j)
                continue
            if not self.breaker.allow(j, vnow):
                exclude.add(j)       # circuit open: skip at zero cost
                continue
            link_s = float("inf") if j in self.dead else float(secs[j])
            delivered, spent = retry.admit(link_s)
            penalty = max(penalty, spent)
            was_open = (self.obs is not None
                        and self.breaker.state(j) != "closed")
            newly_opened = self.breaker.record(j, delivered, vnow)
            if newly_opened and self.obs is not None:
                self.obs.record("breaker_open", step=t, v=vnow, machine=j)
            if delivered:
                if was_open:
                    self.obs.record("breaker_close", step=t, v=vnow,
                                    machine=j)
                self.suspect.discard(j)
                if plan.src_bytes[j] > 0:
                    # observed delivery slowdown vs the bytes/bandwidth
                    # baseline — the telemetry EWMA's straggle evidence
                    src_times[j] = (secs[j] * self.bw.bandwidth
                                    / float(plan.src_bytes[j]))
            else:
                # retry budget exhausted: bounded-staleness fallback —
                # this source's entries stay stale in the buffer
                exclude.add(j)
                self.suspect.add(j)
                if newly_opened and self.autoscaler is not None:
                    # repair cue: the closed loop replaces the shard at
                    # the end of this slot instead of waiting for an op
                    self._pending_repairs.add(j)
        wire = self.bw.ingress_seconds(plan.src_bytes, req.home,
                                       self.straggle, exclude)
        # deterministic queueing: the virtual link clock accumulates the
        # modeled backlog the autoscaler and admission control act on
        vdone = self.vlink.acquire(req.home, vnow, wire)
        vqueue = vdone - vnow - wire
        # wall-clock booking mirrors it: a still-draining push (or a
        # previous pull) pushes this transfer's completion out for real
        now = time.perf_counter()
        done = self.link.acquire(req.home, now, wire)
        _count_dispatch("serving_pull", nbytes=int(plan.total_bytes),
                        home=req.home)
        handle = self.cluster.pull_nowait(plan, frozenset(exclude),
                                          wire_s=wire, wait_s=penalty,
                                          queue_s=done - now - wire)
        handle.modeled_s = (wire + penalty + vqueue
                            + self.config.service_model_s)
        handle.vqueue_s = vqueue
        handle._src_times = src_times
        return handle

    def observe_request(self, req: Request, handle, modeled_s: float,
                        measured_s: float) -> None:
        if self.telemetry is None:
            return
        self.telemetry.observe(modeled_s, measured_s,
                               getattr(handle, "_src_times", None))

    # ------------------------------------------------------ closed loop
    def _snapshot(self, t: int):
        k = self.cluster.k
        return self.telemetry.snapshot(
            step=t,
            occupancy=[self.vlink.backlog(m, self.vtime)
                       for m in range(k)],
            footprint=self.cluster.need.sum(axis=1),
            sizes=[r.size for r in self.cluster.rows],
            open_circuits=self.breaker.open_links(),
            load_factor=self.load_factor)

    def _commit_op(self, op, t: int) -> None:
        self._sync_placement(op)
        self._sync_fleet()
        self._tau_until = t + 1 + self.config.tau_escalation

    def after_slot(self, t: int) -> None:
        """End-of-slot hook: immediate repair on circuit-open, then (every
        ``decide_every`` slots) one autoscaler decision."""
        if (self.elastic is not None and self.telemetry is not None
                and self._pending_repairs):
            for m in sorted(self._pending_repairs):
                if m >= self.cluster.k or m not in self.dead:
                    continue
                snap = self._snapshot(t)
                op = self.elastic.repair(m)
                op.telemetry = snap
                self._commit_op(op, t)
                self._record_op(op, t)
                self.breaker.reset(m)
                self.suspect.discard(m)
                self.dead.discard(m)
                if self.autoscaler is not None:
                    self.autoscaler.note_repair(snap, m)
            self._pending_repairs.clear()
        if self.autoscaler is None or self.telemetry is None:
            return
        if (t + 1) % self.autoscaler.config.decide_every:
            return
        snap = self._snapshot(t)
        decision = self.autoscaler.decide(snap)
        if self.obs is not None:
            slo = getattr(self.autoscaler.config, "slo_ms", None)
            self.obs.record(
                "window", step=t, v=self.vtime,
                window=len(self.autoscaler.decisions) - 1,
                p99_ms=float(snap.p99_ms),
                slo_ms=None if slo is None else float(slo),
                within=(slo is None or snap.p99_ms <= slo),
                action=decision.action, reason=decision.reason,
                k=int(snap.k), load_factor=float(snap.load_factor))
        if decision.action == "grow" and self.elastic is not None:
            self.autoscaler.approve("grow")
            op = self.elastic.grow_k(target=decision.target)
            op.telemetry = snap
            if op.committed:
                self._commit_op(op, t)
            self._record_op(op, t)
        elif decision.action == "shrink" and self.elastic is not None:
            self.autoscaler.approve("shrink")
            op = self.elastic.shrink_k()
            op.telemetry = snap
            if op.committed:
                self._commit_op(op, t)
            self._record_op(op, t)
        elif decision.action == "rebalance":
            self.router.set_weights(np.asarray(snap.speeds))

    # --------------------------------------------------------- serving
    def compute(self, req: Request, payload: jax.Array):
        cfg = self.cluster.cfg
        _count_dispatch("serving_compute", nbytes=int(payload.nbytes),
                        tokens=req.tokens)
        cache_size = getattr(_serve_step, "_cache_size", None)
        before = cache_size() if cache_size is not None else None
        out = _serve_step(req.batch, payload, jnp.asarray(req.need),
                          lr=cfg.lr, lam=cfg.lam,
                          update=self.config.update)
        if before is not None:
            # a grown jit cache means this pad bucket compiled fresh —
            # the label that separates steady-state from compile stalls
            annotate_dispatch(cache_miss=cache_size() > before)
        return out

    def commit(self, req: Request, out, t: int) -> dict:
        new_w, g, loss = out
        if req.home >= self.cluster.k:
            # the home machine retired mid-flight (an elastic shrink
            # landed between issue and commit): the weight update still
            # applies, but there is no NIC left to meter the push on
            if self.config.update:
                self.cluster.commit_weights(new_w)
            return {"loss": float(loss), "push_inner_bytes": 0,
                    "push_inter_bytes": 0, "push_wire_s": 0.0}
        mask = req.need & (np.asarray(g) != 0)
        push = self.cluster.meter_push(req.home, mask)
        # push is fire-and-forget (the τ model absorbs its latency) but
        # still drains real bandwidth: book the home NIC so the machine's
        # next pull queues behind it instead of pretending it was free
        push_wire = (push["inter_bytes"] / self.bw.bandwidth
                     * float(self.straggle[req.home]))
        if push_wire > 0:
            self.link.acquire(req.home, time.perf_counter(), push_wire)
            self.vlink.acquire(req.home, self.vtime, push_wire)
        if self.config.update:
            self.cluster.commit_weights(new_w)
        return {"loss": float(loss),
                "push_inner_bytes": push["inner_bytes"],
                "push_inter_bytes": push["inter_bytes"],
                "push_wire_s": push_wire}


class ServingEngine:
    """The event loop: sync (pull → compute → push per request) or async
    (double-buffered — issue pull t+1, then block on pull t).  Slots the
    admission controller sheds are served as no-ops: the virtual clock
    still advances, so a shed burst drains the backlog it was shed for."""

    def __init__(self, source, prefetch: bool | None = None,
                 warmup: int | None = None):
        self.source = source
        src_cfg = getattr(source, "config", None)
        self.prefetch = (src_cfg.prefetch if prefetch is None and src_cfg
                         else bool(prefetch))
        self.warmup = (src_cfg.warmup if warmup is None and src_cfg
                       else int(warmup or 0))
        self.recorder = LatencyRecorder(
            window_requests=getattr(src_cfg, "window_requests", None))
        self.overlap = OverlapMeter()
        self.obs = (getattr(source, "obs", None)
                    or getattr(src_cfg, "obs", None))

    def _produce(self, t):
        """Generate + admit + issue slot ``t``; ``None`` when shed."""
        src = self.source
        src.on_step(t)
        req = src.next_request(t)
        admit = getattr(src, "admit", None)
        if admit is not None and not admit(req):
            self.recorder.add_shed(req.tenant)
            note = getattr(src, "note_shed", None)
            if note is not None:
                note(req)
            return None
        return (req, src.issue(req, t))

    def run(self, num_requests: int) -> dict:
        if self.obs is None:
            return self._run_loop(num_requests)
        # installed for the run: the deep layers (PS pulls, router
        # refreshes, dispatches) emit instants into this tracer without
        # holding a reference to it
        with self.obs.tracer.installed():
            return self._run_loop(num_requests)

    def _run_loop(self, num_requests: int) -> dict:
        rec, meter = self.recorder, self.overlap
        src = self.source
        after = getattr(src, "after_slot", None)
        wall0 = None
        if self.prefetch:
            cur = self._produce(0) if num_requests > 0 else None
            for t in range(num_requests):
                if t == self.warmup:
                    wall0 = time.perf_counter()
                # double buffer: issue pull t+1 BEFORE blocking on
                # pull t — its wire time ticks behind this step's
                # compute; the view it returns is ≤ 1 commit stale
                nxt = (self._produce(t + 1)
                       if t + 1 < num_requests else None)
                if cur is not None:
                    req, handle = cur
                    self._serve_one(req, handle, t, rec, meter)
                if after is not None:
                    after(t)
                cur = nxt
        else:
            for t in range(num_requests):
                if t == self.warmup:
                    wall0 = time.perf_counter()
                cur = self._produce(t)
                if cur is not None:
                    req, handle = cur
                    self._serve_one(req, handle, t, rec, meter)
                if after is not None:
                    after(t)
        wall_s = (time.perf_counter() - wall0) if wall0 is not None else 0.0
        out = rec.summary(wall_s=wall_s)
        out["mode"] = "async" if self.prefetch else "sync"
        out["overlap"] = meter.as_dict()
        return out

    def _serve_one(self, req, handle, t, rec, meter) -> None:
        src = self.source
        tb = time.perf_counter()
        payload = handle.block()
        blocked = time.perf_counter() - tb
        tc = time.perf_counter()
        out = src.compute(req, payload)
        jax.block_until_ready(out)
        compute = time.perf_counter() - tc
        stats = src.commit(req, out, t)
        end = time.perf_counter()
        queue = getattr(handle, "queue_s", 0.0)
        measured = end - handle.issued_at
        modeled = getattr(handle, "modeled_s",
                          handle.wire_s + handle.wait_s + queue)
        rec.add(RequestRecord(
            tenant=req.tenant, step=t, home=req.home,
            examples=req.examples, tokens=req.tokens,
            latency_s=measured,
            wire_s=handle.wire_s, wait_s=handle.wait_s,
            blocked_s=blocked, compute_s=compute,
            fresh_entries=handle.fresh_entries,
            stale_entries=handle.stale_entries,
            pull_inter_bytes=handle.inter_bytes,
            push_inter_bytes=stats.get("push_inter_bytes", 0),
            warmup=t < self.warmup,
            queue_s=queue, modeled_s=modeled))
        observe = getattr(src, "observe_request", None)
        if observe is not None:
            observe(req, handle, modeled, measured)
        sp = getattr(handle, "_span", None)
        if sp is not None:
            self._finish_request_span(sp, handle, stats, blocked, compute,
                                      measured)
        if t >= self.warmup:
            meter.add(handle.wire_s + queue, handle.wait_s, blocked,
                      compute)

    def _finish_request_span(self, sp, handle, stats, blocked, compute,
                             measured) -> None:
        """Finalize the request span opened at issue time: children at
        explicit offsets from the handle's *modeled* breakdown (wire,
        retry penalty, virtual queue, service slot, push wire), measured
        wall times riding along as replay-variant evidence."""
        src_cfg = getattr(self.source, "config", None)
        svc = getattr(src_cfg, "service_model_s", 0.0)
        wire, wait = handle.wire_s, handle.wait_s
        vq = getattr(handle, "vqueue_s", 0.0)
        pull_end = wire + wait + vq
        push_wire = stats.get("push_wire_s", 0.0)
        sp.set(v_dur=pull_end + svc + push_wire, wall_s=measured,
               fresh=handle.fresh_entries, stale=handle.stale_entries)
        pull = sp.child("pull", 0.0, pull_end, wall_s=blocked,
                        inter_bytes=handle.inter_bytes)
        if wire > 0:
            pull.child("wire", 0.0, wire)
        if wait > 0:
            pull.child("retry", wire, wait)
        if vq > 0:
            pull.child("queue", wire + wait, vq)
        sp.child("compute", pull_end, svc, wall_s=compute,
                 loss=stats.get("loss"))
        sp.child("push", pull_end + svc, push_wire,
                 inter_bytes=stats.get("push_inter_bytes", 0))
