"""Request-driven PS serving engine (ROADMAP item 1: close the paper's
end-to-end loop).

The engine drives k ``PSCluster`` shards through batched
pull → compute → push steps for a multi-tenant request mix.  One request
is one batched step on its *home* worker:

  pull    — the request's working set (the features its example rows
            touch), value-delta cached, priced per source link by the
            ``BandwidthModel`` and issued as a non-blocking
            ``PullHandle`` (the device future from ``ml/ps.py``);
  compute — ONE jitted dispatch: margins/loss, smooth gradient, and the
            masked proximal update on the worker's (≤ τ stale) weight
            view — the DBPG step, served;
  push    — gradient entries metered to their owning servers (key
            caching, compression — ``PSCluster.meter_push``), then the
            update commits.

In async mode (``prefetch=True``) the engine issues request t+1's pull
*before* blocking on request t's — double buffering, so the next
transfer ticks behind the current compute.  The buffered view is then
one commit stale: τ = 1, the §4.3 bounded-delay model.  Overlap is
measured, never assumed: ``PullHandle.block()`` sleeps out only the
transfer time still outstanding and ``jax.block_until_ready`` fences the
compute, so ``blocked_s`` vs ``wire_s`` is wall-clock evidence.

Fault handling composes the existing layers: a ``ChaosSchedule`` kills /
straggles shards mid-serve; a source link that cannot deliver within its
``RetryPolicy`` deadlines is dropped for the step and the worker serves
from its stale buffer (bounded-staleness fallback) — after the first
timeout the link is *suspected* and skipped at zero cost until it
recovers.  With an ``ElasticSession`` attached, kills instead trigger a
warm repair whose new placement reaches the router through
``PSCluster.placement_version``.
"""
from __future__ import annotations

import dataclasses
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..core.jax_partition import _count_dispatch
from ..ml.dbpg import soft_threshold
from ..ml.lr import SparseBatch, lr_grad, _margins
from ..ml.ps import PSCluster
from ..runtime.fault import RetryPolicy
from .latency import BandwidthModel, LatencyRecorder, LinkClock, RequestRecord
from .prefetch import OverlapMeter
from .router import Router

__all__ = ["Request", "ZipfWorkload", "RequestMix", "ServingConfig",
           "PSRequestSource", "ServingEngine"]


@dataclasses.dataclass(frozen=True)
class ZipfWorkload:
    """One tenant: Zipf-skewed batches against its home shard's rows."""

    name: str
    batch: int = 256
    zipf_s: float = 1.1
    hot_offset: int = 0      # rotates the pool: distinct hot set per tenant
    weight: float = 1.0

    def __post_init__(self):
        if self.batch < 1:
            raise ValueError(f"batch must be >= 1, got {self.batch}")
        if self.weight <= 0:
            raise ValueError(f"weight must be > 0, got {self.weight}")


@dataclasses.dataclass(frozen=True)
class RequestMix:
    """Weighted tenant mix; ``sample`` draws the next request's tenant."""

    workloads: tuple[ZipfWorkload, ...]

    def __post_init__(self):
        if not self.workloads:
            raise ValueError("need at least one workload")

    def sample(self, rng: np.random.Generator) -> ZipfWorkload:
        w = np.array([wl.weight for wl in self.workloads])
        return self.workloads[int(rng.choice(len(self.workloads),
                                             p=w / w.sum()))]


@dataclasses.dataclass(frozen=True)
class ServingConfig:
    prefetch: bool = True          # async double-buffered pulls
    bandwidth: float | None = None  # None → the cluster's modeled link
    retry: RetryPolicy = dataclasses.field(default_factory=RetryPolicy)
    update: bool = True            # online DBPG update per request
    warmup: int = 3                # requests excluded from the stats
    pad_multiple: int = 2048       # nnz pad bucket (bounds jit variants)
    seed: int = 0


@dataclasses.dataclass
class Request:
    tenant: str
    home: int
    rows: np.ndarray
    batch: SparseBatch
    need: np.ndarray          # (V,) bool working set
    examples: int
    tokens: int               # nnz processed (text: words)


@functools.partial(jax.jit, static_argnames=("lr", "lam", "update"))
def _serve_step(batch: SparseBatch, w: jax.Array, need: jax.Array,
                lr: float, lam: float, update: bool):
    """One served DBPG step: loss + smooth gradient + masked prox update.

    The update touches only the request's working set — the server slice
    semantics of ``PSCluster.step`` restricted to the coordinates this
    worker may push."""
    m = _margins(batch, w)
    loss = jnp.sum(jnp.logaddexp(0.0, -m))
    g = lr_grad(batch, w)
    if update:
        new_w = jnp.where(need, soft_threshold(w - lr * g, lr * lam), w)
    else:
        new_w = w
    return new_w, g, loss


class PSRequestSource:
    """Generates, prices, and commits PS requests for the engine."""

    def __init__(self, cluster: PSCluster, mix: RequestMix,
                 config: ServingConfig | None = None, chaos=None,
                 elastic=None):
        self.cluster = cluster
        self.mix = mix
        self.config = config if config is not None else ServingConfig()
        self.chaos = chaos
        self.elastic = elastic
        self.router = Router(cluster)
        self.bw = BandwidthModel(self.config.bandwidth
                                 if self.config.bandwidth is not None
                                 else cluster.bandwidth)
        self.rng = np.random.default_rng(self.config.seed)
        self.link = LinkClock(cluster.k)
        self.straggle = np.ones(cluster.k, np.float64)
        self.dead: set[int] = set()
        self.suspect: set[int] = set()   # links past their retry budget
        self.events: list[tuple[int, str, int]] = []

    # ----------------------------------------------------------- chaos
    def on_step(self, t: int) -> None:
        if self.chaos is None:
            return
        for ev in self.chaos.at(t):
            self._apply_event(ev, t)

    def _apply_event(self, ev, t: int) -> None:
        k = self.cluster.k
        if ev.kind == "kill":
            m = ev.machine % k
            if self.elastic is not None:
                # warm repair under load: re-place, re-shard the cluster,
                # and let the router pick it up via placement_version
                self.elastic.repair(m)
                self.elastic.sync_cluster(self.cluster)
                self._sync_fleet()
                self.dead.discard(m)
                self.suspect.discard(m)
            else:
                self.dead.add(m)
        elif ev.kind == "add":
            if self.elastic is not None:
                self.elastic.grow_k(force=True)
                self.elastic.sync_cluster(self.cluster)
                self._sync_fleet()
        elif ev.kind == "straggle":
            self.straggle[ev.machine % k] = ev.factor
        elif ev.kind == "recover":
            m = ev.machine % k
            self.straggle[m] = 1.0
            self.dead.discard(m)
            self.suspect.discard(m)
        self.events.append((t, ev.kind, -1 if ev.machine is None
                            else ev.machine % max(k, 1)))

    def _sync_fleet(self) -> None:
        k = self.cluster.k
        if self.straggle.shape[0] < k:
            self.straggle = np.concatenate(
                [self.straggle, np.ones(k - self.straggle.shape[0])])
        else:
            self.straggle = self.straggle[:k]
        self.link.resize(k)
        self.dead = {m for m in self.dead if m < k}
        self.suspect = {m for m in self.suspect if m < k}
        self.router.refresh(self.cluster)

    # -------------------------------------------------------- requests
    def next_request(self, t: int) -> Request:
        self.router.refresh(self.cluster)
        wl = self.mix.sample(self.rng)
        home = self.router.next_home(self.dead)
        rows = self.router.sample_rows(home, wl.batch, self.rng,
                                       zipf_s=wl.zipf_s,
                                       hot_offset=wl.hot_offset)
        g = self.cluster.graph
        indptr = np.asarray(g.u_indptr, np.int64)
        nnz = int((indptr[rows + 1] - indptr[rows]).sum())
        pad = self.config.pad_multiple
        pad_to = max(pad, -(-nnz // pad) * pad)
        batch = SparseBatch.from_graph(g, rows, self.cluster._labels,
                                       pad_to=pad_to)
        need = np.zeros(g.num_v, bool)
        need[np.asarray(batch.col_ids)[:nnz]] = True
        return Request(tenant=wl.name, home=home, rows=rows, batch=batch,
                       need=need, examples=rows.size, tokens=nnz)

    def issue(self, req: Request, t: int):
        """Price and issue the request's pull; returns a ``PullHandle``."""
        plan = self.cluster.plan_pull(req.home, need=req.need)
        secs = self.bw.per_source(plan.src_bytes, req.home, self.straggle)
        retry = self.config.retry
        exclude: set[int] = set()
        penalty = 0.0   # timeout clocks run concurrently with the wire
        for j in np.flatnonzero(plan.src_bytes):
            j = int(j)
            if j == req.home:
                continue
            if j in self.suspect:
                exclude.add(j)       # circuit open: skip at zero cost
                continue
            link_s = float("inf") if j in self.dead else float(secs[j])
            delivered, spent = retry.admit(link_s)
            penalty = max(penalty, spent)
            if not delivered:
                # retry budget exhausted: bounded-staleness fallback —
                # this source's entries stay stale in the buffer
                exclude.add(j)
                self.suspect.add(j)
        now = time.perf_counter()
        wire = self.bw.ingress_seconds(plan.src_bytes, req.home,
                                       self.straggle, exclude)
        # the home NIC serializes transfers: a still-draining push (or a
        # previous pull) pushes this transfer's completion out
        done = self.link.acquire(req.home, now, wire)
        _count_dispatch("serving_pull")
        return self.cluster.pull_nowait(plan, frozenset(exclude),
                                        wire_s=done - now, wait_s=penalty)

    def compute(self, req: Request, payload: jax.Array):
        cfg = self.cluster.cfg
        _count_dispatch("serving_compute")
        return _serve_step(req.batch, payload, jnp.asarray(req.need),
                           lr=cfg.lr, lam=cfg.lam,
                           update=self.config.update)

    def commit(self, req: Request, out, t: int) -> dict:
        new_w, g, loss = out
        mask = req.need & (np.asarray(g) != 0)
        push = self.cluster.meter_push(req.home, mask)
        # push is fire-and-forget (the τ model absorbs its latency) but
        # still drains real bandwidth: book the home NIC so the machine's
        # next pull queues behind it instead of pretending it was free
        push_wire = (push["inter_bytes"] / self.bw.bandwidth
                     * float(self.straggle[req.home]))
        if push_wire > 0:
            self.link.acquire(req.home, time.perf_counter(), push_wire)
        if self.config.update:
            self.cluster.commit_weights(new_w)
        return {"loss": float(loss),
                "push_inner_bytes": push["inner_bytes"],
                "push_inter_bytes": push["inter_bytes"],
                "push_wire_s": push_wire}


class ServingEngine:
    """The event loop: sync (pull → compute → push per request) or async
    (double-buffered — issue pull t+1, then block on pull t)."""

    def __init__(self, source, prefetch: bool | None = None,
                 warmup: int | None = None):
        self.source = source
        src_cfg = getattr(source, "config", None)
        self.prefetch = (src_cfg.prefetch if prefetch is None and src_cfg
                         else bool(prefetch))
        self.warmup = (src_cfg.warmup if warmup is None and src_cfg
                       else int(warmup or 0))
        self.recorder = LatencyRecorder()
        self.overlap = OverlapMeter()

    def run(self, num_requests: int) -> dict:
        rec, meter = self.recorder, self.overlap
        src = self.source
        wall0 = None
        if self.prefetch:
            src.on_step(0)
            cur = None
            if num_requests > 0:
                req0 = src.next_request(0)
                cur = (req0, src.issue(req0, 0))
            for t in range(num_requests):
                req, handle = cur
                if t == self.warmup:
                    wall0 = time.perf_counter()
                nxt = None
                if t + 1 < num_requests:
                    # double buffer: issue pull t+1 BEFORE blocking on
                    # pull t — its wire time ticks behind this step's
                    # compute; the view it returns is ≤ 1 commit stale
                    src.on_step(t + 1)
                    nreq = src.next_request(t + 1)
                    nxt = (nreq, src.issue(nreq, t + 1))
                self._serve_one(req, handle, t, rec, meter)
                cur = nxt
        else:
            for t in range(num_requests):
                if t == self.warmup:
                    wall0 = time.perf_counter()
                src.on_step(t)
                req = src.next_request(t)
                handle = src.issue(req, t)
                self._serve_one(req, handle, t, rec, meter)
        wall_s = (time.perf_counter() - wall0) if wall0 is not None else 0.0
        out = rec.summary(wall_s=wall_s)
        out["mode"] = "async" if self.prefetch else "sync"
        out["overlap"] = meter.as_dict()
        return out

    def _serve_one(self, req, handle, t, rec, meter) -> None:
        src = self.source
        tb = time.perf_counter()
        payload = handle.block()
        blocked = time.perf_counter() - tb
        tc = time.perf_counter()
        out = src.compute(req, payload)
        jax.block_until_ready(out)
        compute = time.perf_counter() - tc
        stats = src.commit(req, out, t)
        end = time.perf_counter()
        rec.add(RequestRecord(
            tenant=req.tenant, step=t, home=req.home,
            examples=req.examples, tokens=req.tokens,
            latency_s=end - handle.issued_at,
            wire_s=handle.wire_s, wait_s=handle.wait_s,
            blocked_s=blocked, compute_s=compute,
            fresh_entries=handle.fresh_entries,
            stale_entries=handle.stale_entries,
            pull_inter_bytes=handle.inter_bytes,
            push_inter_bytes=stats.get("push_inter_bytes", 0),
            warmup=t < self.warmup))
        if t >= self.warmup:
            meter.add(handle.wire_s, handle.wait_s, blocked, compute)
