"""Double-buffered async pull/compute overlap (§4.3 applied to serving).

The serving engine issues the *next* request's working-set pull before
the current request's compute is dispatched; by the time the current
step commits, the next pull's modeled wire time has been ticking behind
the device work.  The buffered weight view is at most one commit stale —
exactly the bounded-delay τ = 1 consistency DBPG trains under, so the
serving math is the training math.

Nothing here *assumes* the overlap happens: ``PullHandle.block()`` (in
``ml/ps.py``) sleeps out only the transfer time that is still
outstanding, and the engine meters that residual (``blocked_s``) against
the modeled wire time with ``jax.block_until_ready`` fences around the
compute.  ``OverlapMeter`` folds the split; ``hidden_s`` is the
communication the schedule actually removed from the critical path.

``prefetch_batches`` is the same idea for plain training loops: stage
the next batch's host→device transfer while the current step runs
(JAX transfers are async until forced), at a bounded depth.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable, Iterable, Iterator, TypeVar

__all__ = ["OverlapMeter", "ReadyHandle", "prefetch_batches"]

T = TypeVar("T")
S = TypeVar("S")


@dataclasses.dataclass
class OverlapMeter:
    """Cumulative pull/compute overlap accounting across a run."""

    wire_s: float = 0.0       # modeled transfer time, summed
    wait_s: float = 0.0       # retry/timeout penalties, summed
    blocked_s: float = 0.0    # wall time actually spent blocked on pulls
    compute_s: float = 0.0    # block_until_ready-metered device compute

    def add(self, wire_s: float, wait_s: float, blocked_s: float,
            compute_s: float) -> None:
        self.wire_s += wire_s
        self.wait_s += wait_s
        self.blocked_s += blocked_s
        self.compute_s += compute_s

    @property
    def hidden_s(self) -> float:
        """Transfer time hidden behind compute (the measured overlap)."""
        return max(0.0, self.wire_s + self.wait_s - self.blocked_s)

    def as_dict(self) -> dict:
        return {"wire_s": self.wire_s, "wait_s": self.wait_s,
                "blocked_s": self.blocked_s, "compute_s": self.compute_s,
                "hidden_s": self.hidden_s}


@dataclasses.dataclass
class ReadyHandle:
    """A handle for payloads with no transfer to wait for (already-staged
    batches, decode tokens) — lets non-PS sources drive the same engine
    loop as metered pulls.  Carries zeroed metering fields so the
    engine's records stay uniform."""

    payload: object
    wire_s: float = 0.0
    wait_s: float = 0.0
    queue_s: float = 0.0
    inner_bytes: int = 0
    inter_bytes: int = 0
    fresh_entries: int = 0
    stale_entries: int = 0
    issued_at: float = dataclasses.field(
        default_factory=time.perf_counter)

    def block(self):
        return self.payload


def prefetch_batches(batches: Iterable[T],
                     stage: Callable[[T], S] | None = None,
                     depth: int = 2) -> Iterator[S]:
    """Yield staged batches, keeping up to ``depth`` staged ahead.

    ``stage`` typically moves a host batch to device (``jnp.asarray`` /
    tree-map); because JAX device puts are asynchronous, the transfer of
    batch t+1 overlaps the caller's compute on batch t.  ``depth=1``
    degenerates to the unstaged loop."""
    if depth < 1:
        raise ValueError(f"depth must be >= 1, got {depth}")
    if stage is None:
        stage = lambda x: x  # noqa: E731
    buf: collections.deque = collections.deque()
    it = iter(batches)
    try:
        while len(buf) < depth:
            buf.append(stage(next(it)))
    except StopIteration:
        pass
    while buf:
        out = buf.popleft()
        try:
            buf.append(stage(next(it)))
        except StopIteration:
            pass
        yield out
