"""Windowed serving telemetry: the signal layer of the closed SLO loop.

The autoscaler (``repro.elastic.autoscaler``) never reads engine state
directly — it sees immutable ``TelemetrySnapshot``s taken from a
``TelemetryBus`` that the request source feeds one observation per
served (or shed) request:

  * sliding-window p50/p99 latency, twice — *modeled* (the deterministic
    virtual-clock latency: wire + queue + retry penalty + service time)
    and *measured* (wall clock).  Decisions gate on the modeled window so
    a seeded chaos replay is bit-deterministic; the measured window is
    reported alongside as evidence the model tracks reality;
  * per-machine NIC occupancy — the virtual ``LinkClock`` backlog at
    snapshot time, i.e. how many seconds of already-booked transfer a new
    request to that home would queue behind;
  * live popcount footprints and row-shard sizes from the cluster (what a
    grow decision uses to pick the hot part to split);
  * a ``StragglerEWMA`` over per-source delivery speeds, fed from the
    priced transfer times of each request's pull (a straggling machine's
    slices arrive slower than its bytes/bandwidth baseline, so the EWMA
    converges to the straggle factor without being told it);
  * shed/served counters from admission control and the breaker's open
    circuits.

Snapshots carry tuples, not arrays, so two replays of the same seeded
schedule produce snapshot objects that compare ``==`` field-for-field —
the determinism contract ``bench_slo`` asserts end to end.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..runtime.straggler import StragglerEWMA
from .latency import LatencyWindow

__all__ = ["TelemetrySnapshot", "TelemetryBus"]


@dataclasses.dataclass(frozen=True)
class TelemetrySnapshot:
    """One immutable reading of the serving loop, as the autoscaler saw it
    when deciding.  All sequence fields are tuples (hashable, ``==`` by
    value) so decision records replay bit-identically."""

    step: int                        # engine slot the snapshot closed at
    k: int                           # live machine count
    window: int                      # observations in the sliding window
    p50_ms: float                    # modeled sliding-window p50
    p99_ms: float                    # modeled sliding-window p99 (gated)
    mean_ms: float                   # modeled sliding-window mean
    p99_measured_ms: float           # wall-clock p99 (reported, not gated)
    occupancy: tuple[float, ...]     # per-machine NIC backlog seconds
    footprint: tuple[int, ...]       # per-machine hosted-parameter popcount
    sizes: tuple[int, ...]           # per-machine example rows
    speeds: tuple[float, ...]        # StragglerEWMA weights (mean 1)
    shed: int                        # admission drops so far (cumulative)
    served: int                      # served requests so far (cumulative)
    open_circuits: tuple[int, ...]   # links currently open/half-open
    load_factor: float               # current burst multiplier

    @property
    def max_occupancy(self) -> float:
        return max(self.occupancy) if self.occupancy else 0.0

    @property
    def hot_part(self) -> int:
        """The grow split target: the machine hosting the most parameters
        (ties → lowest id), restricted to parts that can be split."""
        if not self.footprint:
            return 0
        best, best_foot = 0, -1
        for m, foot in enumerate(self.footprint):
            if m < len(self.sizes) and self.sizes[m] < 2:
                continue  # a 0/1-row part cannot be split
            if foot > best_foot:
                best, best_foot = m, foot
        return best


class TelemetryBus:
    """Accumulates per-request observations; closes them into snapshots.

    One bus instance is owned by the request source and survives elastic
    resizes (``resize`` keeps the EWMA history of surviving machines).
    The latency windows are ``LatencyWindow`` rings — lazily seeded, so
    the first decision window never averages preallocated zeros."""

    def __init__(self, k: int, window_requests: int = 64,
                 ewma_alpha: float = 0.3, ewma_floor: float = 0.1):
        if window_requests < 1:
            raise ValueError(
                f"window_requests must be >= 1, got {window_requests}")
        self.k = k
        self.window_requests = window_requests
        self._alpha, self._floor = ewma_alpha, ewma_floor
        self.modeled = LatencyWindow(window_requests)
        self.measured = LatencyWindow(window_requests)
        self.ewma = StragglerEWMA(k, alpha=ewma_alpha, floor=ewma_floor)
        self.served = 0
        self.shed: dict[str, int] = {}

    @property
    def shed_total(self) -> int:
        return sum(self.shed.values())

    def resize(self, k: int) -> None:
        """Track an elastic k change; EWMA history of surviving machines
        is preserved, new machines start unobserved (no penalty before
        evidence — the ``StragglerEWMA`` contract)."""
        if k == self.k:
            return
        new = StragglerEWMA(k, alpha=self._alpha, floor=self._floor)
        keep = min(k, self.k)
        new._ewma[:keep] = self.ewma._ewma[:keep]
        new._seen[:keep] = self.ewma._seen[:keep]
        self.ewma = new
        self.k = k

    def observe(self, modeled_s: float, measured_s: float,
                src_times: np.ndarray | None = None) -> None:
        """Fold one served request: modeled + measured latency, and
        (optionally) per-source delivery times — a (k,) vector with NaN
        for machines that shipped nothing this request."""
        self.modeled.add(modeled_s * 1e3)
        self.measured.add(measured_s * 1e3)
        if src_times is not None:
            times = np.asarray(src_times, np.float64)
            if times.shape[0] != self.k:
                fixed = np.full(self.k, np.nan)
                n = min(self.k, times.shape[0])
                fixed[:n] = times[:n]
                times = fixed
            self.ewma.update(times)
        self.served += 1

    def observe_shed(self, tenant: str) -> None:
        self.shed[tenant] = self.shed.get(tenant, 0) + 1

    def snapshot(self, step: int, occupancy, footprint, sizes,
                 open_circuits=(), load_factor: float = 1.0
                 ) -> TelemetrySnapshot:
        """Close the current window into an immutable snapshot."""
        return TelemetrySnapshot(
            step=step, k=self.k, window=self.modeled.filled,
            p50_ms=self.modeled.percentile(50),
            p99_ms=self.modeled.percentile(99),
            mean_ms=self.modeled.mean(),
            p99_measured_ms=self.measured.percentile(99),
            occupancy=tuple(float(x) for x in occupancy),
            footprint=tuple(int(x) for x in footprint),
            sizes=tuple(int(x) for x in sizes),
            speeds=tuple(float(x) for x in self.ewma.weights()),
            shed=self.shed_total, served=self.served,
            open_circuits=tuple(int(x) for x in open_circuits),
            load_factor=float(load_factor))
