"""repro.serving — heavy-traffic PS serving with async pull/compute
overlap (ROADMAP item 1: the paper's traffic cut, measured as a
wall-clock speedup)."""
from .engine import (  # noqa: F401
    PSRequestSource,
    Request,
    RequestMix,
    ServingConfig,
    ServingEngine,
    ZipfWorkload,
)
from .latency import (  # noqa: F401
    BandwidthModel,
    LatencyRecorder,
    LatencyWindow,
    LinkClock,
    RequestRecord,
)
from .prefetch import OverlapMeter, ReadyHandle, prefetch_batches  # noqa: F401
from .router import Router  # noqa: F401
from .telemetry import TelemetryBus, TelemetrySnapshot  # noqa: F401

__all__ = [
    "BandwidthModel",
    "LatencyRecorder",
    "LatencyWindow",
    "LinkClock",
    "OverlapMeter",
    "PSRequestSource",
    "ReadyHandle",
    "Request",
    "RequestMix",
    "RequestRecord",
    "Router",
    "ServingConfig",
    "ServingEngine",
    "TelemetryBus",
    "TelemetrySnapshot",
    "ZipfWorkload",
    "prefetch_batches",
]
