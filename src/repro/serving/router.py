"""Request → worker routing from the live placement.

A request is served by one *home* machine: the worker whose row shard
hosts the examples the request touches.  The router keeps a per-machine
pool of example rows derived from the cluster's current ``parts_u`` and
re-derives it whenever ``PSCluster.placement_version`` moves — which is
how elastic grow/shrink/repair (``ElasticSession.sync_cluster``) become
visible to in-flight traffic without any coordination beyond the version
counter.

Sampling is Zipf *within* the home pool (production traffic is
power-law over a tenant's own hot set), with a per-tenant offset so
different tenants hammer different hot rows.  Keeping the skew inside
the shard is what lets a locality-aware placement pay off: the rows a
request batches together share features, so their working set — and the
pull bytes — concentrate on few machines.
"""
from __future__ import annotations

import numpy as np

__all__ = ["Router"]


class Router:
    """Maps requests to home machines and samples their row batches."""

    def __init__(self, cluster):
        self.version = -1
        self.pools: list[np.ndarray] = []
        self.k = 0
        self._rr = 0
        self._zipf_cache: dict[tuple[int, float], np.ndarray] = {}
        self.refresh(cluster)

    def refresh(self, cluster) -> bool:
        """Re-derive the row pools if the placement moved; returns whether
        anything changed."""
        if cluster.placement_version == self.version:
            return False
        self.version = cluster.placement_version
        self.k = cluster.k
        self.pools = [np.asarray(rows) for rows in cluster.rows]
        return True

    def live(self, dead=()) -> list[int]:
        return [m for m in range(self.k)
                if m not in dead and self.pools[m].size > 0]

    def next_home(self, dead=()) -> int:
        """Round-robin over live machines with non-empty pools."""
        live = self.live(dead)
        if not live:
            raise RuntimeError("no live machine with examples to serve")
        home = live[self._rr % len(live)]
        self._rr += 1
        return home

    def _zipf_p(self, n: int, s: float) -> np.ndarray:
        key = (n, s)
        p = self._zipf_cache.get(key)
        if p is None:
            p = 1.0 / np.arange(1, n + 1) ** s
            p /= p.sum()
            self._zipf_cache[key] = p
        return p

    def sample_rows(self, home: int, size: int, rng: np.random.Generator,
                    zipf_s: float = 1.1, hot_offset: int = 0) -> np.ndarray:
        """Zipf-skewed batch from the home machine's pool.  ``hot_offset``
        rotates the pool so tenants get distinct hot sets."""
        pool = self.pools[home]
        if pool.size == 0:
            raise ValueError(f"machine {home} hosts no examples")
        if hot_offset:
            pool = np.roll(pool, -(hot_offset % pool.size))
        idx = rng.choice(pool.size, size=size,
                         p=self._zipf_p(pool.size, zipf_s))
        return pool[idx]

    def route(self, rows: np.ndarray, parts_u: np.ndarray,
              dead=()) -> int:
        """Home for an explicit row set: majority vote of the rows'
        hosting machines, skipping dead ones."""
        owners = np.asarray(parts_u)[np.asarray(rows)]
        counts = np.bincount(owners, minlength=self.k)
        for m in dead:
            if 0 <= m < counts.shape[0]:
                counts[m] = 0
        if counts.sum() == 0:
            return self.next_home(dead)
        return int(np.argmax(counts))
