"""Request → worker routing from the live placement.

A request is served by one *home* machine: the worker whose row shard
hosts the examples the request touches.  The router keeps a per-machine
pool of example rows derived from the cluster's current ``parts_u`` and
re-derives it whenever ``PSCluster.placement_version`` moves — which is
how elastic grow/shrink/repair (``ElasticSession.sync_cluster``) become
visible to in-flight traffic without any coordination beyond the version
counter.

Sampling is Zipf *within* the home pool (production traffic is
power-law over a tenant's own hot set), with a per-tenant offset so
different tenants hammer different hot rows.  Keeping the skew inside
the shard is what lets a locality-aware placement pay off: the rows a
request batches together share features, so their working set — and the
pull bytes — concentrate on few machines.
"""
from __future__ import annotations

import numpy as np

from ..obs.trace import trace_instant

__all__ = ["Router"]


class Router:
    """Maps requests to home machines and samples their row batches."""

    def __init__(self, cluster):
        self.version = -1
        self.pools: list[np.ndarray] = []
        self.k = 0
        self._rr = 0
        self._zipf_cache: dict[tuple[int, float], np.ndarray] = {}
        self.weights: np.ndarray | None = None
        self._swrr: np.ndarray | None = None
        self.refresh(cluster)

    def refresh(self, cluster) -> bool:
        """Re-derive the row pools if the placement moved; returns whether
        anything changed."""
        if cluster.placement_version == self.version:
            return False
        if cluster.k != self.k:
            # elastic resize: routing weights are stale for the new fleet;
            # fall back to plain round-robin until the controller re-sets
            self.weights = None
            self._swrr = None
        self.version = cluster.placement_version
        self.k = cluster.k
        self.pools = [np.asarray(rows) for rows in cluster.rows]
        trace_instant("router.refresh", version=self.version, k=self.k)
        return True

    def set_weights(self, weights) -> None:
        """Bias ``next_home`` toward fast machines (straggler-aware
        routing): per-machine weights consumed by a smooth weighted
        round-robin.  ``None`` restores plain round-robin."""
        if weights is None:
            self.weights = None
            self._swrr = None
            return
        w = np.asarray(weights, np.float64)
        if w.shape != (self.k,):
            raise ValueError(
                f"weights must have shape ({self.k},), got {w.shape}")
        if (w <= 0).any():
            raise ValueError("weights must be > 0")
        self.weights = w
        self._swrr = np.zeros(self.k, np.float64)

    def live(self, dead=()) -> list[int]:
        return [m for m in range(self.k)
                if m not in dead and self.pools[m].size > 0]

    def next_home(self, dead=()) -> int:
        """Round-robin over live machines with non-empty pools; smooth
        *weighted* round-robin when ``set_weights`` biased the fleet
        (deterministic: no RNG, ties break to the lowest machine id)."""
        live = self.live(dead)
        if not live:
            raise RuntimeError("no live machine with examples to serve")
        if self.weights is None:
            home = live[self._rr % len(live)]
            self._rr += 1
            return home
        # smooth WRR (nginx scheme): credit each live machine its weight,
        # serve the richest, debit it the round's total credit
        idx = np.array(live)
        self._swrr[idx] += self.weights[idx]
        home = int(idx[np.argmax(self._swrr[idx])])
        self._swrr[home] -= float(self.weights[idx].sum())
        return home

    def _zipf_p(self, n: int, s: float) -> np.ndarray:
        key = (n, s)
        p = self._zipf_cache.get(key)
        if p is None:
            p = 1.0 / np.arange(1, n + 1) ** s
            p /= p.sum()
            self._zipf_cache[key] = p
        return p

    def sample_rows(self, home: int, size: int, rng: np.random.Generator,
                    zipf_s: float = 1.1, hot_offset: int = 0) -> np.ndarray:
        """Zipf-skewed batch from the home machine's pool.  ``hot_offset``
        rotates the pool so tenants get distinct hot sets."""
        pool = self.pools[home]
        if pool.size == 0:
            raise ValueError(f"machine {home} hosts no examples")
        if hot_offset:
            pool = np.roll(pool, -(hot_offset % pool.size))
        idx = rng.choice(pool.size, size=size,
                         p=self._zipf_p(pool.size, zipf_s))
        return pool[idx]

    def route(self, rows: np.ndarray, parts_u: np.ndarray,
              dead=()) -> int:
        """Home for an explicit row set: majority vote of the rows'
        hosting machines, skipping dead ones."""
        owners = np.asarray(parts_u)[np.asarray(rows)]
        counts = np.bincount(owners, minlength=self.k)
        for m in dead:
            if 0 <= m < counts.shape[0]:
                counts[m] = 0
        if counts.sum() == 0:
            return self.next_home(dead)
        return int(np.argmax(counts))
