"""Latency accounting for the serving engine (paper §5.5, closed loop).

``BandwidthModel`` prices each pull against the same wall-clock model
``PSCluster`` uses for training: a machine's NIC serializes its
inter-machine bytes (``max_i inter_bytes_i / bandwidth``), so a pull's
transfer time is the *sum* of the remote slices arriving at the home
worker's ingress link, each inflated by its source's straggle factor
from the chaos layer.  ``LinkClock`` extends that to concurrent
transfers: every transfer books the home NIC for its duration, so a
push still draining delays the next pull on the same machine —
fire-and-forget pushes occupy bandwidth without blocking the request.
The engine makes the modeled seconds *real* (the pull handle sleeps
them out), so throughput and overlap are measured on the wall clock,
not inferred from byte counts.

Transfer time and queueing are split: a pull's ``wire_s`` is the pure
modeled transfer (bytes / bandwidth × straggle) and ``queue_s`` is the
extra delay spent waiting for the home NIC to drain earlier bookings.
The split matters twice — the async-overlap comparison is only fair on
pure transfer time, and the queueing term is exactly the overload signal
the SLO autoscaler scales on.

``LatencyRecorder`` accumulates one ``RequestRecord`` per served request
and reduces them to the numbers ``BENCH_system.json`` reports: p50/p99
request latency, examples/s and tokens/s, the overlap split, and the
per-tenant shed counts from admission control.  With ``window_requests``
set it additionally keeps a ring buffer of recent latencies so
``windowed()`` reflects *current* traffic — the all-time p99 of a long
run never recovers from one historic burst, which is useless for a
closed-loop controller.  The ring is lazily seeded (the ``DriftTracker``
pattern): a cold window reduces over the entries actually observed, never
over preallocated zeros.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["BandwidthModel", "LinkClock", "LatencyWindow", "RequestRecord",
           "LatencyRecorder"]


@dataclasses.dataclass(frozen=True)
class BandwidthModel:
    """Per-link transfer pricing: bytes / bandwidth × straggle factor."""

    bandwidth: float = 125e6  # 1 GbE, matching PSCluster's default

    def per_source(self, src_bytes: np.ndarray, home: int,
                   straggle: np.ndarray | None = None) -> np.ndarray:
        """Seconds each source machine needs to ship its slice to
        ``home``.  The home machine's slice is local (0 s)."""
        secs = np.asarray(src_bytes, np.float64) / self.bandwidth
        if straggle is not None:
            secs = secs * np.asarray(straggle, np.float64)[: secs.shape[0]]
        if 0 <= home < secs.shape[0]:
            secs[home] = 0.0
        return secs

    def ingress_seconds(self, src_bytes: np.ndarray, home: int,
                        straggle: np.ndarray | None = None,
                        exclude=()) -> float:
        """Modeled pull transfer time: the remote slices serialize into
        the home worker's ingress link (PSCluster's per-machine
        ``inter_bytes / bandwidth`` wall-clock model)."""
        secs = self.per_source(src_bytes, home, straggle)
        for j in exclude:
            if 0 <= j < secs.shape[0]:
                secs[j] = 0.0
        return float(secs.sum())


class LinkClock:
    """Per-machine NIC availability: transfers book the link in issue
    order, so a fire-and-forget push still drains real (modeled)
    bandwidth and delays the machine's next transfer."""

    def __init__(self, k: int):
        self.free_at = np.zeros(k, np.float64)

    def resize(self, k: int) -> None:
        if k > self.free_at.shape[0]:
            self.free_at = np.concatenate(
                [self.free_at, np.zeros(k - self.free_at.shape[0])])
        else:
            self.free_at = self.free_at[:k]

    def backlog(self, machine: int, now: float) -> float:
        """Seconds of already-booked transfer still ahead of ``now`` on
        the machine's link — the queueing delay a new transfer would
        inherit (the admission controller's per-home queue depth)."""
        return max(0.0, float(self.free_at[machine]) - now)

    def acquire(self, machine: int, now: float, seconds: float) -> float:
        """Book ``seconds`` of the machine's link starting no earlier than
        ``now``; returns the completion time."""
        start = max(now, float(self.free_at[machine]))
        self.free_at[machine] = start + seconds
        return start + seconds


class LatencyWindow:
    """Ring buffer of the last ``size`` observations with lazy seeding.

    ``percentile`` reduces over the entries actually observed so far —
    a cold (or freshly reset) window never averages preallocated zeros,
    the same fix PR 6 applied to ``DriftTracker``'s baseline ring."""

    def __init__(self, size: int):
        if size < 1:
            raise ValueError(f"size must be >= 1, got {size}")
        self.size = size
        self._ring = np.zeros(size, np.float64)
        self._count = 0

    def add(self, value: float) -> None:
        self._ring[self._count % self.size] = value
        self._count += 1

    @property
    def filled(self) -> int:
        return min(self._count, self.size)

    @property
    def total_observed(self) -> int:
        return self._count

    def values(self) -> np.ndarray:
        """The observed entries, oldest-truncated (order unspecified)."""
        return self._ring[: self.filled]

    def percentile(self, q: float) -> float:
        if self.filled == 0:
            return 0.0
        return float(np.percentile(self._ring[: self.filled], q))

    def mean(self) -> float:
        if self.filled == 0:
            return 0.0
        return float(self._ring[: self.filled].mean())

    def reset(self) -> None:
        self._count = 0


@dataclasses.dataclass
class RequestRecord:
    """Everything measured for one served request."""

    tenant: str
    step: int
    home: int
    examples: int
    tokens: int
    latency_s: float          # pull issue → commit, wall clock
    wire_s: float             # modeled pull transfer time (pure transfer)
    wait_s: float             # retry/timeout penalty on failed links
    blocked_s: float          # wall time actually spent in handle.block()
    compute_s: float          # block_until_ready-metered device compute
    fresh_entries: int = 0
    stale_entries: int = 0    # entries served stale (dead/timed-out shard)
    pull_inter_bytes: int = 0
    push_inter_bytes: int = 0
    warmup: bool = False      # excluded from the summary statistics
    queue_s: float = 0.0      # NIC-backlog delay ahead of the transfer
    modeled_s: float = 0.0    # deterministic virtual-clock latency


class LatencyRecorder:
    """Accumulate ``RequestRecord`` rows; reduce to benchmark numbers.

    ``window_requests`` (optional) sizes a sliding ring over the most
    recent non-warmup requests, surfaced as ``windowed()`` and the
    ``p50_window_ms`` / ``p99_window_ms`` summary keys — the recency-aware
    percentiles a closed-loop SLO controller acts on."""

    def __init__(self, window_requests: int | None = None):
        self.records: list[RequestRecord] = []
        self.window_requests = window_requests
        self._win = (LatencyWindow(window_requests)
                     if window_requests else None)
        self.shed: dict[str, int] = {}

    def add(self, rec: RequestRecord) -> None:
        self.records.append(rec)
        if self._win is not None and not rec.warmup:
            self._win.add(rec.latency_s * 1e3)

    def add_shed(self, tenant: str) -> None:
        """Meter one admission-control drop against its tenant."""
        self.shed[tenant] = self.shed.get(tenant, 0) + 1

    @property
    def shed_requests(self) -> int:
        return sum(self.shed.values())

    def windowed(self) -> dict:
        """p50/p99/mean over the sliding window (ms).  Cold start reduces
        over what was actually observed; zero observations → zeros."""
        if self._win is None:
            raise ValueError(
                "LatencyRecorder built without window_requests")
        return {
            "requests": self._win.filled,
            "p50_ms": self._win.percentile(50),
            "p99_ms": self._win.percentile(99),
            "mean_ms": self._win.mean(),
        }

    def summary(self, wall_s: float | None = None) -> dict:
        """Reduce the non-warmup records.

        ``wall_s`` is the engine-measured wall clock of the timed window
        (throughput denominators); defaults to the sum of latencies,
        which is only correct for the sync engine."""
        recs = [r for r in self.records if not r.warmup]
        if not recs:
            return {"requests": 0,
                    "shed_requests": self.shed_requests,
                    "shed_frac": 1.0 if self.shed_requests else 0.0,
                    "shed_per_tenant": dict(self.shed)}
        lat_ms = np.array([r.latency_s for r in recs]) * 1e3
        examples = sum(r.examples for r in recs)
        tokens = sum(r.tokens for r in recs)
        if wall_s is None:
            wall_s = float(sum(r.latency_s for r in recs))
        wire = sum(r.wire_s for r in recs)
        wait = sum(r.wait_s for r in recs)
        queue = sum(r.queue_s for r in recs)
        blocked = sum(r.blocked_s for r in recs)
        compute = sum(r.compute_s for r in recs)
        hidden = max(0.0, wire + wait + queue - blocked)
        shed = self.shed_requests
        tenants = {}
        for name in sorted({r.tenant for r in recs} | set(self.shed)):
            tl = np.array([r.latency_s for r in recs if r.tenant == name])
            tenants[name] = {
                "requests": int(tl.size),
                "p50_ms": float(np.percentile(tl, 50) * 1e3)
                if tl.size else 0.0,
                "p99_ms": float(np.percentile(tl, 99) * 1e3)
                if tl.size else 0.0,
                "shed": self.shed.get(name, 0),
            }
        out = {
            "requests": len(recs),
            "examples": int(examples),
            "tokens": int(tokens),
            "wall_s": float(wall_s),
            "examples_s": examples / wall_s if wall_s > 0 else 0.0,
            "tokens_s": tokens / wall_s if wall_s > 0 else 0.0,
            "p50_ms": float(np.percentile(lat_ms, 50)),
            "p99_ms": float(np.percentile(lat_ms, 99)),
            "mean_ms": float(lat_ms.mean()),
            "wire_s": float(wire),
            "wait_s": float(wait),
            "queue_s": float(queue),
            "blocked_s": float(blocked),
            "compute_s": float(compute),
            "hidden_s": float(hidden),
            "hidden_frac": float(hidden / (wire + wait + queue))
            if wire + wait + queue > 0 else 0.0,
            "stale_entries": int(sum(r.stale_entries for r in recs)),
            "fresh_entries": int(sum(r.fresh_entries for r in recs)),
            "pull_inter_bytes": int(sum(r.pull_inter_bytes for r in recs)),
            "push_inter_bytes": int(sum(r.push_inter_bytes for r in recs)),
            "shed_requests": shed,
            "shed_frac": shed / (shed + len(recs)),
            "shed_per_tenant": dict(self.shed),
            "per_tenant": tenants,
        }
        if self._win is not None:
            w = self.windowed()
            out["p50_window_ms"] = w["p50_ms"]
            out["p99_window_ms"] = w["p99_ms"]
        return out
