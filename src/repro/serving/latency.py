"""Latency accounting for the serving engine (paper §5.5, closed loop).

``BandwidthModel`` prices each pull against the same wall-clock model
``PSCluster`` uses for training: a machine's NIC serializes its
inter-machine bytes (``max_i inter_bytes_i / bandwidth``), so a pull's
transfer time is the *sum* of the remote slices arriving at the home
worker's ingress link, each inflated by its source's straggle factor
from the chaos layer.  ``LinkClock`` extends that to concurrent
transfers: every transfer books the home NIC for its duration, so a
push still draining delays the next pull on the same machine —
fire-and-forget pushes occupy bandwidth without blocking the request.
The engine makes the modeled seconds *real* (the pull handle sleeps
them out), so throughput and overlap are measured on the wall clock,
not inferred from byte counts.

``LatencyRecorder`` accumulates one ``RequestRecord`` per served request
and reduces them to the numbers ``BENCH_system.json`` reports: p50/p99
request latency, examples/s and tokens/s, and the overlap split (wire
time vs time actually spent blocked on the pull — their difference is
communication hidden behind compute).
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["BandwidthModel", "LinkClock", "RequestRecord",
           "LatencyRecorder"]


@dataclasses.dataclass(frozen=True)
class BandwidthModel:
    """Per-link transfer pricing: bytes / bandwidth × straggle factor."""

    bandwidth: float = 125e6  # 1 GbE, matching PSCluster's default

    def per_source(self, src_bytes: np.ndarray, home: int,
                   straggle: np.ndarray | None = None) -> np.ndarray:
        """Seconds each source machine needs to ship its slice to
        ``home``.  The home machine's slice is local (0 s)."""
        secs = np.asarray(src_bytes, np.float64) / self.bandwidth
        if straggle is not None:
            secs = secs * np.asarray(straggle, np.float64)[: secs.shape[0]]
        if 0 <= home < secs.shape[0]:
            secs[home] = 0.0
        return secs

    def ingress_seconds(self, src_bytes: np.ndarray, home: int,
                        straggle: np.ndarray | None = None,
                        exclude=()) -> float:
        """Modeled pull transfer time: the remote slices serialize into
        the home worker's ingress link (PSCluster's per-machine
        ``inter_bytes / bandwidth`` wall-clock model)."""
        secs = self.per_source(src_bytes, home, straggle)
        for j in exclude:
            if 0 <= j < secs.shape[0]:
                secs[j] = 0.0
        return float(secs.sum())


class LinkClock:
    """Per-machine NIC availability: transfers book the link in issue
    order, so a fire-and-forget push still drains real (modeled)
    bandwidth and delays the machine's next transfer."""

    def __init__(self, k: int):
        self.free_at = np.zeros(k, np.float64)

    def resize(self, k: int) -> None:
        if k > self.free_at.shape[0]:
            self.free_at = np.concatenate(
                [self.free_at, np.zeros(k - self.free_at.shape[0])])
        else:
            self.free_at = self.free_at[:k]

    def acquire(self, machine: int, now: float, seconds: float) -> float:
        """Book ``seconds`` of the machine's link starting no earlier than
        ``now``; returns the completion time."""
        start = max(now, float(self.free_at[machine]))
        self.free_at[machine] = start + seconds
        return start + seconds


@dataclasses.dataclass
class RequestRecord:
    """Everything measured for one served request."""

    tenant: str
    step: int
    home: int
    examples: int
    tokens: int
    latency_s: float          # pull issue → commit, wall clock
    wire_s: float             # modeled pull transfer time
    wait_s: float             # retry/timeout penalty on failed links
    blocked_s: float          # wall time actually spent in handle.block()
    compute_s: float          # block_until_ready-metered device compute
    fresh_entries: int = 0
    stale_entries: int = 0    # entries served stale (dead/timed-out shard)
    pull_inter_bytes: int = 0
    push_inter_bytes: int = 0
    warmup: bool = False      # excluded from the summary statistics


class LatencyRecorder:
    """Accumulate ``RequestRecord`` rows; reduce to benchmark numbers."""

    def __init__(self):
        self.records: list[RequestRecord] = []

    def add(self, rec: RequestRecord) -> None:
        self.records.append(rec)

    def summary(self, wall_s: float | None = None) -> dict:
        """Reduce the non-warmup records.

        ``wall_s`` is the engine-measured wall clock of the timed window
        (throughput denominators); defaults to the sum of latencies,
        which is only correct for the sync engine."""
        recs = [r for r in self.records if not r.warmup]
        if not recs:
            return {"requests": 0}
        lat_ms = np.array([r.latency_s for r in recs]) * 1e3
        examples = sum(r.examples for r in recs)
        tokens = sum(r.tokens for r in recs)
        if wall_s is None:
            wall_s = float(sum(r.latency_s for r in recs))
        wire = sum(r.wire_s for r in recs)
        wait = sum(r.wait_s for r in recs)
        blocked = sum(r.blocked_s for r in recs)
        compute = sum(r.compute_s for r in recs)
        hidden = max(0.0, wire + wait - blocked)
        tenants = {}
        for name in sorted({r.tenant for r in recs}):
            tl = np.array([r.latency_s for r in recs if r.tenant == name])
            tenants[name] = {
                "requests": int(tl.size),
                "p50_ms": float(np.percentile(tl, 50) * 1e3),
                "p99_ms": float(np.percentile(tl, 99) * 1e3),
            }
        return {
            "requests": len(recs),
            "examples": int(examples),
            "tokens": int(tokens),
            "wall_s": float(wall_s),
            "examples_s": examples / wall_s if wall_s > 0 else 0.0,
            "tokens_s": tokens / wall_s if wall_s > 0 else 0.0,
            "p50_ms": float(np.percentile(lat_ms, 50)),
            "p99_ms": float(np.percentile(lat_ms, 99)),
            "mean_ms": float(lat_ms.mean()),
            "wire_s": float(wire),
            "wait_s": float(wait),
            "blocked_s": float(blocked),
            "compute_s": float(compute),
            "hidden_s": float(hidden),
            "hidden_frac": float(hidden / (wire + wait))
            if wire + wait > 0 else 0.0,
            "stale_entries": int(sum(r.stale_entries for r in recs)),
            "fresh_entries": int(sum(r.fresh_entries for r in recs)),
            "pull_inter_bytes": int(sum(r.pull_inter_bytes for r in recs)),
            "push_inter_bytes": int(sum(r.push_inter_bytes for r in recs)),
            "per_tenant": tenants,
        }
