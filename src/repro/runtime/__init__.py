from .fault import (  # noqa: F401
    CircuitBreaker,
    FaultConfig,
    RetryPolicy,
    TrainLoop,
)
from .straggler import (  # noqa: F401
    BoundedDelayAccumulator,
    StragglerConfig,
    StragglerEWMA,
)
