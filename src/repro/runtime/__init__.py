from .fault import TrainLoop, FaultConfig, RetryPolicy  # noqa: F401
from .straggler import (  # noqa: F401
    BoundedDelayAccumulator,
    StragglerConfig,
    StragglerEWMA,
)
