from .fault import TrainLoop, FaultConfig  # noqa: F401
from .straggler import BoundedDelayAccumulator, StragglerConfig  # noqa: F401
