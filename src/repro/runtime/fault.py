"""Fault tolerance: checkpoint/restart, failure injection, elastic re-mesh.

On a real pod, node failure kills the whole jax.distributed job; recovery is
restart-from-checkpoint (plus slice auto-repair).  This module provides the
framework side of that contract, testable on one host:

  * ``TrainLoop`` — steps a jitted train_step with a CheckpointManager;
    resume is exact (tested bitwise on params in tests/test_fault.py);
  * failure injection — raise at a chosen step to exercise the path;
  * elastic re-mesh — ``TrainLoop.restore(mesh=...)`` re-device_puts the
    logical checkpoint onto a *different* mesh (data-parallel width change),
    because checkpoints store logical arrays, not device layouts;
  * straggler mitigation lives in runtime/straggler.py (bounded-delay
    gradient semantics, the paper's τ model applied to training);
  * ``RetryPolicy`` — per-link retry/timeout admission used by the
    serving pull path (repro.serving): a source shard that cannot
    deliver within its (backed-off) deadlines is dropped for the step
    and the worker falls back to its stale buffer (§4.3 bounded
    staleness) instead of stalling the request.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax

from ..ckpt import CheckpointManager, latest_step, restore_checkpoint


@dataclasses.dataclass
class FaultConfig:
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    keep: int = 3
    fail_at_step: int | None = None      # failure injection (tests)


class SimulatedFailure(RuntimeError):
    pass


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Deadline/retry admission for one pull link.

    ``admit(wire_s)`` plays the attempts out against the modeled transfer
    time: each attempt has a deadline (``timeout_s`` growing by
    ``backoff``); an attempt whose transfer fits the deadline delivers and
    the call returns ``(True, wait_s)`` where ``wait_s`` is the time burnt
    on *earlier failed* attempts (the caller adds ``wire_s`` itself).  A
    link that never fits — a killed shard models ``wire_s = inf`` —
    returns ``(False, wait_s)`` with the full timeout budget spent, and
    the caller serves from the stale buffer instead of stalling."""

    timeout_s: float = 0.05
    retries: int = 1
    backoff: float = 2.0

    def __post_init__(self):
        if self.timeout_s <= 0:
            raise ValueError(f"timeout_s must be > 0, got {self.timeout_s}")
        if self.retries < 0:
            raise ValueError(f"retries must be >= 0, got {self.retries}")
        if self.backoff < 1.0:
            raise ValueError(f"backoff must be >= 1, got {self.backoff}")

    @property
    def budget_s(self) -> float:
        """Total time a fully failing link costs (sum of all deadlines)."""
        return sum(self.timeout_s * self.backoff ** a
                   for a in range(self.retries + 1))

    def admit(self, wire_s: float) -> tuple[bool, float]:
        deadline, wait = self.timeout_s, 0.0
        for _ in range(self.retries + 1):
            if wire_s <= deadline:
                return True, wait
            wait += deadline
            deadline *= self.backoff
        return False, wait


class TrainLoop:
    def __init__(self, train_step: Callable, fault: FaultConfig,
                 shardings=None):
        self.train_step = train_step
        self.fault = fault
        self.mgr = CheckpointManager(fault.ckpt_dir, fault.ckpt_every, fault.keep)
        self.shardings = shardings

    def resume_or(self, init_fn: Callable):
        """Restore the newest checkpoint, else initialize fresh."""
        step = latest_step(self.fault.ckpt_dir)
        if step is None:
            params, opt = init_fn()
            return 0, params, opt
        like = jax.eval_shape(init_fn)
        state = restore_checkpoint(
            self.fault.ckpt_dir, step, {"params": like[0], "opt": like[1]},
            shardings=self.shardings)
        return step, state["params"], state["opt"]

    def run(self, params, opt_state, batches, start_step: int = 0,
            log_every: int = 0):
        metrics_hist = []
        step = start_step
        for batch in batches:
            if self.fault.fail_at_step is not None and step == self.fault.fail_at_step:
                self.mgr.wait()
                raise SimulatedFailure(f"injected failure at step {step}")
            params, opt_state, metrics = self.train_step(params, opt_state, batch)
            step += 1
            self.mgr.maybe_save(step, {"params": params, "opt": opt_state})
            if log_every and step % log_every == 0:
                m = {k: float(v) for k, v in metrics.items()}
                m["step"] = step
                metrics_hist.append(m)
                print(f"step {step}: " + " ".join(f"{k}={v:.4g}" for k, v in m.items()))
        self.mgr.wait()
        return params, opt_state, metrics_hist
