"""Fault tolerance: checkpoint/restart, failure injection, elastic re-mesh.

On a real pod, node failure kills the whole jax.distributed job; recovery is
restart-from-checkpoint (plus slice auto-repair).  This module provides the
framework side of that contract, testable on one host:

  * ``TrainLoop`` — steps a jitted train_step with a CheckpointManager;
    resume is exact (tested bitwise on params in tests/test_fault.py);
  * failure injection — raise at a chosen step to exercise the path;
  * elastic re-mesh — ``TrainLoop.restore(mesh=...)`` re-device_puts the
    logical checkpoint onto a *different* mesh (data-parallel width change),
    because checkpoints store logical arrays, not device layouts;
  * straggler mitigation lives in runtime/straggler.py (bounded-delay
    gradient semantics, the paper's τ model applied to training);
  * ``RetryPolicy`` — per-link retry/timeout admission used by the
    serving pull path (repro.serving): a source shard that cannot
    deliver within its (backed-off) deadlines is dropped for the step
    and the worker falls back to its stale buffer (§4.3 bounded
    staleness) instead of stalling the request.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import numpy as np

from ..ckpt import CheckpointManager, latest_step, restore_checkpoint


@dataclasses.dataclass
class FaultConfig:
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    keep: int = 3
    fail_at_step: int | None = None      # failure injection (tests)


class SimulatedFailure(RuntimeError):
    pass


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Deadline/retry admission for one pull link.

    ``admit(wire_s)`` plays the attempts out against the modeled transfer
    time: each attempt has a deadline (``timeout_s`` growing by
    ``backoff``); an attempt whose transfer fits the deadline delivers and
    the call returns ``(True, wait_s)`` where ``wait_s`` is the time burnt
    on *earlier failed* attempts (the caller adds ``wire_s`` itself).  A
    link that never fits — a killed shard models ``wire_s = inf`` —
    returns ``(False, wait_s)`` with the full timeout budget spent, and
    the caller serves from the stale buffer instead of stalling."""

    timeout_s: float = 0.05
    retries: int = 1
    backoff: float = 2.0

    def __post_init__(self):
        if self.timeout_s <= 0:
            raise ValueError(f"timeout_s must be > 0, got {self.timeout_s}")
        if self.retries < 0:
            raise ValueError(f"retries must be >= 0, got {self.retries}")
        if self.backoff < 1.0:
            raise ValueError(f"backoff must be >= 1, got {self.backoff}")

    @property
    def budget_s(self) -> float:
        """Total time a fully failing link costs (sum of all deadlines)."""
        return sum(self.timeout_s * self.backoff ** a
                   for a in range(self.retries + 1))

    def admit(self, wire_s: float) -> tuple[bool, float]:
        deadline, wait = self.timeout_s, 0.0
        for _ in range(self.retries + 1):
            if wire_s <= deadline:
                return True, wait
            wait += deadline
            deadline *= self.backoff
        return False, wait


_CB_CLOSED, _CB_OPEN, _CB_HALF_OPEN = "closed", "open", "half_open"


class CircuitBreaker:
    """Per-link closed → open → half-open circuit over a ``RetryPolicy``.

    The PR 7 suspect set opened a link's circuit after one burnt retry
    budget and never closed it again: a killed-then-recovered shard stayed
    suspect forever unless an elastic repair intervened.  This breaker adds
    the missing half-open probe: an open link is skipped at zero cost until
    ``cooldown_s`` elapses, then exactly one trial pull is admitted.  A
    successful trial closes the circuit (direct serving restored); a failed
    one re-opens it with a *decorrelated-jitter* backoff —
    ``cooldown = min(cap, U(base, 3 × previous))`` from a seeded RNG, so
    repeated probes against a still-dead shard spread out instead of
    thundering in lockstep, and replays stay bit-deterministic.

    The clock is caller-supplied (``now``): the serving engine feeds its
    deterministic virtual request clock, so breaker transitions replay
    exactly under a fixed seed regardless of wall-clock jitter.
    """

    def __init__(self, k: int, cooldown_s: float = 0.05,
                 max_cooldown_s: float = 2.0, seed: int = 0):
        if cooldown_s <= 0:
            raise ValueError(f"cooldown_s must be > 0, got {cooldown_s}")
        if max_cooldown_s < cooldown_s:
            raise ValueError(
                f"max_cooldown_s must be >= cooldown_s, got "
                f"{max_cooldown_s}")
        self.cooldown_s = cooldown_s
        self.max_cooldown_s = max_cooldown_s
        self.rng = np.random.default_rng(seed)
        self._state = [_CB_CLOSED] * k
        self._until = np.zeros(k, np.float64)     # open expires at
        self._sleep = np.full(k, cooldown_s)      # last cooldown drawn

    @property
    def k(self) -> int:
        return len(self._state)

    def resize(self, k: int) -> None:
        if k > len(self._state):
            grow = k - len(self._state)
            self._state += [_CB_CLOSED] * grow
            self._until = np.concatenate([self._until, np.zeros(grow)])
            self._sleep = np.concatenate(
                [self._sleep, np.full(grow, self.cooldown_s)])
        else:
            self._state = self._state[:k]
            self._until = self._until[:k]
            self._sleep = self._sleep[:k]

    def state(self, link: int) -> str:
        return self._state[link]

    def open_links(self) -> tuple[int, ...]:
        return tuple(i for i, s in enumerate(self._state)
                     if s != _CB_CLOSED)

    def allow(self, link: int, now: float) -> bool:
        """May this link be pulled from right now?  An open link past its
        cooldown transitions to half-open and gets ONE trial admission."""
        s = self._state[link]
        if s == _CB_CLOSED:
            return True
        if s == _CB_OPEN and now >= self._until[link]:
            self._state[link] = _CB_HALF_OPEN
            return True
        return s == _CB_HALF_OPEN and now >= self._until[link]

    def record(self, link: int, delivered: bool, now: float) -> bool:
        """Fold one admitted attempt's outcome; returns True when this
        attempt newly OPENED the circuit (the autoscaler's repair cue)."""
        if delivered:
            self._state[link] = _CB_CLOSED
            self._sleep[link] = self.cooldown_s
            return False
        was_closed = self._state[link] == _CB_CLOSED
        if self._state[link] == _CB_HALF_OPEN:
            # failed probe: decorrelated jitter on the next cooldown
            self._sleep[link] = min(
                self.max_cooldown_s,
                float(self.rng.uniform(self.cooldown_s,
                                       3.0 * self._sleep[link])))
        self._state[link] = _CB_OPEN
        self._until[link] = now + self._sleep[link]
        return was_closed

    def reset(self, link: int) -> None:
        """Force-close one link's circuit (elastic repair replaced the
        shard; the fresh slot deserves direct serving immediately)."""
        self._state[link] = _CB_CLOSED
        self._sleep[link] = self.cooldown_s
        self._until[link] = 0.0


class TrainLoop:
    def __init__(self, train_step: Callable, fault: FaultConfig,
                 shardings=None):
        self.train_step = train_step
        self.fault = fault
        self.mgr = CheckpointManager(fault.ckpt_dir, fault.ckpt_every, fault.keep)
        self.shardings = shardings

    def resume_or(self, init_fn: Callable):
        """Restore the newest checkpoint, else initialize fresh."""
        step = latest_step(self.fault.ckpt_dir)
        if step is None:
            params, opt = init_fn()
            return 0, params, opt
        like = jax.eval_shape(init_fn)
        state = restore_checkpoint(
            self.fault.ckpt_dir, step, {"params": like[0], "opt": like[1]},
            shardings=self.shardings)
        return step, state["params"], state["opt"]

    def run(self, params, opt_state, batches, start_step: int = 0,
            log_every: int = 0):
        metrics_hist = []
        step = start_step
        for batch in batches:
            if self.fault.fail_at_step is not None and step == self.fault.fail_at_step:
                self.mgr.wait()
                raise SimulatedFailure(f"injected failure at step {step}")
            params, opt_state, metrics = self.train_step(params, opt_state, batch)
            step += 1
            self.mgr.maybe_save(step, {"params": params, "opt": opt_state})
            if log_every and step % log_every == 0:
                m = {k: float(v) for k, v in metrics.items()}
                m["step"] = step
                metrics_hist.append(m)
                print(f"step {step}: " + " ".join(f"{k}={v:.4g}" for k, v in m.items()))
        self.mgr.wait()
        return params, opt_state, metrics_hist
