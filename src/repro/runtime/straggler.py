r"""Straggler mitigation: bounded-delay gradient accumulation.

The paper's consistency model (§4.3: push/pull with maximal delay τ; §5.4:
eventual consistency scales linearly because no worker ever waits) applied
to synchronous LM training: instead of a hard barrier on the slowest data
shard, the optimizer may apply a step once ≥ (1−ε) of shard gradients have
arrived, folding late gradients into the next step with a staleness weight.

On one host we *simulate* shard arrival order to test the numerics; on a
real fleet the same accumulator sits behind per-shard async collectives.
This is the distributed-optimization analogue of DBPG's τ-delay [19].
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class StragglerConfig:
    num_shards: int = 8
    quorum: float = 0.75        # fraction of shards required to step
    max_delay: int = 2          # τ: max staleness (steps) before a hard wait
    stale_decay: float = 0.5    # weight multiplier per step of staleness


class BoundedDelayAccumulator:
    """Accumulates per-shard gradients; steps on quorum; folds stragglers in
    later with decayed weight; hard-syncs any shard older than τ."""

    def __init__(self, cfg: StragglerConfig, grad_like):
        self.cfg = cfg
        self.zero = jax.tree.map(lambda x: jnp.zeros_like(x), grad_like)
        self.pending = jax.tree.map(lambda x: jnp.zeros_like(x), grad_like)
        self.last_seen = np.zeros(cfg.num_shards, dtype=np.int64)
        self.step = 0

    def submit(self, shard: int, grads, arrived_step: int):
        staleness = max(0, self.step - arrived_step)
        if staleness > self.cfg.max_delay:
            staleness = self.cfg.max_delay  # hard-sync clamp
        w = self.cfg.stale_decay ** staleness
        self.pending = jax.tree.map(lambda a, g: a + w * g, self.pending, grads)
        self.last_seen[shard] = self.step

    def ready(self, arrived: int) -> bool:
        if arrived >= int(np.ceil(self.cfg.quorum * self.cfg.num_shards)):
            # τ guard: nobody may lag more than max_delay steps
            return bool(np.all(self.step - self.last_seen <= self.cfg.max_delay))
        return False

    def take(self, arrived: int):
        scale = 1.0 / max(arrived, 1)
        out = jax.tree.map(lambda a: a * scale, self.pending)
        self.pending = self.zero
        self.step += 1
        return out


class StragglerEWMA:
    """EWMA of per-worker scan times → block-assignment weights.

    The elastic stream composes this with the bounded-delay model above:
    instead of letting a slow worker accumulate staleness toward the τ
    clamp, the scheduler *prevents* the lag by handing it fewer blocks —
    ``weights()`` are inverse-EWMA speeds, consumed by
    ``_run_parallel_packed_scan(worker_weights=...)``.  ``floor`` bounds
    how far a worker can be starved (a 10× straggler still gets ≥ floor ×
    its fair share), so a recovered worker keeps receiving enough blocks
    for its EWMA to re-converge instead of being written off forever.
    """

    def __init__(self, workers: int, alpha: float = 0.3,
                 floor: float = 0.1):
        if workers < 1:
            raise ValueError(f"workers must be >= 1, got {workers}")
        if not 0.0 < alpha <= 1.0:
            raise ValueError(f"alpha must be in (0, 1], got {alpha}")
        if not 0.0 < floor <= 1.0:
            raise ValueError(f"floor must be in (0, 1], got {floor}")
        self.workers = workers
        self.alpha = alpha
        self.floor = floor
        self._ewma = np.zeros(workers, np.float64)   # lazy-seeded
        self._seen = np.zeros(workers, bool)

    def update(self, times: np.ndarray) -> None:
        """Fold one round of per-worker wall-clock times (seconds; NaN or
        ≤0 entries mean "no observation this round" and are skipped)."""
        times = np.asarray(times, np.float64)
        if times.shape != (self.workers,):
            raise ValueError(
                f"times must have shape ({self.workers},), got {times.shape}")
        ok = np.isfinite(times) & (times > 0)
        fresh = ok & ~self._seen
        self._ewma[fresh] = times[fresh]             # seed from first sample
        cont = ok & self._seen
        self._ewma[cont] += self.alpha * (times[cont] - self._ewma[cont])
        self._seen |= ok

    def weights(self) -> np.ndarray:
        """Per-worker speed weights (mean 1): inverse EWMA time, floored
        at ``floor`` × the fair share.  Workers never observed yet get the
        observed mean speed (no penalty before evidence)."""
        w = np.ones(self.workers, np.float64)
        if self._seen.any():
            speed = 1.0 / self._ewma[self._seen]
            w[self._seen] = speed / speed.mean()
        w = np.maximum(w, self.floor)
        return w / w.mean()
