r"""Straggler mitigation: bounded-delay gradient accumulation.

The paper's consistency model (§4.3: push/pull with maximal delay τ; §5.4:
eventual consistency scales linearly because no worker ever waits) applied
to synchronous LM training: instead of a hard barrier on the slowest data
shard, the optimizer may apply a step once ≥ (1−ε) of shard gradients have
arrived, folding late gradients into the next step with a staleness weight.

On one host we *simulate* shard arrival order to test the numerics; on a
real fleet the same accumulator sits behind per-shard async collectives.
This is the distributed-optimization analogue of DBPG's τ-delay [19].
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class StragglerConfig:
    num_shards: int = 8
    quorum: float = 0.75        # fraction of shards required to step
    max_delay: int = 2          # τ: max staleness (steps) before a hard wait
    stale_decay: float = 0.5    # weight multiplier per step of staleness


class BoundedDelayAccumulator:
    """Accumulates per-shard gradients; steps on quorum; folds stragglers in
    later with decayed weight; hard-syncs any shard older than τ."""

    def __init__(self, cfg: StragglerConfig, grad_like):
        self.cfg = cfg
        self.zero = jax.tree.map(lambda x: jnp.zeros_like(x), grad_like)
        self.pending = jax.tree.map(lambda x: jnp.zeros_like(x), grad_like)
        self.last_seen = np.zeros(cfg.num_shards, dtype=np.int64)
        self.step = 0

    def submit(self, shard: int, grads, arrived_step: int):
        staleness = max(0, self.step - arrived_step)
        if staleness > self.cfg.max_delay:
            staleness = self.cfg.max_delay  # hard-sync clamp
        w = self.cfg.stale_decay ** staleness
        self.pending = jax.tree.map(lambda a, g: a + w * g, self.pending, grads)
        self.last_seen[shard] = self.step

    def ready(self, arrived: int) -> bool:
        if arrived >= int(np.ceil(self.cfg.quorum * self.cfg.num_shards)):
            # τ guard: nobody may lag more than max_delay steps
            return bool(np.all(self.step - self.last_seen <= self.cfg.max_delay))
        return False

    def take(self, arrived: int):
        scale = 1.0 / max(arrived, 1)
        out = jax.tree.map(lambda a: a * scale, self.pending)
        self.pending = self.zero
        self.step += 1
        return out
