"""Deterministic fault injection for elastic streaming runs.

A ``ChaosSchedule`` is a seeded, pre-declared list of fleet events —
machine kills, joins, stragglers and recoveries — keyed by *feed index*,
so a chaos run is exactly reproducible: the same schedule, seed and
chunk sequence produce bit-identical partitions (asserted in CI's
``chaos-smoke`` job).  Events whose target is left unspecified are
resolved from the schedule's own RNG in declaration order, never from
global state, so resolution is part of the determinism contract.

This is the streaming analogue of ``runtime.fault.FaultConfig``'s
``fail_at_step`` — scheduled, not sampled, because robustness tests want
to replay the exact same disaster until the recovery path is boring.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["ChaosEvent", "ChaosSchedule"]

_KINDS = ("kill", "add", "straggle", "recover", "burst")


@dataclasses.dataclass(frozen=True)
class ChaosEvent:
    """One scheduled fleet event, applied just before feed ``feed``.

    ``machine`` targets a part/machine id for ``kill`` and a *worker*
    lane for ``straggle``/``recover`` (``None`` = let the schedule's RNG
    pick); ``factor`` is the straggler's slowdown multiplier.  ``add``
    events take no target — the new machine is always the split of the
    current largest part.  ``burst`` is a *load* event (serving layer
    only): ``factor`` multiplies request batch sizes from this point on
    — factor 1.0 calms the burst; streams ignore it."""

    feed: int
    kind: str
    machine: int | None = None
    factor: float = 4.0

    def __post_init__(self):
        if self.kind not in _KINDS:
            raise ValueError(
                f"kind must be one of {_KINDS}, got {self.kind!r}")
        if self.feed < 0:
            raise ValueError(f"feed must be >= 0, got {self.feed}")
        if self.kind == "straggle" and self.factor <= 1.0:
            raise ValueError(
                f"straggle factor must be > 1, got {self.factor}")
        if self.kind == "burst" and self.factor <= 0.0:
            raise ValueError(
                f"burst factor must be > 0, got {self.factor}")


class ChaosSchedule:
    """Ordered, seeded event schedule consumed by ``ElasticSession``.

    ``at(feed)`` returns the events due at one feed index in declaration
    order; each event is handed out exactly once.  Unspecified targets
    are drawn eagerly at construction (one ``integers`` call per open
    event, in declaration order) so lookup order cannot perturb the
    resolution.
    """

    def __init__(self, events: list[ChaosEvent] | tuple[ChaosEvent, ...],
                 seed: int = 0):
        rng = np.random.default_rng(seed)
        self.seed = seed
        resolved = []
        for ev in events:
            if ev.machine is None and ev.kind in ("kill", "straggle",
                                                  "recover"):
                # bound by a huge range; the session reduces modulo the
                # live fleet/worker width at apply time, so the draw stays
                # valid across k changes yet is fixed at construction
                ev = dataclasses.replace(
                    ev, machine=int(rng.integers(0, 2**31 - 1)))
            resolved.append(ev)
        self.events = tuple(sorted(resolved, key=lambda e: e.feed))
        self._served = [False] * len(self.events)

    def at(self, feed: int) -> list[ChaosEvent]:
        """Pop every not-yet-served event scheduled for ``feed``."""
        due = []
        for i, ev in enumerate(self.events):
            if ev.feed == feed and not self._served[i]:
                self._served[i] = True
                due.append(ev)
        return due

    @property
    def remaining(self) -> int:
        return sum(not s for s in self._served)

    def reset(self) -> None:
        """Re-arm every event (replay the same disaster)."""
        self._served = [False] * len(self.events)
