"""Fault-tolerant elastic Parsa serving: ``k`` becomes a runtime variable.

``ElasticSession`` wraps a ``StreamSession`` and makes the fleet mutable
mid-stream, composing primitives the repo already ships:

  * ``grow_k`` — split the largest part two ways with the same fused
    cost+select scan a feed uses (ONE jitted dispatch over just that
    part's rows); the new machine takes the second half.
  * ``shrink_k`` — OR-merge the two smallest parts (host lattice join on
    the packed words — zero dispatches) and relabel.
  * ``repair`` — worker-loss recovery that warm-starts from the
    *surviving* packed ``s_masks``: the lost row is zeroed and the lost
    part's vertices are re-assigned in ONE jitted dispatch, where §4.1
    balance naturally refills the emptied slot (its replacement
    machine); ``repartition_frac`` optionally seeds the lost subgraph's
    sample per §4.4.  Cold mode falls through to the stream's full
    ``repartition()`` — the baseline ``bench_chaos`` beats.
  * straggler-aware feeds — a ``StragglerEWMA`` of per-worker scan times
    biases the randomized block→worker assignment away from slow
    workers (``_run_parallel_packed_scan(worker_weights=...)``),
    keeping staleness inside τ instead of reacting to it.

Every mutation is metered in ``TrafficCounters.migration_bytes`` (same
4-bytes-per-32-parameters units as the steady-state counters) and gated
by an ``ElasticPolicy`` that compares the one-time cost against
projected steady-state savings BEFORE committing; uncommitted candidates
leave the live state untouched.  A ``ChaosSchedule`` drives kill/add/
straggle events deterministically through ``feed``.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from ..api_backends import TrafficCounters
from ..core.bipartite import BipartiteGraph
from ..core.jax_partition import (
    _count_dispatch,
    _partition_scan,
    pack_graph_blocks,
)
from ..core.parallel import global_initialization
from ..kernels.parsa_cost import coerce_packed_sets, packed_delta
from ..runtime.straggler import StragglerEWMA
from ..stream.online import ParsaStreamConfig, StreamSession, StreamUpdate
from .chaos import ChaosEvent, ChaosSchedule
from .policy import ElasticPolicy, FleetState, ThresholdPolicy

__all__ = ["ElasticConfig", "ElasticOp", "ElasticSession"]


@dataclasses.dataclass(frozen=True)
class ElasticConfig:
    """Elasticity knobs on top of a ``ParsaStreamConfig``.

    ``observe_wallclock=False`` (default) feeds the straggler EWMA a
    synthetic per-worker time model (1.0 × the injected slowdown factor)
    instead of measured seconds, so chaos runs are bit-deterministic
    under a fixed seed; real deployments flip it on to track actual scan
    times."""

    stream: ParsaStreamConfig
    min_k: int = 2
    max_k: int = 64
    budget_feeds: int = 32      # horizon amortizing migration cost
    ewma_alpha: float = 0.3
    ewma_floor: float = 0.1
    straggler_bias: bool = True
    observe_wallclock: bool = False

    def __post_init__(self):
        if not 1 <= self.min_k <= self.max_k:
            raise ValueError(
                f"need 1 <= min_k <= max_k, got ({self.min_k}, "
                f"{self.max_k})")
        if self.budget_feeds < 0:
            raise ValueError(
                f"budget_feeds must be >= 0, got {self.budget_feeds}")


@dataclasses.dataclass
class ElasticOp:
    """Record of one elastic action (committed or vetoed by policy)."""

    kind: str                   # "grow" | "shrink" | "repair"
    committed: bool
    k_before: int
    k_after: int
    machine: int                # split source / merge target / lost slot
    traffic: TrafficCounters    # migration_bytes of the (candidate) move
    projected_savings: int      # projected steady-state bytes saved/feed
    moved_u: int                # example rows changing machines
    seconds: float              # wall-clock of plan + (if any) commit
    mode: str = ""              # repair only: "warm" | "cold"
    partner: int = -1           # grow: new machine id; shrink: retired id
    telemetry: object = None    # closed loop: triggering TelemetrySnapshot


def _range_gather(starts: np.ndarray, counts: np.ndarray) -> np.ndarray:
    """Concatenate ``[s, s+c)`` ranges without a python loop."""
    total = int(counts.sum())
    if total == 0:
        return np.empty(0, np.int64)
    nonempty = counts > 0
    s, c = starts[nonempty].astype(np.int64), counts[nonempty].astype(np.int64)
    out = np.ones(total, np.int64)
    out[0] = s[0]
    bounds = np.cumsum(c)[:-1]
    out[bounds] = s[1:] - (s[:-1] + c[:-1]) + 1
    return np.cumsum(out)


class ElasticSession:
    """Elastic driver over one ``StreamSession`` — policy decides, the
    session executes and meters.  See the module docstring for the op
    semantics; ``ops`` records every action (including policy vetoes)."""

    def __init__(self, config: ElasticConfig, num_v: int,
                 policy: ElasticPolicy | None = None,
                 chaos: ChaosSchedule | None = None, obs=None):
        self.config = config
        self.stream = StreamSession(config.stream, num_v)
        self.policy = policy if policy is not None else ThresholdPolicy(
            min_k=config.min_k, max_k=config.max_k,
            budget_feeds=config.budget_feeds,
            straggler_bias=config.straggler_bias)
        self.chaos = chaos
        workers = config.stream.workers
        self.ewma = StragglerEWMA(workers, alpha=config.ewma_alpha,
                                  floor=config.ewma_floor)
        self._straggle = np.ones(workers, np.float64)
        self.ops: list[ElasticOp] = []
        self._n_ops = 0
        self._obs = None
        if obs is not None:
            self.obs = obs

    # ------------------------------------------------------ observability
    @property
    def obs(self):
        return self._obs

    @obs.setter
    def obs(self, value) -> None:
        # one hook covers the stack: the stream under this session traces
        # its feeds into the same sinks
        self._obs = value
        self.stream.obs = value

    def _finish_op(self, op: ElasticOp) -> ElasticOp:
        """Book one op: append to the audit trail and (with obs attached)
        emit the ``elastic_op → plan/scan/migrate`` span.  Child offsets
        are fixed fractions of a 1.0 virtual unit — host-side phases have
        no modeled duration, and fixed fractions keep seeded replays
        byte-identical; the measured seconds ride in ``wall_s``."""
        self.ops.append(op)
        if self._obs is not None:
            tr = self._obs.tracer
            sp = tr.begin("elastic_op", v_start=tr.now, v_dur=1.0,
                          track="elastic", kind=op.kind,
                          committed=op.committed, machine=op.machine,
                          k_before=op.k_before, k_after=op.k_after,
                          mode=op.mode, wall_s=op.seconds)
            sp.child("plan", 0.0, 0.4, moved_u=int(op.moved_u))
            sp.child("scan", 0.4, 0.4)
            sp.child("migrate", 0.8, 0.2,
                     migration_bytes=int(op.traffic.migration_bytes))
        return op

    # --------------------------------------------------------- delegation
    @property
    def k(self) -> int:
        return self.stream.k

    @property
    def parts(self) -> np.ndarray:
        return self.stream.parts

    @property
    def traffic(self) -> TrafficCounters:
        return self.stream.traffic

    @property
    def n_feeds(self) -> int:
        return self.stream.n_feeds

    def result(self, refine_v: bool | None = None):
        return self.stream.result(refine_v=refine_v)

    # ------------------------------------------------------------ feeding
    def feed(self, chunk: BipartiteGraph) -> StreamUpdate:
        """Apply due chaos events, then feed with straggler-biased block
        routing (parallel configs) and fold the round's per-worker times
        into the EWMA."""
        if self.chaos is not None:
            for ev in self.chaos.at(self.stream.n_feeds):
                self._apply_event(ev)
        weights = None
        workers = self.config.stream.workers
        if workers > 1:
            w = self.ewma.weights()
            weights = self.policy.rebalance(self._state(), w)
        upd = self.stream.feed(chunk, worker_weights=weights)
        if workers > 1:
            if self.config.observe_wallclock:
                # real mode: feed the MEASURED fused-dispatch wall time —
                # one observation per lane (a single host cannot separate
                # per-worker times out of one dispatch), with NO synthetic
                # straggle multiply; injected chaos straggles are invisible
                # here by design, only actual slowness registers
                wall = upd.timings.get("partition_u", float("nan"))
                self.ewma.update(np.full(workers, wall))
            else:
                # synthetic mode (default): the injected straggle factors
                # ARE the per-worker time model — bit-deterministic
                self.ewma.update(1.0 * self._straggle)
        return upd

    def _apply_event(self, ev: ChaosEvent) -> None:
        workers = self.config.stream.workers
        if ev.kind == "kill":
            self.repair(ev.machine % self.k)
        elif ev.kind == "add":
            self.grow_k(force=True)
        elif ev.kind == "straggle":
            self._straggle[ev.machine % workers] = ev.factor
        elif ev.kind == "recover":
            self._straggle[ev.machine % workers] = 1.0
        elif ev.kind == "burst":
            pass  # load events target the serving layer, not the stream

    # ------------------------------------------------------------- state
    def _state(self, migration_bytes: int = 0,
               projected_savings: int = 0) -> FleetState:
        masks = self.stream.arena.masks_np(logical=False)
        foot = np.unpackbits(
            np.ascontiguousarray(masks).view(np.uint8),
            axis=1).sum(axis=1).astype(np.int64)
        return FleetState(
            k=self.k, feed_index=self.stream.n_feeds,
            sizes=np.bincount(self.parts, minlength=self.k).astype(np.int64),
            footprint=foot, migration_bytes=migration_bytes,
            projected_savings=projected_savings)

    def _op_rng(self) -> np.random.Generator:
        # per-op stream derived from (seed, op ordinal): deterministic
        # under a fixed seed, distinct across successive ops
        return np.random.default_rng(
            [self.config.stream.base.seed, 0x454C, self._n_ops])

    # ---------------------------------------------------------- grow
    def grow_k(self, target: int | None = None,
               force: bool = False) -> ElasticOp:
        """Split one part in two; the new machine ``k`` hosts the second
        half.  ``target`` picks the part to split (the closed-loop
        autoscaler passes the hottest footprint); default is the largest
        part.  ONE jitted ``_partition_scan`` dispatch over the split
        part's rows (exact neighbor sets for both halves come out of the
        scan's S carry).  Commits only when the policy accepts the
        metered migration cost (or ``force=True``)."""
        t0 = time.perf_counter()
        base = self.config.stream.base
        arena = self.stream.arena
        k = self.k
        parts = self.parts
        sizes = np.bincount(parts, minlength=k)
        if target is not None and 0 <= target < k and sizes[target] >= 2:
            src = int(target)
        else:
            src = int(np.argmax(sizes))
        rows = np.flatnonzero(parts == src)
        if rows.size < 2:
            op = ElasticOp("grow", False, k, k, src, TrafficCounters(),
                           0, 0, time.perf_counter() - t0)
            self._finish_op(op)
            return op
        g = arena.graph()
        sub_indptr, counts, sub_indices = self._sub_csr(g, rows)
        g_cap = BipartiteGraph(rows.size, arena.capacity_v, sub_indptr,
                               sub_indices)
        rng = self._op_rng()
        self._n_ops += 1
        order = rng.permutation(rows.size)
        packed = pack_graph_blocks(g_cap, base.block_size, order=order,
                                   cap=base.cap,
                                   tb_pad=self.config.stream.tb_pad)
        import jax.numpy as jnp

        _count_dispatch("elastic_grow_scan",
                        nbytes=int(packed.valid.nbytes), rows=int(rows.size),
                        machine=int(src))
        parts2, m2, _ = _partition_scan(
            jnp.asarray(packed.valid), jnp.asarray(packed.widx),
            jnp.asarray(packed.vals), jnp.asarray(packed.trunc),
            jnp.asarray(packed.tr_ids), jnp.asarray(packed.tr_masks),
            jnp.zeros((2, arena.W_cap), jnp.int32),
            jnp.zeros((2,), jnp.int32),
            k=2, use_kernel=base.use_kernel, interpret=base.interpret,
            sketch=self.stream.sketch is not None)
        half = np.empty(rows.size, np.int32)
        half[order] = np.asarray(parts2).reshape(-1)[: rows.size]
        m2 = np.asarray(m2)
        old_masks = arena.masks_np(logical=False)
        new_masks = np.concatenate([old_masks, m2[1:2]], axis=0)
        new_masks[src] = m2[0]
        new_parts = parts.copy()
        moved = rows[half == 1]
        new_parts[moved] = k
        moved_edges = int(counts[half == 1].sum())
        acquired = 4 * int(np.count_nonzero(m2[1])) + 4 * moved_edges
        retired = 4 * int(np.count_nonzero(packed_delta(old_masks[src],
                                                        m2[0])))
        migration = acquired + retired
        foot_after = self._foot_after(old_masks, {src: m2[0]},
                                      extra=m2[1])
        savings = self._max_foot_savings(old_masks, foot_after)
        state = self._state(migration, savings)
        committed = bool(force or self.policy.grow(state))
        if committed:
            self.stream.apply_partition_state(new_parts, new_masks,
                                              k=k + 1)
            self.stream._accumulate(
                TrafficCounters(tasks=1, migration_bytes=migration))
        op = ElasticOp("grow", committed, k, k + 1 if committed else k,
                       src, TrafficCounters(tasks=1,
                                            migration_bytes=migration),
                       savings, int(moved.size),
                       time.perf_counter() - t0, partner=k)
        self._finish_op(op)
        return op

    # ---------------------------------------------------------- shrink
    def shrink_k(self, force: bool = False) -> ElasticOp:
        """Merge the two smallest parts (machine ``j`` retires into
        machine ``i``): a host OR on the packed words plus a relabel —
        zero scan dispatches.  Projected savings are the de-duplicated
        parameters the fleet stops hosting twice."""
        t0 = time.perf_counter()
        k = self.k
        if k <= max(1, self.config.min_k - 1) or k <= 1:
            op = ElasticOp("shrink", False, k, k, -1, TrafficCounters(),
                           0, 0, time.perf_counter() - t0)
            self._finish_op(op)
            return op
        parts = self.parts
        sizes = np.bincount(parts, minlength=k)
        a, b = np.argsort(sizes, kind="stable")[:2]
        i, j = int(min(a, b)), int(max(a, b))
        arena = self.stream.arena
        old_masks = arena.masks_np(logical=False)
        merged = old_masks[i] | old_masks[j]
        new_masks = np.delete(old_masks, j, axis=0)
        new_masks[i] = merged
        new_parts = parts.copy()
        new_parts[new_parts == j] = i
        new_parts[new_parts > j] -= 1
        g = arena.graph()
        deg = np.diff(g.u_indptr)
        moved_rows = np.flatnonzero(parts == j)
        moved_edges = int(deg[moved_rows].sum())
        acquired = 4 * int(np.count_nonzero(
            packed_delta(old_masks[j], old_masks[i]))) + 4 * moved_edges
        retired = 4 * int(np.count_nonzero(old_masks[j]))
        migration = acquired + retired
        # de-duplicated hosting: params both machines carried, now one
        overlap_words = old_masks[i] & old_masks[j]
        savings = int(np.unpackbits(
            np.ascontiguousarray(overlap_words).view(np.uint8)).sum()) // 8
        state = self._state(migration, savings)
        committed = bool(force or self.policy.shrink(state))
        if committed:
            self.stream.apply_partition_state(new_parts, new_masks,
                                              k=k - 1)
            self.stream._accumulate(
                TrafficCounters(tasks=1, migration_bytes=migration))
        op = ElasticOp("shrink", committed, k, k - 1 if committed else k,
                       i, TrafficCounters(tasks=1,
                                          migration_bytes=migration),
                       savings, int(moved_rows.size),
                       time.perf_counter() - t0, partner=j)
        self._finish_op(op)
        return op

    # ---------------------------------------------------------- repair
    def repair(self, machine: int, mode: str | None = None) -> ElasticOp:
        """Recover from losing ``machine``.  Warm mode zeroes the lost
        row in the surviving packed sets and re-assigns the lost part's
        vertices in ONE jitted dispatch — §4.1 balance refills the empty
        slot (the replacement machine) and ``repartition_frac > 0``
        additionally seeds the lost subgraph's §4.4 sample.  Cold mode is
        the stream's full ``repartition()`` (the benchmark baseline).
        Repair always commits: the machine is already gone."""
        t0 = time.perf_counter()
        k = self.k
        if not 0 <= machine < k:
            raise ValueError(f"machine must be in [0, {k}), got {machine}")
        if mode is None:
            mode = self.policy.repair(self._state())
        if mode not in ("warm", "cold"):
            raise ValueError(f"repair mode must be warm|cold, got {mode!r}")
        if mode == "cold":
            plan = self.stream.repartition()
            op = ElasticOp("repair", True, k, k, machine, plan.traffic,
                           0, plan.moved_u, time.perf_counter() - t0,
                           mode="cold")
            self._finish_op(op)
            return op

        import jax.numpy as jnp

        base = self.config.stream.base
        arena = self.stream.arena
        parts = self.parts
        rows = np.flatnonzero(parts == machine)
        old_masks = arena.masks_np(logical=False)
        masks = old_masks.copy()
        masks[machine] = 0
        survivors = masks.copy()    # pre-seed baseline for the metering
        sizes_live = np.asarray(arena.sizes).copy()
        sizes_live[machine] = 0
        if rows.size == 0:
            self.stream.apply_partition_state(parts.copy(),
                                              masks, sizes=sizes_live, k=k)
            op = ElasticOp("repair", True, k, k, machine,
                           TrafficCounters(tasks=1), 0, 0,
                           time.perf_counter() - t0, mode="warm")
            self._finish_op(op)
            return op
        g = arena.graph()
        sub_indptr, counts, sub_indices = self._sub_csr(g, rows)
        frac = self.config.stream.repartition_frac
        if frac > 0:
            g_sub = BipartiteGraph(rows.size, arena.num_v, sub_indptr,
                                   sub_indices)
            dense = global_initialization(
                g_sub, k, sample_frac=frac, theta=base.theta,
                select=base.select, seed=base.seed)
            seeded = coerce_packed_sets(dense, arena.num_v)
            masks |= np.pad(
                seeded, [(0, 0), (0, arena.W_cap - seeded.shape[1])])
            self.stream._need_exact = False
        g_cap = BipartiteGraph(rows.size, arena.capacity_v, sub_indptr,
                               sub_indices)
        rng = self._op_rng()
        self._n_ops += 1
        order = rng.permutation(rows.size)
        packed = pack_graph_blocks(g_cap, base.block_size, order=order,
                                   cap=base.cap,
                                   tb_pad=self.config.stream.tb_pad)
        _count_dispatch("elastic_repair_scan",
                        nbytes=int(packed.valid.nbytes), rows=int(rows.size),
                        machine=int(machine))
        parts_sub, s_out, sz_out = _partition_scan(
            jnp.asarray(packed.valid), jnp.asarray(packed.widx),
            jnp.asarray(packed.vals), jnp.asarray(packed.trunc),
            jnp.asarray(packed.tr_ids), jnp.asarray(packed.tr_masks),
            jnp.asarray(masks), jnp.asarray(sizes_live),
            k=k, use_kernel=base.use_kernel, interpret=base.interpret,
            sketch=self.stream.sketch is not None)
        assigned = np.empty(rows.size, np.int32)
        assigned[order] = np.asarray(parts_sub).reshape(-1)[: rows.size]
        new_parts = parts.copy()
        new_parts[rows] = assigned
        new_masks = np.asarray(s_out)
        # every lost row re-materializes somewhere (even slot `machine` is
        # a fresh replacement), so all its edges are re-fetched; survivors
        # only gain words under the OR-monotone scan, nothing retires
        acquired = (4 * int(np.count_nonzero(packed_delta(new_masks,
                                                          survivors)))
                    + 4 * int(counts.sum()))
        self.stream.apply_partition_state(
            new_parts, new_masks, sizes=np.asarray(sz_out), k=k)
        self.stream._accumulate(
            TrafficCounters(tasks=1, migration_bytes=acquired))
        op = ElasticOp("repair", True, k, k, machine,
                       TrafficCounters(tasks=1, migration_bytes=acquired),
                       0, int(rows.size), time.perf_counter() - t0,
                       mode="warm")
        self._finish_op(op)
        return op

    # ---------------------------------------------------------- PS bridge
    def sync_cluster(self, cluster, parts_v: np.ndarray | None = None) -> dict:
        """Push the current elastic placement into a ``PSCluster`` serving
        the fed graph — metered re-shard, shard teardown/spawn when the
        machine count changed (``apply_placement(..., k=self.k)``)."""
        n = int(cluster.parts_u.shape[0])
        if n != self.parts.shape[0]:
            raise ValueError(
                f"cluster serves {n} rows but the stream holds "
                f"{self.parts.shape[0]}")
        if parts_v is None:
            parts_v = np.full(cluster.parts_v.shape[0], -1, np.int32)
        return cluster.apply_placement(self.parts.copy(), parts_v, k=self.k)

    # ------------------------------------------------------------ helpers
    @staticmethod
    def _sub_csr(g: BipartiteGraph, rows: np.ndarray):
        indptr = np.asarray(g.u_indptr, np.int64)
        indices = np.asarray(g.u_indices)
        counts = (indptr[rows + 1] - indptr[rows]).astype(np.int64)
        sub_indptr = np.zeros(rows.size + 1, np.int64)
        np.cumsum(counts, out=sub_indptr[1:])
        sub_indices = indices[_range_gather(indptr[rows], counts)]
        return sub_indptr, counts, sub_indices

    @staticmethod
    def _foot_after(old_masks: np.ndarray, replaced: dict,
                    extra: np.ndarray | None = None) -> np.ndarray:
        rows = [replaced.get(i, old_masks[i])
                for i in range(old_masks.shape[0])]
        if extra is not None:
            rows.append(extra)
        stack = np.ascontiguousarray(np.stack(rows))
        return np.unpackbits(stack.view(np.uint8),
                             axis=1).sum(axis=1).astype(np.int64)

    @staticmethod
    def _max_foot_savings(old_masks: np.ndarray,
                          foot_after: np.ndarray) -> int:
        before = np.unpackbits(
            np.ascontiguousarray(old_masks).view(np.uint8),
            axis=1).sum(axis=1).astype(np.int64)
        # serving traffic scales with the max per-machine footprint
        # (objective (6)); /8 converts parameters to TrafficCounters
        # bytes (4 B per 32 params)
        return max(0, int(before.max() - foot_after.max())) // 8
