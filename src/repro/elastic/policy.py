"""Pluggable elasticity policies (Parla's ``PartitioningAlgorithm`` shape).

Parla's partitioning layer separates the *algorithm object* — an
introspectable class exposing sizing properties (``n_partitions``,
``neighborhood_size``) next to per-element decision methods
(``get_vertex_master``/``get_edge_master``) — from the driver that runs
it.  ``ElasticPolicy`` mirrors that shape for fleet elasticity: sizing
bounds (``min_partitions``/``max_partitions``) as properties, one
decision method per elastic event (``grow``/``shrink``/``repair``/
``rebalance``), and a driver (``repro.elastic.ElasticSession``) that
consults the policy but owns all mechanism.

Every decision sees the same ``FleetState`` snapshot, which includes the
*metered* migration cost of the candidate action (``TrafficCounters``
units, 4 bytes per 32 parameters) and the projected steady-state savings
per feed — so policies weigh a one-time re-shard against its recurring
payoff instead of guessing.
"""
from __future__ import annotations

import dataclasses
from typing import Protocol, runtime_checkable

import numpy as np

__all__ = ["FleetState", "ElasticPolicy", "ThresholdPolicy"]


@dataclasses.dataclass(frozen=True)
class FleetState:
    """What a policy sees when deciding one elastic action.

    ``migration_bytes``/``projected_savings`` are zero for decisions with
    no candidate plan attached (``rebalance``); ``projected_savings`` is
    the estimated per-feed steady-state byte reduction the candidate
    action buys (serving traffic scales with the max per-machine
    footprint for grow, with retired duplication for shrink)."""

    k: int                      # current machine count
    feed_index: int             # feeds consumed so far
    sizes: np.ndarray           # (k,) U rows per machine
    footprint: np.ndarray       # (k,) hosted parameters per machine
    migration_bytes: int = 0    # metered cost of the candidate action
    projected_savings: int = 0  # projected steady-state bytes saved / feed


@runtime_checkable
class ElasticPolicy(Protocol):
    """Decision protocol for the elastic driver — mechanism-free.

    Implementations return plain booleans (``grow``/``shrink``), a mode
    string (``repair``), or adjusted worker weights (``rebalance``); the
    session performs the actual split/merge/scan and meters the traffic.
    """

    @property
    def min_partitions(self) -> int: ...

    @property
    def max_partitions(self) -> int: ...

    def grow(self, state: FleetState) -> bool:
        """Commit the candidate largest-part split (k → k+1)?"""
        ...

    def shrink(self, state: FleetState) -> bool:
        """Commit the candidate smallest-pair merge (k → k−1)?"""
        ...

    def repair(self, state: FleetState) -> str:
        """Recovery mode after a worker loss: ``"warm"`` (§4.4 repair
        from surviving sets, one dispatch) or ``"cold"`` (full
        repartition of the arena)."""
        ...

    def rebalance(self, state: FleetState,
                  weights: np.ndarray) -> np.ndarray | None:
        """Adjust (or veto, by returning None) the straggler-EWMA block
        weights for the next parallel feed."""
        ...


@dataclasses.dataclass
class ThresholdPolicy:
    """Default policy: amortize migration cost over a feed horizon.

    Grow/shrink commit when the candidate's one-time ``migration_bytes``
    pays for itself within ``budget_feeds`` feeds of projected steady-
    state savings (and the fleet stays inside the sizing bounds).  Repair
    is always warm — the whole point of keeping surviving ``s_masks`` —
    and rebalance passes the EWMA weights through unchanged when
    ``straggler_bias`` is on.
    """

    min_k: int = 2
    max_k: int = 64
    budget_feeds: int = 32
    straggler_bias: bool = True

    @property
    def min_partitions(self) -> int:
        return self.min_k

    @property
    def max_partitions(self) -> int:
        return self.max_k

    def grow(self, state: FleetState) -> bool:
        if state.k + 1 > self.max_k:
            return False
        return (state.migration_bytes
                <= self.budget_feeds * state.projected_savings)

    def shrink(self, state: FleetState) -> bool:
        if state.k - 1 < self.min_k:
            return False
        return (state.migration_bytes
                <= self.budget_feeds * state.projected_savings)

    def repair(self, state: FleetState) -> str:
        return "warm"

    def rebalance(self, state: FleetState,
                  weights: np.ndarray) -> np.ndarray | None:
        return weights if self.straggler_bias else None
