"""repro.elastic: fault-tolerant elastic Parsa serving.

Makes the machine count ``k`` a runtime variable over a live streaming
partition: machines join (``grow_k``), leave (``shrink_k``), die
(``repair`` — warm §4.4 recovery from surviving packed sets), and
straggle (EWMA-biased block routing) mid-stream, with every move metered
in ``TrafficCounters.migration_bytes`` and gated by a pluggable
``ElasticPolicy``.  ``ChaosSchedule`` injects deterministic kill/add/
straggle events for robustness testing (``benchmarks/bench_chaos.py``,
CI ``chaos-smoke``).
"""
from .autoscaler import (  # noqa: F401
    AutoscaleDecision,
    SLOAutoscaler,
    SLOConfig,
)
from .chaos import ChaosEvent, ChaosSchedule  # noqa: F401
from .policy import ElasticPolicy, FleetState, ThresholdPolicy  # noqa: F401
from .session import ElasticConfig, ElasticOp, ElasticSession  # noqa: F401

__all__ = [
    "AutoscaleDecision",
    "ChaosEvent",
    "ChaosSchedule",
    "ElasticConfig",
    "ElasticOp",
    "ElasticPolicy",
    "ElasticSession",
    "FleetState",
    "SLOAutoscaler",
    "SLOConfig",
    "ThresholdPolicy",
]
