"""Closed-loop SLO autoscaler: the policy layer over elastic mechanisms.

PR 6 landed the *mechanisms* — grow/shrink/repair/straggler bias — gated
by a byte-budget ``ThresholdPolicy``; a kill or load burst was survived
by mechanism, not by a controller holding a user-facing SLO.
``SLOAutoscaler`` closes that gap: it implements the same
``ElasticPolicy`` protocol (so an ``ElasticSession`` constructed with it
consults the autoscaler before committing any move), but decides from
*windowed serving telemetry* rather than byte budgets:

  * **grow** on sustained SLO violation — ``patience`` consecutive
    decision windows with modeled sliding-window p99 over ``slo_ms``;
    the split target is the hottest part by live popcount footprint
    (``TelemetrySnapshot.hot_part``), because serving traffic scales
    with the max per-machine footprint (objective (6));
  * **shrink** on sustained underutilization — ``shrink_patience``
    windows with p99 under ``shrink_p99_frac × SLO`` *and* every NIC
    backlog under ``shrink_occupancy`` seconds;
  * **repair** immediately on circuit-open — not here but in the serving
    source's end-of-slot hook (``PSRequestSource.after_slot``), because
    a dead shard must not wait for the next decision window; the
    autoscaler records the repair (``note_repair``) for the audit trail;
  * **rebalance** on EWMA drift — when the slowest machine's telemetry
    speed falls below ``1/drift_ratio`` of the mean, the decision hands
    the speed weights to the router's weighted round-robin so slow
    machines see proportionally fewer requests.

Decisions from sampled/windowed observations rather than exact global
state is justified by the randomized-assignment guarantees the paper
builds on (arXiv:1502.02606): the windowed p99 concentrates around the
true tail as long as windows span enough requests.

Every ``decide`` call appends ``(snapshot, decision)`` to ``decisions``;
committed elastic ops additionally carry the triggering snapshot in
``ElasticOp.telemetry`` — together they make a seeded ``ChaosSchedule``
replay auditable and bit-deterministic end to end (``bench_slo``).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .policy import FleetState

__all__ = ["SLOConfig", "AutoscaleDecision", "SLOAutoscaler"]


@dataclasses.dataclass(frozen=True)
class SLOConfig:
    """Knobs of the closed loop.  All counting is in *decision windows*
    (one per ``decide_every`` engine slots), not requests."""

    slo_ms: float                    # the p99 latency target (modeled ms)
    window_requests: int = 64        # telemetry sliding-window size
    decide_every: int = 16           # engine slots between decisions
    warmup_windows: int = 2          # windows before the loop may act
    patience: int = 2                # hot windows before a grow
    shrink_patience: int = 4         # cold windows before a shrink
    cooldown_windows: int = 2        # windows to hold after any op
    shrink_p99_frac: float = 0.4     # cold: p99 < frac × SLO ...
    shrink_occupancy_s: float = 0.01  # ... and every backlog under this
    min_k: int = 2
    max_k: int = 64
    drift_ratio: float = 2.0         # slowest/mean speed gap → rebalance
    tau_escalation: int = 8          # engine slots of widened staleness
    # observability hook (repro.obs.Observability); excluded from
    # equality/hash so configs stay comparable and frozen-hashable
    obs: object = dataclasses.field(default=None, compare=False,
                                    repr=False)

    def __post_init__(self):
        if self.slo_ms <= 0:
            raise ValueError(f"slo_ms must be > 0, got {self.slo_ms}")
        if self.decide_every < 1:
            raise ValueError(
                f"decide_every must be >= 1, got {self.decide_every}")
        if self.patience < 1 or self.shrink_patience < 1:
            raise ValueError("patience knobs must be >= 1")
        if not 1 <= self.min_k <= self.max_k:
            raise ValueError(
                f"need 1 <= min_k <= max_k, got ({self.min_k}, "
                f"{self.max_k})")
        if not 0.0 < self.shrink_p99_frac < 1.0:
            raise ValueError(
                f"shrink_p99_frac must be in (0, 1), got "
                f"{self.shrink_p99_frac}")
        if self.drift_ratio <= 1.0:
            raise ValueError(
                f"drift_ratio must be > 1, got {self.drift_ratio}")


@dataclasses.dataclass(frozen=True)
class AutoscaleDecision:
    """One decision-window outcome, paired with its snapshot in
    ``SLOAutoscaler.decisions``."""

    action: str          # "hold" | "grow" | "shrink" | "rebalance"
    target: int = -1     # grow: part to split; rebalance/hold: unused
    reason: str = ""


class SLOAutoscaler:
    """``ElasticPolicy`` whose grow/shrink consent is armed by its own
    ``decide`` loop.

    The two roles compose: the serving source calls ``decide(snapshot)``
    each decision window; when the decision is grow/shrink the source
    calls ``approve(action)`` and then the session's ``grow_k``/
    ``shrink_k`` — whose policy consult (``self.policy.grow(state)``)
    lands back here and succeeds exactly once for the armed action.  Any
    *other* caller asking the session to grow/shrink while no decision is
    armed is refused, so the autoscaler genuinely owns elasticity."""

    def __init__(self, config: SLOConfig):
        self.config = config
        self.obs = config.obs
        self.decisions: list[tuple[object, AutoscaleDecision]] = []
        self.repairs: list[tuple[object, int]] = []
        self._hot = 0          # consecutive over-SLO windows
        self._cold = 0         # consecutive underutilized windows
        self._cooldown = 0     # windows left to hold after an op
        self._windows = 0      # decision windows seen
        self._pending: str | None = None

    # ------------------------------------------------- ElasticPolicy
    @property
    def min_partitions(self) -> int:
        return self.config.min_k

    @property
    def max_partitions(self) -> int:
        return self.config.max_k

    def approve(self, action: str) -> None:
        """Arm one pending action; the next matching policy consult
        consumes it (single-shot consent)."""
        if action not in ("grow", "shrink"):
            raise ValueError(f"cannot approve {action!r}")
        self._pending = action

    def grow(self, state: FleetState) -> bool:
        if self._pending == "grow" and state.k < self.config.max_k:
            self._pending = None
            return True
        return False

    def shrink(self, state: FleetState) -> bool:
        if self._pending == "shrink" and state.k > self.config.min_k:
            self._pending = None
            return True
        return False

    def repair(self, state: FleetState) -> str:
        return "warm"   # circuit-open repair must be fast: always §4.4

    def rebalance(self, state: FleetState,
                  weights: np.ndarray) -> np.ndarray | None:
        return weights

    # ------------------------------------------------- the closed loop
    def note_repair(self, snapshot, machine: int) -> None:
        """Record a circuit-open repair the serving source executed; the
        loop holds one cooldown so the repaired fleet's window drains
        before the next grow/shrink."""
        self.repairs.append((snapshot, machine))
        self._cooldown = max(self._cooldown,
                             self.config.cooldown_windows)
        self._hot = self._cold = 0

    def decide(self, snap) -> AutoscaleDecision:
        """Fold one decision window; returns the action to take."""
        cfg = self.config
        self._windows += 1
        decision = AutoscaleDecision("hold")
        if self._windows <= cfg.warmup_windows or snap.window == 0:
            decision = AutoscaleDecision("hold", reason="warmup")
        elif self._cooldown > 0:
            self._cooldown -= 1
            decision = AutoscaleDecision("hold", reason="cooldown")
        else:
            p99 = snap.p99_ms
            if p99 > cfg.slo_ms:
                self._hot += 1
                self._cold = 0
            elif (p99 < cfg.shrink_p99_frac * cfg.slo_ms
                  and snap.max_occupancy < cfg.shrink_occupancy_s):
                self._cold += 1
                self._hot = 0
            else:
                self._hot = self._cold = 0
            if self._hot >= cfg.patience and snap.k < cfg.max_k:
                decision = AutoscaleDecision(
                    "grow", target=snap.hot_part,
                    reason=f"p99 {p99:.1f}ms > SLO {cfg.slo_ms:.1f}ms "
                           f"for {self._hot} windows")
                self._hot = 0
                self._cooldown = cfg.cooldown_windows
            elif self._cold >= cfg.shrink_patience and snap.k > cfg.min_k:
                decision = AutoscaleDecision(
                    "shrink",
                    reason=f"p99 {p99:.1f}ms < "
                           f"{cfg.shrink_p99_frac:.0%} of SLO and idle "
                           f"NICs for {self._cold} windows")
                self._cold = 0
                self._cooldown = cfg.cooldown_windows
            elif snap.speeds and min(snap.speeds) * cfg.drift_ratio < 1.0:
                decision = AutoscaleDecision(
                    "rebalance",
                    reason=f"slowest machine at "
                           f"{min(snap.speeds):.2f}x mean speed")
        self.decisions.append((snap, decision))
        if self.obs is not None:
            self.obs.record(
                "decision", step=snap.step,
                window=len(self.decisions) - 1, action=decision.action,
                target=decision.target, reason=decision.reason,
                p99_ms=float(snap.p99_ms), k=snap.k)
        return decision
