"""Model facade: build_model(cfg) → init / loss_fn / prefill / decode_step,
plus input_specs() for the dry-run (ShapeDtypeStruct stand-ins, zero alloc).

Batch formats
  train   : {"tokens": (B,S) i32, "labels": (B,S) i32}
            (+ "frames" (B,Se,D) for encdec, "patches" (B,P,D) for vlm)
  decode  : {"token": (B,1) i32, "pos": () i32, "cache": pytree}
            (+ "frames"/"patches" folded into the cache at prefill time)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from . import layers as LL
from . import transformer as TR
from .shardctx import constrain


def _dt(cfg):
    return jnp.dtype(cfg.dtype)


@dataclasses.dataclass
class Model:
    cfg: ModelConfig

    # ------------------------------------------------------------- params
    def init(self, key) -> dict:
        cfg = self.cfg
        ks = jax.random.split(key, 6)
        D, V = cfg.d_model, cfg.padded_vocab
        params: dict[str, Any] = {
            "embed": (jax.random.normal(ks[0], (V, D)) * 0.02).astype(jnp.float32),
            "final_norm": LL.init_norm(cfg),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = (jax.random.normal(ks[1], (D, V)) * 0.02 / np.sqrt(D)).astype(jnp.float32)
        fam = cfg.family
        if fam in ("dense", "moe", "vlm"):
            params["stack"] = TR.init_dense_stack(ks[2], cfg)
        elif fam == "encdec":
            params["enc"] = TR.init_dense_stack(ks[2], cfg, n_layers=cfg.encoder_layers)
            params["enc_norm"] = LL.init_norm(cfg)
            params["stack"] = TR.init_dense_stack(ks[3], cfg, cross=True)
        elif fam == "xlstm":
            params["stack"] = TR.init_xlstm_stack(ks[2], cfg)
        elif fam == "hybrid":
            params["stack"] = TR.init_hybrid_stack(ks[2], cfg)
        else:
            raise ValueError(fam)
        return params

    def param_count(self, params) -> int:
        return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(params))

    # ------------------------------------------------------------- helpers
    def _embed(self, params, tokens):
        cfg = self.cfg
        x = jnp.take(params["embed"], tokens, axis=0).astype(_dt(cfg))
        return constrain(x, "batch", None, None)

    def _logits(self, params, x):
        cfg = self.cfg
        from .shardctx import bf16_grad_barrier
        x = LL.apply_norm(params["final_norm"], x, cfg.norm)
        x = bf16_grad_barrier(x)  # the f32 dlogits dx re-types here (§Perf #7)
        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        logits = jnp.einsum("bsd,dv->bsv", x, head.astype(_dt(cfg)))
        return constrain(logits, "batch", None, "vocab")

    def _encode(self, params, frames):
        """Whisper encoder over precomputed frame embeddings (stub frontend)."""
        cfg = self.cfg
        B, Se, D = frames.shape
        x = frames.astype(_dt(cfg)) + LL.sinusoidal_positions(Se, D).astype(_dt(cfg))
        pos = jnp.broadcast_to(jnp.arange(Se)[None], (B, Se))
        x, _, _ = TR.apply_dense_stack(params["enc"], x, cfg, pos, causal=False)
        x = LL.apply_norm(params["enc_norm"], x, cfg.norm)
        return x

    def _cross_kv(self, params, enc_out):
        """Precompute per-layer cross-attention k/v from encoder output."""
        cfg = self.cfg
        dt = _dt(cfg)

        def one(pl_):
            p = pl_["xattn"]
            k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"].astype(dt))
            v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"].astype(dt))
            if "bk" in p:
                k, v = k + p["bk"].astype(dt), v + p["bv"].astype(dt)
            return (k, v)

        return jax.lax.map(one, params["stack"])

    def _backbone(self, params, x, positions, *, caches=None, cache_len=None,
                  cross_kv=None):
        cfg = self.cfg
        fam = cfg.family
        if fam in ("dense", "moe", "vlm"):
            return TR.apply_dense_stack(params["stack"], x, cfg, positions,
                                        caches=caches, cache_len=cache_len)
        if fam == "encdec":
            return TR.apply_dense_stack(params["stack"], x, cfg, positions,
                                        caches=caches, cache_len=cache_len,
                                        cross_kv=cross_kv)
        if fam == "xlstm":
            x, st = TR.apply_xlstm_stack(params["stack"], x, cfg, states=caches)
            return x, st, jnp.zeros((), jnp.float32)
        if fam == "hybrid":
            x, st = TR.apply_hybrid_stack(params["stack"], x, cfg, positions,
                                          states=caches, cache_len=cache_len)
            return x, st, jnp.zeros((), jnp.float32)
        raise ValueError(fam)

    # ------------------------------------------------------------- train
    def loss_fn(self, params, batch):
        cfg = self.cfg
        tokens, labels = batch["tokens"], batch["labels"]
        B, S = tokens.shape
        x = self._embed(params, tokens)
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        cross_kv = None
        if cfg.family == "encdec":
            enc_out = self._encode(params, batch["frames"])
            cross_kv = self._cross_kv(params, enc_out)
        if cfg.family == "vlm":
            patches = batch["patches"].astype(_dt(cfg))
            x = jnp.concatenate([patches, x], axis=1)
            P = patches.shape[1]
            S = S + P
            positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
            labels = jnp.concatenate(
                [jnp.full((B, P), -1, labels.dtype), labels], axis=1)
        if cfg.family == "encdec":
            x = x + LL.sinusoidal_positions(S, cfg.d_model).astype(x.dtype)
        x, _, aux = self._backbone(params, x, positions, cross_kv=cross_kv)
        logits = self._logits(params, x)
        mask = (labels >= 0).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
        gold = jnp.take_along_axis(
            logits.astype(jnp.float32), jnp.maximum(labels, 0)[..., None], axis=-1
        )[..., 0]
        nll = (logz - gold) * mask
        loss = jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)
        if cfg.num_experts:
            loss = loss + 0.01 * aux / max(cfg.num_layers, 1)
        return loss, {"loss": loss, "tokens": jnp.sum(mask)}

    # ------------------------------------------------------------- serve
    def init_cache(self, batch: int, cache_seq: int, ring: bool = False):
        cfg = self.cfg
        dt = _dt(cfg)
        if cfg.family in ("dense", "moe", "vlm"):
            c = TR.init_kv_caches(cfg, batch, cache_seq, dtype=dt)
            if ring and not cfg.mla:
                L = cfg.num_layers
                c["kpos"] = jnp.full((L, cache_seq), -(2**30), jnp.int32)
            return c
        if cfg.family == "encdec":
            return {
                "self": TR.init_kv_caches(cfg, batch, cache_seq, dtype=dt),
                "cross": None,  # filled by prefill
            }
        if cfg.family == "xlstm":
            return TR.init_xlstm_states(cfg, batch)
        if cfg.family == "hybrid":
            return TR.init_hybrid_states(cfg, batch, cache_seq, dtype=dt)
        raise ValueError(cfg.family)

    def decode_step(self, params, batch):
        """One token against a populated cache. batch: token (B,1), pos (),
        cache pytree (+ 'cross' kv for encdec)."""
        cfg = self.cfg
        token, pos, cache = batch["token"], batch["pos"], batch["cache"]
        B = token.shape[0]
        x = self._embed(params, token)
        positions = jnp.broadcast_to(pos[None, None], (B, 1)).astype(jnp.int32)
        if cfg.family == "encdec":
            x = x + jax.lax.dynamic_slice_in_dim(
                LL.sinusoidal_positions(cache["self"]["k"].shape[2], cfg.d_model),
                pos, 1, axis=0).astype(x.dtype)[None]
            caches, cross_kv = cache["self"], cache["cross"]
            ring_caches = dict(caches)
            x, new_caches, _ = self._backbone(params, x, positions,
                                              caches=ring_caches, cache_len=pos,
                                              cross_kv=cross_kv)
            new_cache = {"self": new_caches, "cross": cross_kv}
        else:
            per_layer = cache
            if cfg.family in ("dense", "moe", "vlm") and "kpos" in cache:
                per_layer = cache  # scan consumes the stacked kpos too
            x, new_caches, _ = self._backbone(params, x, positions,
                                              caches=per_layer, cache_len=pos)
            new_cache = new_caches
        logits = self._logits(params, x)
        if cfg.padded_vocab != cfg.vocab_size:
            # never sample a padding row
            pad_mask = jnp.arange(cfg.padded_vocab) >= cfg.vocab_size
            logits = jnp.where(pad_mask[None, None], -1e30, logits)
        return logits[:, 0], new_cache

    def prefill(self, params, batch):
        """Populate a cache from a full prompt (also used by serve tests)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        cache_seq = batch.get("cache_seq", S)
        x = self._embed(params, tokens)
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        if cfg.family == "encdec":
            enc_out = self._encode(params, batch["frames"])
            cross_kv = self._cross_kv(params, enc_out)
            x = x + LL.sinusoidal_positions(S, cfg.d_model).astype(x.dtype)
            caches = TR.init_kv_caches(cfg, B, cache_seq, dtype=_dt(cfg))
            x, new_caches, _ = self._backbone(params, x, positions,
                                              caches=caches, cache_len=0,
                                              cross_kv=cross_kv)
            cache = {"self": new_caches, "cross": cross_kv}
        elif cfg.family in ("xlstm", "hybrid"):
            # Recurrent families: the parallel train path does not thread
            # final states out; the serving driver (launch/serve.py) warms
            # caches by stepping decode_step over the prompt instead.
            raise NotImplementedError(
                "prefill for recurrent families goes through launch/serve.py")
        else:
            caches = self.init_cache(B, cache_seq)
            x, cache, _ = self._backbone(params, x, positions, caches=caches,
                                         cache_len=0)
        logits = self._logits(params, x[:, -1:])
        return logits[:, 0], cache


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)


# ---------------------------------------------------------------- input specs
SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}


def shape_applicable(cfg: ModelConfig, shape: str) -> tuple[bool, str]:
    """Dry-run skip rules (DESIGN §7)."""
    info = SHAPES[shape]
    if shape == "long_500k":
        if cfg.family in ("xlstm", "hybrid"):
            return True, ""
        if cfg.swa_window:
            return True, ""
        return False, "full attention is quadratic/unbounded-KV at 500k (skip per assignment)"
    if cfg.family == "encdec" and info["kind"] == "prefill" and info["seq"] > 8192:
        return True, ""  # decoder prefill is generic; allowed
    return True, ""


def input_specs(cfg: ModelConfig, shape: str, *, dp_devices: int | None = None):
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    info = SHAPES[shape]
    B, S = info["batch"], info["seq"]
    i32 = jnp.int32
    f = jnp.dtype(cfg.dtype)
    sd = jax.ShapeDtypeStruct
    if info["kind"] in ("train", "prefill"):
        batch = {"tokens": sd((B, S), i32), "labels": sd((B, S), i32)}
        if cfg.family == "encdec":
            batch["frames"] = sd((B, cfg.encoder_seq, cfg.d_model), f)
        if cfg.family == "vlm":
            batch["patches"] = sd((B, cfg.num_patches, cfg.d_model), f)
        return batch
    # decode: one token against a seq_len cache
    model = build_model(cfg)
    ring = bool(cfg.swa_window) and shape == "long_500k"
    cache_seq = min(S, cfg.swa_window) if ring else S
    cache = jax.eval_shape(lambda: model.init_cache(B, cache_seq, ring=ring))
    if cfg.family == "encdec":
        kv = jax.eval_shape(
            lambda: TR.init_kv_caches(cfg, B, cfg.encoder_seq, dtype=f))
        cache = dict(cache)
        cache["cross"] = (kv["k"], kv["v"])
    return {
        "token": sd((B, 1), i32),
        "pos": sd((), i32),
        "cache": cache,
    }
