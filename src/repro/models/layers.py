"""Shared transformer layers: norms, rotary, attention (GQA/MLA/SWA), MLPs.

Pure-JAX functional style: params are plain dicts; init_* functions build
them; apply functions are jit/scan/shard_map friendly.  Dtype policy: params
live in ``param_dtype`` (fp32 master), compute casts to ``dtype`` (bf16).

Attention impls:
  * ``naive``   — full (Sq, Skv) score matrix (smoke tests).
  * ``chunked`` — lax.map over query chunks; bounds the live score tensor to
    (B, cq, H, Skv).  This is the XLA path the dry-run lowers (a Pallas
    flash kernel cannot compile on the CPU backend); the TPU deployment
    path is kernels/flash_attention, numerically validated against these.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


# --------------------------------------------------------------------- init
def _dense_init(key, shape, scale_axis=0, dtype=jnp.float32):
    fan_in = shape[scale_axis] if isinstance(scale_axis, int) else int(
        np.prod([shape[a] for a in scale_axis])
    )
    std = 1.0 / np.sqrt(fan_in)
    return (jax.random.normal(key, shape) * std).astype(dtype)


# --------------------------------------------------------------------- norms
def init_norm(cfg, dim=None):
    d = dim or cfg.d_model
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if cfg.norm == "layernorm" and cfg.use_bias:
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


def apply_norm(p, x, kind: str, eps: float = 1e-6):
    """Norm with f32 *statistics* but elementwise math in x.dtype: keeps the
    activation cotangents bf16 end-to-end, which halves the wire bytes of
    every tensor-parallel all-reduce they cross (§Perf #7); statistics stay
    f32 for stability (standard bf16-layernorm practice)."""
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
        out = x * inv * p["scale"].astype(x.dtype)
    else:  # layernorm
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
        out = (x - mu.astype(x.dtype)) * inv * p["scale"].astype(x.dtype)
        if "bias" in p:
            out = out + p["bias"].astype(x.dtype)
    return out.astype(x.dtype)


def rms_head_norm(scale, x, eps: float = 1e-6):
    """Per-head qk-norm (qwen3): normalize the trailing head_dim."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale).astype(x.dtype)


# --------------------------------------------------------------------- rope
def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, D) with positions (..., S)."""
    d = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(d, theta), jnp.float32)
    angles = positions[..., :, None].astype(jnp.float32)[..., None, :] * freqs  # (..., S, 1, D/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq: int, d_model: int):
    pos = np.arange(seq)[:, None]
    dim = np.arange(0, d_model, 2)[None, :]
    ang = pos / (10000 ** (dim / d_model))
    out = np.zeros((seq, d_model), np.float32)
    out[:, 0::2] = np.sin(ang)
    out[:, 1::2] = np.cos(ang)
    return jnp.asarray(out)


# ----------------------------------------------------------------- attention
def init_attention(key, cfg):
    ks = jax.random.split(key, 8)
    D, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    pd = jnp.float32
    p = {
        "wq": _dense_init(ks[0], (D, H, hd), 0, pd),
        "wk": _dense_init(ks[1], (D, KV, hd), 0, pd),
        "wv": _dense_init(ks[2], (D, KV, hd), 0, pd),
        "wo": _dense_init(ks[3], (H, hd, D), (0, 1), pd),
    }
    if cfg.use_bias:
        p["bq"] = jnp.zeros((H, hd), pd)
        p["bk"] = jnp.zeros((KV, hd), pd)
        p["bv"] = jnp.zeros((KV, hd), pd)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), pd)
        p["k_norm"] = jnp.ones((hd,), pd)
    return p


def _scores_mask(q_pos, k_pos, window: Optional[int], causal: bool):
    """(..., Sq, Skv) additive mask from position vectors."""
    ok = jnp.ones(q_pos.shape[:-1] + (q_pos.shape[-1], k_pos.shape[-1]), bool)
    if causal:
        ok &= k_pos[..., None, :] <= q_pos[..., :, None]
    if window is not None:
        ok &= k_pos[..., None, :] > q_pos[..., :, None] - window
    return jnp.where(ok, 0.0, NEG_INF)


def _sdpa(q, k, v, mask, dtype):
    """q (B,Sq,H,dh) k/v (B,Skv,KV,dh) → (B,Sq,H,dh); GQA via head grouping."""
    B, Sq, H, dh = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, dh)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k).astype(jnp.float32)
    scores = scores / np.sqrt(dh) + mask[:, None, None]
    probs = jax.nn.softmax(scores, axis=-1).astype(dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v)
    return out.reshape(B, Sq, H, v.shape[-1])  # v dim may differ (MLA)


def attention(
    q, k, v, *, q_positions, k_positions, causal=True,
    window: Optional[int] = None, impl="chunked", chunk=1024, dtype=jnp.bfloat16,
    context_parallel: bool = False,
):
    """Masked GQA attention; chunked over queries when impl == 'chunked'.

    context_parallel: shard the *query sequence* over the tp axis instead of
    heads — used when the head count does not divide tp (e.g. qwen3's 40
    heads on a 16-wide model axis), where head_dim-sharded attention would
    otherwise force an all-reduce of the full (Sq × Skv) score tensor.
    k/v replicate across tp (cheap for GQA); each shard computes its query
    slice; the output reshards back.  DESIGN §5."""
    from .shardctx import constrain as _c

    if context_parallel:
        q = _c(q, "batch", "tp", None, None)
        k = _c(k, "batch", None, None, None)
        v = _c(v, "batch", None, None, None)
    B, Sq = q.shape[:2]
    if impl == "naive" or Sq <= chunk:
        mask = _scores_mask(q_positions, k_positions, window, causal)
        return _sdpa(q, k, v, mask, dtype)
    while Sq % chunk:  # non-multiple sequence (e.g. whisper's 1500 frames)
        chunk //= 2
        if chunk < 64:
            mask = _scores_mask(q_positions, k_positions, window, causal)
            return _sdpa(q, k, v, mask, dtype)
    nq = Sq // chunk

    # remat per chunk: the backward pass recomputes each chunk's scores
    # instead of saving (B, cq, H, Skv) probs for every chunk as lax.map
    # residuals — the flash-attention memory contract on the XLA path.
    @jax.checkpoint
    def one_chunk(args):
        qc, qp = args
        if context_parallel:
            # constraints don't propagate into the map body — re-pin the
            # query chunk sequence-sharded so the score contraction needs
            # no tp reduce (§Perf #6)
            qc = _c(qc, "batch", "tp", None, None)
        mask = _scores_mask(qp, k_positions, window, causal)
        out = _sdpa(qc, k, v, mask, dtype)
        if context_parallel:
            out = _c(out, "batch", "tp", None, None)
        return out

    qs = q.reshape(B, nq, chunk, *q.shape[2:]).swapaxes(0, 1)
    qp = q_positions.reshape(B, nq, chunk).swapaxes(0, 1)
    out = jax.lax.map(one_chunk, (qs, qp))  # (nq, B, chunk, H, dv)
    return out.swapaxes(0, 1).reshape(B, Sq, *out.shape[-2:])


def attention_block(p, x, cfg, positions, *, kv_cache=None, cache_len=None,
                    cross_kv=None, causal=True, dtype=jnp.bfloat16):
    """Full attention sub-block: qkv proj → rope → (cache) → sdpa → out proj.

    kv_cache: optional dict {"k","v"} (B, Smax, KV, dh) + write at cache_len.
    cross_kv: optional precomputed (k, v) for cross-attention (enc-dec).
    Returns (out, new_cache).
    """
    B, S, D = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    xq = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dtype))
    if "bq" in p:
        xq = xq + p["bq"].astype(dtype)
    if cross_kv is None:
        xk = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dtype))
        xv = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dtype))
        if "bk" in p:
            xk = xk + p["bk"].astype(dtype)
            xv = xv + p["bv"].astype(dtype)
    else:
        xk, xv = cross_kv
    if cfg.qk_norm:
        xq = rms_head_norm(p["q_norm"], xq)
        if cross_kv is None:
            xk = rms_head_norm(p["k_norm"], xk)
    if cfg.rope_theta and cross_kv is None:
        xq = apply_rope(xq, positions, cfg.rope_theta)
        xk = apply_rope(xk, positions, cfg.rope_theta)
    new_cache = None
    if kv_cache is not None and cross_kv is None:
        if "kpos" in kv_cache:
            # SWA ring buffer (long-context decode): slot = pos mod window
            Smax = kv_cache["k"].shape[1]
            slot = jnp.mod(cache_len, Smax)
            k_all = jax.lax.dynamic_update_slice_in_dim(kv_cache["k"], xk.astype(kv_cache["k"].dtype), slot, axis=1)
            v_all = jax.lax.dynamic_update_slice_in_dim(kv_cache["v"], xv.astype(kv_cache["v"].dtype), slot, axis=1)
            kpos = jax.lax.dynamic_update_slice_in_dim(
                kv_cache["kpos"], positions[0].astype(kv_cache["kpos"].dtype), slot, axis=0)
            new_cache = {"k": k_all, "v": v_all, "kpos": kpos}
            k_positions = jnp.broadcast_to(kpos[None], (B, Smax))
        else:
            k_all = jax.lax.dynamic_update_slice_in_dim(kv_cache["k"], xk.astype(kv_cache["k"].dtype), cache_len, axis=1)
            v_all = jax.lax.dynamic_update_slice_in_dim(kv_cache["v"], xv.astype(kv_cache["v"].dtype), cache_len, axis=1)
            new_cache = {"k": k_all, "v": v_all}
            Smax = k_all.shape[1]
            k_positions = jnp.broadcast_to(jnp.arange(Smax)[None], (B, Smax))
            # mask out unwritten cache slots by pushing their positions past q
            k_positions = jnp.where(k_positions < cache_len + S, k_positions, 2**30)
        xk, xv = k_all.astype(dtype), v_all.astype(dtype)
    elif cross_kv is not None:
        Skv = xk.shape[1]
        k_positions = jnp.broadcast_to(jnp.arange(Skv)[None], (B, Skv))
        causal = False
    else:
        k_positions = positions
    from .shardctx import axis_size
    tp = axis_size("tp")
    ctx_par = (tp > 1 and cfg.num_heads % tp != 0 and xq.shape[1] % tp == 0
               and cfg.attn_impl == "chunked")
    out = attention(
        xq, xk, xv, q_positions=positions, k_positions=k_positions,
        causal=causal, window=cfg.swa_window, impl=cfg.attn_impl,
        chunk=cfg.attn_chunk, dtype=dtype, context_parallel=ctx_par,
    )
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dtype))
    return out, new_cache


# ----------------------------------------------------------------- MLA
def init_mla(key, cfg):
    ks = jax.random.split(key, 10)
    D, H = cfg.d_model, cfg.num_heads
    r_kv, r_q = cfg.kv_lora_rank, cfg.q_lora_rank
    dn, dr, dv = cfg.head_dim, cfg.rope_head_dim, cfg.v_head_dim
    pd = jnp.float32
    return {
        "wq_a": _dense_init(ks[0], (D, r_q), 0, pd),
        "q_a_norm": jnp.ones((r_q,), pd),
        "wq_b": _dense_init(ks[1], (r_q, H, dn + dr), 0, pd),
        "wkv_a": _dense_init(ks[2], (D, r_kv + dr), 0, pd),
        "kv_a_norm": jnp.ones((r_kv,), pd),
        "wk_b": _dense_init(ks[3], (r_kv, H, dn), 0, pd),
        "wv_b": _dense_init(ks[4], (r_kv, H, dv), 0, pd),
        "wo": _dense_init(ks[5], (H, dv, D), (0, 1), pd),
    }


def mla_block(p, x, cfg, positions, *, cache=None, cache_len=None, dtype=jnp.bfloat16):
    """DeepSeek-V2 multi-head latent attention.

    Cache holds the compressed latent c_kv (B, S, r_kv) + rope key k_r
    (B, S, dr) — the MLA memory win.  Decode uses the absorbed formulation
    (scores via W_uk-projected queries against the latent); prefill
    reconstructs per-head k/v (flash-friendly on TPU).
    """
    B, S, D = x.shape
    H = cfg.num_heads
    r_kv, dr, dn, dv = cfg.kv_lora_rank, cfg.rope_head_dim, cfg.head_dim, cfg.v_head_dim
    # --- queries (low-rank)
    q_lat = apply_norm({"scale": p["q_a_norm"]}, jnp.einsum("bsd,dr->bsr", x, p["wq_a"].astype(dtype)), "rmsnorm")
    q = jnp.einsum("bsr,rhk->bshk", q_lat, p["wq_b"].astype(dtype))
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    # --- latent kv
    kv_a = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"].astype(dtype))
    c_kv, k_r = kv_a[..., :r_kv], kv_a[..., r_kv:]
    c_kv = apply_norm({"scale": p["kv_a_norm"]}, c_kv, "rmsnorm")
    k_r = apply_rope(k_r[:, :, None, :], positions, cfg.rope_theta)[:, :, 0, :]
    new_cache = None
    if cache is not None:
        c_all = jax.lax.dynamic_update_slice_in_dim(cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), cache_len, axis=1)
        kr_all = jax.lax.dynamic_update_slice_in_dim(cache["k_rope"], k_r.astype(cache["k_rope"].dtype), cache_len, axis=1)
        new_cache = {"c_kv": c_all, "k_rope": kr_all}
        c_kv, k_r = c_all.astype(dtype), kr_all.astype(dtype)
        Skv = c_kv.shape[1]
        k_pos = jnp.arange(Skv)[None]
        valid = (k_pos < cache_len + S)
        mask = jnp.where(valid[:, None, :] & (k_pos[:, None, :] <= positions[:, :, None]), 0.0, NEG_INF)
        # absorbed decode: score = (q_nope · W_uk c) + (q_rope · k_r)
        q_abs = jnp.einsum("bshk,rhk->bshr", q_nope, p["wk_b"].astype(dtype))
        scores = jnp.einsum("bshr,btr->bhst", q_abs, c_kv).astype(jnp.float32)
        scores = scores + jnp.einsum("bshk,btk->bhst", q_rope, k_r).astype(jnp.float32)
        scores = scores / np.sqrt(dn + dr) + mask[:, None]
        probs = jax.nn.softmax(scores, axis=-1).astype(dtype)
        ctx = jnp.einsum("bhst,btr->bshr", probs, c_kv)
        out = jnp.einsum("bshr,rhv->bshv", ctx, p["wv_b"].astype(dtype))
    else:
        # prefill/train: reconstruct per-head k, v (heads sharded over model)
        k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, p["wk_b"].astype(dtype))
        v = jnp.einsum("bsr,rhv->bshv", c_kv, p["wv_b"].astype(dtype))
        k = jnp.concatenate([k_nope, jnp.broadcast_to(k_r[:, :, None, :], (B, S, H, dr))], axis=-1)
        qf = jnp.concatenate([q_nope, q_rope], axis=-1)
        out = attention(
            qf, k, v, q_positions=positions, k_positions=positions,
            causal=True, impl=cfg.attn_impl, chunk=cfg.attn_chunk, dtype=dtype,
        )
    out = jnp.einsum("bshv,hvd->bsd", out, p["wo"].astype(dtype))
    return out, new_cache


# ----------------------------------------------------------------- MLPs
def init_mlp(key, cfg, d_ff=None):
    ks = jax.random.split(key, 3)
    D, F = cfg.d_model, d_ff or cfg.d_ff
    pd = jnp.float32
    if cfg.mlp == "swiglu":
        return {
            "wg": _dense_init(ks[0], (D, F), 0, pd),
            "wu": _dense_init(ks[1], (D, F), 0, pd),
            "wd": _dense_init(ks[2], (F, D), 0, pd),
        }
    p = {"wi": _dense_init(ks[0], (D, F), 0, pd), "wd": _dense_init(ks[1], (F, D), 0, pd)}
    if cfg.use_bias:
        p["bi"] = jnp.zeros((F,), pd)
        p["bd"] = jnp.zeros((D,), pd)
    return p


def apply_mlp(p, x, kind: str, dtype=jnp.bfloat16):
    if kind == "swiglu":
        g = jnp.einsum("bsd,df->bsf", x, p["wg"].astype(dtype))
        u = jnp.einsum("bsd,df->bsf", x, p["wu"].astype(dtype))
        h = jax.nn.silu(g) * u
    else:
        h = jnp.einsum("bsd,df->bsf", x, p["wi"].astype(dtype))
        if "bi" in p:
            h = h + p["bi"].astype(dtype)
        if kind == "squared_relu":
            h = jnp.square(jax.nn.relu(h))
        else:  # gelu
            h = jax.nn.gelu(h)
    out = jnp.einsum("bsf,fd->bsd", h, p["wd"].astype(dtype))
    if "bd" in p:
        out = out + p["bd"].astype(dtype)
    return out
