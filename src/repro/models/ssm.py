"""Mamba2 (SSD — state-space duality) block, chunkwise-parallel training path
and O(1)-state recurrent decode path (zamba2 backbone).

Head-structured parameters so TP shards the SSM heads over the ``tp``
logical axis (80 heads / 16 = 5 per device for zamba2); B/C are per-group
(n_groups=1) and replicated.  The chunked algorithm is the matmul
formulation from the Mamba2 paper (listing 1): intra-chunk quadratic term +
inter-chunk state recurrence — MXU-friendly, O(L·Q) memory.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .layers import _dense_init, apply_norm
from .shardctx import constrain


def ssm_dims(cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    heads = d_inner // cfg.ssm_headdim
    return d_inner, heads, cfg.ssm_headdim, cfg.ssm_state


def init_mamba2(key, cfg):
    ks = jax.random.split(key, 10)
    D = cfg.d_model
    d_inner, H, P, N = ssm_dims(cfg)
    pd = jnp.float32
    kconv = cfg.ssm_conv
    return {
        "wz": _dense_init(ks[0], (D, H, P), 0, pd),
        "wx": _dense_init(ks[1], (D, H, P), 0, pd),
        "wB": _dense_init(ks[2], (D, N), 0, pd),
        "wC": _dense_init(ks[3], (D, N), 0, pd),
        "w_dt": _dense_init(ks[4], (D, H), 0, pd),
        "dt_bias": jnp.zeros((H,), pd),
        "A_log": jnp.zeros((H,), pd),
        "D_skip": jnp.ones((H,), pd),
        "conv_x": _dense_init(ks[5], (kconv, H, P), 0, pd),
        "conv_B": _dense_init(ks[6], (kconv, N), 0, pd),
        "conv_C": _dense_init(ks[7], (kconv, N), 0, pd),
        "out_norm": jnp.ones((H, P), pd),
        "wo": _dense_init(ks[8], (H, P, D), (0, 1), pd),
    }


def _causal_conv(x, w):
    """Depthwise causal conv along axis 1. x (B, L, C), w (ks, C)."""
    ks = w.shape[0]
    xp = jnp.pad(x, [(0, 0), (ks - 1, 0), (0, 0)])
    out = sum(xp[:, i : i + x.shape[1]] * w[i] for i in range(ks))
    return out


def _segsum(x):
    """x (..., L) → (..., L, L): Σ_{j<m≤i} x_m below diag, -inf above."""
    L = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((L, L), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, log_a, B_, C_, chunk: int):
    """SSD scan. x (B,L,H,P), log_a (B,L,H) ≤ 0, B_/C_ (B,L,N) (group-shared).

    Returns y (B,L,H,P) and final state (B,H,P,N).  x must already include
    the dt scaling (x ← dt·x).
    """
    Bsz, L, H, P = x.shape
    N = B_.shape[-1]
    assert L % chunk == 0, (L, chunk)
    nc = L // chunk
    xc = x.reshape(Bsz, nc, chunk, H, P)
    ac = log_a.reshape(Bsz, nc, chunk, H).transpose(0, 3, 1, 2)  # (B,H,nc,Q)
    Bc = B_.reshape(Bsz, nc, chunk, N)
    Cc = C_.reshape(Bsz, nc, chunk, N)

    A_cum = jnp.cumsum(ac, axis=-1)                                # (B,H,nc,Q)
    Lmat = jnp.exp(_segsum(ac))                                    # (B,H,nc,Q,Q)
    # intra-chunk (diagonal blocks)
    scores = jnp.einsum("bcln,bcsn->bcls", Cc, Bc)                 # (B,nc,Q,Q)
    y_diag = jnp.einsum("bcls,bhcls,bcshp->bclhp",
                        scores, Lmat.astype(scores.dtype), xc)
    # chunk-final states
    decay_states = jnp.exp(A_cum[..., -1:] - A_cum)                # (B,H,nc,Q)
    states = jnp.einsum("bcln,bhcl,bclhp->bchpn",
                        Bc, decay_states.astype(Bc.dtype), xc)     # (B,nc,H,P,N)
    # inter-chunk recurrence
    chunk_decay = A_cum[..., -1]                                   # (B,H,nc)
    padded = jnp.pad(chunk_decay, [(0, 0), (0, 0), (1, 0)])
    decay_chunk = jnp.exp(_segsum(padded))                         # (B,H,nc+1,nc+1)
    states_in = jnp.concatenate(
        [jnp.zeros_like(states[:, :1]), states], axis=1)           # (B,nc+1,H,P,N)
    new_states = jnp.einsum("bhzc,bchpn->bzhpn",
                            decay_chunk.astype(states.dtype), states_in)
    prev_states = new_states[:, :-1]                               # state entering chunk
    final_state = new_states[:, -1]
    # chunk-start state contribution
    state_decay = jnp.exp(A_cum)                                   # (B,H,nc,Q)
    y_off = jnp.einsum("bcln,bchpn,bhcl->bclhp",
                       Cc, prev_states, state_decay.astype(Cc.dtype))
    y = (y_diag + y_off).reshape(Bsz, L, H, P)
    return y, final_state


def mamba2_block(p, x, cfg, *, state=None, conv_cache=None, chunk=256,
                 dtype=jnp.bfloat16):
    """x (B, L, D) → (B, L, D).  Decode: L == 1 with (state, conv_cache)."""
    Bsz, L, D = x.shape
    d_inner, H, P, N = ssm_dims(cfg)
    z = jnp.einsum("bld,dhp->blhp", x, p["wz"].astype(dtype))
    xin = jnp.einsum("bld,dhp->blhp", x, p["wx"].astype(dtype))
    B_ = jnp.einsum("bld,dn->bln", x, p["wB"].astype(dtype))
    C_ = jnp.einsum("bld,dn->bln", x, p["wC"].astype(dtype))
    dt = jax.nn.softplus(
        jnp.einsum("bld,dh->blh", x.astype(jnp.float32), p["w_dt"]) + p["dt_bias"]
    )
    A = -jnp.exp(p["A_log"])                                       # (H,) < 0

    new_conv_cache = None
    if conv_cache is None:
        xin = jax.nn.silu(_causal_conv(
            xin.reshape(Bsz, L, H * P), p["conv_x"].reshape(-1, H * P).astype(dtype)
        )).reshape(Bsz, L, H, P)
        B_ = jax.nn.silu(_causal_conv(B_, p["conv_B"].astype(dtype)))
        C_ = jax.nn.silu(_causal_conv(C_, p["conv_C"].astype(dtype)))
    else:
        # decode: roll the conv window (cache holds the last ks inputs)
        ks = cfg.ssm_conv
        cx = jnp.concatenate([conv_cache["x"][:, 1:], xin.reshape(Bsz, 1, H * P)], axis=1)
        cB = jnp.concatenate([conv_cache["B"][:, 1:], B_], axis=1)
        cC = jnp.concatenate([conv_cache["C"][:, 1:], C_], axis=1)
        new_conv_cache = {"x": cx, "B": cB, "C": cC}
        wx_ = p["conv_x"].reshape(ks, H * P).astype(dtype)
        xin = jax.nn.silu(jnp.einsum("bkc,kc->bc", cx, wx_)).reshape(Bsz, 1, H, P)
        B_ = jax.nn.silu(jnp.einsum("bkn,kn->bn", cB, p["conv_B"].astype(dtype)))[:, None]
        C_ = jax.nn.silu(jnp.einsum("bkn,kn->bn", cC, p["conv_C"].astype(dtype)))[:, None]

    x_dt = xin * dt.astype(dtype)[..., None]
    log_a = (dt * A).astype(jnp.float32)                           # (B,L,H)

    if state is None and L > 1:
        ch = min(chunk, L)
        while L % ch:
            ch //= 2
        y, final_state = ssd_chunked(x_dt, log_a, B_, C_, ch)
    else:
        s0 = state if state is not None else jnp.zeros((Bsz, H, P, N), dtype)
        a = jnp.exp(log_a[:, 0])                                   # (B,H)
        upd = jnp.einsum("bhp,bn->bhpn", x_dt[:, 0], B_[:, 0])
        final_state = s0 * a[..., None, None].astype(dtype) + upd
        y = jnp.einsum("bhpn,bn->bhp", final_state, C_[:, 0])[:, None]
    y = y + xin * p["D_skip"].astype(dtype)[None, None, :, None]
    # gated RMSNorm (mamba2) then output projection
    y = y * jax.nn.silu(z)
    yf = y.astype(jnp.float32)
    var = jnp.mean(yf * yf, axis=-1, keepdims=True)
    y = (yf * jax.lax.rsqrt(var + 1e-6) * p["out_norm"]).astype(dtype)
    out = jnp.einsum("blhp,hpd->bld", y, p["wo"].astype(dtype))
    return out, final_state.astype(dtype), new_conv_cache


def init_conv_cache(cfg, batch: int, dtype=jnp.bfloat16):
    d_inner, H, P, N = ssm_dims(cfg)
    ks = cfg.ssm_conv
    return {
        "x": jnp.zeros((batch, ks, H * P), dtype),
        "B": jnp.zeros((batch, ks, N), dtype),
        "C": jnp.zeros((batch, ks, N), dtype),
    }
