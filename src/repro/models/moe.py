"""Mixture-of-experts layer: top-k routing, capacity-based dispatch, shared
experts, EP-sharded via shard_map, optional Parsa expert permutation.

Two execution paths:

  * LOCAL (no mesh context / 1-wide model axis): sort-and-pack dispatch on
    one device — the reference semantics (smoke tests, CPU training).

  * SHARD_MAP (mesh context active): GSPMD cannot shard the data-dependent
    dispatch gather/scatter — left to sharding propagation it *replicates*
    the token buffer onto every device (measured: a 45 TB/step collective
    term for deepseek-v2; EXPERIMENTS.md §Perf).  Instead the routed part
    runs in shard_map where dispatch is an explicit LOCAL scatter:
      - activations are batch-sharded on dp and replicated across tp, so
        each tp rank packs only the assignments of ITS experts (E % tp == 0:
        expert-parallel) or all experts on its FFN slice (E < tp:
        hidden-sharded), computes, and contributes a partial (T, D) output;
      - one psum over tp completes the layer — the same wire cost as a
        Megatron row-parallel matmul, with zero dispatch replication.

Top-k routing + aux loss + shared experts stay in the GSPMD path (small
dense math).  FLOP overhead vs ideal = capacity_factor (default 1.25).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ..compat import shard_map

from .layers import _dense_init
from .shardctx import constrain, current_rules


def init_moe(key, cfg):
    ks = jax.random.split(key, 8)
    D, F, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    pd = jnp.float32
    p = {
        "router": _dense_init(ks[0], (D, E), 0, pd),
        "wg": _dense_init(ks[1], (E, D, F), 1, pd),
        "wu": _dense_init(ks[2], (E, D, F), 1, pd),
        "wd": _dense_init(ks[3], (E, F, D), 1, pd),
    }
    if cfg.num_shared_experts:
        Fs = cfg.d_ff * cfg.num_shared_experts
        p["shared"] = {
            "wg": _dense_init(ks[4], (D, Fs), 0, pd),
            "wu": _dense_init(ks[5], (D, Fs), 0, pd),
            "wd": _dense_init(ks[6], (Fs, D), 0, pd),
        }
    return p


def capacity(cfg, tokens: int) -> int:
    c = int(np.ceil(tokens * cfg.num_experts_per_tok / cfg.num_experts
                    * cfg.moe_capacity_factor))
    return max(8, int(np.ceil(c / 8) * 8))


def _route(p, xt, cfg):
    """fp32 router → (weights, ids) (T, K), renormalized."""
    K = cfg.num_experts_per_tok
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, K)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)
    return probs, top_w, top_e


def _pack_compute_combine(xt, top_e, top_w, wg, wu, wd, cfg, *,
                          e_lo, e_num, dtype):
    """Sort-pack assignments of experts [e_lo, e_lo+e_num) into a capacity
    buffer, run the expert MLPs, combine back to (T, D).  Pure local math."""
    T, D = xt.shape
    E, K = cfg.num_experts, cfg.num_experts_per_tok
    C = capacity(cfg, T)
    flat_e = top_e.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(T), K)
    flat_w = top_w.reshape(-1).astype(dtype)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sw = flat_e[order], flat_t[order], flat_w[order]
    group_sizes = jnp.bincount(flat_e, length=E)
    group_start = jnp.concatenate([jnp.zeros(1, group_sizes.dtype),
                                   jnp.cumsum(group_sizes)[:-1]])
    pos = jnp.arange(T * K) - group_start[se]
    mine = (se >= e_lo) & (se < e_lo + e_num) & (pos < C)
    dest = jnp.where(mine, (se - e_lo) * C + pos, e_num * C)

    rows = xt[st].astype(dtype)
    buf = jnp.zeros((e_num * C, D), dtype).at[dest].set(rows, mode="drop")
    buf = buf.reshape(e_num, C, D)
    g = jnp.einsum("ecd,edf->ecf", buf, wg.astype(dtype))
    u = jnp.einsum("ecd,edf->ecf", buf, wu.astype(dtype))
    y = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, wd.astype(dtype))
    y_flat = y.reshape(e_num * C, D)
    picked = jnp.where(mine[:, None],
                       y_flat[jnp.clip(dest, 0, e_num * C - 1)], 0)
    return jax.ops.segment_sum(picked * sw[:, None], st, num_segments=T)


def _routed_local(p, xt, top_e, top_w, cfg, dtype):
    return _pack_compute_combine(xt, top_e, top_w, p["wg"], p["wu"], p["wd"],
                                 cfg, e_lo=0, e_num=cfg.num_experts,
                                 dtype=dtype)


def _routed_shard_map(p, x, top_w, top_e, cfg, dtype):
    """EP via shard_map (see module docstring)."""
    mesh, rules = current_rules()
    tp_ax = rules.get("tp")
    dp_ax = rules.get("batch")
    fsdp_ax = rules.get("fsdp")  # data-axis ZeRO shard of the d_model dim
    E = cfg.num_experts
    tp = int(mesh.shape[tp_ax]) if tp_ax else 1
    ep = E % tp == 0
    fsdp = (fsdp_ax is not None and cfg.fsdp
            and cfg.d_model % int(mesh.shape[fsdp_ax]) == 0)

    def body(x_loc, tw, te, wg, wu, wd):
        B_loc, S, D = x_loc.shape
        T_loc = B_loc * S
        xt = x_loc.reshape(T_loc, D)
        te2 = te.reshape(-1, te.shape[-1])
        tw2 = tw.reshape(-1, tw.shape[-1])
        token_path = False
        if fsdp and ep:
            nd = int(mesh.shape[fsdp_ax])
            gather_bytes = (wg.size + wu.size + wd.size) * 2 * (nd - 1)
            token_bytes = 3 * T_loc * D * 2 * (nd - 1) * nd
            # decode: tokens are tiny — move tokens to the F-sliced weights
            # instead of re-gathering GBs of expert weights per step
            token_path = token_bytes < gather_bytes
        if fsdp and not token_path:
            # ZeRO-3: re-materialize full weights in bf16 per layer
            ax_g = 2 if ep else 1
            wg = jax.lax.all_gather(wg.astype(dtype), fsdp_ax, axis=ax_g, tiled=True)
            wu = jax.lax.all_gather(wu.astype(dtype), fsdp_ax, axis=ax_g, tiled=True)
            wd = jax.lax.all_gather(wd.astype(dtype), fsdp_ax, axis=1 if ep else 2, tiled=True)
        if token_path:
            xt = jax.lax.all_gather(xt, fsdp_ax, axis=0, tiled=True)
            te2 = jax.lax.all_gather(te2, fsdp_ax, axis=0, tiled=True)
            tw2 = jax.lax.all_gather(tw2, fsdp_ax, axis=0, tiled=True)
        if ep:
            idx = jax.lax.axis_index(tp_ax)
            e_num = E // tp
            out = _pack_compute_combine(
                xt, te2, tw2, wg, wu, wd, cfg,
                e_lo=idx * e_num, e_num=e_num, dtype=dtype)
        else:
            out = _pack_compute_combine(
                xt, te2, tw2, wg, wu, wd, cfg, e_lo=0, e_num=E, dtype=dtype)
        if token_path:
            out = jax.lax.psum(out, (tp_ax, fsdp_ax))
            didx = jax.lax.axis_index(fsdp_ax)
            out = jax.lax.dynamic_slice_in_dim(out, didx * T_loc, T_loc, 0)
        else:
            out = jax.lax.psum(out, tp_ax)
        return out.reshape(B_loc, S, D)

    f1 = fsdp_ax if fsdp else None
    if ep:
        # F ZeRO-shards over data (wg/wu dim 2, wd dim 1)
        w_specs = (P(tp_ax, None, f1), P(tp_ax, None, f1), P(tp_ax, f1, None))
    else:  # hidden-sharded experts: partial products reduced by the psum
        w_specs = (P(None, f1, tp_ax), P(None, f1, tp_ax),
                   P(None, tp_ax, f1))
    x_spec = P(dp_ax, None, None)
    tk_spec = P(dp_ax, None, None)
    fn = shard_map(
        body, mesh=mesh,
        in_specs=(x_spec, tk_spec, tk_spec) + w_specs,
        out_specs=x_spec, check_vma=False)
    B, S, D = x.shape
    return fn(x, top_w.reshape(B, S, -1), top_e.reshape(B, S, -1),
              p["wg"], p["wu"], p["wd"]).reshape(B * S, D)


def apply_moe(p, x, cfg, dtype=jnp.bfloat16, return_aux=False):
    """x: (B, S, D) → (B, S, D). Router in fp32 for stability."""
    B, S, D = x.shape
    E = cfg.num_experts
    T = B * S
    xt = x.reshape(T, D)
    probs, top_w, top_e = _route(p, xt, cfg)

    ctx = current_rules()
    use_shard_map = False
    if ctx is not None:
        mesh, rules = ctx
        tp_ax = rules.get("tp")
        if tp_ax and int(mesh.shape[tp_ax]) > 1:
            use_shard_map = True
    if use_shard_map:
        out = _routed_shard_map(p, x, top_w, top_e, cfg, dtype)
    else:
        out = _routed_local(p, xt, top_e, top_w, cfg, dtype)

    if "shared" in p:
        sh = p["shared"]
        g = jnp.einsum("td,df->tf", xt.astype(dtype), sh["wg"].astype(dtype))
        u = jnp.einsum("td,df->tf", xt.astype(dtype), sh["wu"].astype(dtype))
        out = out + jnp.einsum("tf,fd->td", jax.nn.silu(g) * u,
                               sh["wd"].astype(dtype))

    out = out.reshape(B, S, D).astype(dtype)
    if return_aux:
        me = jnp.mean(jax.nn.one_hot(top_e, E, dtype=jnp.float32), axis=(0, 1))
        ce = jnp.mean(probs, axis=0)
        aux = E * jnp.sum(me * ce)
        counts = jnp.sum(jax.nn.one_hot(top_e, E, dtype=jnp.int32), axis=(0, 1))
        return out, {"aux_loss": aux, "expert_counts": counts}
    return out
