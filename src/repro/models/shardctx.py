"""Logical-axis sharding context for model code.

Model layers annotate activations with *logical* axes via
``constrain(x, "batch", None, "tp")``.  When a mesh context is active
(launch/sharding.py activates one inside jit traces for the dry-run and the
real launchers), the logical names resolve to mesh axes and become
``with_sharding_constraint``; with no context (unit tests, single-CPU smoke
runs) it is a no-op.  This keeps models mesh-agnostic and import-cycle-free.
"""
from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_state = threading.local()


def current_rules():
    return getattr(_state, "rules", None)


@contextlib.contextmanager
def logical_axis_rules(mesh, rules: dict[str, object]):
    """rules: logical name → mesh axis (str | tuple | None)."""
    prev = getattr(_state, "rules", None)
    _state.rules = (mesh, dict(rules))
    try:
        yield
    finally:
        _state.rules = prev


def resolve(logical_axes: tuple) -> P | None:
    ctx = current_rules()
    if ctx is None:
        return None
    mesh, rules = ctx
    out = []
    for ax in logical_axes:
        if ax is None:
            out.append(None)
        else:
            out.append(rules.get(ax))
    return P(*out)


def axis_size(logical: str) -> int:
    """Mesh extent of a logical axis (1 when no context / unmapped)."""
    ctx = current_rules()
    if ctx is None:
        return 1
    mesh, rules = ctx
    ax = rules.get(logical)
    if ax is None:
        return 1
    axes = ax if isinstance(ax, tuple) else (ax,)
    size = 1
    for a in axes:
        size *= int(mesh.shape[a])
    return size


def constrain(x, *logical_axes):
    ctx = current_rules()
    if ctx is None:
        return x
    mesh, _ = ctx
    spec = resolve(logical_axes)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


@jax.custom_vjp
def _bgb16(x):
    return x


def _bgb_fwd(x):
    return x, None


def _bgb_bwd(_, g):
    import jax.numpy as jnp
    return (g.astype(jnp.bfloat16),)


_bgb16.defvjp(_bgb_fwd, _bgb_bwd)


def bf16_grad_barrier(x):
    """Identity that *retypes* the cotangent to bf16 (the loss head emits an
    f32 dx that otherwise stays f32 through every layer's backward — halving
    the wire bytes of all backward activation all-reduces; §Perf #7).
    Applied only to bf16 activations (fp32 smoke configs pass through)."""
    import jax.numpy as jnp
    if x.dtype == jnp.bfloat16:
        return _bgb16(x)
    return x
