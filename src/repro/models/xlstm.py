"""xLSTM blocks: mLSTM (matrix memory, parallel/chunk-queried train path +
recurrent decode) and sLSTM (scalar memory, lax.scan recurrence).

mLSTM math (xLSTM paper, stabilized):
    f_t = σ-or-exp forget gate, i_t = exp input gate (log-space handling),
    C_t = f_t C_{t-1} + i_t v_t k_tᵀ,   n_t = f_t n_{t-1} + i_t k_t,
    h_t = (C_tᵀ q_t) / max(|n_tᵀ q_t|, 1)      (we use the exp-free bound 1)

Parallel form: weight of source j at query i is
    w_ij = exp(li_j + F_i − F_j − m_i),  F = Σ log f,  m_i = row max,
so y_i = Σ_j w_ij (q_i·k_j) v_j and n·q accumulates the same weights — a
linear-attention-with-gates kernel.  We chunk over queries (lax.map) so the
(L, L) weight matrix never fully materializes (needed for prefill_32k).

Heads shard over ``tp`` on the value dim (dv), the state's output axis.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .layers import _dense_init
from .shardctx import constrain

NEG = -1e30


def init_mlstm(key, cfg):
    ks = jax.random.split(key, 8)
    D, H = cfg.d_model, cfg.num_heads
    dh = cfg.head_dim
    pd = jnp.float32
    return {
        "wq": _dense_init(ks[0], (D, H, dh), 0, pd),
        "wk": _dense_init(ks[1], (D, H, dh), 0, pd),
        "wv": _dense_init(ks[2], (D, H, dh), 0, pd),
        "wz": _dense_init(ks[3], (D, H, dh), 0, pd),   # output gate branch
        "w_i": _dense_init(ks[4], (D, H), 0, pd),
        "w_f": _dense_init(ks[5], (D, H), 0, pd),
        "b_i": jnp.zeros((H,), pd),
        "b_f": jnp.ones((H,), pd) * 3.0,               # open forget gates
        "out_norm": jnp.ones((H, dh), pd),
        "wo": _dense_init(ks[6], (H, dh, D), (0, 1), pd),
    }


def mlstm_block(p, x, cfg, *, state=None, chunk=1024, dtype=jnp.bfloat16):
    """x (B,L,D) → (B,L,D). Decode: L == 1 with state (C, n, m, pos_f)."""
    B, L, D = x.shape
    H, dh = cfg.num_heads, cfg.head_dim
    q = jnp.einsum("bld,dhk->blhk", x, p["wq"].astype(dtype)) / np.sqrt(dh)
    k = jnp.einsum("bld,dhk->blhk", x, p["wk"].astype(dtype))
    v = jnp.einsum("bld,dhk->blhk", x, p["wv"].astype(dtype))
    z = jnp.einsum("bld,dhk->blhk", x, p["wz"].astype(dtype))
    xf = x.astype(jnp.float32)
    li = jnp.einsum("bld,dh->blh", xf, p["w_i"]) + p["b_i"]        # log input gate
    lf = jax.nn.log_sigmoid(jnp.einsum("bld,dh->blh", xf, p["w_f"]) + p["b_f"])

    new_state = None
    if state is None and L > 1:
        F = jnp.cumsum(lf, axis=1)                                  # (B,L,H)
        nq = max(1, L // chunk) if L % chunk == 0 else 1
        cq = L // nq

        @jax.checkpoint  # recompute per-chunk weights in backward (memory)
        def one_chunk(c):
            sl = lambda a: jax.lax.dynamic_slice_in_dim(a, c * cq, cq, axis=1)
            qc, Fc, ic = sl(q), sl(F), sl(li)
            pos_q = c * cq + jnp.arange(cq)
            # log weight: li_j + F_i - F_j, causal
            lw = (Fc[:, :, None] - F[:, None, :] + li[:, None, :]).transpose(0, 3, 1, 2)
            causal = pos_q[:, None] >= jnp.arange(L)[None, :]
            lw = jnp.where(causal[None, None], lw, NEG)             # (B,H,cq,L)
            m = jnp.maximum(jnp.max(lw, axis=-1, keepdims=True), 0.0)
            w = jnp.exp(lw - m)                                     # (B,H,cq,L)
            scores = jnp.einsum("bihk,bjhk->bhij", qc, k).astype(jnp.float32)
            ws = w * scores
            y = jnp.einsum("bhij,bjhk->bihk", ws.astype(dtype), v)
            denom = jnp.maximum(jnp.abs(jnp.sum(ws, axis=-1)), jnp.exp(-m[..., 0]))
            return y / denom.transpose(0, 2, 1)[..., None].astype(dtype)

        y = jax.lax.map(one_chunk, jnp.arange(nq))                  # (nq,B,cq,H,dh)
        y = y.transpose(1, 0, 2, 3, 4).reshape(B, L, H, dh)
    else:
        if state is None:
            C0 = jnp.zeros((B, H, dh, dh), jnp.float32)
            n0 = jnp.zeros((B, H, dh), jnp.float32)
            m0 = jnp.zeros((B, H), jnp.float32)
        else:
            C0, n0, m0 = state
        lf0, li0 = lf[:, 0], li[:, 0]
        m1 = jnp.maximum(lf0 + m0, li0)
        fw = jnp.exp(lf0 + m0 - m1)[..., None]
        iw = jnp.exp(li0 - m1)[..., None]
        k0, v0, q0 = (t[:, 0].astype(jnp.float32) for t in (k, v, q))
        C1 = fw[..., None] * C0 + iw[..., None] * jnp.einsum("bhv,bhk->bhvk", v0, k0)
        n1 = fw * n0 + iw * k0
        num = jnp.einsum("bhvk,bhk->bhv", C1, q0)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n1, q0)), jnp.exp(-m1))
        y = (num / den[..., None]).astype(dtype)[:, None]
        new_state = (C1, n1, m1)
    # per-head norm, output gate, projection
    yf = y.astype(jnp.float32)
    var = jnp.mean(yf * yf, axis=-1, keepdims=True)
    y = (yf * jax.lax.rsqrt(var + 1e-6) * p["out_norm"]).astype(dtype)
    y = y * jax.nn.silu(z)
    out = jnp.einsum("blhk,hkd->bld", y, p["wo"].astype(dtype))
    return out, new_state


def init_slstm(key, cfg):
    ks = jax.random.split(key, 9)
    D, H = cfg.d_model, cfg.num_heads
    dh = cfg.head_dim
    pd = jnp.float32
    p = {"wo": _dense_init(ks[8], (H, dh, D), (0, 1), pd)}
    for gi, g in enumerate(["i", "f", "z", "o"]):
        p[f"w_{g}"] = _dense_init(ks[gi], (D, H, dh), 0, pd)
        p[f"r_{g}"] = _dense_init(ks[gi + 4], (H, dh, dh), 1, pd) * 0.1
        p[f"b_{g}"] = jnp.zeros((H, dh), pd) if g != "f" else jnp.ones((H, dh), pd)
    return p


def slstm_block(p, x, cfg, *, state=None, dtype=jnp.bfloat16):
    """Scalar-memory LSTM with exponential gating; recurrent scan over L."""
    B, L, D = x.shape
    H, dh = cfg.num_heads, cfg.head_dim
    pre = {
        g: jnp.einsum("bld,dhk->blhk", x.astype(jnp.float32), p[f"w_{g}"]) + p[f"b_{g}"]
        for g in ["i", "f", "z", "o"]
    }
    if state is None:
        c0 = jnp.zeros((B, H, dh), jnp.float32)
        n0 = jnp.ones((B, H, dh), jnp.float32)
        h0 = jnp.zeros((B, H, dh), jnp.float32)
        m0 = jnp.zeros((B, H, dh), jnp.float32)
    else:
        c0, n0, h0, m0 = state

    R = {g: p[f"r_{g}"] for g in ["i", "f", "z", "o"]}

    def step(carry, t):
        c, n, h, m = carry
        gates = {
            g: pre[g][:, t] + jnp.einsum("bhk,hkj->bhj", h, R[g])
            for g in ["i", "f", "z", "o"]
        }
        lf = jax.nn.log_sigmoid(gates["f"])
        m1 = jnp.maximum(lf + m, gates["i"])
        iw = jnp.exp(gates["i"] - m1)
        fw = jnp.exp(lf + m - m1)
        c1 = fw * c + iw * jnp.tanh(gates["z"])
        n1 = fw * n + iw
        h1 = jax.nn.sigmoid(gates["o"]) * c1 / jnp.maximum(n1, 1e-6)
        return (c1, n1, h1, m1), h1

    (c, n, h, m), hs = jax.lax.scan(step, (c0, n0, h0, m0), jnp.arange(L))
    hs = hs.transpose(1, 0, 2, 3).astype(dtype)                     # (B,L,H,dh)
    out = jnp.einsum("blhk,hkd->bld", hs, p["wo"].astype(dtype))
    return out, (c, n, h, m)
