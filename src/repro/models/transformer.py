"""Unified block stacks for all 10 architectures.

One ``init_stack``/``apply_stack`` pair per family, all scan-over-layers
(stacked params, single-layer HLO) with a configurable remat policy:

  dense / vlm  — [ln → attn(GQA/SWA/qk-norm) → ln → mlp] × L
  moe          — [ln → attn|mla → ln → moe] × L
  encdec       — encoder [ln → attn(bidir) → ln → mlp] × Le, then decoder
                 [ln → self-attn → ln → cross-attn → ln → mlp] × Ld
  xlstm        — groups of (n−1 mLSTM + 1 sLSTM)
  hybrid       — groups of (n−1 Mamba2 + 1 weight-tied shared attn block)

Caches are stacked along the leading layer axis and consumed by the same
scans during decode.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from . import layers as LL
from . import moe as MOE
from . import ssm as SSM
from . import xlstm as XL
from .shardctx import bf16_grad_barrier, constrain



def _maybe_scan(cfg, body, carry, xs):
    """lax.scan when cfg.scan_layers else an unrolled python loop (used by
    the dry-run's flop-calibration compiles; scan bodies are counted once by
    XLA cost analysis)."""
    if cfg.scan_layers:
        return jax.lax.scan(body, carry, xs)
    n = jax.tree_util.tree_leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        carry, y = body(carry, jax.tree.map(lambda a: a[i], xs))
        ys.append(y)
    stacked = None if ys[0] is None else jax.tree.map(lambda *zs: jnp.stack(zs), *ys)
    return carry, stacked

def _remat(fn, cfg):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        pol = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        return jax.checkpoint(fn, policy=pol)
    return jax.checkpoint(fn)  # "full"


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------- dense/moe
def init_layer(key, cfg, cross=False):
    ks = jax.random.split(key, 6)
    p = {"ln1": LL.init_norm(cfg), "ln2": LL.init_norm(cfg)}
    p["attn"] = LL.init_mla(ks[0], cfg) if cfg.mla else LL.init_attention(ks[0], cfg)
    if cross:
        p["ln_x"] = LL.init_norm(cfg)
        p["xattn"] = LL.init_attention(ks[1], cfg)
    if cfg.num_experts:
        p["moe"] = MOE.init_moe(ks[2], cfg)
    else:
        p["mlp"] = LL.init_mlp(ks[3], cfg)
    return p


def apply_layer(p, x, cfg, positions, *, cache=None, cache_len=None,
                cross_kv=None, causal=True):
    dt = _dtype(cfg)
    h = LL.apply_norm(p["ln1"], x, cfg.norm)
    if cfg.mla:
        a, new_cache = LL.mla_block(p["attn"], h, cfg, positions,
                                    cache=cache, cache_len=cache_len, dtype=dt)
    else:
        a, new_cache = LL.attention_block(p["attn"], h, cfg, positions,
                                          kv_cache=cache, cache_len=cache_len,
                                          causal=causal, dtype=dt)
    x = x + a
    x = constrain(x, "batch", None, None)
    x = bf16_grad_barrier(x)
    if "xattn" in p:
        h = LL.apply_norm(p["ln_x"], x, cfg.norm)
        a, _ = LL.attention_block(p["xattn"], h, cfg, positions,
                                  cross_kv=cross_kv, causal=False, dtype=dt)
        x = x + a
    h = LL.apply_norm(p["ln2"], x, cfg.norm)
    aux = jnp.zeros((), jnp.float32)
    if "moe" in p:
        m, info = MOE.apply_moe(p["moe"], h, cfg, dtype=dt, return_aux=True)
        aux = info["aux_loss"]
    else:
        m = LL.apply_mlp(p["mlp"], h, cfg.mlp, dtype=dt)
    x = x + m
    x = constrain(x, "batch", None, None)
    x = bf16_grad_barrier(x)
    return x, new_cache, aux


def init_dense_stack(key, cfg, n_layers=None, cross=False):
    L = n_layers or cfg.num_layers
    keys = jax.random.split(key, L)
    return jax.vmap(lambda k: init_layer(k, cfg, cross=cross))(keys)


def apply_dense_stack(params_L, x, cfg, positions, *, caches=None,
                      cache_len=None, cross_kv=None, causal=True):
    """lax.scan over the stacked layer params (and stacked caches)."""

    def body(carry, xs):
        x, aux = carry
        if caches is None and cross_kv is None:
            pl_ = xs
            x, _, a = apply_layer(pl_, x, cfg, positions, causal=causal)
            return (x, aux + a), None
        if caches is None:
            pl_, ckv = xs
            x, _, a = apply_layer(pl_, x, cfg, positions, cross_kv=ckv, causal=causal)
            return (x, aux + a), None
        if cross_kv is None:
            pl_, cache_l = xs
            x, newc, a = apply_layer(pl_, x, cfg, positions, cache=cache_l,
                                     cache_len=cache_len, causal=causal)
            return (x, aux + a), newc
        pl_, cache_l, ckv = xs
        x, newc, a = apply_layer(pl_, x, cfg, positions, cache=cache_l,
                                 cache_len=cache_len, cross_kv=ckv, causal=causal)
        return (x, aux + a), newc

    body = _remat(body, cfg)
    xs: Any = params_L
    if caches is not None and cross_kv is not None:
        xs = (params_L, caches, cross_kv)
    elif caches is not None:
        xs = (params_L, caches)
    elif cross_kv is not None:
        xs = (params_L, cross_kv)
    if not cfg.scan_layers:  # unrolled (roofline calibration / small models)
        L = jax.tree_util.tree_leaves(params_L)[0].shape[0]
        carry = (x, jnp.zeros((), jnp.float32))
        ys = []
        for l in range(L):
            carry, y = body(carry, jax.tree.map(lambda a: a[l], xs))
            ys.append(y)
        new_caches = None if ys[0] is None else jax.tree.map(
            lambda *zs: jnp.stack(zs), *ys)
        return carry[0], new_caches, carry[1]
    (x, aux), new_caches = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), xs)
    return x, new_caches, aux


# ---------------------------------------------------------------- xlstm
def init_xlstm_stack(key, cfg):
    G = cfg.num_layers // cfg.xlstm_group
    n_m = cfg.xlstm_group - 1
    k1, k2 = jax.random.split(key)
    mk = jax.random.split(k1, G * n_m).reshape(G, n_m, 2)
    sk = jax.random.split(k2, G)

    def init_m(k):
        return {"ln": LL.init_norm(cfg), "cell": XL.init_mlstm(k, cfg)}

    def init_s(k):
        return {"ln": LL.init_norm(cfg), "cell": XL.init_slstm(k, cfg)}

    return {
        "mlstm": jax.vmap(jax.vmap(init_m))(mk),
        "slstm": jax.vmap(init_s)(sk),
    }


def apply_xlstm_stack(params, x, cfg, *, states=None):
    """states: {"m": (G,n_m,...) mLSTM (C,n,m), "s": (G,...) sLSTM} or None."""
    dt = _dtype(cfg)
    decode = states is not None

    def m_body(carry, xs):
        x = carry[0]
        if decode:
            pl_, st = xs
            h, new_st = XL.mlstm_block(pl_["cell"], LL.apply_norm(pl_["ln"], x, cfg.norm),
                                       cfg, state=st, dtype=dt)
            return (x + h,), new_st
        pl_ = xs
        h, _ = XL.mlstm_block(pl_["cell"], LL.apply_norm(pl_["ln"], x, cfg.norm),
                              cfg, chunk=cfg.attn_chunk, dtype=dt)
        return (x + h,), None

    def g_body(carry, xs):
        x = carry[0]
        if decode:
            gp, gst = xs
            (x,), new_m = _maybe_scan(cfg, m_body, (x,), (gp["mlstm"], gst["m"]))
            h, new_s = XL.slstm_block(gp["slstm"]["cell"],
                                      LL.apply_norm(gp["slstm"]["ln"], x, cfg.norm),
                                      cfg, state=gst["s"], dtype=dt)
            return (x + h,), {"m": new_m, "s": new_s}
        gp = xs
        (x,), _ = _maybe_scan(cfg, m_body, (x,), gp["mlstm"])
        h, _ = XL.slstm_block(gp["slstm"]["cell"],
                              LL.apply_norm(gp["slstm"]["ln"], x, cfg.norm),
                              cfg, dtype=dt)
        return (x + h,), None

    g_body = _remat(g_body, cfg)
    xs = ({"mlstm": params["mlstm"], "slstm": params["slstm"]}, states) if decode \
        else {"mlstm": params["mlstm"], "slstm": params["slstm"]}
    if not cfg.scan_layers:
        return _unrolled_groups(g_body, x, xs)
    (x,), new_states = jax.lax.scan(g_body, (x,), xs)
    return x, new_states


def init_xlstm_states(cfg, batch):
    G = cfg.num_layers // cfg.xlstm_group
    n_m = cfg.xlstm_group - 1
    H, dh = cfg.num_heads, cfg.head_dim
    return {
        "m": (
            jnp.zeros((G, n_m, batch, H, dh, dh), jnp.float32),
            jnp.zeros((G, n_m, batch, H, dh), jnp.float32),
            jnp.zeros((G, n_m, batch, H), jnp.float32),
        ),
        "s": (
            jnp.zeros((G, batch, H, dh), jnp.float32),
            jnp.ones((G, batch, H, dh), jnp.float32),
            jnp.zeros((G, batch, H, dh), jnp.float32),
            jnp.zeros((G, batch, H, dh), jnp.float32),
        ),
    }


# ---------------------------------------------------------------- hybrid
def init_hybrid_stack(key, cfg):
    G = cfg.num_layers // cfg.hybrid_group
    n_m = cfg.hybrid_group - 1
    k1, k2 = jax.random.split(key)
    mk = jax.random.split(k1, G * n_m).reshape(G, n_m, 2)

    def init_m(k):
        return {"ln": LL.init_norm(cfg), "cell": SSM.init_mamba2(k, cfg)}

    return {
        "mamba": jax.vmap(jax.vmap(init_m))(mk),
        "shared_attn": init_layer(k2, cfg),   # ONE weight-tied attn block
    }


def apply_hybrid_stack(params, x, cfg, positions, *, states=None, cache_len=None):
    """states: {"ssm": (G,n_m,B,H,P,N), "conv": {...}, "attn": (G,...) kv} or None."""
    dt = _dtype(cfg)
    decode = states is not None
    shared = params["shared_attn"]

    def m_body(carry, xs):
        x = carry[0]
        if decode:
            pl_, st, cc = xs
            h, new_st, new_cc = SSM.mamba2_block(
                pl_["cell"], LL.apply_norm(pl_["ln"], x, cfg.norm), cfg,
                state=st, conv_cache=cc, dtype=dt)
            return (x + h,), (new_st, new_cc)
        pl_ = xs
        h, _, _ = SSM.mamba2_block(pl_["cell"], LL.apply_norm(pl_["ln"], x, cfg.norm),
                                   cfg, chunk=min(cfg.attn_chunk, 256), dtype=dt)
        return (x + h,), None

    def g_body(carry, xs):
        x = carry[0]
        if decode:
            gp, gst = xs
            (x,), (new_ssm, new_conv) = _maybe_scan(
                cfg, m_body, (x,), (gp, gst["ssm"], gst["conv"]))
            x, new_kv, _ = apply_layer(shared, x, cfg, positions,
                                       cache=gst["attn"], cache_len=cache_len)
            return (x,), {"ssm": new_ssm, "conv": new_conv, "attn": new_kv}
        gp = xs
        (x,), _ = _maybe_scan(cfg, m_body, (x,), gp)
        x, _, _ = apply_layer(shared, x, cfg, positions)
        return (x,), None

    g_body = _remat(g_body, cfg)
    xs = (params["mamba"], states) if decode else params["mamba"]
    if not cfg.scan_layers:
        return _unrolled_groups(g_body, x, xs)
    (x,), new_states = jax.lax.scan(g_body, (x,), xs)
    return x, new_states


def _unrolled_groups(g_body, x, xs):
    G = jax.tree_util.tree_leaves(xs)[0].shape[0]
    carry = (x,)
    ys = []
    for g in range(G):
        carry, y = g_body(carry, jax.tree.map(lambda a: a[g], xs))
        ys.append(y)
    new = None if ys[0] is None else jax.tree.map(lambda *zs: jnp.stack(zs), *ys)
    return carry[0], new


def init_hybrid_states(cfg, batch, cache_seq, dtype=jnp.bfloat16):
    G = cfg.num_layers // cfg.hybrid_group
    n_m = cfg.hybrid_group - 1
    d_inner, H, P, N = SSM.ssm_dims(cfg)
    conv = SSM.init_conv_cache(cfg, batch, dtype)
    return {
        "ssm": jnp.zeros((G, n_m, batch, H, P, N), dtype),
        "conv": {k: jnp.zeros((G, n_m) + v.shape, dtype) for k, v in conv.items()},
        "attn": {
            "k": jnp.zeros((G, batch, cache_seq, cfg.num_kv_heads, cfg.head_dim), dtype),
            "v": jnp.zeros((G, batch, cache_seq, cfg.num_kv_heads, cfg.head_dim), dtype),
        },
    }


def init_kv_caches(cfg, batch, cache_seq, n_layers=None, dtype=jnp.bfloat16):
    L = n_layers or cfg.num_layers
    if cfg.mla:
        return {
            "c_kv": jnp.zeros((L, batch, cache_seq, cfg.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((L, batch, cache_seq, cfg.rope_head_dim), dtype),
        }
    return {
        "k": jnp.zeros((L, batch, cache_seq, cfg.num_kv_heads, cfg.head_dim), dtype),
        "v": jnp.zeros((L, batch, cache_seq, cfg.num_kv_heads, cfg.head_dim), dtype),
    }
