from .pipeline import SyntheticLMData, ParsaShardedData  # noqa: F401
