"""Data pipeline: deterministic synthetic token streams + Parsa-aware
document sharding.

``SyntheticLMData`` — seeded Zipfian token batches (train smoke/examples and
the dry-run's runtime-shape source).  Determinism: batch t is a pure
function of (seed, t), so restart-from-checkpoint replays the exact stream —
the property the fault-tolerance test asserts.

``ParsaShardedData`` — documents assigned to data shards by a Parsa
U-partition (DESIGN §3.1): each shard's batches draw from its own documents,
shrinking the shard's working vocabulary; the embedding traffic benchmark
measures the effect.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..core.bipartite import BipartiteGraph
from ..core.placement import Placement


@dataclasses.dataclass
class SyntheticLMData:
    vocab_size: int
    batch: int
    seq: int
    seed: int = 0
    zipf_s: float = 1.1

    def __post_init__(self):
        w = 1.0 / np.arange(1, self.vocab_size + 1) ** self.zipf_s
        self._p = w / w.sum()

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        toks = rng.choice(self.vocab_size, size=(self.batch, self.seq + 1), p=self._p)
        return {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
        }

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class ParsaShardedData:
    """Batches whose rows are grouped by the Parsa document partition."""

    def __init__(self, graph: BipartiteGraph, placement: Placement,
                 batch: int, seq: int, seed: int = 0):
        self.graph, self.pl = graph, placement
        self.batch, self.seq, self.seed = batch, seq, seed
        self.k = placement.k
        self.shard_docs = [np.flatnonzero(placement.doc_to_shard == i)
                           for i in range(self.k)]
        assert batch % self.k == 0, "batch must split across shards"

    def batch_at(self, step: int, permute_vocab: bool = True) -> dict:
        rng = np.random.default_rng((self.seed, step))
        per = self.batch // self.k
        rows = []
        for i in range(self.k):
            docs = rng.choice(self.shard_docs[i], size=per)
            for d in docs:
                words = self.graph.neighbors(int(d))
                if len(words) == 0:
                    words = np.zeros(1, np.int32)
                seq = rng.choice(words, size=self.seq + 1)
                rows.append(seq)
        toks = np.stack(rows).astype(np.int32)
        if permute_vocab:
            toks = self.pl.vocab_perm[toks].astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def working_set_per_shard(self, step: int) -> np.ndarray:
        """Unique vocab rows touched per shard — the paper's objective (6).
        Exact: union of the drawn documents' vocabularies (not subsampled)."""
        rng = np.random.default_rng((self.seed, step))
        per = self.batch // self.k
        out = np.zeros(self.k, np.int64)
        for i in range(self.k):
            docs = rng.choice(self.shard_docs[i], size=per)
            vocab = set()
            for d in docs:
                vocab.update(self.graph.neighbors(int(d)).tolist())
            out[i] = len(vocab)
        return out
