"""Sketched server sets: bounded-error compression of the packed wire
format (see ``repro.sketch.spec``)."""
from .spec import (  # noqa: F401
    SketchSpec,
    linear_counting_estimate,
    packed_popcount_rows,
    rank_hot_columns,
    set_structure_bytes,
)

__all__ = [
    "SketchSpec",
    "linear_counting_estimate",
    "packed_popcount_rows",
    "rank_hot_columns",
    "set_structure_bytes",
]
