r"""Sketched server sets: break the O(k·|V|/32) bitmask width ceiling.

Every set structure in the pipeline — server-set masks, need words, the
parallel backend's per-worker stale copies, the stream arena — is a packed
(k, ⌈|V|/32⌉) uint32 bitmask.  At the paper's CTR scale (|V| ≈ 10^8,
k = 64) that is hundreds of gigabytes of replicated masks; the greedy
select's working set can never be VMEM-resident.  The submodular theory
already tolerates approximate marginal gains (GreeDi's two-round partition,
arXiv:1411.0541; the randomized-rounds block assignment of
arXiv:1502.02606), so a bounded-error estimate of |N(u) \ S_i| preserves
the approximation story while shrinking every structure by the compression
ratio.

The sketch is a *column compression*, not a new wire format: a static map

    m(c) = rank of c in the hot set            if c is hot (exact prefix)
         = hot_bits + h(c) mod bucket_bits     otherwise (hashed buckets)

sends every parameter column into a ``width_bits = hot_bits + bucket_bits``
domain, and all sets are kept as ordinary packed uint32 bitmasks over that
domain.  Consequences, each load-bearing:

  * Same wire format — union / delta / popcount / OR-merge / the arena /
    the Alg 4 all_gather run UNCHANGED on the sketched words; only the
    width shrinks.  ``sketch(a | b) == sketch(a) | sketch(b)`` exactly
    (a hash of a union is the union of the hashes), so the lattice algebra
    the parallel merge relies on is preserved, not approximated.
  * Bounded error, one-sided — a sketched popcount never exceeds the true
    cardinality (hashing only merges bits), is exact on the hot prefix,
    and the bucket region is a classic linear-counting sketch whose
    cardinality estimate −m·ln(z/m) carries the standard error band
    (``linear_counting_error``).
  * Exact-parity mode for free — ``hot_bits ≥ |V|`` makes the map the
    identity: the sketched pipeline is bit-identical to the exact one
    (regression-tested), so the sketch path cannot silently drift when it
    is not compressing.
  * The hot set is either the identity prefix ``[0, hot_bits)`` (streams,
    where future footprints are unknown) or the top-``hot_bits`` columns by
    popcount footprint (``rank_hot_columns``; membership kept as a sorted
    array + searchsorted, so the map stays O(hot_bits) memory — no
    (|V|,)-sized table exists even at |V| = 10^8).

V-side assignments in sketch space map back to real columns through the
same m: ``expand_parts_v`` gives every true column the machine of its
sketch slot — hot columns get their exact Alg 2 assignment, bucketed tail
columns are co-located by hash, i.e. the random placement of the cold tail
the randomized-rounds guarantee covers.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from ..core.bipartite import BipartiteGraph

__all__ = [
    "SketchSpec",
    "rank_hot_columns",
    "set_structure_bytes",
    "packed_popcount_rows",
    "linear_counting_estimate",
]

# splitmix64 finalizer constants — the column hash must be arithmetic (no
# lookup table) so the map costs O(1) memory at |V| = 10^8
_MIX1 = np.uint64(0xBF58476D1CE4E5B9)
_MIX2 = np.uint64(0x94D049BB133111EB)
_GOLDEN = np.uint64(0x9E3779B97F4A7C15)

# per-byte popcount (numpy < 2.0 has no np.bitwise_count)
_POPCOUNT8 = np.unpackbits(
    np.arange(256, dtype=np.uint8).reshape(-1, 1), axis=1).sum(
        axis=1).astype(np.int64)

_MAP_CHUNK = 1 << 24  # columns mapped per host pass (bounds transients)


def _splitmix64(x: np.ndarray) -> np.ndarray:
    """Vectorized splitmix64 finalizer on uint64 (wrapping arithmetic)."""
    x = x * _GOLDEN + np.uint64(1)
    x ^= x >> np.uint64(30)
    x *= _MIX1
    x ^= x >> np.uint64(27)
    x *= _MIX2
    x ^= x >> np.uint64(31)
    return x


def packed_popcount_rows(masks: np.ndarray) -> np.ndarray:
    """Per-row popcount of a packed (rows, W) bitmask stack → (rows,) int64."""
    m = np.ascontiguousarray(masks).view(np.uint32)
    if hasattr(np, "bitwise_count"):
        return np.bitwise_count(m).sum(axis=-1, dtype=np.int64)
    return _POPCOUNT8[m.view(np.uint8).reshape(m.shape[0], -1)].sum(axis=-1)


def linear_counting_estimate(occupied: int, m: int) -> float:
    """Linear-counting cardinality estimate n̂ = −m·ln(z/m) from ``occupied``
    set buckets out of ``m``.  A saturated sketch (z = 0) is clamped to the
    z = 1/2 estimate — the caller's error band will not cover saturation,
    by design (it means the sketch is underprovisioned)."""
    z = max(m - occupied, 0)
    return float(m * math.log(m / max(z, 0.5)))


def linear_counting_error(n: int, m: int) -> float:
    """Standard deviation of the linear-counting estimate of an n-element
    set in m buckets: √m·(e^t − t − 1)^½ with load t = n/m (Whang et al.).
    Used by the property tests to set the tolerated error band."""
    t = n / m
    return math.sqrt(m * max(math.expm1(t) - t, 1e-12))


def rank_hot_columns(graph: BipartiteGraph, hot_bits: int) -> np.ndarray:
    """The ``hot_bits`` columns with the largest popcount footprint (column
    degree — the number of U rows whose mask sets the bit), as a SORTED id
    array ready for ``SketchSpec(hot_ids=...)``.  O(E) bincount + one
    argpartition; ties resolve to lower column ids."""
    deg = np.bincount(graph.u_indices, minlength=graph.num_v)
    if hot_bits >= graph.num_v:
        return np.arange(graph.num_v, dtype=np.int64)
    top = np.argpartition(-deg, hot_bits - 1)[:hot_bits]
    return np.sort(top).astype(np.int64)


def set_structure_bytes(width_bits: int, k: int, block: int,
                        workers: int = 1) -> int:
    """Peak bytes of the width-dependent set structures ONE partition scan
    holds live per its (k, W) masks: the per-worker stale server-set copy,
    the all_gather merge buffer, and each worker's rebuilt (B, W) block
    tile (plus its transposed twin on the jnp down-date path).  Everything
    here scales linearly in the packed width — the quantity the sketch
    compresses — and is what ``bench_sketch`` meters as ``mem_bytes``.
    Per-vertex compact word lists (O(cap), width-independent) are excluded
    on purpose."""
    W = (width_bits + 31) // 32
    stale = workers * k * W * 4          # per-worker stale S copies
    gather = workers * k * W * 4         # OR-merge all_gather buffer
    tiles = workers * 2 * block * W * 4  # rebuilt (B, W) nbr + transpose
    return stale + gather + tiles


@dataclasses.dataclass(frozen=True)
class SketchSpec:
    """Static column-compression map behind ``ParsaConfig.set_repr="sketch"``.

    ``num_v`` is the true parameter extent; columns below ``hot_bits`` (or
    in ``hot_ids``, when given) keep exact identity slots, every other
    column hashes into one of ``bucket_bits`` shared slots.  The sketched
    domain has ``width_bits`` columns and everything packed-bitmask shaped
    downstream simply runs at that width.
    """

    num_v: int
    hot_bits: int
    bucket_bits: int
    seed: int = 0
    # sorted ids of the columns granted exact slots (len == hot_bits);
    # None = the identity prefix [0, hot_bits)
    hot_ids: np.ndarray | None = dataclasses.field(
        default=None, repr=False, compare=False)

    def __post_init__(self):
        if self.num_v <= 0:
            raise ValueError(f"num_v must be positive, got {self.num_v}")
        if self.hot_bits < 0:
            raise ValueError(
                f"hot_bits must be >= 0, got {self.hot_bits}")
        if self.bucket_bits < 0:
            raise ValueError(
                f"bucket_bits must be >= 0, got {self.bucket_bits}")
        if not self.is_exact and self.bucket_bits == 0:
            raise ValueError(
                "a compressing sketch (hot_bits < num_v) needs "
                "bucket_bits > 0")
        if self.hot_ids is not None:
            ids = np.asarray(self.hot_ids)
            if ids.shape != (self.hot_bits,):
                raise ValueError(
                    f"hot_ids must have shape ({self.hot_bits},), got "
                    f"{ids.shape}")

    # ------------------------------------------------------------ geometry
    @classmethod
    def for_graph(cls, num_v: int, hot_bits: int, bucket_bits: int,
                  seed: int = 0,
                  hot_ids: np.ndarray | None = None) -> "SketchSpec":
        """Clip the configured geometry to the graph: ``hot_bits ≥ num_v``
        collapses to the exact identity map (bucket region dropped), which
        is what makes ``set_repr="sketch"`` safe at any scale — small
        graphs run bit-identical to the exact pipeline."""
        if hot_bits >= num_v:
            return cls(num_v=num_v, hot_bits=num_v, bucket_bits=0,
                       seed=seed)
        return cls(num_v=num_v, hot_bits=hot_bits, bucket_bits=bucket_bits,
                   seed=seed, hot_ids=hot_ids)

    @property
    def is_exact(self) -> bool:
        """True when the map is the identity (no compression)."""
        return self.hot_bits >= self.num_v

    @property
    def width_bits(self) -> int:
        """Column extent of the sketched domain."""
        return self.num_v if self.is_exact else \
            self.hot_bits + self.bucket_bits

    @property
    def width_words(self) -> int:
        return (self.width_bits + 31) // 32

    @property
    def compression(self) -> float:
        """Exact-width : sketch-width ratio of every packed structure."""
        return ((self.num_v + 31) // 32) / self.width_words

    # ------------------------------------------------------------- the map
    def map_columns(self, cols: np.ndarray) -> np.ndarray:
        """m(c) for an arbitrary int column array — identity (or hot rank)
        on the hot set, splitmix64 bucket otherwise.  Columns ≥ ``num_v``
        are legal (growing streams): the hash covers any id, so the
        sketched width never grows."""
        cols = np.asarray(cols, dtype=np.int64)
        if self.is_exact:
            return cols.copy()
        with np.errstate(over="ignore"):  # uint64 wrap is the hash
            h = _splitmix64(cols.astype(np.uint64) +
                            np.uint64(self.seed) * _GOLDEN)
        bucket = (self.hot_bits +
                  (h % np.uint64(self.bucket_bits)).astype(np.int64))
        if self.hot_ids is None:
            return np.where(cols < self.hot_bits, cols, bucket)
        ids = np.asarray(self.hot_ids)
        pos = np.searchsorted(ids, cols)
        pos_c = np.minimum(pos, self.hot_bits - 1)
        is_hot = ids[pos_c] == cols
        return np.where(is_hot, pos_c, bucket)

    def sketch_graph(self, graph: BipartiteGraph) -> BipartiteGraph:
        """The graph with every edge column pushed through the map: same U
        rows and CSR structure, ``num_v = width_bits``.  Duplicate columns
        a row gains from bucket collisions are harmless — every consumer
        ORs bits.  Chunked so no second edge-sized int64 transient exists
        at the 10^8-edge scale."""
        if self.is_exact:
            return graph
        src = np.asarray(graph.u_indices)
        out = np.empty(src.shape[0], np.int32)
        for lo in range(0, src.shape[0], _MAP_CHUNK):
            hi = min(lo + _MAP_CHUNK, src.shape[0])
            out[lo:hi] = self.map_columns(src[lo:hi]).astype(np.int32)
        return BipartiteGraph(graph.num_u, self.width_bits,
                              np.asarray(graph.u_indptr), out)

    def sketch_masks(self, masks: np.ndarray, num_v: int | None = None
                     ) -> np.ndarray:
        """Packed (k, ⌈num_v/32⌉) masks over the TRUE domain → packed
        (k, width_words) masks over the sketched domain (bit b set iff
        some set column maps to b).  Warm-start / test helper — walks the
        set bits row by row, so meant for moderate |V|, not the
        unallocatable-exact regime (where no true-domain mask exists to
        convert in the first place)."""
        from ..kernels.parsa_cost import coerce_packed_sets, pack_bitmask

        num_v = self.num_v if num_v is None else num_v
        packed = coerce_packed_sets(masks, num_v)
        if self.is_exact:
            return packed
        rows = []
        for r in range(packed.shape[0]):
            bits = np.unpackbits(
                np.ascontiguousarray(packed[r : r + 1]).view(np.uint8),
                bitorder="little")[:num_v]
            rows.append(self.map_columns(np.flatnonzero(bits)))
        return np.asarray(pack_bitmask(rows, self.width_bits))

    def expand_parts_v(self, parts_v_sketch: np.ndarray,
                       num_v: int | None = None) -> np.ndarray:
        """Sketch-space V assignment → true-space: column c is served by
        the machine of its sketch slot m(c).  Chunked gather, O(num_v)
        output only."""
        num_v = self.num_v if num_v is None else num_v
        parts_v_sketch = np.asarray(parts_v_sketch, np.int32)
        if self.is_exact:
            return parts_v_sketch[:num_v].copy()
        out = np.empty(num_v, np.int32)
        for lo in range(0, num_v, _MAP_CHUNK):
            hi = min(lo + _MAP_CHUNK, num_v)
            out[lo:hi] = parts_v_sketch[
                self.map_columns(np.arange(lo, hi, dtype=np.int64))]
        return out

    # ------------------------------------------------------------ estimates
    def estimate_cardinality(self, mask_row: np.ndarray) -> float:
        """Bounded-error cardinality estimate of the TRUE set behind one
        sketched packed row: exact popcount on the hot prefix + linear
        counting over the bucket region."""
        row = np.ascontiguousarray(mask_row).reshape(1, -1)
        if self.is_exact:
            return float(packed_popcount_rows(row)[0])
        bits = np.unpackbits(row.view(np.uint32).view(np.uint8),
                             bitorder="little")[: self.width_bits]
        hot = int(bits[: self.hot_bits].sum())
        occ = int(bits[self.hot_bits :].sum())
        return hot + linear_counting_estimate(occ, self.bucket_bits)

    def error_band(self, tail_n: int, sigmas: float = 4.0) -> float:
        """Tolerated |estimate − truth| for a set with ``tail_n`` elements
        outside the hot prefix: ``sigmas`` linear-counting standard
        deviations (the hot part contributes zero error)."""
        if self.is_exact:
            return 0.0
        return sigmas * linear_counting_error(tail_n, self.bucket_bits)

    # ------------------------------------------------------------- memory
    def mem_bytes(self, k: int, block: int, workers: int = 1) -> int:
        """``set_structure_bytes`` at this spec's sketched width."""
        return set_structure_bytes(self.width_bits, k, block, workers)

    def exact_mem_bytes(self, k: int, block: int, workers: int = 1) -> int:
        """``set_structure_bytes`` the exact pipeline would need at the
        true width — the denominator of the measured compression ratio."""
        return set_structure_bytes(self.num_v, k, block, workers)
