r"""ℓ1-regularized logistic regression on sparse data (paper §5.5).

minimize  Σ_i log(1 + exp(-y_i x_i·w)) + λ‖w‖₁

Data rows are CSR; the JAX compute path uses gather + segment_sum so a
worker's step is one jit over fixed (padded) nnz — the same shape every
iteration, matching a real worker's steady state.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from ..core.bipartite import BipartiteGraph

__all__ = ["SparseBatch", "lr_objective", "lr_grad", "make_problem"]


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=["row_ids", "col_ids", "values", "labels"],
    meta_fields=["num_rows", "num_features"],
)
@dataclasses.dataclass
class SparseBatch:
    """Padded CSR batch: row_ids aligns each nnz with its row."""

    num_rows: int
    num_features: int
    row_ids: jax.Array   # (nnz_pad,) int32
    col_ids: jax.Array   # (nnz_pad,) int32
    values: jax.Array    # (nnz_pad,) f32  (0 on padding)
    labels: jax.Array    # (num_rows,) f32 ∈ {-1, +1}

    @staticmethod
    def from_graph(
        graph: BipartiteGraph, rows: np.ndarray, labels: np.ndarray, pad_to: int | None = None
    ) -> "SparseBatch":
        lens = (graph.u_indptr[rows + 1] - graph.u_indptr[rows]).astype(np.int64)
        nnz = int(lens.sum())
        pad = pad_to if pad_to is not None else nnz
        row_ids = np.zeros(pad, np.int32)
        col_ids = np.zeros(pad, np.int32)
        vals = np.zeros(pad, np.float32)
        off = 0
        for local_r, u in enumerate(rows):
            nb = graph.neighbors(int(u))
            row_ids[off : off + len(nb)] = local_r
            col_ids[off : off + len(nb)] = nb
            vals[off : off + len(nb)] = 1.0
            off += len(nb)
        return SparseBatch(
            len(rows), graph.num_v,
            jnp.asarray(row_ids), jnp.asarray(col_ids), jnp.asarray(vals),
            jnp.asarray(labels[rows].astype(np.float32)),
        )


def _margins(batch: SparseBatch, w: jax.Array) -> jax.Array:
    xw = jax.ops.segment_sum(
        batch.values * w[batch.col_ids], batch.row_ids, num_segments=batch.num_rows
    )
    return batch.labels * xw


def lr_objective(batch: SparseBatch, w: jax.Array, lam: float) -> jax.Array:
    m = _margins(batch, w)
    # log(1 + e^{-m}) computed stably
    loss = jnp.sum(jnp.logaddexp(0.0, -m))
    return loss + lam * jnp.sum(jnp.abs(w))


def lr_grad(batch: SparseBatch, w: jax.Array) -> jax.Array:
    """∇ of the smooth part: Σ -y_i σ(-y_i x_i·w) x_i, via scatter-add."""
    m = _margins(batch, w)
    coef = -batch.labels * jax.nn.sigmoid(-m)  # (rows,)
    contrib = batch.values * coef[batch.row_ids]
    return jax.ops.segment_sum(contrib, batch.col_ids, num_segments=batch.num_features)


def make_problem(graph: BipartiteGraph, seed: int = 0, noise: float = 0.1):
    """Plant a sparse ground-truth w* and emit consistent ±1 labels."""
    rng = np.random.default_rng(seed)
    w_star = np.zeros(graph.num_v, np.float32)
    support = rng.choice(graph.num_v, size=max(1, graph.num_v // 20), replace=False)
    w_star[support] = rng.normal(0, 1, size=support.size).astype(np.float32)
    margins = np.zeros(graph.num_u, np.float32)
    for u in range(graph.num_u):
        margins[u] = w_star[graph.neighbors(u)].sum()
    flip = rng.random(graph.num_u) < noise
    labels = np.where(np.sign(margins + 1e-6) * (1 - 2 * flip) >= 0, 1.0, -1.0)
    return w_star, labels.astype(np.float32)
