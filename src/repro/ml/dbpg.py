r"""DBPG: delayed block proximal gradient (the paper's solver, ref [19]).

Per iteration each worker computes the smooth gradient on its data block and
pushes it; servers apply the proximal update

    w ← prox_{ηλ‖·‖₁}(w − η·g)   (soft threshold)

Communication-reduction filters from [19], all implemented:
  * KKT filter   — a coordinate with w_j = 0 and |g_j| ≤ λ·(1−ε) already
    satisfies the ℓ1 KKT condition; its gradient entry need not be sent.
  * key caching  — key lists are sent once; steady-state messages carry
    values only (we meter bytes accordingly).
  * value compression — gradients quantized to int8 with a per-message
    scale and *error feedback* so quantization noise doesn't accumulate.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = ["DBPGConfig", "soft_threshold", "kkt_filter", "quantize_int8", "dequantize_int8"]


@dataclasses.dataclass
class DBPGConfig:
    lam: float = 0.1
    lr: float = 0.1
    max_delay: int = 0          # τ: bounded-delay consistency
    kkt_eps: float = 0.1        # KKT filter slack ε
    compress: bool = True       # int8 value compression
    error_feedback: bool = True


def soft_threshold(w: jax.Array, t: float | jax.Array) -> jax.Array:
    return jnp.sign(w) * jnp.maximum(jnp.abs(w) - t, 0.0)


def kkt_filter(w: jax.Array, g: jax.Array, lam: float, eps: float) -> jax.Array:
    """Bool mask of coordinates whose gradient MUST be communicated."""
    inactive = (w == 0.0) & (jnp.abs(g) <= lam * (1.0 - eps))
    return ~inactive


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def prox_step(w: jax.Array, g: jax.Array, cfg: DBPGConfig) -> jax.Array:
    return soft_threshold(w - cfg.lr * g, cfg.lr * cfg.lam)
