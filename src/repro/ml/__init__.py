from .lr import SparseBatch, lr_objective, lr_grad, make_problem  # noqa: F401
from .dbpg import DBPGConfig, soft_threshold, kkt_filter  # noqa: F401
from .ps import PSCluster, PullHandle, PullPlan, TrafficMeter  # noqa: F401
