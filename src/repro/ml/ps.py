r"""Parameter-server simulation with exact traffic metering (paper §2.3, §5.5).

k machines, each hosting worker i (rows U_i) and server i (weights V_i).
Per DBPG iteration:

  push  — worker i sends smooth-gradient entries for its working set
          N(U_i), split by owning server; the KKT filter drops inactive
          coordinates; values int8-compressed (w/ error feedback); keys are
          cached after the first iteration ([19]'s key caching).
  update— each server aggregates and applies the proximal step to its slice.
  pull  — worker i fetches the *changed* values it needs (value-delta
          caching); entries owned by server i are free (same machine).

Traffic is metered exactly in bytes, split inner- vs inter-machine — the
quantity in Tables 3/4.  Bounded delay τ: a worker's gradient may be
computed against weights up to τ iterations stale (deterministic schedule),
the consistency model both Parsa (§4.3) and DBPG [19] rely on.

Wall-clock is *modeled* (single-CPU container): per iteration,
  t = max_i flops_i / flops_rate + max_i inter_bytes_i / bandwidth,
with compute overlapping none of the communication (conservative).
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..core.bipartite import BipartiteGraph
from ..core.costs import need_matrix
from ..obs.trace import trace_instant
from .dbpg import DBPGConfig, kkt_filter, prox_step, quantize_int8, dequantize_int8
from .lr import SparseBatch, lr_grad, lr_objective

__all__ = ["TrafficMeter", "PSCluster", "PullPlan", "PullHandle"]


@dataclasses.dataclass
class TrafficMeter:
    inner_bytes: int = 0
    inter_bytes: int = 0
    per_machine: np.ndarray | None = None

    def _ensure(self, size: int) -> None:
        # per_machine sizes itself lazily so a bare TrafficMeter() works;
        # PSCluster still pre-sizes it from k at construction
        if self.per_machine is None:
            self.per_machine = np.zeros(size, dtype=np.int64)
        elif self.per_machine.shape[0] < size:
            self.per_machine = np.concatenate(
                [self.per_machine,
                 np.zeros(size - self.per_machine.shape[0], np.int64)])

    def add(self, src: int, dst: int, nbytes: int):
        if src == dst:
            self.inner_bytes += nbytes
        else:
            self.inter_bytes += nbytes
            self._ensure(max(src, dst) + 1)
            self.per_machine[src] += nbytes
            self.per_machine[dst] += nbytes

    @property
    def total(self) -> int:
        return self.inner_bytes + self.inter_bytes


@dataclasses.dataclass
class PullPlan:
    """What a worker's next pull would fetch, before committing to it.

    ``delta`` marks the working-set entries whose server value differs from
    the worker's stale buffer (value-delta caching — the same quantity
    ``step()`` meters); ``src_bytes[j]`` is the 4 B/value payload owed by
    server machine ``j``.  Planning is separated from ``pull_nowait`` so the
    serving engine can price each source link (bandwidth × straggle, retry
    timeouts) and exclude dead shards *before* any bytes are metered."""

    worker: int
    need: np.ndarray          # (V,) bool — the request's working set
    delta: np.ndarray         # (V,) bool — entries that must be fetched
    src_bytes: np.ndarray     # (k,) int64 — bytes per source machine

    @property
    def total_bytes(self) -> int:
        return int(self.src_bytes.sum())


@dataclasses.dataclass
class PullHandle:
    """Device future for a non-blocking pull.

    The host→device transfer of the worker's refreshed buffer is dispatched
    at issue time; ``block()`` waits out the *remaining* modeled wire time
    (``wire_s`` + retry penalties ``wait_s``, clocked from ``issued_at``)
    and then ``jax.block_until_ready`` on the buffer — so any compute the
    caller dispatched in between genuinely overlaps the transfer, and the
    overlap is measured rather than assumed."""

    worker: int
    issued_at: float          # perf_counter at issue
    wire_s: float             # modeled transfer time (pure, per live links)
    wait_s: float             # retry/timeout penalty spent on failed links
    inner_bytes: int
    inter_bytes: int
    fresh_entries: int        # entries actually refreshed
    stale_entries: int        # entries left stale (excluded/dead sources)
    buffer: jax.Array         # (V,) f32 device view of the worker's cache
    queue_s: float = 0.0      # NIC-backlog delay ahead of the transfer

    @property
    def done_at(self) -> float:
        return self.issued_at + self.wire_s + self.wait_s + self.queue_s

    def block(self) -> jax.Array:
        remaining = self.done_at - time.perf_counter()
        if remaining > 0:
            time.sleep(remaining)
        jax.block_until_ready(self.buffer)
        return self.buffer


class PSCluster:
    @classmethod
    def from_partition(cls, graph, labels, result, cfg, **kw) -> "PSCluster":
        """Build the cluster from a ``repro.api.PartitionResult`` — the
        supported path for wiring a Parsa layout into the PS simulation."""
        if result.parts_v is None:
            raise ValueError(
                "PartitionResult has no parts_v; run repro.api.partition "
                "with ParsaConfig(refine_v=True)")
        return cls(graph, labels, result.parts_u, result.parts_v,
                   result.k, cfg, **kw)

    def __init__(
        self,
        graph: BipartiteGraph,
        labels: np.ndarray,
        parts_u: np.ndarray,
        parts_v: np.ndarray,
        k: int,
        cfg: DBPGConfig,
        flops_rate: float = 50e9,
        bandwidth: float = 125e6,  # 1 GbE, as in the paper's cluster
        seed: int = 0,
    ):
        self.graph, self.k, self.cfg = graph, k, cfg
        self.parts_u = np.asarray(parts_u)
        self.parts_v = np.asarray(parts_v)
        self.flops_rate, self.bandwidth = flops_rate, bandwidth
        self.need = need_matrix(graph, self.parts_u, k)  # (k, V) bool
        self.owner = self.parts_v.copy()
        rr = np.flatnonzero(self.owner < 0)
        self.owner[rr] = rr % k  # isolated rows: arbitrary owners
        self._labels = np.asarray(labels, np.float32)
        self.rows = [np.flatnonzero(self.parts_u == i) for i in range(k)]
        # per-machine batches and the concatenated oracle batch are built on
        # first use — serving-scale clusters (50k+ rows) only ever touch a
        # small working set per request and never pay the full conversion
        self._batches: list[SparseBatch] | None = None
        self._full_batch: SparseBatch | None = None
        self.placement_version = 0  # bumped by apply_placement (router sync)
        self.w = jnp.zeros(graph.num_v, jnp.float32)
        self._grad = jax.jit(lr_grad)
        self._obj = jax.jit(lr_objective, static_argnames=("lam",))
        self.meter = TrafficMeter(per_machine=np.zeros(k, dtype=np.int64))
        self._keys_sent = np.zeros((k, k), dtype=bool)  # push key caching
        self._pull_cache: list[np.ndarray] = [
            np.zeros(graph.num_v, np.float32) for _ in range(k)
        ]
        self._ef = [np.zeros(graph.num_v, np.float32) for _ in range(k)]
        self._hist: list[np.ndarray] = []
        self.rng = np.random.default_rng(seed)

    @property
    def batches(self) -> list[SparseBatch]:
        if self._batches is None:
            self._batches = [
                SparseBatch.from_graph(self.graph, rows, self._labels)
                for rows in self.rows
            ]
        return self._batches

    @property
    def full_batch(self) -> SparseBatch:
        if self._full_batch is None:
            self._full_batch = SparseBatch.from_graph(
                self.graph, np.arange(self.graph.num_u), self._labels)
        return self._full_batch

    # ------------------------------------------------------------------
    def apply_placement(self, parts_u: np.ndarray, parts_v: np.ndarray,
                        k: int | None = None) -> dict:
        """Apply a new Parsa placement mid-run (streaming drift repair, or
        an elastic grow/shrink/repair that changes the machine count).

        Re-shards example rows across workers and weight ownership across
        servers, metering the one-time re-sharding traffic in the same
        ``TrafficMeter`` the training loop uses: a moved example row costs
        its nnz × 8 bytes (4 B key + 4 B value per entry), a moved weight
        8 bytes — both inter-machine only when the hosting machine actually
        changes.  Weight values and the optimizer state live in the global
        vector, so training continues exactly where it left off; the push
        key caches are invalidated (working sets changed, keys must be
        re-sent).  Returns the move counts and metered bytes.

        ``k`` changes the machine count (``repro.elastic``): departing
        shards are torn down after their rows/weights are re-metered onto
        their new hosts, spawned shards start with cold pull caches (their
        first pull fetches the full working set, which the training loop
        meters as ordinary pull traffic).  Labels in ``parts_u``/
        ``parts_v`` must already be < the new ``k``.
        """
        parts_u = np.asarray(parts_u)
        parts_v = np.asarray(parts_v)
        new_k = self.k if k is None else int(k)
        if new_k < 1:
            raise ValueError(f"k must be >= 1, got {new_k}")
        if parts_u.shape != self.parts_u.shape:
            raise ValueError(
                f"parts_u shape {parts_u.shape} != cluster's "
                f"{self.parts_u.shape} (PSCluster serves a fixed graph)")
        if parts_v.shape != self.parts_v.shape:
            raise ValueError(
                f"parts_v shape {parts_v.shape} != cluster's "
                f"{self.parts_v.shape}")
        if parts_u.size and int(parts_u.max()) >= new_k:
            raise ValueError(
                f"parts_u labels reach {int(parts_u.max())} but k={new_k}")
        if parts_v.size and int(parts_v.max()) >= new_k:
            raise ValueError(
                f"parts_v labels reach {int(parts_v.max())} but k={new_k}")
        new_owner = parts_v.copy()
        rr = np.flatnonzero(new_owner < 0)
        new_owner[rr] = rr % new_k
        bytes_before = self.meter.total
        # src labels live in the old fleet, dst labels in the new one —
        # meter over the union so grow/shrink transfers land on both ends
        km = max(self.k, new_k)
        if km > self.meter.per_machine.shape[0]:
            self.meter.per_machine = np.concatenate(
                [self.meter.per_machine,
                 np.zeros(km - self.meter.per_machine.shape[0], np.int64)])
        # moved example rows: delta-encoded batch re-shard, 8 B per entry
        # (4 B key + 4 B value); per-(src, dst) byte totals in two
        # vectorized bincount passes instead of k² full-array masks
        deg = np.diff(self.graph.u_indptr)
        pair_u = self.parts_u.astype(np.int64) * km + parts_u
        row_bytes = np.bincount(pair_u, weights=deg * 8.0,
                                minlength=km * km).reshape(km, km)
        moved_rows = int((self.parts_u != parts_u).sum())
        # moved weights: value + key per parameter changing its server
        moved_w = self.owner != new_owner
        moved_weights = int(moved_w.sum())
        pair_v = self.owner[moved_w].astype(np.int64) * km + new_owner[moved_w]
        w_bytes = np.bincount(pair_v, minlength=km * km).reshape(km, km) * 8
        for i in range(km):
            for j in range(km):
                if i == j:
                    continue
                nbytes = int(row_bytes[i, j]) + int(w_bytes[i, j])
                if nbytes:
                    self.meter.add(i, j, nbytes)
        # rebuild the sharded state for the new placement (shard teardown /
        # spawn when the machine count changed)
        if new_k != self.k:
            if new_k > self.k:
                self._pull_cache.extend(
                    np.zeros(self.graph.num_v, np.float32)
                    for _ in range(new_k - self.k))
            else:
                del self._pull_cache[new_k:]
            self.meter.per_machine = np.concatenate(
                [self.meter.per_machine[:new_k],
                 np.zeros(max(0, new_k - self.meter.per_machine.shape[0]),
                          np.int64)])
            self._keys_sent = np.zeros((new_k, new_k), dtype=bool)
            self.k = new_k
        else:
            self.meter.per_machine = self.meter.per_machine[:new_k]
            self._keys_sent[:] = False
        self.parts_u = parts_u.copy()
        self.parts_v = parts_v.copy()
        self.owner = new_owner
        self.need = need_matrix(self.graph, self.parts_u, self.k)
        self.rows = [np.flatnonzero(self.parts_u == i)
                     for i in range(self.k)]
        self._batches = None  # rebuilt lazily for the new row shards
        self.placement_version += 1
        # error-feedback residuals are supported on the OLD working sets;
        # under the new need masks the stranded coordinates could neither
        # be sent nor dropped — start the accumulators clean instead
        self._ef = [np.zeros(self.graph.num_v, np.float32)
                    for _ in range(self.k)]
        return {
            "moved_rows": moved_rows,
            "moved_weights": moved_weights,
            "reshard_bytes": self.meter.total - bytes_before,
        }

    def _worker_view(self, i: int, t: int) -> np.ndarray:
        """Weights as seen by worker i at iteration t under delay ≤ τ."""
        tau = self.cfg.max_delay
        if tau <= 0 or not self._hist:
            return np.asarray(self.w)
        d = int(self.rng.integers(0, tau + 1))
        d = min(d, len(self._hist))
        return self._hist[-d] if d > 0 else np.asarray(self.w)

    # ------------------------------------------------------------------
    # non-blocking pull API (repro.serving): plan → issue → overlap → block.
    # Byte accounting is identical to step()'s pull/push metering — value-
    # delta caching on pull, key caching + optional int8 compression on
    # push — but split into separate calls so a serving engine can overlap
    # the modeled wire time with device compute.

    def plan_pull(self, worker: int,
                  need: np.ndarray | None = None) -> PullPlan:
        """Price worker's next pull without transferring anything.

        ``need`` restricts the working set (a request touching few rows
        needs few weights); defaults to the worker's full §2.3 need mask."""
        need = self.need[worker] if need is None else np.asarray(need, bool)
        w_host = np.asarray(self.w)
        delta = need & (w_host != self._pull_cache[worker])
        src_bytes = np.bincount(self.owner[delta], minlength=self.k) * 4
        plan = PullPlan(worker=worker, need=need, delta=delta,
                        src_bytes=src_bytes.astype(np.int64))
        trace_instant("ps.plan_pull", worker=worker,
                      nbytes=int(plan.total_bytes))
        return plan

    def pull_nowait(self, plan: PullPlan, exclude: frozenset = frozenset(),
                    wire_s: float = 0.0, wait_s: float = 0.0,
                    queue_s: float = 0.0) -> PullHandle:
        """Issue the planned pull; returns a device future immediately.

        ``exclude`` lists source machines that failed their retry budget
        (dead or timed-out shards): their entries stay stale in the
        worker's buffer — the §4.3 bounded-staleness fallback — and cost
        no bytes.  ``wire_s``/``wait_s``/``queue_s`` are the modeled
        transfer time, retry penalty, and NIC-backlog delay (priced by the
        caller's bandwidth model and link clock); the returned handle's
        ``block()`` makes them real wall-clock."""
        worker = plan.worker
        w_host = np.asarray(self.w)
        fetch = plan.delta.copy()
        stale_entries = 0
        for j in exclude:
            if j == worker:
                continue  # local slice never travels; cannot go stale
            from_j = plan.delta & (self.owner == j)
            stale_entries += int(from_j.sum())
            fetch &= ~from_j
        inner = inter = 0
        per_src = np.bincount(self.owner[fetch], minlength=self.k)
        for j in np.flatnonzero(per_src):
            cnt = int(per_src[j])
            self.meter.add(int(j), worker, cnt * 4)
            if j == worker:
                inner += cnt * 4
            else:
                inter += cnt * 4
        cache = self._pull_cache[worker]
        cache[fetch] = w_host[fetch]
        # snapshot before the device transfer: later cache mutations (the
        # next pull) must not alias into a buffer still being computed on
        buffer = jnp.asarray(cache.copy())
        trace_instant("ps.pull_nowait", worker=worker,
                      fresh=int(fetch.sum()), stale=stale_entries,
                      inter_bytes=inter)
        return PullHandle(
            worker=worker, issued_at=time.perf_counter(),
            wire_s=float(wire_s), wait_s=float(wait_s),
            inner_bytes=inner, inter_bytes=inter,
            fresh_entries=int(fetch.sum()), stale_entries=stale_entries,
            buffer=buffer, queue_s=float(queue_s))

    def meter_push(self, worker: int, mask: np.ndarray) -> dict:
        """Meter worker's push of gradient entries ``mask`` to the owning
        servers (step()'s push accounting: per-entry values plus a 4 B key
        the first time a (worker, server) pair ships that link)."""
        mask = np.asarray(mask, bool)
        val_bytes = 1 if self.cfg.compress else 4
        inner = inter = 0
        per_server = np.bincount(self.owner[mask], minlength=self.k)
        for j in np.flatnonzero(per_server):
            cnt = int(per_server[j])
            nbytes = cnt * val_bytes
            if not self._keys_sent[worker, j]:
                nbytes += cnt * 4
                self._keys_sent[worker, j] = True
            self.meter.add(worker, int(j), nbytes)
            if j == worker:
                inner += nbytes
            else:
                inter += nbytes
        return {"inner_bytes": inner, "inter_bytes": inter}

    def commit_weights(self, new_w) -> None:
        """Server-side commit of the proximal update (serving push path)."""
        self.w = jnp.asarray(new_w)

    def step(self, t: int) -> dict:
        k, cfg = self.k, self.cfg
        val_bytes = 1 if cfg.compress else 4
        agg = np.zeros(self.graph.num_v, np.float64)
        flops = np.zeros(k)
        for i in range(k):
            w_view = self._worker_view(i, t)
            g = np.asarray(self._grad(self.batches[i], jnp.asarray(w_view)))
            if cfg.error_feedback and cfg.compress:
                g = g + self._ef[i]
            flops[i] = 4.0 * self.batches[i].values.shape[0]
            send_mask = self.need[i].copy()
            if cfg.kkt_eps > 0:
                keep = np.asarray(
                    kkt_filter(jnp.asarray(w_view), jnp.asarray(g), cfg.lam, cfg.kkt_eps)
                )
                send_mask &= keep
            if cfg.compress:
                sent = np.zeros_like(g)
                idx = np.flatnonzero(send_mask)
                if idx.size:
                    q, scale = quantize_int8(jnp.asarray(g[idx]))
                    deq = np.asarray(dequantize_int8(q, scale))
                    sent[idx] = deq
                if cfg.error_feedback:
                    self._ef[i] = g - sent
                payload = sent
            else:
                payload = np.where(send_mask, g, 0.0)
            agg += payload
            # ---- push traffic: entries per owning server
            for j in range(k):
                cnt = int((send_mask & (self.owner == j)).sum())
                if cnt == 0:
                    continue
                nbytes = cnt * val_bytes
                if not self._keys_sent[i, j]:
                    nbytes += cnt * 4  # key list, sent once
                    self._keys_sent[i, j] = True
                self.meter.add(i, j, nbytes)
        # ---- server proximal update (each server updates its slice; we hold
        # the concatenated global vector)
        new_w = np.asarray(
            prox_step(self.w, jnp.asarray(agg.astype(np.float32)), cfg)
        )
        changed = new_w != np.asarray(self.w)
        self._hist.append(np.asarray(self.w))
        if len(self._hist) > max(cfg.max_delay, 1) + 1:
            self._hist.pop(0)
        self.w = jnp.asarray(new_w)
        # ---- pull traffic: changed values in each worker's working set
        for i in range(k):
            stale = self._pull_cache[i]
            need_i = self.need[i]
            delta = need_i & (new_w != stale)
            for j in range(k):
                cnt = int((delta & (self.owner == j)).sum())
                if cnt:
                    self.meter.add(j, i, cnt * 4)
            stale[need_i] = new_w[need_i]
        inter_now = int(self.meter.per_machine.max())
        time = flops.max() / self.flops_rate + inter_now / self.bandwidth
        return {"modeled_time_cum": time}

    def run(self, iters: int, lam: float | None = None, log_every: int = 0) -> dict:
        lam = self.cfg.lam if lam is None else lam
        objs = []
        for t in range(iters):
            self.step(t)
            if log_every and (t % log_every == 0 or t == iters - 1):
                objs.append(float(self._obj(self.full_batch, self.w, lam=lam)))
        total_flops = 4.0 * self.full_batch.values.shape[0] * iters
        compute_time = total_flops / self.flops_rate / self.k
        comm_time = self.meter.per_machine.max() / self.bandwidth
        return {
            "objective": objs,
            "inner_bytes": self.meter.inner_bytes,
            "inter_bytes": self.meter.inter_bytes,
            "total_bytes": self.meter.total,
            "inner_fraction": self.meter.inner_bytes / max(self.meter.total, 1),
            "modeled_time_s": compute_time + comm_time,
            "modeled_compute_s": compute_time,
            "modeled_comm_s": comm_time,
            "nnz_w": int((np.asarray(self.w) != 0).sum()),
        }
