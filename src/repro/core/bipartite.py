"""Bipartite graph G(U, V, E) in CSR/CSC form (paper §2.2).

U is the data/example side, V the parameter side.  Edges are stored CSR from
U (``u_indptr``/``u_indices``) and, lazily, CSC from V (``v_indptr``/
``v_indices``) for the cost-update sweep in Algorithm 3 (step 13 needs
``N(v) ∩ U``).

Everything is plain numpy — the partitioner's reference implementation is a
host-side combinatorial algorithm; the TPU-native path packs this structure
into bitmasks (see ``jax_partition.py``).
"""
from __future__ import annotations

import dataclasses
import pathlib

import numpy as np

__all__ = ["BipartiteGraph", "from_edges", "load_npz"]


@dataclasses.dataclass
class BipartiteGraph:
    """CSR bipartite graph. ``u_indices[u_indptr[i]:u_indptr[i+1]]`` = N(u_i)."""

    num_u: int
    num_v: int
    u_indptr: np.ndarray  # int64 (num_u + 1,)
    u_indices: np.ndarray  # int32 (num_edges,)
    _v_indptr: np.ndarray | None = None
    _v_indices: np.ndarray | None = None

    # ------------------------------------------------------------------ api
    @property
    def num_edges(self) -> int:
        return int(self.u_indices.shape[0])

    def neighbors(self, u: int) -> np.ndarray:
        return self.u_indices[self.u_indptr[u] : self.u_indptr[u + 1]]

    def degree_u(self) -> np.ndarray:
        return np.diff(self.u_indptr).astype(np.int64)

    def degree_v(self) -> np.ndarray:
        return np.bincount(self.u_indices, minlength=self.num_v).astype(np.int64)

    # --------------------------------------------------------------- csc
    def _build_csc(self) -> None:
        order = np.argsort(self.u_indices, kind="stable")
        self._v_indices = np.repeat(
            np.arange(self.num_u, dtype=np.int32), np.diff(self.u_indptr)
        )[order]
        counts = np.bincount(self.u_indices, minlength=self.num_v)
        self._v_indptr = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)

    @property
    def v_indptr(self) -> np.ndarray:
        if self._v_indptr is None:
            self._build_csc()
        return self._v_indptr

    @property
    def v_indices(self) -> np.ndarray:
        if self._v_indices is None:
            self._build_csc()
        return self._v_indices

    def v_neighbors(self, v: int) -> np.ndarray:
        """N(v) ⊆ U."""
        return self.v_indices[self.v_indptr[v] : self.v_indptr[v + 1]]

    # --------------------------------------------------------------- slicing
    def subgraph_u(self, u_ids: np.ndarray) -> "BipartiteGraph":
        """Induced subgraph on a subset of U (V ids kept global, §4.2).

        V stays in the *global* id space so neighbor sets S_i compose across
        subgraphs — exactly how Alg 4 streams subgraphs against shared S_i.
        """
        u_ids = np.asarray(u_ids, dtype=np.int64)
        lens = self.u_indptr[u_ids + 1] - self.u_indptr[u_ids]
        indptr = np.concatenate([[0], np.cumsum(lens)]).astype(np.int64)
        indices = np.empty(int(indptr[-1]), dtype=np.int32)
        for out_i, u in enumerate(u_ids):
            indices[indptr[out_i] : indptr[out_i + 1]] = self.neighbors(int(u))
        return BipartiteGraph(len(u_ids), self.num_v, indptr, indices)

    def slice_u(self, start: int, stop: int) -> "BipartiteGraph":
        """Contiguous U-row slice ``[start, stop)`` with global V ids —
        vectorized (no per-vertex loop), the chunking primitive of the
        streaming pipeline: ``g.slice_u(a, b)`` is what a stream fed rows
        a..b of ``g`` would have received as one chunk."""
        if not 0 <= start <= stop <= self.num_u:
            raise ValueError(
                f"slice [{start}, {stop}) out of range for num_u={self.num_u}")
        lo, hi = self.u_indptr[start], self.u_indptr[stop]
        return BipartiteGraph(
            stop - start, self.num_v,
            (self.u_indptr[start : stop + 1] - lo).astype(np.int64),
            self.u_indices[lo:hi])

    # --------------------------------------------------------------- io
    def save_npz(self, path: str | pathlib.Path) -> None:
        np.savez_compressed(
            path,
            num_u=self.num_u,
            num_v=self.num_v,
            u_indptr=self.u_indptr,
            u_indices=self.u_indices,
        )

    def validate(self) -> None:
        assert self.u_indptr.shape == (self.num_u + 1,)
        assert self.u_indptr[0] == 0 and self.u_indptr[-1] == self.num_edges
        assert np.all(np.diff(self.u_indptr) >= 0)
        if self.num_edges:
            assert self.u_indices.min() >= 0
            assert self.u_indices.max() < self.num_v


def from_edges(num_u: int, num_v: int, edges_u: np.ndarray, edges_v: np.ndarray) -> BipartiteGraph:
    """Build CSR from an edge list (duplicates removed)."""
    edges_u = np.asarray(edges_u, dtype=np.int64)
    edges_v = np.asarray(edges_v, dtype=np.int64)
    key = edges_u * num_v + edges_v
    key = np.unique(key)
    eu = (key // num_v).astype(np.int64)
    ev = (key % num_v).astype(np.int32)
    counts = np.bincount(eu, minlength=num_u)
    indptr = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)
    return BipartiteGraph(num_u, num_v, indptr, ev)


def load_npz(path: str | pathlib.Path) -> BipartiteGraph:
    z = np.load(path)
    return BipartiteGraph(
        int(z["num_u"]), int(z["num_v"]), z["u_indptr"], z["u_indices"]
    )
