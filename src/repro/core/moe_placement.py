"""Parsa-driven MoE expert placement (DESIGN §3.2).

The (token-group × expert) affinity graph: U = groups of consecutive tokens
(a proxy for the sequences a data shard owns), V = experts; an edge means
the group routed ≥1 token to the expert.  Parsa's V-partition maps experts
to EP shards so that each data shard's routed experts are mostly local,
shrinking the all-to-all.  U-partition co-locates groups with correlated
routing.  Output is an expert permutation consumed by the MoE layer's
EP sharding (experts are laid out contiguously per shard).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .bipartite import from_edges

__all__ = ["ExpertPlacement", "build_expert_placement", "alltoall_traffic"]


@dataclasses.dataclass
class ExpertPlacement:
    k: int
    expert_to_shard: np.ndarray   # (num_experts,)
    expert_perm: np.ndarray       # new position of each expert id
    group_to_shard: np.ndarray


def build_expert_placement(
    routing_counts: np.ndarray,  # (num_groups, num_experts) int — tokens routed
    k: int,
    seed: int = 0,
    backend: str = "host",
) -> ExpertPlacement:
    """Parsa-place experts via the ``repro.api`` facade (one call: U + V)."""
    from ..api import ParsaConfig, partition  # lazy: core ↔ api

    groups, experts = routing_counts.shape
    gu, gv = np.nonzero(routing_counts)
    g = from_edges(groups, experts, gu, gv)
    res = partition(g, ParsaConfig(k=k, backend=backend, seed=seed,
                                   refine_v=True, sweeps=2))
    parts_u, parts_v = res.parts_u, res.parts_v
    parts_v = parts_v.copy()
    unused = np.flatnonzero(parts_v < 0)
    if unused.size:
        counts = np.bincount(parts_v[parts_v >= 0], minlength=k)
        fill = np.argsort(counts, kind="stable")
        parts_v[unused] = fill[np.arange(unused.size) % k]
    order = np.argsort(parts_v, kind="stable")
    perm = np.empty(experts, dtype=np.int64)
    perm[order] = np.arange(experts)
    return ExpertPlacement(k, parts_v.astype(np.int32), perm, parts_u.astype(np.int32))


def alltoall_traffic(
    routing_counts: np.ndarray, placement: ExpertPlacement, token_bytes: int = 2
) -> dict:
    """Tokens crossing shards under the placement vs. round-robin experts."""
    groups, experts = routing_counts.shape
    k = placement.k

    def cross(expert_shard: np.ndarray, group_shard: np.ndarray) -> int:
        total = 0
        for gidx in range(groups):
            gs = group_shard[gidx]
            counts = routing_counts[gidx]
            remote = counts[expert_shard != gs].sum()
            total += int(remote)
        return total

    rr_expert = np.arange(experts) % k
    rr_group = np.arange(groups) % k
    base = cross(rr_expert, rr_group)
    opt = cross(placement.expert_to_shard, placement.group_to_shard)
    return {
        "crossing_tokens_roundrobin": base,
        "crossing_tokens_parsa": opt,
        "bytes_roundrobin": base * token_bytes,
        "bytes_parsa": opt * token_bytes,
        "reduction": 1.0 - opt / max(base, 1),
    }
