r"""Algorithm 4: parallel Parsa on a (simulated) parameter server (§4.3–4.5).

Roles:
  * scheduler — divides G into b subgraphs, issues (a, τ, init) then
    (b, τ, ¬init) rounds;
  * servers   — hold the shared neighbor sets S_i; pushes *replace* S during
    initialization and *union* afterwards (Alg 4 server lines 6–10);
  * workers   — pull S, partition their subgraph with Algorithm 3, push back
    only the delta S_i^new \ S_i (Alg 4 worker line 9, traffic saving).

Consistency: pushes are asynchronous with maximal delay τ (measured in
tasks).  We simulate W concurrent workers deterministically: the pull for
global task t observes every push from tasks finished before
``t - staleness(t)``, where staleness models the W−1 in-flight peers plus an
extra bounded delay drawn from [0, τ] (τ=None ⇒ eventual consistency: a
worker never waits, it sees whatever has landed — modeled as the in-flight
window only, pushes land immediately after their task).  §5.4's claim is
that quality degrades ≤ ~5% under this staleness; benchmarks/bench_fig10
reproduces the curve.

Wire format: the server state, every pending push, and the delta extraction
all live on *packed* uint32 bitmask words — the same (k, ceil(|V|/32))
layout the device pipelines carry (``kernels/parsa_cost``).  A worker pull
unpacks the packed view into a dense bool scratch (the worker's private
working set, handed to Algorithm 3 without another copy via
``copy_init=False``); nothing dense persists between tasks and the old
per-task ``S_server.copy()`` dense snapshot is gone.

This is the host-side runtime.  The TPU-native bulk-synchronous mapping of
the same protocol (bitmask all-reduce OR == server union) is the
``parallel_device`` backend (``jax_partition.parallel_blocked_partition_u_impl``).
"""
from __future__ import annotations

import dataclasses
import warnings

import numpy as np

from ..kernels.parsa_cost import (
    coerce_packed_sets,
    pack_bitmask,
    packed_delta,
    packed_union,
)
from .bipartite import BipartiteGraph
from .costs import need_matrix
from .partition_u import partition_u_impl
from .subgraphs import divide

__all__ = ["ParallelParsa", "ParsaReport", "global_initialization",
           "parallel_parsa_impl"]


@dataclasses.dataclass
class ParsaReport:
    """Traffic of the partitioning run itself, in *bitmask-word bytes*.

    Both directions use the packed wire format (4 bytes per 32 parameters):
    ``pulled_bytes`` counts the words covering each task's V support
    (server→worker), ``pushed_bytes`` the delta-encoded changed words
    (worker→server, Alg 4 worker line 9) — consistent units, directly
    comparable to each other and to the ``parallel_device`` counters.
    """

    parts_u: np.ndarray
    pushed_bytes: int          # worker→server traffic (delta-encoded words)
    pulled_bytes: int          # server→worker traffic (support words)
    tasks: int
    stale_pushes_missed: int   # how many pushes were invisible due to delay


def global_initialization(
    graph: BipartiteGraph,
    k: int,
    sample_frac: float = 0.01,
    theta: int = 1000,
    select: str = "size",
    seed: int = 0,
) -> np.ndarray:
    """§4.4 global initialization: one worker partitions a small sample and
    the resulting neighbor sets seed all workers."""
    rng = np.random.default_rng(seed)
    m = max(1, int(graph.num_u * sample_frac))
    sample = np.sort(rng.choice(graph.num_u, size=m, replace=False))
    sg = graph.subgraph_u(sample)
    res = partition_u_impl(sg, k, theta=theta, select=select, seed=seed)
    return need_matrix(sg, res.parts_u, k)


def parallel_parsa_impl(
    graph: BipartiteGraph,
    k: int,
    b: int,
    a: int = 0,
    workers: int = 4,
    tau: int | None = 0,
    theta: int = 1000,
    select: str = "size",
    seed: int = 0,
    init_sets: np.ndarray | None = None,
) -> tuple[ParsaReport, np.ndarray]:
    """Deterministic simulation of Alg 4 with W workers and max delay τ.

    Returns (report, final *packed* server neighbor sets (k, ceil(|V|/32))
    int32) — the same wire format the device backends produce, so sets warm-
    start either path through the facade.
    """
    W = workers
    num_v = graph.num_v
    W_words = (num_v + 31) // 32
    plan = divide(graph, b, seed=seed)
    rng = np.random.default_rng(seed + 1)

    # server state is packed words, end to end; no dense copy of it exists
    # .copy(): coerce returns already-packed input as-is (zero-copy view),
    # but the server merges pushes into S_server in place — never through
    # the caller's warm-start buffer (e.g. a PartitionResult's s_masks)
    S_server = (
        np.zeros((k, W_words), dtype=np.int32)
        if init_sets is None
        else coerce_packed_sets(init_sets, num_v).copy()
    )
    parts_u = np.full(graph.num_u, -1, dtype=np.int32)
    pushed_words = pulled_words = missed = 0

    # the worker's dense working set: ONE reusable (k, |V|) scratch for the
    # whole run.  A pull expands the packed words into it in place (shift +
    # mask with ``out=``), so tasks allocate no dense memory at all.
    unpack_buf = np.empty((k, W_words * 4, 8), dtype=np.uint8)
    scratch = unpack_buf.reshape(k, W_words * 32)[:, :num_v].view(np.bool_)
    bit_idx = np.arange(8, dtype=np.uint8)

    def pull() -> np.ndarray:
        """Expand the packed server words into the dense scratch, in place
        (little-endian bit/byte order — the exact inverse of
        ``pack_bitmask``)."""
        bytes_ = S_server.view(np.uint8).reshape(k, W_words * 4)
        np.right_shift(bytes_[:, :, None], bit_idx, out=unpack_buf)
        np.bitwise_and(unpack_buf, 1, out=unpack_buf)
        return scratch

    # pending pushes: list of (apply_at_task, replace?, packed_sets)
    pending: list[tuple[int, bool, np.ndarray]] = []

    def flush(now: int):
        still = []
        for at, replace, sets in pending:
            if at <= now:
                if replace:
                    S_server[:] = sets
                else:
                    S_server[:] = packed_union(S_server, sets)
            else:
                still.append((at, replace, sets))
        pending[:] = still

    schedule = [("init", t % b) for t in range(a)] + [("real", j) for j in range(b)]
    for t, (mode, j) in enumerate(schedule):
        flush(t)
        missed += len(pending)  # pushes in flight ⇒ invisible to this pull
        sg = plan.subgraphs[j]
        # pull: only the packed words covering this subgraph's V support
        pulled_words += k * np.unique(sg.u_indices >> 5).size
        # the worker's private working set: expand the packed server view
        # into the reusable dense scratch and hand it to Alg 3 *without*
        # another per-task dense snapshot (copy_init=False mutates it).
        res = partition_u_impl(
            sg, k, init_sets=pull(), theta=theta, select=select,
            seed=seed + t, copy_init=False,
        )
        delay = 1 if tau is None else 1 + int(rng.integers(0, tau + 1))
        if mode == "init":
            new_packed = pack_bitmask(need_matrix(sg, res.parts_u, k), num_v)
            pending.append((t + delay, True, new_packed))
        else:
            parts_u[plan.blocks[j]] = res.parts_u
            new_packed = pack_bitmask(res.neighbor_sets, num_v)
            # push only the change — S_server is untouched since the pull,
            # so the word delta vs the server equals the delta vs the pull
            pushed_words += int(np.count_nonzero(
                packed_delta(new_packed, S_server)))
            # model W concurrent workers: a push lands after the in-flight
            # window of W−1 peer tasks plus the bounded delay
            pending.append((t + (W - 1) + delay, False, new_packed))
    flush(len(schedule) + max(1, W) + (tau or 0) + 2)
    report = ParsaReport(parts_u, pushed_words * 4, pulled_words * 4,
                         len(schedule), missed)
    return report, S_server


class ParallelParsa:
    """Deterministic simulation of Alg 4 with W workers and max delay τ.

    Deprecated shim — use ``repro.api.partition`` with
    ``backend="parallel_sim"``; ``run`` delegates to the backend registry.
    ``parts_u`` is bit-identical to the pre-facade implementation; the
    traffic counters use the PR-3 packed-word units (see ``ParsaReport``)."""

    def __init__(
        self,
        k: int,
        workers: int = 4,
        tau: int | None = 0,
        theta: int = 1000,
        select: str = "size",
        seed: int = 0,
    ):
        self.k = k
        self.workers = workers
        self.tau = tau
        self.theta = theta
        self.select = select
        self.seed = seed

    def run(
        self,
        graph: BipartiteGraph,
        b: int,
        a: int = 0,
        init_sets: np.ndarray | None = None,
    ) -> ParsaReport:
        warnings.warn(
            "ParallelParsa.run is deprecated; use repro.api.partition(graph, "
            "ParsaConfig(k=..., backend='parallel_sim', blocks=b, "
            "init_iters=a, workers=..., tau=...))",
            DeprecationWarning, stacklevel=2)
        from ..api import ParsaConfig
        from ..api_backends import get_backend

        cfg = ParsaConfig(
            k=self.k, backend="parallel_sim", blocks=b, init_iters=a,
            workers=self.workers, tau=self.tau, theta=self.theta,
            select=self.select, seed=self.seed, refine_v=False)
        out = get_backend(cfg.backend)(graph, cfg, init_sets=init_sets)
        t = out.traffic
        return ParsaReport(out.parts_u, t.pushed_bytes, t.pulled_bytes,
                           t.tasks, t.stale_pushes_missed)
