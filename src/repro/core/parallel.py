r"""Algorithm 4: parallel Parsa on a (simulated) parameter server (§4.3–4.5).

Roles:
  * scheduler — divides G into b subgraphs, issues (a, τ, init) then
    (b, τ, ¬init) rounds;
  * servers   — hold the shared neighbor sets S_i; pushes *replace* S during
    initialization and *union* afterwards (Alg 4 server lines 6–10);
  * workers   — pull S, partition their subgraph with Algorithm 3, push back
    only the delta S_i^new \ S_i (Alg 4 worker line 9, traffic saving).

Consistency: pushes are asynchronous with maximal delay τ (measured in
tasks).  We simulate W concurrent workers deterministically: the pull for
global task t observes every push from tasks finished before
``t - staleness(t)``, where staleness models the W−1 in-flight peers plus an
extra bounded delay drawn from [0, τ] (τ=None ⇒ eventual consistency: a
worker never waits, it sees whatever has landed — modeled as the in-flight
window only, pushes land immediately after their task).  §5.4's claim is
that quality degrades ≤ ~5% under this staleness; benchmarks/bench_fig10
reproduces the curve.

This is the host-side runtime.  The TPU-native bulk-synchronous mapping of
the same protocol (bitmask all-reduce OR == server union) lives in
jax_partition.py.
"""
from __future__ import annotations

import dataclasses
import warnings

import numpy as np

from .bipartite import BipartiteGraph
from .costs import need_matrix
from .partition_u import partition_u_impl
from .subgraphs import divide

__all__ = ["ParallelParsa", "ParsaReport", "global_initialization",
           "parallel_parsa_impl"]


@dataclasses.dataclass
class ParsaReport:
    parts_u: np.ndarray
    pushed_bytes: int          # worker→server traffic (delta encoding)
    pulled_bytes: int          # server→worker traffic
    tasks: int
    stale_pushes_missed: int   # how many pushes were invisible due to delay


def global_initialization(
    graph: BipartiteGraph,
    k: int,
    sample_frac: float = 0.01,
    theta: int = 1000,
    select: str = "size",
    seed: int = 0,
) -> np.ndarray:
    """§4.4 global initialization: one worker partitions a small sample and
    the resulting neighbor sets seed all workers."""
    rng = np.random.default_rng(seed)
    m = max(1, int(graph.num_u * sample_frac))
    sample = np.sort(rng.choice(graph.num_u, size=m, replace=False))
    sg = graph.subgraph_u(sample)
    res = partition_u_impl(sg, k, theta=theta, select=select, seed=seed)
    return need_matrix(sg, res.parts_u, k)


def parallel_parsa_impl(
    graph: BipartiteGraph,
    k: int,
    b: int,
    a: int = 0,
    workers: int = 4,
    tau: int | None = 0,
    theta: int = 1000,
    select: str = "size",
    seed: int = 0,
    init_sets: np.ndarray | None = None,
) -> tuple[ParsaReport, np.ndarray]:
    """Deterministic simulation of Alg 4 with W workers and max delay τ.

    Returns (report, final server neighbor sets S (k, |V|) bool) — the sets
    support warm-start / incremental repartitioning through the facade.
    """
    W = workers
    plan = divide(graph, b, seed=seed)
    rng = np.random.default_rng(seed + 1)

    S_server = (
        np.zeros((k, graph.num_v), dtype=bool)
        if init_sets is None
        else np.asarray(init_sets, dtype=bool).copy()
    )
    parts_u = np.full(graph.num_u, -1, dtype=np.int32)
    pushed = pulled = missed = 0

    # pending pushes: list of (apply_at_task, replace?, delta_sets)
    pending: list[tuple[int, bool, np.ndarray]] = []

    def flush(now: int):
        nonlocal S_server
        still = []
        for at, replace, delta in pending:
            if at <= now:
                if replace:
                    S_server = delta.copy()
                else:
                    S_server |= delta
            else:
                still.append((at, replace, delta))
        pending[:] = still

    schedule = [("init", t % b) for t in range(a)] + [("real", j) for j in range(b)]
    for t, (mode, j) in enumerate(schedule):
        flush(t)
        missed += len(pending)  # pushes in flight ⇒ invisible to this pull
        sg = plan.subgraphs[j]
        # pull: only the slice of S touching this subgraph's V support
        support = np.unique(sg.u_indices)
        pulled += int(S_server[:, support].size // 8)  # bitmask bytes
        S_local = S_server.copy()
        res = partition_u_impl(
            sg, k, init_sets=S_local, theta=theta, select=select, seed=seed + t,
        )
        if mode == "init":
            new_sets = need_matrix(sg, res.parts_u, k)
            delay = 1 if tau is None else 1 + int(rng.integers(0, tau + 1))
            pending.append((t + delay, True, new_sets))
        else:
            parts_u[plan.blocks[j]] = res.parts_u
            delta = res.neighbor_sets & ~S_local  # push only the change
            pushed += int(delta.sum())  # set-delta entries (ids)
            delay = 1 if tau is None else 1 + int(rng.integers(0, tau + 1))
            # model W concurrent workers: a push lands after the in-flight
            # window of W−1 peer tasks plus the bounded delay
            pending.append((t + (W - 1) + delay, False, res.neighbor_sets))
    flush(len(schedule) + max(1, W) + (tau or 0) + 2)
    report = ParsaReport(parts_u, pushed * 4, pulled, len(schedule), missed)
    return report, S_server


class ParallelParsa:
    """Deterministic simulation of Alg 4 with W workers and max delay τ.

    Deprecated shim — use ``repro.api.partition`` with
    ``backend="parallel_sim"``; ``run`` delegates to the backend registry and
    returns a bit-identical ``ParsaReport``."""

    def __init__(
        self,
        k: int,
        workers: int = 4,
        tau: int | None = 0,
        theta: int = 1000,
        select: str = "size",
        seed: int = 0,
    ):
        self.k = k
        self.workers = workers
        self.tau = tau
        self.theta = theta
        self.select = select
        self.seed = seed

    def run(
        self,
        graph: BipartiteGraph,
        b: int,
        a: int = 0,
        init_sets: np.ndarray | None = None,
    ) -> ParsaReport:
        warnings.warn(
            "ParallelParsa.run is deprecated; use repro.api.partition(graph, "
            "ParsaConfig(k=..., backend='parallel_sim', blocks=b, "
            "init_iters=a, workers=..., tau=...))",
            DeprecationWarning, stacklevel=2)
        from ..api import ParsaConfig
        from ..api_backends import get_backend

        cfg = ParsaConfig(
            k=self.k, backend="parallel_sim", blocks=b, init_iters=a,
            workers=self.workers, tau=self.tau, theta=self.theta,
            select=self.select, seed=self.seed, refine_v=False)
        out = get_backend(cfg.backend)(graph, cfg, init_sets=init_sets)
        t = out.traffic
        return ParsaReport(out.parts_u, t.pushed_bytes, t.pulled_bytes,
                           t.tasks, t.stale_pushes_missed)
