"""Partitioning objectives (paper §2.4, eqs. (4), (6), (7)) + metrics.

Conventions: ``parts_u[i] ∈ [0,k)`` assigns example u_i to worker
``parts_u[i]``; ``parts_v[j] ∈ [0,k)`` (or -1 = unassigned/isolated) assigns
parameter v_j to server ``parts_v[j]``.  Machine m hosts worker m + server m
(§2.4, Fig 4).

``need_matrix`` / ``evaluate`` (including ``parts_v=None``) are the host
*parity oracles* for the packed-word device implementations
(``core.jax_refine.need_masks`` / ``evaluate_device``), which are pinned
bit-equal to them in ``tests/test_refine.py`` — the device path never
materializes this dense (k, |V|) bool matrix.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .bipartite import BipartiteGraph

__all__ = ["PartitionMetrics", "evaluate", "need_matrix", "random_parts", "improvement"]


@dataclasses.dataclass
class PartitionMetrics:
    k: int
    sizes: np.ndarray          # |U_i|                      — objective (4)
    footprint: np.ndarray      # |N(U_i)|                   — objective (6)
    traffic: np.ndarray        # per-machine traffic        — objective (7)
    worker_recv: np.ndarray    # |N(U_i) \ V_i|
    server_send: np.ndarray    # Σ_{j≠i} |V_i ∩ N(U_j)|

    @property
    def size_max(self) -> int:
        return int(self.sizes.max())

    @property
    def mem_max(self) -> int:
        return int(self.footprint.max())

    @property
    def traffic_max(self) -> int:
        return int(self.traffic.max())

    @property
    def traffic_sum(self) -> int:
        return int(self.traffic.sum())

    def as_dict(self) -> dict:
        return {
            "k": self.k,
            "size_max": self.size_max,
            "mem_max": self.mem_max,
            "traffic_max": self.traffic_max,
            "traffic_sum": self.traffic_sum,
        }


def need_matrix(graph: BipartiteGraph, parts_u: np.ndarray, k: int) -> np.ndarray:
    """(k, |V|) bool: need[i, j] == (v_j ∈ N(U_i))  — the u_ij of eq. (8)."""
    need = np.zeros((k, graph.num_v), dtype=bool)
    edge_part = np.repeat(parts_u.astype(np.int64), np.diff(graph.u_indptr))
    need[edge_part, graph.u_indices] = True
    return need


def evaluate(
    graph: BipartiteGraph,
    parts_u: np.ndarray,
    parts_v: np.ndarray | None,
    k: int,
) -> PartitionMetrics:
    """Compute objectives (4), (6), (7) exactly.

    With ``parts_v=None`` we report the V-independent terms only (traffic
    defaults to the worker working-set size — i.e. all pulls remote, the
    random-server upper bound used by Figure 1).
    """
    parts_u = np.asarray(parts_u)
    sizes = np.bincount(parts_u, minlength=k).astype(np.int64)
    need = need_matrix(graph, parts_u, k)
    footprint = need.sum(axis=1).astype(np.int64)
    if parts_v is None:
        worker = footprint.copy()
        server = np.zeros(k, dtype=np.int64)
        return PartitionMetrics(k, sizes, footprint, worker + server, worker, server)
    parts_v = np.asarray(parts_v)
    # worker i pulls parameters it needs but does not host: |N(U_i) \ V_i|
    worker = np.zeros(k, dtype=np.int64)
    # server i answers requests from other workers: Σ_{j≠i} |V_i ∩ N(U_j)|
    server = np.zeros(k, dtype=np.int64)
    nneed = need.sum(axis=0).astype(np.int64)  # how many partitions need v_j
    for i in range(k):
        mine = parts_v == i
        local_hits = need[i] & mine
        worker[i] = footprint[i] - int(local_hits.sum())
        server[i] = int((nneed[mine] - need[i][mine].astype(np.int64)).sum())
    return PartitionMetrics(k, sizes, footprint, worker + server, worker, server)


def random_parts(n: int, k: int, seed: int = 0) -> np.ndarray:
    """Balanced random assignment — the paper's baseline."""
    rng = np.random.default_rng(seed)
    parts = np.arange(n, dtype=np.int32) % k
    rng.shuffle(parts)
    return parts


def improvement(random_val: float, proposed_val: float) -> float:
    """Paper §5.1: (random - proposed) / proposed × 100%."""
    if proposed_val == 0:
        return float("inf")
    return (random_val - proposed_val) / proposed_val * 100.0
