"""Algorithm 2: partition V for given {U_i} (paper §3.2).

Greedy single sweep over the totally-unimodular convex integer program (8):
for each parameter v_j, assign it to the needing partition with the current
minimum cost; the cost update is

    cost_ξ ← cost_ξ − 1 + Σ_{i≠ξ} u_ij            (Alg 2 line 8)

(hosting j locally saves one pull for ξ, but ξ's server now answers every
other needing partition).  Repeated sweeps re-assign one variable at a time
and, by convexity + total unimodularity, converge to a global optimum in a
finite number of sweeps (§3.2).

This numpy loop is the *parity oracle*: the device-resident implementation
(``core.jax_refine.refine_v_device`` — the ``refine_backend="device"``
facade path, one jitted chunked scan over the packed need words) is pinned
bit-identical to it for every sweep count in ``tests/test_refine.py``,
including the isolated-parameter −1 convention and the early convergence
break (a converged sweep is a fixed point, so the device path simply runs
all sweeps).
"""
from __future__ import annotations

import numpy as np

from .bipartite import BipartiteGraph
from .costs import need_matrix

__all__ = ["partition_v"]


def partition_v(
    graph: BipartiteGraph,
    parts_u: np.ndarray,
    k: int,
    sweeps: int = 1,
    need: np.ndarray | None = None,
) -> np.ndarray:
    """Return parts_v (|V|,) int32; -1 for isolated parameters (never needed)."""
    if need is None:
        need = need_matrix(graph, parts_u, k)  # (k, |V|) bool == u_ij
    num_v = graph.num_v
    nneed = need.sum(axis=0).astype(np.int64)  # Σ_i u_ij per parameter

    parts_v = np.full(num_v, -1, dtype=np.int32)
    # lines 1–4: cost_i ← |N(U_i)|
    cost = need.sum(axis=1).astype(np.int64)

    order = np.arange(num_v)
    for sweep in range(sweeps):
        changed = 0
        for j in order:
            nj = int(nneed[j])
            if nj == 0:
                continue  # isolated parameter: no server ever needs it
            cur = int(parts_v[j])
            if cur >= 0:
                # retract j's contribution before re-assigning (sweep ≥ 2)
                cost[cur] -= -1 + (nj - int(need[cur, j]))
            needers = np.flatnonzero(need[:, j])
            xi = int(needers[np.argmin(cost[needers])])
            parts_v[j] = xi
            # line 8: cost_ξ ← cost_ξ − 1 + Σ_{i≠ξ} u_ij
            cost[xi] += -1 + (nj - 1)
            changed += int(xi != cur)
        if sweep > 0 and changed == 0:
            break
    return parts_v
