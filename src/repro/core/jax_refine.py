r"""Device-resident Algorithm 2 + packed-bitmask metrics (paper §3.2, §2.4).

PRs 1 and 3 made ``partition_u`` device-resident; this module does the same
for the remaining phases of the one-call pipeline, all over the packed
uint32 wire format those PRs standardized:

  * ``need_masks``     — the u_ij matrix of eq. (8) as packed (k, W) words,
    built on device straight from ``parts_u`` + the CSR edge array in one
    sorted segment-OR pass.  No dense (k, |V|) bool array ever exists.
  * ``refine_v_device``— Algorithm 2's greedy sweep over V as ONE jitted
    ``lax.scan`` over chunks of C parameters with donated (cost, parts_v)
    carries.  Within a chunk the PR 1 rounds trick applies: parameter picks
    whose reads see no earlier in-chunk cost write commute, so a chunk whose
    prefix write-sets stay clear of every later parameter's needer set
    commits in one vectorized pass (the common case once the sweep has
    converged); any interference trips a sequential in-chunk ``lax.scan``
    that replays the host oracle step-for-step — bit-identical either way
    (property-tested against ``core.partition_v``).  ``use_kernel=True``
    swaps the chunk body for the fused cost-update Pallas kernel
    (``kernels/parsa_cost/select.py:refine_sweep_kernel``), which runs the
    whole chunk sweep inside VMEM.
  * ``evaluate_device``— objectives (4)/(6)/(7) as ``population_count``
    reductions over packed words: footprint = popcount(need_i), the
    worker/server overlap terms via the (k, k) packed intersection matrix
    M[i, j] = |V_i ∩ N(U_j)|.  Exact — bit-equal to ``core.costs.evaluate``.

Cost-update algebra mirrored from the host oracle (Alg 2 line 8):

    assign  j → ξ : cost_ξ  += −1 + (n_j − 1)            (n_j = Σ_i u_ij)
    retract j from cur (sweep ≥ 2): cost_cur −= −1 + (n_j − u_{cur,j})

A converged sweep retracts and re-adds the same amount at the same index,
so the chunk-prefix write vector stays zero and the vectorized fast path
commits — extra sweeps after convergence are free of the sequential tail,
matching the host loop's early ``break`` bit-for-bit.

Dispatch model: one ``need_pack`` launch (sort + scatter), one
``refine_scan`` launch for ALL sweeps × chunks, one ``metrics`` launch —
O(1) per phase, observed by ``jax_partition.dispatch_counter``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels.parsa_cost import BIG, refine_sweep_chunk, refine_sweep_ref
from .bipartite import BipartiteGraph
from .costs import PartitionMetrics
from .jax_partition import _count_dispatch

__all__ = ["need_masks", "refine_v_device", "evaluate_device"]

# Largest k²·W int32 transient (words) the metrics intersection matrix may
# materialize in one broadcast; larger problems reduce row-by-row instead.
_M_BCAST_MAX_WORDS = 1 << 26  # 256 MB


# --------------------------------------------------------------------------
# need_matrix as packed words, on device.
# --------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("k", "num_v", "W"))
def _need_masks_scatter(
    parts_u: jax.Array,    # (|U|,) int32
    edge_rows: jax.Array,  # (E,) int32/int64 — source row of each edge
    cols: jax.Array,       # (E,) int32 — V column of each edge
    *,
    k: int,
    num_v: int,
    W: int,
) -> jax.Array:
    """One segment-OR pass: sort the (partition, column) keys, keep each
    distinct key's first occurrence, scatter-add its bit.  Distinct keys in
    the same word carry distinct bits, so add ≡ OR; duplicate keys add 0.

    Keys are ``partition · num_v + column`` — int32 unless x64 is enabled,
    so k · num_v must stay below 2³¹ (e.g. |V| ≤ 33M at k = 64); flip
    ``jax_enable_x64`` for the regime beyond that.
    """
    kd = jnp.int64 if jax.config.jax_enable_x64 else jnp.int32
    edge_part = parts_u[edge_rows].astype(kd)
    key = edge_part * num_v + cols.astype(kd)
    key = jnp.sort(key)
    first = jnp.concatenate(
        [jnp.ones((1,), bool), key[1:] != key[:-1]])
    part = (key // num_v).astype(jnp.int32)
    col = (key % num_v).astype(jnp.int32)
    bit = jnp.left_shift(jnp.int32(1), col & 31)
    flat = part * W + (col >> 5)
    words = jnp.zeros((k * W,), jnp.int32).at[flat].add(
        jnp.where(first, bit, 0))
    return words.reshape(k, W)


def need_masks(
    graph: BipartiteGraph,
    parts_u: np.ndarray | jax.Array,
    k: int,
) -> jax.Array:
    """(k, W) int32 packed need matrix: bit j of row i ⇔ v_j ∈ N(U_i).

    Device analogue of ``core.costs.need_matrix`` — same bits, packed
    little-endian per 32-column word (``pack_bitmask`` layout), computed
    without materializing the dense bool matrix.  Accepts ``parts_u`` as a
    device array (no host round trip for device backends).
    """
    W = (graph.num_v + 31) // 32
    # the scatter's sort key is partition·num_v + column; its maximum is
    # k·num_v − 1 and silently wraps past int32, corrupting the need
    # matrix — refuse loudly instead (ROADMAP known limit, now checked)
    if k * graph.num_v > 2**31 and not jax.config.jax_enable_x64:
        raise ValueError(
            f"need-pack sort key range k*num_v = {k}*{graph.num_v} = "
            f"{k * graph.num_v} exceeds int32 (max key k*num_v-1 must be "
            f"< 2^31); enable jax_enable_x64 for this regime")
    if graph.num_edges == 0:
        return jnp.zeros((k, W), jnp.int32)
    edge_rows = np.repeat(
        np.arange(graph.num_u, dtype=np.int64), np.diff(graph.u_indptr))
    _count_dispatch("need_pack")
    return _need_masks_scatter(
        jnp.asarray(parts_u, dtype=jnp.int32), jnp.asarray(edge_rows),
        jnp.asarray(graph.u_indices, dtype=jnp.int32),
        k=k, num_v=graph.num_v, W=W)


# --------------------------------------------------------------------------
# Algorithm 2 as one jitted chunked scan.
# --------------------------------------------------------------------------
def _chunk_sweep_jnp(
    tile_words: jax.Array,  # (k, cw) int32 packed need bits of this chunk
    tile: jax.Array,    # (k, C) int32 0/1 — the same bits, expanded
    nneed: jax.Array,   # (C,) int32 — Σ_i u_ij per in-chunk parameter
    prev: jax.Array,    # (C,) int32 — parameter assignments entering the sweep
    cost: jax.Array,    # (k,) int32 — carried Alg 2 cost vector
    *,
    k: int,
) -> tuple[jax.Array, jax.Array]:
    """One chunk of the greedy sweep.  Returns (cost', parts_chunk).

    Fast path: pretend every in-chunk parameter reads the chunk-entry cost
    snapshot (own retraction applied), pick all C argmins in one pass, and
    commit iff no parameter's needer set intersects the *prefix* of earlier
    picks'/retractions' cost writes — then the snapshot picks ARE the
    sequential picks.  A converged sweep writes net zero everywhere, so its
    chunks all commit vectorized.  Any interference falls back to the exact
    per-parameter oracle (``refine_sweep_ref`` — the same program the
    Pallas kernel is pinned to, so the Alg 2 step algebra lives in one
    place).
    """
    C = tile.shape[1]
    iota_c = jnp.arange(C, dtype=jnp.int32)
    needers = tile.T.astype(bool)                      # (C, k)
    active = nneed > 0
    cur_safe = jnp.where(prev >= 0, prev, 0)
    bit_cur = tile[cur_safe, iota_c]                   # u_{cur,j}
    # retraction delta applied at prev[j] (0 when unassigned)
    retract = jnp.where(prev >= 0, 1 - nneed + bit_cur, 0)   # (C,)
    onehot_cur = (jnp.arange(k, dtype=jnp.int32)[None, :] == prev[:, None])
    # snapshot costs with each row's own retraction folded in
    adj = cost[None, :] + jnp.where(onehot_cur, retract[:, None], 0)
    masked = jnp.where(needers, adj, BIG)              # (C, k)
    xi0 = jnp.argmin(masked, axis=1).astype(jnp.int32)
    xi_safe = jnp.where(active, xi0, 0)
    assign = jnp.where(active, nneed - 2, 0)           # −1 + (n_j − 1)
    # per-parameter write vectors and their exclusive prefix sums
    w = jnp.zeros((C, k), jnp.int32)
    w = w.at[iota_c, cur_safe].add(retract)
    w = w.at[iota_c, xi_safe].add(assign)
    prefix = jnp.cumsum(w, axis=0) - w                 # exclusive
    clean = ~((prefix != 0) & needers).any()

    def fast(_):
        return cost + w.sum(axis=0), jnp.where(active, xi0, -1)

    def slow(_):
        return refine_sweep_ref(tile_words, prev, cost)

    return jax.lax.cond(clean, fast, slow, None)


@functools.partial(
    jax.jit,
    static_argnames=("k", "sweeps", "cw", "use_kernel", "interpret"),
    donate_argnums=(1, 2),
)
def _refine_scan(
    need_pad: jax.Array,  # (k, Wp) int32, Wp % cw == 0
    cost: jax.Array,      # (k,) int32 — donated; |N(U_i)| at entry
    parts: jax.Array,     # (n_chunks, C) int32 — donated; -1 at entry
    *,
    k: int,
    sweeps: int,
    cw: int,
    use_kernel: bool,
    interpret: bool | None,
) -> tuple[jax.Array, jax.Array]:
    """All Alg 2 sweeps as one dispatch: scan chunks, carry (cost, parts).
    Returns (cost, parts (n_chunks, C)) aliasing the donated inputs."""
    Wp = need_pad.shape[1]
    n_chunks = Wp // cw
    words = need_pad.reshape(k, n_chunks, cw).transpose(1, 0, 2)
    shifts = jnp.arange(32, dtype=jnp.int32)
    C = cw * 32

    def per_chunk(cost, xs):
        tile_words, prev = xs                          # (k, cw), (C,)
        if use_kernel:
            return refine_sweep_chunk(tile_words, prev, cost,
                                      interpret=interpret)
        tile = ((tile_words[:, :, None] >> shifts) & 1).reshape(k, C)
        nneed = tile.sum(axis=0, dtype=jnp.int32)
        return _chunk_sweep_jnp(tile_words, tile, nneed, prev, cost, k=k)

    for _ in range(sweeps):
        cost, parts = jax.lax.scan(per_chunk, cost, (words, parts))
    return cost, parts


def refine_v_device(
    graph: BipartiteGraph,
    parts_u: np.ndarray | jax.Array,
    k: int,
    sweeps: int = 1,
    chunk: int = 1024,
    use_kernel: bool = False,
    interpret: bool | None = None,
    need_words: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Device-resident Algorithm 2.  Returns (parts_v (|V|,) int32 device
    array, need_words (k, W) int32) — the latter so ``evaluate_device``
    reuses the packed need matrix instead of recomputing it.

    Bit-identical to ``core.partition_v(graph, parts_u, k, sweeps)`` for any
    sweep count (the host loop's early convergence break is a fixed point of
    the device sweep, so running all ``sweeps`` is exact), including the
    isolated-parameter −1 convention.  The whole refinement — every sweep,
    every chunk — is ONE XLA dispatch after the need pack.

    Range limit: costs are carried as int32 and masked with ``BIG`` = 2³⁰
    (the host oracle uses int64), so every true cost — bounded by
    |N(U_i)| + Σ_j (n_j − 2) ≤ nnz(need) ≤ k·|V| — must stay below 2³⁰;
    beyond that (the extreme end of the 10⁸-parameter regime at high k) a
    capped needer could tie with masked non-needers and diverge from the
    oracle.  Widen the carry to int64 (x64 mode) before trusting parity
    there.
    """
    if chunk <= 0 or chunk % 32:
        raise ValueError(f"chunk must be a positive multiple of 32, got {chunk}")
    if need_words is None:
        need_words = need_masks(graph, parts_u, k)
    W = (graph.num_v + 31) // 32
    cw = chunk // 32
    Wp = -(-W // cw) * cw
    need_pad = jnp.pad(need_words, [(0, 0), (0, Wp - W)])
    n_chunks = Wp // cw
    cost0 = jax.lax.population_count(need_words).astype(jnp.int32).sum(axis=1)
    parts0 = jnp.full((n_chunks, chunk), -1, jnp.int32)
    _count_dispatch("refine_scan")
    _, parts_v = _refine_scan(need_pad, cost0, parts0, k=k, sweeps=sweeps,
                              cw=cw, use_kernel=use_kernel, interpret=interpret)
    return parts_v.reshape(-1)[: graph.num_v], need_words


# --------------------------------------------------------------------------
# Objectives (4)/(6)/(7) as popcount reductions over packed words.
# --------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("k", "num_v", "W", "have_pv"))
def _metrics_popcount(
    need_w: jax.Array,   # (k, W) int32
    parts_u: jax.Array,  # (|U|,) int32
    parts_v: jax.Array,  # (|V|,) int32 (ignored when have_pv=False)
    *,
    k: int,
    num_v: int,
    W: int,
    have_pv: bool,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    sizes = jnp.zeros((k,), jnp.int32).at[parts_u].add(1)
    pc = jax.lax.population_count(need_w).astype(jnp.int32)
    footprint = pc.sum(axis=1)
    if not have_pv:
        return sizes, footprint, footprint, jnp.zeros((k,), jnp.int32)
    # pack parts_v → (k, W) server-ownership words (row k catches the -1s)
    iota_v = jnp.arange(num_v, dtype=jnp.int32)
    row = jnp.where(parts_v >= 0, parts_v, k)
    bit = jnp.left_shift(jnp.int32(1), iota_v & 31)
    v_words = jnp.zeros((k + 1, W), jnp.int32).at[row, iota_v >> 5].add(bit)[:k]
    # M[i, j] = |V_i ∩ N(U_j)| — the only V/U overlap term the objectives
    # need.  The one-shot (k, k, W) broadcast is fastest but k× larger than
    # the dense need matrix this module exists to avoid, so past a 256 MB
    # transient (k²·W words, static at trace time) fall back to row-by-row
    # — one (k, W) temp per server.
    if k * k * W <= _M_BCAST_MAX_WORDS:
        M = jax.lax.population_count(
            v_words[:, None, :] & need_w[None, :, :]).astype(jnp.int32).sum(-1)
    else:
        M = jax.lax.map(
            lambda vw: jax.lax.population_count(
                vw[None, :] & need_w).astype(jnp.int32).sum(-1),
            v_words)
    local = jnp.diagonal(M)                 # |V_i ∩ N(U_i)|
    worker = footprint - local              # |N(U_i) \ V_i|
    server = M.sum(axis=1) - local          # Σ_{j≠i} |V_i ∩ N(U_j)|
    return sizes, footprint, worker, server


def evaluate_device(
    graph: BipartiteGraph,
    parts_u: np.ndarray | jax.Array,
    parts_v: np.ndarray | jax.Array | None,
    k: int,
    need_words: jax.Array | None = None,
) -> PartitionMetrics:
    """Objectives (4)/(6)/(7), bit-equal to ``core.costs.evaluate``, from
    packed words only.  Pass ``need_words`` (e.g. from ``refine_v_device``)
    to skip recomputing the need pack; metrics themselves are one dispatch.
    """
    if need_words is None:
        need_words = need_masks(graph, parts_u, k)
    W = (graph.num_v + 31) // 32
    _count_dispatch("metrics")
    have_pv = parts_v is not None
    pv = (jnp.asarray(parts_v, dtype=jnp.int32) if have_pv
          else jnp.zeros((graph.num_v,), jnp.int32))
    sizes, footprint, worker, server = _metrics_popcount(
        need_words, jnp.asarray(parts_u, dtype=jnp.int32), pv,
        k=k, num_v=graph.num_v, W=W, have_pv=have_pv)
    sizes, footprint, worker, server = (
        np.asarray(x).astype(np.int64) for x in (sizes, footprint, worker,
                                                 server))
    return PartitionMetrics(k, sizes, footprint, worker + server,
                            worker, server)
