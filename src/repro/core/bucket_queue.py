"""The §4.1 vertex-selection structure: cost array + doubly-linked bucket list.

Per partition i, Algorithm 3 needs, over a universe of |U| vertices whose
integer costs only *decrease*:

  * extract-min            O(1) amortized
  * decrease-key (by d)    O(1) amortized
  * delete (assigned u)    O(1)

The paper stores costs in an array ``A_i`` and imposes a doubly-linked list
in increasing cost order, with "head pointers" into the first node of each
cost bucket 0..θ.  An equivalent-but-simpler formulation of the same idea is
a *bucket queue*: one doubly-linked list per cost value, plus a moving
``min_cost`` cursor.  Since costs only decrease, the cursor only needs to
move down on decrease-key and scan up on extract-min; total scan work is
bounded by (#ops + max_cost), giving the same O(1) amortized bounds the
paper claims.  Costs above ``theta`` share an overflow bucket (the paper's
θ=1000 covers >99% of vertices; overflow extract is rare).

Implemented on flat numpy arrays (prev/next/bucket-head) — no Python objects
per node — so a full Algorithm 3 run is practical from CPython.
"""
from __future__ import annotations

import numpy as np

__all__ = ["BucketQueue"]

_NIL = -1


class BucketQueue:
    """Monotone (decrease-only) integer-priority bucket queue over ids [0, n)."""

    def __init__(self, costs: np.ndarray, theta: int = 1000):
        costs = np.asarray(costs)
        n = costs.shape[0]
        self.n = n
        self.theta = int(theta)
        # cost value per id; -1 == deleted
        self.cost = costs.astype(np.int64).copy()
        if n and self.cost.min() < 0:
            raise ValueError("costs must be non-negative")
        self.nbuckets = self.theta + 2  # [0..theta] exact + overflow bucket
        self.head = np.full(self.nbuckets, _NIL, dtype=np.int64)
        self.prev = np.full(n, _NIL, dtype=np.int64)
        self.next = np.full(n, _NIL, dtype=np.int64)
        self.in_queue = np.ones(n, dtype=bool)
        self.size = n
        # bulk build: counting-sort style bucket fill (paper: counting sort O(|U|))
        for i in range(n - 1, -1, -1):  # reverse so lists come out id-ascending
            self._push(i, self._bucket(int(self.cost[i])))
        self.min_bucket = 0

    # ------------------------------------------------------------ internals
    def _bucket(self, c: int) -> int:
        return c if c <= self.theta else self.theta + 1

    def _push(self, i: int, b: int) -> None:
        h = self.head[b]
        self.prev[i] = _NIL
        self.next[i] = h
        if h != _NIL:
            self.prev[h] = i
        self.head[b] = i

    def _unlink(self, i: int) -> None:
        p, nx = self.prev[i], self.next[i]
        if p != _NIL:
            self.next[p] = nx
        else:  # head of its bucket
            self.head[self._bucket(int(self.cost[i]))] = nx
        if nx != _NIL:
            self.prev[nx] = p
        self.prev[i] = _NIL
        self.next[i] = _NIL

    # ------------------------------------------------------------ public api
    def peek_min(self) -> tuple[int, int]:
        """Return (id, cost) of the minimum-cost live entry. O(1) amortized."""
        if self.size == 0:
            raise IndexError("empty bucket queue")
        b = self.min_bucket
        while self.head[b] == _NIL:
            b += 1
        self.min_bucket = b
        i = int(self.head[b])
        if b == self.theta + 1:  # overflow bucket: linear scan (rare)
            j, best, best_c = i, i, int(self.cost[i])
            while j != _NIL:
                if self.cost[j] < best_c:
                    best, best_c = j, int(self.cost[j])
                j = int(self.next[j])
            return best, best_c
        return i, int(self.cost[i])

    def pop_min(self) -> tuple[int, int]:
        i, c = self.peek_min()
        self.delete(i)
        return i, c

    def delete(self, i: int) -> None:
        if not self.in_queue[i]:
            return
        self._unlink(i)
        self.in_queue[i] = False
        self.size -= 1

    def decrease(self, i: int, new_cost: int) -> None:
        """Decrease-key. Costs never increase in Algorithm 3 (§4.1)."""
        if not self.in_queue[i]:
            return
        old = int(self.cost[i])
        if new_cost >= old:
            return
        if new_cost < 0:
            raise ValueError("negative cost")
        ob, nb = self._bucket(old), self._bucket(new_cost)
        if ob != nb:
            self._unlink(i)
            self.cost[i] = new_cost
            self._push(i, nb)
        else:
            self.cost[i] = new_cost
        if nb < self.min_bucket:
            self.min_bucket = nb

    def __len__(self) -> int:
        return self.size
