r"""Algorithm 3: partition U efficiently, O(k|E|) (paper §4.1).

Faithful sequential reference.  Per partition i we maintain

  * ``S_i``   — the (global-V-id) neighbor set, a bool bitmap,
  * ``A_i``   — vertex costs  cost_i(u) = |N(u) \ S_i|  in a monotone
                bucket queue (the paper's array + doubly-linked list with
                head pointers; see bucket_queue.py).

Loop (Alg 3 lines 5–15): pick a partition, pop its lowest-cost vertex,
assign, fold N(u*) into S_i, and decrement the cost of every still-
unassigned U-neighbor of each *newly covered* v — each (edge, partition)
pair is touched at most once ⇒ O(k|E|).

``select`` chooses the partition per step:
  * ``"size"``      — argmin |U_i| (Alg 1 line 7; §4.1's "assign one vertex
                      at a time to the smallest partition ⇒ perfect
                      balancing").  Default.
  * ``"footprint"`` — argmin |S_i| (Alg 3 line 6 as printed; balances the
                      memory objective (6) instead).
"""
from __future__ import annotations

import warnings

import numpy as np

from .bipartite import BipartiteGraph
from .bucket_queue import BucketQueue

__all__ = ["partition_u", "partition_u_impl", "PartitionUResult"]


class PartitionUResult:
    def __init__(self, parts_u: np.ndarray, neighbor_sets: np.ndarray):
        self.parts_u = parts_u          # (|U|,) int32
        self.neighbor_sets = neighbor_sets  # (k, |V|) bool — updated S_i


def partition_u(
    graph: BipartiteGraph,
    k: int,
    init_sets: np.ndarray | None = None,
    theta: int = 1000,
    select: str = "size",
    seed: int = 0,
) -> PartitionUResult:
    """Deprecated shim — use ``repro.api.partition`` with ``backend="host"``.

    Delegates to the backend registry; output is bit-identical to the
    pre-facade implementation (``partition_u_impl``)."""
    warnings.warn(
        "repro.core.partition_u is deprecated; use repro.api.partition("
        "graph, ParsaConfig(k=..., backend='host'))",
        DeprecationWarning, stacklevel=2)
    from ..api import ParsaConfig
    from ..api_backends import get_backend

    cfg = ParsaConfig(k=k, backend="host", theta=theta, select=select,
                      seed=seed, refine_v=False)
    out = get_backend(cfg.backend)(graph, cfg, init_sets=init_sets)
    return PartitionUResult(out.parts_u, out.neighbor_sets)


def partition_u_impl(
    graph: BipartiteGraph,
    k: int,
    init_sets: np.ndarray | None = None,
    theta: int = 1000,
    select: str = "size",
    seed: int = 0,
    copy_init: bool = True,
) -> PartitionUResult:
    """Run Algorithm 3 on ``graph`` with optional initial neighbor sets S_i.

    ``copy_init=False`` adopts ``init_sets`` as the working S and mutates it
    in place — callers that already materialized a private dense scratch
    (e.g. the Alg 4 worker pull in ``parallel.py``) skip the per-call
    (k, |V|) copy.  ``init_sets`` may also arrive packed ((k, W) int32
    words, e.g. ``PartitionResult.s_masks``); it is unpacked into a fresh
    scratch either way.
    """
    num_u, num_v = graph.num_u, graph.num_v
    if init_sets is not None and not (
            isinstance(init_sets, np.ndarray) and init_sets.dtype == np.bool_
            and init_sets.shape == (k, num_v)):
        from ..kernels.parsa_cost import coerce_dense_sets

        init_sets = coerce_dense_sets(init_sets, num_v)
    if init_sets is None:
        S = np.zeros((k, num_v), dtype=bool)
    elif copy_init:
        S = np.asarray(init_sets, dtype=bool).copy()
        assert S.shape == (k, num_v)
    else:
        S = init_sets
        assert S.dtype == bool and S.shape == (k, num_v) and S.flags.writeable

    # line 3: A_i(u) = |N(u) \ S_i| for all u — vectorized per partition.
    indptr, indices = graph.u_indptr, graph.u_indices
    deg = np.diff(indptr).astype(np.int64)
    row_of_edge = np.repeat(np.arange(num_u), deg)
    queues: list[BucketQueue] = []
    for i in range(k):
        covered = np.bincount(
            row_of_edge, weights=S[i][indices].astype(np.float64),
            minlength=num_u).astype(np.int64) if graph.num_edges else \
            np.zeros(num_u, dtype=np.int64)
        queues.append(BucketQueue(deg - covered, theta=theta))

    parts_u = np.full(num_u, -1, dtype=np.int32)
    sizes = np.zeros(k, dtype=np.int64)
    ssize = S.sum(axis=1).astype(np.int64)
    rng = np.random.default_rng(seed)
    order_noise = rng.random(k) * 1e-9  # deterministic tie-break jitter

    v_indptr, v_indices = graph.v_indptr, graph.v_indices

    for _ in range(num_u):
        # line 6: pick the partition to grow
        crit = sizes if select == "size" else ssize
        i = int(np.argmin(crit + order_noise))
        # line 7: lowest-cost vertex for partition i
        u_star, _ = queues[i].pop_min()
        # lines 8–10: assign, remove from all queues
        parts_u[u_star] = i
        sizes[i] += 1
        for j in range(k):
            if j != i:
                queues[j].delete(u_star)
        # lines 11–14: fold new coverage into S_i, decrement affected costs
        nbrs = indices[indptr[u_star] : indptr[u_star + 1]]
        new_vs = nbrs[~S[i][nbrs]]
        if new_vs.size:
            S[i][new_vs] = True
            ssize[i] += new_vs.size
            q = queues[i]
            cost, in_q = q.cost, q.in_queue
            for v in new_vs:
                for u in v_indices[v_indptr[v] : v_indptr[v + 1]]:
                    if in_q[u]:
                        q.decrease(int(u), int(cost[u]) - 1)
    return PartitionUResult(parts_u, S)
