"""Parsa core: the paper's primary contribution (Algorithms 1–4)."""
from .bipartite import BipartiteGraph, from_edges, load_npz  # noqa: F401
from .bucket_queue import BucketQueue  # noqa: F401
from .costs import PartitionMetrics, evaluate, improvement, need_matrix, random_parts  # noqa: F401
from .partition_u import partition_u  # noqa: F401
from .partition_v import partition_v  # noqa: F401
from .subgraphs import divide, sequential_parsa  # noqa: F401
from .parallel import ParallelParsa, global_initialization  # noqa: F401
