"""Parsa-driven vocabulary/embedding placement for the LM stack (DESIGN §3.1).

The (document × token-id) incidence graph is exactly the paper's Fig. 2
bipartite graph: U = documents, V = vocabulary rows.  Parsa's U-partition
assigns documents to data shards, its V-partition assigns embedding rows to
model shards.  We expose the result as a ``Placement``:

  * ``doc_to_shard``   — feeds data/pipeline.py (which documents each data
    shard reads);
  * ``vocab_perm``     — a permutation of vocab ids such that rows owned by
    shard s occupy the contiguous slice s; the embedding table sharded over
    the ``model`` axis then holds each shard's *hot* vocabulary locally;
  * traffic accounting — exact remote-row counts per step, the quantity
    Table 4 measures (we reproduce it for embedding gathers).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .bipartite import BipartiteGraph
from .costs import evaluate, need_matrix

__all__ = ["Placement", "build_placement", "placement_from_parts",
           "gather_traffic"]


@dataclasses.dataclass
class Placement:
    k: int
    doc_to_shard: np.ndarray      # (num_docs,) int32
    vocab_to_shard: np.ndarray    # (vocab,) int32  (-1 = never used → round-robin)
    vocab_perm: np.ndarray        # (vocab,) new position of each vocab id
    vocab_unperm: np.ndarray      # inverse permutation
    shard_row_counts: np.ndarray  # (k,) rows per shard after permutation

    def permute_ids(self, token_ids: np.ndarray) -> np.ndarray:
        return self.vocab_perm[token_ids]


def placement_from_parts(
    parts_u: np.ndarray,
    parts_v: np.ndarray,
    num_v: int,
    k: int,
) -> Placement:
    """Derive the embedding layout from finished (parts_u, parts_v)."""
    # unused vocab rows: spread round-robin over the least-loaded shards
    parts_v = np.asarray(parts_v).copy()
    unused = np.flatnonzero(parts_v < 0)
    if unused.size:
        counts = np.bincount(parts_v[parts_v >= 0], minlength=k)
        fill = np.argsort(counts, kind="stable")
        parts_v[unused] = fill[np.arange(unused.size) % k]
    # build the contiguous permutation: rows of shard 0 first, etc.
    order = np.argsort(parts_v, kind="stable")
    vocab_perm = np.empty(num_v, dtype=np.int64)
    vocab_perm[order] = np.arange(num_v)
    counts = np.bincount(parts_v, minlength=k).astype(np.int64)
    return Placement(
        k=k,
        doc_to_shard=np.asarray(parts_u).astype(np.int32),
        vocab_to_shard=parts_v.astype(np.int32),
        vocab_perm=vocab_perm,
        vocab_unperm=order,
        shard_row_counts=counts,
    )


def build_placement(
    graph: BipartiteGraph,
    k: int,
    b: int = 8,
    a: int = 4,
    sweeps: int = 2,
    seed: int = 0,
    method: str = "parsa",
    backend: str = "host",
) -> Placement:
    """Partition the doc×vocab graph and derive the embedding layout.

    ``method="parsa"`` runs the whole pipeline through
    ``repro.api.partition`` on the chosen ``backend``."""
    if method == "parsa":
        from ..api import ParsaConfig, partition  # lazy: placement ↔ api

        cfg = ParsaConfig(
            k=k, backend=backend,
            blocks=b if b > 1 else 1,
            init_iters=a if b > 1 else 0,  # b<=1 ran plain Alg 3 pre-facade
            sweeps=sweeps, seed=seed, refine_v=True, placement=True)
        return partition(graph, cfg).placement
    if method == "random":
        rng = np.random.default_rng(seed)
        parts_u = rng.permutation(np.arange(graph.num_u) % k).astype(np.int32)
        parts_v = rng.permutation(np.arange(graph.num_v) % k).astype(np.int32)
        return placement_from_parts(parts_u, parts_v, graph.num_v, k)
    raise ValueError(method)


def gather_traffic(graph: BipartiteGraph, placement: Placement) -> dict:
    """Exact embedding-gather traffic per optimizer step (unique rows model,
    as in the parameter server's key-cached pulls)."""
    m = evaluate(graph, placement.doc_to_shard, placement.vocab_to_shard, placement.k)
    need = need_matrix(graph, placement.doc_to_shard, placement.k)
    local = sum(
        int((need[i] & (placement.vocab_to_shard == i)).sum())
        for i in range(placement.k)
    )
    total_need = int(need.sum())
    return {
        "remote_rows_max": m.traffic_max,
        "remote_rows_sum": m.traffic_sum,
        "local_fraction": local / max(total_need, 1),
        "footprint_max": m.mem_max,
    }
