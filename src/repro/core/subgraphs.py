"""§4.2 division into subgraphs + §4.4 neighbor-set initialization.

Parsa splits U into b blocks, builds the b induced subgraphs (V ids stay
global so the shared neighbor sets S_i compose), and feeds them sequentially
through Algorithm 3, carrying S_i forward.  b trades quality (b=1: global
greedy) against speed/IO (b=|U|: random partition).

Initialization (§4.4):
  * individual — run ``a`` extra iterations first; after each, *reset*
    S_i ← N(U_{i,j}) and drop the assignments (keeping them would pin every
    vertex to its old partition at cost 0);
  * global     — partition a small sample once, use its neighbor sets to
    seed every worker (see parallel.py);
  * incremental — seed S_i from a previous run's result.
"""
from __future__ import annotations

import dataclasses
import warnings

import numpy as np

from .bipartite import BipartiteGraph
from .costs import need_matrix
from .partition_u import partition_u_impl

__all__ = ["divide", "sequential_parsa", "sequential_parsa_impl", "SubgraphPlan"]


@dataclasses.dataclass
class SubgraphPlan:
    """b random blocks of U and their induced subgraphs (global V ids)."""

    blocks: list[np.ndarray]          # u-id arrays
    subgraphs: list[BipartiteGraph]


def divide(graph: BipartiteGraph, b: int, seed: int = 0) -> SubgraphPlan:
    rng = np.random.default_rng(seed)
    perm = rng.permutation(graph.num_u)
    blocks = [np.sort(x) for x in np.array_split(perm, b)]
    return SubgraphPlan(blocks, [graph.subgraph_u(blk) for blk in blocks])


def sequential_parsa(
    graph: BipartiteGraph,
    k: int,
    b: int = 16,
    a: int = 0,
    theta: int = 1000,
    select: str = "size",
    seed: int = 0,
    init_sets: np.ndarray | None = None,
) -> np.ndarray:
    """Deprecated shim — use ``repro.api.partition`` with ``backend="host"``
    and ``blocks=b`` / ``init_iters=a``.  Output is bit-identical to the
    pre-facade implementation (``sequential_parsa_impl``)."""
    warnings.warn(
        "repro.core.sequential_parsa is deprecated; use repro.api.partition("
        "graph, ParsaConfig(k=..., backend='host', blocks=b, init_iters=a))",
        DeprecationWarning, stacklevel=2)
    from ..api import ParsaConfig
    from ..api_backends import get_backend

    cfg = ParsaConfig(k=k, backend="host", blocks=b, init_iters=a,
                      theta=theta, select=select, seed=seed, refine_v=False)
    return get_backend(cfg.backend)(graph, cfg, init_sets=init_sets).parts_u


def sequential_parsa_impl(
    graph: BipartiteGraph,
    k: int,
    b: int = 16,
    a: int = 0,
    theta: int = 1000,
    select: str = "size",
    seed: int = 0,
    init_sets: np.ndarray | None = None,
) -> tuple[np.ndarray, np.ndarray]:
    """Single-thread Parsa: a init iterations + b real iterations (§4.2/§4.4).

    Returns (parts_u over the full graph, final neighbor sets S (k, |V|)
    bool).  ``init_sets`` supports the incremental-partitioning mode (seed
    from a previous run).
    """
    plan = divide(graph, b, seed=seed)
    if init_sets is None:
        S = np.zeros((k, graph.num_v), dtype=bool)
    else:
        from ..kernels.parsa_cost import coerce_dense_sets

        S = coerce_dense_sets(init_sets, graph.num_v).copy()

    # ---- individual initialization: partition, then RESET S to the fresh
    # neighbor sets and drop assignments (§4.4).
    for t in range(a):
        sg = plan.subgraphs[t % b]
        res = partition_u_impl(sg, k, init_sets=S, theta=theta, select=select,
                               seed=seed + t)
        S = need_matrix(sg, res.parts_u, k)  # reset: S_i ← N(U_{i,t})

    # ---- real pass: union-accumulate S, keep assignments.
    parts_u = np.full(graph.num_u, -1, dtype=np.int32)
    for j in range(b):
        sg = plan.subgraphs[j]
        res = partition_u_impl(sg, k, init_sets=S, theta=theta, select=select,
                               seed=seed + a + j)
        parts_u[plan.blocks[j]] = res.parts_u
        S = res.neighbor_sets  # already S ∪ N(U_{i,j})
    return parts_u, S
