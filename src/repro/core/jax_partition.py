r"""TPU-native Parsa: blocked greedy over packed bitmasks (DESIGN.md §2).

The CPU algorithm's O(1) pointer updates don't map to TPU; instead we
*recompute over blocks*: for a block of B candidate vertices we evaluate the
full (B × k) cost tile with the parsa_cost Pallas kernel, then run a
device-side greedy loop of B steps — each step picks the partition to grow
(smallest size, Alg 1 line 7 / §4.1 perfect balance), selects the
minimum-cost unassigned vertex *within the block*, commits it, ORs its
neighbor mask into S_i, and down-dates only column i of the cost tile with
one popcount pass (cost never increases — same monotonicity the bucket
queue exploits).

Block-local greedy is a sampling approximation in exactly the sense of §4.2
(a block plays the role of a subgraph R); quality deltas vs the sequential
reference are measured in benchmarks/bench_table2.py.

``shard_parsa`` maps Alg 4 onto shard_map: each device on the ``data`` axis
partitions its own U-shard block-by-block against a device-local *stale*
bitmask copy; every ``merge_every`` blocks an all_gather + OR merges the
sets — the bulk-synchronous image of the parameter server's union-push
(server line 9), with τ == merge_every − 1 blocks of staleness.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels.parsa_cost import pack_bitmask, parsa_cost
from .bipartite import BipartiteGraph

__all__ = ["blocked_partition_u", "shard_parsa_step", "pack_graph_blocks"]


def pack_graph_blocks(graph: BipartiteGraph, block: int) -> list[tuple[np.ndarray, np.ndarray]]:
    """Split U into contiguous blocks and pack each block's neighbor bitmasks."""
    out = []
    for start in range(0, graph.num_u, block):
        ids = np.arange(start, min(start + block, graph.num_u))
        masks = pack_bitmask([graph.neighbors(int(u)) for u in ids], graph.num_v)
        out.append((ids, masks))
    return out


@functools.partial(jax.jit, static_argnames=("k", "use_kernel", "interpret"))
def _assign_block(
    nbr: jax.Array,        # (B, W) int32 packed N(u)
    s_masks: jax.Array,    # (k, W) int32 packed S_i
    sizes: jax.Array,      # (k,) int32 |U_i|
    *,
    k: int,
    use_kernel: bool = True,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Greedy-assign every vertex in the block. Returns (parts, S', sizes')."""
    B, W = nbr.shape
    cost = parsa_cost(nbr, s_masks, use_kernel=use_kernel, interpret=interpret)  # (B, k)
    BIG = jnp.int32(2**30)

    def step(state, _):
        cost, s_masks, sizes, parts = state
        i = jnp.argmin(sizes)  # partition to grow (perfect balance)
        u = jnp.argmin(cost[:, i])  # cheapest unassigned vertex in block
        mask_u = nbr[u]
        delta = mask_u & ~s_masks[i]
        new_si = s_masks[i] | mask_u
        # down-date column i only: cost never increases (§4.1)
        dec = jax.lax.population_count(nbr & delta[None, :]).astype(jnp.int32).sum(-1)
        cost = cost.at[:, i].add(-dec)
        cost = cost.at[u, :].set(BIG)  # retire u from the block
        s_masks = s_masks.at[i].set(new_si)
        sizes = sizes.at[i].add(1)
        parts = parts.at[u].set(i.astype(jnp.int32))
        return (cost, s_masks, sizes, parts), None

    parts0 = jnp.full((B,), -1, jnp.int32)
    (cost, s_masks, sizes, parts), _ = jax.lax.scan(
        step, (cost, s_masks, sizes, parts0), None, length=B
    )
    return parts, s_masks, sizes


def blocked_partition_u(
    graph: BipartiteGraph,
    k: int,
    block: int = 256,
    init_sets: np.ndarray | None = None,
    use_kernel: bool = True,
    interpret: bool | None = None,
    seed: int = 0,
) -> np.ndarray:
    """Host-driven blocked greedy partition (single 'device'). Returns parts_u."""
    W = (graph.num_v + 31) // 32
    if init_sets is None:
        s_masks = jnp.zeros((k, W), jnp.int32)
    else:
        s_masks = jnp.asarray(pack_bitmask(np.asarray(init_sets, bool), graph.num_v))
    sizes = jnp.zeros((k,), jnp.int32)
    rng = np.random.default_rng(seed)
    order = rng.permutation(graph.num_u)
    parts = np.full(graph.num_u, -1, np.int32)
    for start in range(0, graph.num_u, block):
        ids = order[start : start + block]
        masks = pack_bitmask([graph.neighbors(int(u)) for u in ids], graph.num_v)
        p, s_masks, sizes = _assign_block(
            jnp.asarray(masks), s_masks, sizes,
            k=k, use_kernel=use_kernel, interpret=interpret,
        )
        parts[ids] = np.asarray(p)
    return parts


def shard_parsa_step(k: int, axis: str = "data", use_kernel: bool = False):
    """Return a shard_map-able body: (local nbr blocks, S, sizes) → assignment.

    Each device processes its (n_blocks, B, W) stack of packed blocks against
    its local S copy, then merges S across ``axis`` by all_gather + OR and
    sizes by psum — one Alg 4 round with τ = n_blocks − 1.
    """

    def body(nbr_blocks: jax.Array, s_masks: jax.Array, sizes: jax.Array):
        def per_block(carry, nbr):
            s_masks, sizes = carry
            parts, s_masks, sizes = _assign_block(
                nbr, s_masks, sizes, k=k, use_kernel=use_kernel
            )
            return (s_masks, sizes), parts

        (s_masks, sizes), parts = jax.lax.scan(per_block, (s_masks, sizes), nbr_blocks)
        # server union-push: OR-merge neighbor sets across the data axis
        gathered = jax.lax.all_gather(s_masks, axis)  # (n_dev, k, W)
        merged = jax.lax.reduce(
            gathered, jnp.int32(0), jax.lax.bitwise_or, dimensions=(0,)
        )
        sizes = jax.lax.psum(sizes, axis)
        return parts, merged, sizes

    return body
