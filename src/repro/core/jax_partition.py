r"""TPU-native Parsa: blocked greedy over packed bitmasks (DESIGN.md §2).

The CPU algorithm's O(1) pointer updates don't map to TPU; instead we
*recompute over blocks*: a block of B candidate vertices is greedily
assigned by repeatedly picking the partition to grow (smallest size, Alg 1
line 7 / §4.1 perfect balance) and the minimum-cost unassigned vertex
within the block for it.  Block-local greedy is a sampling approximation in
exactly the sense of §4.2 (a block plays the role of a subgraph R); quality
deltas vs the sequential reference are measured in
benchmarks/bench_table2.py.

Dispatch model (one scan, donated carries, fused select)
--------------------------------------------------------

The pipeline is fully device-resident:

1. *Packing* — the whole permuted U is packed host-side in one vectorized
   sorted pass over the edge array (``pack_bitmask_csr_sparse``; zero
   Python-level per-vertex work) into per-row *compact word lists* plus a
   tiny dense side channel for rows with more than ``cap`` nonzero words.
   No dense ``(n_blocks, B, W)`` stack exists on either host or device:
   each block's (B, W) bitmask is rebuilt inside the scan by a 12K-element
   scatter-add (``_rebuild_nbr``).

2. *One dispatch* — ``blocked_partition_u_impl`` issues a single jitted
   ``jax.lax.scan`` over the block stack (``_partition_scan``) with the
   ``(S, sizes)`` carries donated, instead of one host dispatch per block.
   ``dispatch_counter()`` observes exactly one launch per partition call.

This module's public names are deprecation shims over the ``repro.api``
facade (backends ``device_scan`` / ``host_blocked_oracle``); the ``_impl``
functions are the registered implementations and also return the final
packed ``s_masks`` so the device path warm-starts with host-path parity.

3. *Greedy rounds + fused select* — perfect balance makes the partition
   visit order deterministic: when partition sizes differ by at most one
   (always true here: sizes start equal and every round preserves it), the
   next k picks visit each partition exactly once — first the catch-up set
   (partitions at the current min size, in index order), then full rounds
   in plain index order.  ``_assign_block_rounds`` therefore runs
   ceil-ish(B/k) *rounds* instead of B scalar steps.  Each round selects
   one vertex per partition with progressive retirement — on TPU via the
   fused cost+select Pallas kernel (``parsa_cost_select``), which reduces
   the (B, k) cost tile to per-partition (min, argmin) inside VMEM without
   materializing it, enabling B=1024 blocks; on CPU (``use_kernel=False``)
   from a down-dated cost tile whose per-round update gathers only the
   ≤ cap nonzero words of each selected vertex's mask (dense fallback via
   ``lax.cond`` when a hub vertex exceeds cap — bit-exact either way).

   Both paths produce *identical* assignments to the sequential per-vertex
   reference ``blocked_partition_u_hostloop`` (property-tested), because a
   round's selections see exactly the tile state the per-vertex loop would:
   within a round each column is picked at most once, down-dates touch only
   the picked column, and cross-column interaction is pure retirement.

``shard_parsa_step`` maps Alg 4 onto shard_map: each device on the ``data``
axis partitions its own U-shard block-by-block against a device-local
*stale* bitmask copy; every ``merge_every`` blocks an all_gather + OR
merges the sets — the bulk-synchronous image of the parameter server's
union-push (server line 9), with τ == merge_every − 1 blocks of staleness.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import time
import warnings
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from ..obs import trace as _obs_trace

from ..kernels.parsa_cost import (
    BIG,
    coerce_packed_sets,
    pack_bitmask,
    pack_bitmask_csr_sparse,
    parsa_cost,
    parsa_cost_select,
    select_greedy_from_cost,
    sketch_cost_select,
)
from .bipartite import BipartiteGraph

__all__ = [
    "blocked_partition_u",
    "blocked_partition_u_hostloop",
    "blocked_partition_u_impl",
    "blocked_partition_u_hostloop_impl",
    "parallel_blocked_partition_u_impl",
    "shard_parsa_step",
    "pack_graph_blocks",
    "PackedBlocks",
    "dispatch_counter",
    "reset_dispatch_counts",
    "annotate_dispatch",
    "DispatchEvent",
    "DispatchLog",
    "resolve_worker_devices",
]

# Dispatch accounting: one entry per *host→device pipeline launch*;
# blocked_partition_u_impl bumps it exactly once per call regardless of
# graph size (O(1)-dispatch invariant, asserted in
# tests/test_jax_partition.py).  Counts are observed through the
# ``dispatch_counter()`` context manager so concurrent tests can't leak
# counts into each other the way the old module-global dict did.


@dataclasses.dataclass
class DispatchEvent:
    """One labeled pipeline launch: phase, donated-carry bytes, extras
    (jit cache hit/miss, worker id, ...)."""

    phase: str
    nbytes: int = 0
    meta: dict = dataclasses.field(default_factory=dict)


class DispatchLog(dict):
    """The dict ``dispatch_counter`` yields, upgraded with labeled
    per-launch records.

    Still a plain ``phase -> count`` mapping (every existing
    ``counts["partition_scan"] == 1`` / ``counts == {...}`` assert keeps
    working); ``.records`` carries the ordered ``DispatchEvent`` stream
    behind those totals."""

    def __init__(self, *args, **kwargs):
        super().__init__(*args, **kwargs)
        self.records: list[DispatchEvent] = []

    def bytes_by_phase(self) -> dict[str, int]:
        out: dict[str, int] = {}
        for r in self.records:
            out[r.phase] = out.get(r.phase, 0) + r.nbytes
        return out


_ACTIVE_COUNTERS: list[DispatchLog] = []


def _count_dispatch(name: str, nbytes: int = 0, **meta) -> None:
    for counts in _ACTIVE_COUNTERS:
        counts[name] = counts.get(name, 0) + 1
        counts.records.append(DispatchEvent(name, int(nbytes),
                                            dict(meta)))
    _obs_trace.dispatch_instant(name, nbytes=nbytes, meta=meta or None)


def annotate_dispatch(**meta) -> None:
    """Attach after-the-fact labels (jit ``cache_miss`` is only knowable
    once the call returns) to the launch just counted."""
    for counts in _ACTIVE_COUNTERS:
        if counts.records:
            counts.records[-1].meta.update(meta)
    _obs_trace.annotate_last_instant(**meta)


@contextlib.contextmanager
def dispatch_counter():
    """Yield a fresh ``{"partition_scan": 0, ...}`` log (a dict subclass;
    see ``DispatchLog``) that records only the pipeline launches issued
    inside this ``with`` block."""
    counts = DispatchLog({"partition_scan": 0})
    _ACTIVE_COUNTERS.append(counts)
    try:
        yield counts
    finally:
        # remove by identity: equal-valued dicts from nested scopes must not
        # deregister each other
        for i, c in enumerate(_ACTIVE_COUNTERS):
            if c is counts:
                del _ACTIVE_COUNTERS[i]
                break


def reset_dispatch_counts() -> None:
    """Zero every active counter (test-isolation helper)."""
    for counts in _ACTIVE_COUNTERS:
        for key in counts:
            counts[key] = 0
        counts.records.clear()


class PackedBlocks(NamedTuple):
    """Device-ready blocked packing of (a permutation of) U.

    The dense (B, W) bitmask of a block is *not* stored — it is rebuilt on
    device inside the scan from the compact word lists (a 12K-element
    scatter-add per block), so the packing ships ~cap words per vertex
    instead of W.  The rare rows with more than ``cap`` nonzero words ride
    along densely in ``tr_masks`` and overwrite their rebuilt row.
    """

    valid: np.ndarray     # (n_blocks, B) bool — False for padding rows
    widx: np.ndarray      # (n_blocks, B, cap) int32 nonzero-word indices
    vals: np.ndarray      # (n_blocks, B, cap) int32 word values at widx
    trunc: np.ndarray     # (n_blocks, B) bool — row has > cap nonzero words
    tr_ids: np.ndarray    # (n_blocks, TB) int32 local row of each truncated
                          #   row; B (out of range → dropped) for padding
    tr_masks: np.ndarray  # (n_blocks, TB, W) int32 full masks of those rows
    order: np.ndarray     # (num_u,) int64 — global vertex id per packed row


def pack_graph_blocks(
    graph: BipartiteGraph,
    block: int,
    order: np.ndarray | None = None,
    cap: int = 48,
    tb_pad: int | None = None,
) -> PackedBlocks:
    """Pack all of U (in ``order``) into padded (n_blocks, B, …) stacks.

    Fully vectorized: one CSR gather + one sorted pass over the edge array
    yields the compact word lists and the truncated-row side channel.  No
    per-vertex Python work, and no dense (n, W) array on the host.

    ``tb_pad`` rounds the truncated-row side-channel width TB up to the
    next power of two ≥ max(TB, tb_pad).  Padding entries carry
    ``tr_ids == B`` (dropped on device), so the output is bit-equivalent —
    the point is shape stability: streaming feeds re-pack same-sized chunks
    whose natural TB jitters with the data, and a stable TB keeps every
    feed on the already-compiled scan.
    """
    n = graph.num_u
    if order is None:
        order = np.arange(n, dtype=np.int64)
    order = np.asarray(order, dtype=np.int64)
    uniq, wordvals, widx, vals, trunc = pack_bitmask_csr_sparse(
        graph.u_indptr, graph.u_indices, graph.num_v, rows=order, cap=cap)[:5]
    W = (graph.num_v + 31) // 32
    n_blocks = max(1, -(-n // block))
    pad = n_blocks * block - n
    if pad:
        widx = np.pad(widx, [(0, pad), (0, 0)])
        vals = np.pad(vals, [(0, pad), (0, 0)])
        trunc = np.pad(trunc, [(0, pad)])
    valid = (np.arange(n_blocks * block) < n).reshape(n_blocks, block)
    # side channel: full masks of truncated rows, grouped per block
    t_rows = np.flatnonzero(trunc)                       # padded row ids
    t_block = t_rows // block
    t_counts = np.bincount(t_block, minlength=n_blocks)
    TB = max(1, int(t_counts.max()) if t_rows.size else 1)
    if tb_pad is not None:
        TB = max(TB, tb_pad)
        TB = 1 << (TB - 1).bit_length()
    tr_ids = np.full((n_blocks, TB), block, np.int32)    # block == dropped
    tr_masks = np.zeros((n_blocks, TB, W), np.int32)
    if t_rows.size:
        t_starts = np.concatenate([[0], np.cumsum(t_counts)[:-1]])
        slot = np.arange(t_rows.size, dtype=np.int64) - t_starts[t_block]
        tr_ids[t_block, slot] = (t_rows % block).astype(np.int32)
        trunc_idx = np.full(n_blocks * block, -1, np.int64)
        trunc_idx[t_rows] = t_block * TB + slot
        r = uniq // W
        member = trunc[r]
        tr_masks.reshape(-1, W)[trunc_idx[r[member]], uniq[member] % W] = \
            wordvals[member]
    return PackedBlocks(
        valid=valid,
        widx=widx.reshape(n_blocks, block, cap),
        vals=vals.reshape(n_blocks, block, cap),
        trunc=trunc.reshape(n_blocks, block),
        tr_ids=tr_ids,
        tr_masks=tr_masks,
        order=order,
    )


# --------------------------------------------------------------------------
# Sequential per-vertex reference (the seed implementation, kept as the
# parity oracle and benchmark baseline).
# --------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("k", "use_kernel", "interpret"))
def _assign_block(
    nbr: jax.Array,        # (B, W) int32 packed N(u)
    s_masks: jax.Array,    # (k, W) int32 packed S_i
    sizes: jax.Array,      # (k,) int32 |U_i|
    valid: jax.Array | None = None,  # (B,) bool — padding rows, if any
    *,
    k: int,
    use_kernel: bool = True,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Greedy-assign every vertex in the block, one scalar step at a time.

    Returns (parts, S', sizes').  This is the sequential reference: B scan
    steps, each down-dating one column of the (B, k) cost tile.  With
    ``valid=None`` the loop is exactly the seed implementation (the parity
    oracle — every row is assigned).  Passing ``valid`` marks padding rows
    unpickable so a ragged block doesn't leak phantom picks into ``sizes``
    or skew the assignment order.
    """
    B, W = nbr.shape
    cost = parsa_cost(nbr, s_masks, use_kernel=use_kernel, interpret=interpret)  # (B, k)
    if valid is not None:
        cost = jnp.where(valid[:, None], cost, BIG)

    def step(state, _):
        cost, s_masks, sizes, parts = state
        i = jnp.argmin(sizes)  # partition to grow (perfect balance)
        u = jnp.argmin(cost[:, i])  # cheapest unassigned vertex in block
        if valid is None:
            active = jnp.bool_(True)
        else:
            # once only retired/padding rows remain their cost sits near
            # BIG (down-dates can drift it a little); stop assigning then
            active = cost[u, i] < BIG // 2
        mask_u = jnp.where(active, nbr[u], 0)
        delta = mask_u & ~s_masks[i]
        new_si = s_masks[i] | mask_u
        # down-date column i only: cost never increases (§4.1)
        dec = jax.lax.population_count(nbr & delta[None, :]).astype(jnp.int32).sum(-1)
        cost = cost.at[:, i].add(-dec)
        cost = cost.at[u, :].set(BIG)  # retire u from the block
        s_masks = s_masks.at[i].set(new_si)
        sizes = sizes.at[i].add(active.astype(jnp.int32))
        parts = parts.at[u].set(
            jnp.where(active, i.astype(jnp.int32), parts[u]))
        return (cost, s_masks, sizes, parts), None

    parts0 = jnp.full((B,), -1, jnp.int32)
    (cost, s_masks, sizes, parts), _ = jax.lax.scan(
        step, (cost, s_masks, sizes, parts0), None, length=B
    )
    return parts, s_masks, sizes


# --------------------------------------------------------------------------
# Rounds-based device-resident block greedy.
# --------------------------------------------------------------------------
def _rebuild_nbr(widx: jax.Array, vals: jax.Array,
                 tr_ids: jax.Array, tr_masks: jax.Array) -> jax.Array:
    """Densify a block's (B, W) bitmask from its compact word lists.

    Padding slots all target word 0 with value 0, so scatter-*add* is
    duplicate-safe; truncated rows are then overwritten with their full
    masks (tr_ids == B ⇒ dropped)."""
    B, _ = widx.shape
    W = tr_masks.shape[-1]
    nbr = jnp.zeros((B, W), jnp.int32)
    nbr = nbr.at[jnp.arange(B, dtype=jnp.int32)[:, None], widx].add(vals)
    return nbr.at[tr_ids].set(tr_masks, mode="drop")


def _assign_block_rounds(
    valid: jax.Array,     # (B,) bool
    widx: jax.Array,      # (B, cap) int32
    vals: jax.Array,      # (B, cap) int32
    trunc: jax.Array,     # (B,) bool
    tr_ids: jax.Array,    # (TB,) int32
    tr_masks: jax.Array,  # (TB, W) int32
    s_masks: jax.Array,   # (k, W) int32
    sizes: jax.Array,     # (k,) int32
    *,
    k: int,
    use_kernel: bool,
    interpret: bool | None,
    sketch: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Greedy-assign a block in balanced rounds.  Returns (parts, S', sizes').

    Identical output to ``_assign_block`` whenever sizes differ by ≤ 1 at
    entry (property-tested); on the kernel path the cost tile lives only in
    VMEM (fused cost+select), on the jnp path it is carried and down-dated
    sparsely via the compact word lists.

    ``sketch=True`` marks the packed width as a sketched domain
    (``repro.sketch``): the kernel path switches to the gridless
    VMEM-resident ``sketch_cost_select`` (the whole block tile fits in one
    grid step at sketch widths).  The jnp path is width-agnostic — the
    same integer program at a smaller W — so the flag changes nothing
    there, which is precisely why the exact-parity regression holds.
    """
    nbr = _rebuild_nbr(widx, vals, tr_ids, tr_masks)
    B, W = nbr.shape
    retired0 = ~valid
    parts0 = jnp.full((B,), -1, jnp.int32)
    cap = widx.shape[1]
    iota_b = jnp.arange(B, dtype=jnp.int32)
    iota_k = jnp.arange(k, dtype=jnp.int32)

    if use_kernel:
        # Fused cost+select recomputes the (B, k) tile in VMEM each round
        # and reduces it in the same pass — no tile is carried at all, so
        # the state holds a placeholder.
        nbr_t = None
        tile0 = jnp.zeros((1, 1), jnp.int32)
    else:
        # jnp path: carry the tile and down-date it sparsely.  Initial tile
        # cost[v, i] = deg(v) − |N(v) ∩ S_i|: the intersection only touches
        # each row's ≤ cap nonzero words, so gather S at widx instead of
        # the dense (B, k, W) product; any truncated row in the block trips
        # the exact dense fallback (rare for cap ≈ 48).  Both sparse
        # gathers run over *transposed* operands so each gathered index
        # pulls a contiguous row instead of a strided column — XLA CPU's
        # element gather was the down-date bottleneck (~45% of scan time).
        nbr_t = nbr.T                                      # (W, B)
        deg = jax.lax.population_count(vals).astype(jnp.int32).sum(-1)

        def sparse_init(_):
            sg = s_masks.T[widx.reshape(-1)].reshape(B, cap, k)
            inter = jax.lax.population_count(
                sg & vals[:, :, None]).astype(jnp.int32).sum(1)  # (B, k)
            return deg[:, None] - inter

        def dense_init(_):
            return parsa_cost(nbr, s_masks, use_kernel=False)

        tile0 = jax.lax.cond(trunc.any(), dense_init, sparse_init, None)

    def round_body(state, ord_, en):
        """One greedy round.  ord_ = None means the identity visit order
        0..k-1 (every round after the catch-up), which skips all the
        slot→partition permutation gathers."""
        tile, s_masks, sizes, parts, retired = state
        if use_kernel:
            select_fn = sketch_cost_select if sketch else parsa_cost_select
            u_sel, c_sel = select_fn(
                nbr, s_masks, retired,
                order=iota_k if ord_ is None else ord_, enabled=en,
                use_kernel=True, interpret=interpret)
        else:
            u_sel, c_sel = select_greedy_from_cost(tile, retired, ord_, en)
        act = c_sel < BIG
        u_safe = jnp.where(act, u_sel, 0)
        sel_nbr = nbr[u_safe]                              # (k, W)
        if not use_kernel:
            # Down-date values in compact space: delta_j's nonzero words
            # are a subset of the selected vertex's word list, so gather S
            # (pre-update) at widx[u_j] instead of materializing delta
            # full-width.  Padding slots carry vals == 0 → contribute 0.
            d_widx = widx[u_safe]                          # (k, cap)
            d_sel_vals = vals[u_safe]
            if ord_ is None:
                s_at = jnp.take_along_axis(s_masks, d_widx, axis=1)
            else:
                s_at = s_masks[ord_[:, None], d_widx]
            d_vals = jnp.where(act[:, None], d_sel_vals & ~s_at, 0)

            def sparse_dec(_):
                g = nbr_t[d_widx.reshape(-1)].reshape(k, cap, B)
                return jax.lax.population_count(
                    g & d_vals[:, :, None]).astype(jnp.int32).sum(1).T

            def dense_dec(_):
                s_cols = s_masks if ord_ is None else s_masks[ord_]
                delta = jnp.where(act[:, None], sel_nbr & ~s_cols, 0)
                return jax.lax.population_count(
                    nbr[:, None, :] & delta[None]).astype(jnp.int32).sum(-1)

            any_trunc = jnp.any(act & trunc[u_safe])
            dec = jax.lax.cond(any_trunc, dense_dec, sparse_dec, None)
        # commit: S_i |= N(u), sizes, parts, retirement, tile down-date
        add = jnp.where(act[:, None], sel_nbr, 0)
        match = (iota_b[:, None] == u_sel[None, :]) & act[None, :]  # (B, k)
        assigned = match.any(axis=1)
        retired = retired | assigned
        if ord_ is None:
            s_masks = s_masks | add
            sizes = sizes + act.astype(jnp.int32)
            col_id = (match * iota_k[None, :]).sum(axis=1).astype(jnp.int32)
            if not use_kernel:
                tile = tile - dec
        else:
            inv = jnp.argsort(ord_)
            s_masks = s_masks | add[inv]
            sizes = sizes + act[inv].astype(jnp.int32)
            col_id = (match * ord_[None, :]).sum(axis=1).astype(jnp.int32)
            if not use_kernel:
                tile = tile - dec[:, inv]
        parts = jnp.where(assigned, col_id, parts)
        return tile, s_masks, sizes, parts, retired

    # catch-up round (partition visit order = stable argsort of sizes,
    # only the min-sized partitions may pick), then full identity rounds
    ord0 = jnp.argsort(sizes, stable=True).astype(jnp.int32)
    en0 = sizes[ord0] == jnp.min(sizes)
    state = round_body((tile0, s_masks, sizes, parts0, retired0), ord0, en0)
    en_all = jnp.ones((k,), bool)

    def full_round(state, _):
        return round_body(state, None, en_all), None

    n_full = -(-(B - 1) // k)  # catch-up may assign as little as one vertex
    (_, s_masks, sizes, parts, _), _ = jax.lax.scan(
        full_round, state, None, length=n_full)
    return parts, s_masks, sizes


@functools.partial(
    jax.jit,
    static_argnames=("k", "use_kernel", "interpret", "sketch"),
    donate_argnums=(6, 7),
)
def _partition_scan(
    valid: jax.Array,     # (n_blocks, B) bool
    widx: jax.Array,      # (n_blocks, B, cap) int32
    vals: jax.Array,      # (n_blocks, B, cap) int32
    trunc: jax.Array,     # (n_blocks, B) bool
    tr_ids: jax.Array,    # (n_blocks, TB) int32
    tr_masks: jax.Array,  # (n_blocks, TB, W) int32
    s_masks: jax.Array,   # (k, W) int32 — donated
    sizes: jax.Array,     # (k,) int32 — donated
    *,
    k: int,
    use_kernel: bool,
    interpret: bool | None,
    sketch: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """The whole partition as ONE XLA dispatch: scan blocks, carry (S, sizes)."""

    def per_block(carry, xs):
        s, sz = carry
        parts, s, sz = _assign_block_rounds(
            *xs, s, sz, k=k, use_kernel=use_kernel, interpret=interpret,
            sketch=sketch)
        return (s, sz), parts

    (s_masks, sizes), parts = jax.lax.scan(
        per_block, (s_masks, sizes),
        (valid, widx, vals, trunc, tr_ids, tr_masks))
    return parts, s_masks, sizes


def blocked_partition_u_impl(
    graph: BipartiteGraph,
    k: int,
    block: int = 256,
    init_sets: np.ndarray | None = None,
    use_kernel: bool = True,
    interpret: bool | None = None,
    seed: int = 0,
    cap: int = 48,
    as_numpy: bool = True,
    timings: dict | None = None,
    sketch: bool = False,
) -> tuple[np.ndarray, np.ndarray]:
    """Device-resident blocked greedy partition.
    Returns (parts_u, final packed s_masks (k, W) int32).

    Packs the entire permuted U once (vectorized, compact word lists —
    ~cap words per vertex instead of W; the dense (B, W) bitmask of each
    block is rebuilt on device inside the scan, so a gigabyte-scale stack
    never exists on either side) and issues one jitted scan over the block
    stack — O(1) XLA dispatches per call.  The final neighbor-set bitmasks
    come back with the scan carry, so the device path supports warm-start /
    incremental repartitioning with full parity to the host path.

    ``init_sets`` may be dense (k, |V|) bool or already-packed (k, W) int32
    words (the ``PartitionResult.s_masks`` fast path — no dense detour).
    ``as_numpy=False`` keeps both outputs as device arrays so the V-refine
    and metrics phases can consume them without a host round trip.
    A ``timings`` dict, when given, receives the host-side ``"pack"``
    seconds so the facade can report packing separately from the scan.
    """
    t_pack = time.perf_counter()
    W = (graph.num_v + 31) // 32
    if init_sets is None:
        s_masks = jnp.zeros((k, W), jnp.int32)
    else:
        s_masks = jnp.asarray(coerce_packed_sets(init_sets, graph.num_v))
    sizes = jnp.zeros((k,), jnp.int32)
    rng = np.random.default_rng(seed)
    order = rng.permutation(graph.num_u)
    packed = pack_graph_blocks(graph, block, order=order, cap=cap)
    if timings is not None:
        timings["pack"] = time.perf_counter() - t_pack
    _count_dispatch("partition_scan",
                    nbytes=int(s_masks.nbytes) + int(sizes.nbytes),
                    k=k, blocks=int(packed.valid.shape[0]))
    parts_blocks, s_out, _ = _partition_scan(
        jnp.asarray(packed.valid), jnp.asarray(packed.widx),
        jnp.asarray(packed.vals), jnp.asarray(packed.trunc),
        jnp.asarray(packed.tr_ids), jnp.asarray(packed.tr_masks),
        s_masks, sizes,
        k=k, use_kernel=use_kernel, interpret=interpret, sketch=sketch)
    if not as_numpy:
        flat = parts_blocks.reshape(-1)[: graph.num_u]
        parts = jnp.zeros((graph.num_u,), jnp.int32).at[
            jnp.asarray(order)].set(flat)
        return parts, s_out
    flat = np.asarray(parts_blocks).reshape(-1)[: graph.num_u]
    parts = np.full(graph.num_u, -1, np.int32)
    parts[order] = flat
    return parts, np.asarray(s_out)


def blocked_partition_u(
    graph: BipartiteGraph,
    k: int,
    block: int = 256,
    init_sets: np.ndarray | None = None,
    use_kernel: bool = True,
    interpret: bool | None = None,
    seed: int = 0,
    cap: int = 48,
    return_sets: bool = False,
) -> np.ndarray | tuple[np.ndarray, np.ndarray]:
    """Deprecated shim — use ``repro.api.partition`` with
    ``backend="device_scan"``.  Returns parts_u (bit-identical to the
    pre-facade output); with ``return_sets=True`` also the final packed
    ``s_masks`` for warm-start parity with the host path."""
    warnings.warn(
        "blocked_partition_u is deprecated; use repro.api.partition(graph, "
        "ParsaConfig(k=..., backend='device_scan', block_size=...))",
        DeprecationWarning, stacklevel=2)
    from ..api import ParsaConfig
    from ..api_backends import get_backend

    cfg = ParsaConfig(k=k, backend="device_scan", block_size=block,
                      cap=cap, use_kernel=use_kernel, interpret=interpret,
                      seed=seed, refine_v=False)
    out = get_backend(cfg.backend)(graph, cfg, init_sets=init_sets)
    return (out.parts_u, out.s_masks) if return_sets else out.parts_u


def blocked_partition_u_hostloop_impl(
    graph: BipartiteGraph,
    k: int,
    block: int = 256,
    init_sets: np.ndarray | None = None,
    use_kernel: bool = True,
    interpret: bool | None = None,
    seed: int = 0,
) -> tuple[np.ndarray, np.ndarray]:
    """The seed implementation: per-block Python packing + one dispatch per
    block + per-vertex greedy.  Kept verbatim as the parity oracle and the
    benchmark baseline for the single-dispatch pipeline.
    Returns (parts_u, final packed s_masks)."""
    W = (graph.num_v + 31) // 32
    if init_sets is None:
        s_masks = jnp.zeros((k, W), jnp.int32)
    else:
        s_masks = jnp.asarray(coerce_packed_sets(init_sets, graph.num_v))
    sizes = jnp.zeros((k,), jnp.int32)
    rng = np.random.default_rng(seed)
    order = rng.permutation(graph.num_u)
    parts = np.full(graph.num_u, -1, np.int32)
    for start in range(0, graph.num_u, block):
        ids = order[start : start + block]
        masks = pack_bitmask([graph.neighbors(int(u)) for u in ids], graph.num_v)
        p, s_masks, sizes = _assign_block(
            jnp.asarray(masks), s_masks, sizes,
            k=k, use_kernel=use_kernel, interpret=interpret,
        )
        parts[ids] = np.asarray(p)
    return parts, np.asarray(s_masks)


def blocked_partition_u_hostloop(
    graph: BipartiteGraph,
    k: int,
    block: int = 256,
    init_sets: np.ndarray | None = None,
    use_kernel: bool = True,
    interpret: bool | None = None,
    seed: int = 0,
    return_sets: bool = False,
) -> np.ndarray | tuple[np.ndarray, np.ndarray]:
    """Deprecated shim — use ``repro.api.partition`` with
    ``backend="host_blocked_oracle"``."""
    warnings.warn(
        "blocked_partition_u_hostloop is deprecated; use repro.api.partition("
        "graph, ParsaConfig(k=..., backend='host_blocked_oracle'))",
        DeprecationWarning, stacklevel=2)
    from ..api import ParsaConfig
    from ..api_backends import get_backend

    cfg = ParsaConfig(k=k, backend="host_blocked_oracle", block_size=block,
                      use_kernel=use_kernel, interpret=interpret, seed=seed,
                      refine_v=False)
    out = get_backend(cfg.backend)(graph, cfg, init_sets=init_sets)
    return (out.parts_u, out.s_masks) if return_sets else out.parts_u


def _pad_block_stack(packed: PackedBlocks, n_total: int) -> PackedBlocks:
    """Append ``n_total - n_blocks`` empty blocks (all rows padding: valid
    False, tr_ids == B ⇒ dropped) so a block stack divides evenly into
    per-worker shards and merge groups.  Empty blocks assign nothing and
    leave (S, sizes) untouched, so trailing padding is parity-safe."""
    nb, B = packed.valid.shape
    if n_total == nb:
        return packed
    e = n_total - nb

    def pad0(a):
        return np.pad(a, [(0, e)] + [(0, 0)] * (a.ndim - 1))

    tr_pad = np.full((e, packed.tr_ids.shape[1]), B, np.int32)
    return PackedBlocks(
        valid=pad0(packed.valid),
        widx=pad0(packed.widx),
        vals=pad0(packed.vals),
        trunc=pad0(packed.trunc),
        tr_ids=np.concatenate([packed.tr_ids, tr_pad]),
        tr_masks=pad0(packed.tr_masks),
        order=packed.order,
    )


@functools.cache
def _parallel_scan_fn(devices, k: int, merge_every: int, use_kernel: bool,
                      interpret: bool | None, sketch: bool = False):
    """Build (and cache) the jitted shard_map pipeline for one worker mesh.

    Each device scans its (n_super, merge_every, B, …) block stack against a
    device-local *stale* copy of the packed (k, W) server sets; after every
    ``merge_every`` blocks the shards merge by all_gather + lattice OR on
    uint32 words (the bulk-synchronous image of the Alg 4 server union-push,
    τ ≡ merge_every − 1 blocks of staleness) and sizes by psum of the local
    deltas.  The (S, sizes) carries are donated, so nothing round-trips
    through the host between merges.  Also returns the total number of
    changed words pushed across all merges (the delta-encoded worker→server
    traffic of Alg 4 worker line 9).
    """
    from jax.sharding import Mesh, PartitionSpec as P

    from ..compat import shard_map

    axis = "parsa_workers"
    mesh = Mesh(np.asarray(devices), (axis,))

    def body(valid, widx, vals, trunc, tr_ids, tr_masks, s_masks, sizes):
        # shard_map leaves the sharded leading axis in place with local
        # extent 1 — drop it, then group blocks into merge rounds.
        valid, widx, vals, trunc, tr_ids, tr_masks = (
            x[0] for x in (valid, widx, vals, trunc, tr_ids, tr_masks))
        nb = valid.shape[0]
        n_super = nb // merge_every

        def group(x):
            return x.reshape((n_super, merge_every) + x.shape[1:])

        def per_block(carry, xs):
            s, sz = carry
            parts, s, sz = _assign_block_rounds(
                *xs, s, sz, k=k, use_kernel=use_kernel, interpret=interpret,
                sketch=sketch)
            return (s, sz), parts

        def super_step(carry, xs):
            s_global, sz_global, pushed = carry
            # local greedy over merge_every blocks against the stale copy
            (s_local, sz_local), parts = jax.lax.scan(
                per_block, (s_global, sz_global), xs)
            # worker push is delta-encoded: count the changed words
            pushed = pushed + jnp.count_nonzero(
                s_local & ~s_global).astype(jnp.int32)
            # server union-push: OR-merge the neighbor sets across workers,
            # and psum the size *deltas* onto the shared pre-merge totals
            gathered = jax.lax.all_gather(s_local, axis)
            s_merged = jax.lax.reduce(
                gathered, jnp.int32(0), jax.lax.bitwise_or, dimensions=(0,))
            sz_merged = sz_global + jax.lax.psum(sz_local - sz_global, axis)
            return (s_merged, sz_merged, pushed), parts

        (s_masks, sizes, pushed), parts = jax.lax.scan(
            super_step, (s_masks, sizes, jnp.int32(0)),
            tuple(group(x) for x in
                  (valid, widx, vals, trunc, tr_ids, tr_masks)))
        pushed = jax.lax.psum(pushed, axis)
        return parts[None], s_masks, sizes, pushed

    fn = shard_map(
        body, mesh=mesh,
        in_specs=(P(axis),) * 6 + (P(), P()),
        out_specs=(P(axis), P(), P(), P()),
        check_vma=False)
    return jax.jit(fn, donate_argnums=(6, 7))


def resolve_worker_devices(workers: int, devices: tuple | None = None) -> tuple:
    """The ``workers``-wide device slice, or a fail-fast ValueError when
    the mesh cannot exist — cheap, so callers run it BEFORE any O(edges)
    host packing."""
    if devices is None:
        devices = tuple(jax.devices())
    if len(devices) < workers:
        raise ValueError(
            f"need {workers} devices but only {len(devices)} are visible; "
            f"on CPU hosts set XLA_FLAGS=--xla_force_host_platform_device_"
            f"count={workers} before importing jax")
    return tuple(devices[:workers])


def _weighted_block_targets(weights: np.ndarray, nb: int) -> np.ndarray:
    """Largest-remainder apportionment of ``nb`` real blocks proportional
    to per-worker ``weights`` (higher weight ⇒ more blocks)."""
    raw = weights / weights.sum() * nb
    t = np.floor(raw).astype(np.int64)
    short = nb - int(t.sum())
    if short:
        t[np.argsort(-(raw - t), kind="stable")[:short]] += 1
    return t


def _biased_perm(targets: np.ndarray, nb: int, nb_per: int,
                 shuffle_rng: np.random.Generator | None) -> np.ndarray:
    """Block→worker permutation handing worker ``w`` exactly
    ``targets[w]`` real blocks (randomized across workers when a rng is
    given) and topping every worker up to ``nb_per`` with trailing padding
    blocks — the parity-safe no-ops ``_pad_block_stack`` appends — so the
    sharded shapes stay identical while slow workers scan mostly padding.
    """
    real = (shuffle_rng.permutation(nb) if shuffle_rng is not None
            else np.arange(nb, dtype=np.int64))
    pad_ids = np.arange(nb, nb_per * targets.shape[0], dtype=np.int64)
    out, r0, p0 = [], 0, 0
    for t_w in targets:
        t_w = int(t_w)
        out.append(real[r0 : r0 + t_w])
        out.append(pad_ids[p0 : p0 + nb_per - t_w])
        r0 += t_w
        p0 += nb_per - t_w
    return np.concatenate(out)


def _run_parallel_packed_scan(
    packed: PackedBlocks,
    s_masks: jax.Array,
    sizes: jax.Array,
    *,
    k: int,
    workers: int,
    merge_every: int,
    use_kernel: bool,
    interpret: bool | None,
    devices: tuple | None = None,
    shuffle_rng: np.random.Generator | None = None,
    worker_weights: np.ndarray | None = None,
    count_name: str = "parallel_partition_scan",
    sketch: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array, dict, np.ndarray | None]:
    """Shared Alg 4 core of ``parallel_blocked_partition_u_impl`` and the
    streaming parallel feed: pad the block stack to whole per-worker merge
    groups, shard it across the worker mesh (optionally in a randomized
    block→worker order drawn from ``shuffle_rng`` — the arXiv:1502.02606
    assignment the stream uses), and run the cached shard_map pipeline
    against the (donated) live ``(s_masks, sizes)``.

    ``worker_weights`` (workers-long, nonnegative, e.g. the inverse-EWMA
    speeds from ``runtime.straggler.StragglerEWMA``) biases the block
    distribution: real blocks are apportioned proportionally to weight
    (largest remainder) and the shortfall on slow workers is filled with
    parity-safe padding blocks, so every shard keeps the same shape —
    shard_map's requirement — while a straggler's wall-clock share
    shrinks.  The merge cadence is untouched: each worker still syncs
    every ``merge_every`` blocks, so the τ ≡ merge_every − 1 staleness
    bound of the bounded-delay model holds regardless of the bias.

    Returns ``(parts_blocks, s_out, sizes_out, traffic, perm)`` where
    ``parts_blocks`` is the device (workers, n_super, merge_every, B)
    output in *sharded* block order (flatten + ``argsort(perm)`` to
    recover stack order when a permutation was applied; ``perm`` is None
    only when neither shuffle nor weights were given), and ``traffic`` is
    the push/pull dict in bitmask-word bytes — the single source of the
    Alg 4 counter formulas.
    """
    devices = resolve_worker_devices(workers, devices)
    nb = packed.valid.shape[0]
    if worker_weights is not None and workers > 1:
        w = np.asarray(worker_weights, np.float64)
        if w.shape != (workers,):
            raise ValueError(
                f"worker_weights must have shape ({workers},), got {w.shape}")
        if not np.all(np.isfinite(w)) or np.any(w < 0) or w.sum() <= 0:
            raise ValueError(
                "worker_weights must be finite, nonnegative, with a "
                "positive sum")
        targets = _weighted_block_targets(w, nb)
        nb_per = max(int(targets.max()), 1)
        nb_per = -(-nb_per // merge_every) * merge_every
        packed = _pad_block_stack(packed, nb_per * workers)
        perm = _biased_perm(targets, nb, nb_per, shuffle_rng)
    else:
        # blocks per worker, rounded up to whole merge groups
        nb_per = -(-nb // workers)
        nb_per = -(-nb_per // merge_every) * merge_every
        packed = _pad_block_stack(packed, nb_per * workers)
        total = nb_per * workers
        perm = (shuffle_rng.permutation(total) if shuffle_rng is not None
                else None)

    def shard(x):
        if perm is not None:
            x = x[perm]
        return jnp.asarray(x.reshape((workers, nb_per) + x.shape[1:]))

    fn = _parallel_scan_fn(devices, k, merge_every, use_kernel, interpret,
                           sketch)
    _count_dispatch(count_name,
                    nbytes=int(s_masks.nbytes) + int(sizes.nbytes),
                    k=k, workers=workers, blocks=nb_per * workers)
    parts_blocks, s_out, sizes_out, pushed_words = fn(
        shard(packed.valid), shard(packed.widx), shard(packed.vals),
        shard(packed.trunc), shard(packed.tr_ids), shard(packed.tr_masks),
        s_masks, sizes)
    W = packed.tr_masks.shape[-1]
    n_super = nb_per // merge_every
    traffic = {
        "pushed_bytes": 4 * int(pushed_words),
        "pulled_bytes": 4 * workers * n_super * k * W,
        "tasks": workers * n_super,
        "stale_pushes_missed": n_super * workers * (workers - 1),
    }
    return parts_blocks, s_out, sizes_out, traffic, perm


def parallel_blocked_partition_u_impl(
    graph: BipartiteGraph,
    k: int,
    workers: int = 4,
    block: int = 256,
    merge_every: int = 1,
    init_sets: np.ndarray | None = None,
    use_kernel: bool = False,
    interpret: bool | None = None,
    seed: int = 0,
    cap: int = 48,
    devices: tuple | None = None,
    as_numpy: bool = True,
    timings: dict | None = None,
    sketch: bool = False,
) -> tuple[np.ndarray, np.ndarray, dict]:
    """Device-parallel Algorithm 4: shard_map multi-worker Parsa.

    The permuted U is packed once (same permutation as ``device_scan``) and
    split into ``workers`` contiguous shards of whole blocks; one jitted
    shard_map dispatch runs every worker's blocked scan and all the
    periodic OR-merges.  With ``workers=1`` the schedule collapses to the
    sequential ``device_scan`` pipeline bit-for-bit (the merge is the
    identity), for any ``merge_every``.

    Balance: every worker enforces §4.1 perfect balance against its *stale*
    view of the global sizes, so when a merge lands with uneven sizes
    (possible whenever k ∤ |U|) each worker independently applies the same
    catch-up and the corrections overlap — global ``max|U_i| − min|U_i|``
    is bounded by ``workers`` (exactly ≤ 1 at workers=1), a ≤ W/⌈|U|/k⌉
    relative slack on objective (4).  This is the BSP analogue of the
    staleness-induced quality slack of §5.4.

    Returns (parts_u, final packed s_masks, traffic dict).  Traffic units
    are bitmask-word bytes (4 bytes per 32 parameters): each worker pulls
    the full packed (k, W) set at every merge and pushes only its changed
    words (delta encoding); ``stale_pushes_missed`` counts the peer pushes
    in flight during each worker's local phase — W−1 peers per worker per
    merge round.
    """
    if workers < 1:
        raise ValueError(f"workers must be >= 1, got {workers}")
    if merge_every < 1:
        raise ValueError(f"merge_every must be >= 1, got {merge_every}")
    devices = resolve_worker_devices(workers, devices)  # before the pack
    t_pack = time.perf_counter()
    W = (graph.num_v + 31) // 32
    if init_sets is None:
        s_masks = jnp.zeros((k, W), jnp.int32)
    else:
        s_masks = jnp.asarray(coerce_packed_sets(init_sets, graph.num_v))
    sizes = jnp.zeros((k,), jnp.int32)
    rng = np.random.default_rng(seed)
    order = rng.permutation(graph.num_u)
    packed = pack_graph_blocks(graph, block, order=order, cap=cap)
    if timings is not None:
        timings["pack"] = time.perf_counter() - t_pack
    parts_blocks, s_out, _, traffic, _ = _run_parallel_packed_scan(
        packed, s_masks, sizes, k=k, workers=workers,
        merge_every=merge_every, use_kernel=use_kernel, interpret=interpret,
        devices=devices, sketch=sketch)
    if not as_numpy:
        flat = parts_blocks.reshape(-1)[: graph.num_u]
        parts = jnp.zeros((graph.num_u,), jnp.int32).at[
            jnp.asarray(order)].set(flat)
        return parts, s_out, traffic
    flat = np.asarray(parts_blocks).reshape(-1)[: graph.num_u]
    parts = np.full(graph.num_u, -1, np.int32)
    parts[order] = flat
    return parts, np.asarray(s_out), traffic


def shard_parsa_step(k: int, axis: str = "data", use_kernel: bool = False,
                     select: str = "rounds", interpret: bool | None = None):
    """Return a shard_map-able body: (local packed block stack, S, sizes) →
    assignment.

    Each device processes its (n_blocks, B, …) stack (from
    ``pack_graph_blocks`` on its U-shard) against its local S copy, then
    merges S across ``axis`` by all_gather + OR and sizes by psum — one
    Alg 4 round with τ = n_blocks − 1.

    ``select="rounds"`` uses the balanced-rounds pipeline (fused
    cost+select; exact vs the sequential loop while global sizes differ by
    ≤ 1, and a balanced approximation thereof once cross-device psums widen
    the gap).  ``select="seq"`` keeps the per-vertex reference loop.
    """

    def body(valid: jax.Array, widx: jax.Array, vals: jax.Array,
             trunc: jax.Array, tr_ids: jax.Array, tr_masks: jax.Array,
             s_masks: jax.Array, sizes: jax.Array):
        def per_block(carry, xs):
            s_masks, sizes = carry
            val, wi, va, tr, ti, tm = xs
            if select == "rounds":
                parts, s_masks, sizes = _assign_block_rounds(
                    val, wi, va, tr, ti, tm, s_masks, sizes,
                    k=k, use_kernel=use_kernel, interpret=interpret)
            else:
                parts, s_masks, sizes = _assign_block(
                    _rebuild_nbr(wi, va, ti, tm), s_masks, sizes, val,
                    k=k, use_kernel=use_kernel, interpret=interpret)
            return (s_masks, sizes), parts

        (s_masks, sizes), parts = jax.lax.scan(
            per_block, (s_masks, sizes),
            (valid, widx, vals, trunc, tr_ids, tr_masks))
        # server union-push: OR-merge neighbor sets across the data axis
        gathered = jax.lax.all_gather(s_masks, axis)  # (n_dev, k, W)
        merged = jax.lax.reduce(
            gathered, jnp.int32(0), jax.lax.bitwise_or, dimensions=(0,)
        )
        sizes = jax.lax.psum(sizes, axis)
        return parts, merged, sizes

    return body
