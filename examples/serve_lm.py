"""Batched greedy decoding through the serving path (KV cache / SSM state),
for any of the 10 architectures.

    PYTHONPATH=src python examples/serve_lm.py --arch zamba2-2.7b
"""
import argparse

from repro.launch import serve as serve_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x22b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()
    serve_mod.main(["--arch", args.arch, "--reduce", "--batch",
                    str(args.batch), "--prompt-len", "12", "--gen",
                    str(args.gen)])


if __name__ == "__main__":
    main()
