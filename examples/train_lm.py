"""Train a small LM end-to-end through the full framework path (config →
model → AdamW → data pipeline → checkpointed TrainLoop), with optional
Parsa-placed embedding data sharding.

Any of the 10 architectures works via --arch; default trains a reduced
qwen3-family model for a few hundred steps on CPU.

    PYTHONPATH=src python examples/train_lm.py --arch qwen3-14b --steps 200
"""
import argparse
import sys

from repro.launch import train as train_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-14b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()
    hist = train_mod.main([
        "--arch", args.arch, "--reduce", "--steps", str(args.steps),
        "--batch", str(args.batch), "--seq", str(args.seq),
        "--ckpt-dir", "/tmp/repro_example_lm", "--log-every", "20",
    ])
    assert hist and hist[-1]["loss"] < hist[0]["loss"], "loss must decrease"
    print("OK: loss decreased over training")


if __name__ == "__main__":
    main()
