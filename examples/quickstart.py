"""Quickstart: the whole Parsa pipeline is ONE call now.

``repro.api.partition(graph, ParsaConfig(...))`` partitions U (Algorithm
3/4), refines V (Algorithm 2), and measures all three paper objectives —
returning a single ``PartitionResult``.  Swap the ``backend`` field to move
the same workload between the sequential reference (``host``), the
device-resident blocked scan (``device_scan``), the simulated
parameter-server run (``parallel_sim``), and the real shard_map multi-
worker partitioner (``parallel_device``); nothing else changes.

    PYTHONPATH=src python examples/quickstart.py
    # multi-worker parallel_device on a CPU host:
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses
import pathlib

import jax
import numpy as np

from repro.api import ParsaConfig, partition
from repro.core import evaluate, improvement, random_parts
from repro.graphs import text_like

k = 16
print("building a documents × vocabulary bipartite graph ...")
g = text_like(num_docs=2000, vocab=6000, mean_len=50, seed=0)
print(f"  |U|={g.num_u} docs  |V|={g.num_v} vocab  |E|={g.num_edges} edges")

cfg = ParsaConfig(k=k, backend="host", blocks=8, init_iters=8, seed=0)
print(f"running Parsa via repro.api.partition ({cfg.backend} backend, "
      f"b={cfg.blocks} subgraphs, a={cfg.init_iters} init iterations, k={k}) ...")
res = partition(g, cfg)   # one call: partition U, refine V, measure

m = res.metrics
mr = evaluate(g, random_parts(g.num_u, k, 0), random_parts(g.num_v, k, 1), k)

print("\nobjective             parsa      random   improvement")
for name, a, b in [
    ("(4) max |U_i|      ", m.size_max, mr.size_max),
    ("(6) max |N(U_i)|   ", m.mem_max, mr.mem_max),
    ("(7) max traffic    ", m.traffic_max, mr.traffic_max),
    ("    total traffic  ", m.traffic_sum, mr.traffic_sum),
]:
    print(f"{name}  {a:8d}  {b:8d}   {improvement(b, a):6.0f}%")
print("\n(improvement = (random − parsa)/parsa × 100%, as in the paper §5.1;")
print(" the paper's CTR runs cut inter-machine traffic by >90%)")

print("\nphase timings:",
      {name: f"{dt * 1e3:.1f}ms" for name, dt in res.timings.items()})

# the fully device-resident pipeline: partition U on device (one scan
# dispatch), refine V on device (Algorithm 2 over packed words), measure on
# device (popcount reductions) — no host round trip between phases, and
# per-phase wall clocks in res.timings ("pack" is the host-side bitmask
# packing, split out so "partition_u" is the scan alone).  A single cold
# call includes jit compilation; steady-state numbers live in
# benchmarks/bench_fig10_scalability.run_acceptance() → BENCH_pipeline.json.
cfg_dev = ParsaConfig(k=k, backend="device_scan", refine_backend="device",
                      seed=0)
res_dev = partition(g, cfg_dev)
assert res_dev.metrics.as_dict() == partition(
    g, cfg_dev.replace(refine_backend="host")).metrics.as_dict()
print("\ndevice-resident pipeline (device_scan + device refine/metrics, "
      "bit-identical):")
print("  phase timings:",
      {name: f"{dt * 1e3:.1f}ms" for name, dt in res_dev.timings.items()})

# warm-start / incremental repartitioning: tomorrow's graph reuses today's
# neighbor sets with one method call (§4.4 incremental mode).
g2 = text_like(num_docs=2000, vocab=6000, mean_len=50, seed=1)
res2 = res.refine(g2)
print(f"\nincremental repartition of a fresh graph via res.refine(): "
      f"max traffic {res2.metrics.traffic_max} "
      f"(cold: {partition(g2, cfg).metrics.traffic_max})")

# the distributed partitioner (Algorithm 4 on shard_map): W workers run the
# blocked bitmask scan concurrently, one per device, OR-merging their
# neighbor sets every `merge_every` blocks.  One worker per visible device.
W = min(8, len(jax.devices()))
cfg_par = ParsaConfig(k=k, backend="parallel_device", workers=W,
                      merge_every=2, seed=0)
res_par = partition(g, cfg_par)
t = res_par.traffic
print(f"\nparallel_device backend ({W} worker{'s' if W > 1 else ''}): "
      f"max traffic {res_par.metrics.traffic_max}, "
      f"partition_u {res_par.timings['partition_u'] * 1e3:.0f}ms, "
      f"PS traffic pushed/pulled {t.pushed_bytes}/{t.pulled_bytes} bytes")
if W == 1:
    print("  (single device — set "
          "XLA_FLAGS=--xla_force_host_platform_device_count=8 for a real "
          "multi-worker run)")

# --------------------------------------------------------------------------
# sketched server sets: partition at a width the exact path cannot allocate
# (repro.sketch).  Every packed structure — server sets, need words, the
# parallel workers' stale copies — is O(k·|V|/32); at the paper's CTR scale
# (|V| ~ 10^8) that is tens of GB of live set structures plus a transpose
# side channel in the V-refine measured in terabytes.  set_repr="sketch"
# maps the 10^8 columns into hot exact slots (top features by footprint)
# plus hashed buckets for the cold tail; the SAME packed-uint32 pipeline
# then runs at the sketched width, and parts_v is expanded back to all
# 10^8 features at the end.
from repro.sketch import set_structure_bytes

NUM_V_HUGE = 100_000_000
print(f"\nsketched sets: {NUM_V_HUGE:,} features (the paper's CTR scale)")
rng_s = np.random.default_rng(0)
rows_s, hot_s, tail_s = 20_000, 100_000, NUM_V_HUGE
cols = np.where(rng_s.random((rows_s, 12)) < 0.7,
                rng_s.zipf(1.3, (rows_s, 12)) % hot_s,     # hot Zipf head
                rng_s.integers(0, tail_s, (rows_s, 12)))   # long cold tail
from repro.core.bipartite import from_edges
g_huge = from_edges(rows_s, NUM_V_HUGE,
                    np.repeat(np.arange(rows_s), 12), cols.reshape(-1))
cfg_sk = ParsaConfig(k=k, backend="device_scan", set_repr="sketch",
                     sketch_hot_bits=16_384, sketch_bucket_bits=16_384,
                     refine_backend="device", seed=0)
exact_b = set_structure_bytes(NUM_V_HUGE, k, cfg_sk.block_size)
res_sk = partition(g_huge, cfg_sk)
sk = res_sk.sketch
print(f"  exact-mode set structures would need {exact_b / 2**30:.1f} GiB "
      f"(plus a ~TB-scale refine transpose) — never allocated")
print(f"  sketch width {sk.width_bits:,} bits -> "
      f"{sk.mem_bytes(k, cfg_sk.block_size) / 2**20:.1f} MiB "
      f"({exact_b / sk.mem_bytes(k, cfg_sk.block_size):.0f}x smaller), "
      f"traffic_max {res_sk.metrics.traffic_max}")
print(f"  parts_v covers all {res_sk.parts_v.size:,} true features "
      f"(hot exact, cold tail co-located by hash); "
      f"total {res_sk.timings['total']:.1f}s on this host")
print("(hot prefix >= |V| is bit-identical to the exact pipeline — "
      "regression-tested; acceptance gates: benchmarks/bench_sketch.py "
      "--acceptance)")

# --------------------------------------------------------------------------
# streaming: partition a graph that GROWS over time (repro.stream).
# Examples arrive continuously in production (ad impressions, social
# edges); a StreamSession keeps the packed server sets live on device and
# assigns each arriving chunk with ONE scan dispatch against them —
# O(chunk) work instead of repartitioning everything from scratch.  A
# sliding-window drift tracker watches the popcount objectives and, when
# the arriving distribution has drifted enough to decay the partition,
# triggers a full repartition that is matched back onto the old labels
# (minimal migration, metered in bytes).
from repro.api import ParsaStreamConfig, StreamSession
from repro.graphs import ctr_like_stream

print("\nstreaming: 6 chunks of drifting CTR-like traffic "
      "(campaign churn) ...")
chunks = ctr_like_stream(3000, 6000, chunks=6, nnz_per_row=20, churn=0.5,
                         seed=0)
scfg = ParsaStreamConfig(
    base=ParsaConfig(k=k, backend="device_scan", refine_v=False, seed=0),
    drift_threshold=1.02)     # repartition on >2% imbalance degradation
session = StreamSession(scfg, num_v=6000)
for chunk in chunks:
    upd = session.feed(chunk)   # ONE jitted scan against the live sets
    note = ""
    if upd.repartitioned:
        note = (f"  <- drift repair: {upd.migration.moved_u} examples "
                f"migrated, {upd.migration.traffic.pushed_bytes} bytes")
    print(f"  chunk {upd.chunk}: +{upd.u_stop - upd.u_start} examples, "
          f"traffic_max {upd.metrics.traffic_max}, "
          f"feed {upd.timings['total'] * 1e3:.0f}ms{note}")
res_stream = session.result(refine_v=True)   # a full PartitionResult
print("final streamed partition:", res_stream.metrics.as_dict())
print("(one-chunk feeds are bit-identical to the device_scan backend; "
      "see benchmarks/bench_stream.py)")

# --------------------------------------------------------------------------
# elastic serving: the machine count k is a RUNTIME VARIABLE (repro.elastic).
# Fleets are not static — capacity arrives mid-stream, machines die, some
# straggle.  An ElasticSession wraps the stream and composes the pieces:
# grow_k splits the largest part with one jitted scan, repair survives a
# machine loss by warm-starting §4.4 from the SURVIVING packed sets (the
# lost part's vertices re-assigned in one dispatch — no cold repartition),
# and a seeded ChaosSchedule replays the same disaster deterministically.
# Every move is metered in TrafficCounters.migration_bytes and gated by an
# ElasticPolicy that weighs the one-time cost against steady-state savings.
from repro.api import (ChaosEvent, ChaosSchedule, ElasticConfig,
                       ElasticSession)

print("\nelastic: grow the fleet 8->12 mid-stream, then lose a machine ...")
chunks = ctr_like_stream(3000, 6000, chunks=6, nnz_per_row=20, churn=0.5,
                         seed=0)
ecfg = ElasticConfig(stream=ParsaStreamConfig(
    base=ParsaConfig(k=8, backend="device_scan", refine_v=False, seed=0),
    repartition="never"))
chaos = ChaosSchedule([
    ChaosEvent(feed=1, kind="add"),        # four machines join ...
    ChaosEvent(feed=2, kind="add"),
    ChaosEvent(feed=3, kind="add"),
    ChaosEvent(feed=4, kind="add"),
    ChaosEvent(feed=5, kind="kill"),       # ... then one dies (seeded pick)
], seed=0)
es = ElasticSession(ecfg, num_v=6000, chaos=chaos)
for chunk in chunks:
    upd = es.feed(chunk)                   # chaos events apply, then feed
    print(f"  chunk {upd.chunk}: k={es.k}, "
          f"traffic_max {upd.metrics.traffic_max}, migration so far "
          f"{es.traffic.migration_bytes} bytes")
for op in es.ops:
    what = f"{op.kind}{' (' + op.mode + ')' if op.mode else ''}"
    print(f"  {what}: k {op.k_before}->{op.k_after}, moved {op.moved_u} "
          f"examples, {op.traffic.migration_bytes} migration bytes in "
          f"{op.seconds * 1e3:.0f}ms")
print("(warm repair re-assigns only the lost part's vertices — one scan "
      "dispatch, ~10x faster than a cold repartition of the whole stream; "
      "see benchmarks/bench_chaos.py --acceptance)")

# --------------------------------------------------------------------------
# serving: turn the traffic cut into a measured end-to-end speedup
# (repro.serving).  A ServingEngine drives k PSCluster shards through
# batched pull -> compute -> push requests for a Zipf-skewed tenant mix;
# async mode double-buffers the next request's pull behind the current
# compute (τ=1 bounded staleness), and every modeled byte becomes real
# wall-clock through the bandwidth model — so tokens/s and p99 below are
# measured, not derived from byte counts.
from repro.api import (PSRequestSource, RequestMix, ServingConfig,
                       ServingEngine, ZipfWorkload)
from repro.core import random_parts
from repro.graphs import ctr_like
from repro.ml import DBPGConfig, PSCluster

print("\nserving: random vs Parsa placement under a Zipf request mix ...")
g_srv = ctr_like(num_impressions=3000, num_features=5000, nnz_per_row=20,
                 clusters=24, locality=0.85, seed=0)
res_srv = partition(g_srv, ParsaConfig(k=8, backend="device_scan",
                                       refine_backend="device", seed=0))
labels = np.where(np.random.default_rng(0).random(g_srv.num_u) < 0.5,
                  1.0, -1.0).astype(np.float32)
mix = RequestMix((ZipfWorkload("text", batch=96, zipf_s=1.1),
                  ZipfWorkload("ctr", batch=48, zipf_s=1.3,
                               hot_offset=777, weight=0.5)))
dcfg = DBPGConfig(lam=0.05, lr=0.1, kkt_eps=0.0, compress=False,
                  error_feedback=False)
for name, (pu, pv) in [
    ("random", (random_parts(g_srv.num_u, 8, 0),
                random_parts(g_srv.num_v, 8, 1))),
    ("parsa", (np.asarray(res_srv.parts_u), np.asarray(res_srv.parts_v))),
]:
    cluster = PSCluster(g_srv, labels, pu, pv, 8, dcfg, bandwidth=2.5e5)
    cluster.commit_weights(np.random.default_rng(1).normal(
        0, 0.1, g_srv.num_v).astype(np.float32))   # serve a trained model
    engine = ServingEngine(PSRequestSource(
        cluster, mix, ServingConfig(prefetch=True, warmup=16, seed=0)))
    s = engine.run(46)
    print(f"  {name:6s} async: {s['tokens_s']:8.0f} tokens/s  "
          f"{s['examples_s']:7.0f} examples/s  p99 {s['p99_ms']:.1f}ms  "
          f"(pull inter {s['pull_inter_bytes']} B, "
          f"{s['hidden_s'] * 1e3:.0f}ms of wire hidden behind compute)")
print("(full {random,parsa} x {sync,async} grid with acceptance gates: "
      "benchmarks/bench_system.py --acceptance -> BENCH_system.json)")

# --------------------------------------------------------------------------
# closed loop: hold a p99 SLO through chaos (repro.elastic.SLOAutoscaler).
# The serving source keeps a deterministic virtual clock (requests arrive
# every service_model_s; every pull/push books a virtual per-machine NIC),
# a TelemetryBus windows the modeled latencies, and every decide_every
# slots the autoscaler reads a snapshot: grow on sustained p99-over-SLO
# (splitting the hottest part by live footprint), shrink when cold, warm
# repair immediately on circuit-open, straggler-bias the router on EWMA
# drift.  Under overload the engine degrades gracefully instead of falling
# over: per-home admission control sheds lowest-weight tenants first.
from repro.api import Observability, SLOAutoscaler, SLOConfig, prometheus_text
from repro.runtime import RetryPolicy

print("\nclosed loop: a load burst + a machine kill, static k=8 vs "
      "autoscaled ...")
SLO_MS = 30.0
chaos_events = [
    ChaosEvent(feed=32, kind="burst", factor=2.5),    # traffic 2.5x
    ChaosEvent(feed=160, kind="burst", factor=1.0),   # ... and back
    ChaosEvent(feed=200, kind="kill", machine=3),     # then a shard dies
]
slo_cfg = SLOConfig(slo_ms=SLO_MS, window_requests=16, decide_every=16,
                    warmup_windows=2, patience=1, cooldown_windows=0,
                    shrink_patience=3, shrink_p99_frac=0.5,
                    shrink_occupancy_s=0.015, min_k=8, max_k=14,
                    drift_ratio=2.0, tau_escalation=4)
serve_kw = dict(prefetch=True, warmup=16, seed=0, bandwidth=6e4,
                service_model_s=2e-3, window_requests=16,
                retry=RetryPolicy(timeout_s=0.004, retries=0))
for name, autoscale in [("static k=8", False), ("autoscaled", True)]:
    cluster = PSCluster(g_srv, labels, np.asarray(res_srv.parts_u),
                        np.asarray(res_srv.parts_v), 8, dcfg,
                        bandwidth=serve_kw["bandwidth"])
    cluster.commit_weights(np.random.default_rng(1).normal(
        0, 0.1, g_srv.num_v).astype(np.float32))
    obs = Observability() if autoscale else None   # traced pass, see below
    asc = SLOAutoscaler(dataclasses.replace(slo_cfg, obs=obs))
    elastic = None
    if autoscale:
        elastic = ElasticSession(ElasticConfig(
            stream=ParsaStreamConfig(base=ParsaConfig(
                k=8, backend="device_scan", refine_v=False, seed=0),
                repartition="never"),
            min_k=slo_cfg.min_k, max_k=slo_cfg.max_k),
            num_v=g_srv.num_v, policy=asc)
        elastic.feed(g_srv)
        cluster.apply_placement(elastic.parts.copy(),
                                np.asarray(res_srv.parts_v))
    src = PSRequestSource(
        cluster, mix,
        ServingConfig(max_backlog_s=0.025 if autoscale else None,
                      tau_escalation=slo_cfg.tau_escalation, obs=obs,
                      **serve_kw),
        chaos=ChaosSchedule(list(chaos_events), seed=0),
        elastic=elastic, autoscaler=asc)
    engine = ServingEngine(src)
    s = engine.run(256)
    windows = asc.decisions[slo_cfg.warmup_windows:]
    hold = sum(snap.p99_ms <= SLO_MS for snap, _ in windows) / len(windows)
    peak = max(snap.p99_ms for snap, _ in windows)
    ops = ([f"{op.kind} k{op.k_before}->{op.k_after}"
            for op in elastic.ops if op.committed] if elastic else [])
    print(f"  {name:11s}: held p99<={SLO_MS:.0f}ms in {hold:5.1%} of "
          f"windows, peak window p99 {peak:6.1f}ms, shed "
          f"{s['shed_requests']:2d}" + (f"  ops: {', '.join(ops)}"
                                        if ops else ""))
print("(every decision is recorded with its telemetry snapshot and the "
      "seeded chaos replay is bit-deterministic; acceptance gates: "
      "benchmarks/bench_slo.py --acceptance -> BENCH_system.json slo_rows)")

# --------------------------------------------------------------------------
# observability: the autoscaled run above was fully traced (repro.obs).
# One Observability handle threads through every layer as the single obs=
# hook (ServingConfig.obs / SLOConfig.obs / StreamSession / ElasticSession):
# the tracer emits nested virtual-clock spans (request -> pull/wire/retry/
# queue -> compute -> push, elastic ops -> plan/scan/migrate, feeds ->
# pack/scan/merge) on the same deterministic clock the engine models, and
# the flight recorder correlates chaos events, window verdicts, breaker
# trips and elastic ops on one slot timeline — so recorder.explain(window)
# answers "WHY did this window violate the SLO" from the recording alone.
# Off by default: with obs=None every hook is a single attribute check.
out_dir = pathlib.Path(__file__).resolve().parent / "out"
paths = obs.save(out_dir, prefix="quickstart")
print(f"\nobservability: {len(obs.tracer.spans)} virtual-clock spans, "
      f"{len(obs.recorder)} recorded facts from the autoscaled run")
print(f"  Perfetto trace -> {paths['trace']}  (open in ui.perfetto.dev)")
print(f"  flight recorder -> {paths['events']}")

violated = [i for i, (snap, _) in enumerate(asc.decisions)
            if i >= slo_cfg.warmup_windows and snap.p99_ms > SLO_MS]
print(f"  {len(violated)} post-warmup windows violated the SLO; "
      f"asking the flight recorder why:")
for i in violated[:2]:
    print("    " + str(obs.explain(i)).replace("\n", "\n    "))

metrics = prometheus_text(latency=engine.recorder, telemetry=src.telemetry,
                          traffic=elastic.traffic, meter=cluster.meter)
lines = metrics.splitlines()
n_fams = sum(ln.startswith("# TYPE") for ln in lines)
n_samples = sum(bool(ln) and not ln.startswith("#") for ln in lines)
print(f"  prometheus snapshot: {n_samples} samples across {n_fams} "
      f"metric families, e.g.")
for ln in lines:
    if ln.startswith("parsa_telemetry_p99_ms"):
        print(f"    {ln}")
print("(the seeded replay exports byte-identical traces and event streams "
      "— gated in tests/test_obs.py and benchmarks/bench_slo.py)")
