"""Quickstart: partition a synthetic doc×vocab graph with Parsa, inspect all
three paper objectives, and compare to random placement.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (
    evaluate, improvement, partition_v, random_parts, sequential_parsa,
)
from repro.graphs import text_like

k = 16
print("building a documents × vocabulary bipartite graph ...")
g = text_like(num_docs=2000, vocab=6000, mean_len=50, seed=0)
print(f"  |U|={g.num_u} docs  |V|={g.num_v} vocab  |E|={g.num_edges} edges")

print(f"running Parsa (b=8 subgraphs, a=8 init iterations, k={k}) ...")
parts_u = sequential_parsa(g, k, b=8, a=8, seed=0)
parts_v = partition_v(g, parts_u, k, sweeps=2)
m = evaluate(g, parts_u, parts_v, k)

mr = evaluate(g, random_parts(g.num_u, k, 0), random_parts(g.num_v, k, 1), k)

print("\nobjective             parsa      random   improvement")
for name, a, b in [
    ("(4) max |U_i|      ", m.size_max, mr.size_max),
    ("(6) max |N(U_i)|   ", m.mem_max, mr.mem_max),
    ("(7) max traffic    ", m.traffic_max, mr.traffic_max),
    ("    total traffic  ", m.traffic_sum, mr.traffic_sum),
]:
    print(f"{name}  {a:8d}  {b:8d}   {improvement(b, a):6.0f}%")
print("\n(improvement = (random − parsa)/parsa × 100%, as in the paper §5.1)")
