"""End-to-end driver (the paper's own application, §5.5): distributed
ℓ1-regularized logistic regression with DBPG on a parameter-server layout,
Parsa vs random placement, exact traffic metering + modeled wall-clock.

    PYTHONPATH=src python examples/train_l1lr.py [--iters 45] [--k 16]
"""
import argparse

from repro.api import ParsaConfig, partition
from repro.core import random_parts
from repro.graphs import ctr_like
from repro.ml import DBPGConfig, PSCluster, make_problem


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--iters", type=int, default=30)
    ap.add_argument("--k", type=int, default=16)
    ap.add_argument("--rows", type=int, default=1200)
    ap.add_argument("--features", type=int, default=5000)
    args = ap.parse_args()
    k = args.k

    print("generating CTR-like training data ...")
    g = ctr_like(args.rows, args.features, nnz_per_row=25, seed=5)
    w_star, labels = make_problem(g, seed=5)
    print(f"  {g.num_u} examples × {g.num_v} features, {g.num_edges} nnz")

    print("Parsa-partitioning data + parameters (4 workers, τ=∞) ...")
    parsa = partition(g, ParsaConfig(
        k=k, backend="parallel_sim", blocks=8, workers=4, tau=None,
        global_init_frac=0.01, seed=0, refine_v=True, sweeps=2))

    cfg = DBPGConfig(lam=0.3, lr=0.005, max_delay=1)
    for name in ("random", "parsa"):
        if name == "parsa":
            cl = PSCluster.from_partition(g, labels, parsa, cfg, seed=1)
        else:
            cl = PSCluster(g, labels, random_parts(g.num_u, k, 0),
                           random_parts(g.num_v, k, 1), k, cfg, seed=1)
        res = cl.run(args.iters, log_every=max(args.iters // 5, 1))
        print(f"\n[{name}] after {args.iters} DBPG iterations:")
        print(f"  objective      : {res['objective'][0]:.1f} -> {res['objective'][-1]:.1f}")
        print(f"  nnz(w)         : {res['nnz_w']}")
        print(f"  inner-machine  : {res['inner_bytes']/1e6:.2f} MB")
        print(f"  inter-machine  : {res['inter_bytes']/1e6:.2f} MB")
        print(f"  local fraction : {res['inner_fraction']*100:.0f}%")
        print(f"  modeled time   : {res['modeled_time_s']*1e3:.2f} ms")


if __name__ == "__main__":
    main()
