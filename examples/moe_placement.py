"""Parsa expert placement for MoE serving (DESIGN §3.2): build the
token-group × expert affinity graph from measured routing counts of a
reduced deepseek-family model, then place experts to shrink the all-to-all.

``build_expert_placement`` runs the partition through the unified
``repro.api.partition()`` facade (host backend by default — pass
``backend=`` to move it on-device).

    PYTHONPATH=src python examples/moe_placement.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.moe_placement import alltoall_traffic, build_expert_placement
from repro.models.model import build_model
from repro.models.moe import apply_moe

cfg = get_config("deepseek-v2-236b").reduced(num_experts=16,
                                             num_experts_per_tok=4)
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
k = 4

print("collecting routing statistics from the reduced model ...")
rng = np.random.default_rng(0)
groups = []
moe_params = jax.tree.map(lambda a: a[0], params["stack"])["moe"]
# token groups come from a handful of domains (code/news/dialog/...): groups
# of the same domain route to the same expert family — the structure Parsa
# exploits.  6 domains × ~5 groups each.
domains = rng.normal(0, 1, (6, cfg.d_model)) * 2.5
for g in range(32):
    center = domains[g % 6]
    x = jnp.asarray(center + rng.normal(0, 0.25, (1, 16, cfg.d_model)),
                    jnp.float32)
    _, aux = apply_moe(moe_params, x, cfg, dtype=jnp.float32, return_aux=True)
    groups.append(np.asarray(aux["expert_counts"]))
counts = np.stack(groups)
print(f"  routing matrix: {counts.shape} (groups × experts)")

pl = build_expert_placement(counts, k)
t = alltoall_traffic(counts, pl)
print(f"\nall-to-all crossing tokens, round-robin experts: "
      f"{t['crossing_tokens_roundrobin']}")
print(f"all-to-all crossing tokens, Parsa placement   : "
      f"{t['crossing_tokens_parsa']}")
print(f"reduction: {t['reduction']*100:.0f}%")
print(f"expert→shard: {pl.expert_to_shard.tolist()}")
