"""Parsa → LM integration: embedding placement, MoE expert placement,
Parsa-sharded data pipeline."""
import numpy as np
import pytest

from repro.core.moe_placement import alltoall_traffic, build_expert_placement
from repro.core.placement import build_placement, gather_traffic
from repro.data import ParsaShardedData
from repro.graphs import text_like


@pytest.fixture(scope="module")
def doc_graph():
    return text_like(320, 800, mean_len=25, seed=13)


def test_placement_structure(doc_graph):
    k = 8
    pl = build_placement(doc_graph, k, b=4, a=2)
    assert pl.doc_to_shard.shape == (doc_graph.num_u,)
    assert pl.vocab_to_shard.shape == (doc_graph.num_v,)
    assert (pl.vocab_to_shard >= 0).all()
    # permutation is a bijection and groups shards contiguously
    assert np.array_equal(np.sort(pl.vocab_perm), np.arange(doc_graph.num_v))
    bounds = np.cumsum(pl.shard_row_counts)
    new_pos = pl.vocab_perm
    for i in range(k):
        lo = 0 if i == 0 else bounds[i - 1]
        rows = np.flatnonzero(pl.vocab_to_shard == i)
        assert np.all((new_pos[rows] >= lo) & (new_pos[rows] < bounds[i]))


def test_placement_beats_random(doc_graph):
    k = 8
    parsa = gather_traffic(doc_graph, build_placement(doc_graph, k, b=4, a=2))
    rand = gather_traffic(doc_graph, build_placement(doc_graph, k, method="random"))
    assert parsa["local_fraction"] > rand["local_fraction"]
    assert parsa["remote_rows_sum"] < rand["remote_rows_sum"]


def test_expert_placement_reduces_alltoall():
    rng = np.random.default_rng(0)
    groups, experts, k = 64, 32, 8
    # clustered routing: group g prefers experts around (g mod experts)
    counts = np.zeros((groups, experts), int)
    for gidx in range(groups):
        favorites = (gidx * 3 + np.arange(6)) % experts
        counts[gidx, favorites] = rng.integers(5, 50, size=6)
    pl = build_expert_placement(counts, k)
    t = alltoall_traffic(counts, pl)
    assert t["crossing_tokens_parsa"] < t["crossing_tokens_roundrobin"]
    assert 0.0 < t["reduction"] <= 1.0
    # every expert placed, k-way
    assert set(np.unique(pl.expert_to_shard)) <= set(range(k))


def test_parsa_sharded_data_shrinks_working_set(doc_graph):
    """The footprint objective (6) is a *shard-level* working-set property:
    it shows once a steady-state fraction of each shard streams through
    (tiny subsamples are dominated by per-document noise — measured in
    EXPERIMENTS.md)."""
    k = 8
    pl = build_placement(doc_graph, k, b=4, a=2)
    rnd = build_placement(doc_graph, k, method="random")
    d_parsa = ParsaShardedData(doc_graph, pl, batch=160, seq=8, seed=1)
    d_rand = ParsaShardedData(doc_graph, rnd, batch=160, seq=8, seed=1)
    ws_p = sum(d_parsa.working_set_per_shard(s).sum() for s in range(3))
    ws_r = sum(d_rand.working_set_per_shard(s).sum() for s in range(3))
    assert ws_p < ws_r
