"""PR 8 closed loop: sliding-window telemetry, circuit half-open probes,
admission control, the SLO autoscaler's decision logic, and chaos
composition through the serving engine — including bit-identical replay.
"""
import numpy as np
import pytest

from repro.api import (ChaosEvent, ChaosSchedule, ElasticConfig,
                       ElasticSession, ParsaConfig, ParsaStreamConfig)
from repro.core import random_parts
from repro.core.jax_partition import dispatch_counter
from repro.elastic import AutoscaleDecision, SLOAutoscaler, SLOConfig
from repro.elastic.policy import FleetState
from repro.graphs import ctr_like, ctr_like_stream
from repro.ml import DBPGConfig, PSCluster
from repro.runtime import CircuitBreaker, RetryPolicy
from repro.serving import (LatencyRecorder, LatencyWindow, PSRequestSource,
                           RequestMix, Router, ServingConfig, ServingEngine,
                           TelemetryBus, ZipfWorkload)
from repro.serving.latency import RequestRecord

K = 4


# -------------------------------------------------------------- fixtures
@pytest.fixture(scope="module")
def serving_graph():
    g = ctr_like(600, 1200, nnz_per_row=12, clusters=8, locality=0.85,
                 seed=0)
    labels = np.where(np.random.default_rng(0).random(g.num_u) < 0.5,
                      1.0, -1.0).astype(np.float32)
    return g, labels


def _mix():
    return RequestMix((
        ZipfWorkload("heavy", batch=24, zipf_s=1.1, weight=3.0),
        ZipfWorkload("light", batch=16, zipf_s=1.3, hot_offset=7,
                     weight=1.0),
    ))


def _session(g, k=K):
    scfg = ParsaStreamConfig(base=ParsaConfig(
        k=k, backend="device_scan", refine_v=False, seed=0))
    sess = ElasticSession(ElasticConfig(stream=scfg, min_k=2, max_k=k + 4),
                          num_v=g.num_v)
    sess.feed(g)
    return sess


def _cluster(g, labels, parts_u=None, bandwidth=2.5e5, k=K):
    if parts_u is None:
        parts_u = random_parts(g.num_u, k, 0)
    dcfg = DBPGConfig(lam=0.05, lr=0.1, kkt_eps=0.0, compress=False,
                      error_feedback=False)
    cl = PSCluster(g, labels, parts_u, random_parts(g.num_v, k, 1), k,
                   dcfg, bandwidth=bandwidth)
    cl.commit_weights(np.random.default_rng(1).normal(
        0, 0.1, g.num_v).astype(np.float32))
    return cl


def _closed_loop(g, labels, slo_cfg, chaos=None, bandwidth=2.5e5,
                 max_backlog_s=None, tau_escalation=0,
                 retry=None, seed=0):
    """A full closed-loop stack: autoscaler-owned ElasticSession feeding a
    PSRequestSource whose placement matches the session's."""
    asc = SLOAutoscaler(slo_cfg)
    scfg = ParsaStreamConfig(base=ParsaConfig(
        k=K, backend="device_scan", refine_v=False, seed=0))
    sess = ElasticSession(
        ElasticConfig(stream=scfg, min_k=slo_cfg.min_k,
                      max_k=slo_cfg.max_k),
        num_v=g.num_v, policy=asc)
    sess.feed(g)
    cluster = _cluster(g, labels, parts_u=sess.parts.copy(),
                       bandwidth=bandwidth)
    cfg = ServingConfig(
        prefetch=True, warmup=2, seed=seed, pad_multiple=512,
        retry=retry if retry is not None else RetryPolicy(
            timeout_s=0.004, retries=0),
        service_model_s=2e-3, max_backlog_s=max_backlog_s,
        tau_escalation=tau_escalation,
        window_requests=slo_cfg.window_requests)
    src = PSRequestSource(cluster, _mix(), cfg, chaos=chaos, elastic=sess,
                          autoscaler=asc)
    return ServingEngine(src), src, sess, asc


# --------------------------------------------------- LatencyWindow (ring)
def test_latency_window_cold_start_never_reads_zeros():
    w = LatencyWindow(8)
    assert w.filled == 0 and w.percentile(99) == 0.0 and w.mean() == 0.0
    w.add(10.0)
    # one observation: every percentile reduces over [10.0], not the
    # preallocated zeros (the DriftTracker lazy-seeding fix)
    assert w.percentile(1) == 10.0 and w.percentile(99) == 10.0
    assert w.mean() == 10.0 and w.filled == 1
    w.add(30.0)
    assert w.percentile(50) == 20.0 and w.filled == 2


def test_latency_window_wraparound_overwrites_oldest():
    w = LatencyWindow(4)
    for v in (1.0, 2.0, 3.0, 4.0, 100.0, 200.0):
        w.add(v)
    assert w.filled == 4 and w.total_observed == 6
    assert set(w.values()) == {3.0, 4.0, 100.0, 200.0}
    w.reset()
    assert w.filled == 0 and w.percentile(99) == 0.0
    w.add(7.0)
    assert w.values().tolist() == [7.0]
    with pytest.raises(ValueError):
        LatencyWindow(0)


def test_recorder_sliding_window_tracks_recent_not_alltime():
    rec = LatencyRecorder(window_requests=4)

    def add(step, lat, warm=False):
        rec.add(RequestRecord(
            tenant="t", step=step, home=0, examples=1, tokens=1,
            latency_s=lat, wire_s=lat, wait_s=0.0, blocked_s=0.0,
            compute_s=0.0, warmup=warm))

    add(0, 9.9, warm=True)                 # warmup: not in the window
    for i in range(4):
        add(i + 1, 1.0)
    for i in range(4):
        add(i + 5, 0.001)                  # burst long gone
    w = rec.windowed()
    assert w["requests"] == 4
    assert w["p99_ms"] == pytest.approx(1.0)   # window forgot the 1s burst
    s = rec.summary(wall_s=1.0)
    assert s["p99_window_ms"] == pytest.approx(1.0)
    assert s["p99_ms"] > 100                   # all-time p99 never recovers
    assert LatencyRecorder(window_requests=None)._win is None
    with pytest.raises(ValueError):
        LatencyRecorder().windowed()


# ----------------------------------------------- circuit half-open probe
def test_breaker_half_open_probe_closes_on_recovery():
    b = CircuitBreaker(2, cooldown_s=0.1, max_cooldown_s=1.0, seed=0)
    assert b.allow(1, now=0.0) and b.state(1) == "closed"
    assert b.record(1, delivered=False, now=0.0)      # newly opened
    assert b.state(1) == "open" and b.open_links() == (1,)
    assert not b.allow(1, now=0.05)                   # cooling down
    assert b.allow(1, now=0.11)                       # half-open probe
    assert b.state(1) == "half_open"
    assert not b.record(1, delivered=True, now=0.11)  # probe succeeded
    assert b.state(1) == "closed" and b.open_links() == ()


def test_breaker_failed_probe_backs_off_with_decorrelated_jitter():
    b = CircuitBreaker(1, cooldown_s=0.1, max_cooldown_s=0.5, seed=3)
    b.record(0, delivered=False, now=0.0)
    sleeps = []
    now = 0.0
    for _ in range(6):
        now = float(b._until[0])
        assert b.allow(0, now=now)                    # probe admitted
        b.record(0, delivered=False, now=now)         # still dead
        sleeps.append(float(b._sleep[0]))
    # every cooldown drawn from U(base, 3 x prev), capped
    assert all(0.1 <= s <= 0.5 for s in sleeps)
    assert len(set(sleeps)) > 1                       # jittered, not fixed
    # deterministic: same seed, same probe outcomes -> same draws
    b2 = CircuitBreaker(1, cooldown_s=0.1, max_cooldown_s=0.5, seed=3)
    b2.record(0, delivered=False, now=0.0)
    replay = []
    for _ in range(6):
        n2 = float(b2._until[0])
        b2.allow(0, now=n2)
        b2.record(0, delivered=False, now=n2)
        replay.append(float(b2._sleep[0]))
    assert replay == sleeps
    b.reset(0)
    assert b.state(0) == "closed" and b._sleep[0] == 0.1


def test_kill_then_recover_returns_to_direct_serving(serving_graph):
    """Regression (PR 7 suspect set): a killed-then-recovered shard used to
    stay suspect forever.  The half-open probe must rediscover the link —
    nobody tells serving the shard came back."""
    g, labels = serving_graph
    chaos = ChaosSchedule([
        ChaosEvent(feed=3, kind="kill", machine=1),
        ChaosEvent(feed=8, kind="recover", machine=1),
    ], seed=0)
    cluster = _cluster(g, labels)
    cfg = ServingConfig(prefetch=True, warmup=2, seed=0, pad_multiple=512,
                        retry=RetryPolicy(timeout_s=0.002, retries=1),
                        breaker_cooldown_s=0.004,   # 2 virtual slots
                        service_model_s=2e-3)
    src = PSRequestSource(cluster, _mix(), cfg, chaos=chaos)
    engine = ServingEngine(src)
    s = engine.run(24)
    assert (3, "kill", 1) in src.events and (8, "recover", 1) in src.events
    # the probe rediscovered the link: circuit closed, suspect cleared
    assert src.breaker.state(1) == "closed"
    assert 1 not in src.suspect and src.dead == set()
    assert s["stale_entries"] > 0            # the dead stretch served stale
    # after recovery the link delivers fresh entries again
    tail = [r for r in engine.recorder.records if r.step >= 16]
    assert all(r.stale_entries == 0 for r in tail)


# ------------------------------------------------------ admission control
def test_admission_sheds_lowest_weight_tenant_first(serving_graph):
    g, labels = serving_graph
    cluster = _cluster(g, labels)
    cfg = ServingConfig(prefetch=True, warmup=0, seed=0, pad_multiple=512,
                        service_model_s=2e-3, max_backlog_s=0.03)
    src = PSRequestSource(cluster, _mix(), cfg)
    src.vtime = 0.0
    heavy = src.next_request(0)
    light_wl = src.mix.workloads[1]
    # between the light tenant's scaled bound (0.03/3) and the heavy
    # tenant's full bound: light sheds, heavy holds out
    src.vlink.free_at[:] = 0.02
    light = heavy
    while light.tenant != "light" or heavy.tenant != "heavy":
        r = src.next_request(0)
        if r.tenant == "light":
            light = r
        else:
            heavy = r
    assert src.admit(heavy) and not src.admit(light)
    src.vlink.free_at[:] = 0.05              # past the full bound
    assert not src.admit(heavy)
    src.vlink.free_at[:] = 0.0
    assert src.admit(light) and src.admit(heavy)
    assert src.admit(light) is True          # no bound consumed by admits


def test_shed_slots_advance_the_virtual_clock(serving_graph):
    """A shed burst must drain the backlog it was shed for: shed slots are
    no-ops but the virtual clock still ticks, and every drop is metered
    against its tenant."""
    g, labels = serving_graph
    cluster = _cluster(g, labels, bandwidth=4e4)    # slow wire: backlog
    cfg = ServingConfig(prefetch=True, warmup=2, seed=0, pad_multiple=512,
                        service_model_s=1e-3, max_backlog_s=0.004,
                        window_requests=16)
    src = PSRequestSource(cluster, _mix(), cfg,
                          telemetry=TelemetryBus(K, window_requests=16))
    engine = ServingEngine(src)
    n = 40
    s = engine.run(n)
    assert s["shed_requests"] > 0
    assert s["requests"] + s["shed_requests"] == n - 2  # nothing lost
    assert s["shed_per_tenant"] == src.telemetry.shed
    assert src.telemetry.shed.get("light", 0) >= 1
    assert src.vtime == pytest.approx((n - 1) * 1e-3)   # clock never skips
    assert 0.0 < s["shed_frac"] < 1.0


# ---------------------------------------------------------- telemetry bus
def test_telemetry_bus_windows_and_snapshot_equality():
    bus = TelemetryBus(3, window_requests=8)
    for i in range(10):
        bus.observe(0.005 + i * 1e-4, 0.009,
                    src_times=np.array([1.0, 2.0, np.nan]))
    snap = bus.snapshot(step=9, occupancy=[0.1, 0.0, 0.2],
                        footprint=[10, 30, 20], sizes=[5, 5, 5],
                        open_circuits=(1,), load_factor=2.0)
    assert snap.window == 8 and snap.served == 10
    assert snap.p99_ms > snap.p50_ms > 0
    assert snap.max_occupancy == pytest.approx(0.2)
    assert snap.hot_part == 1                      # largest footprint
    assert snap.open_circuits == (1,)
    # straggler EWMA saw machine 1 at 2x machine 0's delivery time
    assert snap.speeds[1] < snap.speeds[0]
    # snapshots are tuples all the way down: equal by value
    snap2 = bus.snapshot(step=9, occupancy=[0.1, 0.0, 0.2],
                         footprint=[10, 30, 20], sizes=[5, 5, 5],
                         open_circuits=(1,), load_factor=2.0)
    assert snap == snap2
    with pytest.raises(ValueError):
        TelemetryBus(3, window_requests=0)


def test_telemetry_bus_resize_preserves_survivor_ewma():
    bus = TelemetryBus(3, window_requests=4)
    for _ in range(6):
        bus.observe(1e-3, 1e-3, src_times=np.array([1.0, 4.0, 1.0]))
    slow = bus.ewma.weights()[1]
    assert slow < 1.0
    bus.resize(4)                                  # grow: survivor history
    assert bus.k == 4
    assert bus.ewma.weights()[1] == pytest.approx(slow, rel=0.2)
    bus.observe(1e-3, 1e-3, src_times=np.array([1.0, 4.0, 1.0]))  # short
    bus.resize(2)                                  # shrink
    assert bus.ewma.weights().shape == (2,)
    bus.resize(2)                                  # no-op
    assert bus.k == 2


def test_hot_part_skips_unsplittable_parts():
    bus = TelemetryBus(3, window_requests=4)
    snap = bus.snapshot(step=0, occupancy=[0.0] * 3,
                        footprint=[50, 40, 10], sizes=[1, 8, 8])
    assert snap.hot_part == 1                      # part 0 has 1 row only


# ------------------------------------------------- autoscaler unit logic
def _snap(bus_k=4, p99=10.0, occ=0.0, k=4, speeds=None, window=8,
          sizes=None):
    bus = TelemetryBus(bus_k, window_requests=8)
    for _ in range(window):
        bus.observe(p99 * 1e-3, p99 * 1e-3)
    if speeds is not None:
        bus.ewma._ewma[:] = 0.0                   # neutral
        snap = bus.snapshot(0, [occ] * k, [10] * k,
                            sizes if sizes is not None else [8] * k)
        return snap.__class__(**{**snap.__dict__, "speeds": speeds, "k": k})
    snap = bus.snapshot(0, [occ] * k, [10] * k,
                        sizes if sizes is not None else [8] * k)
    return snap.__class__(**{**snap.__dict__, "k": k})


def _slo_cfg(**kw):
    base = dict(slo_ms=20.0, window_requests=8, decide_every=4,
                warmup_windows=1, patience=2, shrink_patience=2,
                cooldown_windows=1, shrink_p99_frac=0.4,
                shrink_occupancy_s=0.01, min_k=2, max_k=6,
                drift_ratio=2.0)
    base.update(kw)
    return SLOConfig(**base)


def test_autoscaler_patience_then_grow_targets_hot_part():
    asc = SLOAutoscaler(_slo_cfg())
    assert asc.decide(_snap(p99=30.0)).reason == "warmup"
    assert asc.decide(_snap(p99=30.0)).action == "hold"    # 1 hot window
    d = asc.decide(_snap(p99=30.0))
    assert d.action == "grow" and d.reason.startswith("p99")
    assert d.target == 0                                   # hot footprint
    assert asc.decide(_snap(p99=30.0)).reason == "cooldown"
    assert len(asc.decisions) == 4
    # an under-SLO window resets the hot streak
    assert asc.decide(_snap(p99=30.0)).action == "hold"
    assert asc.decide(_snap(p99=10.0, occ=1.0)).action == "hold"
    assert asc.decide(_snap(p99=30.0)).action == "hold"
    assert asc.decide(_snap(p99=30.0)).action == "grow"


def test_autoscaler_shrink_needs_cold_p99_and_idle_nics():
    asc = SLOAutoscaler(_slo_cfg(warmup_windows=0, cooldown_windows=0))
    assert asc.decide(_snap(p99=5.0, occ=0.0)).action == "hold"
    assert asc.decide(_snap(p99=5.0, occ=0.0)).action == "shrink"
    # busy NICs block the cold count even with a cold p99
    asc2 = SLOAutoscaler(_slo_cfg(warmup_windows=0))
    asc2.decide(_snap(p99=5.0, occ=0.5))
    asc2.decide(_snap(p99=5.0, occ=0.5))
    assert all(d.action == "hold" for _, d in asc2.decisions)


def test_autoscaler_respects_k_bounds():
    asc = SLOAutoscaler(_slo_cfg(warmup_windows=0, patience=1, max_k=4))
    assert asc.decide(_snap(p99=30.0, k=4)).action == "hold"  # at max_k
    asc2 = SLOAutoscaler(_slo_cfg(warmup_windows=0, shrink_patience=1,
                                  min_k=4))
    assert asc2.decide(_snap(p99=1.0, k=4)).action == "hold"  # at min_k


def test_autoscaler_rebalance_on_ewma_drift():
    asc = SLOAutoscaler(_slo_cfg(warmup_windows=0))
    d = asc.decide(_snap(p99=10.0, speeds=(1.2, 1.2, 1.2, 0.4)))
    assert d.action == "rebalance" and "0.40x" in d.reason
    # drift within ratio: plain hold
    d2 = asc.decide(_snap(p99=10.0, speeds=(1.1, 1.0, 1.0, 0.9)))
    assert d2.action == "hold"


def test_autoscaler_single_shot_consent():
    asc = SLOAutoscaler(_slo_cfg())
    state = FleetState(k=4, feed_index=0, sizes=np.full(4, 8),
                       footprint=np.full(4, 10))
    assert not asc.grow(state)               # nothing armed: refused
    asc.approve("grow")
    assert asc.grow(state)                   # armed: consumed
    assert not asc.grow(state)               # single shot
    asc.approve("shrink")
    assert not asc.grow(state)               # wrong action armed
    assert asc.shrink(state)
    asc.approve("grow")
    assert not asc.grow(FleetState(k=6, feed_index=0, sizes=np.full(6, 8),
                                   footprint=np.full(6, 10)))  # at max_k
    assert asc.repair(state) == "warm"
    with pytest.raises(ValueError):
        asc.approve("repair")


def test_autoscaler_note_repair_holds_cooldown():
    asc = SLOAutoscaler(_slo_cfg(warmup_windows=0, patience=1))
    asc.note_repair(_snap(), machine=2)
    assert asc.repairs[0][1] == 2
    assert asc.decide(_snap(p99=30.0)).reason == "cooldown"
    assert asc.decide(_snap(p99=30.0)).action == "grow"


def test_slo_config_validation():
    for bad in (dict(slo_ms=0.0), dict(decide_every=0), dict(patience=0),
                dict(shrink_patience=0), dict(min_k=5, max_k=4),
                dict(shrink_p99_frac=1.0), dict(drift_ratio=1.0)):
        with pytest.raises(ValueError):
            _slo_cfg(**bad)


# --------------------------------------- chaos composition (closed loop)
def test_closed_loop_repair_on_kill(serving_graph):
    """Kill with the autoscaler attached: the loop discovers the loss via
    its own breaker, repairs at end-of-slot, resets the circuit, and logs
    the repair with its triggering telemetry snapshot."""
    g, labels = serving_graph
    chaos = ChaosSchedule([ChaosEvent(feed=4, kind="kill", machine=2)],
                          seed=0)
    cfg = _slo_cfg(slo_ms=500.0, decide_every=8, warmup_windows=1)
    engine, src, sess, asc = _closed_loop(g, labels, cfg, chaos=chaos)
    v0 = src.cluster.placement_version
    with dispatch_counter() as counts:
        s = engine.run(16)
    assert src.dead == set() and 2 not in src.suspect
    assert src.breaker.state(2) == "closed"
    repairs = [op for op in sess.ops if op.kind == "repair"]
    assert len(repairs) == 1 and repairs[0].committed
    assert repairs[0].telemetry is not None
    assert repairs[0].telemetry.open_circuits == (2,)
    assert asc.repairs and asc.repairs[0][1] == 2
    assert counts["elastic_repair_scan"] == 1     # one dispatch per repair
    assert src.cluster.placement_version > v0
    assert src.router.version == src.cluster.placement_version
    assert s["requests"] == 14                    # nothing dropped


def test_closed_loop_straggle_recover_rebalances_routing(serving_graph):
    """A straggling machine shows up in the telemetry EWMA (priced wire
    times, not injected factors) and the decision hands its weight to the
    router's smooth WRR."""
    g, labels = serving_graph
    chaos = ChaosSchedule([
        ChaosEvent(feed=4, kind="straggle", machine=1, factor=8.0),
        ChaosEvent(feed=40, kind="recover", machine=1),
    ], seed=0)
    cfg = _slo_cfg(slo_ms=500.0, decide_every=8, warmup_windows=1,
                   drift_ratio=1.5)
    engine, src, sess, asc = _closed_loop(g, labels, cfg, chaos=chaos)
    engine.run(48)
    acts = [d.action for _, d in asc.decisions]
    assert "rebalance" in acts
    i = acts.index("rebalance")
    snap = asc.decisions[i][0]
    assert min(snap.speeds) == snap.speeds[1]     # EWMA fingered machine 1
    assert src.router.weights is not None
    assert np.argmin(src.router.weights) == 1     # routed away from it
    homes = [r.home for r in engine.recorder.records if r.step > 8 * (i + 1)]
    assert homes.count(1) < len(homes) / K        # fewer visits than fair


def test_closed_loop_grow_single_scan_and_tau_escalation(serving_graph):
    """A decision-window grow costs exactly ONE elastic_grow_scan dispatch
    and is followed by tau_escalation fully-stale slots (widened §4.3
    staleness while the migration settles)."""
    g, labels = serving_graph
    chaos = ChaosSchedule([ChaosEvent(feed=2, kind="burst", factor=4.0)],
                          seed=0)
    cfg = _slo_cfg(slo_ms=4.0, decide_every=8, warmup_windows=1,
                   patience=1, max_k=6)
    engine, src, sess, asc = _closed_loop(
        g, labels, cfg, chaos=chaos, bandwidth=1e5, tau_escalation=4)
    with dispatch_counter() as counts:
        engine.run(32)
    grows = [op for op in sess.ops if op.kind == "grow"]
    assert grows and all(op.committed for op in grows)
    assert counts["elastic_grow_scan"] == len(grows)
    assert sess.k > K and src.cluster.k == sess.k
    # the snapshot that triggered the grow rode along on the op
    assert grows[0].telemetry is not None
    assert grows[0].telemetry.p99_ms > cfg.slo_ms
    # tau escalation: the slots right after the commit served fully stale
    t_op = min(r.step for r in engine.recorder.records
               if r.step > 8 and r.stale_entries > 0)
    stale = [r for r in engine.recorder.records
             if t_op <= r.step < t_op + 3]
    assert stale and all(r.wire_s == 0.0 for r in stale)


def test_closed_loop_replay_is_bit_deterministic(serving_graph):
    """Same seeded chaos, two fresh stacks: identical events, ops,
    decisions and shed counts — nothing a decision reads comes from the
    wall clock (p99_measured_ms is reported but never gated)."""
    g, labels = serving_graph

    def run_once():
        chaos = ChaosSchedule([
            ChaosEvent(feed=2, kind="burst", factor=4.0),
            ChaosEvent(feed=10, kind="kill", machine=1),
            ChaosEvent(feed=20, kind="straggle", machine=2, factor=4.0),
        ], seed=0)
        cfg = _slo_cfg(slo_ms=8.0, decide_every=8, warmup_windows=1,
                       patience=1, max_k=6)
        engine, src, sess, asc = _closed_loop(
            g, labels, cfg, chaos=chaos, bandwidth=1e5,
            max_backlog_s=0.02, tau_escalation=2)
        engine.run(32)
        det = [(s.step, s.k, s.window, s.p50_ms, s.p99_ms, s.occupancy,
                s.footprint, s.speeds, s.shed, s.served, s.open_circuits,
                d.action, d.target, d.reason)
               for s, d in asc.decisions]
        ops = [(op.kind, op.k_before, op.k_after, op.machine, op.partner,
                op.committed) for op in sess.ops]
        return det, ops, src.events, dict(src.telemetry.shed)

    a, b = run_once(), run_once()
    assert a == b


def test_kill_then_add_composition_through_engine(serving_graph):
    """kill -> add with an elastic session (no autoscaler): the warm
    repair and the forced grow both land mid-serve, each a single scan,
    and the placement version reaches the router every time."""
    g, labels = serving_graph
    sess = _session(g)
    cluster = _cluster(g, labels, parts_u=sess.parts.copy())
    chaos = ChaosSchedule([
        ChaosEvent(feed=3, kind="kill", machine=1),
        ChaosEvent(feed=8, kind="add"),
    ], seed=0)
    cfg = ServingConfig(prefetch=True, warmup=2, seed=0, pad_multiple=512)
    src = PSRequestSource(cluster, _mix(), cfg, chaos=chaos, elastic=sess)
    engine = ServingEngine(src)
    with dispatch_counter() as counts:
        s = engine.run(14)
    assert [op.kind for op in sess.ops] == ["repair", "grow"]
    assert counts["elastic_repair_scan"] == 1
    assert counts["elastic_grow_scan"] == 1
    assert src.dead == set()
    assert sess.k == K + 1 and src.cluster.k == K + 1
    assert src.router.version == src.cluster.placement_version
    assert src.router.k == K + 1
    assert s["requests"] == 12


def test_observe_wallclock_mode_feeds_measured_times(serving_graph):
    """observe_wallclock=True: the session EWMA ingests MEASURED scan wall
    time (one observation per lane), so injected chaos factors are
    invisible by design and only actual slowness registers."""
    import jax
    if len(jax.devices()) < 2:
        pytest.skip("needs >= 2 devices (XLA_FLAGS host device count)")
    workers = min(4, len(jax.devices()))
    chunks = ctr_like_stream(600, 1200, chunks=3, nnz_per_row=10,
                             clusters=6, locality=0.8, seed=0)
    scfg = ParsaStreamConfig(base=ParsaConfig(
        k=K, backend="parallel_device", workers=workers, block_size=32,
        merge_every=1, refine_v=False, seed=0))
    sess = ElasticSession(
        ElasticConfig(stream=scfg, observe_wallclock=True,
                      straggler_bias=True),
        num_v=1200,
        chaos=ChaosSchedule([ChaosEvent(feed=1, kind="straggle", machine=0,
                                        factor=100.0)], seed=0))
    for ch in chunks:
        sess.feed(ch)
    w = sess.ewma.weights()
    assert w.shape == (workers,) and np.isfinite(w).all()
    # measured mode: every lane saw the same fused-dispatch wall time, so
    # the injected 100x factor must NOT skew the weights
    assert np.allclose(w, 1.0)


def test_router_smooth_wrr_biases_away_from_slow(serving_graph):
    g, labels = serving_graph
    cluster = _cluster(g, labels)
    r = Router(cluster)
    r.set_weights([1.0, 1.0, 1.0, 0.2])
    homes = [r.next_home() for _ in range(32)]
    assert homes.count(3) < homes.count(0)        # down-weighted
    assert set(homes) == {0, 1, 2, 3}             # starved of none
    with pytest.raises(ValueError):
        r.set_weights([1.0, 1.0])                 # wrong fleet size
    with pytest.raises(ValueError):
        r.set_weights([1.0, 1.0, 1.0, 0.0])       # non-positive
    r.set_weights(None)
    assert r.weights is None                      # plain RR restored
