"""Parameter-server application (§5.5): DBPG convergence, Parsa vs random
traffic, KKT filter + compression semantics."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import from_edges, random_parts
from repro.core.placement import build_placement, gather_traffic
from repro.ml import DBPGConfig, PSCluster, TrafficMeter, make_problem
from repro.ml.dbpg import dequantize_int8, kkt_filter, quantize_int8, soft_threshold
from repro.graphs import ctr_like


@pytest.fixture(scope="module")
def lr_setup():
    g = ctr_like(500, 1500, nnz_per_row=15, seed=11)
    w_star, labels = make_problem(g, seed=11)
    return g, labels


def test_dbpg_converges(lr_setup):
    g, labels = lr_setup
    k = 4
    cfg = DBPGConfig(lam=0.3, lr=0.005, max_delay=0, compress=False, kkt_eps=0.0)
    pl = build_placement(g, k, b=2, a=0)
    cl = PSCluster(g, labels, pl.doc_to_shard, pl.vocab_to_shard, k, cfg)
    r = cl.run(20, log_every=5)
    objs = r["objective"]
    assert objs[-1] < objs[0] * 0.85


def test_parsa_reduces_inter_machine_traffic(lr_setup):
    g, labels = lr_setup
    k = 8
    cfg = DBPGConfig(lam=0.3, lr=0.03)
    pl = build_placement(g, k, b=4, a=2)
    r_parsa = PSCluster(g, labels, pl.doc_to_shard, pl.vocab_to_shard, k, cfg).run(5)
    r_rand = PSCluster(g, labels, random_parts(g.num_u, k, 0),
                       random_parts(g.num_v, k, 1), k, cfg).run(5)
    assert r_parsa["inter_bytes"] < r_rand["inter_bytes"]
    assert r_parsa["inner_fraction"] > r_rand["inner_fraction"]
    t = gather_traffic(g, pl)
    assert t["local_fraction"] > 1.0 / k  # beats random's expectation


def test_bounded_delay_still_converges(lr_setup):
    g, labels = lr_setup
    k = 4
    cfg = DBPGConfig(lam=0.3, lr=0.003, max_delay=3)
    pl = build_placement(g, k, b=2, a=0)
    r = PSCluster(g, labels, pl.doc_to_shard, pl.vocab_to_shard, k, cfg).run(
        20, log_every=19)
    assert r["objective"][-1] < r["objective"][0]


def test_kkt_filter_keeps_active_coords():
    w = jnp.asarray([0.0, 0.0, 1.0, -2.0])
    g = jnp.asarray([0.05, 0.5, 0.01, 0.3])
    keep = kkt_filter(w, g, lam=0.2, eps=0.1)
    # coord 0: w=0, |g|=.05 ≤ .18 → filtered; coord 1: |g|=.5 > .18 → kept
    assert list(np.asarray(keep)) == [False, True, True, True]


def test_quantization_roundtrip_error_bounded():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(0, 3, 1000), jnp.float32)
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s)) - np.asarray(x))
    assert err.max() <= float(s) * 0.5 + 1e-6


def test_soft_threshold():
    w = jnp.asarray([-3.0, -0.1, 0.0, 0.1, 3.0])
    out = np.asarray(soft_threshold(w, 0.5))
    np.testing.assert_allclose(out, [-2.5, 0, 0, 0, 2.5])


def test_traffic_meter_bare_regression():
    """A bare TrafficMeter() (no per_machine pre-sizing) must not crash on
    its first inter-machine add — per_machine sizes itself lazily."""
    m = TrafficMeter()
    m.add(0, 0, 8)                  # inner: no per-machine map needed
    assert m.per_machine is None
    m.add(2, 5, 4)                  # used to crash: per_machine was None
    assert (m.inner_bytes, m.inter_bytes, m.total) == (8, 4, 12)
    assert m.per_machine.shape[0] == 6
    assert m.per_machine[2] == 4 == m.per_machine[5]
    m.add(7, 0, 2)                  # grows past the current size
    assert m.per_machine.shape[0] == 8
    assert list(m.per_machine) == [2, 0, 4, 0, 0, 4, 0, 2]


def _tiny_cluster(cfg=None):
    """4 examples x 6 features, k=2.  Worker 0 hosts rows {0,1} (working
    set {0,1,2,3}), worker 1 hosts rows {2,3} (working set {3,4,5,0});
    server 0 owns features {0,1,2}, server 1 owns {3,4,5}."""
    g = from_edges(4, 6,
                   np.array([0, 0, 1, 1, 1, 2, 2, 3, 3, 3]),
                   np.array([0, 1, 1, 2, 3, 3, 4, 4, 5, 0]))
    if cfg is None:
        cfg = DBPGConfig(lam=0.0, lr=0.1, kkt_eps=0.0, compress=False,
                         max_delay=0, error_feedback=False)
    return PSCluster(g, np.ones(4, np.float32), np.array([0, 0, 1, 1]),
                     np.array([0, 0, 0, 1, 1, 1]), 2, cfg)


def test_metering_hand_computed_4x6():
    """Exact push/pull byte accounting on the tiny cluster, two steps.

    Push (4 B values, +4 B/key on the first send to a server — key
    caching drops them in step 2; kkt_eps=0 keeps every touched coord):
      step 1: w0->s0 3x8=24, w1->s1 3x8=24 inner; w0->s1 8, w1->s0 8 inter
      step 2: keys cached -> 12+12 inner, 4+4 inter
    Pull (4 B per *changed* needed value; lam=0 and a nonzero gradient
    move every touched coordinate every step, no key bytes):
      per step: w0<-s0 12, w1<-s1 12 inner; w0<-s1 4, w1<-s0 4 inter
    Totals after 2 steps: inner 48+24+48 = 120, inter 16+8+16 = 40; every
    inter byte crosses the m0<->m1 link, so per_machine = [40, 40]."""
    cl = _tiny_cluster()
    cl.run(2)
    assert cl.meter.inner_bytes == 120
    assert cl.meter.inter_bytes == 40
    assert list(cl.meter.per_machine) == [40, 40]


def test_pull_plan_value_delta_cache_and_stale_fallback():
    """plan_pull prices exactly the changed entries; pull_nowait refreshes
    the worker cache (second plan owes nothing) and an excluded source's
    entries stay stale — still owed on the next plan."""
    cl = _tiny_cluster()
    cl.commit_weights(np.arange(1, 7, dtype=np.float32))
    plan = cl.plan_pull(0)
    # worker 0 needs {0,1,2,3}, all changed vs its zeroed cache
    assert plan.total_bytes == 16
    assert list(plan.src_bytes) == [12, 4]      # {0,1,2} from s0, {3} from s1
    h = cl.pull_nowait(plan)
    assert h.fresh_entries == 4 and h.stale_entries == 0
    assert h.inner_bytes == 12 and h.inter_bytes == 4
    np.testing.assert_array_equal(np.asarray(h.buffer)[:4], [1, 2, 3, 4])
    assert cl.plan_pull(0).total_bytes == 0     # cache now current
    # server 1 excluded (dead/timed-out): its entry is served stale
    cl.commit_weights(np.arange(11, 17, dtype=np.float32))
    h2 = cl.pull_nowait(cl.plan_pull(0), exclude=frozenset({1}))
    assert h2.stale_entries == 1 and h2.fresh_entries == 3
    buf = np.asarray(h2.buffer)
    np.testing.assert_array_equal(buf[:3], [11, 12, 13])
    assert buf[3] == 4.0                        # the stale value, not 14
    nxt = cl.plan_pull(0)
    assert nxt.src_bytes[1] == 4 and nxt.src_bytes[0] == 0
