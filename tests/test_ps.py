"""Parameter-server application (§5.5): DBPG convergence, Parsa vs random
traffic, KKT filter + compression semantics."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import random_parts
from repro.core.placement import build_placement, gather_traffic
from repro.ml import DBPGConfig, PSCluster, make_problem
from repro.ml.dbpg import dequantize_int8, kkt_filter, quantize_int8, soft_threshold
from repro.graphs import ctr_like


@pytest.fixture(scope="module")
def lr_setup():
    g = ctr_like(500, 1500, nnz_per_row=15, seed=11)
    w_star, labels = make_problem(g, seed=11)
    return g, labels


def test_dbpg_converges(lr_setup):
    g, labels = lr_setup
    k = 4
    cfg = DBPGConfig(lam=0.3, lr=0.005, max_delay=0, compress=False, kkt_eps=0.0)
    pl = build_placement(g, k, b=2, a=0)
    cl = PSCluster(g, labels, pl.doc_to_shard, pl.vocab_to_shard, k, cfg)
    r = cl.run(20, log_every=5)
    objs = r["objective"]
    assert objs[-1] < objs[0] * 0.85


def test_parsa_reduces_inter_machine_traffic(lr_setup):
    g, labels = lr_setup
    k = 8
    cfg = DBPGConfig(lam=0.3, lr=0.03)
    pl = build_placement(g, k, b=4, a=2)
    r_parsa = PSCluster(g, labels, pl.doc_to_shard, pl.vocab_to_shard, k, cfg).run(5)
    r_rand = PSCluster(g, labels, random_parts(g.num_u, k, 0),
                       random_parts(g.num_v, k, 1), k, cfg).run(5)
    assert r_parsa["inter_bytes"] < r_rand["inter_bytes"]
    assert r_parsa["inner_fraction"] > r_rand["inner_fraction"]
    t = gather_traffic(g, pl)
    assert t["local_fraction"] > 1.0 / k  # beats random's expectation


def test_bounded_delay_still_converges(lr_setup):
    g, labels = lr_setup
    k = 4
    cfg = DBPGConfig(lam=0.3, lr=0.003, max_delay=3)
    pl = build_placement(g, k, b=2, a=0)
    r = PSCluster(g, labels, pl.doc_to_shard, pl.vocab_to_shard, k, cfg).run(
        20, log_every=19)
    assert r["objective"][-1] < r["objective"][0]


def test_kkt_filter_keeps_active_coords():
    w = jnp.asarray([0.0, 0.0, 1.0, -2.0])
    g = jnp.asarray([0.05, 0.5, 0.01, 0.3])
    keep = kkt_filter(w, g, lam=0.2, eps=0.1)
    # coord 0: w=0, |g|=.05 ≤ .18 → filtered; coord 1: |g|=.5 > .18 → kept
    assert list(np.asarray(keep)) == [False, True, True, True]


def test_quantization_roundtrip_error_bounded():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(0, 3, 1000), jnp.float32)
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s)) - np.asarray(x))
    assert err.max() <= float(s) * 0.5 + 1e-6


def test_soft_threshold():
    w = jnp.asarray([-3.0, -0.1, 0.0, 0.1, 3.0])
    out = np.asarray(soft_threshold(w, 0.5))
    np.testing.assert_allclose(out, [-2.5, 0, 0, 0, 2.5])
