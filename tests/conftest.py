"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests run on the single real
CPU device; only the dry-run (and its subprocess test) forces host devices.
"""
import numpy as np
import pytest

from repro.graphs import text_like, ctr_like, social_like, natural_to_bipartite


@pytest.fixture(scope="session")
def small_text_graph():
    return text_like(400, 1000, mean_len=30, seed=7)


@pytest.fixture(scope="session")
def small_ctr_graph():
    return ctr_like(400, 2000, nnz_per_row=20, seed=7)


@pytest.fixture(scope="session")
def small_social_graph():
    src, dst, n = social_like(500, m=5, seed=7)
    return natural_to_bipartite(src, dst, n)
