"""Device-resident Algorithm 2 + packed-bitmask metrics (core.jax_refine):
bit-exact parity with the host oracles (core.partition_v / core.costs),
the fused refine-sweep Pallas kernel, the facade's ``refine_backend``
device flow, and the O(1)-dispatch invariant of the full pipeline."""
import numpy as np
import pytest

from repro.api import ParsaConfig, partition
from repro.core.bipartite import from_edges
from repro.core.costs import evaluate, need_matrix
from repro.core.jax_partition import dispatch_counter
from repro.core.jax_refine import evaluate_device, need_masks, refine_v_device
from repro.core.partition_u import partition_u_impl
from repro.core.partition_v import partition_v
from repro.graphs import text_like
from repro.kernels.parsa_cost import pack_bitmask


def _random_graph(rng, nu, nv, ne, isolate_frac=0.0):
    """Random bipartite graph; ``isolate_frac`` reserves a tail of V that no
    edge may touch, so the Alg 2 isolated-parameter −1 convention is hit."""
    hi = max(1, int(nv * (1 - isolate_frac)))
    eu = rng.integers(0, nu, size=ne)
    ev = rng.integers(0, hi, size=ne)
    return from_edges(nu, nv, eu, ev)


# ------------------------------------------------------------ need_masks
@pytest.mark.parametrize("k", [4, 16, 64])
def test_need_masks_matches_packed_need_matrix(k):
    rng = np.random.default_rng(k)
    g = _random_graph(rng, 300, 700, 4000, isolate_frac=0.1)
    parts_u = rng.integers(0, k, size=g.num_u).astype(np.int32)
    got = np.asarray(need_masks(g, parts_u, k))
    want = pack_bitmask(need_matrix(g, parts_u, k), g.num_v)
    assert np.array_equal(got, want)


def test_need_masks_empty_graph():
    g = from_edges(5, 70, np.zeros(0, np.int64), np.zeros(0, np.int64))
    got = np.asarray(need_masks(g, np.zeros(5, np.int32), 4))
    assert got.shape == (4, 3) and not got.any()


# ------------------------------------------------- partition_v parity
@pytest.mark.parametrize("sweeps", [1, 2, 4])
@pytest.mark.parametrize("k", [4, 16])
def test_refine_v_device_bit_identical(k, sweeps):
    """Acceptance: device Alg 2 == host Alg 2 for every sweep count,
    including the isolated-parameter −1 case and ragged chunk tails."""
    rng = np.random.default_rng(17 * k + sweeps)
    g = _random_graph(rng, 400, 777, 6000, isolate_frac=0.15)
    parts_u = partition_u_impl(g, k, seed=1).parts_u
    want = partition_v(g, parts_u, k, sweeps=sweeps)
    got, _ = refine_v_device(g, parts_u, k, sweeps=sweeps, chunk=128)
    assert np.array_equal(np.asarray(got), want)
    assert (want == -1).any()  # the isolated tail is actually exercised


def test_refine_v_device_k64_and_chunk_sizes():
    rng = np.random.default_rng(5)
    g = _random_graph(rng, 500, 1500, 9000, isolate_frac=0.05)
    parts_u = rng.integers(0, 64, size=g.num_u).astype(np.int32)
    want = partition_v(g, parts_u, 64, sweeps=2)
    for chunk in (32, 256, 2048):
        got, _ = refine_v_device(g, parts_u, 64, sweeps=2, chunk=chunk)
        assert np.array_equal(np.asarray(got), want), chunk


def test_refine_v_device_converged_sweeps_are_fixed_point():
    """Host breaks out of converged sweeps; device runs them all — results
    must still agree (a converged sweep is a no-op on (cost, parts))."""
    g = text_like(300, 600, mean_len=15, seed=0)
    parts_u = partition_u_impl(g, 8).parts_u
    want = partition_v(g, parts_u, 8, sweeps=4)   # converged by sweep 4
    assert np.array_equal(want, partition_v(g, parts_u, 8, sweeps=5))
    got, _ = refine_v_device(g, parts_u, 8, sweeps=6, chunk=256)
    assert np.array_equal(np.asarray(got), want)


def test_refine_v_device_rejects_bad_chunk():
    g = text_like(50, 100, mean_len=5, seed=0)
    with pytest.raises(ValueError, match="multiple of 32"):
        refine_v_device(g, np.zeros(50, np.int32), 4, chunk=48)


# --------------------------------------------------- fused Pallas kernel
def test_refine_sweep_kernel_matches_ref_interpret():
    """The fused cost-update kernel is bit-exact vs the jnp oracle across
    shapes, including re-assignment sweeps (prev ≥ 0) and empty columns."""
    import jax.numpy as jnp

    from repro.kernels.parsa_cost import refine_sweep_chunk, refine_sweep_ref

    rng = np.random.default_rng(0)
    for k, cw in [(4, 2), (8, 4), (16, 2), (32, 1)]:
        C = cw * 32
        words = rng.integers(0, 2**31, size=(k, cw), dtype=np.int64) \
            .astype(np.int32)
        words[:, -1] &= rng.integers(0, 2**16, dtype=np.int64)  # empty cols
        bits = ((words[:, :, None] >> np.arange(32)) & 1).reshape(k, C)
        prev = np.full(C, -1, np.int32)
        for j in range(C):  # a consistent partial previous assignment
            nz = np.flatnonzero(bits[:, j])
            if nz.size and rng.random() < 0.6:
                prev[j] = rng.choice(nz)
        cost = rng.integers(0, 500, k).astype(np.int32)
        c_ref, p_ref = refine_sweep_ref(
            jnp.asarray(words), jnp.asarray(prev), jnp.asarray(cost))
        c_ker, p_ker = refine_sweep_chunk(
            jnp.asarray(words), jnp.asarray(prev), jnp.asarray(cost),
            use_kernel=True, interpret=True)
        assert np.array_equal(np.asarray(c_ref), np.asarray(c_ker)), (k, cw)
        assert np.array_equal(np.asarray(p_ref), np.asarray(p_ker)), (k, cw)


def test_refine_v_device_kernel_path_parity():
    rng = np.random.default_rng(3)
    g = _random_graph(rng, 250, 400, 3000, isolate_frac=0.1)
    parts_u = partition_u_impl(g, 8).parts_u
    want = partition_v(g, parts_u, 8, sweeps=2)
    got, _ = refine_v_device(g, parts_u, 8, sweeps=2, chunk=64,
                             use_kernel=True, interpret=True)
    assert np.array_equal(np.asarray(got), want)


# --------------------------------------------------------- metrics parity
@pytest.mark.parametrize("k", [4, 16, 64])
def test_evaluate_device_bit_equal(k):
    rng = np.random.default_rng(k + 1)
    g = _random_graph(rng, 350, 900, 5000, isolate_frac=0.1)
    parts_u = rng.integers(0, k, size=g.num_u).astype(np.int32)
    parts_v = partition_v(g, parts_u, k, sweeps=2)
    mh = evaluate(g, parts_u, parts_v, k)
    md = evaluate_device(g, parts_u, parts_v, k)
    for field in ("sizes", "footprint", "traffic", "worker_recv",
                  "server_send"):
        assert np.array_equal(getattr(mh, field), getattr(md, field)), field
    assert mh.as_dict() == md.as_dict()


def test_evaluate_device_rowmap_branch_bit_equal(monkeypatch):
    """The large-k²W row-by-row intersection path (no (k, k, W) broadcast)
    is bit-equal too.  Fresh shapes force a retrace under the patched
    threshold (the branch is chosen at trace time)."""
    import repro.core.jax_refine as jr

    monkeypatch.setattr(jr, "_M_BCAST_MAX_WORDS", 0)
    rng = np.random.default_rng(11)
    g = _random_graph(rng, 333, 901, 5000, isolate_frac=0.1)  # unseen shape
    parts_u = rng.integers(0, 16, size=g.num_u).astype(np.int32)
    parts_v = partition_v(g, parts_u, 16, sweeps=2)
    mh = evaluate(g, parts_u, parts_v, 16)
    md = evaluate_device(g, parts_u, parts_v, 16)
    assert mh.as_dict() == md.as_dict()
    assert np.array_equal(mh.traffic, md.traffic)


def test_evaluate_device_parts_v_none_matches_host():
    g = text_like(300, 600, mean_len=15, seed=2)
    parts_u = partition_u_impl(g, 8).parts_u
    mh = evaluate(g, parts_u, None, 8)
    md = evaluate_device(g, parts_u, None, 8)
    assert mh.as_dict() == md.as_dict()
    assert np.array_equal(mh.traffic, md.traffic)


# ----------------------------------------------------- hypothesis property
try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:

    @given(seed=st.integers(0, 2**31 - 1), k=st.sampled_from([4, 16, 64]),
           sweeps=st.integers(1, 3))
    @settings(max_examples=25, deadline=None)
    def test_property_device_refine_and_metrics_bit_equal(seed, k, sweeps):
        rng = np.random.default_rng(seed)
        g = _random_graph(rng, int(rng.integers(5, 80)),
                          int(rng.integers(5, 150)),
                          int(rng.integers(1, 500)),
                          isolate_frac=float(rng.random() * 0.3))
        parts_u = rng.integers(0, k, size=g.num_u).astype(np.int32)
        want_v = partition_v(g, parts_u, k, sweeps=sweeps)
        got_v, nw = refine_v_device(g, parts_u, k, sweeps=sweeps, chunk=64)
        assert np.array_equal(np.asarray(got_v), want_v)
        mh = evaluate(g, parts_u, want_v, k)
        md = evaluate_device(g, parts_u, got_v, k, need_words=nw)
        assert mh.as_dict() == md.as_dict()
        assert np.array_equal(mh.traffic, md.traffic)


# ------------------------------------------------------------- facade flow
@pytest.mark.parametrize("backend,extra", [
    ("host", {}),
    ("device_scan", dict(block_size=64)),
    ("parallel_sim", dict(workers=4, tau=0)),
    ("parallel_device", dict(workers=1, block_size=64, merge_every=2)),
    # init_iters / global_init_frac leave S_i ⊋ N(U_i), so these two pin
    # the gating of the cold-start s_masks-as-need shortcut
    ("host", dict(init_iters=2)),
    ("parallel_sim", dict(workers=2, tau=0, global_init_frac=0.2)),
])
def test_partition_refine_backend_device_parity(backend, extra):
    """The one-call pipeline with refine_backend="device" is bit-identical
    to the host pipeline for every backend — parts, metrics, and sets."""
    g = text_like(500, 900, mean_len=15, seed=9)
    base = ParsaConfig(k=8, backend=backend, blocks=4, sweeps=2, **extra)
    rh = partition(g, base)
    rd = partition(g, base.replace(refine_backend="device"))
    assert np.array_equal(rh.parts_u, rd.parts_u)
    assert np.array_equal(rh.parts_v, rd.parts_v)
    assert rh.metrics.as_dict() == rd.metrics.as_dict()
    assert np.array_equal(rh.metrics.traffic, rd.metrics.traffic)
    assert np.array_equal(rh.s_masks, rd.s_masks)


def test_full_pipeline_o1_dispatches():
    """Acceptance: the fully device-resident pipeline (scan → refine →
    metrics) issues O(1) XLA pipeline launches per phase.  Cold starts
    reuse the scan's own s_masks as the need matrix (zero need_pack
    launches); warm starts pay exactly one segment-OR need pack."""
    g = text_like(600, 1100, mean_len=12, seed=1)
    cfg = ParsaConfig(k=8, backend="device_scan", block_size=64,
                      refine_backend="device")
    warm = partition(g, cfg)  # warm the jitted pipelines
    with dispatch_counter() as counts:
        partition(g, cfg)
    assert counts == {"partition_scan": 1,
                      "refine_scan": 1, "metrics": 1}, counts
    partition(g, cfg, init_sets=warm.s_masks)  # warm the need-pack jit
    with dispatch_counter() as counts:
        partition(g, cfg, init_sets=warm.s_masks)
    assert counts == {"partition_scan": 1, "need_pack": 1,
                      "refine_scan": 1, "metrics": 1}, counts


def test_pack_timing_split_for_device_backends():
    g = text_like(300, 500, mean_len=10, seed=0)
    res = partition(g, ParsaConfig(k=4, backend="device_scan", block_size=64))
    assert "pack" in res.timings and res.timings["pack"] >= 0
    assert res.timings["partition_u"] >= 0
    res_h = partition(g, ParsaConfig(k=4, backend="host"))
    assert "pack" not in res_h.timings  # host backends do not pack


def test_refine_backend_validation():
    with pytest.raises(ValueError, match="refine_backend"):
        ParsaConfig(k=4, refine_backend="gpu")
    with pytest.raises(ValueError, match="refine_chunk"):
        ParsaConfig(k=4, refine_chunk=100)


# -------------------------------------------------- packed warm-start path
def test_partition_accepts_packed_init_sets_all_backends():
    """partition(init_sets=packed) == partition(init_sets=dense) for host
    and device backends — the warm-start fast path never densifies."""
    g1 = text_like(400, 800, mean_len=12, seed=3)
    g2 = text_like(300, 800, mean_len=12, seed=4)
    for backend, extra in [("host", {}), ("device_scan", dict(block_size=64)),
                           ("parallel_sim", dict(workers=2, tau=0))]:
        cfg = ParsaConfig(k=8, backend=backend, blocks=2, **extra)
        r1 = partition(g1, cfg)
        dense = partition(g2, cfg, init_sets=r1.neighbor_sets)
        packed = partition(g2, cfg, init_sets=r1.s_masks)
        assert np.array_equal(dense.parts_u, packed.parts_u), backend
        assert np.array_equal(dense.s_masks, packed.s_masks), backend


def test_packed_warm_start_never_mutates_caller_sets():
    """Regression: backends must not OR their updates into the caller's
    packed warm-start buffer (parallel_sim's server merges in place)."""
    g1 = text_like(400, 800, mean_len=12, seed=3)
    g2 = text_like(300, 800, mean_len=12, seed=4)
    for backend, extra in [("parallel_sim", dict(workers=2, tau=0)),
                           ("device_scan", dict(block_size=64)),
                           ("host", {})]:
        cfg = ParsaConfig(k=8, backend=backend, blocks=2, **extra)
        r1 = partition(g1, cfg)
        before = r1.s_masks.copy()
        partition(g2, cfg, init_sets=r1.s_masks)
        assert np.array_equal(r1.s_masks, before), backend


def test_result_refine_uses_native_view():
    """refine() hands over whichever set view the backend produced — the
    packed view for device backends (no dense unpack), dense for host —
    and both give bit-identical warm-started results."""
    g1 = text_like(400, 800, mean_len=12, seed=3)
    g2 = text_like(300, 800, mean_len=12, seed=4)
    cfg = ParsaConfig(k=8, backend="device_scan", block_size=64)
    r1 = partition(g1, cfg)
    assert r1._dense_sets is None          # packed-native result
    r2 = r1.refine(g2)
    assert r1._dense_sets is None          # refine() did NOT force an unpack
    want = partition(g2, cfg, init_sets=r1.neighbor_sets)
    assert np.array_equal(r2.parts_u, want.parts_u)
    assert np.array_equal(r2.s_masks, want.s_masks)


def test_multidevice_parallel_device_device_refine_subprocess():
    """The 8-virtual-device path end to end in ONE process: parallel_device
    partition_u → device refine → device metrics, bit-equal to the host
    refine/metrics of the same parts_u, O(1) dispatches per phase."""
    import os
    import pathlib
    import subprocess
    import sys

    root = pathlib.Path(__file__).resolve().parents[1]
    env = dict(os.environ)
    env.update(
        XLA_FLAGS="--xla_force_host_platform_device_count=8",
        JAX_PLATFORMS="cpu",
        PYTHONPATH=str(root / "src"),
    )
    script = r"""
import jax, numpy as np
assert len(jax.devices()) == 8, jax.devices()
from repro.graphs import text_like
from repro.api import ParsaConfig, partition
from repro.core.jax_partition import dispatch_counter

g = text_like(1200, 2000, mean_len=15, seed=4)
cfg = ParsaConfig(k=8, backend="parallel_device", workers=8, merge_every=2,
                  block_size=64, sweeps=2, refine_backend="device", seed=0)
partition(g, cfg)  # warm
with dispatch_counter() as counts:
    res = partition(g, cfg)
assert counts == {"partition_scan": 0, "parallel_partition_scan": 1,
                  "refine_scan": 1, "metrics": 1}, counts
ref = partition(g, cfg.replace(refine_backend="host"))
assert np.array_equal(res.parts_v, ref.parts_v)
assert res.metrics.as_dict() == ref.metrics.as_dict()
print("REFINE_8DEV_OK")
"""
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=600)
    assert "REFINE_8DEV_OK" in out.stdout, out.stdout + out.stderr
