"""Device-resident blocked-Parsa pipeline: packing property tests, fused
cost+select kernel exactness, and single-dispatch scan parity vs the
sequential per-block host loop."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.bipartite import from_edges
from repro.core.jax_partition import (
    blocked_partition_u,
    blocked_partition_u_hostloop,
    dispatch_counter,
    pack_graph_blocks,
    parallel_blocked_partition_u_impl,
    reset_dispatch_counts,
    shard_parsa_step,
)
from repro.graphs import text_like
from repro.kernels.parsa_cost import (
    BIG,
    compact_row_words,
    pack_bitmask,
    pack_bitmask_csr,
    pack_bitmask_csr_compact,
    packed_delta,
    packed_union,
    packed_union_delta,
    parsa_cost_select,
    parsa_select_greedy_ref,
    parsa_select_ref,
    unpack_bitmask,
)


def _random_graph(seed, nu=None, nv=None, ne=None):
    rng = np.random.default_rng(seed)
    nu = nu or int(rng.integers(50, 900))
    nv = nv or int(rng.integers(30, 400))
    ne = ne or int(rng.integers(1, 6000))
    return from_edges(nu, nv, rng.integers(0, nu, ne), rng.integers(0, nv, ne))


# ------------------------------------------------------------------ packing
@pytest.mark.parametrize("seed", range(6))
def test_vectorized_packing_matches_pack_bitmask(seed):
    """Property: CSR→bitmask with zero per-vertex Python work is exact."""
    g = _random_graph(seed)
    rng = np.random.default_rng(seed + 100)
    want = pack_bitmask([g.neighbors(int(u)) for u in range(g.num_u)], g.num_v)
    assert np.array_equal(
        pack_bitmask_csr(g.u_indptr, g.u_indices, g.num_v), want)
    perm = rng.permutation(g.num_u)
    want_p = pack_bitmask([g.neighbors(int(u)) for u in perm], g.num_v)
    assert np.array_equal(
        pack_bitmask_csr(g.u_indptr, g.u_indices, g.num_v, rows=perm), want_p)
    # the fused sorted-pass variant agrees with the two-step reference
    cap = int(rng.integers(2, 12))
    m2, w2, v2, t2 = pack_bitmask_csr_compact(
        g.u_indptr, g.u_indices, g.num_v, rows=perm, cap=cap)
    w1, v1, t1 = compact_row_words(want_p, cap)
    assert np.array_equal(m2, want_p)
    assert np.array_equal(w2, w1) and np.array_equal(v2, v1)
    assert np.array_equal(t2, t1)


def test_compact_row_words_identity():
    """Σ_d popcount(vals & X[widx]) == popcount(mask & X) for clean rows."""
    g = text_like(200, 600, mean_len=25, seed=2)
    masks = pack_bitmask_csr(g.u_indptr, g.u_indices, g.num_v)
    widx, vals, trunc = compact_row_words(masks, cap=8)
    rng = np.random.default_rng(0)
    X = rng.integers(0, 2**32, masks.shape[1], dtype=np.uint64).astype(np.uint32)
    mu, vu = masks.view(np.uint32), vals.view(np.uint32)
    for r in range(masks.shape[0]):
        if trunc[r]:
            continue
        full = int(sum(bin(x).count("1") for x in (mu[r] & X)))
        comp = int(sum(bin(int(v & X[i])).count("1")
                       for i, v in zip(widx[r], vu[r])))
        assert full == comp


def test_pack_graph_blocks_shapes_and_trunc_side_channel():
    g = text_like(700, 900, mean_len=30, seed=4)
    packed = pack_graph_blocks(g, 256, cap=4)  # tiny cap → lots of trunc
    nb = -(-g.num_u // 256)
    assert packed.valid.shape == (nb, 256)
    assert packed.valid.sum() == g.num_u
    assert packed.trunc.any()  # cap=4 must truncate on this graph
    # every truncated row appears exactly once in the side channel
    t_total = int(packed.trunc.sum())
    assert int((packed.tr_ids < 256).sum()) == t_total


# ------------------------------------------------- fused cost+select kernel
@pytest.mark.parametrize("B", [256, 1024])
@pytest.mark.parametrize("k", [8, 32, 64])
def test_select_kernel_bit_exact_vs_ref(B, k):
    """Acceptance: fused kernel matches ref.py bit-exactly (interpret)."""
    rng = np.random.default_rng(B * k)
    num_v = int(rng.integers(100, 3000))
    nbr = jnp.asarray(pack_bitmask(
        [rng.choice(num_v, size=rng.integers(0, min(60, num_v)),
                    replace=False) for _ in range(B)], num_v))
    s = jnp.asarray(pack_bitmask(rng.random((k, num_v)) < 0.25, num_v))
    retired = jnp.asarray(rng.random(B) < 0.3)
    # independent mode: per-partition (min, argmin)
    m1, a1 = parsa_cost_select(nbr, s, retired, use_kernel=True,
                               interpret=True)
    m2, a2 = parsa_select_ref(nbr, s, retired)
    assert np.array_equal(np.asarray(m1), np.asarray(m2))
    assert np.array_equal(np.asarray(a1), np.asarray(a2))
    # greedy-round mode: progressive retirement in `order`
    order = jnp.asarray(rng.permutation(k).astype(np.int32))
    enabled = jnp.asarray(rng.random(k) < 0.8)
    u1, c1 = parsa_cost_select(nbr, s, retired, order=order, enabled=enabled,
                               use_kernel=True, interpret=True)
    u2, c2 = parsa_select_greedy_ref(nbr, s, retired, order, enabled)
    assert np.array_equal(np.asarray(u1), np.asarray(u2))
    assert np.array_equal(np.asarray(c1), np.asarray(c2))


def test_select_kernel_conflict_chain():
    """All-identical columns force the worst-case collision cascade."""
    B, k, num_v = 128, 16, 500
    rng = np.random.default_rng(7)
    nbr = jnp.asarray(pack_bitmask(
        [rng.choice(num_v, size=20, replace=False) for _ in range(B)], num_v))
    s = jnp.zeros((k, (num_v + 31) // 32), jnp.int32)  # identical columns
    retired = jnp.zeros((B,), bool)
    order = jnp.arange(k, dtype=jnp.int32)
    enabled = jnp.ones((k,), bool)
    u1, c1 = parsa_cost_select(nbr, s, retired, order=order, enabled=enabled,
                               use_kernel=True, interpret=True)
    u2, c2 = parsa_select_greedy_ref(nbr, s, retired, order, enabled)
    assert np.array_equal(np.asarray(u1), np.asarray(u2))
    assert np.array_equal(np.asarray(c1), np.asarray(c2))
    assert len(set(np.asarray(u1).tolist())) == k  # distinct picks
    assert (np.asarray(c1) < BIG).all()


# ----------------------------------------------------- scan pipeline parity
@pytest.mark.parametrize("seed,k,block", [
    (0, 4, 128), (1, 16, 128), (2, 8, 256), (3, 16, 64), (4, 3, 104),
])
def test_scan_pipeline_matches_hostloop(seed, k, block):
    """Acceptance: the single-dispatch scan returns identical parts_u to
    the per-block host loop (seed implementation) on random graphs."""
    g = _random_graph(seed)
    want = blocked_partition_u_hostloop(g, k, block=block, use_kernel=False,
                                        seed=seed)
    got = blocked_partition_u(g, k, block=block, use_kernel=False, seed=seed)
    assert np.array_equal(got, want)


def test_scan_pipeline_matches_hostloop_kernel_path():
    g = text_like(500, 800, mean_len=20, seed=9)
    want = blocked_partition_u_hostloop(g, 8, block=128, use_kernel=False,
                                        seed=0)
    got = blocked_partition_u(g, 8, block=128, use_kernel=True,
                              interpret=True, seed=0)
    assert np.array_equal(got, want)


def test_scan_pipeline_matches_hostloop_trunc_fallback():
    """cap small enough that the dense fallbacks actually run."""
    g = text_like(400, 600, mean_len=25, seed=5)
    want = blocked_partition_u_hostloop(g, 4, block=128, use_kernel=False,
                                        seed=0)
    got = blocked_partition_u(g, 4, block=128, use_kernel=False, seed=0,
                              cap=3)
    assert np.array_equal(got, want)


def test_scan_pipeline_matches_hostloop_init_sets():
    g = text_like(300, 500, mean_len=15, seed=6)
    rng = np.random.default_rng(1)
    S0 = rng.random((8, g.num_v)) < 0.1
    want = blocked_partition_u_hostloop(g, 8, block=128, init_sets=S0,
                                        use_kernel=False, seed=2)
    got = blocked_partition_u(g, 8, block=128, init_sets=S0,
                              use_kernel=False, seed=2)
    assert np.array_equal(got, want)


def test_blocked_partition_returns_final_s_masks():
    """The device pipeline now returns the final packed neighbor sets: they
    must equal the per-partition union of assigned vertices' neighborhoods
    (∪ init), i.e. exactly what the host path would carry forward."""
    from repro.core.costs import need_matrix
    from repro.kernels.parsa_cost import unpack_bitmask

    g = text_like(350, 500, mean_len=15, seed=11)
    k = 8
    parts, s_masks = blocked_partition_u(g, k, block=128, use_kernel=False,
                                         seed=3, return_sets=True)
    assert s_masks.shape == (k, (g.num_v + 31) // 32)
    dense = unpack_bitmask(s_masks, g.num_v)
    assert np.array_equal(dense, need_matrix(g, parts, k))  # cold start
    # packed→dense→packed round trip is exact
    assert np.array_equal(pack_bitmask(dense, g.num_v), s_masks)


def test_init_sets_round_trip_host_device_parity():
    """Warm-start parity: neighbor sets produced by the device scan seed the
    host path (and vice versa) with bit-identical downstream partitions."""
    from repro.kernels.parsa_cost import unpack_bitmask

    g1 = text_like(300, 500, mean_len=15, seed=12)
    g2 = text_like(250, 500, mean_len=15, seed=13)
    k = 8
    # device run on g1 → packed sets → dense view
    _, s_masks = blocked_partition_u(g1, k, block=128, use_kernel=False,
                                     seed=0, return_sets=True)
    S0 = unpack_bitmask(s_masks, g1.num_v)
    # the SAME dense sets warm-start both paths on g2 → identical parts
    want = blocked_partition_u_hostloop(g2, k, block=128, init_sets=S0,
                                        use_kernel=False, seed=2)
    got, s2 = blocked_partition_u(g2, k, block=128, init_sets=S0,
                                  use_kernel=False, seed=2, return_sets=True)
    assert np.array_equal(got, want)
    # and the device's final sets re-pack what the host loop accumulated
    _, s2_host = blocked_partition_u_hostloop(
        g2, k, block=128, init_sets=S0, use_kernel=False, seed=2,
        return_sets=True)
    assert np.array_equal(s2, s2_host)


def test_blocked_partition_balance_and_cover():
    g = text_like(777, 700, mean_len=18, seed=3)
    k = 8
    parts = blocked_partition_u(g, k, block=128, use_kernel=False)
    assert np.all(parts >= 0) and np.all(parts < k)
    sizes = np.bincount(parts, minlength=k)
    assert sizes.max() - sizes.min() <= 1


def test_single_dispatch_per_call(monkeypatch):
    """Acceptance: O(1) XLA dispatches per partition call, regardless of
    how many blocks the graph spans — the whole partition goes through
    exactly one `_partition_scan` launch and never the per-block loop."""
    import repro.core.jax_partition as jp

    calls = []
    real_scan = jp._partition_scan

    def counting_scan(*args, **kwargs):
        calls.append(1)
        return real_scan(*args, **kwargs)

    def no_per_block_dispatch(*args, **kwargs):
        raise AssertionError("per-block host dispatch on the scan pipeline")

    monkeypatch.setattr(jp, "_partition_scan", counting_scan)
    monkeypatch.setattr(jp, "_assign_block", no_per_block_dispatch)
    small = text_like(150, 300, mean_len=10, seed=0)   # 2 blocks @ 128
    large = text_like(1500, 300, mean_len=10, seed=0)  # 12 blocks @ 128
    for g in (small, large):
        calls.clear()
        with dispatch_counter() as counts:
            blocked_partition_u(g, 4, block=128, use_kernel=False)
        assert calls == [1]  # one scan launch, independent of n_blocks
        assert counts["partition_scan"] == 1


def test_dispatch_counter_isolated():
    """Counters are scoped to their with-block: no cross-test leakage, and
    nesting observes only launches inside each scope."""
    g = text_like(120, 200, mean_len=8, seed=1)
    with dispatch_counter() as outer:
        blocked_partition_u(g, 2, block=64, use_kernel=False)
        with dispatch_counter() as inner:
            assert inner["partition_scan"] == 0  # fresh scope
            blocked_partition_u(g, 2, block=64, use_kernel=False)
        assert inner["partition_scan"] == 1
        assert outer["partition_scan"] == 2
        reset_dispatch_counts()
        assert outer["partition_scan"] == 0
    with dispatch_counter() as fresh:
        assert fresh["partition_scan"] == 0  # prior launches invisible
    # nested scopes whose dicts compare EQUAL must deregister by identity:
    # the inner exit may not knock out the outer counter
    with dispatch_counter() as outer2:
        with dispatch_counter():
            pass  # both counters are {"partition_scan": 0} here
        blocked_partition_u(g, 2, block=64, use_kernel=False)
        assert outer2["partition_scan"] == 1


# --------------------------------------------------- packed union/delta ops
@pytest.mark.parametrize("seed", range(4))
def test_packed_union_delta_round_trip(seed):
    """Property: word-lattice ops commute with packing, and the delta is a
    faithful wire encoding — OR-ing it back reproduces the full union."""
    rng = np.random.default_rng(seed)
    k = int(rng.integers(2, 20))
    num_v = int(rng.integers(40, 2500))
    A = rng.random((k, num_v)) < 0.2
    B = rng.random((k, num_v)) < 0.2
    pa, pb = pack_bitmask(A, num_v), pack_bitmask(B, num_v)
    union = packed_union(pa, pb)
    delta = packed_delta(pa, pb)
    assert np.array_equal(union, pack_bitmask(A | B, num_v))
    assert np.array_equal(delta, pack_bitmask(A & ~B, num_v))
    # delta-encoded push: server OR delta == server OR full new sets
    assert np.array_equal(packed_union(pb, delta), union)
    assert np.array_equal(unpack_bitmask(union, num_v), A | B)


@pytest.mark.parametrize("seed", range(3))
def test_packed_union_delta_pallas_matches_numpy(seed):
    """The fused Pallas variant (interpret mode) is bit-exact vs numpy."""
    rng = np.random.default_rng(seed + 50)
    k = int(rng.integers(2, 33))
    num_v = int(rng.integers(100, 3000))
    new = rng.random((k, num_v)) < 0.3
    old = rng.random((k, num_v)) < 0.3
    pn, po = pack_bitmask(new, num_v), pack_bitmask(old, num_v)
    u1, d1 = packed_union_delta(jnp.asarray(pn), jnp.asarray(po),
                                use_kernel=True, interpret=True)
    assert np.array_equal(np.asarray(u1), packed_union(pn, po))
    assert np.array_equal(np.asarray(d1), packed_delta(pn, po))
    u2, d2 = packed_union_delta(jnp.asarray(pn), jnp.asarray(po),
                                use_kernel=False)
    assert np.array_equal(np.asarray(u2), np.asarray(u1))
    assert np.array_equal(np.asarray(d2), np.asarray(d1))


# --------------------------------------------- parallel_device (shard_map)
@pytest.mark.parametrize("merge_every", [1, 3])
def test_parallel_device_w1_bit_exact_vs_device_scan(merge_every):
    """Acceptance: one worker collapses to the sequential device pipeline
    bit-for-bit, for any merge cadence (the OR-merge is the identity)."""
    from repro.core.jax_partition import blocked_partition_u_impl

    g = text_like(500, 800, mean_len=20, seed=9)
    k = 8
    want, s_want = blocked_partition_u_impl(g, k, block=128,
                                            use_kernel=False, seed=0)
    got, s_got, traffic = parallel_blocked_partition_u_impl(
        g, k, workers=1, block=128, merge_every=merge_every,
        use_kernel=False, seed=0)
    assert np.array_equal(got, want)
    assert np.array_equal(s_got, s_want)
    assert traffic["stale_pushes_missed"] == 0  # no peers at W=1
    assert traffic["pushed_bytes"] > 0 and traffic["pulled_bytes"] > 0


def test_parallel_device_w1_warm_start_parity():
    from repro.core.jax_partition import blocked_partition_u_impl

    g = text_like(300, 500, mean_len=15, seed=6)
    rng = np.random.default_rng(1)
    S0 = rng.random((8, g.num_v)) < 0.1
    want, _ = blocked_partition_u_impl(g, 8, block=128, init_sets=S0,
                                       use_kernel=False, seed=2)
    got, _, _ = parallel_blocked_partition_u_impl(
        g, 8, workers=1, block=128, init_sets=S0, use_kernel=False, seed=2)
    assert np.array_equal(got, want)


def test_parallel_device_balance_bound_when_k_not_dividing():
    """k ∤ num_u leaves uneven sizes at merges; every worker applies the
    same catch-up against its stale view, so global imbalance is bounded by
    ``workers`` (and stays exactly ≤ 1 at workers=1) — the documented
    balance contract of the BSP mapping."""
    g = text_like(997, 1500, mean_len=12, seed=0)
    k = 3
    parts1, _, _ = parallel_blocked_partition_u_impl(
        g, k, workers=1, block=64, merge_every=1, use_kernel=False, seed=0)
    sizes1 = np.bincount(parts1, minlength=k)
    assert sizes1.max() - sizes1.min() <= 1
    # multi-worker path needs >1 device to differ; on a 1-device host this
    # still exercises the bound trivially
    w = min(4, len(jax.devices()))
    parts, _, _ = parallel_blocked_partition_u_impl(
        g, k, workers=w, block=64, merge_every=1, use_kernel=False, seed=0)
    sizes = np.bincount(parts, minlength=k)
    assert (parts >= 0).all()
    assert sizes.max() - sizes.min() <= max(1, w), sizes


def test_parallel_device_requires_enough_devices():
    g = text_like(100, 200, mean_len=8, seed=0)
    with pytest.raises(ValueError, match="XLA_FLAGS"):
        parallel_blocked_partition_u_impl(g, 4, workers=len(jax.devices()) + 1)


def test_parallel_device_multidevice_smoke_subprocess():
    """Alg 4 on 8 forced host devices: shard_map fan-out, OR-merges, global
    balance, and S ⊇ N(U_i) coverage all hold with real multi-worker
    staleness (merge_every > 1)."""
    import os
    import pathlib
    import subprocess
    import sys

    root = pathlib.Path(__file__).resolve().parents[1]
    env = dict(os.environ)
    env.update(
        XLA_FLAGS="--xla_force_host_platform_device_count=8",
        JAX_PLATFORMS="cpu",
        PYTHONPATH=str(root / "src"),
    )
    script = r"""
import jax, numpy as np
assert len(jax.devices()) == 8, jax.devices()
from repro.graphs import text_like
from repro.api import ParsaConfig, partition
from repro.core.costs import need_matrix

g = text_like(1200, 2000, mean_len=15, seed=4)
k = 8
for workers, m in [(4, 1), (8, 2)]:
    cfg = ParsaConfig(k=k, backend="parallel_device", workers=workers,
                      merge_every=m, block_size=64, refine_v=False, seed=0)
    res = partition(g, cfg)
    assert (res.parts_u >= 0).all() and (res.parts_u < k).all()
    sizes = np.bincount(res.parts_u, minlength=k)
    # balanced within the documented stale-catch-up bound (== 1 here since
    # k divides num_u and shards evenly)
    assert sizes.max() - sizes.min() <= max(1, workers), sizes
    need = need_matrix(g, res.parts_u, k)
    assert not (need & ~res.neighbor_sets).any()
    assert res.traffic.stale_pushes_missed > 0  # real concurrency exercised
    print("ok", workers, m, res.traffic)
print("PARALLEL_DEVICE_SMOKE_OK")
"""
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=600)
    assert "PARALLEL_DEVICE_SMOKE_OK" in out.stdout, out.stdout + out.stderr


# ------------------------------------------------------------- shard_parsa
def test_shard_parsa_step_single_device():
    """One Alg-4 round through shard_map on a 1-wide data axis."""
    from jax.sharding import Mesh, PartitionSpec as P

    from repro.compat import shard_map

    g = text_like(256, 400, mean_len=12, seed=8)
    k, block = 4, 64
    packed = pack_graph_blocks(g, block)
    body = shard_parsa_step(k, axis="data", use_kernel=False)
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    W = (g.num_v + 31) // 32
    fn = shard_map(body, mesh=mesh, in_specs=(P(),) * 8,
                   out_specs=(P(), P(), P()), check_vma=False)
    parts, merged, sizes = fn(
        jnp.asarray(packed.valid), jnp.asarray(packed.widx),
        jnp.asarray(packed.vals), jnp.asarray(packed.trunc),
        jnp.asarray(packed.tr_ids), jnp.asarray(packed.tr_masks),
        jnp.zeros((k, W), jnp.int32), jnp.zeros((k,), jnp.int32))
    parts = np.asarray(parts).reshape(-1)[: g.num_u]
    assert (parts >= 0).all()
    sizes_np = np.bincount(parts, minlength=k)
    assert sizes_np.max() - sizes_np.min() <= 1
    assert np.array_equal(np.asarray(sizes), sizes_np)
    # merged S_i == union of assigned vertices' neighborhoods
    want = np.zeros((k, W), np.uint32)
    for local, u in enumerate(packed.order):
        i = parts[local]
        nb = pack_bitmask([g.neighbors(int(u))], g.num_v).view(np.uint32)[0]
        want[i] |= nb
    assert np.array_equal(np.asarray(merged).view(np.uint32), want)


@pytest.mark.parametrize("select", ["rounds", "seq"])
def test_shard_parsa_step_padded_blocks(select):
    """Ragged U-shards: padding rows must not leak into sizes or S."""
    from jax.sharding import Mesh, PartitionSpec as P

    from repro.compat import shard_map

    g = text_like(150, 300, mean_len=10, seed=3)  # 150 % 64 != 0 → padding
    k, block = 4, 64
    packed = pack_graph_blocks(g, block)
    body = shard_parsa_step(k, axis="data", use_kernel=False, select=select)
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    W = (g.num_v + 31) // 32
    fn = shard_map(body, mesh=mesh, in_specs=(P(),) * 8,
                   out_specs=(P(), P(), P()), check_vma=False)
    parts, merged, sizes = fn(
        jnp.asarray(packed.valid), jnp.asarray(packed.widx),
        jnp.asarray(packed.vals), jnp.asarray(packed.trunc),
        jnp.asarray(packed.tr_ids), jnp.asarray(packed.tr_masks),
        jnp.zeros((k, W), jnp.int32), jnp.zeros((k,), jnp.int32))
    parts = np.asarray(parts).reshape(-1)
    real, pad = parts[: g.num_u], parts[g.num_u:]
    assert (real >= 0).all() and (pad == -1).all()
    # sizes count exactly the real vertices — no phantom picks
    assert int(np.asarray(sizes).sum()) == g.num_u
    assert np.array_equal(np.asarray(sizes),
                          np.bincount(real, minlength=k))
