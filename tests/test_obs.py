"""PR 10 observability: virtual-clock tracing through the closed serving
loop, flight-recorder causal attribution, deterministic exports, labeled
dispatch records, and the near-zero disabled overhead of the whole stack.
"""
import json
import time

import numpy as np
import pytest

from repro.api import (ChaosEvent, ChaosSchedule, ElasticConfig,
                       ElasticSession, Observability, ParsaConfig,
                       ParsaStreamConfig, StreamSession, chrome_trace_json,
                       prometheus_text, save_chrome_trace)
from repro.core import random_parts
from repro.core.jax_partition import (DispatchLog, annotate_dispatch,
                                      dispatch_counter)
from repro.elastic import SLOAutoscaler, SLOConfig
from repro.graphs import ctr_like, text_like
from repro.ml import DBPGConfig, PSCluster
from repro.obs import (CAUSE_KINDS, FlightRecorder, Tracer, to_chrome_trace,
                       trace_instant)
from repro.runtime import RetryPolicy
from repro.serving import (PSRequestSource, RequestMix, ServingConfig,
                           ServingEngine, ZipfWorkload)

K = 4
N_SLOTS = 96


# -------------------------------------------------------------- fixtures
@pytest.fixture(scope="module")
def serving_graph():
    g = ctr_like(600, 1200, nnz_per_row=12, clusters=8, locality=0.85,
                 seed=0)
    labels = np.where(np.random.default_rng(0).random(g.num_u) < 0.5,
                      1.0, -1.0).astype(np.float32)
    return g, labels


def _mix():
    return RequestMix((
        ZipfWorkload("heavy", batch=24, zipf_s=1.1, weight=3.0),
        ZipfWorkload("light", batch=16, zipf_s=1.3, hot_offset=7,
                     weight=1.0),
    ))


def _cluster(g, labels, parts_u, bandwidth=2.5e5, k=K):
    dcfg = DBPGConfig(lam=0.05, lr=0.1, kkt_eps=0.0, compress=False,
                      error_feedback=False)
    cl = PSCluster(g, labels, parts_u.copy(), random_parts(g.num_v, k, 1),
                   k, dcfg, bandwidth=bandwidth)
    cl.commit_weights(np.random.default_rng(1).normal(
        0, 0.1, g.num_v).astype(np.float32))
    return cl


def _chaos():
    """Burst -> calm -> kill -> straggle -> recover: every cause kind the
    recorder can attribute, in one seeded script."""
    return ChaosSchedule([
        ChaosEvent(feed=8, kind="burst", factor=2.5),
        ChaosEvent(feed=40, kind="burst", factor=1.0),
        ChaosEvent(feed=48, kind="kill"),
        ChaosEvent(feed=64, kind="straggle", machine=1, factor=4.0),
        ChaosEvent(feed=80, kind="recover", machine=1),
    ], seed=0)


def _closed_loop_run(g, labels, obs, chaos=True, n_slots=N_SLOTS):
    """One full closed-loop run on fresh state with obs threaded through
    every layer via the config hooks; returns (engine, src, sess, asc)."""
    slo_cfg = SLOConfig(slo_ms=16.0, window_requests=8, decide_every=8,
                        warmup_windows=1, patience=1, cooldown_windows=0,
                        min_k=K, max_k=K + 3, obs=obs)
    asc = SLOAutoscaler(slo_cfg)
    scfg = ParsaStreamConfig(base=ParsaConfig(
        k=K, backend="device_scan", refine_v=False, seed=0))
    sess = ElasticSession(
        ElasticConfig(stream=scfg, min_k=K, max_k=K + 3),
        num_v=g.num_v, policy=asc)
    sess.feed(g)
    cfg = ServingConfig(
        prefetch=True, warmup=2, seed=0, pad_multiple=512,
        retry=RetryPolicy(timeout_s=0.004, retries=0),
        service_model_s=2e-3, max_backlog_s=0.1,
        window_requests=slo_cfg.window_requests, obs=obs)
    src = PSRequestSource(_cluster(g, labels, np.asarray(sess.parts),
                                   bandwidth=6e4),
                          _mix(), cfg,
                          chaos=_chaos() if chaos else None,
                          elastic=sess, autoscaler=asc)
    engine = ServingEngine(src)
    engine.run(n_slots)
    return engine, src, sess, asc


# ------------------------------------------------- determinism (tentpole)
@pytest.fixture(scope="module")
def traced_runs(serving_graph):
    g, labels = serving_graph
    obs1, obs2 = Observability(), Observability()
    run1 = _closed_loop_run(g, labels, obs1)
    _closed_loop_run(g, labels, obs2)
    return run1, obs1, obs2


def test_seeded_replays_export_byte_identical_streams(traced_runs):
    """The acceptance bit: two seeded chaos replays produce byte-identical
    trace JSON and recorder streams (wall clocks and jit-cache evidence
    excluded by the deterministic export)."""
    _, obs1, obs2 = traced_runs
    assert len(obs1.tracer.spans) > 100
    assert chrome_trace_json(obs1.tracer) == chrome_trace_json(obs2.tracer)
    assert obs1.recorder.to_json() == obs2.recorder.to_json()
    # wall clocks were measured (ride along, excluded from the diff)
    assert any(sp.wall_s is not None for sp in obs1.tracer.spans)


def test_trace_covers_every_layer(traced_runs):
    (_, _, _, _), obs, _ = traced_runs
    names = {sp.name for sp in obs.tracer.spans}
    # engine request tree
    assert {"request", "pull", "compute", "push"} <= names
    # deep-layer instants via the installed-tracer registry
    assert {"ps.plan_pull", "ps.pull_nowait"} <= names
    assert any(n.startswith("dispatch:") for n in names)
    # recorder saw the whole story
    kinds = {ev.kind for ev in obs.recorder.events}
    assert {"chaos", "window", "elastic_op", "decision"} <= kinds


def test_request_span_tree_nests_correctly(traced_runs):
    (_, _, _, _), obs, _ = traced_runs
    by_id = {sp.span_id: sp for sp in obs.tracer.spans}
    roots = [sp for sp in obs.tracer.spans
             if sp.name == "request" and not sp.instant]
    assert roots
    eps = 1e-9
    for root in roots:
        kids = [sp for sp in obs.tracer.spans
                if sp.parent_id == root.span_id and not sp.instant]
        kid_names = {sp.name for sp in kids}
        assert {"pull", "compute", "push"} <= kid_names, kid_names
        for sp in kids:
            assert sp.trace_id == root.trace_id
            assert sp.v_start >= root.v_start - eps
            assert (sp.v_start + sp.v_dur
                    <= root.v_start + root.v_dur + eps), (sp, root)
        pull = next(sp for sp in kids if sp.name == "pull")
        compute = next(sp for sp in kids if sp.name == "compute")
        push = next(sp for sp in kids if sp.name == "push")
        # pull, then compute, then push on the virtual timeline
        assert compute.v_start == pytest.approx(
            pull.v_start + pull.v_dur, abs=1e-9)
        assert push.v_start == pytest.approx(
            compute.v_start + compute.v_dur, abs=1e-9)
        # wire/retry/queue live inside pull
        for sub in obs.tracer.spans:
            if sub.parent_id == pull.span_id:
                assert sub.name in ("wire", "retry", "queue")
                assert sub.v_start >= pull.v_start - eps
                assert (sub.v_start + sub.v_dur
                        <= pull.v_start + pull.v_dur + eps)
    # every non-root interval span's parent exists and contains it
    for sp in obs.tracer.spans:
        if sp.parent_id >= 0 and not sp.instant:
            parent = by_id[sp.parent_id]
            assert sp.v_start >= parent.v_start - eps
            assert (sp.v_start + sp.v_dur
                    <= parent.v_start + parent.v_dur + eps)


def test_explain_attributes_all_violated_windows(traced_runs):
    (_, _, _, asc), obs, _ = traced_runs
    slo_ms = asc.config.slo_ms
    violated = 0
    for i, (snap, _) in enumerate(asc.decisions):
        ex = obs.explain(i)
        if i < asc.config.warmup_windows or snap.p99_ms <= slo_ms:
            assert ex.verdict == "within-slo" or ex.attributed
            continue
        violated += 1
        assert ex.verdict == "violated"
        assert ex.attributed, f"window {i} unattributed: {ex}"
        assert all(c["kind"] in CAUSE_KINDS for c in ex.causes)
        assert "VIOLATED" in str(ex) and "<-" in str(ex)
    assert violated >= 1, "chaos script never stressed the loop"


def test_perfetto_export_format(traced_runs, tmp_path):
    (_, _, _, _), obs, _ = traced_runs
    paths = obs.save(tmp_path, prefix="run")
    doc = json.loads(paths["trace"].read_text())
    assert doc["displayTimeUnit"] == "ms"
    evs = doc["traceEvents"]
    assert evs[0] == {"name": "process_name", "ph": "M", "pid": 0,
                      "args": {"name": "parsa virtual clock"}}
    tracks = {e["args"]["name"] for e in evs if e["name"] == "thread_name"}
    assert "elastic" in tracks and any(t.startswith("home") for t in tracks)
    complete = [e for e in evs if e.get("ph") == "X"]
    instants = [e for e in evs if e.get("ph") == "i"]
    assert complete and instants
    for e in complete:
        assert e["ts"] >= 0 and e["dur"] >= 0
    # the saved (include_wall=True) variant carries measured evidence
    assert any("wall_ms" in e["args"] for e in complete)
    # recorder snapshot round-trips
    rec = FlightRecorder.load(paths["events"])
    assert rec.to_json() == obs.recorder.to_json()


# --------------------------------------------------------- stream/elastic
def test_stream_feed_and_elastic_op_spans():
    g = text_like(800, 1024, mean_len=12, seed=0)
    obs = Observability()
    scfg = ParsaStreamConfig(base=ParsaConfig(
        k=K, backend="device_scan", refine_v=False, seed=0))
    sess = ElasticSession(ElasticConfig(stream=scfg, min_k=2, max_k=K + 2),
                          num_v=g.num_v, obs=obs)
    assert sess.stream.obs is obs          # one hook covers the stack
    sess.feed(g.slice_u(0, 400))
    sess.feed(g.slice_u(400, 800))
    feeds = [sp for sp in obs.tracer.spans if sp.name == "feed"]
    assert len(feeds) == 2
    # the virtual clock advances one unit per feed
    assert feeds[1].v_start == pytest.approx(feeds[0].v_start + 1.0)
    for f in feeds:
        kids = {sp.name for sp in obs.tracer.spans
                if sp.parent_id == f.span_id}
        assert {"pack", "scan", "metrics"} <= kids

    op = sess.repair(int(np.argmax(np.bincount(sess.parts, minlength=K))),
                     mode="warm")
    assert op.committed
    ops = [sp for sp in obs.tracer.spans if sp.name == "elastic_op"]
    assert ops and ops[-1].attrs["kind"] == "repair"
    assert ops[-1].wall_s is not None
    kids = {sp.name for sp in obs.tracer.spans
            if sp.parent_id == ops[-1].span_id}
    assert kids == {"plan", "scan", "migrate"}


# --------------------------------------------------- explain() unit tests
def _window(rec, idx, step, p99, slo=10.0):
    rec.record("window", step=step, window=idx, p99_ms=p99, slo_ms=slo,
               within=p99 <= slo)


def test_explain_burst_interval_and_drain_lookback():
    rec = FlightRecorder()
    rec.record("chaos", step=4, data={"kind": "burst", "factor": 3.0,
                                      "machine": None})
    _window(rec, 0, step=8, p99=50.0)       # during the burst
    rec.record("chaos", step=10, data={"kind": "burst", "factor": 1.0,
                                       "machine": None})
    _window(rec, 1, step=16, p99=30.0)      # calm, still draining backlog
    _window(rec, 2, step=24, p99=5.0)       # recovered
    ex0 = rec.explain(0)
    assert ex0.verdict == "violated" and ex0.attributed
    assert [c["kind"] for c in ex0.causes] == ["burst"]
    assert "still in force" not in ex0.causes[0]["detail"] or True
    # window 1 violated after the calm: the burst interval [4, 10) still
    # intersects its lookback (drain attribution)
    ex1 = rec.explain(1)
    assert ex1.attributed and ex1.causes[0]["kind"] == "burst"
    # window 2 within SLO: no causes, str() says so
    ex2 = rec.explain(2)
    assert ex2.verdict == "within-slo" and ex2.causes == []
    assert "within SLO" in str(ex2)


def test_explain_kill_until_repair_then_migration():
    rec = FlightRecorder()
    rec.record("chaos", step=5, data={"kind": "kill", "machine": 2,
                                      "factor": None})
    _window(rec, 0, step=8, p99=40.0)
    ex = rec.explain(0)
    assert [c["kind"] for c in ex.causes] == ["kill"]
    assert "not repaired" in ex.causes[0]["detail"]
    rec.record("elastic_op", step=9,
               data={"kind": "repair", "committed": True, "machine": 2,
                     "k_before": 4, "k_after": 4, "migration_bytes": 128})
    _window(rec, 1, step=16, p99=30.0)
    ex1 = rec.explain(1)
    kinds = sorted(c["kind"] for c in ex1.causes)
    assert kinds == ["kill", "migration"]          # closed kill + the op
    # an uncommitted op is not a cause
    rec2 = FlightRecorder()
    rec2.record("elastic_op", step=3,
                data={"kind": "grow", "committed": False, "machine": 1,
                      "k_before": 4, "k_after": 5})
    _window(rec2, 0, step=8, p99=40.0)
    assert rec2.explain(0).causes == []


def test_explain_unknown_window_raises():
    rec = FlightRecorder()
    with pytest.raises(KeyError):
        rec.explain(7)


def test_recorder_bounded_and_kwarg_collisions():
    rec = FlightRecorder(maxlen=4)
    for i in range(10):
        rec.record("shed", step=i, tenant="t")
    assert len(rec) == 4
    assert [ev.step for ev in rec.events] == [6, 7, 8, 9]
    assert [ev.seq for ev in rec.events] == [6, 7, 8, 9]  # seq keeps going
    # data= carries payload keys colliding with the parameter names
    ev = rec.record("chaos", step=1, data={"kind": "burst", "step": 99},
                    factor=2.0)
    assert ev.kind == "chaos" and ev.step == 1
    assert ev.data == {"kind": "burst", "step": 99, "factor": 2.0}


# ----------------------------------------------------------- prometheus
def test_prometheus_text_unifies_counters(traced_runs):
    (engine, src, sess, _), obs, _ = traced_runs
    with dispatch_counter() as counts:
        pass
    text = prometheus_text(latency=engine.recorder, telemetry=src.telemetry,
                           traffic=sess.traffic, meter=src.cluster.meter,
                           dispatches=counts)
    for fam in ("parsa_serving_requests_total", "parsa_serving_latency_ms",
                "parsa_telemetry_p99_ms", "parsa_telemetry_speed_ratio",
                "parsa_stream_migration_bytes_total",
                "parsa_ps_inter_bytes_total"):
        assert f"# TYPE {fam}" in text, fam
    for line in text.splitlines():
        if line.startswith("#") or not line:
            continue
        name_labels, value = line.rsplit(" ", 1)
        float(value)                                  # parses
        assert name_labels.startswith("parsa_")
    assert 'stat="p99"' in text and 'clock="modeled"' in text


def test_prometheus_dispatch_families():
    g = text_like(400, 512, mean_len=10, seed=0)
    from repro.api import partition
    with dispatch_counter() as counts:
        partition(g, ParsaConfig(k=4, backend="device_scan",
                                 refine_v=False, seed=0))
    text = prometheus_text(dispatches=counts)
    assert 'parsa_dispatch_total{phase="partition_scan"} 1' in text
    assert 'parsa_dispatch_bytes_total{phase="partition_scan"}' in text


# ------------------------------------------------- labeled dispatch log
def test_dispatch_log_labeled_records_back_compat():
    g = text_like(400, 512, mean_len=10, seed=0)
    from repro.api import partition
    with dispatch_counter() as counts:
        partition(g, ParsaConfig(k=4, backend="device_scan",
                                 refine_v=False, seed=0))
    # the pre-PR-10 contract: a dict of phase -> count
    assert isinstance(counts, DispatchLog) and isinstance(counts, dict)
    assert counts["partition_scan"] == 1
    assert counts == dict(counts)
    # the labeled upgrade rides along
    recs = [r for r in counts.records if r.phase == "partition_scan"]
    assert len(recs) == 1 and recs[0].nbytes > 0
    assert recs[0].meta.get("k") == 4
    assert counts.bytes_by_phase()["partition_scan"] == recs[0].nbytes


def test_annotate_dispatch_updates_last_record():
    from repro.core.jax_partition import _count_dispatch
    with dispatch_counter() as counts:
        _count_dispatch("phase_a", nbytes=10)
        _count_dispatch("phase_b", nbytes=20, k=2)
        annotate_dispatch(cache_miss=True)
    assert counts.records[-1].meta == {"k": 2, "cache_miss": True}
    assert counts.records[0].meta == {}
    assert counts == {"partition_scan": 0, "phase_a": 1, "phase_b": 1}


def test_cache_miss_annotations_stripped_from_deterministic_export():
    tr = Tracer()
    sp = tr.begin("request", v_start=0.0, v_dur=1.0)
    tr.push(sp)
    tr.instant("dispatch:serving_compute", cache_miss=True, nbytes=4)
    tr.pop()
    det = chrome_trace_json(tr)
    assert "cache_miss" not in det
    assert "cache_miss" in chrome_trace_json(tr, include_wall=True)


# -------------------------------------------------------- disabled cost
def test_obs_disabled_zero_spans_and_cheap_hooks(serving_graph):
    g, labels = serving_graph
    # no obs anywhere: the installed registry stays empty during a run
    t0 = time.perf_counter()
    engine, src, sess, _ = _closed_loop_run(g, labels, obs=None,
                                            n_slots=32)
    off_s = time.perf_counter() - t0
    assert src.obs is None and sess.obs is None and engine.obs is None
    # the module-level hook with nothing installed: one truthiness check
    n = 50_000
    t0 = time.perf_counter()
    for _ in range(n):
        trace_instant("noop", a=1)
    per_call = (time.perf_counter() - t0) / n
    assert per_call < 5e-6, f"disabled trace_instant {per_call*1e6:.2f}us"
    # and the engine with obs off is not slower than with obs on
    # (generous band + absolute slack: shared CI runners jitter)
    t0 = time.perf_counter()
    _closed_loop_run(g, labels, obs=Observability(), n_slots=32)
    on_s = time.perf_counter() - t0
    assert off_s <= 1.5 * on_s + 0.5, (off_s, on_s)


def test_tracer_span_bound():
    tr = Tracer(max_spans=8)
    for i in range(20):
        tr.begin(f"s{i}", v_start=float(i), v_dur=1.0)
    assert len(tr.spans) == 8
    assert tr.spans[0].name == "s12"        # oldest dropped


# ------------------------------------------------------- bench schemas
def test_validate_bench_files(tmp_path):
    report = pytest.importorskip(
        "benchmarks.report",
        reason="benchmarks package importable from repo root only")
    payloads = report.validate_bench_files(tmp_path)
    assert set(payloads) == {"BENCH_pipeline.json", "BENCH_system.json",
                             "BENCH_parsa.json"}
    for payload in payloads.values():
        assert payload["schema_version"] == report.SCHEMA_VERSION
    # the helper ran against the scratch dir, not the real trajectories
    assert (tmp_path / "BENCH_pipeline.json").exists()
    assert report.ROOT.name != str(tmp_path)
