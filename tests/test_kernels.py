"""Per-kernel shape/dtype sweeps vs pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import attention_ref, flash_attention
from repro.kernels.parsa_cost import pack_bitmask, parsa_cost, parsa_cost_ref


# ------------------------------------------------------------- parsa_cost
@pytest.mark.parametrize("num_v", [33, 256, 1000])
@pytest.mark.parametrize("U,K", [(7, 3), (64, 16), (130, 8)])
def test_parsa_cost_sweep(num_v, U, K):
    rng = np.random.default_rng(U * K + num_v)
    nbr_sets = [rng.choice(num_v, size=rng.integers(0, min(50, num_v)),
                           replace=False) for _ in range(U)]
    s_bool = rng.random((K, num_v)) < 0.3
    nbr = jnp.asarray(pack_bitmask(nbr_sets, num_v))
    s = jnp.asarray(pack_bitmask(s_bool, num_v))
    got = np.asarray(parsa_cost(nbr, s, bu=32, bw=128))
    want = np.asarray(parsa_cost_ref(nbr, s))
    assert np.array_equal(got, want)
    # python-set oracle on a sample
    for u in rng.choice(U, size=min(5, U), replace=False):
        for i in range(K):
            exact = len(set(nbr_sets[u]) - set(np.flatnonzero(s_bool[i])))
            assert got[u, i] == exact


def test_parsa_cost_empty_sets():
    num_v = 64
    nbr = jnp.asarray(pack_bitmask([np.arange(10)], num_v))
    s = jnp.asarray(pack_bitmask(np.zeros((2, num_v), bool), num_v))
    got = np.asarray(parsa_cost(nbr, s))
    assert (got == 10).all()


# --------------------------------------------------------- flash attention
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,Sq,H,KV,D,causal,window",
    [
        (2, 128, 4, 4, 64, True, None),
        (1, 256, 4, 2, 64, True, None),
        (2, 128, 2, 2, 32, True, 64),
        (1, 64, 2, 1, 128, False, None),
        (1, 128, 8, 8, 16, True, None),
    ],
)
def test_flash_attention_sweep(B, Sq, H, KV, D, causal, window, dtype):
    rng = np.random.default_rng(B * Sq + H + D)
    q = jnp.asarray(rng.normal(0, 1, (B, Sq, H, D)), dtype)
    k = jnp.asarray(rng.normal(0, 1, (B, Sq, KV, D)), dtype)
    v = jnp.asarray(rng.normal(0, 1, (B, Sq, KV, D)), dtype)
    got = flash_attention(q, k, v, causal=causal, window=window, bq=64, bk=64)
    kr, vr = jnp.repeat(k, H // KV, 2), jnp.repeat(v, H // KV, 2)
    want = attention_ref(q.astype(jnp.float32), kr.astype(jnp.float32),
                         vr.astype(jnp.float32), causal=causal, window=window)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=tol, rtol=tol)


def test_flash_matches_model_chunked_path():
    """The XLA chunked attention (dry-run path) and the Pallas kernel agree."""
    from repro.models.layers import attention

    rng = np.random.default_rng(0)
    B, S, H, D = 2, 256, 4, 32
    q = jnp.asarray(rng.normal(0, 1, (B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (B, S, H, D)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (B, S, H, D)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    a = attention(q / np.sqrt(D) * np.sqrt(D), k, v, q_positions=pos,
                  k_positions=pos, causal=True, impl="chunked", chunk=64,
                  dtype=jnp.float32)
    b = flash_attention(q, k, v, causal=True, bq=64, bk=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-5, rtol=3e-5)
