"""Property tests for the §4.1 vertex-selection structure."""
import heapq

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.bucket_queue import BucketQueue


@given(
    costs=st.lists(st.integers(0, 50), min_size=1, max_size=200),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=50, deadline=None)
def test_matches_heap_under_random_ops(costs, seed):
    """Interleaved pop-min / decrease-key / delete must match a reference."""
    rng = np.random.default_rng(seed)
    q = BucketQueue(np.array(costs), theta=8)  # tiny theta → overflow exercised
    ref = {i: c for i, c in enumerate(costs)}
    for _ in range(len(costs) * 2):
        if not ref:
            break
        op = rng.integers(0, 3)
        if op == 0:
            i, c = q.pop_min()
            best = min(ref.values())
            assert c == best == ref[i]
            del ref[i]
        elif op == 1:
            i = int(rng.choice(list(ref)))
            new = int(rng.integers(0, ref[i] + 1))
            q.decrease(i, new)
            ref[i] = min(ref[i], new)
        else:
            i = int(rng.choice(list(ref)))
            q.delete(i)
            del ref[i]
    assert len(q) == len(ref)


def test_monotone_pop_order():
    rng = np.random.default_rng(0)
    costs = rng.integers(0, 2000, size=500)  # beyond theta
    q = BucketQueue(costs, theta=100)
    out = [q.pop_min()[1] for _ in range(500)]
    assert out == sorted(out)


def test_decrease_below_min_bucket():
    q = BucketQueue(np.array([5, 9]), theta=10)
    q.decrease(1, 0)
    assert q.pop_min() == (1, 0)
    assert q.pop_min() == (0, 5)
