"""Alg 4 parallel Parsa: staleness robustness (§5.4) + the TPU-native
blocked/bitmask reformulation (DESIGN §2)."""
import numpy as np
import pytest

from repro.core import (
    ParallelParsa, evaluate, global_initialization, partition_v, random_parts,
)
from repro.core.jax_partition import blocked_partition_u
from repro.graphs import text_like


def _quality(g, parts_u, k):
    return evaluate(g, parts_u, partition_v(g, parts_u, k), k).traffic_max


def test_parallel_matches_sequential_quality(small_text_graph):
    g, k = small_text_graph, 8
    seq = ParallelParsa(k, workers=1, tau=0).run(g, b=8)
    par = ParallelParsa(k, workers=4, tau=2).run(g, b=8)
    q_seq, q_par = _quality(g, seq.parts_u, k), _quality(g, par.parts_u, k)
    # §5.4: staleness costs at most a few percent (allow 25% on tiny graphs)
    assert q_par <= q_seq * 1.25
    assert par.stale_pushes_missed > 0  # staleness actually exercised


def test_eventual_consistency_still_beats_random(small_text_graph):
    g, k = small_text_graph, 8
    par = ParallelParsa(k, workers=8, tau=None).run(g, b=16)
    rand = _quality(g, random_parts(g.num_u, k, 0), k)
    assert _quality(g, par.parts_u, k) < rand


def test_global_initialization_helps(small_ctr_graph):
    g, k = small_ctr_graph, 8
    cold = ParallelParsa(k, workers=4, tau=1, seed=1).run(g, b=8)
    S0 = global_initialization(g, k, sample_frac=0.1, seed=1)
    warm = ParallelParsa(k, workers=4, tau=1, seed=1).run(g, b=8, init_sets=S0)
    assert _quality(g, warm.parts_u, k) <= _quality(g, cold.parts_u, k) * 1.1


def test_parallel_sim_w1_tau0_equals_host_backend(small_text_graph):
    """Degenerate parity: one worker with no delay is the §4.2 sequential
    subgraph stream — bit-identical parts and (packed) sets vs the host
    backend at the same block count."""
    from repro.api import ParsaConfig, partition
    from repro.core.parallel import parallel_parsa_impl
    from repro.kernels.parsa_cost import pack_bitmask

    g, k, b = small_text_graph, 8, 8
    host = partition(g, ParsaConfig(k=k, backend="host", blocks=b, seed=3,
                                    refine_v=False))
    rep, s_packed = parallel_parsa_impl(g, k, b=b, workers=1, tau=0, seed=3)
    assert np.array_equal(rep.parts_u, host.parts_u)
    assert np.array_equal(s_packed, pack_bitmask(host.neighbor_sets, g.num_v))
    assert rep.stale_pushes_missed == 0


def test_parallel_sim_server_stays_packed_no_dense_snapshot(small_text_graph):
    """The satellite guarantee: the server state is packed words end to end
    and the worker pull is handed to Alg 3 without a per-task dense copy —
    the scratch partition_u_impl mutates IS the array it was given."""
    import repro.core.parallel as par

    g, k = small_text_graph, 8
    adopted = []
    real = par.partition_u_impl

    def spy(sg, kk, init_sets=None, copy_init=True, **kw):
        res = real(sg, kk, init_sets=init_sets, copy_init=copy_init, **kw)
        adopted.append(res.neighbor_sets is init_sets)
        return res

    par.partition_u_impl = spy
    try:
        rep, s_packed = par.parallel_parsa_impl(g, k, b=4, a=2, workers=2,
                                                tau=1, seed=0)
    finally:
        par.partition_u_impl = real
    assert adopted and all(adopted)  # no dense snapshot between pull and run
    assert s_packed.dtype == np.int32
    assert s_packed.shape == (k, (g.num_v + 31) // 32)
    assert (rep.parts_u >= 0).all()


def test_parallel_sim_peak_memory_bounded():
    """Allocation assertion: with the packed server state, peak incremental
    memory stays near ONE dense (k, |V|) worker scratch — the old dense
    server + per-task snapshot + Alg-3 copy (3×dense concurrent, plus dense
    pending pushes) would blow this bound."""
    import tracemalloc

    from repro.core.parallel import parallel_parsa_impl
    from repro.graphs import text_like

    # k large enough that the dense (k, |V|) term dominates the
    # k-independent per-subgraph CSC transients
    g = text_like(300, 200_000, mean_len=10, seed=1)
    k, b = 64, 4
    dense_bytes = k * g.num_v  # one (k, |V|) bool scratch
    parallel_parsa_impl(g, k, b=b, workers=2, tau=1, seed=0)  # warm imports
    tracemalloc.start()
    base = tracemalloc.get_traced_memory()[0]
    parallel_parsa_impl(g, k, b=b, workers=2, tau=1, seed=0)
    peak = tracemalloc.get_traced_memory()[1]
    tracemalloc.stop()
    # one pull scratch + pack transients ≈ 2×dense; the old layout held
    # ≥ 3×dense concurrently (server + snapshot + Alg-3 copy) plus up to
    # W+τ dense pending pushes in flight
    assert peak - base < 2.5 * dense_bytes, (peak - base, dense_bytes)


def test_blocked_jax_partitioner(small_text_graph):
    """TPU-native blocked greedy: balanced, complete, beats random."""
    g, k = small_text_graph, 8
    parts = blocked_partition_u(g, k, block=128)
    assert np.all(parts >= 0)
    sizes = np.bincount(parts, minlength=k)
    assert sizes.max() - sizes.min() <= 1
    assert _quality(g, parts, k) < _quality(
        g, random_parts(g.num_u, k, 0), k)
