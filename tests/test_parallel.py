"""Alg 4 parallel Parsa: staleness robustness (§5.4) + the TPU-native
blocked/bitmask reformulation (DESIGN §2)."""
import numpy as np
import pytest

from repro.core import (
    ParallelParsa, evaluate, global_initialization, partition_v, random_parts,
)
from repro.core.jax_partition import blocked_partition_u
from repro.graphs import text_like


def _quality(g, parts_u, k):
    return evaluate(g, parts_u, partition_v(g, parts_u, k), k).traffic_max


def test_parallel_matches_sequential_quality(small_text_graph):
    g, k = small_text_graph, 8
    seq = ParallelParsa(k, workers=1, tau=0).run(g, b=8)
    par = ParallelParsa(k, workers=4, tau=2).run(g, b=8)
    q_seq, q_par = _quality(g, seq.parts_u, k), _quality(g, par.parts_u, k)
    # §5.4: staleness costs at most a few percent (allow 25% on tiny graphs)
    assert q_par <= q_seq * 1.25
    assert par.stale_pushes_missed > 0  # staleness actually exercised


def test_eventual_consistency_still_beats_random(small_text_graph):
    g, k = small_text_graph, 8
    par = ParallelParsa(k, workers=8, tau=None).run(g, b=16)
    rand = _quality(g, random_parts(g.num_u, k, 0), k)
    assert _quality(g, par.parts_u, k) < rand


def test_global_initialization_helps(small_ctr_graph):
    g, k = small_ctr_graph, 8
    cold = ParallelParsa(k, workers=4, tau=1, seed=1).run(g, b=8)
    S0 = global_initialization(g, k, sample_frac=0.1, seed=1)
    warm = ParallelParsa(k, workers=4, tau=1, seed=1).run(g, b=8, init_sets=S0)
    assert _quality(g, warm.parts_u, k) <= _quality(g, cold.parts_u, k) * 1.1


def test_blocked_jax_partitioner(small_text_graph):
    """TPU-native blocked greedy: balanced, complete, beats random."""
    g, k = small_text_graph, 8
    parts = blocked_partition_u(g, k, block=128)
    assert np.all(parts >= 0)
    sizes = np.bincount(parts, minlength=k)
    assert sizes.max() - sizes.min() <= 1
    assert _quality(g, parts, k) < _quality(
        g, random_parts(g.num_u, k, 0), k)
