"""repro.elastic: grow/shrink/repair determinism + O(1) dispatches,
chaos schedule replay, policy gating, straggler EWMA biasing, PSCluster
shard teardown/spawn, and the elastic satellites (need-pack int32
ceiling, drift cold window)."""
import numpy as np
import pytest

from repro.api import ParsaConfig, ParsaStreamConfig
from repro.api_backends import TrafficCounters
from repro.core.bipartite import BipartiteGraph
from repro.core.costs import evaluate, need_matrix
from repro.core.jax_partition import (
    _biased_perm,
    _weighted_block_targets,
    dispatch_counter,
)
from repro.elastic import (
    ChaosEvent,
    ChaosSchedule,
    ElasticConfig,
    ElasticPolicy,
    ElasticSession,
    FleetState,
    ThresholdPolicy,
)
from repro.graphs import ctr_like, ctr_like_stream
from repro.runtime import StragglerEWMA
from repro.stream.drift import DriftTracker


def _chunks(n=4, rows=600, num_v=1500, seed=1):
    return ctr_like_stream(rows, num_v, chunks=n, nnz_per_row=10,
                           churn=0.3, seed=seed)


def _ecfg(k=4, workers=1, **kw):
    if workers > 1:
        base = ParsaConfig(k=k, backend="parallel_device", workers=workers,
                           block_size=32, merge_every=1, refine_v=False)
    else:
        base = ParsaConfig(k=k, backend="device_scan", block_size=64,
                           refine_v=False)
    stream = ParsaStreamConfig(base=base, repartition="never",
                               repartition_frac=kw.pop("repartition_frac",
                                                       0.02))
    return ElasticConfig(stream=stream, min_k=2, max_k=16, **kw)


def _fed_session(cfg=None, n=3, **kw):
    cfg = cfg or _ecfg(**kw)
    sess = ElasticSession(cfg, num_v=1500)
    for ch in _chunks(n):
        sess.feed(ch)
    return sess


# ---------------------------------------------------------- elastic ops
def test_grow_one_dispatch_and_consistency():
    sess = _fed_session()
    k0 = sess.k
    before = np.bincount(sess.parts, minlength=k0)
    with dispatch_counter() as counts:
        op = sess.grow_k(force=True)
    assert op.committed and sess.k == k0 + 1
    # labeled records: exactly one grow scan, tagged with the split source
    scans = [r for r in counts.records if "scan" in r.phase]
    assert [r.phase for r in scans] == ["elastic_grow_scan"], \
        "grow must be O(1) jitted dispatches"
    assert scans[0].nbytes > 0 and scans[0].meta["machine"] == op.machine
    after = np.bincount(sess.parts, minlength=sess.k)
    # only the split source lost rows; the new machine hosts the rest
    assert after[op.machine] + after[k0] == before[op.machine]
    assert op.traffic.migration_bytes > 0
    # live masks stay exact N(U_i): popcount metrics match the oracle
    g = sess.stream.arena.graph()
    want = evaluate(g, sess.parts, None, sess.k)
    assert sess.stream._popcount_metrics().as_dict() == want.as_dict()


def test_shrink_zero_scans_and_consistency():
    sess = _fed_session()
    k0 = sess.k
    with dispatch_counter() as counts:
        op = sess.shrink_k(force=True)
    assert op.committed and sess.k == k0 - 1
    assert sum(v for n, v in counts.items() if "scan" in n) == 0
    assert not any("scan" in r.phase for r in counts.records)
    assert op.traffic.migration_bytes > 0
    assert sess.parts.max() < sess.k
    # merged masks = OR of the merged parts' need sets: still exact
    g = sess.stream.arena.graph()
    want = need_matrix(g, sess.parts, sess.k)
    got = sess.stream.arena.masks_np()
    from repro.kernels.parsa_cost import unpack_bitmask

    assert np.array_equal(unpack_bitmask(got, g.num_v), want)


def test_repair_one_dispatch_refills_lost_machine():
    sess = _fed_session(repartition_frac=0.0)
    lost = 1
    lost_rows = int((sess.parts == lost).sum())
    assert lost_rows > 0
    with dispatch_counter() as counts:
        op = sess.repair(lost)
    # labeled records: exactly one repair scan, tagged with the lost slot
    scans = [r for r in counts.records if "scan" in r.phase]
    assert [r.phase for r in scans] == ["elastic_repair_scan"]
    assert scans[0].meta["machine"] == lost and scans[0].meta["rows"] > 0
    assert op.mode == "warm" and op.moved_u == lost_rows
    assert op.traffic.migration_bytes > 0
    # with frac=0 the live sets stay exact need sets after the repair
    g = sess.stream.arena.graph()
    want = evaluate(g, sess.parts, None, sess.k)
    assert sess.stream._popcount_metrics().as_dict() == want.as_dict()
    assert sess.traffic.migration_bytes >= op.traffic.migration_bytes


def test_ops_bit_deterministic_under_fixed_seed():
    def run():
        sess = _fed_session()
        ops = [sess.grow_k(force=True), sess.repair(0),
               sess.shrink_k(force=True)]
        return sess, ops

    s1, o1 = run()
    s2, o2 = run()
    assert s1.k == s2.k
    assert np.array_equal(s1.parts, s2.parts)
    assert np.array_equal(s1.stream.arena.masks_np(),
                          s2.stream.arena.masks_np())
    for a, b in zip(o1, o2):
        assert a.traffic == b.traffic and a.moved_u == b.moved_u


def test_policy_veto_leaves_state_untouched():
    class NoPolicy:
        min_partitions, max_partitions = 2, 16

        def grow(self, state):
            return False

        def shrink(self, state):
            return False

        def repair(self, state):
            return "warm"

        def rebalance(self, state, weights):
            return None

    cfg = _ecfg()
    sess = ElasticSession(cfg, num_v=1500, policy=NoPolicy())
    for ch in _chunks(2):
        sess.feed(ch)
    parts0 = sess.parts.copy()
    masks0 = sess.stream.arena.masks_np().copy()
    traffic0 = sess.traffic
    op_g, op_s = sess.grow_k(), sess.shrink_k()
    assert not op_g.committed and not op_s.committed
    assert sess.k == 4
    assert np.array_equal(sess.parts, parts0)
    assert np.array_equal(sess.stream.arena.masks_np(), masks0)
    # vetoed candidates meter nothing into the session
    assert sess.traffic == traffic0


def test_threshold_policy_budget_gate():
    pol = ThresholdPolicy(min_k=2, max_k=8, budget_feeds=10)
    cheap = FleetState(4, 5, np.ones(4), np.ones(4),
                       migration_bytes=50, projected_savings=10)
    dear = FleetState(4, 5, np.ones(4), np.ones(4),
                      migration_bytes=5000, projected_savings=10)
    assert pol.grow(cheap) and not pol.grow(dear)
    assert pol.shrink(cheap) and not pol.shrink(dear)
    at_max = FleetState(8, 5, np.ones(8), np.ones(8), 0, 10**9)
    at_min = FleetState(2, 5, np.ones(2), np.ones(2), 0, 10**9)
    assert not pol.grow(at_max) and not pol.shrink(at_min)
    assert pol.repair(cheap) == "warm"
    assert isinstance(ThresholdPolicy(), ElasticPolicy)


# ------------------------------------------------------------- chaos
def test_chaos_schedule_deterministic_and_validated():
    ev = [ChaosEvent(3, "kill"), ChaosEvent(1, "straggle", factor=2.0),
          ChaosEvent(1, "add")]
    s1, s2 = ChaosSchedule(ev, seed=9), ChaosSchedule(ev, seed=9)
    assert s1.events == s2.events          # None targets resolve identically
    assert [e.kind for e in s1.at(1)] == ["straggle", "add"]
    assert s1.at(1) == []                  # served exactly once
    assert s1.remaining == 1
    s1.reset()
    assert s1.remaining == 3
    with pytest.raises(ValueError, match="kind"):
        ChaosEvent(0, "explode")
    with pytest.raises(ValueError, match="factor"):
        ChaosEvent(0, "straggle", factor=1.0)
    with pytest.raises(ValueError, match="feed"):
        ChaosEvent(-1, "kill")


def test_chaos_run_bit_deterministic():
    chaos_events = [ChaosEvent(1, "kill", 1), ChaosEvent(2, "add"),
                    ChaosEvent(3, "straggle", 0, 4.0)]

    def run():
        sess = ElasticSession(_ecfg(), num_v=1500,
                              chaos=ChaosSchedule(chaos_events, seed=5))
        for ch in _chunks(4):
            sess.feed(ch)
        return sess

    s1, s2 = run(), run()
    assert s1.k == s2.k
    assert np.array_equal(s1.parts, s2.parts)
    assert s1.traffic == s2.traffic
    kinds = [(o.kind, o.committed) for o in s1.ops]
    assert ("repair", True) in kinds and ("grow", True) in kinds


# --------------------------------------------------- straggler routing
def test_weighted_block_targets_apportionment():
    t = _weighted_block_targets(np.array([1.0, 1.0, 4.0, 2.0]), 16)
    assert t.sum() == 16
    assert t[2] == t.max() and t[2] == 8
    # degenerate: one worker owns everything
    t = _weighted_block_targets(np.array([0.0, 1.0]), 7)
    assert list(t) == [0, 7]


def test_biased_perm_routes_padding_to_slow_workers():
    targets = np.array([1, 7])
    nb, nb_per = 8, 7
    perm = _biased_perm(targets, nb, nb_per, None)
    assert perm.size == nb_per * 2
    shard = perm.reshape(2, nb_per)
    # worker 0 (slow): 1 real block + 6 padding; worker 1: 7 real
    assert (shard[0] < nb).sum() == 1 and (shard[1] < nb).sum() == 7
    assert sorted(p for p in perm if p < nb) == list(range(nb))


def test_straggler_ewma_seeds_lazily_and_floors():
    e = StragglerEWMA(4, alpha=0.5, floor=0.25)
    assert np.allclose(e.weights(), 1.0)      # no evidence, no penalty
    e.update([1.0, np.nan, 1.0, 1.0])         # missing sample skipped
    assert np.allclose(e.weights(), 1.0)
    e.update([1.0, 1.0, 100.0, 1.0])
    w = e.weights()
    assert w.argmin() == 2
    assert w[2] >= 0.25 / w.mean() * 0  # floored (never starved to zero)
    assert w[2] > 0
    with pytest.raises(ValueError, match="shape"):
        e.update([1.0])


def test_parallel_feed_with_bias_covers_all_rows():
    pytest.importorskip("jax")
    import jax

    if len(jax.devices()) < 2:
        pytest.skip("needs >= 2 devices (XLA_FLAGS host device count)")
    workers = min(4, len(jax.devices()))
    sess = _fed_session(cfg=_ecfg(workers=workers), n=3)
    sess._straggle[0] = 8.0               # synthetic straggler
    for ch in _chunks(2, seed=3):
        upd = sess.feed(ch)
    assert sess.parts.shape[0] == sess.stream.arena.num_u
    assert np.bincount(sess.parts, minlength=sess.k).sum() == \
        sess.parts.shape[0]
    w = sess.ewma.weights()
    assert w.argmin() == 0, "straggled worker must get the lowest weight"


# ------------------------------------------------------------ PS bridge
def test_ps_cluster_k_change_teardown_spawn():
    from repro.ml.dbpg import DBPGConfig
    from repro.ml.ps import PSCluster

    g = ctr_like(200, 400, nnz_per_row=8, seed=2)
    rng = np.random.default_rng(0)
    labels = rng.integers(0, 2, g.num_u).astype(np.float32)
    parts_u = rng.integers(0, 3, g.num_u).astype(np.int32)
    parts_v = rng.integers(0, 3, g.num_v).astype(np.int32)
    ps = PSCluster(g, labels, parts_u, parts_v, 3, DBPGConfig())
    ps.run(2)
    # grow 3 → 5
    pu5 = rng.integers(0, 5, g.num_u).astype(np.int32)
    pv5 = rng.integers(0, 5, g.num_v).astype(np.int32)
    rep = ps.apply_placement(pu5, pv5, k=5)
    assert ps.k == 5 and len(ps.batches) == 5 and len(ps._pull_cache) == 5
    assert ps.meter.per_machine.shape == (5,)
    assert ps._keys_sent.shape == (5, 5) and not ps._keys_sent.any()
    assert rep["reshard_bytes"] > 0
    ps.run(2)
    # shrink 5 → 2
    pu2 = pu5 % 2
    pv2 = pv5 % 2
    rep = ps.apply_placement(pu2, pv2, k=2)
    assert ps.k == 2 and len(ps.batches) == 2 and len(ps._pull_cache) == 2
    assert ps.meter.per_machine.shape == (2,)
    assert rep["reshard_bytes"] > 0
    ps.run(2)                              # training continues post-shrink
    with pytest.raises(ValueError, match="labels reach"):
        ps.apply_placement(pu5, pv2, k=2)


def test_sync_cluster_pushes_elastic_placement():
    from repro.ml.dbpg import DBPGConfig
    from repro.ml.ps import PSCluster

    sess = _fed_session(n=2)
    g = sess.stream.arena.graph()
    labels = np.zeros(g.num_u, np.float32)
    ps = PSCluster(g, labels, sess.parts.copy(),
                   np.full(g.num_v, -1, np.int32), sess.k, DBPGConfig())
    sess.grow_k(force=True)
    rep = sess.sync_cluster(ps)
    assert ps.k == sess.k
    assert np.array_equal(ps.parts_u, sess.parts)
    assert rep["moved_rows"] > 0


# ------------------------------------------------- stream k-change hook
def test_apply_partition_state_validates_shapes():
    sess = _fed_session(n=1)
    W_cap = sess.stream.arena.W_cap
    n = sess.parts.shape[0]
    with pytest.raises(ValueError, match="capacity-stable"):
        sess.stream.apply_partition_state(
            np.zeros(n, np.int32), np.zeros((5, W_cap + 1), np.int32), k=5)
    with pytest.raises(ValueError, match="U rows"):
        sess.stream.apply_partition_state(
            np.zeros(n + 3, np.int32), np.zeros((4, W_cap), np.int32))


def test_feed_after_k_change_keeps_streaming():
    sess = _fed_session(n=2)
    sess.grow_k(force=True)
    k_new = sess.k
    upd = sess.feed(_chunks(1, seed=9)[0])
    assert upd.metrics.k == k_new
    assert upd.dispatches.get("stream_feed_scan") == 1
    g = sess.stream.arena.graph()
    want = evaluate(g, sess.parts, None, sess.k)
    # frac>0 seeding makes popcounts an upper bound; exact when untripped
    got = sess.stream._popcount_metrics()
    assert got.traffic_sum >= want.traffic_sum


# ------------------------------------- satellite: need-pack int32 ceiling
def test_need_masks_int32_key_ceiling():
    import jax

    from repro.core.jax_refine import need_masks

    if jax.config.jax_enable_x64:
        pytest.skip("x64 enabled: the ceiling does not apply")
    # tiny edge list, huge declared num_v: k * num_v straddles 2^31
    num_v_ok = 2**31 // 4          # k*num_v == 2^31 exactly: max key fits
    num_v_bad = 2**31 // 4 + 1
    indptr = np.array([0, 1], np.int64)
    indices = np.array([0], np.int32)
    g_ok = BipartiteGraph(1, num_v_ok, indptr, indices)
    masks = need_masks(g_ok, np.zeros(1, np.int32), 4)
    assert masks.shape == (4, (num_v_ok + 31) // 32)
    g_bad = BipartiteGraph(1, num_v_bad, indptr, indices)
    with pytest.raises(ValueError, match="int32"):
        need_masks(g_bad, np.zeros(1, np.int32), 4)


# --------------------------------------- satellite: drift cold window
def test_drift_tracker_cold_window_lazy_seed():
    from repro.core.costs import PartitionMetrics

    def metrics(max_foot, k=4):
        foot = np.full(k, 50, np.int64)    # growth concentrates on machine 0
        foot[0] = max_foot
        return PartitionMetrics(k, np.ones(k, np.int64), foot, foot.copy(),
                                foot.copy(), np.zeros(k, np.int64))

    # a zero-seeded window mean would make the very first update trip at
    # any threshold; the lazy seed must keep the first feeds quiet
    t = DriftTracker(window=8, threshold=1.0, min_feeds=1)
    d0 = t.update(metrics(100))
    assert not d0.repartition and d0.baseline == pytest.approx(d0.drift)
    d1 = t.update(metrics(100))            # steady ratio: still no trip
    assert not d1.repartition
    # partially-filled window averages the 2 real entries, never the 6
    # unobserved slots
    assert d1.baseline == pytest.approx(d0.drift)
    d2 = t.update(metrics(300))            # genuine degradation trips
    assert d2.repartition
    # after reset the window re-seeds lazily again (no stale entries)
    d3 = t.update(metrics(300))
    assert not d3.repartition and d3.baseline == pytest.approx(d3.drift)


def test_migration_bytes_accumulates_separately():
    a = TrafficCounters(pushed_bytes=8, migration_bytes=100)
    b = TrafficCounters(pulled_bytes=4, migration_bytes=50)
    s = a + b
    assert s.migration_bytes == 150
    assert (s.pushed_bytes, s.pulled_bytes) == (8, 4)
