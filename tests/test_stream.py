"""repro.stream: online incremental Parsa — arena growth, one-chunk
degenerate parity vs device_scan, O(1) dispatches per feed, the
padding-bit invariant of the ragged last packed word, drift-triggered
repartition + migration metering, snapshot round trips, and the PSCluster
mid-run placement update."""
import numpy as np
import pytest

from repro.api import ParsaConfig, ParsaStreamConfig, StreamSession, partition
from repro.api_backends import TrafficCounters
from repro.core.bipartite import BipartiteGraph, from_edges, load_npz
from repro.core.costs import evaluate, need_matrix
from repro.core.jax_partition import dispatch_counter
from repro.graphs import (
    ctr_like_stream,
    social_like_stream,
    text_like,
    text_like_stream,
)
from repro.kernels.parsa_cost import (
    pack_bitmask,
    packed_delta,
    packed_intersect_counts,
    packed_union,
    packed_union_delta,
    unpack_bitmask,
)
from repro.stream import StreamArena, stream_partition


def _stream_cfg(k=4, **kw):
    base = ParsaConfig(k=k, backend="device_scan", block_size=64,
                       use_kernel=False, refine_v=False)
    return ParsaStreamConfig(base=base, **kw)


# ----------------------------------------------------------- satellite: io
def test_save_npz_round_trip(tmp_path):
    g = text_like(300, 777, mean_len=12, seed=5)   # 777 % 32 != 0
    path = tmp_path / "graph.npz"
    g.save_npz(path)
    g2 = load_npz(path)
    assert (g2.num_u, g2.num_v) == (g.num_u, g.num_v)
    assert np.array_equal(g2.u_indptr, g.u_indptr)
    assert np.array_equal(g2.u_indices, g.u_indices)
    g2.validate()


def test_arena_snapshot_round_trip(tmp_path):
    cfg = _stream_cfg()
    sess = StreamSession(cfg, num_v=500)
    for ch in text_like_stream(300, 500, chunks=3, mean_len=10, seed=2):
        sess.feed(ch)
    path = tmp_path / "arena.npz"
    sess.arena.save(path)
    arena2 = StreamArena.load(path)
    assert arena2.num_u == sess.arena.num_u
    assert arena2.num_v == sess.arena.num_v
    g1, g2 = sess.arena.graph(), arena2.graph()
    assert np.array_equal(g1.u_indptr, g2.u_indptr)
    assert np.array_equal(g1.u_indices, g2.u_indices)
    assert np.array_equal(np.asarray(sess.arena.s_masks),
                          np.asarray(arena2.s_masks))
    assert np.array_equal(np.asarray(sess.arena.sizes),
                          np.asarray(arena2.sizes))


def test_session_snapshot_resumes_bit_identically(tmp_path):
    """StreamSession.save/load restores the FULL stream state: resuming
    the same chunk sequence produces bit-identical parts and sets."""
    chunks = text_like_stream(450, 700, chunks=3, mean_len=10, seed=8)
    cfg = _stream_cfg(repartition="never")
    sess = StreamSession(cfg, num_v=700)
    sess.feed(chunks[0])
    sess.feed(chunks[1])
    path = tmp_path / "session.npz"
    sess.save(path)
    restored = StreamSession.load(path, cfg)
    assert np.array_equal(restored.parts, sess.parts)
    u1 = sess.feed(chunks[2])
    u2 = restored.feed(chunks[2])
    assert np.array_equal(u2.parts, u1.parts)
    assert np.array_equal(restored.parts, sess.parts)
    assert np.array_equal(restored.arena.masks_np(), sess.arena.masks_np())
    assert restored.n_feeds == sess.n_feeds
    with pytest.raises(ValueError, match="k="):
        StreamSession.load(path, _stream_cfg(k=8))


def test_feed_failure_leaves_session_consistent():
    """A chunk that fails validation must not mutate the appended graph or
    the parts — feed is retry-safe (append happens after the scan)."""
    g = text_like(200, 400, mean_len=8, seed=0)
    sess = StreamSession(_stream_cfg(), num_v=400)
    sess.feed(g.slice_u(0, 100))
    bad = BipartiteGraph(5, 10, np.array([0, 1, 2, 3, 4, 5], np.int64),
                         np.array([1, 2, 3, 99, 4], np.int32))  # 99 >= 10
    before_u, before_parts = sess.arena.num_u, sess.parts.copy()
    with pytest.raises(ValueError, match="exceeds"):
        sess.feed(bad)
    assert sess.arena.num_u == before_u
    assert np.array_equal(sess.parts, before_parts)
    sess.feed(g.slice_u(100, 200))  # stream continues fine
    assert sess.parts.shape == (200,)


def test_slice_u_matches_subgraph_u():
    g = text_like(200, 300, mean_len=8, seed=1)
    sl = g.slice_u(37, 151)
    ref = g.subgraph_u(np.arange(37, 151))
    assert sl.num_u == ref.num_u and sl.num_v == ref.num_v
    assert np.array_equal(sl.u_indptr, ref.u_indptr)
    assert np.array_equal(sl.u_indices, ref.u_indices)
    with pytest.raises(ValueError, match="out of range"):
        g.slice_u(10, 500)


# ---------------------------------------- satellite: padding-bit invariant
def _padding_bits_zero(masks: np.ndarray, num_v: int) -> bool:
    """True iff every bit at a column ≥ num_v is zero."""
    W = masks.shape[1]
    assert W * 32 >= num_v
    dense = unpack_bitmask(masks, W * 32)
    return not dense[:, num_v:].any()


@pytest.mark.parametrize("seed", range(5))
def test_padding_bits_stay_zero_through_packed_ops(seed):
    """Property: with num_v % 32 != 0, the ragged last word's padding bits
    are zero after packing and remain zero through union / delta / fused
    union+delta — the invariant the stream arena's appends lean on."""
    rng = np.random.default_rng(seed)
    num_v = int(rng.integers(33, 400))
    if num_v % 32 == 0:
        num_v += 1
    k = int(rng.integers(2, 8))
    a = pack_bitmask([rng.integers(0, num_v, rng.integers(1, 50))
                      for _ in range(k)], num_v)
    b = pack_bitmask([rng.integers(0, num_v, rng.integers(1, 50))
                      for _ in range(k)], num_v)
    assert _padding_bits_zero(a, num_v) and _padding_bits_zero(b, num_v)
    assert _padding_bits_zero(packed_union(a, b), num_v)
    assert _padding_bits_zero(packed_delta(a, b), num_v)
    u, d = packed_union_delta(np.asarray(a), np.asarray(b), use_kernel=False)
    assert _padding_bits_zero(np.asarray(u), num_v)
    assert _padding_bits_zero(np.asarray(d), num_v)
    u, d = packed_union_delta(np.asarray(a), np.asarray(b), interpret=True)
    assert _padding_bits_zero(np.asarray(u), num_v)
    assert _padding_bits_zero(np.asarray(d), num_v)


@pytest.mark.parametrize("num_v", [97, 510, 1001])
def test_padding_bits_stay_zero_through_stream_and_need(num_v):
    """The arena's live sets and the device need path keep capacity bits
    beyond num_v zero across appends (ragged last word included)."""
    from repro.core.jax_refine import need_masks

    chunks = text_like_stream(240, num_v, chunks=3, mean_len=9, seed=3)
    sess = StreamSession(_stream_cfg(), num_v=num_v)
    for ch in chunks:
        sess.feed(ch)
        masks = np.asarray(sess.arena.s_masks)
        assert _padding_bits_zero(masks, sess.arena.num_v)
    g = sess.arena.graph()
    nw = np.asarray(need_masks(g, sess.parts, 4))
    assert _padding_bits_zero(nw, num_v)
    # popcount metrics over the live sets == exact host evaluate (cold
    # stream ⇒ S_i == N(U_i)), so padding bits never inflate objectives
    want = evaluate(g, sess.parts, None, 4)
    got = sess._popcount_metrics()
    assert got.as_dict() == want.as_dict()


def test_padding_bits_stay_zero_through_sketched_stream():
    """PR 9 extension of the invariant, both sketch regimes.  Compressing:
    the arena runs at the word-aligned sketched width and every set bit
    stays inside it.  Exact-collapse (hot >= |V|): the arena runs at the
    ragged TRUE width and the PR 5 padding invariant must survive the
    sketch-mode plumbing bit for bit.  (Truly ragged sketched widths need
    a hand-built SketchSpec — covered in test_sketch.py.)"""
    num_v = 1001                                  # ragged true width
    chunks = text_like_stream(240, num_v, chunks=3, mean_len=9, seed=3)

    base = ParsaConfig(k=4, backend="device_scan", block_size=64,
                       use_kernel=False, refine_v=False, set_repr="sketch",
                       sketch_hot_bits=96, sketch_bucket_bits=64)
    sess = StreamSession(ParsaStreamConfig(base=base), num_v=num_v)
    assert sess.sketch is not None
    width = sess.sketch.width_bits
    assert sess.arena.num_v == width == 160
    for ch in chunks:
        sess.feed(ch)
        assert _padding_bits_zero(np.asarray(sess.arena.s_masks), width)

    base_x = base.replace(sketch_hot_bits=1024)   # >= num_v: exact collapse
    sx = StreamSession(ParsaStreamConfig(base=base_x), num_v=num_v)
    assert sx.sketch is None and sx.arena.num_v == num_v
    for ch in chunks:
        sx.feed(ch)
        assert _padding_bits_zero(np.asarray(sx.arena.s_masks), num_v)
    # exact collapse is bit-identical to the plain stream (PR 9 regression)
    plain = StreamSession(_stream_cfg(), num_v=num_v)
    for ch in chunks:
        plain.feed(ch)
    assert np.array_equal(sx.parts, plain.parts)
    assert np.array_equal(sx.arena.masks_np(), plain.arena.masks_np())


# ------------------------------------------- satellite: degenerate parity
def test_one_chunk_feed_bit_identical_to_device_scan():
    """Feeding the entire graph as ONE chunk is the device_scan backend:
    same permutation, same scan, same parts and s_masks bit for bit."""
    g = text_like(900, 1100, mean_len=18, seed=11)
    cfg = _stream_cfg(k=8)
    sess = StreamSession(cfg, num_v=g.num_v)
    upd = sess.feed(g)
    ref = partition(g, ParsaConfig(k=8, backend="device_scan", block_size=64,
                                   use_kernel=False, refine_v=False))
    assert np.array_equal(sess.parts, ref.parts_u)
    assert np.array_equal(upd.parts, ref.parts_u)
    assert np.array_equal(sess.arena.masks_np(), ref.s_masks)
    res = sess.result(refine_v=True)
    want = partition(g, ParsaConfig(k=8, backend="device_scan",
                                    block_size=64, use_kernel=False,
                                    refine_backend="device"))
    assert np.array_equal(res.parts_v, want.parts_v)
    assert res.metrics.as_dict() == want.metrics.as_dict()


# --------------------------------------------------- feeding fundamentals
def test_multi_chunk_feed_o1_dispatches_and_balance():
    g = text_like(800, 1000, mean_len=15, seed=7)
    sess = StreamSession(_stream_cfg(repartition="never"), num_v=g.num_v)
    for i in range(4):
        with dispatch_counter() as counts:
            upd = sess.feed(g.slice_u(i * 200, (i + 1) * 200))
        # O(1) device dispatches per feed: the scan + the metrics popcount
        # (labeled records: the scan record carries the live-arena bytes)
        phases = [r.phase for r in counts.records]
        assert phases.count("stream_feed_scan") == 1, phases
        assert phases.count("stream_metrics") == 1, phases
        scan = next(r for r in counts.records
                    if r.phase == "stream_feed_scan")
        assert scan.nbytes > 0 and scan.meta.get("k") == 4
        assert upd.u_stop - upd.u_start == 200
        assert (upd.parts >= 0).all() and (upd.parts < 4).all()
    assert sess.parts.shape == (800,)
    sizes = np.bincount(sess.parts, minlength=4)
    # carried (S, sizes) keep §4.1 perfect balance across chunk boundaries
    assert sizes.max() - sizes.min() <= 1
    # the live sets cover exactly the assigned neighborhoods
    need = need_matrix(g, sess.parts, 4)
    assert np.array_equal(
        pack_bitmask(need, g.num_v), sess.arena.masks_np())


def test_growing_v_capacity_doubling():
    chunks = social_like_stream(600, chunks=4, m=5, seed=2)
    sess = StreamSession(_stream_cfg(repartition="never"),
                         num_v=chunks[0].num_v)
    w0 = sess.arena.W_cap
    for ch in chunks:
        sess.feed(ch)
    assert sess.arena.num_v == 600
    assert sess.arena.W_cap >= (600 + 31) // 32 > w0
    assert _padding_bits_zero(np.asarray(sess.arena.s_masks),
                              sess.arena.num_v)
    res = sess.result(refine_v=False)
    assert res.num_v == 600
    assert (res.parts_u >= 0).all()
    want = evaluate(sess.arena.graph(), sess.parts, None, 4)
    assert res.metrics.as_dict() == want.as_dict()


def test_arena_zero_edge_snapshot_restores_and_grows(tmp_path):
    """A snapshot taken before any edges arrived restores with zero-length
    buffers; the next append must re-grow them (capacity floor)."""
    arena = StreamArena(4, 100)
    path = tmp_path / "empty.npz"
    arena.save(path)
    arena2 = StreamArena.load(path)
    g = text_like(50, 100, mean_len=5, seed=0)
    start, stop = arena2.append(g)
    assert (start, stop) == (0, 50)
    g2 = arena2.graph()
    assert np.array_equal(g2.u_indices, g.u_indices)


def test_session_rejects_unreachable_worker_count_at_construction():
    """The device-count check runs at __init__ — a mid-feed failure would
    leave the arena appended but the parts unassigned."""
    import jax

    workers = len(jax.devices()) + 1
    base = ParsaConfig(k=4, backend="parallel_device", workers=workers,
                       block_size=64, use_kernel=False, refine_v=False)
    with pytest.raises(ValueError, match="devices"):
        StreamSession(ParsaStreamConfig(base=base), num_v=100)


def test_update_dispatches_reports_repartition_launches():
    """StreamUpdate.dispatches comes from a real dispatch counter: a
    drift-repair feed reports the repartition's own scan too."""
    chunks = ctr_like_stream(900, 2000, chunks=4, nnz_per_row=12, churn=0.7,
                             seed=1)
    cfg = _stream_cfg(drift_threshold=1.0, drift_min_feeds=1,
                      repartition_frac=0.0)
    sess = StreamSession(cfg, num_v=2000)
    updates = [sess.feed(ch) for ch in chunks]
    plain = [u for u in updates if not u.repartitioned]
    repaired = [u for u in updates if u.repartitioned]
    assert repaired, "drift repair never triggered"
    for u in plain:
        assert u.dispatches == {"stream_feed_scan": 1, "stream_metrics": 1}
    for u in repaired:
        assert u.dispatches["stream_feed_scan"] == 1
        assert u.dispatches["stream_metrics"] == 2
        assert u.dispatches["partition_scan"] == 1  # the repair's full scan


def test_stream_config_validation():
    with pytest.raises(ValueError, match="device backend"):
        ParsaStreamConfig(base=ParsaConfig(k=4, backend="host"))
    with pytest.raises(ValueError, match="repartition must be"):
        _stream_cfg(repartition="sometimes")
    with pytest.raises(ValueError, match="repartition_frac"):
        _stream_cfg(repartition_frac=1.5)
    with pytest.raises(ValueError, match="tb_pad"):
        _stream_cfg(tb_pad=0)
    with pytest.raises(ValueError, match="window"):
        _stream_cfg(drift_window=0)
    with pytest.raises(ValueError, match="threshold"):
        _stream_cfg(drift_threshold=0.5)


def test_stream_partition_convenience():
    chunks = text_like_stream(400, 600, chunks=3, mean_len=10, seed=4)
    res, updates = stream_partition(chunks, _stream_cfg(repartition="never"))
    assert len(updates) == 3
    assert res.parts_u.shape == (400,)
    assert [u.chunk for u in updates] == [0, 1, 2]
    with pytest.raises(ValueError, match="at least one chunk"):
        stream_partition([], _stream_cfg())


# ------------------------------------------------ drift repair + migration
def test_drift_triggered_repartition_and_migration_metering():
    chunks = ctr_like_stream(900, 2000, chunks=5, nnz_per_row=12, churn=0.6,
                             seed=1)
    cfg = _stream_cfg(drift_threshold=1.0, drift_min_feeds=1,
                      repartition_frac=0.0)
    sess = StreamSession(cfg, num_v=2000)
    updates = [sess.feed(ch) for ch in chunks]
    assert sess.repartitions >= 1
    reparted = [u for u in updates if u.repartitioned]
    assert reparted, "drift threshold 1.0 should have tripped"
    mig = reparted[0].migration
    assert mig is not None
    assert mig.traffic.migration_bytes > 0
    assert mig.traffic.migration_bytes == mig.acquired_bytes + mig.retired_bytes
    # recovery traffic never pollutes the steady-state push/pull counters
    assert mig.traffic.pushed_bytes == 0 and mig.traffic.pulled_bytes == 0
    assert 0 <= mig.moved_u <= sess.parts.shape[0]
    assert np.array_equal(np.sort(mig.assign), np.arange(4))
    # session accumulates migration traffic in TrafficCounters units
    assert sess.traffic.migration_bytes >= mig.traffic.migration_bytes
    # cold repartition keeps the need invariant: popcounts stay exact
    g = sess.arena.graph()
    want = evaluate(g, sess.parts, None, 4)
    assert sess._popcount_metrics().as_dict() == want.as_dict()


def test_repartition_improves_or_matches_drifted_quality():
    """After heavy churn, one repartition should not be worse than the
    decayed online assignment it replaces (same graph, fresh greedy)."""
    chunks = ctr_like_stream(800, 1600, chunks=4, nnz_per_row=12, churn=0.8,
                             seed=9)
    sess = StreamSession(_stream_cfg(repartition="never"), num_v=1600)
    for ch in chunks:
        sess.feed(ch)
    g = sess.arena.graph()
    before = evaluate(g, sess.parts, None, 4).traffic_max
    plan = sess.repartition()
    after = evaluate(g, sess.parts, None, 4).traffic_max
    assert after <= before * 1.02  # fresh greedy ≥ decayed online (±noise)
    assert np.array_equal(plan.parts_u, sess.parts)


def test_migration_relabel_maximizes_overlap():
    from repro.stream import plan_migration

    rng = np.random.default_rng(0)
    num_v, k = 200, 4
    old = pack_bitmask([rng.integers(0, num_v, 60) for _ in range(k)], num_v)
    # the "new" partition is the old one with labels rotated by 1
    rot = np.roll(np.arange(k), -1)
    new = old[rot]
    old_parts = rng.integers(0, k, 100).astype(np.int32)
    new_parts = np.empty_like(old_parts)
    for i in range(k):
        new_parts[old_parts == rot[i]] = i
    plan = plan_migration(new_parts, new, old_parts, old)
    # perfect overlap exists: the matcher must find the rotation and
    # reconstruct the identical labeling with zero migration
    assert np.array_equal(plan.parts_u, old_parts)
    assert np.array_equal(plan.s_masks, old)
    assert plan.moved_u == 0
    assert plan.traffic.migration_bytes == 0
    assert plan.acquired_bytes == 0 and plan.retired_bytes == 0
    M = packed_intersect_counts(new, old)
    assert plan.kept_overlap == int(M.max(axis=1).sum())


def test_traffic_counters_add():
    a = TrafficCounters(1, 2, 3, 4)
    b = TrafficCounters(10, 20, 30, 40)
    # positional construction stays backward compatible: migration_bytes
    # defaults to 0 and sums component-wise like the original four fields
    assert a + b == TrafficCounters(11, 22, 33, 44)
    assert (a + b).migration_bytes == 0
    c = TrafficCounters(migration_bytes=7)
    assert (a + c).migration_bytes == 7
    assert (a + c).pushed_bytes == 1


# ------------------------------------------------------- PSCluster updates
def test_ps_cluster_apply_placement_mid_run():
    from repro.ml.dbpg import DBPGConfig
    from repro.ml.ps import PSCluster

    g = text_like(120, 300, mean_len=10, seed=6)
    rng = np.random.default_rng(0)
    labels = rng.choice([-1.0, 1.0], g.num_u)
    k = 4
    r1 = partition(g, ParsaConfig(k=k, backend="host"))
    cluster = PSCluster(g, labels, r1.parts_u, r1.parts_v, k,
                        DBPGConfig(lam=1e-4, lr=0.1))
    cluster.step(0)
    total_before = cluster.meter.total
    # a genuinely different placement: rotate every assignment
    new_u = ((r1.parts_u + 1) % k).astype(np.int32)
    new_v = np.where(r1.parts_v >= 0, (r1.parts_v + 1) % k, -1).astype(
        np.int32)
    info = cluster.apply_placement(new_u, new_v)
    assert info["moved_rows"] == g.num_u
    assert info["moved_weights"] == int((r1.parts_v >= 0).sum())
    assert info["reshard_bytes"] > 0
    assert cluster.meter.total == total_before + info["reshard_bytes"]
    assert np.array_equal(cluster.need, need_matrix(g, new_u, k))
    assert not cluster._keys_sent.any()
    cluster.step(1)  # training continues on the new placement
    with pytest.raises(ValueError, match="fixed graph"):
        cluster.apply_placement(new_u[:-1], new_v)


def test_stream_generators_shapes():
    for chunks in (text_like_stream(200, 500, chunks=4, mean_len=8, seed=0),
                   ctr_like_stream(200, 800, chunks=4, nnz_per_row=10,
                                   seed=0)):
        assert len(chunks) == 4
        assert sum(c.num_u for c in chunks) == 200
        for c in chunks:
            c.validate()
    soc = social_like_stream(300, chunks=3, m=4, seed=0)
    assert sum(c.num_u for c in soc) == 300
    assert soc[-1].num_v == 300
    nv = 0
    for c in soc:
        c.validate()
        assert c.num_v >= nv
        nv = c.num_v
