"""End-to-end behaviour tests: the paper's full pipeline + the mini dry-run
(subprocess with 8 forced host devices — proves the sharded lowering path
without the production 512-device compile)."""
import json
import os
import pathlib
import subprocess
import sys

import numpy as np
import pytest

from repro.core import evaluate, partition_v, random_parts, sequential_parsa
from repro.core.placement import build_placement
from repro.graphs import ctr_like
from repro.ml import DBPGConfig, PSCluster, make_problem

ROOT = pathlib.Path(__file__).resolve().parents[1]


def test_end_to_end_paper_pipeline():
    """§5.5 in miniature: generate data → Parsa partition → DBPG → less
    traffic AND no worse convergence than random placement."""
    g = ctr_like(400, 1200, nnz_per_row=12, seed=21)
    w_star, labels = make_problem(g, seed=21)
    k = 8
    cfg = DBPGConfig(lam=0.3, lr=0.03, max_delay=1)
    pl = build_placement(g, k, b=4, a=2)
    res_p = PSCluster(g, labels, pl.doc_to_shard, pl.vocab_to_shard, k, cfg,
                      seed=1).run(10, log_every=9)
    ru, rv = random_parts(g.num_u, k, 0), random_parts(g.num_v, k, 1)
    res_r = PSCluster(g, labels, ru, rv, k, cfg, seed=1).run(10, log_every=9)
    assert res_p["inter_bytes"] < res_r["inter_bytes"]
    assert res_p["objective"][-1] < res_p["objective"][0]
    # modeled end-to-end time (the Table 3 quantity) improves
    assert res_p["modeled_time_s"] <= res_r["modeled_time_s"]


def test_partition_quality_objectives_jointly():
    """All three §2.4 objectives beat random simultaneously (Table 2 shape)."""
    g = ctr_like(600, 2000, nnz_per_row=18, seed=5)
    k = 16
    pu = sequential_parsa(g, k, b=4, a=4)
    pv = partition_v(g, pu, k, sweeps=2)
    m = evaluate(g, pu, pv, k)
    mr = evaluate(g, random_parts(g.num_u, k, 0), random_parts(g.num_v, k, 1), k)
    assert m.size_max <= mr.size_max + 1
    assert m.mem_max < mr.mem_max
    assert m.traffic_max < mr.traffic_max


@pytest.mark.slow
def test_mini_dryrun_subprocess(tmp_path):
    """dryrun.py on a 2×2×2 mesh with 8 forced host devices: the multi-pod
    lowering path (pod axis + shardings + collectives) compiles."""
    env = dict(os.environ)
    env.update(
        DRYRUN_XLA_FLAGS="--xla_force_host_platform_device_count=8",
        REPRO_MESH="2,2,2",
        PYTHONPATH=str(ROOT / "src"),
    )
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "whisper-medium",
         "--shape", "train_4k", "--multi-pod", "--force"],
        env=env, capture_output=True, text=True, timeout=900, cwd=ROOT)
    assert "[ok]" in out.stdout, out.stdout + out.stderr
    cell = json.loads(
        (ROOT / "benchmarks/out/dryrun/whisper-medium__train_4k__2x2x2.json").read_text())
    assert cell["status"] == "ok"
    assert cell["roofline"]["wire_bytes_per_device"] > 0
