"""repro.serving: the request-driven PS serving engine — measured async
overlap, O(1) dispatches per request, bounded-staleness fault fallback,
elastic composition, and the LM-decode parity oracle."""
import jax
import numpy as np
import pytest

from repro.api import (ChaosEvent, ChaosSchedule, ElasticConfig,
                       ElasticSession, ParsaConfig, ParsaStreamConfig,
                       partition)
from repro.core import random_parts
from repro.core.jax_partition import dispatch_counter
from repro.graphs import ctr_like
from repro.ml import DBPGConfig, PSCluster
from repro.runtime import RetryPolicy
from repro.serving import (PSRequestSource, RequestMix, Router,
                           ServingConfig, ServingEngine, ZipfWorkload,
                           prefetch_batches)

K = 4


@pytest.fixture(scope="module")
def serving_graph():
    g = ctr_like(600, 1200, nnz_per_row=12, clusters=8, locality=0.85,
                 seed=0)
    labels = np.where(np.random.default_rng(0).random(g.num_u) < 0.5,
                      1.0, -1.0).astype(np.float32)
    return g, labels


def _cluster(g, labels, bandwidth=2.5e5, parts=None):
    if parts is None:
        parts = (random_parts(g.num_u, K, 0), random_parts(g.num_v, K, 1))
    cfg = DBPGConfig(lam=0.05, lr=0.1, kkt_eps=0.0, compress=False,
                     error_feedback=False)
    cl = PSCluster(g, labels, parts[0], parts[1], K, cfg,
                   bandwidth=bandwidth)
    cl.commit_weights(np.random.default_rng(1).normal(
        0, 0.1, g.num_v).astype(np.float32))
    return cl


def _mix(batch=32):
    return RequestMix((ZipfWorkload("t", batch=batch, zipf_s=1.1),))


def _engine(g, labels, prefetch, bandwidth=2.5e5, chaos=None, elastic=None,
            warmup=2, retry=None, parts=None):
    cluster = _cluster(g, labels, bandwidth=bandwidth, parts=parts)
    cfg = ServingConfig(prefetch=prefetch, warmup=warmup, seed=0,
                        pad_multiple=512,
                        **({"retry": retry} if retry else {}))
    source = PSRequestSource(cluster, _mix(), cfg, chaos=chaos,
                             elastic=elastic)
    return ServingEngine(source), source, cluster


# ------------------------------------------------------------------ engine
@pytest.mark.parametrize("prefetch", [False, True])
def test_engine_smoke_one_dispatch_per_request(serving_graph, prefetch):
    g, labels = serving_graph
    n, warmup = 10, 2
    engine, src, _ = _engine(g, labels, prefetch, warmup=warmup)
    with dispatch_counter() as counts:
        s = engine.run(n)
    # O(1) jitted dispatches per request: one pull issue + one serve step
    # (labeled records: per-request home + payload bytes ride along)
    phases = [r.phase for r in counts.records]
    assert phases.count("serving_pull") == n, counts
    assert phases.count("serving_compute") == n, counts
    for r in counts.records:
        if r.phase == "serving_pull":
            assert "home" in r.meta and r.nbytes >= 0
        elif r.phase == "serving_compute":
            assert r.nbytes > 0 and r.meta.get("tokens", 0) > 0
    assert s["mode"] == ("async" if prefetch else "sync")
    assert s["requests"] == n - warmup
    assert s["examples"] == 32 * (n - warmup)   # one 32-row tenant
    assert s["tokens"] > 0 and s["wall_s"] > 0
    assert s["p99_ms"] >= s["p50_ms"] > 0
    assert s["pull_inter_bytes"] > 0 and s["push_inter_bytes"] > 0
    assert s["stale_entries"] == 0              # healthy fleet: no fallback


def test_async_overlap_is_measured_not_assumed(serving_graph):
    """Same cluster/workload, wire-dominated (slow link): async hides the
    transfer behind compute — blocked_s collapses while wire_s stays.
    The blocked/wall comparison is wall-clock and scheduler jitter can
    inflate a single async run, so it gets best-of-3; the invariants
    (equal wire, positive hidden overlap) stay strict on every attempt."""
    g, labels = serving_graph
    bw = 5e4
    last = None
    for _ in range(3):
        engine_s, _, _ = _engine(g, labels, prefetch=False, bandwidth=bw)
        engine_a, _, _ = _engine(g, labels, prefetch=True, bandwidth=bw)
        sync = engine_s.run(12)
        asyn = engine_a.run(12)
        assert asyn["wire_s"] == pytest.approx(sync["wire_s"], rel=0.5)
        assert asyn["hidden_s"] > 0              # wire actually overlapped
        if (asyn["blocked_s"] < sync["blocked_s"] * 0.8
                and asyn["wall_s"] < sync["wall_s"]):
            return
        last = (asyn["blocked_s"], sync["blocked_s"],
                asyn["wall_s"], sync["wall_s"])
    pytest.fail("async never hid the wire in 3 attempts: "
                f"blocked {last[0]:.4f}s vs sync {last[1]:.4f}s, "
                f"wall {last[2]:.4f}s vs sync {last[3]:.4f}s")


def test_update_propagates_between_requests(serving_graph):
    """Serving is online DBPG: commits move the server weights."""
    g, labels = serving_graph
    engine, src, cluster = _engine(g, labels, prefetch=True)
    w0 = np.asarray(cluster.w).copy()
    engine.run(6)
    assert not np.array_equal(np.asarray(cluster.w), w0)


# ------------------------------------------------------------------- fault
def test_retry_policy_admission():
    p = RetryPolicy(timeout_s=0.05, retries=1, backoff=2.0)
    assert p.admit(0.01) == (True, 0.0)          # fits the first deadline
    ok, wait = p.admit(0.07)                     # fits the backed-off retry
    assert ok and wait == pytest.approx(0.05)
    ok, wait = p.admit(float("inf"))             # killed link: never fits
    assert not ok and wait == pytest.approx(p.budget_s)
    assert p.budget_s == pytest.approx(0.15)
    with pytest.raises(ValueError):
        RetryPolicy(timeout_s=0.0)
    with pytest.raises(ValueError):
        RetryPolicy(backoff=0.5)


def test_kill_mid_serve_falls_back_to_stale(serving_graph):
    """A shard killed mid-serve must NOT stall the engine: its links fail
    their retry budget once, the circuit opens (suspect), and requests
    keep serving from the stale buffer — bounded staleness, measured."""
    g, labels = serving_graph
    chaos = ChaosSchedule([ChaosEvent(feed=3, kind="kill", machine=1)],
                          seed=0)
    retry = RetryPolicy(timeout_s=0.002, retries=1)
    engine, src, _ = _engine(g, labels, prefetch=True, chaos=chaos,
                             retry=retry)
    s = engine.run(12)
    assert src.dead == {1}
    assert 1 in src.suspect                      # circuit opened after kill
    assert s["stale_entries"] > 0                # served with stale entries
    assert s["requests"] == 10
    assert (3, "kill", 1) in src.events
    # the timeout budget is paid at most once per link before the circuit
    # opens — total wait is bounded by one budget, not one per request
    assert s["wait_s"] <= retry.budget_s + 1e-9


def test_straggler_inflates_wire_then_recovers(serving_graph):
    g, labels = serving_graph
    chaos = ChaosSchedule([
        ChaosEvent(feed=2, kind="straggle", machine=1, factor=50.0),
        ChaosEvent(feed=8, kind="recover", machine=1),
    ], seed=0)
    engine, src, _ = _engine(g, labels, prefetch=False, bandwidth=1e6,
                             chaos=chaos)
    engine.run(12)
    assert src.straggle[1] == 1.0                # recovered
    recs = engine.recorder.records
    slow = [r.wire_s for r in recs if 2 <= r.step < 8 and r.home != 1]
    fast = [r.wire_s for r in recs if r.step >= 8]
    assert max(slow) > max(fast)                 # straggled link showed up


def test_elastic_repair_under_load(serving_graph):
    """Kill with an ElasticSession attached: warm §4.4 repair re-places
    the lost shard's rows, the new placement reaches the router via
    placement_version, and serving continues with NO dead machine."""
    g, labels = serving_graph
    scfg = ParsaStreamConfig(base=ParsaConfig(
        k=K, backend="device_scan", refine_v=False, seed=0))
    es = ElasticSession(ElasticConfig(stream=scfg), num_v=g.num_v)
    es.feed(g)
    cluster = _cluster(g, labels,
                       parts=(es.parts.copy(), random_parts(g.num_v, K, 1)))
    chaos = ChaosSchedule([ChaosEvent(feed=3, kind="kill", machine=2)],
                          seed=0)
    cfg = ServingConfig(prefetch=True, warmup=2, seed=0, pad_multiple=512)
    src = PSRequestSource(cluster, _mix(), cfg, chaos=chaos, elastic=es)
    engine = ServingEngine(src)
    v0 = cluster.placement_version
    s = engine.run(10)
    assert src.dead == set()                     # repaired, not abandoned
    assert cluster.placement_version > v0        # re-shard reached serving
    assert src.router.version == cluster.placement_version
    assert s["requests"] == 8
    assert len(es.ops) == 1 and es.ops[0].kind == "repair"


# ------------------------------------------------------------------ router
def test_router_pools_and_routing(serving_graph):
    g, labels = serving_graph
    cluster = _cluster(g, labels)
    r = Router(cluster)
    for m in range(K):
        assert np.array_equal(r.pools[m], np.flatnonzero(cluster.parts_u == m))
    homes = [r.next_home(dead={1}) for _ in range(6)]
    assert 1 not in homes                        # dead machine skipped
    assert set(homes) == {0, 2, 3}               # round-robin over live
    rng = np.random.default_rng(0)
    rows = r.sample_rows(2, 64, rng, zipf_s=1.2, hot_offset=5)
    assert np.isin(rows, r.pools[2]).all()       # home pool only
    # explicit row sets route to the majority hosting machine
    assert r.route(r.pools[3][:8], cluster.parts_u) == 3
    assert r.route(r.pools[3][:8], cluster.parts_u, dead={3}) != 3
    # refresh is a no-op until the placement version moves
    assert not r.refresh(cluster)
    cluster.apply_placement(cluster.parts_u, cluster.parts_v)
    assert r.refresh(cluster)


def test_workload_validation():
    with pytest.raises(ValueError):
        ZipfWorkload("t", batch=0)
    with pytest.raises(ValueError):
        ZipfWorkload("t", weight=0.0)
    with pytest.raises(ValueError):
        RequestMix(())


# ---------------------------------------------------------------- prefetch
def test_prefetch_batches_order_and_staging():
    staged = []

    def stage(x):
        staged.append(x)
        return x * 10

    out = list(prefetch_batches(range(5), stage, depth=3))
    assert out == [0, 10, 20, 30, 40]
    assert staged == [0, 1, 2, 3, 4]
    assert list(prefetch_batches([], stage)) == []
    assert list(prefetch_batches([7], depth=1)) == [7]
    with pytest.raises(ValueError):
        next(prefetch_batches(range(3), depth=0))


def test_prefetch_batches_stages_ahead():
    """depth=2 keeps one batch staged beyond the one being consumed."""
    staged = []
    it = prefetch_batches(range(4), staged.append, depth=2)
    next(it)
    assert staged == [0, 1, 2]   # consumed 0, staged 2 ahead


# ----------------------------------------------------------- decode parity
def test_decode_engine_matches_oracle():
    """The engine-routed LM decode is bit-identical to the pre-engine
    reference loop, in both sync and async modes."""
    from repro.configs import get_config
    from repro.launch.serve import decode_loop, decode_loop_engine
    from repro.launch.steps import make_serve_step

    cfg = get_config("qwen3-14b").reduced()
    model, serve_step = make_serve_step(cfg)
    serve_step = jax.jit(serve_step)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompt = np.asarray(rng.integers(0, cfg.vocab_size, size=(2, 6)),
                        np.int32)
    cache_seq = 6 + 4
    ref = decode_loop(model, serve_step, params, prompt, gen=4,
                      cache_seq=cache_seq)
    for prefetch in (False, True):
        out, summary = decode_loop_engine(model, serve_step, params, prompt,
                                          gen=4, cache_seq=cache_seq,
                                          prefetch=prefetch)
        np.testing.assert_array_equal(out, ref)
        assert summary["requests"] == 6 - 1 + 4
        assert set(summary["per_tenant"]) == {"prefill", "decode"}
