"""The unified repro.api facade: config validation, backend registry,
result uniformity, warm-start refine, and bit-exact parity between the
five legacy entry points (now deprecation shims) and their pre-refactor
implementations."""
import numpy as np
import pytest

from repro.api import (
    ParsaConfig,
    PartitionResult,
    available_backends,
    partition,
)
from repro.graphs import text_like


@pytest.fixture(scope="module")
def parity_graph():
    """Fixed-seed 2k-vertex graph for the shim parity acceptance test."""
    return text_like(2000, 3000, mean_len=20, seed=42)


@pytest.fixture(scope="module")
def small_graph():
    return text_like(300, 600, mean_len=15, seed=0)


# ------------------------------------------------------------- validation
def test_registry_has_all_backends():
    assert {"host", "device_scan", "host_blocked_oracle",
            "parallel_sim", "parallel_device"} <= set(available_backends())


@pytest.mark.parametrize("kwargs,match", [
    (dict(k=4, backend="nope"), "unknown Parsa backend"),
    (dict(k=0), "k must be"),
    (dict(k=-3), "k must be"),
    (dict(k=4, block_size=100), "multiple of 8"),
    (dict(k=4, block_size=0), "multiple of 8"),
    (dict(k=4, blocks=0), "blocks must be"),
    (dict(k=4, init_iters=-1), "init_iters"),
    (dict(k=4, select="weird"), "select must be"),
    (dict(k=4, workers=0), "workers"),
    (dict(k=4, tau=-1), "tau"),
    (dict(k=4, global_init_frac=1.5), "global_init_frac"),
    (dict(k=4, merge_every=0), "merge_every"),
    (dict(k=4, devices=0), "devices"),
    (dict(k=4, sweeps=0), "sweeps"),
    (dict(k=4, placement=True, refine_v=False), "placement"),
])
def test_config_validation_errors(kwargs, match):
    with pytest.raises(ValueError, match=match):
        ParsaConfig(**kwargs)


def test_config_is_frozen_and_replaceable():
    import dataclasses

    cfg = ParsaConfig(k=8)
    with pytest.raises(dataclasses.FrozenInstanceError):
        cfg.k = 4
    cfg2 = cfg.replace(backend="device_scan", block_size=64)
    assert cfg2.k == 8 and cfg2.backend == "device_scan"
    assert cfg.backend == "host"  # original untouched


# ------------------------------------------------- backend equivalence smoke
@pytest.mark.parametrize("backend,extra", [
    ("host", {}),
    ("device_scan", dict(block_size=64)),
    ("host_blocked_oracle", dict(block_size=64)),
    ("parallel_sim", dict(workers=4, tau=0)),
    ("parallel_device", dict(workers=1, block_size=64, merge_every=2)),
])
def test_backend_smoke_valid_partition_and_schema(small_graph, backend, extra):
    """Every backend yields a valid partition and the identical metrics /
    result schema through the one partition() entry point."""
    g, k = small_graph, 4
    res = partition(g, ParsaConfig(k=k, backend=backend, blocks=4, **extra))
    assert isinstance(res, PartitionResult)
    assert res.parts_u.shape == (g.num_u,)
    assert (res.parts_u >= 0).all() and (res.parts_u < k).all()
    assert res.parts_v is not None and res.parts_v.shape == (g.num_v,)
    assert res.s_masks.shape == (k, (g.num_v + 31) // 32)
    assert res.neighbor_sets.shape == (k, g.num_v)
    assert res.neighbor_sets.dtype == bool
    # identical metrics schema across backends
    assert set(res.metrics.as_dict()) == {
        "k", "size_max", "mem_max", "traffic_max", "traffic_sum"}
    assert {"partition_u", "partition_v", "metrics", "total"} <= set(res.timings)
    if backend == "parallel_sim":
        assert res.traffic is not None and res.traffic.tasks == 4
    elif backend == "parallel_device":
        assert res.traffic is not None and res.traffic.pulled_bytes > 0
    else:
        assert res.traffic is None


def test_neighbor_sets_cover_assigned_vertices(small_graph):
    """S_i ⊇ N(U_i) for every backend output (dense view of s_masks)."""
    from repro.core.costs import need_matrix

    g, k = small_graph, 4
    for backend in ("host", "device_scan", "parallel_sim", "parallel_device"):
        res = partition(g, ParsaConfig(k=k, backend=backend, blocks=2,
                                       block_size=64, workers=1,
                                       refine_v=False))
        need = need_matrix(g, res.parts_u, k)
        assert not (need & ~res.neighbor_sets).any(), backend


def test_placement_composition(small_graph):
    g, k = small_graph, 4
    res = partition(g, ParsaConfig(k=k, blocks=4, init_iters=2,
                                   placement=True))
    pl = res.placement
    assert pl is not None and pl.k == k
    assert np.array_equal(pl.doc_to_shard, res.parts_u)
    assert np.array_equal(np.sort(pl.vocab_perm), np.arange(g.num_v))
    assert "placement" in res.timings


def test_refine_warm_start_matches_hand_threaded(small_graph):
    from repro.core.partition_u import partition_u_impl

    g1 = small_graph
    g2 = text_like(200, 600, mean_len=15, seed=1)
    cfg = ParsaConfig(k=4, backend="host")
    r1 = partition(g1, cfg)
    r2 = r1.refine(g2)
    want = partition_u_impl(g2, 4, init_sets=r1.neighbor_sets)
    assert np.array_equal(r2.parts_u, want.parts_u)
    assert np.array_equal(r2.neighbor_sets, want.neighbor_sets)


def test_refine_rejects_mismatched_parameter_side(small_graph):
    res = partition(small_graph, ParsaConfig(k=4, refine_v=False))
    g_other = text_like(100, small_graph.num_v + 17, mean_len=10, seed=2)
    with pytest.raises(ValueError, match="num_v"):
        res.refine(g_other)


def test_sets_views_round_trip_both_directions(small_graph):
    """host produces dense sets (packed view lazy), device_scan produces
    packed sets (dense view lazy) — both views must agree bit-for-bit."""
    from repro.kernels.parsa_cost import pack_bitmask, unpack_bitmask

    for backend in ("host", "device_scan"):
        res = partition(small_graph, ParsaConfig(
            k=4, backend=backend, block_size=64, refine_v=False))
        dense, packed = res.neighbor_sets, res.s_masks
        assert np.array_equal(pack_bitmask(dense, res.num_v), packed)
        assert np.array_equal(unpack_bitmask(packed, res.num_v), dense)


def test_unknown_backend_at_partition_time(small_graph):
    """Construction is validated; replace() re-validates too."""
    with pytest.raises(ValueError, match="unknown Parsa backend"):
        ParsaConfig(k=4).replace(backend="also-nope")


# --------------------------------------------------- legacy shims: warnings
def test_legacy_shims_emit_deprecation_warnings(small_graph):
    from repro.core.jax_partition import (
        blocked_partition_u, blocked_partition_u_hostloop)
    from repro.core.parallel import ParallelParsa
    from repro.core.partition_u import partition_u
    from repro.core.subgraphs import sequential_parsa

    g = small_graph
    with pytest.warns(DeprecationWarning, match="partition_u is deprecated"):
        partition_u(g, 4)
    with pytest.warns(DeprecationWarning, match="sequential_parsa is deprecated"):
        sequential_parsa(g, 4, b=2, a=0)
    with pytest.warns(DeprecationWarning, match="ParallelParsa.run is deprecated"):
        ParallelParsa(4, workers=2, tau=0).run(g, b=2)
    with pytest.warns(DeprecationWarning, match="blocked_partition_u is deprecated"):
        blocked_partition_u(g, 4, block=64, use_kernel=False)
    with pytest.warns(DeprecationWarning,
                      match="blocked_partition_u_hostloop is deprecated"):
        blocked_partition_u_hostloop(g, 4, block=64, use_kernel=False)


def test_legacy_shims_warn_exactly_once_and_match_registry(small_graph):
    """Each of the five legacy entry points emits its DeprecationWarning
    exactly ONCE per call (no double-warn through the delegation chain) and
    still returns what the backend registry returns."""
    import warnings

    from repro.api_backends import get_backend
    from repro.core.jax_partition import (
        blocked_partition_u, blocked_partition_u_hostloop)
    from repro.core.parallel import ParallelParsa
    from repro.core.partition_u import partition_u
    from repro.core.subgraphs import sequential_parsa

    g, k = small_graph, 4

    def once(fn):
        with warnings.catch_warnings(record=True) as rec:
            warnings.simplefilter("always")
            out = fn()
        dep = [w for w in rec if issubclass(w.category, DeprecationWarning)]
        assert len(dep) == 1, [str(w.message) for w in dep]
        return out

    res = once(lambda: partition_u(g, k))
    want = get_backend("host")(g, ParsaConfig(k=k))
    assert np.array_equal(res.parts_u, want.parts_u)

    got = once(lambda: sequential_parsa(g, k, b=2, a=0, seed=1))
    want = get_backend("host")(g, ParsaConfig(k=k, blocks=2, seed=1))
    assert np.array_equal(got, want.parts_u)

    rep = once(lambda: ParallelParsa(k, workers=2, tau=0, seed=2)
               .run(g, b=2))
    want = get_backend("parallel_sim")(
        g, ParsaConfig(k=k, blocks=2, workers=2, tau=0, seed=2))
    assert np.array_equal(rep.parts_u, want.parts_u)

    got = once(lambda: blocked_partition_u(g, k, block=64, use_kernel=False,
                                           seed=3))
    want = get_backend("device_scan")(
        g, ParsaConfig(k=k, backend="device_scan", block_size=64,
                       use_kernel=False, seed=3))
    assert np.array_equal(got, want.parts_u)

    got = once(lambda: blocked_partition_u_hostloop(
        g, k, block=64, use_kernel=False, seed=3))
    want = get_backend("host_blocked_oracle")(
        g, ParsaConfig(k=k, backend="host_blocked_oracle", block_size=64,
                       use_kernel=False, seed=3))
    assert np.array_equal(got, want.parts_u)


# ---------------------------------------------- legacy shims: exact parity
# Acceptance: each shim, now delegating through the backend registry, returns
# results bit-identical to its pre-refactor implementation on a fixed-seed
# 2k-vertex graph.
def test_parity_partition_u(parity_graph):
    from repro.core.partition_u import partition_u, partition_u_impl

    res = partition_u(parity_graph, 8, seed=3)
    ref = partition_u_impl(parity_graph, 8, seed=3)
    assert np.array_equal(res.parts_u, ref.parts_u)
    assert np.array_equal(res.neighbor_sets, ref.neighbor_sets)


def test_parity_sequential_parsa(parity_graph):
    from repro.core.subgraphs import sequential_parsa, sequential_parsa_impl

    got = sequential_parsa(parity_graph, 8, b=8, a=4, seed=1)
    want, _ = sequential_parsa_impl(parity_graph, 8, b=8, a=4, seed=1)
    assert np.array_equal(got, want)


def test_parity_parallel_parsa(parity_graph):
    from repro.core.parallel import ParallelParsa, parallel_parsa_impl

    rep = ParallelParsa(8, workers=4, tau=2, seed=5).run(parity_graph, b=8, a=2)
    ref, _ = parallel_parsa_impl(parity_graph, 8, b=8, a=2, workers=4, tau=2,
                                 seed=5)
    assert np.array_equal(rep.parts_u, ref.parts_u)
    assert rep.pushed_bytes == ref.pushed_bytes
    assert rep.pulled_bytes == ref.pulled_bytes
    assert rep.tasks == ref.tasks
    assert rep.stale_pushes_missed == ref.stale_pushes_missed


def test_parity_blocked_partition_u(parity_graph):
    from repro.core.jax_partition import (
        blocked_partition_u, blocked_partition_u_impl)

    got = blocked_partition_u(parity_graph, 8, block=256, use_kernel=False,
                              seed=7)
    want, _ = blocked_partition_u_impl(parity_graph, 8, block=256,
                                       use_kernel=False, seed=7)
    assert np.array_equal(got, want)


def test_parity_blocked_partition_u_hostloop(parity_graph):
    from repro.core.jax_partition import (
        blocked_partition_u_hostloop, blocked_partition_u_hostloop_impl)

    got = blocked_partition_u_hostloop(parity_graph, 8, block=256,
                                       use_kernel=False, seed=7)
    want, _ = blocked_partition_u_hostloop_impl(parity_graph, 8, block=256,
                                                use_kernel=False, seed=7)
    assert np.array_equal(got, want)


def test_parity_build_placement_matches_pre_refactor_recipe(parity_graph):
    """build_placement now routes through the facade; its output must match
    the pre-refactor recipe (sequential_parsa_impl + partition_v) exactly."""
    from repro.core.partition_v import partition_v
    from repro.core.placement import build_placement, placement_from_parts
    from repro.core.subgraphs import sequential_parsa_impl

    g, k = parity_graph, 8
    pl = build_placement(g, k, b=4, a=2, seed=0)
    pu, _ = sequential_parsa_impl(g, k, b=4, a=2, seed=0)
    pv = partition_v(g, pu, k, sweeps=2)
    ref = placement_from_parts(pu, pv, g.num_v, k)
    assert np.array_equal(pl.doc_to_shard, ref.doc_to_shard)
    assert np.array_equal(pl.vocab_to_shard, ref.vocab_to_shard)
    assert np.array_equal(pl.vocab_perm, ref.vocab_perm)
    assert np.array_equal(pl.shard_row_counts, ref.shard_row_counts)
