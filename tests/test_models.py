"""Per-arch smoke tests (reduced configs) + decode/train-path consistency +
recurrent-vs-parallel equivalences."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_configs
from repro.models import transformer as TR
from repro.models.model import build_model

ARCHS = list_configs()


def _batch(cfg, B=2, S=16, seed=0):
    rng = np.random.default_rng(seed)
    b = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
    }
    if cfg.family == "encdec":
        b["frames"] = jnp.asarray(
            rng.normal(0, 0.1, (B, cfg.encoder_seq, cfg.d_model)), jnp.float32)
    if cfg.family == "vlm":
        b["patches"] = jnp.asarray(
            rng.normal(0, 0.1, (B, cfg.num_patches, cfg.d_model)), jnp.float32)
    return b


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    """One forward + one optimizer step on CPU: shapes + no NaNs."""
    from repro.launch.steps import make_train_step

    cfg = get_config(arch).reduced()
    model, train_step, init_state, _ = make_train_step(cfg)
    params, opt = init_state(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    step = jax.jit(train_step)
    p2, o2, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually changed
    delta = jax.tree.reduce(
        lambda a, x: a + float(jnp.abs(x).sum()),
        jax.tree.map(lambda a, b: a - b, p2, params), 0.0)
    assert delta > 0
    # loss decreases over a few steps on a fixed batch
    p, o = p2, o2
    l0 = float(metrics["loss"])
    for _ in range(3):
        p, o, metrics = step(p, o, batch)
    assert float(metrics["loss"]) < l0


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_step(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B = 2
    cache = model.init_cache(B, 32)
    if cfg.family == "encdec":
        kv = TR.init_kv_caches(cfg, B, cfg.encoder_seq, dtype=jnp.float32)
        cache["cross"] = (kv["k"], kv["v"])
    step = jax.jit(model.decode_step)
    for t in range(3):
        logits, cache = step(params, {
            "token": jnp.full((B, 1), 3 + t, jnp.int32),
            "pos": jnp.asarray(t, jnp.int32), "cache": cache})
    assert logits.shape == (B, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ["qwen3-14b", "mixtral-8x22b", "deepseek-v2-236b",
                                  "whisper-medium", "internvl2-76b"])
def test_decode_matches_teacher_forcing(arch):
    """Step-by-step decode logits == full-sequence forward logits."""
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    B, S = 2, 8
    batch = _batch(cfg, B, S, seed=2)
    # full forward logits via loss path surrogate: prefill on the whole prompt
    logits_full, _ = model.prefill(params, {**batch, "cache_seq": S})
    # incremental decode
    cache = model.init_cache(B, S)
    if cfg.family == "encdec":
        enc_out = model._encode(params, batch["frames"])
        cache["cross"] = model._cross_kv(params, enc_out)
        dec_batch_tokens = batch["tokens"]
    else:
        dec_batch_tokens = batch["tokens"]
    step = jax.jit(model.decode_step)
    if cfg.family == "vlm":
        pytest.skip("vlm decode offsets by patch positions; covered by smoke")
    for t in range(S):
        logits_step, cache = step(params, {
            "token": dec_batch_tokens[:, t:t + 1],
            "pos": jnp.asarray(t, jnp.int32), "cache": cache})
    np.testing.assert_allclose(
        np.asarray(logits_step, np.float32),
        np.asarray(logits_full, np.float32), atol=2e-3, rtol=2e-3)


def test_ssd_chunked_matches_recurrence():
    """Mamba2 SSD chunked scan == step-by-step recurrence."""
    from repro.models.ssm import ssd_chunked

    rng = np.random.default_rng(0)
    B, L, H, P, N = 2, 32, 3, 8, 4
    x = jnp.asarray(rng.normal(0, 1, (B, L, H, P)), jnp.float32)
    log_a = jnp.asarray(-np.abs(rng.normal(0, 0.5, (B, L, H))), jnp.float32)
    B_ = jnp.asarray(rng.normal(0, 1, (B, L, N)), jnp.float32)
    C_ = jnp.asarray(rng.normal(0, 1, (B, L, N)), jnp.float32)
    y_chunk, final = ssd_chunked(x, log_a, B_, C_, chunk=8)
    # recurrence
    state = np.zeros((B, H, P, N), np.float32)
    ys = []
    for t in range(L):
        a = np.exp(np.asarray(log_a[:, t]))          # (B,H)
        upd = np.einsum("bhp,bn->bhpn", np.asarray(x[:, t]), np.asarray(B_[:, t]))
        state = state * a[..., None, None] + upd
        ys.append(np.einsum("bhpn,bn->bhp", state, np.asarray(C_[:, t])))
    y_rec = np.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk), y_rec, atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(final), state, atol=1e-4, rtol=1e-3)


def test_mlstm_parallel_matches_recurrence():
    """mLSTM chunk-queried parallel form == recurrent decode steps."""
    from repro.models.xlstm import init_mlstm, mlstm_block

    cfg = get_config("xlstm-350m").reduced()
    p = init_mlstm(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    B, L = 2, 12
    x = jnp.asarray(rng.normal(0, 0.5, (B, L, cfg.d_model)), jnp.float32)
    y_par, _ = mlstm_block(p, x, cfg, chunk=4, dtype=jnp.float32)
    state = None
    ys = []
    for t in range(L):
        y_t, state = mlstm_block(p, x[:, t:t + 1], cfg, state=state,
                                 dtype=jnp.float32)
        ys.append(np.asarray(y_t))
    y_rec = np.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_par), y_rec, atol=2e-4, rtol=2e-3)


def test_unrolled_matches_scanned():
    """cfg.scan_layers=False (calibration path) is numerically identical."""
    cfg = get_config("qwen3-14b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    l1, _ = model.loss_fn(params, batch)
    cfg2 = dataclasses.replace(cfg, scan_layers=False)
    model2 = build_model(cfg2)
    l2, _ = model2.loss_fn(params, batch)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)


def test_swa_ring_buffer_decode():
    """Mixtral-style SWA ring cache: decoding past the window stays finite
    and matches a full-cache decode inside the window."""
    cfg = get_config("mixtral-8x22b").reduced()
    cfg = dataclasses.replace(cfg, swa_window=8)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, W = 1, 8
    ring = model.init_cache(B, W, ring=True)
    full = model.init_cache(B, 64)
    step = jax.jit(model.decode_step)
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab_size, size=24)
    for t, tok in enumerate(toks):
        tk = jnp.full((B, 1), int(tok), jnp.int32)
        lr, ring = step(params, {"token": tk, "pos": jnp.asarray(t, jnp.int32),
                                 "cache": ring})
        lf, full = step(params, {"token": tk, "pos": jnp.asarray(t, jnp.int32),
                                 "cache": full})
        assert bool(jnp.all(jnp.isfinite(lr)))
        np.testing.assert_allclose(np.asarray(lr), np.asarray(lf),
                                   atol=2e-3, rtol=2e-3)


def test_bf16_grad_barrier_retypes_cotangent():
    """§Perf #7: the barrier forces bf16 cotangents (and is identity fwd)."""
    import jax
    import jax.numpy as jnp
    from repro.models.shardctx import bf16_grad_barrier

    def f(x, w):
        h = bf16_grad_barrier(x)
        return jnp.sum(jnp.square((h @ w).astype(jnp.float32)))

    x = jnp.ones((4, 8), jnp.bfloat16)
    w = jnp.ones((8, 4), jnp.bfloat16)
    g = jax.grad(f)(x, w)
    assert g.dtype == jnp.bfloat16
    # fp32 passthrough (smoke configs)
    x32 = jnp.ones((4, 8), jnp.float32)
    assert bf16_grad_barrier(x32).dtype == jnp.float32
