"""Fault tolerance: exact restart, failure injection, elastic reshard,
straggler semantics, checkpoint atomicity."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import latest_step, restore_checkpoint, save_checkpoint
from repro.configs import get_config
from repro.data import SyntheticLMData
from repro.launch.steps import make_train_step
from repro.runtime import BoundedDelayAccumulator, FaultConfig, StragglerConfig, TrainLoop
from repro.runtime.fault import SimulatedFailure


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen3-14b").reduced()
    model, train_step, init_state, _ = make_train_step(cfg)
    data = SyntheticLMData(cfg.vocab_size, 2, 16, seed=3)
    return cfg, jax.jit(train_step), init_state, data


def _batches(data, lo, hi):
    return [{k: jnp.asarray(v) for k, v in data.batch_at(t).items()}
            for t in range(lo, hi)]


def test_restart_bitwise_exact(setup, tmp_path):
    cfg, train_step, init_state, data = setup
    # uninterrupted reference
    p_ref, o_ref = init_state(jax.random.PRNGKey(0))
    for b in _batches(data, 0, 8):
        p_ref, o_ref, _ = train_step(p_ref, o_ref, b)

    # run with failure injected at step 6, checkpoints every 2
    fault = FaultConfig(ckpt_dir=str(tmp_path), ckpt_every=2, fail_at_step=6)
    loop = TrainLoop(train_step, fault)
    p, o = init_state(jax.random.PRNGKey(0))
    with pytest.raises(SimulatedFailure):
        loop.run(p, o, _batches(data, 0, 8))
    # recover: resume from latest checkpoint and replay the data stream
    step = latest_step(tmp_path)
    assert step == 6
    fault2 = FaultConfig(ckpt_dir=str(tmp_path), ckpt_every=2)
    loop2 = TrainLoop(train_step, fault2)
    start, p2, o2 = loop2.resume_or(lambda: init_state(jax.random.PRNGKey(0)))
    assert start == 6
    p2, o2, _ = loop2.run(p2, o2, _batches(data, start, 8), start_step=start)
    for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomic_and_gc(tmp_path):
    tree = {"w": jnp.arange(10.0), "nested": {"b": jnp.ones((3, 3))}}
    for s in (1, 2, 3, 4):
        save_checkpoint(tmp_path, s, tree)
    assert latest_step(tmp_path) == 4
    out = restore_checkpoint(tmp_path, 4, tree)
    np.testing.assert_array_equal(np.asarray(out["w"]), np.arange(10.0))
    # tmp dirs never linger
    assert not list(tmp_path.glob("*.tmp"))


def test_elastic_reshard_roundtrip(tmp_path):
    """Restore onto different shardings (mesh width change) — logical arrays
    are layout-free, device_put re-lays them out."""
    mesh = jax.make_mesh((1,), ("data",))
    from jax.sharding import NamedSharding, PartitionSpec as P
    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    save_checkpoint(tmp_path, 1, tree)
    sh = {"w": NamedSharding(mesh, P(None, None))}
    out = restore_checkpoint(tmp_path, 1, tree, shardings=sh)
    assert out["w"].sharding == sh["w"]


def test_straggler_accumulator():
    cfg = StragglerConfig(num_shards=4, quorum=0.75, max_delay=1, stale_decay=0.5)
    like = {"g": jnp.zeros(3)}
    acc = BoundedDelayAccumulator(cfg, like)
    g = {"g": jnp.ones(3)}
    # 3 of 4 shards arrive on time → quorum met
    for s in range(3):
        acc.submit(s, g, arrived_step=0)
    assert acc.ready(arrived=3)
    out = acc.take(arrived=3)
    np.testing.assert_allclose(np.asarray(out["g"]), 1.0)
    # straggler arrives one step late → folded in with decay 0.5
    acc.submit(3, g, arrived_step=0)
    for s in range(3):
        acc.submit(s, g, arrived_step=1)
    out = acc.take(arrived=4)
    np.testing.assert_allclose(np.asarray(out["g"]), (3 * 1.0 + 0.5) / 4)


def test_straggler_accumulator_tau_bounded_equals_synchronous_sum():
    """Property: with stale_decay=1.0 (pure bounded-delay, no damping),
    quorum-stepping with stale folds applies EXACTLY the synchronous
    gradient sum whenever every shard's gradient arrives within τ — no
    gradient is dropped, double-counted, or rescaled by the fold path."""
    hypothesis = pytest.importorskip(
        "hypothesis", reason="property tests need hypothesis")
    from hypothesis import given, settings, strategies as st

    @given(
        num_shards=st.integers(2, 5),
        steps=st.integers(1, 4),
        tau=st.integers(0, 2),
        data=st.data(),
    )
    @settings(max_examples=40, deadline=None)
    def run(num_shards, steps, tau, data):
        cfg = StragglerConfig(num_shards=num_shards, quorum=1.0 / num_shards,
                              max_delay=tau, stale_decay=1.0)
        acc = BoundedDelayAccumulator(cfg, {"g": jnp.zeros(2)})
        grads = np.asarray(data.draw(st.lists(
            st.lists(st.lists(
                st.floats(-8, 8, allow_nan=False, width=32),
                min_size=2, max_size=2),
                min_size=num_shards, max_size=num_shards),
            min_size=steps, max_size=steps)), np.float32)
        delays = np.asarray(data.draw(st.lists(
            st.lists(st.integers(0, tau),
                     min_size=num_shards, max_size=num_shards),
            min_size=steps, max_size=steps)))
        applied = np.zeros(2, np.float64)
        un_taken = 0    # submissions not yet folded into an applied step
        for t in range(steps + tau + 1):
            for step in range(steps):
                for s in range(num_shards):
                    if step + delays[step][s] == t:
                        acc.submit(s, {"g": jnp.asarray(grads[step][s])},
                                   arrived_step=step)
                        un_taken += 1
            if un_taken and acc.ready(un_taken):
                applied += np.asarray(
                    acc.take(arrived=un_taken)["g"], np.float64) * un_taken
                un_taken = 0
        if un_taken:    # τ-guard deferred the last fold: hard-sync drain
            applied += np.asarray(
                acc.take(arrived=un_taken)["g"], np.float64) * un_taken
        np.testing.assert_allclose(
            applied, grads.astype(np.float64).sum(axis=(0, 1)),
            rtol=1e-5, atol=1e-4)

    run()


def test_data_pipeline_deterministic():
    d1 = SyntheticLMData(1000, 4, 32, seed=9)
    d2 = SyntheticLMData(1000, 4, 32, seed=9)
    b1, b2 = d1.batch_at(17), d2.batch_at(17)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(d1.batch_at(17)["tokens"], d1.batch_at(18)["tokens"])
