"""Partitioner invariants (Algorithms 2 & 3) — property-based."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (
    evaluate, from_edges, need_matrix, partition_u, partition_v, random_parts,
    sequential_parsa,
)
from repro.graphs import text_like


@st.composite
def bipartite_graphs(draw):
    nu = draw(st.integers(5, 60))
    nv = draw(st.integers(5, 60))
    ne = draw(st.integers(1, 300))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    eu = rng.integers(0, nu, size=ne)
    ev = rng.integers(0, nv, size=ne)
    return from_edges(nu, nv, eu, ev)


@given(g=bipartite_graphs(), k=st.integers(2, 8))
@settings(max_examples=30, deadline=None)
def test_partition_u_invariants(g, k):
    res = partition_u(g, k)
    # disjoint cover
    assert res.parts_u.shape == (g.num_u,)
    assert np.all(res.parts_u >= 0) and np.all(res.parts_u < k)
    # perfect balance (select="size", one vertex at a time — §4.1)
    sizes = np.bincount(res.parts_u, minlength=k)
    assert sizes.max() - sizes.min() <= 1
    # returned neighbor sets == N(U_i)
    assert np.array_equal(res.neighbor_sets, need_matrix(g, res.parts_u, k))


@given(g=bipartite_graphs(), k=st.integers(2, 8))
@settings(max_examples=30, deadline=None)
def test_partition_v_invariants(g, k):
    parts_u = partition_u(g, k).parts_u
    need = need_matrix(g, parts_u, k)
    parts_v = partition_v(g, parts_u, k)
    for j in range(g.num_v):
        if need[:, j].any():
            assert parts_v[j] >= 0
            assert need[parts_v[j], j]  # v_ij ≤ u_ij (8b)
        else:
            assert parts_v[j] == -1     # isolated → unassigned


@given(g=bipartite_graphs(), k=st.integers(2, 6))
@settings(max_examples=20, deadline=None)
def test_repeated_sweeps_never_worse(g, k):
    """§3.2: repeated sweeps improve until convergence (convex ⇒ global)."""
    parts_u = partition_u(g, k).parts_u
    m1 = evaluate(g, parts_u, partition_v(g, parts_u, k, sweeps=1), k)
    m3 = evaluate(g, parts_u, partition_v(g, parts_u, k, sweeps=3), k)
    assert m3.traffic_max <= m1.traffic_max


def test_cost_definition_matches_bruteforce():
    g = text_like(60, 150, mean_len=10, seed=3)
    k = 4
    parts_u = partition_u(g, k).parts_u
    parts_v = partition_v(g, parts_u, k)
    m = evaluate(g, parts_u, parts_v, k)
    # brute force with python sets
    N = [set() for _ in range(k)]
    for u in range(g.num_u):
        N[parts_u[u]].update(g.neighbors(u).tolist())
    for i in range(k):
        Vi = set(np.flatnonzero(parts_v == i).tolist())
        worker = len(N[i] - Vi)
        server = sum(len(Vi & N[j]) for j in range(k) if j != i)
        assert m.footprint[i] == len(N[i])
        assert m.traffic[i] == worker + server


def test_parsa_beats_random_on_traffic(small_text_graph, small_ctr_graph):
    k = 8
    for g in (small_text_graph, small_ctr_graph):
        pu = sequential_parsa(g, k, b=4, a=2)
        pv = partition_v(g, pu, k)
        m = evaluate(g, pu, pv, k)
        mr = evaluate(g, random_parts(g.num_u, k, 0), random_parts(g.num_v, k, 1), k)
        assert m.traffic_max < mr.traffic_max
        assert m.traffic_sum < mr.traffic_sum


def test_init_sets_carry_over():
    """Incremental partitioning: warm S_i must change (and not hurt) results."""
    g = text_like(200, 500, mean_len=15, seed=5)
    k = 4
    r1 = partition_u(g, k)
    r2 = partition_u(g, k, init_sets=r1.neighbor_sets)
    assert np.array_equal(
        r2.neighbor_sets & ~r1.neighbor_sets,
        need_matrix(g, r2.parts_u, k) & ~r1.neighbor_sets)
