"""repro.sketch: column-compressed server sets — error-band properties of
the linear-counting estimates, the exact union homomorphism the lattice
algebra rides on, exact-parity regression (hot prefix >= |V| bit-identical
to device_scan, host and parallel backends), the fused VMEM-resident
sketch-cost+select kernel vs its oracle, O(1)-dispatch counters in sketch
mode, and sketched stream/elastic sessions.  The seeded property sweeps
extend the PR 5 padding-bit invariant suite; when hypothesis is installed
(CI), a fuzzed variant widens the geometry coverage."""
import numpy as np
import pytest

from repro.api import (
    ParsaConfig,
    ParsaStreamConfig,
    StreamSession,
    partition,
)
from repro.core import evaluate, partition_v
from repro.core.jax_partition import dispatch_counter
from repro.graphs import ctr_like, ctr_like_stream, text_like
from repro.kernels.parsa_cost import (
    pack_bitmask,
    packed_delta,
    packed_union,
    sketch_cost_select,
    sketch_select_ref,
    unpack_bitmask,
)
from repro.sketch import (
    SketchSpec,
    linear_counting_estimate,
    packed_popcount_rows,
    rank_hot_columns,
    set_structure_bytes,
)
from repro.sketch.spec import linear_counting_error


def _random_sets(rng, k, num_v, max_n):
    return [rng.choice(num_v, size=int(rng.integers(1, max_n)),
                       replace=False) for _ in range(k)]


def _spec(num_v, hot, buckets, seed=0):
    return SketchSpec(num_v=num_v, hot_bits=hot, bucket_bits=buckets,
                      seed=seed)


# ------------------------------------------------- property: the map itself
@pytest.mark.parametrize("k", [8, 64])
@pytest.mark.parametrize("seed", range(3))
def test_union_homomorphism_is_exact(k, seed):
    """sketch(a | b) == sketch(a) | sketch(b), bit for bit — the property
    that lets union / OR-merge / the arena run unchanged on sketched words.
    num_v is chosen ragged so the last true and sketched words are partial."""
    rng = np.random.default_rng(seed)
    num_v = int(rng.integers(900, 2000))
    spec = _spec(num_v, hot=256, buckets=128, seed=seed)
    a = np.asarray(pack_bitmask(_random_sets(rng, k, num_v, 200), num_v))
    b = np.asarray(pack_bitmask(_random_sets(rng, k, num_v, 200), num_v))
    sa, sb = spec.sketch_masks(a), spec.sketch_masks(b)
    su = spec.sketch_masks(np.asarray(packed_union(a, b)))
    assert np.array_equal(su, np.bitwise_or(sa, sb))


@pytest.mark.parametrize("k", [8, 64])
@pytest.mark.parametrize("seed", range(3))
def test_delta_containment_and_popcount_one_sided(k, seed):
    """sketch(a) & ~sketch(b) ⊆ sketch(a \\ b): a surviving sketched bit
    implies a surviving true column, so sketched marginal gains never
    invent work.  And popcount(sketch(x)) <= popcount(x): hashing only
    merges bits (one-sided error, exact on the hot prefix)."""
    rng = np.random.default_rng(seed + 100)
    num_v = int(rng.integers(900, 2000))
    spec = _spec(num_v, hot=256, buckets=128, seed=seed)
    a = np.asarray(pack_bitmask(_random_sets(rng, k, num_v, 300), num_v))
    b = np.asarray(pack_bitmask(_random_sets(rng, k, num_v, 300), num_v))
    sa, sb = spec.sketch_masks(a), spec.sketch_masks(b)
    sd = spec.sketch_masks(np.asarray(packed_delta(a, b)))
    lhs = np.bitwise_and(sa, np.bitwise_not(sb))
    assert not np.any(np.bitwise_and(lhs, np.bitwise_not(sd))), \
        "sketched delta lost a surviving bit"
    assert np.all(packed_popcount_rows(sa) <= packed_popcount_rows(a))
    # hot-only sets sketch losslessly
    hot_sets = [rng.choice(spec.hot_bits, size=40, replace=False)
                for _ in range(k)]
    hp = np.asarray(pack_bitmask(hot_sets, num_v))
    assert np.array_equal(packed_popcount_rows(spec.sketch_masks(hp)),
                          packed_popcount_rows(hp))


@pytest.mark.parametrize("seed", range(4))
def test_linear_counting_band(seed):
    """estimate_cardinality stays within error_band (4σ of the Whang et al.
    variance) of the true cardinality across load factors t = n/m up to ~2."""
    rng = np.random.default_rng(seed)
    num_v = 50_000
    spec = _spec(num_v, hot=512, buckets=2048, seed=seed)
    for tail_n in (50, 400, 1500, 4000):
        cols = np.concatenate([
            rng.choice(spec.hot_bits, size=30, replace=False),
            spec.hot_bits + rng.choice(num_v - spec.hot_bits, size=tail_n,
                                       replace=False)])
        row = np.asarray(pack_bitmask([spec.map_columns(cols)],
                                      spec.width_bits))[0]
        est = spec.estimate_cardinality(row)
        band = spec.error_band(tail_n, sigmas=4.0)
        assert abs(est - cols.size) <= band, \
            f"tail_n={tail_n}: |{est:.0f} - {cols.size}| > {band:.0f}"


def test_padding_bits_zero_in_sketched_masks():
    """Extends the PR 5 invariant: a ragged sketched width keeps every bit
    >= width_bits zero through sketch_masks and packed union/delta."""
    rng = np.random.default_rng(7)
    num_v = 1111
    spec = _spec(num_v, hot=96, buckets=72)   # width 168: ragged last word
    assert spec.width_bits % 32 != 0
    a = spec.sketch_masks(
        np.asarray(pack_bitmask(_random_sets(rng, 6, num_v, 400), num_v)))
    b = spec.sketch_masks(
        np.asarray(pack_bitmask(_random_sets(rng, 6, num_v, 400), num_v)))
    W = a.shape[1]
    for m in (a, b, np.asarray(packed_union(a, b)),
              np.asarray(packed_delta(a, b))):
        dense = unpack_bitmask(m, W * 32)
        assert not dense[:, spec.width_bits:].any()


def test_map_columns_ranked_hot_ids_and_growth():
    """Ranked hot ids get identity-rank slots; all other columns — including
    ids >= num_v (growing streams) — land in the bucket region."""
    g = ctr_like(500, 2000, nnz_per_row=12, seed=0)
    hot_ids = rank_hot_columns(g, 64)
    spec = SketchSpec(num_v=2000, hot_bits=64, bucket_bits=96,
                      hot_ids=hot_ids)
    got = spec.map_columns(hot_ids)
    assert np.array_equal(got, np.arange(64))
    cold = np.setdiff1d(np.arange(2000), hot_ids)[:500]
    mc = spec.map_columns(cold)
    assert np.all((mc >= 64) & (mc < spec.width_bits))
    grown = spec.map_columns(np.array([2000, 5000, 10**9]))
    assert np.all((grown >= 64) & (grown < spec.width_bits))
    # degree ranking: every hot column's degree >= every cold column's
    deg = np.bincount(g.u_indices, minlength=g.num_v)
    assert deg[hot_ids].min() >= deg[np.setdiff1d(np.arange(2000),
                                                  hot_ids)].max()


def test_for_graph_collapses_to_identity_and_expand_round_trip():
    spec = SketchSpec.for_graph(300, hot_bits=512, bucket_bits=128)
    assert spec.is_exact and spec.width_bits == 300
    assert np.array_equal(spec.map_columns(np.arange(300)), np.arange(300))
    # compressing expand: every true column inherits its slot's machine
    spec_c = _spec(1000, hot=128, buckets=64)
    pv_sketch = np.arange(spec_c.width_bits, dtype=np.int32) % 4
    pv = spec_c.expand_parts_v(pv_sketch)
    assert pv.shape == (1000,)
    assert np.array_equal(
        pv, pv_sketch[spec_c.map_columns(np.arange(1000, dtype=np.int64))])


def test_spec_validation_and_memory_model():
    with pytest.raises(ValueError, match="bucket_bits"):
        SketchSpec(num_v=100, hot_bits=32, bucket_bits=0)
    with pytest.raises(ValueError, match="hot_ids"):
        SketchSpec(num_v=100, hot_bits=32, bucket_bits=32,
                   hot_ids=np.arange(5))
    spec = _spec(10**8, hot=65_536, buckets=65_536)
    ratio = spec.exact_mem_bytes(16, 1024) / spec.mem_bytes(16, 1024)
    assert ratio > 700                       # 1e8 → 2^17 bits
    assert set_structure_bytes(2**17, 16, 1024, workers=4) == \
        4 * set_structure_bytes(2**17, 16, 1024, workers=1)


# ------------------------------------------ optional hypothesis fuzz (CI)
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    @settings(max_examples=50, deadline=None)
    @given(st.integers(64, 3000), st.integers(0, 2**31), st.integers(1, 64))
    def test_fuzz_union_homomorphism(num_v, seed, k):
        rng = np.random.default_rng(seed)
        hot = int(rng.integers(0, num_v))
        spec = SketchSpec(num_v=num_v, hot_bits=hot,
                          bucket_bits=int(rng.integers(1, 256)), seed=seed)
        a = np.asarray(pack_bitmask(
            _random_sets(rng, k, num_v, min(100, num_v)), num_v))
        b = np.asarray(pack_bitmask(
            _random_sets(rng, k, num_v, min(100, num_v)), num_v))
        sa, sb = spec.sketch_masks(a), spec.sketch_masks(b)
        su = spec.sketch_masks(np.asarray(packed_union(a, b)))
        assert np.array_equal(su, np.bitwise_or(sa, sb))
        assert np.all(packed_popcount_rows(sa) <= packed_popcount_rows(a))
except ImportError:                           # container has no hypothesis;
    pass                                      # CI installs it and runs this


def test_linear_counting_estimate_edge_cases():
    assert linear_counting_estimate(0, 64) == 0.0
    assert linear_counting_estimate(64, 64) > 64  # saturation clamp, finite
    assert linear_counting_error(64, 64) > linear_counting_error(4, 64)


# ----------------------------------------------------- exact-parity facade
def test_sketch_exact_parity_host_and_parallel():
    """set_repr="sketch" with hot prefix >= |V| is bit-identical to the
    exact device_scan pipeline — parts, sets, metrics — for the host scan
    and the parallel backend, so the sketch path cannot drift when it is
    not compressing."""
    g = text_like(500, 900, mean_len=15, seed=9)
    for backend, extra in [("device_scan", dict(block_size=64)),
                           ("parallel_device",
                            dict(workers=1, block_size=64, merge_every=2))]:
        cfg = ParsaConfig(k=8, backend=backend, sweeps=2, **extra)
        ref = partition(g, cfg)
        skc = partition(g, cfg.replace(set_repr="sketch",
                                       sketch_hot_bits=1024,
                                       sketch_bucket_bits=32))
        assert np.array_equal(ref.parts_u, skc.parts_u), backend
        assert np.array_equal(ref.parts_v, skc.parts_v), backend
        assert np.array_equal(np.asarray(ref.s_masks),
                              np.asarray(skc.s_masks)), backend
        assert ref.metrics.as_dict() == skc.metrics.as_dict(), backend
        assert skc.sketch is not None and skc.sketch.is_exact


def test_sketch_compressing_facade_end_to_end():
    """A compressing run: scan + refine at the sketched width, parts_v
    expanded to the true extent, placement forbidden, timings recorded."""
    g = ctr_like(800, 4000, nnz_per_row=15, seed=2)
    cfg = ParsaConfig(k=8, backend="device_scan", block_size=128,
                      set_repr="sketch", sketch_hot_bits=1024,
                      sketch_bucket_bits=512)
    res = partition(g, cfg)
    assert res.sketch is not None and not res.sketch.is_exact
    assert res.num_v == res.sketch.width_bits        # sets live sketched
    assert res.parts_v.shape == (4000,)              # expanded to true V
    assert res.parts_u.shape == (800,) and res.parts_u.max() < 8
    assert "sketch" in res.timings
    # cold-tail co-location: a bucketed column's machine equals its slot's
    pv_sketch_width = res.sketch.width_bits
    assert res.s_masks.shape[1] == (pv_sketch_width + 31) // 32
    with pytest.raises(ValueError, match="placement"):
        partition(g, cfg.replace(placement=True))


def test_sketch_refine_warm_start_keeps_spec():
    """result.refine(next_graph) re-uses the SAME spec (warm masks live in
    its sketch space — re-deriving a ranked spec would scramble them)."""
    g1 = ctr_like(600, 4000, nnz_per_row=15, seed=2)
    g2 = ctr_like(500, 4000, nnz_per_row=15, seed=3)
    cfg = ParsaConfig(k=8, backend="device_scan", block_size=128,
                      set_repr="sketch", sketch_hot_bits=1024,
                      sketch_bucket_bits=512)
    r1 = partition(g1, cfg)
    r2 = r1.refine(g2)
    assert r2.sketch is r1.sketch
    want = partition(g2, cfg, init_sets=r1.s_masks, sketch_spec=r1.sketch)
    assert np.array_equal(r2.parts_u, want.parts_u)
    assert np.array_equal(np.asarray(r2.s_masks), np.asarray(want.s_masks))


def test_sketch_quality_tracks_exact():
    """At 6x column compression with a ranked hot prefix the sketched
    partition's true-graph traffic_max stays within a loose factor of the
    exact run's (the tight 5% band is asserted at bench scale — this pins
    against catastrophic regressions at test scale)."""
    g = ctr_like(2000, 12_000, nnz_per_row=20, seed=5)
    k = 8
    cfg = ParsaConfig(k=k, backend="device_scan", block_size=256,
                      refine_v=False)
    re_ = partition(g, cfg)
    rs = partition(g, cfg.replace(set_repr="sketch", sketch_hot_bits=1024,
                                  sketch_bucket_bits=1024))
    te = evaluate(g, re_.parts_u, partition_v(g, re_.parts_u, k), k
                  ).traffic_max
    ts = evaluate(g, rs.parts_u, partition_v(g, rs.parts_u, k), k
                  ).traffic_max
    assert ts <= 1.5 * te, (ts, te)


# ------------------------------------------------ fused sketch select kernel
@pytest.mark.parametrize("B", [256, 1024])
@pytest.mark.parametrize("k", [8, 64])
@pytest.mark.parametrize("greedy", [False, True])
def test_sketch_select_kernel_bit_exact(B, k, greedy):
    """The gridless VMEM-resident kernel is bit-exact vs sketch_select_ref
    in interpret mode across block sizes, server counts, and both select
    modes, on a ragged sketched width (Ws = 12 words, padded to one lane
    tile inside the wrapper)."""
    rng = np.random.default_rng(B + k + greedy)
    width = 372                                   # 12 words, ragged
    nbr = np.asarray(pack_bitmask(
        [rng.choice(width, size=rng.integers(1, 60)) for _ in range(B)],
        width))
    s = np.asarray(pack_bitmask(
        (rng.random((k, width)) < 0.15), width))
    retired = rng.random(B) < 0.1
    order = rng.permutation(k).astype(np.int32)
    enabled = (rng.random(k) < 0.9)
    import jax.numpy as jnp

    args = (jnp.asarray(nbr), jnp.asarray(s), jnp.asarray(retired))
    kw = dict(order=jnp.asarray(order), enabled=jnp.asarray(enabled)) \
        if greedy else {}
    got = sketch_cost_select(*args, use_kernel=True, interpret=True,
                             **kw)
    want = sketch_cost_select(*args, use_kernel=False, **kw)
    assert np.array_equal(np.asarray(got[0]), np.asarray(want[0]))
    assert np.array_equal(np.asarray(got[1]), np.asarray(want[1]))


def test_sketch_select_ref_matches_dense_semantics():
    """On an uncompressed width the sketch oracle must agree with the
    packed cost + select composition it claims to fuse."""
    rng = np.random.default_rng(3)
    B, k, width = 128, 8, 640
    nbr = np.asarray(pack_bitmask(
        [rng.choice(width, size=20) for _ in range(B)], width))
    s = np.asarray(pack_bitmask((rng.random((k, width)) < 0.2), width))
    retired = np.zeros(B, bool)
    u, c = sketch_select_ref(nbr, s, retired, greedy=False)
    from repro.kernels.parsa_cost import parsa_cost_ref

    cost = np.asarray(parsa_cost_ref(nbr, s))
    assert np.array_equal(np.asarray(c)[0], cost.min(axis=0))
    assert np.array_equal(np.asarray(u)[0], cost.argmin(axis=0))


def test_sketch_select_kernel_width_guard():
    """Widths beyond SKETCH_KERNEL_MAX_WORDS fall back to the W-gridded
    dense kernel path instead of overflowing VMEM."""
    from repro.kernels.parsa_cost import SKETCH_KERNEL_MAX_WORDS

    rng = np.random.default_rng(0)
    width = (SKETCH_KERNEL_MAX_WORDS + 128) * 32
    nbr = np.asarray(pack_bitmask(
        [rng.choice(width, size=10) for _ in range(16)], width))
    s = np.asarray(pack_bitmask([rng.choice(width, size=50)
                                 for _ in range(4)], width))
    retired = np.zeros(16, bool)
    got = sketch_cost_select(nbr, s, retired, use_kernel=True,
                             interpret=True)
    want = sketch_cost_select(nbr, s, retired, use_kernel=False)
    assert np.array_equal(np.asarray(got[0]), np.asarray(want[0]))
    assert np.array_equal(np.asarray(got[1]), np.asarray(want[1]))


# --------------------------------------------------- O(1) dispatch + stream
def test_sketch_mode_o1_dispatches():
    """The per-phase dispatch counters hold unchanged in sketch mode —
    compression changes widths, never the launch structure."""
    g = ctr_like(800, 4000, nnz_per_row=15, seed=2)
    cfg = ParsaConfig(k=8, backend="device_scan", block_size=128,
                      refine_backend="device", set_repr="sketch",
                      sketch_hot_bits=1024, sketch_bucket_bits=512)
    partition(g, cfg)                             # warm the jitted pipeline
    with dispatch_counter() as counts:
        partition(g, cfg)
    assert counts == {"partition_scan": 1,
                      "refine_scan": 1, "metrics": 1}, counts


def _sketch_stream_cfg(k=4, hot=256, buckets=128, **kw):
    base = ParsaConfig(k=k, backend="device_scan", block_size=64,
                       use_kernel=False, refine_v=False, set_repr="sketch",
                       sketch_hot_bits=hot, sketch_bucket_bits=buckets)
    return ParsaStreamConfig(base=base, **kw)


def test_stream_sketch_feed_grow_and_o1_dispatch():
    """Sketched arena: feeds stay one dispatch, the arena's packed width is
    the sketch width, V growth beyond num_v is free (the hash covers any
    column id), and the result expands parts_v to the true extent."""
    num_v = 1500
    chunks = ctr_like_stream(600, num_v, chunks=3, nnz_per_row=10, seed=1)
    sess = StreamSession(_sketch_stream_cfg(repartition="never"),
                         num_v=num_v)
    assert sess.sketch is not None
    assert sess.arena.num_v == sess.sketch.width_bits
    for ch in chunks:
        with dispatch_counter() as counts:
            sess.feed(ch)
        assert counts["stream_feed_scan"] == 1
        assert sum(v for n, v in counts.items() if "scan" in n) == 1
    grown = BipartiteGraphGrow(chunks[0], num_v + 800)
    sess.feed(grown)                              # V grew past num_v
    res = sess.result()
    assert res.parts_u.shape[0] == sess.arena.num_u
    assert res.sketch is sess.sketch


def BipartiteGraphGrow(chunk, new_num_v):
    """A copy of ``chunk`` claiming a larger V extent (stream growth)."""
    from repro.core.bipartite import BipartiteGraph

    return BipartiteGraph(chunk.num_u, new_num_v,
                          np.asarray(chunk.u_indptr),
                          np.asarray(chunk.u_indices))


def test_stream_sketch_save_load_bit_identical(tmp_path):
    """Snapshot round trip rebuilds the identical spec from config + true
    extent: the resumed session feeds bit-identically."""
    num_v = 1200
    chunks = ctr_like_stream(500, num_v, chunks=3, nnz_per_row=10, seed=4)
    cfg = _sketch_stream_cfg(repartition="never")
    sess = StreamSession(cfg, num_v=num_v)
    sess.feed(chunks[0])
    sess.feed(chunks[1])
    path = tmp_path / "sketch_session.npz"
    sess.save(path)
    restored = StreamSession.load(path, cfg)
    assert restored.sketch is not None
    assert restored.sketch.width_bits == sess.sketch.width_bits
    assert restored._true_num_v == sess._true_num_v
    u1 = sess.feed(chunks[2])
    u2 = restored.feed(chunks[2])
    assert np.array_equal(u2.parts, u1.parts)
    assert np.array_equal(restored.arena.masks_np(), sess.arena.masks_np())


def test_elastic_sketch_grow_repair_one_dispatch():
    """Elastic ops on a sketched arena: grow and repair stay one scan each
    and leave a consistent sketched session."""
    from repro.api import ParsaStreamConfig
    from repro.elastic import ElasticConfig, ElasticSession

    base = ParsaConfig(k=4, backend="device_scan", block_size=64,
                       refine_v=False, set_repr="sketch",
                       sketch_hot_bits=256, sketch_bucket_bits=128)
    cfg = ElasticConfig(stream=ParsaStreamConfig(base=base,
                                                 repartition="never"),
                        min_k=2, max_k=16)
    sess = ElasticSession(cfg, num_v=1500)
    for ch in ctr_like_stream(600, 1500, chunks=3, nnz_per_row=10, seed=1):
        sess.feed(ch)
    assert sess.stream.sketch is not None
    k0 = sess.k
    with dispatch_counter() as counts:
        op = sess.grow_k(force=True)
    assert op.committed and sess.k == k0 + 1
    assert counts["elastic_grow_scan"] == 1
    assert sum(v for n, v in counts.items() if "scan" in n) == 1
    with dispatch_counter() as counts:
        op = sess.repair(1)
    assert counts["elastic_repair_scan"] == 1
    assert sum(v for n, v in counts.items() if "scan" in n) == 1
    assert sess.parts.max() < sess.k
    assert sess.parts.shape[0] == sess.stream.arena.num_u
