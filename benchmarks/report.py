"""Roofline report generator: reads benchmarks/out/dryrun/*.json and emits
the §Dry-run and §Roofline tables for EXPERIMENTS.md.

Two memory terms are reported:
  * t_mem(HLO)      — `bytes accessed` from the CPU-backend compile.  The
    CPU pipeline barely fuses, so every elementwise intermediate round-trips
    through "memory"; on TPU these chains fuse.  Kept because the prompt's
    formula asks for it — treat as a pessimistic bound.
  * t_mem(analytic) — minimum-traffic model of the fused TPU execution:
    weight-shard reads per pass (×3 for fwd/bwd/remat, ×microbatches),
    optimizer state read/write, saved activations at remat boundaries,
    KV-cache sweeps for decode, logits.  Used for the bottleneck call and
    the roofline fraction (§Perf iterates on whichever term dominates).

Run:  PYTHONPATH=src python -m benchmarks.report [--mesh 16x16]
"""
from __future__ import annotations

import argparse
import json
import pathlib

import numpy as np

HW = {"peak": 197e12, "hbm": 819e9, "ici": 50e9}
OUT = pathlib.Path(__file__).resolve().parent / "out"
ROOT = pathlib.Path(__file__).resolve().parents[1]
DRY = OUT / "dryrun"

# Version of the BENCH_*.json schemas, stamped by every emitter.  Bump on
# any key *rename or removal* (keys are append-only by contract, so bumps
# should be rare); consumers (check_regression.py, the PR driver) use it
# to refuse cross-version comparisons instead of mis-parsing.
SCHEMA_VERSION = 1


def emit_parsa_bench(rows: list[dict], name: str = "BENCH_parsa",
                     meta: dict | None = None) -> pathlib.Path:
    """Machine-readable Parsa perf trajectory: benchmarks/out/<name>.json.

    ``rows`` carry one partitioning run each (backend, workers, wall-clock
    seconds, traffic counters/quality); the driver tracks these across PRs,
    so keys must stay append-only.  Returns the written path.
    """
    OUT.mkdir(exist_ok=True)
    path = OUT / f"{name}.json"
    payload = {"benchmark": "parsa", "schema_version": SCHEMA_VERSION,
               **(meta or {}), "rows": rows}
    path.write_text(json.dumps(payload, indent=2, sort_keys=True) + "\n")
    print(f"# wrote {path}")
    return path


def emit_pipeline_bench(rows: list[dict],
                        meta: dict | None = None) -> pathlib.Path:
    """Per-phase wall-clock trajectory of the one-call ``partition()``
    pipeline: repo-root ``BENCH_pipeline.json``.

    Each row is one (backend, refine_backend, phase) cell with
    ``wall_clock_s`` — phases are the ``PartitionResult.timings`` keys
    (pack, partition_u, partition_v, metrics, total).  Lives at the repo
    root (not benchmarks/out) so the cross-PR perf trajectory is tracked in
    version control alongside the code that moved it; keys are append-only.
    """
    path = ROOT / "BENCH_pipeline.json"
    payload = {"benchmark": "parsa_pipeline",
               "schema_version": SCHEMA_VERSION, **(meta or {}),
               "rows": rows}
    if path.exists():
        # preserve the streaming/chaos benchmark sections (written by
        # emit_stream_bench / emit_chaos_bench) — the emitters own
        # disjoint keys
        old = json.loads(path.read_text())
        for key in ("stream_rows", "stream_meta", "chaos_rows",
                    "chaos_meta", "stream_rows_quick", "stream_meta_quick",
                    "chaos_rows_quick", "chaos_meta_quick"):
            if key in old:
                payload.setdefault(key, old[key])
    path.write_text(json.dumps(payload, indent=2, sort_keys=True) + "\n")
    print(f"# wrote {path}")
    return path


def emit_stream_bench(rows: list[dict], meta: dict | None = None,
                      quick: bool = False) -> pathlib.Path:
    """Append the streaming benchmark's per-chunk rows to the repo-root
    ``BENCH_pipeline.json`` trajectory.

    Each row is one fed chunk (``chunk``, ``feed_s``, ``scratch_s``,
    ``speedup_vs_scratch``, ``traffic_max`` …).  The pipeline payload's
    existing keys are preserved (append-only schema): stream rows land
    under ``stream_rows`` / ``stream_meta`` so re-runs replace rather than
    duplicate them, and a missing file is created with an empty pipeline
    section.  ``quick=True`` (CI-scale run) lands under
    ``stream_rows_quick`` / ``stream_meta_quick`` so a smoke run never
    clobbers the acceptance numbers.
    """
    path = ROOT / "BENCH_pipeline.json"
    if path.exists():
        payload = json.loads(path.read_text())
    else:
        payload = {"benchmark": "parsa_pipeline", "rows": []}
    payload["schema_version"] = SCHEMA_VERSION
    suffix = "_quick" if quick else ""
    payload[f"stream_rows{suffix}"] = rows
    payload[f"stream_meta{suffix}"] = meta or {}
    path.write_text(json.dumps(payload, indent=2, sort_keys=True) + "\n")
    print(f"# wrote {path} (+{len(rows)} stream rows{suffix or ''})")
    return path


def emit_chaos_bench(rows: list[dict], meta: dict | None = None,
                     quick: bool = False) -> pathlib.Path:
    """Append the elastic chaos benchmark's per-feed rows to the repo-root
    ``BENCH_pipeline.json`` trajectory.

    Each row is one chaos-scripted feed (``feed``, ``k``, ``events``,
    ``traffic_max``, ``migration_bytes_total`` …); ``meta`` carries the
    warm-repair vs cold-repartition wall clocks and the final quality gap
    vs the oracle static partition.  Existing keys (pipeline, stream) are
    preserved — chaos rows land under ``chaos_rows`` / ``chaos_meta`` so
    re-runs replace rather than duplicate them.  ``quick=True`` lands
    under ``chaos_rows_quick`` / ``chaos_meta_quick`` so a smoke run
    never clobbers the acceptance numbers.
    """
    path = ROOT / "BENCH_pipeline.json"
    if path.exists():
        payload = json.loads(path.read_text())
    else:
        payload = {"benchmark": "parsa_pipeline", "rows": []}
    payload["schema_version"] = SCHEMA_VERSION
    suffix = "_quick" if quick else ""
    payload[f"chaos_rows{suffix}"] = rows
    payload[f"chaos_meta{suffix}"] = meta or {}
    path.write_text(json.dumps(payload, indent=2, sort_keys=True) + "\n")
    print(f"# wrote {path} (+{len(rows)} chaos rows{suffix or ''})")
    return path


def emit_system_bench(rows: list[dict], meta: dict | None = None,
                      quick: bool = False) -> pathlib.Path:
    """Write the serving-system benchmark grid to the repo-root
    ``BENCH_system.json`` trajectory.

    Schema (append-only; the driver tracks these keys across PRs):

    * ``benchmark``: always ``"parsa_system"``.
    * ``rows`` — one row per (placement, mode) cell of the
      {random, parsa} x {sync, async} serving grid, each carrying:
      ``placement`` ("random"/"parsa"), ``mode`` ("sync"/"async"),
      ``requests``, ``examples``, ``tokens``, ``wall_s``,
      ``examples_s``, ``tokens_s``, ``p50_ms``, ``p99_ms``,
      ``mean_ms``, ``wire_s`` (modeled transfer seconds),
      ``blocked_s`` (wall time actually spent blocked on pulls),
      ``compute_s``, ``hidden_s`` (wire hidden behind compute — the
      measured overlap), ``hidden_frac``, ``pull_inter_bytes``,
      ``push_inter_bytes``, ``stale_entries``, ``fresh_entries``.
    * ``meta`` — the run configuration (graph, k, bandwidth, request
      counts) plus the derived headline ratios:
      ``speedup_parsa_async_vs_random_sync`` (the end-to-end claim),
      ``async_speedup_parsa`` / ``async_speedup_random`` (overlap win
      at equal placement), ``traffic_cut_pct`` (pull inter-machine
      bytes, parsa vs random).

    ``quick=True`` (CI-scale run) lands under ``rows_quick`` /
    ``meta_quick`` instead, so a smoke run never clobbers the
    acceptance numbers.  Either write preserves the other section's
    keys — re-runs replace rather than duplicate their own section.
    """
    path = ROOT / "BENCH_system.json"
    if path.exists():
        payload = json.loads(path.read_text())
    else:
        payload = {"benchmark": "parsa_system"}
    payload["schema_version"] = SCHEMA_VERSION
    suffix = "_quick" if quick else ""
    payload[f"rows{suffix}"] = rows
    payload[f"meta{suffix}"] = meta or {}
    path.write_text(json.dumps(payload, indent=2, sort_keys=True) + "\n")
    print(f"# wrote {path} (+{len(rows)} system rows{suffix or ''})")
    return path


def emit_slo_bench(rows: list[dict], meta: dict | None = None,
                   quick: bool = False) -> pathlib.Path:
    """Append the closed-loop SLO benchmark's per-decision-window rows to
    the repo-root ``BENCH_system.json`` trajectory.

    Schema (append-only; the driver tracks these keys across PRs):

    * ``slo_rows`` — one row per autoscaler decision window of the
      closed-loop run, each carrying:
      ``window`` (decision index), ``step`` (engine slot of the
      snapshot), ``k`` (fleet size at decision time), ``p99_ms`` /
      ``p50_ms`` (modeled sliding-window latency percentiles the loop
      decides on), ``p99_measured_ms`` (wall-clock window p99, reported
      but never gated on — CI runners jitter), ``max_occupancy_s``
      (worst per-machine virtual NIC backlog), ``load_factor`` (burst
      multiplier in force), ``shed`` (cumulative shed requests),
      ``served`` (cumulative served requests), ``action`` ("hold" /
      "grow" / "shrink" / "rebalance"), ``reason`` (the decision's
      trigger, human-readable), ``within_slo`` (bool: window p99 ≤ SLO),
      ``open_circuits`` (count of breaker-open links).
    * ``slo_meta`` — run configuration (graph, k0, SLO, chaos script,
      admission bound) plus the headline results: ``hold_frac``
      (fraction of post-warmup windows within SLO, the acceptance
      gate), ``baseline_hold_frac`` (static-k run, must violate),
      ``shed_frac``, ``k_trajectory``, ``ops`` (committed elastic ops
      with their triggers), ``deterministic`` (bit-identical replay).

    ``quick=True`` lands under ``slo_rows_quick`` / ``slo_meta_quick``
    so a CI smoke run never clobbers the acceptance numbers.  Other
    emitters' keys (system rows/meta) are preserved — re-runs replace
    only their own section.
    """
    path = ROOT / "BENCH_system.json"
    if path.exists():
        payload = json.loads(path.read_text())
    else:
        payload = {"benchmark": "parsa_system"}
    payload["schema_version"] = SCHEMA_VERSION
    suffix = "_quick" if quick else ""
    payload[f"slo_rows{suffix}"] = rows
    payload[f"slo_meta{suffix}"] = meta or {}
    path.write_text(json.dumps(payload, indent=2, sort_keys=True) + "\n")
    print(f"# wrote {path} (+{len(rows)} slo rows{suffix or ''})")
    return path


def validate_bench_files(tmp_dir: str | pathlib.Path | None = None) -> dict:
    """Round-trip every BENCH emitter against a scratch directory and assert
    the append-only contract: each emitter must preserve every other
    emitter's keys, and every payload must carry ``schema_version``.

    Runs against a temp dir (never the real trajectory files).  Returns the
    final payloads keyed by file name so callers/tests can inspect them.
    Raises ``AssertionError`` on any contract violation.
    """
    import contextlib
    import io
    import tempfile

    global OUT, ROOT
    ctx = tempfile.TemporaryDirectory() if tmp_dir is None else None
    base = pathlib.Path(ctx.name if ctx is not None else tmp_dir)
    saved_out, saved_root = OUT, ROOT
    OUT, ROOT = base / "out", base
    try:
        row = {"probe": 1.0}
        meta = {"probe_meta": "x"}
        with contextlib.redirect_stdout(io.StringIO()):
            # BENCH_pipeline.json: four emitters share one file.  Write the
            # section-owners first, then re-emit the pipeline rows — the
            # preserve-keys loop must keep every section alive.
            emit_stream_bench([row], meta)
            emit_stream_bench([row], meta, quick=True)
            emit_chaos_bench([row], meta)
            emit_chaos_bench([row], meta, quick=True)
            emit_pipeline_bench([row], meta)
            # BENCH_system.json: system + slo emitters, full and quick.
            emit_system_bench([row], meta)
            emit_system_bench([row], meta, quick=True)
            emit_slo_bench([row], meta)
            emit_slo_bench([row], meta, quick=True)
            emit_parsa_bench([row], meta=meta)
        pipeline = json.loads((ROOT / "BENCH_pipeline.json").read_text())
        system = json.loads((ROOT / "BENCH_system.json").read_text())
        parsa = json.loads((OUT / "BENCH_parsa.json").read_text())
        expect_pipeline = {
            "benchmark", "schema_version", "rows", "probe_meta",
            "stream_rows", "stream_meta", "stream_rows_quick",
            "stream_meta_quick", "chaos_rows", "chaos_meta",
            "chaos_rows_quick", "chaos_meta_quick",
        }
        missing = expect_pipeline - set(pipeline)
        assert not missing, f"BENCH_pipeline.json dropped keys: {sorted(missing)}"
        expect_system = {
            "benchmark", "schema_version", "rows", "meta", "rows_quick",
            "meta_quick", "slo_rows", "slo_meta", "slo_rows_quick",
            "slo_meta_quick",
        }
        missing = expect_system - set(system)
        assert not missing, f"BENCH_system.json dropped keys: {sorted(missing)}"
        for name, payload in (("BENCH_pipeline.json", pipeline),
                              ("BENCH_system.json", system),
                              ("BENCH_parsa.json", parsa)):
            assert payload.get("schema_version") == SCHEMA_VERSION, \
                f"{name} missing/stale schema_version: {payload.get('schema_version')!r}"
        assert pipeline["stream_rows"] == [row]
        assert system["slo_rows_quick"] == [row]
        assert parsa["rows"] == [row]
        return {"BENCH_pipeline.json": pipeline,
                "BENCH_system.json": system,
                "BENCH_parsa.json": parsa}
    finally:
        OUT, ROOT = saved_out, saved_root
        if ctx is not None:
            ctx.cleanup()


def pipeline_phase_rows(res, backend: str, refine_backend: str) -> list[dict]:
    """Flatten one PartitionResult's timings into BENCH_pipeline rows.

    Every row also carries ``mem_bytes`` — the peak width-dependent
    set-structure bytes of the run (``repro.sketch.set_structure_bytes``
    at the width the scan actually ran: the sketched width for
    ``set_repr="sketch"`` results, the true packed width otherwise) — so
    the sketch compression ratio is machine-tracked next to the wall
    clocks.
    """
    from repro.sketch import set_structure_bytes

    cfg = res.config
    workers = 1
    if backend.startswith("parallel"):
        workers = cfg.devices if cfg.devices is not None else cfg.workers
    mem_bytes = set_structure_bytes(res.num_v, res.k, cfg.block_size,
                                    workers=workers)
    return [
        {"backend": backend, "refine_backend": refine_backend,
         "phase": phase, "wall_clock_s": seconds, "mem_bytes": mem_bytes}
        for phase, seconds in sorted(res.timings.items())
    ]

SHAPE_INFO = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}


def _mesh_sizes(mesh_name: str):
    parts = [int(x) for x in mesh_name.split("x")]
    tp = parts[-1]
    dp = int(np.prod(parts[:-1]))
    return dp, tp


def analytic_hbm_bytes(cell: dict, cfg_extra: dict) -> float:
    """Fused-execution HBM traffic model, per device per step."""
    from repro.configs import get_config

    cfg = get_config(cell["arch"])
    info = SHAPE_INFO[cell["shape"]]
    dp, tp = _mesh_sizes(cell["mesh"])
    N, Na = cell["params_total"], cell["params_active"]
    B, S = info["batch"], info["seq"]
    D, L, V = cfg.d_model, cfg.num_layers, cfg.padded_vocab
    tok_local = max(B // dp, 1) * S
    state_shards = tp * (dp if cfg.fsdp else 1)
    opt_b = 2 if cfg.opt_dtype == "bfloat16" else 4

    if info["kind"] == "train":
        n_micro = max(cfg.microbatches, 1)
        weights = 3 * (N * 2 / tp) * n_micro          # fwd+bwd+remat, bf16
        opt = (2 * 4 + 4 * opt_b + 2 * 4) * N / state_shards
        acts = 6 * L * (tok_local / n_micro) * D * 2 * n_micro
        logits = 3 * tok_local * (V / tp) * 4
        return weights + opt + acts + logits
    if info["kind"] == "prefill":
        weights = N * 2 / tp
        acts = 4 * L * tok_local * D * 2
        cache = L * tok_local * 2 * cfg.num_kv_heads * cfg.head_dim * 2 \
            if not cfg.mla else L * tok_local * (cfg.kv_lora_rank + cfg.rope_head_dim) * 2
        logits = tok_local * (V / tp) * 2
        return weights + acts + cache + logits
    # decode
    weights = Na * 2 / tp
    b_local = max(B // dp, 1)
    if cfg.family == "xlstm":
        G = L // cfg.xlstm_group
        cache = G * (cfg.xlstm_group - 1) * b_local * cfg.num_heads \
            * cfg.head_dim * cfg.head_dim * 4 * 2 / tp
    elif cfg.family == "hybrid":
        G = L // cfg.hybrid_group
        d_in = cfg.ssm_expand * D
        Hs = d_in // cfg.ssm_headdim
        cache = G * (cfg.hybrid_group - 1) * b_local * Hs * cfg.ssm_headdim \
            * cfg.ssm_state * 2 * 2 / tp
        cache += G * b_local * min(S, 2**30) * 2 * cfg.num_kv_heads * cfg.head_dim * 2 / tp
    elif cfg.mla:
        cache = L * b_local * S * (cfg.kv_lora_rank + cfg.rope_head_dim) * 2 / tp
    else:
        eff_S = min(S, cfg.swa_window) if cfg.swa_window else S
        kv_shard = tp if cfg.num_kv_heads % tp == 0 else \
            (tp if cfg.head_dim % tp == 0 else 1)
        cache = L * b_local * eff_S * 2 * cfg.num_kv_heads * cfg.head_dim * 2 / kv_shard
    return weights + cache


def load_cells(mesh: str | None = None):
    cells = []
    for p in sorted(DRY.glob("*.json")):
        d = json.loads(p.read_text())
        if mesh and d.get("mesh") != mesh:
            continue
        cells.append(d)
    return cells


def build_rows(mesh: str):
    rows = []
    for c in load_cells(mesh):
        if c["status"] == "skip":
            rows.append({"arch": c["arch"], "shape": c["shape"], "mesh": mesh,
                         "status": "skip", "reason": c.get("reason", "")})
            continue
        if c["status"] != "ok":
            rows.append({"arch": c["arch"], "shape": c["shape"], "mesh": mesh,
                         "status": "fail", "reason": c.get("error", "")})
            continue
        r = c["roofline"]
        t_c = r["t_compute_s"]
        t_m_hlo = r["t_memory_s"]
        mem_an = analytic_hbm_bytes(c, {})
        t_m_an = mem_an / HW["hbm"]
        t_x = r["t_collective_s"]
        terms = {"compute": t_c, "memory": t_m_an, "collective": t_x}
        bneck = max(terms, key=terms.get)
        ideal = r["model_flops"] / r["chips"] / HW["peak"]
        frac = ideal / max(terms.values()) if max(terms.values()) else 0.0
        rows.append({
            "arch": c["arch"], "shape": c["shape"], "mesh": mesh, "status": "ok",
            "mem_GiB": r["peak_memory_per_device"] / 2**30,
            "t_compute_ms": t_c * 1e3,
            "t_mem_hlo_ms": t_m_hlo * 1e3,
            "t_mem_analytic_ms": t_m_an * 1e3,
            "t_collective_ms": t_x * 1e3,
            "bottleneck": bneck,
            "useful_ratio": r["useful_ratio"],
            "roofline_frac": frac,
            "roofline_frac_hlo": r["roofline_fraction"],
            "wire_GB": r["wire_bytes_per_device"] / 1e9,
            "compile_s": c.get("compile_s", 0),
        })
    return rows


def markdown(rows, mesh):
    out = [f"\n### Mesh {mesh}\n",
           "| arch | shape | status | mem/dev GiB | t_comp ms | t_mem(HLO) ms | "
           "t_mem(model) ms | t_coll ms | bottleneck | useful | roofline |",
           "|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | {r['status']} | "
                       f"{r.get('reason','')[:60]} | | | | | | | |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | ok | {r['mem_GiB']:.1f} | "
            f"{r['t_compute_ms']:.2f} | {r['t_mem_hlo_ms']:.1f} | "
            f"{r['t_mem_analytic_ms']:.2f} | {r['t_collective_ms']:.2f} | "
            f"{r['bottleneck']} | {r['useful_ratio']:.2f} | "
            f"{r['roofline_frac']:.1%} |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--markdown", action="store_true")
    args = ap.parse_args()
    meshes = [args.mesh] if args.mesh else ["16x16", "2x16x16"]
    for mesh in meshes:
        rows = build_rows(mesh)
        if not rows:
            continue
        if args.markdown:
            print(markdown(rows, mesh))
        else:
            ok = [r for r in rows if r["status"] == "ok"]
            print(f"\n=== {mesh}: {len(ok)} ok / {len(rows)} cells ===")
            for r in rows:
                if r["status"] == "ok":
                    print(f"{r['arch']:22s} {r['shape']:12s} mem={r['mem_GiB']:7.1f}G "
                          f"tc={r['t_compute_ms']:8.2f} tm={r['t_mem_analytic_ms']:8.2f} "
                          f"tx={r['t_collective_ms']:8.2f} {r['bottleneck']:10s} "
                          f"useful={r['useful_ratio']:5.2f} roof={r['roofline_frac']:6.1%}")
                else:
                    print(f"{r['arch']:22s} {r['shape']:12s} {r['status'].upper()} "
                          f"{r.get('reason','')[:70]}")


if __name__ == "__main__":
    main()
