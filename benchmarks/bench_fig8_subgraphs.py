"""Figure 8: subgraph count b × initialization fraction a/b (single thread).
Reproduces: more init data ⇒ better quality; small subgraphs + init beat
b=1; runtime grows with a."""
from __future__ import annotations

from repro.api import ParsaConfig, partition

from .common import datasets, emit, score


def run(scale: float = 0.6, k: int = 16):
    rows = []
    data = datasets(scale)
    for dname in ("ctr-like", "social-lj-like"):
        g = data[dname]
        for b in (1, 4, 16):
            for frac in (0.0, 0.5, 1.0, 2.0):      # a/b
                a = int(b * frac)
                cfg = ParsaConfig(k=k, blocks=b, init_iters=a, seed=0,
                                  refine_v=False)
                res = partition(g, cfg)
                parts, dt = res.parts_u, res.timings["partition_u"]
                rows.append({"dataset": dname, "b": b, "init_frac": frac,
                             "a": a, "time_s": dt, **score(g, parts, k)})
    emit(rows, "fig8_subgraphs")
    return rows


if __name__ == "__main__":
    run()
