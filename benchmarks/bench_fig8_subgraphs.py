"""Figure 8: subgraph count b × initialization fraction a/b (single thread).
Reproduces: more init data ⇒ better quality; small subgraphs + init beat
b=1; runtime grows with a."""
from __future__ import annotations

from repro.core import sequential_parsa

from .common import datasets, emit, score, timed


def run(scale: float = 0.6, k: int = 16):
    rows = []
    data = datasets(scale)
    for dname in ("ctr-like", "social-lj-like"):
        g = data[dname]
        for b in (1, 4, 16):
            for frac in (0.0, 0.5, 1.0, 2.0):      # a/b
                a = int(b * frac)
                parts, dt = timed(
                    lambda: sequential_parsa(g, k, b=b, a=a, seed=0))
                rows.append({"dataset": dname, "b": b, "init_frac": frac,
                             "a": a, "time_s": dt, **score(g, parts, k)})
    emit(rows, "fig8_subgraphs")
    return rows


if __name__ == "__main__":
    run()
