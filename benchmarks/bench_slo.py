"""Closed-loop SLO autoscaler benchmark: hold p99 through chaos (PR 8
acceptance run).

Two serving runs over the same CTR-like clustered graph, the same seeded
Zipf tenant mix, and the same seeded ``ChaosSchedule`` (a load burst, one
machine kill, one straggler):

  * **static baseline** — a fixed ``k0``-shard fleet with no admission
    control and no elasticity; a ``_WindowMonitor`` records the same
    decision-cadence telemetry snapshots the autoscaler would see, but
    every decision is "hold".  Under the burst its per-home virtual NIC
    backlog grows without bound, so the windowed modeled p99 blows
    through the SLO and stays there until long after the burst calms.
  * **closed loop** — an ``SLOAutoscaler`` owns the ``ElasticSession``
    policy consult: sustained over-SLO windows grow ``k`` (splitting the
    hottest-footprint part), the kill is repaired the slot its circuit
    opens, EWMA drift from the straggler reweights the router, sustained
    calm shrinks back to ``k0``, and bounded admission sheds the
    lowest-weight tenant first when a home's backlog exceeds its scaled
    bound.

Latency here is the *modeled* virtual-clock latency (wire + queue +
retry penalty + service time) — deterministic by construction, so the
whole closed-loop run replays bit-identically (asserted against a second
run: same ops, same decisions, same snapshots, same shed counts).

The overload is *calibrated*, not hard-coded: the benchmark measures the
mix's mean remote pull bytes at burst load and sets the NIC bandwidth so
one burst-load visit books ``visit_over`` x the fleet's per-home service
cadence (``k0 * service_model_s``).  Growing k stretches the cadence
past the visit cost, which is exactly the relief valve the autoscaler
controls; the SLO is then placed a fixed margin above the burst wire
time so only *queueing* (the thing the loop can fix) violates it.

``run_acceptance()`` gates on the shared ``benchmarks.common``
thresholds: post-warmup windowed-p99 hold fraction >=
``SLO_MIN_HOLD_FRAC`` for the closed loop, baseline hold fraction below
it, shed fraction <= ``SLO_MAX_SHED_FRAC``, exactly one
``elastic_grow_scan`` per grow and one ``elastic_repair_scan`` per
repair, and bit-deterministic replay.  Per-decision-window rows land in
``benchmarks/out/slo_bench*.csv`` and the repo-root ``BENCH_system.json``
under ``slo_rows`` (``report.emit_slo_bench``); ``run()`` is the
CI-scale variant (same machinery and determinism/dispatch assertions,
no hold-fraction floors — the dynamics need the long run to dilute the
detection transient).
"""
from __future__ import annotations

import dataclasses
import pathlib

import numpy as np

from repro.api import (ChaosEvent, ChaosSchedule, ElasticConfig,
                       ElasticSession, Observability, ParsaConfig,
                       ParsaStreamConfig, SLOAutoscaler, SLOConfig,
                       chrome_trace_json, save_chrome_trace)
from repro.obs.recorder import CAUSE_KINDS
from repro.core import partition_v
from repro.core.jax_partition import dispatch_counter
from repro.elastic import AutoscaleDecision
from repro.graphs import ctr_like
from repro.ml import DBPGConfig, PSCluster
from repro.runtime.fault import RetryPolicy
from repro.serving import (PSRequestSource, RequestMix, ServingConfig,
                           ServingEngine, ZipfWorkload)

from .common import SLO_MAX_SHED_FRAC, SLO_MIN_HOLD_FRAC, emit
from .report import emit_slo_bench


def _mix() -> RequestMix:
    """Two tenants with a 3:1 weight split so admission control has a
    shedding order to demonstrate: the light tenant's backlog bound is a
    third of the heavy tenant's."""
    return RequestMix((
        ZipfWorkload("checkout", batch=72, zipf_s=1.1, weight=3.0),
        ZipfWorkload("reco", batch=48, zipf_s=1.3, hot_offset=777,
                     weight=1.0),
    ))


class _WindowMonitor:
    """The static baseline's stand-in autoscaler: identical decision
    cadence and telemetry windows, but every decision is "hold" — it can
    watch the SLO burn, it just cannot act (no elastic session, no
    admission bound)."""

    def __init__(self, config: SLOConfig):
        self.config = config
        self.decisions: list[tuple[object, AutoscaleDecision]] = []

    def decide(self, snap) -> AutoscaleDecision:
        d = AutoscaleDecision("hold", reason="static baseline")
        self.decisions.append((snap, d))
        return d

    def note_repair(self, snap, machine: int) -> None:  # pragma: no cover
        pass


def _events(n_slots: int, burst: float) -> tuple[ChaosEvent, ...]:
    """The disaster script, scaled to the run length: burst -> calm ->
    kill (seeded target) -> straggle -> recover."""
    at = lambda frac: int(n_slots * frac)  # noqa: E731
    return (
        ChaosEvent(feed=at(0.06), kind="burst", factor=burst),
        ChaosEvent(feed=at(0.30), kind="burst", factor=1.0),
        ChaosEvent(feed=at(0.45), kind="kill"),
        ChaosEvent(feed=at(0.60), kind="straggle", machine=1, factor=4.0),
        ChaosEvent(feed=at(0.80), kind="recover", machine=1),
    )


def _pilot_bytes(g, labels, parts_u, parts_v, k0, dcfg, load_factor: float,
                 service_model_s: float, slots: int = 160,
                 warm: int = 32) -> tuple[float, float]:
    """Measured steady-state (pull, push) inter-machine bytes per request
    at one load factor.  A pilot serving run on a throwaway cluster with
    an effectively infinite NIC — the value-delta cache makes runtime
    pull bytes far smaller than a cold ``plan_pull`` would suggest, so
    calibrating the overload needs the *measured* delta traffic."""
    cluster = _fresh_cluster(g, labels, parts_u, parts_v, k0, dcfg,
                             bandwidth=1e12)
    cfg = ServingConfig(prefetch=True, warmup=warm, seed=0,
                        pad_multiple=512,
                        service_model_s=service_model_s)
    src = PSRequestSource(cluster, _mix(), cfg)
    src.load_factor = load_factor
    engine = ServingEngine(src)
    engine.run(slots)
    recs = [r for r in engine.recorder.records if not r.warmup]
    pull = float(np.mean([r.pull_inter_bytes for r in recs]))
    push = float(np.mean([r.push_inter_bytes for r in recs]))
    return pull, push


def _calibrate(g, labels, parts_u, parts_v, k0, dcfg, burst: float,
               service_model_s: float, visit_over: float):
    """Pick the NIC bandwidth so one burst-load visit (pull + push) books
    ``visit_over`` x the per-home cadence ``k0 * service_model_s`` —
    just past saturation, which is the overload the autoscaler's cadence
    stretch (grow_k) can actually relieve.  Returns (bandwidth,
    pull-wire seconds at burst, per-visit seconds at base and burst)."""
    pull_b, push_b = _pilot_bytes(g, labels, parts_u, parts_v, k0, dcfg,
                                  burst, service_model_s)
    pull_0, push_0 = _pilot_bytes(g, labels, parts_u, parts_v, k0, dcfg,
                                  1.0, service_model_s)
    cadence = k0 * service_model_s
    bandwidth = (pull_b + push_b) / (visit_over * cadence)
    wire_burst = pull_b / bandwidth
    visit_base = (pull_0 + push_0) / bandwidth
    visit_burst = visit_over * cadence
    f_eff = (pull_b + push_b) / max(pull_0 + push_0, 1.0)
    assert f_eff >= 1.25, (
        f"burst x{burst} only moves delta traffic x{f_eff:.2f} — the "
        f"working set is saturated; raise the burst factor or shrink "
        f"the per-part feature pool")
    return bandwidth, wire_burst, visit_base, visit_burst


def _fresh_cluster(g, labels, parts_u, parts_v, k0, dcfg,
                   bandwidth: float) -> PSCluster:
    cluster = PSCluster(g, labels, parts_u.copy(), parts_v.copy(), k0,
                        dcfg, bandwidth=bandwidth)
    cluster.commit_weights(np.random.default_rng(1).normal(
        0, 0.1, g.num_v).astype(np.float32))
    return cluster


def _det_snap(snap) -> tuple:
    """The deterministic projection of a snapshot — everything except the
    wall-clock-measured p99, which is reported but never gated on."""
    return (snap.step, snap.k, snap.window, snap.p50_ms, snap.p99_ms,
            snap.mean_ms, snap.occupancy, snap.footprint, snap.sizes,
            snap.speeds, snap.shed, snap.served, snap.open_circuits,
            snap.load_factor)


def _signature(asc: SLOAutoscaler, src: PSRequestSource,
               sess: ElasticSession) -> dict:
    """Everything a bit-deterministic replay must reproduce."""
    return {
        "ops": tuple((op.kind, op.k_before, op.k_after, op.machine,
                      op.partner, op.committed, op.moved_u,
                      int(op.traffic.migration_bytes))
                     for op in sess.ops),
        "decisions": tuple((_det_snap(snap), d.action, d.target)
                           for snap, d in asc.decisions),
        "repairs": tuple((_det_snap(snap), m) for snap, m in asc.repairs),
        "shed": tuple(sorted(src.telemetry.shed.items())),
        "events": tuple(src.events),
    }


def _closed_loop_run(g, labels, parts_u, parts_v, k0, dcfg, bandwidth,
                     scfg, slo_cfg: SLOConfig, events, serve_cfg,
                     n_slots: int):
    """One full closed-loop serving run on fresh state; returns
    (autoscaler, source, session, engine summary, dispatch counts, obs).

    Every run carries its own ``Observability`` (tracer + flight
    recorder): the seeded replay pair must produce byte-identical trace
    and event streams, and ``recorder.explain()`` must attribute every
    violated post-warmup window — both gated in ``_bench``."""
    obs = Observability()
    asc = SLOAutoscaler(dataclasses.replace(slo_cfg, obs=obs))
    sess = ElasticSession(
        ElasticConfig(stream=scfg, min_k=slo_cfg.min_k,
                      max_k=slo_cfg.max_k),
        num_v=g.num_v, policy=asc)
    sess.feed(g)
    assert np.array_equal(sess.parts, parts_u), \
        "stream placement drifted from the serving placement"
    cluster = _fresh_cluster(g, labels, parts_u, parts_v, k0, dcfg,
                             bandwidth)
    src = PSRequestSource(cluster, _mix(),
                          dataclasses.replace(serve_cfg, obs=obs),
                          chaos=ChaosSchedule(list(events), seed=0),
                          elastic=sess, autoscaler=asc)
    engine = ServingEngine(src)
    with dispatch_counter() as counts:
        summary = engine.run(n_slots)
    return asc, src, sess, summary, dict(counts), obs


def _hold_frac(decisions, warmup_windows: int, slo_ms: float) -> float:
    post = decisions[warmup_windows:]
    if not post:
        return 1.0
    return sum(1 for snap, _ in post if snap.p99_ms <= slo_ms) / len(post)


def _window_rows(decisions, slo_ms: float) -> list[dict]:
    rows = []
    for i, (snap, d) in enumerate(decisions):
        rows.append({
            "window": i, "step": int(snap.step), "k": int(snap.k),
            "p50_ms": float(snap.p50_ms), "p99_ms": float(snap.p99_ms),
            "p99_measured_ms": float(snap.p99_measured_ms),
            "max_occupancy_s": float(snap.max_occupancy),
            "load_factor": float(snap.load_factor),
            "shed": int(snap.shed), "served": int(snap.served),
            "open_circuits": len(snap.open_circuits),
            "action": d.action, "reason": d.reason.replace(",", ";"),
            "within_slo": int(snap.p99_ms <= slo_ms),
        })
    return rows


def _bench(n_u: int, n_v: int, nnz: int, clusters: int, k0: int,
           n_slots: int, burst: float, name: str, quick: bool,
           min_hold_frac: float | None, max_shed_frac: float | None,
           service_model_s: float = 2e-3, visit_over: float = 1.06):
    g = ctr_like(num_impressions=n_u, num_features=n_v, nnz_per_row=nnz,
                 clusters=clusters, locality=0.85, seed=0)
    labels = np.where(np.random.default_rng(0).random(g.num_u) < 0.5,
                      1.0, -1.0).astype(np.float32)
    base = ParsaConfig(k=k0, backend="device_scan", block_size=128,
                       refine_v=False, seed=0)
    scfg = ParsaStreamConfig(base=base, repartition="never")

    # ---- the placement both cells serve: one stream feed of the full
    # graph (the elastic session's native state), owners via partition_v
    seed_sess = ElasticSession(ElasticConfig(stream=scfg), num_v=g.num_v)
    seed_sess.feed(g)
    parts_u = np.asarray(seed_sess.parts).copy()
    parts_v = np.asarray(partition_v(g, parts_u, k0, sweeps=2)).copy()
    dcfg = DBPGConfig(lam=0.05, lr=0.1, kkt_eps=0.0, compress=False,
                      error_feedback=False)

    # ---- calibrate the overload, then place the SLO above it
    bandwidth, wire_burst, visit_base, visit_burst = _calibrate(
        g, labels, parts_u, parts_v, k0, dcfg, burst, service_model_s,
        visit_over)
    # the SLO sits 2.6x above the mean base visit: the per-request byte
    # distribution is Zipf-skewed, so the base-load p99 tail runs ~2x the
    # mean and must clear the SLO with margin (a target the healthy fleet
    # already violates just produces grow/shrink thrash), while an
    # unmanaged burst queue blows far past it; the admission bound sits
    # just under the SLO so the loop *detects* the violation before
    # shedding can mask it
    slo_ms = 2.6e3 * visit_base
    cadence = k0 * service_model_s
    print(f"# calibrated: bandwidth {bandwidth:.3g} B/s, burst pull wire "
          f"{wire_burst * 1e3:.1f}ms, visit {visit_base * 1e3:.1f}ms -> "
          f"{visit_burst * 1e3:.1f}ms vs cadence {cadence * 1e3:.1f}ms, "
          f"SLO {slo_ms:.1f}ms")

    slo_cfg = SLOConfig(
        slo_ms=slo_ms, window_requests=16, decide_every=16,
        warmup_windows=2, patience=1, shrink_patience=3,
        cooldown_windows=0, shrink_p99_frac=0.5,
        shrink_occupancy_s=0.9 * visit_burst,
        min_k=k0, max_k=k0 + 6, drift_ratio=2.0, tau_escalation=4)
    retry = RetryPolicy(timeout_s=0.006, retries=0)
    serve_cfg = ServingConfig(
        prefetch=True, warmup=slo_cfg.decide_every, seed=0,
        pad_multiple=512, retry=retry, service_model_s=service_model_s,
        max_backlog_s=0.85 * slo_ms * 1e-3,
        tau_escalation=slo_cfg.tau_escalation,
        window_requests=slo_cfg.window_requests)
    base_cfg = ServingConfig(
        prefetch=True, warmup=slo_cfg.decide_every, seed=0,
        pad_multiple=512, retry=retry, service_model_s=service_model_s,
        window_requests=slo_cfg.window_requests)
    events = _events(n_slots, burst)

    # ---- static baseline: same chaos, same telemetry windows, no loop
    mon = _WindowMonitor(slo_cfg)
    base_src = PSRequestSource(
        _fresh_cluster(g, labels, parts_u, parts_v, k0, dcfg, bandwidth),
        _mix(), base_cfg, chaos=ChaosSchedule(list(events), seed=0),
        autoscaler=mon)
    base_summary = ServingEngine(base_src).run(n_slots)
    base_hold = _hold_frac(mon.decisions, slo_cfg.warmup_windows, slo_ms)
    base_peak = max(s.p99_ms for s, _ in mon.decisions)
    print(f"# baseline (static k={k0}): hold {base_hold:.1%}, "
          f"peak window p99 {base_peak:.1f}ms vs SLO {slo_ms:.1f}ms")

    # ---- the closed loop, twice: the second run must replay bit-for-bit
    asc, src, sess, summary, counts, obs = _closed_loop_run(
        g, labels, parts_u, parts_v, k0, dcfg, bandwidth, scfg, slo_cfg,
        events, serve_cfg, n_slots)
    asc2, src2, sess2, _, _, obs2 = _closed_loop_run(
        g, labels, parts_u, parts_v, k0, dcfg, bandwidth, scfg, slo_cfg,
        events, serve_cfg, n_slots)
    sig, sig2 = _signature(asc, src, sess), _signature(asc2, src2, sess2)
    for key in sig:
        assert sig[key] == sig2[key], \
            f"closed-loop replay is not bit-deterministic ({key} differ)"
    # ... and so must the virtual-clock trace and the flight recorder
    # (wall clocks and jit-cache evidence are excluded from the
    # deterministic export by default)
    assert chrome_trace_json(obs.tracer) == chrome_trace_json(obs2.tracer), \
        "seeded replays exported different traces"
    assert obs.recorder.to_json() == obs2.recorder.to_json(), \
        "seeded replays recorded different event streams"

    # ---- every violated post-warmup window must have a recorded cause
    explanations = []
    for i, (snap, _) in enumerate(asc.decisions):
        if i < slo_cfg.warmup_windows or snap.p99_ms <= slo_ms:
            continue
        ex = obs.explain(i)
        assert ex.attributed, (
            f"window {i} violated the SLO (p99 {snap.p99_ms:.1f}ms > "
            f"{slo_ms:.1f}ms) with no recorded cause in the flight "
            f"recorder — explain() came back empty")
        assert all(c["kind"] in CAUSE_KINDS for c in ex.causes), ex.causes
        explanations.append(str(ex))
    trace_path = pathlib.Path(__file__).resolve().parent / "out" / \
        f"{name}_trace.json"
    trace_path.parent.mkdir(exist_ok=True)
    save_chrome_trace(obs.tracer, trace_path)
    print(f"# obs: {len(obs.tracer.spans)} spans, {len(obs.recorder)} "
          f"events, {len(explanations)} violated windows all attributed; "
          f"trace -> {trace_path}")

    hold = _hold_frac(asc.decisions, slo_cfg.warmup_windows, slo_ms)
    shed = src.telemetry.shed_total
    shed_frac = shed / n_slots
    committed = [op for op in sess.ops if op.committed]
    kinds = {kind: sum(1 for op in committed if op.kind == kind)
             for kind in ("grow", "shrink", "repair")}
    k_traj = [int(s.k) for s, _ in asc.decisions]

    # O(1) dispatches per elastic op: every grow/repair attempt is exactly
    # one fused scan (shrink is a host lattice join — zero dispatches)
    n_grow_ops = sum(1 for op in sess.ops if op.kind == "grow")
    n_repair_ops = sum(1 for op in sess.ops if op.kind == "repair")
    assert counts.get("elastic_grow_scan", 0) == n_grow_ops, counts
    assert counts.get("elastic_repair_scan", 0) == n_repair_ops, counts
    assert counts["serving_pull"] == n_slots - shed, (counts, shed)
    assert counts["serving_compute"] == n_slots - shed, (counts, shed)
    assert src.dead == set(), "closed loop left a dead machine unrepaired"
    assert kinds["repair"] == 1, kinds   # the one kill, circuit-repaired

    print(f"# closed loop: hold {hold:.1%} (need >= "
          f"{min_hold_frac if min_hold_frac is not None else 0:.0%}), "
          f"shed {shed} ({shed_frac:.2%}), k {k0} -> {max(k_traj)} -> "
          f"{k_traj[-1]} ({kinds['grow']} grows, {kinds['shrink']} "
          f"shrinks, {kinds['repair']} repair)")

    rows = _window_rows(asc.decisions, slo_ms)
    emit(rows, name)
    emit(_window_rows(mon.decisions, slo_ms), name + "_baseline")
    emit_slo_bench(rows, meta={
        "graph": f"ctr_like({n_u}x{n_v}, nnz={nnz}, clusters={clusters}, "
                 f"locality=0.85)",
        "k0": k0, "n_slots": n_slots, "burst": burst,
        "bandwidth": float(bandwidth), "slo_ms": float(slo_ms),
        "service_model_s": service_model_s,
        "max_backlog_s": serve_cfg.max_backlog_s,
        "visit_base_ms": float(visit_base * 1e3),
        "visit_burst_ms": float(visit_burst * 1e3),
        "chaos": [f"{ev.feed}:{ev.kind}" for ev in events],
        "hold_frac": float(hold), "baseline_hold_frac": float(base_hold),
        "baseline_peak_p99_ms": float(base_peak),
        "shed_frac": float(shed_frac), "shed_per_tenant": dict(
            sorted(src.telemetry.shed.items())),
        "k_trajectory": k_traj,
        "ops": [f"{op.kind}(k{op.k_before}->{op.k_after}, m{op.machine})"
                for op in committed],
        "examples_s": float(summary["examples_s"]),
        "baseline_examples_s": float(base_summary["examples_s"]),
        "deterministic": True,
        "trace_spans": len(obs.tracer.spans),
        "recorder_events": len(obs.recorder),
        "violated_window_explanations": explanations,
    }, quick=quick)

    if min_hold_frac is not None:
        assert hold >= min_hold_frac, (
            f"closed loop held the SLO only {hold:.1%} of post-warmup "
            f"windows (need >= {min_hold_frac:.0%})")
        assert base_hold < min_hold_frac, (
            f"static baseline held {base_hold:.1%} — the chaos script "
            f"never stressed it; the comparison is vacuous")
        assert kinds["grow"] >= 1, "the loop never grew under the burst"
    if max_shed_frac is not None:
        assert shed_frac <= max_shed_frac, (
            f"admission shed {shed_frac:.2%} of offered requests "
            f"(limit {max_shed_frac:.0%})")
    return rows


def run(scale: float = 1.0, k0: int = 8):
    """CI-scale closed loop: same machinery, determinism and dispatch
    assertions, no hold-fraction floors (the detection transient needs
    the long acceptance run to amortize)."""
    s = min(scale, 1.0)
    return _bench(n_u=int(3_000 * s), n_v=int(5_000 * s), nnz=14,
                  clusters=16, k0=k0, n_slots=1024, burst=2.5,
                  name="slo_bench_quick", quick=True,
                  min_hold_frac=None, max_shed_frac=None)


def run_acceptance(n_u: int = 6_000, n_v: int = 8_000, nnz: int = 16,
                   clusters: int = 24, k0: int = 8, n_slots: int = 3072,
                   burst: float = 2.5,
                   min_hold_frac: float = SLO_MIN_HOLD_FRAC,
                   max_shed_frac: float = SLO_MAX_SHED_FRAC):
    """The PR 8 acceptance gate: under the seeded burst+kill+straggle
    script the closed loop holds the windowed modeled p99 within SLO for
    >= ``min_hold_frac`` of post-warmup decision windows while the
    static-k baseline violates it, shedding <= ``max_shed_frac``."""
    return _bench(n_u=n_u, n_v=n_v, nnz=nnz, clusters=clusters, k0=k0,
                  n_slots=n_slots, burst=burst, name="slo_bench",
                  quick=False, min_hold_frac=min_hold_frac,
                  max_shed_frac=max_shed_frac)


if __name__ == "__main__":
    import sys

    if "--acceptance" in sys.argv:
        run_acceptance()
    else:
        run()
