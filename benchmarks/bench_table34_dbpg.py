"""Tables 3/4 + Figure 1: ℓ1-LR (DBPG) traffic and modeled end-to-end time,
random vs Parsa placement.

Traffic bytes are measured exactly in the PS simulation.  Time uses the
paper's cluster model (1 GbE, §5.1) applied consistently to BOTH phases:
  inference  — measured per-machine inter-bytes / bandwidth + flops/rate
  partition  — k|E| edge-visits (the O(k|E|) bound) at c_ops each + the
               partitioner's own measured push/pull bytes / bandwidth
(the paper's Table 3: partition 0.07h amortizes against a 0.59h inference
saving; Python wall-clock is not comparable to their C++, so the model
prices both phases on the same hardware.)"""
from __future__ import annotations

from repro.api import ParsaConfig, partition
from repro.core import random_parts
from repro.graphs import ctr_like
from repro.ml import DBPGConfig, PSCluster, make_problem

from .common import emit

FLOPS_RATE = 50e9       # per machine (2015 Xeon-ish)
BANDWIDTH = 125e6       # 1 GbE
C_OPS = 12.0            # ops per (edge × partition) visit in Algorithm 3


def run(k: int = 16, iters: int = 45, scale: float = 1.0):
    g = ctr_like(int(1500 * scale), int(6000 * scale), nnz_per_row=25, seed=5)
    w_star, labels = make_problem(g, seed=5)
    cfg = DBPGConfig(lam=0.3, lr=0.005, max_delay=1)
    rows = []

    # Parsa partition (parallel, eventual consistency, global init — §5.4/5.5)
    parsa = partition(g, ParsaConfig(
        k=k, backend="parallel_sim", blocks=16, workers=4, tau=None,
        global_init_frac=0.01, seed=0, refine_v=True, sweeps=2))
    # model the partitioning phase on the same hardware
    part_compute = C_OPS * k * g.num_edges / (FLOPS_RATE * k)
    part_comm = (parsa.traffic.pushed_bytes + parsa.traffic.pulled_bytes) \
        / BANDWIDTH / k
    t_partition = part_compute + part_comm

    results = {}
    for method in ("random", "parsa"):
        if method == "parsa":
            tp = t_partition
            cl = PSCluster.from_partition(
                g, labels, parsa, cfg,
                flops_rate=FLOPS_RATE, bandwidth=BANDWIDTH, seed=1)
        else:
            tp = 0.0
            cl = PSCluster(g, labels, random_parts(g.num_u, k, 0),
                           random_parts(g.num_v, k, 1), k, cfg,
                           flops_rate=FLOPS_RATE, bandwidth=BANDWIDTH, seed=1)
        res = cl.run(iters, log_every=iters - 1)
        results[method] = dict(res, t_partition=tp)
        rows.append({
            "method": method,
            "partition_time_s": tp,
            "inner_MB": res["inner_bytes"] / 1e6,
            "inter_MB": res["inter_bytes"] / 1e6,
            "inner_fraction_pct": res["inner_fraction"] * 100,
            "modeled_inference_s": res["modeled_time_s"],
            "modeled_total_s": res["modeled_time_s"] + tp,
            "final_objective": res["objective"][-1],
        })
    r, p = results["random"], results["parsa"]
    reduction = 100 * (1 - p["inter_bytes"] / max(r["inter_bytes"], 1))
    speedup = (r["modeled_time_s"] + r["t_partition"]) / (
        p["modeled_time_s"] + p["t_partition"])
    print(f"# inter-machine traffic reduction: {reduction:.1f}% (paper: >90%); "
          f"end-to-end modeled speedup: {speedup:.2f}x (paper: 1.6x)")
    rows.append({"method": "ratio", "partition_time_s": 0.0, "inner_MB": 0.0,
                 "inter_MB": reduction, "inner_fraction_pct": 0.0,
                 "modeled_inference_s": 0.0, "modeled_total_s": speedup,
                 "final_objective": 0.0})
    emit(rows, "table34_dbpg")
    return rows


if __name__ == "__main__":
    run()
