"""Beyond-paper: Parsa placement inside the LM stack — embedding-gather
working set + remote-row traffic, and MoE expert-placement all-to-all bytes
(DESIGN §3)."""
from __future__ import annotations

import numpy as np

from repro.core.moe_placement import alltoall_traffic, build_expert_placement
from repro.core.placement import build_placement, gather_traffic
from repro.data import ParsaShardedData
from repro.graphs import text_like

from .common import emit


def run(k: int = 16):
    rows = []
    g = text_like(2000, 16000, mean_len=120, seed=9)  # doc × vocab
    for method in ("random", "parsa"):
        pl = build_placement(g, k, b=8, a=8, method=method, seed=0)
        t = gather_traffic(g, pl)
        data = ParsaShardedData(g, pl, batch=32 * k, seq=16, seed=0)
        ws = float(np.mean([data.working_set_per_shard(s).sum()
                            for s in range(3)]))
        rows.append({"layer": "embedding", "method": method,
                     "local_fraction_pct": t["local_fraction"] * 100,
                     "remote_rows_max": t["remote_rows_max"],
                     "footprint_max": t["footprint_max"],
                     "working_set_rows": ws})
    # MoE: clustered token→expert routing (deepseek-v2 scale: 160 experts)
    rng = np.random.default_rng(0)
    groups, experts = 256, 160
    counts = np.zeros((groups, experts), int)
    for gi in range(groups):
        fav = (gi * 7 + np.arange(12)) % experts
        counts[gi, fav] = rng.integers(4, 40, size=12)
    pl_e = build_expert_placement(counts, k)
    t = alltoall_traffic(counts, pl_e)
    rows.append({"layer": "moe-alltoall", "method": "parsa-vs-roundrobin",
                 "local_fraction_pct": t["reduction"] * 100,
                 "remote_rows_max": t["crossing_tokens_parsa"],
                 "footprint_max": t["crossing_tokens_roundrobin"],
                 "working_set_rows": 0.0})
    emit(rows, "embedding_traffic")
    return rows


if __name__ == "__main__":
    run()
