"""End-to-end serving system benchmark: the paper's traffic cut as a
measured wall-clock speedup (PR 7 acceptance run).

The grid is {random, parsa} placement x {sync, async} engine mode, four
fresh ``PSCluster`` + ``ServingEngine`` builds over the same CTR-like
clustered graph (campaign locality is what Parsa placement exploits — a
text graph's Zipf head has no cluster structure to keep local).  Every
cell serves the same seeded Zipf request mix; each request is one
batched pull -> compute -> push against the k-shard PS, with modeled
wire time slept out through the ingress-NIC bandwidth model, so
``examples_s`` and ``p99_ms`` are *measured* wall clock, not derived
from byte counts.

``run_acceptance()`` asserts:

  * parsa placement + async overlap serves >= ``min_speedup``x the
    examples/s of random placement + sync pulls (the end-to-end claim:
    the >90% traffic cut of §5.1 becomes throughput);
  * async overlap alone wins at EQUAL placement (>= ``min_async``x for
    both random and parsa) — the overlap is measured, not assumed:
    ``blocked_s`` collapses while ``wire_s`` stays put;
  * every request costs exactly ONE ``serving_pull`` and ONE
    ``serving_compute`` jitted dispatch (O(1) per step,
    ``dispatch_counter``-asserted — no hidden per-key loops).

Rows land in ``benchmarks/out/system_bench*.csv`` and the repo-root
``BENCH_system.json`` (``report.emit_system_bench``); ``run()`` is the
CI-scale variant (same grid and dispatch assertions, relaxed wall-clock
floors — shared runners are noisy).
"""
from __future__ import annotations

import numpy as np

from repro.api import ParsaConfig, partition
from repro.core import random_parts
from repro.core.jax_partition import dispatch_counter
from repro.graphs import ctr_like
from repro.ml import DBPGConfig, PSCluster
from repro.serving import (PSRequestSource, RequestMix, ServingConfig,
                           ServingEngine, ZipfWorkload)

from .common import SYSTEM_MIN_ASYNC, SYSTEM_MIN_SPEEDUP, emit
from .report import emit_system_bench

_ROW_KEYS = ("requests", "examples", "tokens", "wall_s", "examples_s",
             "tokens_s", "p50_ms", "p99_ms", "mean_ms", "wire_s",
             "blocked_s", "compute_s", "hidden_s", "hidden_frac",
             "pull_inter_bytes", "push_inter_bytes", "stale_entries",
             "fresh_entries")


def _mix() -> RequestMix:
    """Two Zipf tenants sharing the fleet: a big mild-skew workload and a
    smaller hot-headed one offset into a different part of the pool."""
    return RequestMix((
        ZipfWorkload("text", batch=256, zipf_s=1.1),
        ZipfWorkload("ctr", batch=128, zipf_s=1.3, hot_offset=777,
                     weight=0.5),
    ))


def _serve_cell(g, labels, parts_u, parts_v, k: int, dcfg: DBPGConfig,
                bandwidth: float, prefetch: bool, warmup: int,
                requests: int) -> dict:
    """One fresh cluster + engine build; returns the run summary with the
    O(1)-dispatch assertion already applied."""
    cluster = PSCluster(g, labels, parts_u, parts_v, k, dcfg,
                        bandwidth=bandwidth)
    # serve a trained (nonzero) model — an all-zero w has no deltas to pull
    cluster.commit_weights(np.random.default_rng(1).normal(
        0, 0.1, g.num_v).astype(np.float32))
    cfg = ServingConfig(prefetch=prefetch, warmup=warmup, seed=0)
    engine = ServingEngine(PSRequestSource(cluster, _mix(), cfg))
    with dispatch_counter() as counts:
        summary = engine.run(requests)
    # O(1) jitted dispatches per request: one pull issue, one serve step
    assert counts["serving_pull"] == requests, counts
    assert counts["serving_compute"] == requests, counts
    return summary


def _grid(n_u: int, n_v: int, nnz: int, clusters: int, k: int,
          bandwidth: float, requests: int, name: str, quick: bool,
          min_speedup: float | None, min_async: float | None):
    g = ctr_like(num_impressions=n_u, num_features=n_v, nnz_per_row=nnz,
                 clusters=clusters, locality=0.85, seed=0)
    labels = np.where(np.random.default_rng(0).random(g.num_u) < 0.5,
                      1.0, -1.0).astype(np.float32)
    res = partition(g, ParsaConfig(k=k, backend="device_scan",
                                   refine_backend="device", seed=0))
    placements = {
        "random": (random_parts(g.num_u, k, 0), random_parts(g.num_v, k, 1)),
        "parsa": (np.asarray(res.parts_u), np.asarray(res.parts_v)),
    }
    dcfg = DBPGConfig(lam=0.05, lr=0.1, kkt_eps=0.0, compress=False,
                      error_feedback=False)
    warmup = 2 * k            # two rounds per machine warm jit + caches
    rows, cells = [], {}
    for placement, (pu, pv) in placements.items():
        for mode, prefetch in (("sync", False), ("async", True)):
            s = _serve_cell(g, labels, pu, pv, k, dcfg, bandwidth,
                            prefetch, warmup, requests)
            cells[placement, mode] = s
            rows.append({"placement": placement, "mode": mode,
                         **{key: s[key] for key in _ROW_KEYS}})
            print(f"# {placement:6s} {mode:5s}: "
                  f"{s['examples_s']:9.0f} ex/s  {s['tokens_s']:9.0f} tok/s  "
                  f"p99 {s['p99_ms']:6.1f}ms  blocked {s['blocked_s']:.3f}s "
                  f"of {s['wire_s']:.3f}s wire  "
                  f"(pull inter {s['pull_inter_bytes']} B)")

    speedup = (cells["parsa", "async"]["examples_s"]
               / cells["random", "sync"]["examples_s"])
    async_parsa = (cells["parsa", "async"]["examples_s"]
                   / cells["parsa", "sync"]["examples_s"])
    async_random = (cells["random", "async"]["examples_s"]
                    / cells["random", "sync"]["examples_s"])
    cut_pct = 100.0 * (1.0 - cells["parsa", "async"]["pull_inter_bytes"]
                       / max(cells["random", "async"]["pull_inter_bytes"], 1))
    print(f"# parsa+async vs random+sync: {speedup:.2f}x examples/s")
    print(f"# async overlap at equal placement: parsa {async_parsa:.2f}x, "
          f"random {async_random:.2f}x")
    print(f"# pull inter-machine traffic cut (parsa vs random): "
          f"{cut_pct:.0f}%")

    emit(rows, name)
    emit_system_bench(rows, meta={
        "graph": f"ctr_like({n_u}x{n_v}, nnz={nnz}, clusters={clusters}, "
                 f"locality=0.85)",
        "k": k, "bandwidth": bandwidth, "requests": requests,
        "warmup": warmup,
        "speedup_parsa_async_vs_random_sync": speedup,
        "async_speedup_parsa": async_parsa,
        "async_speedup_random": async_random,
        "traffic_cut_pct": cut_pct,
    }, quick=quick)
    if min_speedup is not None:
        assert speedup >= min_speedup, (
            f"parsa+async only {speedup:.2f}x vs random+sync "
            f"(need >= {min_speedup}x; rerun on an idle box if contended)")
    if min_async is not None:
        assert min(async_parsa, async_random) >= min_async, (
            f"async overlap win {async_parsa:.2f}x/{async_random:.2f}x at "
            f"equal placement (need >= {min_async}x for both)")
    return rows


def run(scale: float = 1.0, k: int = 8):
    """CI-scale grid: same cells and dispatch assertions, small graph,
    no wall-clock floors (shared CI runners jitter too much to gate)."""
    s = min(scale, 1.0)
    return _grid(n_u=int(6_000 * s), n_v=int(8_000 * s), nnz=20,
                 clusters=24, k=k, bandwidth=2.5e5,
                 requests=2 * k + 24, name="system_bench_quick",
                 quick=True, min_speedup=None, min_async=None)


def run_acceptance(n_u: int = 50_000, n_v: int = 50_000, nnz: int = 24,
                   clusters: int = 64, k: int = 8,
                   bandwidth: float = 2.5e5, timed_requests: int = 40,
                   min_speedup: float = SYSTEM_MIN_SPEEDUP,
                   min_async: float = SYSTEM_MIN_ASYNC):
    """The PR 7 acceptance gate: >= ``min_speedup``x end-to-end on a
    50k x 50k clustered graph, k=8.  ``bandwidth`` is scaled down with
    the graph (~10^3 smaller than the paper's CTR runs) so the modeled
    wire time stays in the same ratio to compute as a real fleet's."""
    return _grid(n_u=n_u, n_v=n_v, nnz=nnz, clusters=clusters, k=k,
                 bandwidth=bandwidth, requests=2 * k + timed_requests,
                 name="system_bench", quick=False,
                 min_speedup=min_speedup, min_async=min_async)


if __name__ == "__main__":
    import sys

    if "--acceptance" in sys.argv:
        run_acceptance()
    else:
        run()
