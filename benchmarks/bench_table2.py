"""Table 2: Parsa vs baselines on the dataset analogues — improvement % over
random on M_max / T_max / T_sum + runtime."""
from __future__ import annotations

import numpy as np

from repro.core import sequential_parsa
from repro.core.jax_partition import (
    blocked_partition_u,
    blocked_partition_u_hostloop,
)

from .baselines import powergraph_greedy, recursive_bisection
from .common import datasets, emit, score, timed


def run(scale: float = 1.0, k: int = 16, trials: int = 3):
    rows = []
    for dname, g in datasets(scale).items():
        # parsa-tpu-blocked (single-dispatch scan) and -hostloop (seed
        # per-block loop) return identical partitions — the table shows the
        # block-greedy quality delta vs sequential Alg 3 once, and the
        # runtime column shows the dispatch/packing speedup.
        methods = {
            "parsa": lambda g=g: sequential_parsa(g, k, b=16, a=16, seed=0),
            "parsa-tpu-blocked": lambda g=g: blocked_partition_u(
                g, k, block=256, use_kernel=False),
            "parsa-tpu-hostloop": lambda g=g: blocked_partition_u_hostloop(
                g, k, block=256, use_kernel=False),
            "powergraph": lambda g=g: powergraph_greedy(g, k, seed=0),
            "bisection": lambda g=g: recursive_bisection(g, k, seed=0),
        }
        for mname, fn in methods.items():
            scores, ts = [], []
            for t in range(trials if mname.startswith("parsa") else 1):
                parts, dt = timed(fn)
                scores.append(score(g, parts, k, seed=t))
                ts.append(dt)
            agg = {kk: float(np.mean([s[kk] for s in scores]))
                   for kk in scores[0]}
            rows.append({"dataset": dname, "method": mname,
                         "time_s": float(np.mean(ts)), **agg})
    emit(rows, "table2")
    return rows


if __name__ == "__main__":
    run()
