"""Table 2: Parsa vs baselines on the dataset analogues — improvement % over
random on M_max / T_max / T_sum + runtime."""
from __future__ import annotations

import numpy as np

from repro.api import ParsaConfig, partition

from .baselines import powergraph_greedy, recursive_bisection
from .common import datasets, emit, score, timed


def _parsa(g, cfg):
    """(parts, dt) with dt = the backend phase only — apples-to-apples with
    the bare baseline partitioners."""
    res = partition(g, cfg)
    return res.parts_u, res.timings["partition_u"]


def run(scale: float = 1.0, k: int = 16, trials: int = 3):
    rows = []
    seq_cfg = ParsaConfig(k=k, backend="host", blocks=16, init_iters=16,
                          seed=0, refine_v=False)
    dev_cfg = ParsaConfig(k=k, backend="device_scan", block_size=256,
                          use_kernel=False, refine_v=False)
    oracle_cfg = dev_cfg.replace(backend="host_blocked_oracle")
    for dname, g in datasets(scale).items():
        # parsa-tpu-blocked (single-dispatch scan) and -hostloop (seed
        # per-block loop) return identical partitions — the table shows the
        # block-greedy quality delta vs sequential Alg 3 once, and the
        # runtime column shows the dispatch/packing speedup.
        methods = {
            "parsa": lambda g=g: _parsa(g, seq_cfg),
            "parsa-tpu-blocked": lambda g=g: _parsa(g, dev_cfg),
            "parsa-tpu-hostloop": lambda g=g: _parsa(g, oracle_cfg),
            "powergraph": lambda g=g: timed(
                lambda: powergraph_greedy(g, k, seed=0)),
            "bisection": lambda g=g: timed(
                lambda: recursive_bisection(g, k, seed=0)),
        }
        for mname, fn in methods.items():
            scores, ts = [], []
            for t in range(trials if mname.startswith("parsa") else 1):
                parts, dt = fn()
                scores.append(score(g, parts, k, seed=t))
                ts.append(dt)
            agg = {kk: float(np.mean([s[kk] for s in scores]))
                   for kk in scores[0]}
            rows.append({"dataset": dname, "method": mname,
                         "time_s": float(np.mean(ts)), **agg})
    emit(rows, "table2")
    return rows


if __name__ == "__main__":
    run()
