"""Self-implemented partitioning baselines (the paper's comparison set is
METIS/PaToH/Zoltan/PowerGraph — external C packages unavailable offline; we
implement the two reproducible ones + a bisection stand-in):

  * random           — the paper's normalization baseline
  * powergraph       — PowerGraph's greedy streaming vertex-cut [12]
  * bisection        — recursive bisection with BFS-grown halves (the
                       multilevel-family stand-in for METIS/PaToH/Zoltan)
"""
from __future__ import annotations

import numpy as np

from repro.core.bipartite import BipartiteGraph


def powergraph_greedy(graph: BipartiteGraph, k: int, seed: int = 0) -> np.ndarray:
    """Greedy streaming assignment: place u on the partition that already
    covers most of N(u), tie-broken by load (PowerGraph's heuristic adapted
    from edges to example-vertices)."""
    rng = np.random.default_rng(seed)
    order = rng.permutation(graph.num_u)
    cover = np.zeros((k, graph.num_v), dtype=bool)
    load = np.zeros(k, dtype=np.int64)
    parts = np.full(graph.num_u, -1, dtype=np.int32)
    cap = int(np.ceil(graph.num_u / k))
    for u in order:
        nb = graph.neighbors(int(u))
        gains = cover[:, nb].sum(axis=1).astype(np.float64)
        gains[load >= cap] = -np.inf          # balance constraint
        gains -= load / (10.0 * cap)          # light load tie-break
        i = int(np.argmax(gains))
        parts[u] = i
        load[i] += 1
        cover[i, nb] = True
    return parts


def recursive_bisection(graph: BipartiteGraph, k: int, seed: int = 0) -> np.ndarray:
    """BFS-grown balanced bisection, recursively applied (multilevel-family
    stand-in).  Splits on shared-vocabulary affinity."""
    assert k & (k - 1) == 0, "k must be a power of two"
    rng = np.random.default_rng(seed)
    parts = np.zeros(graph.num_u, dtype=np.int32)

    def bisect(u_ids: np.ndarray, label: int, depth: int):
        if depth == 0 or len(u_ids) <= 1:
            parts[u_ids] = label
            return
        sub = graph.subgraph_u(u_ids)
        half = len(u_ids) // 2
        # BFS from a random seed over the doc-word-doc adjacency
        start = int(rng.integers(0, len(u_ids)))
        visited = np.zeros(len(u_ids), dtype=bool)
        v_mark = np.zeros(graph.num_v, dtype=bool)
        queue = [start]
        visited[start] = True
        taken = []
        while queue and len(taken) < half:
            cur = queue.pop()
            taken.append(cur)
            nb = sub.neighbors(cur)
            new_v = nb[~v_mark[nb]]
            v_mark[new_v] = True
            for v in new_v:
                for u2 in sub.v_neighbors(int(v)):
                    if not visited[u2]:
                        visited[u2] = True
                        queue.append(int(u2))
        if len(taken) < half:  # disconnected: pad arbitrarily
            rest = np.flatnonzero(~np.isin(np.arange(len(u_ids)),
                                           np.asarray(taken, dtype=int)))
            taken.extend(rest[: half - len(taken)].tolist())
        mask = np.zeros(len(u_ids), dtype=bool)
        mask[np.asarray(taken[:half], dtype=int)] = True
        bisect(u_ids[mask], label, depth - 1)
        bisect(u_ids[~mask], label + (1 << (depth - 1)), depth - 1)

    bisect(np.arange(graph.num_u), 0, int(np.log2(k)))
    return parts
