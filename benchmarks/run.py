"""Benchmark entrypoint: one function per paper table/figure.
``python -m benchmarks.run [--quick]`` prints name,us_per_call,derived CSVs
to stdout and benchmarks/out/*.csv."""
from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller graphs (CI-scale)")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    scale = 0.35 if args.quick else 1.0

    from . import (bench_chaos, bench_embedding_traffic, bench_fig7_vary_k,
                   bench_fig8_subgraphs, bench_fig9_global_init,
                   bench_fig10_scalability, bench_kernels, bench_sketch,
                   bench_slo, bench_stream, bench_system, bench_table2,
                   bench_table34_dbpg)

    suites = {
        "table2": lambda: bench_table2.run(scale=scale),
        "fig7": lambda: bench_fig7_vary_k.run(scale=0.7 * scale),
        "fig8": lambda: bench_fig8_subgraphs.run(scale=0.6 * scale),
        "fig9": lambda: bench_fig9_global_init.run(scale=0.6 * scale),
        "fig10": lambda: bench_fig10_scalability.run(scale=0.6 * scale),
        "table34": lambda: bench_table34_dbpg.run(scale=scale),
        "embedding": lambda: bench_embedding_traffic.run(),
        "kernels": lambda: bench_kernels.run(scale=scale),
        "sketch": lambda: bench_sketch.run(scale=scale),
        "stream": lambda: bench_stream.run(scale=scale),
        "chaos": lambda: bench_chaos.run(scale=scale),
        "system": lambda: bench_system.run(scale=scale),
        "slo": lambda: bench_slo.run(scale=scale),
    }
    for name, fn in suites.items():
        if args.only and name != args.only:
            continue
        print(f"\n### {name} " + "=" * 50, flush=True)
        t0 = time.time()
        fn()
        print(f"### {name} done in {time.time() - t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()
