"""Elastic Parsa under chaos: kill/add/straggle mid-stream, then prove the
warm repair path earns its keep.

The PR 6 acceptance run (``run_acceptance()``): a text graph arrives in
``chunks`` feeds through an ``ElasticSession`` while a seeded
``ChaosSchedule`` grows the fleet 8→12 (four ``add`` events), kills two
machines (warm §4.4 repair from the surviving packed sets), and straggles
a worker lane.  Asserts:

  * every repair costs exactly ONE ``elastic_repair_scan`` dispatch and
    every grow exactly ONE ``elastic_grow_scan`` (O(1) jitted dispatches
    per elastic op, counted per feed);
  * the whole chaos run is bit-deterministic — the warm-up replay and the
    timed replay produce identical ``parts`` and packed ``s_masks``;
  * warm repair recovers ≥ ``min_repair_speedup``× faster than a cold
    full ``repartition()`` of the same post-stream state (both jit-warmed
    on clones restored from one snapshot, so shapes and state match);
  * the final elastic partition's ``traffic_max`` stays within
    ``max_quality_pct``% of an oracle one-shot ``device_scan`` partition
    of the full graph at the final ``k`` — elasticity is not allowed to
    buy availability with serving traffic.

Per-feed rows land in ``benchmarks/out/chaos_bench.csv`` and the repo-root
``BENCH_pipeline.json`` under ``chaos_rows`` (``report.emit_chaos_bench``).
``run()`` is the CI-scale variant (same assertions minus the wall-clock
floor, noisy on shared runners).
"""
from __future__ import annotations

import dataclasses
import tempfile
import time
from pathlib import Path

import numpy as np

from repro.api import (ChaosEvent, ChaosSchedule, ElasticConfig,
                       ElasticSession, ParsaConfig, ParsaStreamConfig,
                       StreamSession, partition)
from repro.core.jax_partition import dispatch_counter
from repro.graphs import text_like

from .common import (CHAOS_MAX_QUALITY_PCT, CHAOS_MIN_REPAIR_SPEEDUP, emit,
                     score)
from .report import emit_chaos_bench

# kills/adds per feed index — the "disaster script" both replays follow
_EVENTS = (
    ChaosEvent(feed=2, kind="add"),
    ChaosEvent(feed=3, kind="add"),
    ChaosEvent(feed=4, kind="straggle", machine=1, factor=4.0),
    ChaosEvent(feed=5, kind="kill"),           # seeded target
    ChaosEvent(feed=6, kind="add"),
    ChaosEvent(feed=7, kind="add"),
    ChaosEvent(feed=8, kind="recover", machine=1),
    ChaosEvent(feed=9, kind="kill"),           # seeded target
)


def _expected(schedule: ChaosSchedule, feed: int, kind: str) -> int:
    return sum(1 for ev in schedule.events
               if ev.feed == feed and ev.kind == kind)


def _chaos_replay(scfg: ParsaStreamConfig, num_v: int, chunk_graphs,
                  seed: int, check_dispatches: bool):
    """One full chaos run; returns (session, per-feed rows)."""
    chaos = ChaosSchedule(list(_EVENTS), seed=seed)
    sess = ElasticSession(ElasticConfig(stream=scfg), num_v=num_v,
                          chaos=chaos)
    rows = []
    for i, cg in enumerate(chunk_graphs):
        kinds = ";".join(ev.kind for ev in chaos.events if ev.feed == i)
        t0 = time.perf_counter()
        with dispatch_counter() as counts:
            upd = sess.feed(cg)
        feed_s = time.perf_counter() - t0
        if check_dispatches:
            assert counts["stream_feed_scan"] == 1, counts
            assert counts.get("elastic_repair_scan", 0) == \
                _expected(chaos, i, "kill"), (i, counts)
            assert counts.get("elastic_grow_scan", 0) == \
                _expected(chaos, i, "add"), (i, counts)
        rows.append({
            "feed": i, "k": sess.k, "events": kinds or "-",
            "num_u_chunk": cg.num_u, "feed_s": feed_s,
            "traffic_max": int(upd.metrics.traffic_max),
            "migration_bytes_total": int(sess.traffic.migration_bytes),
        })
    assert chaos.remaining == 0, "schedule events never delivered"
    return sess, rows


def _clone(snapshot: Path, scfg_final: ParsaStreamConfig,
           num_v: int) -> ElasticSession:
    """Restore the post-stream state into a fresh elastic wrapper."""
    es = ElasticSession(ElasticConfig(stream=scfg_final), num_v=num_v)
    es.stream = StreamSession.load(snapshot, scfg_final)
    return es


def run(scale: float = 1.0, k0: int = 8, chunks: int = 12,
        min_repair_speedup: float | None = None,
        max_quality_pct: float | None = CHAOS_MAX_QUALITY_PCT):
    """CI-scale chaos benchmark (same shape as the acceptance run)."""
    return run_acceptance(
        n_u=int(12_000 * scale), num_v=int(16_384 * scale), k0=k0,
        chunks=chunks, block=128, min_repair_speedup=min_repair_speedup,
        max_quality_pct=max_quality_pct, name="chaos_bench_quick")


def run_acceptance(n_u: int = 60_000, num_v: int = 49_152, k0: int = 8,
                   chunks: int = 12, block: int = 256,
                   min_repair_speedup: float | None = CHAOS_MIN_REPAIR_SPEEDUP,
                   max_quality_pct: float | None = CHAOS_MAX_QUALITY_PCT,
                   name: str = "chaos_bench"):
    g = text_like(n_u, num_v, mean_len=20, seed=0)
    base = ParsaConfig(k=k0, backend="device_scan", block_size=block,
                       refine_v=False, seed=0)
    scfg = ParsaStreamConfig(base=base, repartition="never")
    bounds = np.linspace(0, n_u, chunks + 1).astype(int)
    chunk_graphs = [g.slice_u(int(bounds[i]), int(bounds[i + 1]))
                    for i in range(chunks)]

    # ---- replay twice: first warms every jit shape the script touches,
    # second is timed; identical outputs = bit-determinism under chaos
    warm_sess, _ = _chaos_replay(scfg, num_v, chunk_graphs, seed=0,
                                 check_dispatches=True)
    sess, rows = _chaos_replay(scfg, num_v, chunk_graphs, seed=0,
                               check_dispatches=True)
    assert np.array_equal(warm_sess.parts, sess.parts), \
        "chaos replay is not bit-deterministic (parts differ)"
    assert np.array_equal(warm_sess.stream.arena.masks_np(),
                          sess.stream.arena.masks_np()), \
        "chaos replay is not bit-deterministic (packed sets differ)"
    final_k = sess.k
    kills = sum(1 for ev in _EVENTS if ev.kind == "kill")
    adds = sum(1 for ev in _EVENTS if ev.kind == "add")
    assert final_k == k0 + adds, (final_k, k0, adds)
    print(f"# chaos replay bit-deterministic: k {k0}->{final_k} "
          f"({adds} adds, {kills} kills), "
          f"{int(sess.traffic.migration_bytes)} migration bytes metered")

    # ---- warm repair vs cold repartition on clones of ONE snapshot
    # (state and jit shapes match exactly; first clone of each mode warms)
    with tempfile.TemporaryDirectory() as td:
        snapshot = Path(td) / "chaos_state.npz"
        sess.stream.save(snapshot)
        scfg_final = dataclasses.replace(
            scfg, base=dataclasses.replace(base, k=final_k))
        lost = int(np.argmax(np.bincount(sess.parts, minlength=final_k)))
        _clone(snapshot, scfg_final, num_v).repair(lost, mode="warm")
        with dispatch_counter() as counts:
            warm_op = _clone(snapshot, scfg_final,
                             num_v).repair(lost, mode="warm")
        assert counts["elastic_repair_scan"] == 1, counts
        _clone(snapshot, scfg_final, num_v).stream.repartition()
        cold = _clone(snapshot, scfg_final, num_v)
        t0 = time.perf_counter()
        cold.stream.repartition()
        cold_s = time.perf_counter() - t0
    warm_s = warm_op.seconds
    repair_speedup = cold_s / warm_s
    print(f"# worst-case repair (machine {lost}, {warm_op.moved_u} rows): "
          f"warm {warm_s:.3f}s vs cold repartition {cold_s:.3f}s = "
          f"{repair_speedup:.1f}x")

    # ---- final quality vs an oracle static partition at the final k
    oracle_cfg = dataclasses.replace(base, k=final_k)
    partition(g, oracle_cfg)                 # warm
    oracle = partition(g, oracle_cfg)
    streamed = score(g, sess.parts, final_k)["traffic_max"]
    baseline = score(g, oracle.parts_u, final_k)["traffic_max"]
    quality_pct = (streamed - baseline) / baseline * 100
    print(f"# final traffic_max {streamed} vs oracle(k={final_k}) "
          f"{baseline} ({quality_pct:+.2f}%)")

    emit(rows, name)
    emit_chaos_bench(rows, quick=name.endswith("_quick"), meta={
        "graph": f"text_like({n_u}x{num_v})", "k0": k0, "k_final": final_k,
        "chunks": chunks, "block_size": block, "adds": adds, "kills": kills,
        "migration_bytes_total": int(sess.traffic.migration_bytes),
        "repair_warm_s": warm_s, "repair_cold_s": cold_s,
        "repair_speedup": repair_speedup,
        "quality_vs_oracle_pct": quality_pct})
    if max_quality_pct is not None:
        assert quality_pct <= max_quality_pct, (
            f"elastic traffic_max {quality_pct:+.2f}% vs oracle "
            f"(limit {max_quality_pct}%)")
    if min_repair_speedup is not None:
        assert repair_speedup >= min_repair_speedup, (
            f"warm repair only {repair_speedup:.1f}x vs cold repartition "
            f"(need ≥{min_repair_speedup}x; rerun on an idle box if "
            f"contended)")
    return rows


if __name__ == "__main__":
    import sys

    if "--acceptance" in sys.argv:
        run_acceptance()
    else:
        run()
