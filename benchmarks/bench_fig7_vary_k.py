"""Figure 7: quality & runtime vs number of partitions k (Parsa improves
with k while bisection-family degrades)."""
from __future__ import annotations

from repro.api import ParsaConfig, partition

from .baselines import powergraph_greedy, recursive_bisection
from .common import datasets, emit, score, timed


def run(scale: float = 0.7):
    rows = []
    data = datasets(scale)
    for dname in ("ctr-like", "social-lj-like"):
        g = data[dname]
        for k in (8, 16, 32, 64):
            for mname in ("parsa", "powergraph", "bisection"):
                if mname == "parsa":
                    # time only the backend phase — apples-to-apples with
                    # the bare baseline partitioners below
                    res = partition(g, ParsaConfig(
                        k=k, blocks=8, init_iters=8, seed=0, refine_v=False))
                    parts, dt = res.parts_u, res.timings["partition_u"]
                elif mname == "powergraph":
                    parts, dt = timed(lambda: powergraph_greedy(g, k, seed=0))
                else:
                    parts, dt = timed(lambda: recursive_bisection(g, k, seed=0))
                rows.append({"dataset": dname, "method": mname, "k": k,
                             "time_s": dt, **score(g, parts, k)})
    emit(rows, "fig7_vary_k")
    return rows


if __name__ == "__main__":
    run()
