"""Figure 7: quality & runtime vs number of partitions k (Parsa improves
with k while bisection-family degrades)."""
from __future__ import annotations

from repro.core import sequential_parsa

from .baselines import powergraph_greedy, recursive_bisection
from .common import datasets, emit, score, timed


def run(scale: float = 0.7):
    rows = []
    data = datasets(scale)
    for dname in ("ctr-like", "social-lj-like"):
        g = data[dname]
        for k in (8, 16, 32, 64):
            for mname, fn in {
                "parsa": lambda: sequential_parsa(g, k, b=8, a=8, seed=0),
                "powergraph": lambda: powergraph_greedy(g, k, seed=0),
                "bisection": lambda: recursive_bisection(g, k, seed=0),
            }.items():
                parts, dt = timed(fn)
                rows.append({"dataset": dname, "method": mname, "k": k,
                             "time_s": dt, **score(g, parts, k)})
    emit(rows, "fig7_vary_k")
    return rows


if __name__ == "__main__":
    run()
