"""Figure 9: parallel partitioning quality vs % of data used for global
initialization (4 workers)."""
from __future__ import annotations

from repro.api import ParsaConfig, partition

from .common import datasets, emit, score


def run(scale: float = 0.6, k: int = 16):
    rows = []
    g = datasets(scale)["ctr-like"]
    for frac in (0.0, 0.001, 0.01, 0.1):
        cfg = ParsaConfig(k=k, backend="parallel_sim", blocks=16, workers=4,
                          tau=None, global_init_frac=frac, seed=0,
                          refine_v=False)
        res = partition(g, cfg)
        # backend phase time == global init + Alg 4 run (as pre-facade)
        rows.append({"init_frac_pct": frac * 100,
                     "time_s": res.timings["partition_u"],
                     "pushed_bytes": res.traffic.pushed_bytes,
                     **score(g, res.parts_u, k)})
    emit(rows, "fig9_global_init")
    return rows


if __name__ == "__main__":
    run()
