"""Figure 9: parallel partitioning quality vs % of data used for global
initialization (4 workers)."""
from __future__ import annotations

from repro.core import ParallelParsa, global_initialization

from .common import datasets, emit, score, timed


def run(scale: float = 0.6, k: int = 16):
    rows = []
    g = datasets(scale)["ctr-like"]
    for frac in (0.0, 0.001, 0.01, 0.1):
        def go():
            S0 = (global_initialization(g, k, sample_frac=frac, seed=0)
                  if frac > 0 else None)
            pp = ParallelParsa(k, workers=4, tau=None, seed=0)
            return pp.run(g, b=16, init_sets=S0)
        rep, dt = timed(go)
        rows.append({"init_frac_pct": frac * 100, "time_s": dt,
                     "pushed_bytes": rep.pushed_bytes,
                     **score(g, rep.parts_u, k)})
    emit(rows, "fig9_global_init")
    return rows


if __name__ == "__main__":
    run()
