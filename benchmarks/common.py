"""Shared benchmark plumbing: dataset analogues (Table 1 scaled to one CPU
core), metric helpers, CSV emission."""
from __future__ import annotations

import pathlib
import time

import numpy as np

from repro.core import evaluate, improvement, partition_v, random_parts
from repro.graphs import ctr_like, natural_to_bipartite, social_like, text_like

OUT = pathlib.Path(__file__).resolve().parent / "out"
OUT.mkdir(exist_ok=True)

# ---------------------------------------------------------------------------
# Acceptance thresholds shared across benchmark gates.  One place to tune
# them, one place for CI and the acceptance runs to agree on — bench_chaos,
# bench_system and bench_slo all import from here instead of hard-coding.
# ---------------------------------------------------------------------------
# bench_chaos: warm §4.4 repair must beat a cold repartition by this much,
# and the post-chaos partition may cost at most this much extra traffic_max
# vs an oracle static partition at the final k.
CHAOS_MIN_REPAIR_SPEEDUP = 3.0
CHAOS_MAX_QUALITY_PCT = 5.0
# bench_system: parsa placement + async overlap vs random + sync end to
# end, and the async-vs-sync overlap win at equal placement.
SYSTEM_MIN_SPEEDUP = 1.3
SYSTEM_MIN_ASYNC = 1.05
# bench_slo: the closed loop must keep the windowed modeled p99 within SLO
# for at least this fraction of post-warmup decision windows, shedding at
# most this fraction of offered requests while doing it.
SLO_MIN_HOLD_FRAC = 0.95
SLO_MAX_SHED_FRAC = 0.05
# bench_sketch: the acceptance sketch geometry must compress the
# width-dependent set structures by at least this ratio, and the sketch-mode
# partition's TRUE-graph traffic_max may exceed the exact-mode run's by at
# most this percentage at the quality-band scale.
SKETCH_MIN_MEM_RATIO = 8.0
SKETCH_MAX_QUALITY_PCT = 5.0


def datasets(scale: float = 1.0) -> dict:
    """Synthetic analogues of Table 1, scaled for a single CPU core."""
    s = scale
    src, dst, n = social_like(int(1500 * s), m=8, seed=2)
    src2, dst2, n2 = social_like(int(1200 * s), m=12, seed=3)
    return {
        "rcv1-like": text_like(int(1600 * s), int(4000 * s), mean_len=60, seed=1),
        "news20-like": text_like(int(900 * s), int(8000 * s), mean_len=80,
                                 zipf_s=1.05, seed=2),
        "ctr-like": ctr_like(int(1500 * s), int(6000 * s), nnz_per_row=25, seed=3),
        "social-lj-like": natural_to_bipartite(src, dst, n),
        "social-orkut-like": natural_to_bipartite(src2, dst2, n2),
    }


def score(graph, parts_u, k, seed=0):
    """(M_max, T_max, T_sum) improvements vs random — Table 2 columns."""
    pv = partition_v(graph, parts_u, k, sweeps=2)
    m = evaluate(graph, parts_u, pv, k)
    mr = evaluate(graph, random_parts(graph.num_u, k, seed),
                  random_parts(graph.num_v, k, seed + 1), k)
    return {
        "M_max_improv_pct": improvement(mr.mem_max, m.mem_max),
        "T_max_improv_pct": improvement(mr.traffic_max, m.traffic_max),
        "T_sum_improv_pct": improvement(mr.traffic_sum, m.traffic_sum),
        "traffic_max": m.traffic_max,
        "mem_max": m.mem_max,
    }


def timed(fn, *args, **kw):
    t0 = time.time()
    out = fn(*args, **kw)
    return out, time.time() - t0


def emit(rows: list[dict], name: str):
    """CSV: name,us_per_call,derived columns."""
    if not rows:
        return
    keys = list(rows[0].keys())
    path = OUT / f"{name}.csv"
    with open(path, "w") as f:
        f.write(",".join(keys) + "\n")
        for r in rows:
            f.write(",".join(f"{r[c]:.4g}" if isinstance(r[c], float)
                             else str(r[c]) for c in keys) + "\n")
    print(f"# wrote {path}")
    for r in rows:
        print(",".join(f"{r[c]:.4g}" if isinstance(r[c], float) else str(r[c])
                       for c in keys))
