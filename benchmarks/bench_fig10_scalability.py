"""Figure 10 + §5.4: workers vs quality under eventual consistency.

Wall-clock speedup cannot be measured on one core; we report the paper's
*quality-robustness* claim (≤ ~5% degradation from 1→16 workers at τ=∞)
plus the work-scaling model (each worker partitions b/W subgraphs)."""
from __future__ import annotations

from repro.api import ParsaConfig, partition
from repro.core import global_initialization

from .common import datasets, emit, score


def run(scale: float = 0.6, k: int = 16, b: int = 32):
    rows = []
    g = datasets(scale)["ctr-like"]
    # §4.4 global init computed ONCE and shared across worker counts
    S0 = global_initialization(g, k, sample_frac=0.01, seed=0)
    base_traffic = None
    for workers in (1, 2, 4, 8, 16):
        cfg = ParsaConfig(k=k, backend="parallel_sim", blocks=b,
                          workers=workers, tau=None, seed=0, refine_v=False)
        res = partition(g, cfg, init_sets=S0)
        s = score(g, res.parts_u, k)
        if base_traffic is None:
            base_traffic = s["traffic_max"]
        rows.append({
            "workers": workers,
            "stale_pushes": res.traffic.stale_pushes_missed,
            "quality_vs_1worker_pct":
                (s["traffic_max"] - base_traffic) / base_traffic * 100,
            "ideal_speedup": workers,
            "modeled_speedup": workers / (1 + 0.02 * workers),  # §5.4: 13.7x@16
            **s,
        })
    emit(rows, "fig10_scalability")
    return rows


if __name__ == "__main__":
    run()
