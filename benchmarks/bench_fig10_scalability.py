"""Figure 10 + §5.4: workers vs wall-clock and quality under staleness.

Two parallel runtimes of Algorithm 4 over the same packed-bitmask wire
format:

  * ``parallel_sim``    — deterministic host simulation (W workers, bounded
    delay τ).  One core executes all W workers' tasks sequentially, so its
    wall-clock *rises* with problem size; we report the paper's
    quality-robustness claim (≤ ~5% degradation under staleness).
  * ``parallel_device`` — the real thing: shard_map fans the blocked scans
    out across devices with periodic all_gather+OR merges.  Wall-clock,
    traffic, and quality are measured per worker count (requires
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` on CPU hosts to
    sweep past one worker).

Both sweeps land in ``fig10_scalability.csv`` (human) and
``BENCH_parsa.json`` (machine-readable trajectory via benchmarks/report.py).

``run(acceptance=True)`` runs the PR acceptance comparison instead: the
100k×65k synthetic graph, ``parallel_device`` (8 workers) vs
``parallel_sim``, asserting ≥5x wall-clock at equal quality (traffic_max
within 5% of the sequential backend, per §5.4).
"""
from __future__ import annotations

import jax

from repro.api import ParsaConfig, partition
from repro.core import global_initialization
from repro.graphs import text_like

from .common import datasets, emit, score
from .report import emit_parsa_bench, emit_pipeline_bench, pipeline_phase_rows


def _row(backend, workers, res, g, k, base_traffic):
    s = score(g, res.parts_u, k)
    return {
        "backend": backend,
        "workers": workers,
        "sketch": int(getattr(res.config, "set_repr", "exact") == "sketch"),
        # pack + scan: device backends split host-side packing into its own
        # timing entry, but it is still wall clock this backend spends —
        # keep the cross-backend comparison scope-equal
        "wall_clock_s": res.timings.get("pack", 0.0)
        + res.timings["partition_u"],
        "pushed_bytes": res.traffic.pushed_bytes,
        "pulled_bytes": res.traffic.pulled_bytes,
        "stale_pushes": res.traffic.stale_pushes_missed,
        "quality_vs_seq_pct":
            (s["traffic_max"] - base_traffic) / base_traffic * 100
            if base_traffic else 0.0,
        **s,
    }


def _pipeline_phases(g, cfg_host, min_refine_speedup: float | None = None):
    """Time the full one-call pipeline host-refine vs device-refine.

    Returns (rows for BENCH_pipeline, refine-phase speedup) where the
    refine phase is partition_v + metrics — the Amdahl tail PRs 1/3 left
    behind.  Both paths are warmed so the numbers are steady-state; device
    parts_v/metrics are asserted bit-equal to host before timing counts.
    """
    import numpy as np

    cfg_dev = cfg_host.replace(refine_backend="device")
    partition(g, cfg_host)                    # warm both pipelines
    partition(g, cfg_dev)
    host = partition(g, cfg_host)
    dev = partition(g, cfg_dev)
    assert np.array_equal(host.parts_v, dev.parts_v), "device refine drifted"
    assert host.metrics.as_dict() == dev.metrics.as_dict()
    refine_host = host.timings["partition_v"] + host.timings["metrics"]
    refine_dev = dev.timings["partition_v"] + dev.timings["metrics"]
    speedup = refine_host / refine_dev
    rows = (pipeline_phase_rows(host, cfg_host.backend, "host")
            + pipeline_phase_rows(dev, cfg_dev.backend, "device"))
    for r in rows:
        print(r)
    print(f"# device refine (partition_v + metrics): {refine_host:.3f}s → "
          f"{refine_dev:.3f}s = {speedup:.1f}x")
    if min_refine_speedup is not None:
        assert speedup >= min_refine_speedup, (
            f"device refine only {speedup:.1f}x vs host (need "
            f"≥{min_refine_speedup}x; rerun on an idle box if contended)")
    return rows, speedup


def run(scale: float = 0.6, k: int = 16, b: int = 32, acceptance: bool = False):
    if acceptance:
        return run_acceptance(k=k)
    rows = []
    g = datasets(scale)["ctr-like"]
    # §4.4 global init computed ONCE and shared across worker counts
    S0 = global_initialization(g, k, sample_frac=0.01, seed=0)
    base_traffic = None
    for workers in (1, 2, 4, 8, 16):
        cfg = ParsaConfig(k=k, backend="parallel_sim", blocks=b,
                          workers=workers, tau=None, seed=0, refine_v=False)
        res = partition(g, cfg, init_sets=S0)
        if base_traffic is None:
            base_traffic = score(g, res.parts_u, k)["traffic_max"]
        rows.append({**_row("parallel_sim", workers, res, g, k, base_traffic),
                     "ideal_speedup": workers,
                     "modeled_speedup": workers / (1 + 0.02 * workers)})
    n_dev = len(jax.devices())
    for workers in (1, 2, 4, 8):
        if workers > n_dev:
            print(f"# skipping parallel_device workers={workers}: only "
                  f"{n_dev} devices (set XLA_FLAGS="
                  f"--xla_force_host_platform_device_count=8)")
            continue
        cfg = ParsaConfig(k=k, backend="parallel_device", workers=workers,
                          merge_every=2, seed=0, refine_v=False)
        partition(g, cfg, init_sets=S0)          # warm the jitted pipeline
        res = partition(g, cfg, init_sets=S0)
        rows.append({**_row("parallel_device", workers, res, g, k,
                            base_traffic),
                     "ideal_speedup": workers,
                     "modeled_speedup": workers / (1 + 0.02 * workers)})
    # sketched sets over the same wire format: the workers OR-merge sketch
    # buckets instead of full masks — all_gather bytes shrink by the column
    # compression, the row schema (and quality column) stays the same
    if n_dev >= 2:
        w = min(8, n_dev)
        hot = max(32, (g.num_v // 3) // 32 * 32)
        cfg = ParsaConfig(k=k, backend="parallel_device", workers=w,
                          merge_every=2, seed=0, refine_v=False,
                          set_repr="sketch", sketch_hot_bits=hot,
                          sketch_bucket_bits=max(32, hot // 64 * 32))
        partition(g, cfg, init_sets=S0)          # warm the jitted pipeline
        res = partition(g, cfg, init_sets=S0)
        rows.append({**_row("parallel_device", w, res, g, k, base_traffic),
                     "ideal_speedup": w,
                     "modeled_speedup": w / (1 + 0.02 * w)})
    emit(rows, "fig10_scalability")
    emit_parsa_bench(rows, meta={"graph": f"ctr-like(scale={scale})",
                                 "k": k, "b": b,
                                 "quality_baseline": "parallel_sim_w1"})
    # per-phase pipeline trajectory (small graph — the acceptance run
    # re-emits this at the 100k×65k scale with the speedup floor asserted)
    pipe_rows, refine_speedup = _pipeline_phases(
        g, ParsaConfig(k=k, backend="device_scan", sweeps=2, seed=0))
    emit(pipe_rows, "fig10_pipeline_phases")
    emit_pipeline_bench(pipe_rows, meta={
        "graph": f"ctr-like(scale={scale})", "k": k,
        "refine_speedup_device_vs_host": refine_speedup})
    return rows


def run_acceptance(n_u: int = 100_000, num_v: int = 65_536, k: int = 16,
                   workers: int = 8, b: int = 64,
                   min_speedup: float | None = 5.0,
                   max_quality_pct: float | None = 5.0,
                   min_refine_speedup: float | None = 5.0):
    """The PR acceptance benchmark (§5.4 scale): parallel_device vs
    parallel_sim wall-clock at equal quality on the 100k×65k graph, plus
    the per-phase pipeline comparison — device-resident Algorithm 2 +
    packed metrics vs the host oracles (``min_refine_speedup``x floor on
    the partition_v + metrics phases).

    Asserts ``min_speedup``x wall-clock and ``max_quality_pct``% traffic_max
    vs the sequential baseline (pass None to only report — e.g. on a loaded
    shared box where wall-clock is noisy)."""
    n_dev = len(jax.devices())
    if n_dev < workers:
        raise SystemExit(
            f"acceptance needs {workers} devices, have {n_dev}; run with "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={workers}")
    g = text_like(n_u, num_v, mean_len=20, seed=0)
    rows = []

    cfg_seq = ParsaConfig(k=k, backend="device_scan", refine_v=False, seed=0)
    partition(g, cfg_seq)                        # warm the jitted pipeline
    seq = partition(g, cfg_seq)
    base = score(g, seq.parts_u, k)["traffic_max"]
    rows.append({"backend": "device_scan", "workers": 1, "sketch": 0,
                 "wall_clock_s": seq.timings["pack"]
                 + seq.timings["partition_u"],
                 "pushed_bytes": 0, "pulled_bytes": 0, "stale_pushes": 0,
                 "quality_vs_seq_pct": 0.0, "traffic_max": base})

    # B=128 halves the per-round tile work of the jnp path (∝ B) while the
    # merge cadence of 12 blocks keeps staleness ≈ 1.5k vertices per worker
    # — quality stays within the paper's ~5% band (§5.4)
    cfg_dev = ParsaConfig(k=k, backend="parallel_device", workers=workers,
                          block_size=128, merge_every=12, seed=0,
                          refine_v=False)
    partition(g, cfg_dev)                        # warm the jitted pipeline
    dev = partition(g, cfg_dev)
    rows.append(_row("parallel_device", workers, dev, g, k, base))

    cfg_sim = ParsaConfig(k=k, backend="parallel_sim", blocks=b,
                          workers=workers, tau=None, seed=0, refine_v=False)
    sim = partition(g, cfg_sim)
    rows.append(_row("parallel_sim", workers, sim, g, k, base))

    speedup = sim.timings["partition_u"] / (
        dev.timings["pack"] + dev.timings["partition_u"])
    for r in rows:
        print(r)
    quality_pct = rows[1]["quality_vs_seq_pct"]
    print(f"# parallel_device speedup vs parallel_sim: {speedup:.1f}x; "
          f"quality delta vs sequential: {quality_pct:+.2f}%")
    if max_quality_pct is not None:
        assert quality_pct <= max_quality_pct, (
            f"quality degraded {quality_pct:+.2f}% vs sequential "
            f"(limit {max_quality_pct}%)")
    if min_speedup is not None:
        assert speedup >= min_speedup, (
            f"parallel_device only {speedup:.1f}x vs parallel_sim "
            f"(need ≥{min_speedup}x; rerun on an idle box if contended)")
    # --- the PR 4 phase rows: partition_v / metrics / total, host vs device
    pipe_rows, refine_speedup = _pipeline_phases(
        g, ParsaConfig(k=k, backend="device_scan", sweeps=2, seed=0),
        min_refine_speedup=min_refine_speedup)

    emit(rows, "fig10_acceptance")
    emit(pipe_rows, "fig10_pipeline_phases")
    emit_parsa_bench(rows, name="BENCH_parsa_acceptance",
                     meta={"graph": f"text_like({n_u}x{num_v})", "k": k,
                           "speedup_device_vs_sim": speedup,
                           "quality_baseline": "device_scan"})
    emit_pipeline_bench(pipe_rows, meta={
        "graph": f"text_like({n_u}x{num_v})", "k": k,
        "refine_speedup_device_vs_host": refine_speedup})
    return rows


if __name__ == "__main__":
    import sys

    run(acceptance="--acceptance" in sys.argv)
