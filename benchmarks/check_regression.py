"""Bench regression gate: fresh quick-suite BENCH rows vs committed baselines.

CI (the ``bench-regression`` job) copies the committed ``BENCH_pipeline.json``
/ ``BENCH_system.json`` into a baseline directory, re-runs the quick suites
(``python -m benchmarks.run --quick --only {stream,chaos,system,slo}``) so the
repo-root files carry fresh ``*_quick`` sections, then runs::

    python -m benchmarks.check_regression --baseline <dir>

The gate compares only the ``*_quick`` sections (the acceptance sections are
produced on dedicated boxes, not CI runners) and fails when any wall-clock
ratio degrades by more than ``--band`` (default 25%) or any traffic metric
grows by more than the same band.  Wall-clock metrics are compared as
*ratios* (speedups, examples/s) rather than raw seconds so shared-runner
noise cancels where both sides slow down together; traffic metrics are
deterministic byte/assignment counts, so the band only forgives intentional
small drifts — anything larger needs a baseline update.

``schema_version`` must match on both sides — a version bump means keys were
renamed/removed, and the checker refuses to mis-parse across that boundary.

``--update-baseline`` copies the fresh repo-root files over the baseline dir
(for refreshing a committed baseline after an intentional perf change).
"""
from __future__ import annotations

import argparse
import json
import pathlib
import shutil
import sys

from .report import ROOT, SCHEMA_VERSION

BENCH_FILES = ("BENCH_pipeline.json", "BENCH_system.json")

# (file, section path, metric, direction) — direction is which way the
# metric is allowed to move freely: "higher" metrics fail when the fresh
# value drops below (1-band)x baseline, "lower" metrics fail when it rises
# above (1+band)x.  A path element of -1 indexes the last row of a list.
CHECKS = (
    ("BENCH_pipeline.json", ("stream_meta_quick", "speedup_vs_scratch"),
     "higher"),
    ("BENCH_pipeline.json", ("stream_rows_quick", -1, "traffic_max"),
     "lower"),
    ("BENCH_pipeline.json", ("chaos_meta_quick", "repair_speedup"),
     "higher"),
    ("BENCH_pipeline.json", ("chaos_meta_quick", "migration_bytes_total"),
     "lower"),
    ("BENCH_pipeline.json", ("chaos_rows_quick", -1, "traffic_max"),
     "lower"),
    ("BENCH_system.json", ("meta_quick", "speedup_parsa_async_vs_random_sync"),
     "higher"),
    ("BENCH_system.json", ("meta_quick", "traffic_cut_pct"), "higher"),
    ("BENCH_system.json", ("slo_meta_quick", "examples_s"), "higher"),
    ("BENCH_system.json", ("slo_meta_quick", "shed_frac"), "lower"),
)


def _dig(payload, path):
    cur = payload
    for key in path:
        try:
            cur = cur[key]
        except (KeyError, IndexError, TypeError):
            return None
    return cur if isinstance(cur, (int, float)) and not isinstance(
        cur, bool) else None


def _load(dir_path: pathlib.Path, name: str) -> dict | None:
    path = dir_path / name
    if not path.exists():
        return None
    return json.loads(path.read_text())


def check(baseline_dir: pathlib.Path, fresh_dir: pathlib.Path = ROOT,
          band: float = 0.25) -> tuple[list[str], list[str]]:
    """Compare fresh quick sections vs the baseline.  Returns
    (failures, notes); empty failures means the gate passes."""
    failures: list[str] = []
    notes: list[str] = []
    payloads: dict[str, tuple[dict, dict]] = {}
    for name in BENCH_FILES:
        base, fresh = _load(baseline_dir, name), _load(fresh_dir, name)
        if base is None or fresh is None:
            failures.append(f"{name}: missing on "
                            f"{'baseline' if base is None else 'fresh'} side")
            continue
        bv, fv = base.get("schema_version"), fresh.get("schema_version")
        if fv != SCHEMA_VERSION:
            failures.append(f"{name}: fresh schema_version {fv!r} != "
                            f"checker's {SCHEMA_VERSION}")
            continue
        if bv != fv:
            failures.append(f"{name}: baseline schema_version {bv!r} != "
                            f"fresh {fv!r} — refusing cross-version compare "
                            f"(update the baseline)")
            continue
        payloads[name] = (base, fresh)

    compared = 0
    for name, path, direction in CHECKS:
        if name not in payloads:
            continue
        base, fresh = payloads[name]
        label = f"{name}:{'.'.join(str(p) for p in path)}"
        bval, fval = _dig(base, path), _dig(fresh, path)
        if bval is None or fval is None:
            notes.append(f"skip {label}: missing on "
                         f"{'baseline' if bval is None else 'fresh'} side")
            continue
        compared += 1
        if bval == 0:
            notes.append(f"skip {label}: baseline is 0 (relative band "
                         f"degenerate); fresh={fval:g}")
            continue
        ratio = fval / bval
        ok = ratio >= 1 - band if direction == "higher" else ratio <= 1 + band
        verdict = "ok" if ok else "FAIL"
        line = (f"{verdict:4s} {label}: baseline {bval:g} -> fresh {fval:g} "
                f"({ratio:.2f}x baseline, {direction} is better, "
                f"band {band:.0%})")
        notes.append(line)
        if not ok:
            failures.append(line)
    if compared == 0 and not failures:
        failures.append("no metrics compared — quick sections absent on "
                        "both sides? run the quick suites first")
    return failures, notes


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline", type=pathlib.Path,
                    help="directory holding the baseline BENCH_*.json files")
    ap.add_argument("--band", type=float, default=0.25,
                    help="allowed fractional degradation (default 0.25)")
    ap.add_argument("--update-baseline", type=pathlib.Path, default=None,
                    metavar="DIR",
                    help="copy the fresh repo-root BENCH files into DIR "
                         "and exit (no comparison)")
    args = ap.parse_args()

    if args.update_baseline is not None:
        args.update_baseline.mkdir(parents=True, exist_ok=True)
        for name in BENCH_FILES:
            src = ROOT / name
            if src.exists():
                shutil.copy2(src, args.update_baseline / name)
                print(f"# baseline updated: {args.update_baseline / name}")
        return 0

    if args.baseline is None:
        ap.error("--baseline is required (or use --update-baseline)")
    failures, notes = check(args.baseline, band=args.band)
    for line in notes:
        print(line)
    if failures:
        print(f"\n{len(failures)} regression check(s) FAILED:")
        for line in failures:
            print(f"  {line}")
        return 1
    print("\nbench regression gate: PASS")
    return 0


if __name__ == "__main__":
    sys.exit(main())
