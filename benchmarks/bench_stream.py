"""Streaming Parsa acceptance: online ``feed()`` vs from-scratch repartition.

The PR 5 acceptance run (``run_acceptance()``): the 100k×65k text graph
arrives in 16 chunks (k=16, CPU host) and we compare, per chunk,

  * ``StreamSession.feed(chunk)``   — one scan dispatch against the live
    packed server sets (O(chunk) work, O(1) dispatches, asserted); vs
  * repartitioning the whole prefix graph from scratch with the
    ``device_scan`` backend at every arrival (O(stream) work) — what a
    system without streaming state would have to do.

Asserts: mean per-chunk ``feed`` ≥ ``min_speedup``× faster than the mean
from-scratch repartition (both warmed, scope-equal pack+scan wall clock);
the final streamed partition's ``traffic_max`` within ``max_quality_pct``%
of a one-shot ``device_scan`` partition of the full graph; the one-chunk
degenerate feed bit-identical to ``device_scan``.  Per-chunk rows land in
``benchmarks/out/stream_bench.csv`` and the repo-root
``BENCH_pipeline.json`` (``report.emit_stream_bench``).

``run()`` is the CI-scale variant: same assertions (minus the wall-clock
floor, noisy on shared runners) on a small graph.
"""
from __future__ import annotations

import numpy as np

from repro.api import ParsaConfig, ParsaStreamConfig, StreamSession, partition
from repro.core.jax_partition import dispatch_counter
from repro.graphs import text_like

from .common import emit, score
from .report import emit_stream_bench


def _feed_wall(upd) -> float:
    # scope-equal to the scratch runs: host packing + the scan itself
    return upd.timings["pack"] + upd.timings["partition_u"]


def _scratch_wall(res) -> float:
    return res.timings["pack"] + res.timings["partition_u"]


def run(scale: float = 1.0, k: int = 8, chunks: int = 8,
        min_speedup: float | None = None,
        max_quality_pct: float | None = 5.0):
    """CI-scale streaming benchmark (same shape as the acceptance run)."""
    return run_acceptance(
        n_u=int(12_000 * scale), num_v=int(16_384 * scale), k=k,
        chunks=chunks, block=128, min_speedup=min_speedup,
        max_quality_pct=max_quality_pct, name="stream_bench_quick")


def run_acceptance(n_u: int = 100_000, num_v: int = 65_536, k: int = 16,
                   chunks: int = 16, block: int = 256,
                   min_speedup: float | None = 5.0,
                   max_quality_pct: float | None = 5.0,
                   name: str = "stream_bench"):
    g = text_like(n_u, num_v, mean_len=20, seed=0)
    base = ParsaConfig(k=k, backend="device_scan", block_size=block,
                       refine_v=False, seed=0)
    scfg = ParsaStreamConfig(base=base, repartition="never")
    bounds = np.linspace(0, n_u, chunks + 1).astype(int)
    chunk_graphs = [g.slice_u(int(bounds[i]), int(bounds[i + 1]))
                    for i in range(chunks)]

    # ---- one-shot baseline (warmed) + degenerate one-chunk parity
    partition(g, base)
    one_shot = partition(g, base)
    sess_parity = StreamSession(scfg, num_v=num_v)
    sess_parity.feed(g)
    assert np.array_equal(sess_parity.parts, one_shot.parts_u), \
        "one-chunk feed is not bit-identical to device_scan"
    assert np.array_equal(sess_parity.arena.masks_np(), one_shot.s_masks)
    print(f"# one-chunk degenerate parity: bit-identical "
          f"({n_u} vertices, k={k})")

    # ---- warm the chunk-shaped feed scan, then time a fresh stream
    warm = StreamSession(scfg, num_v=num_v)
    for cg in chunk_graphs:
        warm.feed(cg)
    sess = StreamSession(scfg, num_v=num_v)
    feeds = []
    for cg in chunk_graphs:
        with dispatch_counter() as counts:
            upd = sess.feed(cg)
        assert counts["stream_feed_scan"] == 1, counts
        assert counts["stream_metrics"] == 1, counts
        feeds.append(upd)

    # ---- from-scratch repartition of every prefix (each shape warmed)
    scratch_s = []
    for i in range(chunks):
        prefix = g.slice_u(0, int(bounds[i + 1]))
        partition(prefix, base)              # warm this prefix's shapes
        scratch_s.append(_scratch_wall(partition(prefix, base)))

    rows = []
    for i, upd in enumerate(feeds):
        f, s = _feed_wall(upd), scratch_s[i]
        rows.append({
            "chunk": i, "num_u_chunk": int(bounds[i + 1] - bounds[i]),
            "num_u_total": int(bounds[i + 1]), "feed_s": f,
            "scratch_s": s, "speedup_vs_scratch": s / f,
            "traffic_max": int(upd.metrics.traffic_max),
        })
    mean_feed = float(np.mean([r["feed_s"] for r in rows]))
    mean_scratch = float(np.mean(scratch_s))
    speedup = mean_scratch / mean_feed

    # ---- final quality vs the one-shot partition (full objectives)
    streamed = score(g, sess.parts, k)["traffic_max"]
    baseline = score(g, one_shot.parts_u, k)["traffic_max"]
    quality_pct = (streamed - baseline) / baseline * 100
    emit(rows, name)
    emit_stream_bench(rows, quick=name.endswith("_quick"), meta={
        "graph": f"text_like({n_u}x{num_v})", "k": k, "chunks": chunks,
        "block_size": block, "mean_feed_s": mean_feed,
        "mean_scratch_s": mean_scratch, "speedup_vs_scratch": speedup,
        "quality_vs_one_shot_pct": quality_pct})
    print(f"# mean feed {mean_feed:.3f}s vs mean from-scratch "
          f"{mean_scratch:.3f}s = {speedup:.1f}x; final traffic_max "
          f"{streamed} vs one-shot {baseline} ({quality_pct:+.2f}%)")
    if max_quality_pct is not None:
        assert quality_pct <= max_quality_pct, (
            f"streamed traffic_max {quality_pct:+.2f}% vs one-shot "
            f"(limit {max_quality_pct}%)")
    if min_speedup is not None:
        assert speedup >= min_speedup, (
            f"feed only {speedup:.1f}x vs from-scratch (need "
            f"≥{min_speedup}x; rerun on an idle box if contended)")
    return rows


if __name__ == "__main__":
    import sys

    if "--acceptance" in sys.argv:
        run_acceptance()
    else:
        run()
